//! Build-time probe for AVX-512 intrinsic support.
//!
//! The `core::arch::x86_64` AVX-512 intrinsics (`_mm512_madd_epi16` etc.)
//! stabilized in rustc 1.89; the SSE2/AVX2 ones have been stable since 1.27.
//! The AVX-512 micro-kernel in `rust/src/gemm/dispatch.rs` is therefore
//! compiled only when (a) the target is x86-64 and (b) the compiler is new
//! enough — older toolchains silently fall back to the scalar/SSE2/AVX2 set,
//! keeping the crate buildable everywhere with zero new dependencies.

use std::process::Command;

fn main() {
    // Declare the custom cfg so `unexpected_cfgs` stays quiet on toolchains
    // that check cfg names (rustc >= 1.80 / cargo >= 1.77).
    println!("cargo::rustc-check-cfg=cfg(iaoi_avx512)");
    println!("cargo:rerun-if-changed=build.rs");
    println!("cargo:rerun-if-env-changed=RUSTC");
    let x86_64 = std::env::var("CARGO_CFG_TARGET_ARCH").as_deref() == Ok("x86_64");
    if x86_64 && rustc_at_least(1, 89) {
        println!("cargo:rustc-cfg=iaoi_avx512");
    }
}

/// True when `$RUSTC --version` reports at least `major.minor`. Any parse
/// failure answers `false` — losing the AVX-512 variant is safe, failing the
/// build is not.
fn rustc_at_least(major: u32, minor: u32) -> bool {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = match Command::new(rustc).arg("--version").output() {
        Ok(out) => out,
        Err(_) => return false,
    };
    let text = String::from_utf8_lossy(&out.stdout);
    // Format: "rustc 1.89.0 (abc123 2025-07-01)" (possibly "-nightly" etc.).
    let Some(version) = text.split_whitespace().nth(1) else {
        return false;
    };
    let mut parts = version.split(['.', '-']);
    let (Some(maj), Some(min)) = (
        parts.next().and_then(|v| v.parse::<u32>().ok()),
        parts.next().and_then(|v| v.parse::<u32>().ok()),
    ) else {
        return false;
    };
    maj > major || (maj == major && min >= minor)
}
