//! Coordinator benchmark: serving throughput and latency under different
//! batching policies — quantifies the dynamic batcher's contribution on
//! top of the integer engine's per-image win (the L3 serving story).
//!
//! Run: `cargo bench --bench coordinator`

use iaoi::coordinator::{BatchPolicy, Coordinator, EngineKind};
use iaoi::data::{ClassificationSet, Rng};
use iaoi::graph::builders::papernet_random;
use iaoi::nn::FusedActivation;
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let float_model = papernet_random(16, FusedActivation::Relu6, 3);
    let mut rng = Rng::seeded(9);
    let calib: Vec<Tensor<f32>> = (0..3)
        .map(|_| {
            let mut d = vec![0f32; 2 * 16 * 16 * 3];
            for v in d.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            Tensor::from_vec(&[2, 16, 16, 3], d)
        })
        .collect();
    let (folded, int8_model) = quantize_graph(&float_model, &calib, QuantizeOptions::default());
    let ds = ClassificationSet::new(16, 16, 11);
    // Smoke mode (CI): enough requests to exercise batching, not to measure.
    let requests = if iaoi::bench_util::smoke_mode() { 32usize } else { 1024 };

    println!("== coordinator throughput ({requests} closed-loop requests, burst 32) ==");
    for (label, engine) in [
        ("int8", EngineKind::Quant(Arc::new(int8_model))),
        ("float32", EngineKind::Float(Arc::new(folded))),
    ] {
        for max_batch in [1usize, 4, 8, 16] {
            let policy = BatchPolicy { max_batch, max_delay: Duration::from_millis(1), ..Default::default() };
            let coord = Coordinator::start(engine.clone(), policy, 1);
            let client = coord.client();
            let start = Instant::now();
            let mut done = 0usize;
            while done < requests {
                let burst: Vec<_> = (0..32.min(requests - done))
                    .map(|i| {
                        let (img, _) = ds.example(3, (done + i) as u64);
                        client.submit(img).expect("submit")
                    })
                    .collect();
                done += burst.len();
                for (_, rx) in burst {
                    rx.recv().expect("response");
                }
            }
            let wall = start.elapsed().as_secs_f64();
            let m = coord.shutdown();
            let (p50, p95, _, _) = m.latency_summary_us();
            println!(
                "{label:<8} max_batch={max_batch:<3} {:>8.0} req/s   p50 {p50:>6}us  p95 {p95:>6}us  mean batch {:.2}",
                requests as f64 / wall,
                m.mean_batch_size()
            );
        }
        println!();
    }
}
