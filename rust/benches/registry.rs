//! Fleet lifecycle benchmark: what a multi-tenant registry pays to cycle
//! models in and out of residency. Two claims are measured:
//!
//! 1. **Warm reinstall beats cold install.** A cold install (copy-mode
//!    read + eager panel packing) pays the full decode + pack cost up
//!    front. An evict→reinstall cycle of an mmap-backed, lazily-prepared
//!    model re-reads page-cache-resident bytes and packs nothing until
//!    first touch — its p50 should sit strictly below the cold p50.
//! 2. **An LRU-capped fleet keeps serving under churn.** 32 models behind
//!    a cap of 8, driven by a Zipf-distributed request mix: misses
//!    reinstall from tombstones (evicting the least-recent resident),
//!    hits run straight off the resident plan.
//!
//! Emits `BENCH_registry.json` (CI grep-asserts a non-zero
//! `"evictions_total"`).
//!
//! Run: `cargo bench --bench registry`
//! (CI runs it under `IAOI_BENCH_SMOKE=1`, whose numbers are not
//! meaningful.)

use iaoi::bench_util::counting_alloc::{self, CountingAlloc};
use iaoi::bench_util::{bench, smoke_mode, Sample};
use iaoi::coordinator::registry::{ModelRegistry, ResidencyPolicy};
use iaoi::data::Rng;
use iaoi::gemm::PrepareMode;
use iaoi::graph::ExecState;
use iaoi::harness::demo_artifact;
use iaoi::model_format::{self, LoadMode};
use iaoi::tensor::Tensor;
use std::path::{Path, PathBuf};
use std::time::Instant;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const FLEET: usize = 32;
const CAP: usize = 8;

fn fleet_dir() -> PathBuf {
    std::env::temp_dir().join(format!("iaoi-bench-registry-{}", std::process::id()))
}

fn model_name(i: usize) -> String {
    format!("m{i:02}")
}

/// Write the 32 tiny fleet artifacts; returns their paths in model order.
fn write_fleet(dir: &Path) -> Vec<PathBuf> {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).expect("create fleet dir");
    (0..FLEET)
        .map(|i| {
            let name = model_name(i);
            let art = demo_artifact(&name, 1, 8, i as u64);
            let path = dir.join(format!("{name}.iaoiq"));
            model_format::write_file(&path, &art).expect("write artifact");
            path
        })
        .collect()
}

fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

/// Cold install (copy + eager, fresh every time) vs warm evict→reinstall
/// (mmap + lazy, artifact bytes page-cache-resident). Also reports the
/// peak transient allocation of one cycle of each.
fn install_cases(path: &Path) -> (Sample, Sample, u64, u64) {
    let name = model_name(0);
    let cold_reg = ModelRegistry::new();
    // Pin modes explicitly so the comparison is stable under the CI
    // IAOI_PREPARE=lazy / IAOI_LOAD lanes.
    cold_reg.set_prepare_mode(PrepareMode::Eager);
    let cold = bench("cold install [copy + eager]", 10, || {
        cold_reg.remove(&name);
        let v = cold_reg.register_file_with(path, LoadMode::Copy).expect("install").version;
        std::hint::black_box(v);
    });
    let cold_peak = counting_alloc::measure(|| {
        cold_reg.remove(&name);
        let v = cold_reg.register_file_with(path, LoadMode::Copy).expect("install").version;
        std::hint::black_box(v);
    })
    .peak_bytes;

    let warm_reg = ModelRegistry::new();
    warm_reg.set_prepare_mode(PrepareMode::Lazy);
    warm_reg.register_file_with(path, LoadMode::Mmap).expect("seed install");
    let warm = bench("warm evict + reinstall [mmap + lazy]", 10, || {
        warm_reg.evict(&name).expect("evict");
        let v = warm_reg.reinstall(&name).expect("reinstall").version;
        std::hint::black_box(v);
    });
    let warm_peak = counting_alloc::measure(|| {
        warm_reg.evict(&name).expect("evict");
        let v = warm_reg.reinstall(&name).expect("reinstall").version;
        std::hint::black_box(v);
    })
    .peak_bytes;
    (cold, warm, cold_peak, warm_peak)
}

/// What the Zipf-driven fleet churn observed.
struct ChurnStats {
    requests: usize,
    misses: usize,
    evictions_total: u64,
    hit_p50_ms: f64,
    miss_p50_ms: f64,
    resident_models: usize,
    resident_plan_bytes: usize,
}

/// Drive a 32-model fleet behind an LRU cap of 8 with a Zipf(1) request
/// mix: every request resolves (reinstalling from the tombstone on a
/// miss) and runs one inference on the resident plan.
fn churn_case(paths: &[PathBuf]) -> ChurnStats {
    let fleet = ModelRegistry::new();
    fleet.set_prepare_mode(PrepareMode::Lazy);
    fleet.set_residency(ResidencyPolicy { max_resident_models: CAP });
    for p in paths {
        fleet.register_file_with(p, LoadMode::Mmap).expect("fleet install");
    }
    assert_eq!(fleet.len(), CAP, "installs past the cap must LRU-evict");
    assert_eq!(fleet.cold_names().len(), FLEET - CAP);

    // Zipf(1) over model rank: weight 1/(rank+1), model 0 most popular —
    // the mix that keeps a hot working set resident while the tail churns.
    let weights: Vec<f64> = (0..FLEET).map(|i| 1.0 / (i as f64 + 1.0)).collect();
    let total: f64 = weights.iter().sum();
    let mut rng = Rng::seeded(17);
    let mut pick = move || {
        let mut u = rng.range_f32(0.0, total as f32) as f64;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= *w;
        }
        FLEET - 1
    };

    let requests = if smoke_mode() { 64 } else { 2_000 };
    let img = Tensor::<f32>::zeros(&[1, 16, 16, 3]);
    let mut state = ExecState::new();
    let mut hits_ms = Vec::new();
    let mut misses_ms = Vec::new();
    for _ in 0..requests {
        let name = model_name(pick());
        let t = Instant::now();
        match fleet.resolve(&name) {
            Ok(entry) => {
                std::hint::black_box(entry.plan.run(&img, &mut state).len());
                hits_ms.push(t.elapsed().as_secs_f64() * 1e3);
            }
            Err(_) => {
                let entry = fleet.reinstall(&name).expect("reinstall from tombstone");
                std::hint::black_box(entry.plan.run(&img, &mut state).len());
                misses_ms.push(t.elapsed().as_secs_f64() * 1e3);
            }
        }
    }

    let resident_plan_bytes: usize = fleet
        .names()
        .iter()
        .filter_map(|n| fleet.get(n))
        .map(|e| e.plan_bytes())
        .sum();
    ChurnStats {
        requests,
        misses: misses_ms.len(),
        evictions_total: fleet.evictions_total(),
        hit_p50_ms: percentile(&hits_ms, 0.5),
        miss_p50_ms: percentile(&misses_ms, 0.5),
        resident_models: fleet.len(),
        resident_plan_bytes,
    }
}

fn main() {
    println!("== fleet lifecycle: {FLEET} models, LRU residency cap {CAP} ==\n");
    let dir = fleet_dir();
    let paths = write_fleet(&dir);

    let (cold, warm, cold_peak, warm_peak) = install_cases(&paths[0]);
    let ratio = warm.median_ms() / cold.median_ms().max(1e-9);
    println!(
        "    -> cold install {:.3} ms (peak {} B) | warm evict+reinstall {:.3} ms (peak {} B) \
         | warm/cold {:.2}x{}",
        cold.median_ms(),
        cold_peak,
        warm.median_ms(),
        warm_peak,
        ratio,
        if ratio < 1.0 { "" } else { "  [WARNING: warm not below cold]" },
    );

    let churn = churn_case(&paths);
    println!(
        "    -> churn: {} requests, {} misses, {} evictions | hit p50 {:.3} ms, \
         miss p50 {:.3} ms | {} resident, {} plan bytes\n",
        churn.requests,
        churn.misses,
        churn.evictions_total,
        churn.hit_p50_ms,
        churn.miss_p50_ms,
        churn.resident_models,
        churn.resident_plan_bytes,
    );

    let json = format!(
        "{{\n  \"fleet_models\": {FLEET},\n  \"residency_cap\": {CAP},\n  \
         \"cold_install_ms\": {:.4},\n  \"cold_peak_bytes\": {cold_peak},\n  \
         \"warm_reinstall_ms\": {:.4},\n  \"warm_peak_bytes\": {warm_peak},\n  \
         \"warm_over_cold\": {:.4},\n  \"requests\": {},\n  \"misses\": {},\n  \
         \"evictions_total\": {},\n  \"hit_p50_ms\": {:.4},\n  \"miss_p50_ms\": {:.4},\n  \
         \"resident_models\": {},\n  \"resident_plan_bytes\": {}\n}}\n",
        cold.median_ms(),
        warm.median_ms(),
        ratio,
        churn.requests,
        churn.misses,
        churn.evictions_total,
        churn.hit_p50_ms,
        churn.miss_p50_ms,
        churn.resident_models,
        churn.resident_plan_bytes,
    );
    std::fs::write("BENCH_registry.json", &json).expect("write BENCH_registry.json");
    println!("wrote BENCH_registry.json");
    let _ = std::fs::remove_dir_all(&dir);
}
