//! GEMM micro-benchmarks: the paper's core claim is that the uint8 integer
//! GEMM (eq. 9 + output pipeline) beats the float GEMM on the same shapes.
//! Sweeps MobileNet-representative shapes across all three inner kernels
//! plus the f32 baseline, and reports effective GMAC/s.
//!
//! Run: `cargo bench --bench gemm`

use iaoi::bench_util::bench;
use iaoi::data::Rng;
use iaoi::gemm::{gemm_f32, output::OutputStage, Kernel, QGemm};
use iaoi::quant::QuantizedMultiplier;

fn main() {
    // (M, K, N) conv-as-GEMM shapes: (Cout, KhKwCin, spatial positions).
    let shapes = [
        (32, 27, 1024),   // 3x3x3 stem at 32x32
        (64, 288, 256),   // 3x3x32 mid layer
        (128, 1152, 64),  // 3x3x128 deep layer
        (256, 256, 196),  // 1x1 pointwise
        (1024, 1024, 16), // late pointwise, small spatial
    ];
    println!("== quantized vs float GEMM (host, single thread) ==");
    for (m, k, n) in shapes {
        let mut rng = Rng::seeded((m * k + n) as u64);
        let lhs_q: Vec<u8> = (0..m * k).map(|_| 1 + rng.below(255) as u8).collect();
        let rhs_q: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let lhs_f: Vec<f32> = lhs_q.iter().map(|&v| f32::from(v) / 255.0 - 0.5).collect();
        let rhs_f: Vec<f32> = rhs_q.iter().map(|&v| f32::from(v) / 255.0 - 0.5).collect();
        let g = QGemm::new(m, k, n, 128, 120);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.002), 7);
        let mut out_q = vec![0u8; m * n];
        let mut out_f = vec![0f32; m * n];
        let macs = (m * k * n) as f64;

        let report = |label: &str, med_ms: f64| {
            println!("    -> {label}: {:.2} GMAC/s", macs / (med_ms / 1e3) / 1e9);
        };
        let s = bench(&format!("f32 gemm {m}x{k}x{n}"), 5, || {
            gemm_f32(m, k, n, &lhs_f, &rhs_f, &mut out_f);
        });
        report("f32", s.median_ms());
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let s = bench(&format!("u8 gemm {kern:?} {m}x{k}x{n}"), 5, || {
                g.run(kern, &lhs_q, &rhs_q, &stage, &mut out_q);
            });
            report(&format!("{kern:?}"), s.median_ms());
        }
        println!();
    }
}
