//! GEMM micro-benchmarks: the paper's core claim is that the uint8 integer
//! GEMM (eq. 9 + output pipeline) beats the float GEMM on the same shapes.
//! Sweeps MobileNet-representative shapes across all three inner kernels
//! plus the f32 baseline, then pits every runtime-dispatched SIMD
//! micro-kernel ([`iaoi::gemm::dispatch`]) against the scalar tile, and
//! reports effective GMAC/s.
//!
//! Bit-identity guard: a SIMD kernel whose accumulators differ from the
//! scalar golden output by even one byte gets its timing withheld (the
//! bench panics) — no speedup may ever be reported on mismatched results.
//!
//! Emits `BENCH_gemm.json` with per-kernel cases and the dispatch
//! selection, so CI can assert the runner picked a non-scalar path and
//! future PRs have a per-kernel perf trajectory.
//!
//! Run: `cargo bench --bench gemm`
//! (CI runs it under `IAOI_BENCH_SMOKE=1`, whose numbers are not meaningful.)

use iaoi::bench_util::{bench, smoke_mode};
use iaoi::data::Rng;
use iaoi::gemm::kernel::accumulate_blocked_with;
use iaoi::gemm::{dispatch, gemm_f32, output::OutputStage, Kernel, QGemm};
use iaoi::quant::QuantizedMultiplier;

fn main() {
    // (M, K, N) conv-as-GEMM shapes: (Cout, KhKwCin, spatial positions).
    let shapes = [
        (32, 27, 1024),   // 3x3x3 stem at 32x32
        (64, 288, 256),   // 3x3x32 mid layer
        (128, 1152, 64),  // 3x3x128 deep layer
        (256, 256, 196),  // 1x1 pointwise
        (1024, 1024, 16), // late pointwise, small spatial
    ];
    println!("== quantized vs float GEMM (host, single thread) ==");
    for (m, k, n) in shapes {
        let mut rng = Rng::seeded((m * k + n) as u64);
        let lhs_q: Vec<u8> = (0..m * k).map(|_| 1 + rng.below(255) as u8).collect();
        let rhs_q: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let lhs_f: Vec<f32> = lhs_q.iter().map(|&v| f32::from(v) / 255.0 - 0.5).collect();
        let rhs_f: Vec<f32> = rhs_q.iter().map(|&v| f32::from(v) / 255.0 - 0.5).collect();
        let g = QGemm::new(m, k, n, 128, 120);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.002), 7);
        let mut out_q = vec![0u8; m * n];
        let mut out_f = vec![0f32; m * n];
        let macs = (m * k * n) as f64;

        let report = |label: &str, med_ms: f64| {
            println!("    -> {label}: {:.2} GMAC/s", macs / (med_ms / 1e3) / 1e9);
        };
        let s = bench(&format!("f32 gemm {m}x{k}x{n}"), 5, || {
            gemm_f32(m, k, n, &lhs_f, &rhs_f, &mut out_f);
        });
        report("f32", s.median_ms());
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let s = bench(&format!("u8 gemm {kern:?} {m}x{k}x{n}"), 5, || {
                g.run(kern, &lhs_q, &rhs_q, &stage, &mut out_q);
            });
            report(&format!("{kern:?}"), s.median_ms());
        }
        println!();
    }

    // Dispatch sweep: every compiled-and-detected micro-kernel on the raw
    // eq. 9 accumulation, scalar first so its timing is the baseline.
    let impls = dispatch::available();
    println!(
        "== micro-kernel dispatch sweep (selected: {}, available: {}) ==",
        dispatch::active().name,
        impls.iter().map(|d| d.name).collect::<Vec<_>>().join("/"),
    );
    let mut cases = Vec::new();
    for (m, k, n) in shapes {
        let mut rng = Rng::seeded((m * 3 + k * 7 + n) as u64);
        let lhs: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let rhs: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let g = QGemm::new(m, k, n, 77, 201);
        let mut golden = vec![0i32; m * n];
        accumulate_blocked_with(dispatch::scalar(), &g, &lhs, &rhs, &mut golden);
        let macs = (m * k * n) as f64;
        let mut scalar_ms = f64::NAN;
        for d in impls.iter().copied() {
            let mut acc = vec![0i32; m * n];
            let s = bench(&format!("u8 gemm [{}] {m}x{k}x{n}", d.name), 5, || {
                accumulate_blocked_with(d, &g, &lhs, &rhs, &mut acc);
            });
            // Bit-identity guard: refuse to report a timing for diverging
            // output.
            assert!(
                acc == golden,
                "{} diverged from scalar at ({m},{k},{n}) — timing withheld",
                d.name
            );
            let ms = s.median_ms();
            if d.name == "scalar" {
                scalar_ms = ms;
            }
            let gmacs = macs / (ms / 1e3) / 1e9;
            let speedup = scalar_ms / ms.max(1e-9);
            println!("    -> {}: {gmacs:.2} GMAC/s ({speedup:.2}x vs scalar)", d.name);
            cases.push(format!(
                "    {{\"m\": {m}, \"k\": {k}, \"n\": {n}, \"kernel\": \"{}\", \"gmacs\": {gmacs:.3}, \"speedup_vs_scalar\": {speedup:.3}}}",
                d.name
            ));
        }
        println!();
    }
    println!("selected kernel: {}", dispatch::active().name);

    let json = format!(
        "{{\n  \"bench\": \"gemm\",\n  \"smoke\": {},\n  \"selected_kernel\": \"{}\",\n  \"cases\": [\n{}\n  ]\n}}\n",
        smoke_mode(),
        dispatch::active().name,
        cases.join(",\n"),
    );
    std::fs::write("BENCH_gemm.json", &json).expect("write BENCH_gemm.json");
    println!("wrote BENCH_gemm.json");
}
