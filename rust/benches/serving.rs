//! Loadgen bench for the socket serving front end (`iaoi serve --addr`):
//! N concurrent client threads over real TCP sockets, first closed-loop
//! (latency distribution at a sane load), then an overload sweep offering
//! more concurrency than the admission cap to show load-shedding behaving —
//! excess arrivals get fast 503s, not unbounded queueing. Emits
//! `BENCH_serve.json` with throughput, client-side p50/p99/p999, and the
//! shed rate.
//!
//! Two modes:
//! * default — starts an in-process [`iaoi::serve::Server`] (global
//!   in-flight cap 8) on an ephemeral port; also forces a deterministic
//!   shed burst by holding admission permits, so the shed numbers are
//!   nonzero even on a fast machine.
//! * `IAOI_SERVE_ADDR=HOST:PORT` — targets an externally launched
//!   `iaoi serve --addr` process (the CI smoke job does this), exercising
//!   the real binary end to end.
//!
//! Run: `cargo bench --bench serving`
//! (CI runs it under `IAOI_BENCH_SMOKE=1`, whose numbers are not meaningful.)

use iaoi::bench_util::smoke_mode;
use iaoi::coordinator::registry::{ModelRegistry, QuarantineConfig};
use iaoi::coordinator::BatchPolicy;
use iaoi::data::Rng;
use iaoi::graph::fault::FaultPlan;
use iaoi::harness::demo_artifact;
use iaoi::serve::client::HttpClient;
use iaoi::serve::{ServeConfig, Server};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// First `"key":"value"` string field in a JSON blob (hand-rolled: the
/// healthz payload is flat enough that full parsing would be overkill).
fn json_str_field(text: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let start = text.find(&pat)? + pat.len();
    let end = text[start..].find('"')? + start;
    Some(text[start..end].to_string())
}

/// First `"input_shape":[H,W,C]` array in the healthz payload.
fn json_input_shape(text: &str) -> Option<[usize; 3]> {
    let pat = "\"input_shape\":[";
    let start = text.find(pat)? + pat.len();
    let end = text[start..].find(']')? + start;
    let nums: Vec<usize> =
        text[start..end].split(',').filter_map(|s| s.trim().parse().ok()).collect();
    if nums.len() == 3 {
        Some([nums[0], nums[1], nums[2]])
    } else {
        None
    }
}

/// `metric_name{...} value` line value from a Prometheus text page.
fn prom_value(text: &str, line_prefix: &str) -> Option<u64> {
    text.lines()
        .find(|l| l.starts_with(line_prefix))?
        .rsplit(' ')
        .next()?
        .parse()
        .ok()
}

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    sorted_us[((sorted_us.len() - 1) as f64 * p) as usize]
}

fn random_image(rng: &mut Rng, shape: [usize; 3]) -> Vec<f32> {
    (0..shape[0] * shape[1] * shape[2]).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

/// One closed-loop client: `reqs` back-to-back inferences, returning
/// (latencies_us of 200s, ok, shed, failed). Shed responses are retried
/// after a short backoff so the thread keeps offering load; contained
/// faults (500 internal, 504 deadline_exceeded — the degraded-mode and
/// fault-injected smoke paths) count as failed and keep the loop going;
/// anything else ends the thread (draining server / torn connection).
fn run_client(
    addr: &str,
    model: &str,
    shape: [usize; 3],
    seed: u64,
    reqs: usize,
) -> (Vec<f64>, u64, u64, u64) {
    let mut lat = Vec::with_capacity(reqs);
    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
    let Ok(mut client) = HttpClient::connect(addr) else {
        return (lat, ok, shed, failed);
    };
    let mut rng = Rng::seeded(seed);
    let mut sent = 0usize;
    while sent < reqs {
        let img = random_image(&mut rng, shape);
        let t = Instant::now();
        match client.infer(model, &img) {
            Ok(resp) if resp.status == 200 => {
                lat.push(t.elapsed().as_secs_f64() * 1e6);
                ok += 1;
                sent += 1;
            }
            Ok(resp) if resp.status == 503 && resp.body_text().contains("overloaded") => {
                shed += 1;
                sent += 1;
                std::thread::sleep(Duration::from_micros(200));
            }
            Ok(resp) if resp.status == 500 || resp.status == 504 => {
                failed += 1;
                sent += 1;
            }
            Ok(_) | Err(_) => break,
        }
    }
    (lat, ok, shed, failed)
}

/// Fan out `clients` concurrent closed-loop threads; returns
/// (all latencies sorted, ok, shed, failed, wall seconds).
fn sweep(
    addr: &str,
    model: &str,
    shape: [usize; 3],
    clients: usize,
    reqs: usize,
    seed: u64,
) -> (Vec<f64>, u64, u64, u64, f64) {
    let start = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|t| {
            let addr = addr.to_string();
            let model = model.to_string();
            std::thread::spawn(move || run_client(&addr, &model, shape, seed + t as u64, reqs))
        })
        .collect();
    let mut lat = Vec::new();
    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
    for h in handles {
        let (l, o, s, f) = h.join().expect("client thread");
        lat.extend(l);
        ok += o;
        shed += s;
        failed += f;
    }
    let wall = start.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (lat, ok, shed, failed, wall)
}

fn main() {
    let smoke = smoke_mode();
    let external = std::env::var("IAOI_SERVE_ADDR").ok();
    let cap = 8usize;

    // Target: an externally launched `iaoi serve --addr` (CI smoke), or an
    // in-process server with a deliberately small global cap.
    let (addr, server) = match &external {
        Some(a) => {
            println!("targeting external server at {a}");
            (a.clone(), None)
        }
        None => {
            let registry = ModelRegistry::new();
            registry.install(demo_artifact("alpha", 1, 16, 3), PathBuf::from("<bench:alpha>"));
            registry.install(demo_artifact("beta", 1, 8, 11), PathBuf::from("<bench:beta>"));
            let policy = BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_millis(1),
                global_inflight_cap: cap,
                ..Default::default()
            };
            let server = Server::start(registry, policy, 2, ServeConfig::default())
                .expect("in-process server");
            let addr = server.local_addr().to_string();
            println!("in-process server on {addr} (global in-flight cap {cap})");
            (addr, Some(server))
        }
    };

    // Discover a model + its input geometry from the health endpoint, the
    // same way an operator's probe would.
    let mut probe = HttpClient::connect(addr.as_str()).expect("connect for discovery");
    let health = probe.get("/healthz").expect("healthz").body_text();
    let model = json_str_field(&health, "name").expect("a served model in /healthz");
    let shape = json_input_shape(&health).expect("input_shape in /healthz");
    println!("model {model:?}, input {shape:?}\n");

    // Phase A — closed loop at modest concurrency: the latency numbers.
    let (a_clients, a_reqs) = if smoke { (2, 8) } else { (4, 300) };
    println!("== phase A: closed loop, {a_clients} clients x {a_reqs} requests ==");
    let (lat, a_ok, a_shed, a_failed, a_wall) = sweep(&addr, &model, shape, a_clients, a_reqs, 100);
    let (p50, p99, p999) =
        (percentile(&lat, 0.5), percentile(&lat, 0.99), percentile(&lat, 0.999));
    let a_rps = a_ok as f64 / a_wall.max(1e-9);
    println!(
        "  {a_ok} ok, {a_shed} shed, {a_failed} failed in {a_wall:.2}s — {a_rps:.1} req/s, p50 {p50:.0}us p99 {p99:.0}us p999 {p999:.0}us\n"
    );

    // Phase B — overload: offer well more concurrency than the admission
    // cap; the excess must convert to fast 503 sheds, not queueing.
    let (b_clients, b_reqs) = if smoke { (8, 25) } else { (32, 200) };
    println!("== phase B: overload sweep, {b_clients} clients x {b_reqs} requests ==");
    let (_, b_ok, mut b_shed, b_failed, b_wall) =
        sweep(&addr, &model, shape, b_clients, b_reqs, 500);
    let b_rps = b_ok as f64 / b_wall.max(1e-9);

    // Deterministic forced shed (in-process only): saturate the cap by
    // holding permits directly, then fire requests that must all be shed —
    // guarantees a nonzero shed count regardless of machine speed.
    let mut forced_shed = 0u64;
    if let Some(server) = &server {
        let admission = server.admission();
        let mut permits = Vec::new();
        while let Ok(p) = admission.try_acquire(&model) {
            permits.push(p);
            assert!(permits.len() <= cap + 1, "admission failed to enforce its cap");
        }
        let mut client = HttpClient::connect(addr.as_str()).expect("connect for forced shed");
        let mut rng = Rng::seeded(900);
        for _ in 0..10 {
            let img = random_image(&mut rng, shape);
            let resp = client.infer(&model, &img).expect("shed response");
            assert_eq!(resp.status, 503, "saturated server must shed, got {}", resp.status);
            forced_shed += 1;
        }
        drop(permits);
    }
    b_shed += forced_shed;
    let b_total = b_ok + b_shed + b_failed;
    let shed_rate = if b_total > 0 { b_shed as f64 / b_total as f64 } else { 0.0 };
    println!(
        "  {b_ok} ok, {b_shed} shed ({forced_shed} forced), {b_failed} failed — {b_rps:.1} req/s, shed rate {:.1}%\n",
        shed_rate * 100.0
    );

    // Phase C — the metrics endpoint must expose the same story, including
    // the containment counters (a healthy run must report zero panics; the
    // CI smoke job asserts exactly that on this JSON).
    let metrics = probe.get("/metrics").expect("metrics").body_text();
    let quantiles_exported = metrics.contains("iaoi_latency_us{");
    let server_admitted =
        prom_value(&metrics, "iaoi_admitted_total{scope=\"global\"}").unwrap_or(0);
    let server_shed = prom_value(&metrics, "iaoi_shed_total{scope=\"global\"}").unwrap_or(0);
    let worker_panics =
        prom_value(&metrics, "iaoi_worker_panics_total{model=\"_all\"}").unwrap_or(0);
    println!(
        "== phase C: server-side counters — admitted {server_admitted}, shed {server_shed}, worker panics {worker_panics} =="
    );
    assert!(quantiles_exported, "/metrics must export latency quantiles");
    assert!(server_shed >= forced_shed, "server must have observed the forced sheds");

    // Phase D — degraded mode (in-process only): install a deliberately
    // faulty model and sweep it with the breaker disabled. Containment
    // invariant under load: every request is answered (some 200, some
    // contained 500), the closed loop never wedges, and the healthy models
    // are untouched.
    let degraded = match &server {
        None => "null".to_string(),
        Some(server) => {
            let (d_clients, d_reqs) = if smoke { (4, 12) } else { (4, 100) };
            println!("== phase D: degraded mode, {d_clients} clients x {d_reqs} requests ==");
            let registry = server.registry();
            registry.set_quarantine(QuarantineConfig { threshold: 0, ..Default::default() });
            registry.install_with(
                demo_artifact("gamma", 1, 8, 77),
                PathBuf::from("<bench:gamma>"),
                Some(FaultPlan { panic_every: 3, ..Default::default() }),
            );
            let (_, d_ok, _, d_failed, d_wall) =
                sweep(&addr, "gamma", shape, d_clients, d_reqs, 700);
            assert_eq!(
                d_ok + d_failed,
                (d_clients * d_reqs) as u64,
                "degraded sweep must answer every request"
            );
            assert!(d_ok > 0, "non-faulted gamma batches must still succeed");
            assert!(d_failed > 0, "the injected panics must surface as contained failures");
            println!("  {d_ok} ok, {d_failed} contained failures in {d_wall:.2}s\n");
            format!(
                "{{\"clients\": {d_clients}, \"requests\": {d_reqs}, \"ok\": {d_ok}, \"failed\": {d_failed}}}"
            )
        }
    };

    let json = format!(
        "{{\n  \"bench\": \"serving\",\n  \"smoke\": {},\n  \"mode\": \"{}\",\n  \"model\": \"{}\",\n  \"closed_loop\": {{\"clients\": {}, \"requests_ok\": {}, \"failed\": {}, \"throughput_rps\": {:.2}, \"p50_us\": {:.1}, \"p99_us\": {:.1}, \"p999_us\": {:.1}}},\n  \"overload\": {{\"clients\": {}, \"ok\": {}, \"shed\": {}, \"forced_shed\": {}, \"failed\": {}, \"shed_rate\": {:.4}, \"throughput_rps\": {:.2}}},\n  \"server\": {{\"admitted_total\": {}, \"shed_total\": {}, \"worker_panics_total\": {}, \"latency_quantiles_exported\": {}}},\n  \"degraded\": {}\n}}\n",
        smoke,
        if external.is_some() { "external" } else { "in-process" },
        model,
        a_clients,
        a_ok,
        a_failed,
        a_rps,
        p50,
        p99,
        p999,
        b_clients,
        b_ok,
        b_shed,
        forced_shed,
        b_failed,
        shed_rate,
        b_rps,
        server_admitted,
        server_shed,
        worker_panics,
        quantiles_exported,
        degraded,
    );
    std::fs::write("BENCH_serve.json", &json).expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");

    if let Some(server) = server {
        let report = server.shutdown();
        assert!(report.drained_clean, "bench shutdown must drain clean");
    }
}
