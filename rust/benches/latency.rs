//! End-to-end model latency (figs. 1.1c / 4.1 / 4.2 measured half):
//! MobileNet at the paper's DM sweep, float engine vs integer engine,
//! single image, single thread — the host-measured analogue of the
//! latency axis in the latency-vs-accuracy figures (the accuracy axis and
//! per-core estimates come from `iaoi bench --fig <id>`).
//!
//! Run: `cargo bench --bench latency`

use iaoi::bench_util::bench;
use iaoi::data::Rng;
use iaoi::graph::builders::mobilenet;
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::sim::{ArmCoreModel, Dtype};
use iaoi::tensor::Tensor;

fn main() {
    // Scaled-down sweep: paper uses DM x {96..224}; the host float engine
    // is a reference implementation, so resolutions are kept moderate.
    let sweep = [(0.25f64, 32usize), (0.25, 64), (0.5, 32), (0.5, 64), (1.0, 32)];
    println!("== MobileNet end-to-end latency: float vs integer-only engine ==");
    for (dm, res) in sweep {
        let g = mobilenet(dm, 16, false, 1);
        let folded = g.fold_batch_norms();
        let mut rng = Rng::seeded(5);
        let calib: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; res * res * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[1, res, res, 3], d)
            })
            .collect();
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
        let x = &calib[0];

        let sf = bench(&format!("mobilenet dm={dm} res={res} f32"), 3, || {
            let _ = folded.run(x);
        });
        let sq = bench(&format!("mobilenet dm={dm} res={res} int8"), 3, || {
            let _ = q.run(x);
        });
        let macs = folded.mac_count(&[1, res, res, 3]);
        println!(
            "    -> {:.1}M MACs | int8 speedup {:.2}x | est. S835-big: f32 {:.1}ms int8 {:.1}ms | est. S835-LITTLE: f32 {:.1}ms int8 {:.1}ms\n",
            macs as f64 / 1e6,
            sf.median_ms() / sq.median_ms(),
            ArmCoreModel::s835_big().latency_ms(&folded, &[1, res, res, 3], Dtype::F32),
            ArmCoreModel::s835_big().latency_ms(&folded, &[1, res, res, 3], Dtype::Int8),
            ArmCoreModel::s835_little().latency_ms(&folded, &[1, res, res, 3], Dtype::F32),
            ArmCoreModel::s835_little().latency_ms(&folded, &[1, res, res, 3], Dtype::Int8),
        );
    }
}
