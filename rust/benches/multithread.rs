//! Multi-threaded GEMM benchmark (Table 4.6's measured half): the
//! column-strip parallel quantized GEMM at 1/2/4 threads on detector-sized
//! shapes. This testbed exposes a single core, so threads > 1 measure the
//! coordination overhead (the Snapdragon multi-core *estimates* come from
//! `iaoi bench --table 4.6`'s fitted core model).
//!
//! Run: `cargo bench --bench multithread`

use iaoi::bench_util::bench;
use iaoi::data::Rng;
use iaoi::gemm::{output::OutputStage, parallel::run_parallel, Kernel, QGemm};
use iaoi::quant::QuantizedMultiplier;

fn main() {
    println!("== parallel quantized GEMM scaling (host cores: {}) ==",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    for (m, k, n) in [(72, 648, 1024), (40, 360, 1024), (24, 216, 1024)] {
        let mut rng = Rng::seeded(7);
        let lhs: Vec<u8> = (0..m * k).map(|_| 1 + rng.below(255) as u8).collect();
        let rhs: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let g = QGemm::new(m, k, n, 128, 111);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.003), 10);
        let mut out = vec![0u8; m * n];
        let mut base_ms = 0.0;
        for threads in [1usize, 2, 4] {
            let s = bench(&format!("qgemm {m}x{k}x{n} threads={threads}"), 5, || {
                run_parallel(&g, Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut out, threads);
            });
            if threads == 1 {
                base_ms = s.median_ms();
            } else {
                println!("    -> scaling vs 1 thread: {:.2}x", base_ms / s.median_ms());
            }
        }
        println!();
    }
}
