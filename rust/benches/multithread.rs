//! Multi-threaded GEMM benchmark (Table 4.6's measured half): the
//! column-strip parallel quantized GEMM at 1/2/4 threads on detector-sized
//! shapes, comparing the per-call scoped-spawn baseline against the
//! persistent [`WorkerPool`] (same strip partition, bit-identical results —
//! the delta is pure thread provisioning). This testbed exposes a single
//! core, so threads > 1 measure the coordination overhead the pool
//! amortizes (the Snapdragon multi-core *estimates* come from
//! `iaoi bench --table 4.6`'s fitted core model).
//!
//! Run: `cargo bench --bench multithread`

use iaoi::bench_util::bench;
use iaoi::data::Rng;
use iaoi::gemm::parallel::run_strips_scoped;
use iaoi::gemm::{output::OutputStage, Kernel, PreparedGemm, QGemm, Scratch, WorkerPool};
use iaoi::quant::QuantizedMultiplier;

fn main() {
    println!("== parallel quantized GEMM scaling (host cores: {}) ==",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    for (m, k, n) in [(72, 648, 1024), (40, 360, 1024), (24, 216, 1024)] {
        let mut rng = Rng::seeded(7);
        let lhs: Vec<u8> = (0..m * k).map(|_| 1 + rng.below(255) as u8).collect();
        let rhs: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let g = QGemm::new(m, k, n, 128, 111);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.003), 10);
        let plan = PreparedGemm::from_qgemm(&g, Kernel::Int8Pairwise, &lhs, stage);
        let mut scoped_out = vec![0u8; m * n];
        let mut pool_out = vec![0u8; m * n];
        let mut base_ms = 0.0;
        for threads in [1usize, 2, 4] {
            let s = bench(&format!("qgemm {m}x{k}x{n} scoped threads={threads}"), 5, || {
                run_strips_scoped(&plan, &rhs, n, &mut scoped_out, threads);
            });
            let pool = WorkerPool::new(threads);
            let mut scratch = Scratch::new();
            let p = bench(&format!("qgemm {m}x{k}x{n} pool   threads={threads}"), 5, || {
                pool.run_strips(&plan, &rhs, n, &mut pool_out, &mut scratch);
            });
            assert_eq!(scoped_out, pool_out, "pool and scoped paths diverged");
            if threads == 1 {
                base_ms = s.median_ms();
            } else {
                println!(
                    "    -> scoped vs 1 thread: {:.2}x   pool vs 1 thread: {:.2}x   pool vs scoped: {:.2}x",
                    base_ms / s.median_ms(),
                    base_ms / p.median_ms(),
                    s.median_ms() / p.median_ms()
                );
            }
        }
        println!();
    }
}
