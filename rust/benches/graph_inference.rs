//! End-to-end graph inference: prepared plans vs. the unprepared engine,
//! for both weight-quantization modes.
//!
//! Measures the payoff of the pack-once / zero-alloc-steady-state execution
//! layer ([`iaoi::graph::PreparedGraph`]) on whole models, single-image and
//! batched, and emits `BENCH_graph.json` with ops/sec so future PRs have a
//! perf trajectory to regress against. Every case is run under both
//! [`QuantMode::PerTensor`] and [`QuantMode::PerChannel`] (tagged with a
//! `quant_mode` field in the JSON): the per-channel requantization stage
//! indexes one multiplier per output row, and this bench is the regression
//! guard that the indexing costs nothing measurable on whole-model
//! inference. The unprepared numbers run the original
//! [`iaoi::graph::QGraph::run_q`] path, which re-derives all weight-side
//! state (packing, row sums, output stages) and reallocates every
//! intermediate per request.
//!
//! Run: `cargo bench --bench graph_inference`
//! (CI runs it under `IAOI_BENCH_SMOKE=1`, whose numbers are not meaningful.)

use iaoi::bench_util::{bench, smoke_mode, Sample};
use iaoi::data::Rng;
use iaoi::graph::builders::mobilenet;
use iaoi::graph::{ExecState, QGraph};
use iaoi::harness::demo_artifact_with_mode;
use iaoi::nn::QTensor;
use iaoi::quantize::{quantize_graph, QuantMode, QuantizeOptions};
use iaoi::tensor::Tensor;

struct Case {
    model: &'static str,
    quant_mode: QuantMode,
    batch: usize,
    unprepared: Sample,
    prepared: Sample,
}

impl Case {
    /// Inferences per second at this batch size.
    fn ops(&self, s: &Sample) -> f64 {
        self.batch as f64 * 1e6 / s.median_us.max(1e-9)
    }

    fn speedup(&self) -> f64 {
        self.unprepared.median_us / self.prepared.median_us.max(1e-9)
    }

    fn json(&self) -> String {
        format!(
            "    {{\"model\": \"{}\", \"quant_mode\": \"{}\", \"batch\": {}, \"unprepared_ops_per_sec\": {:.2}, \"prepared_ops_per_sec\": {:.2}, \"speedup\": {:.3}}}",
            self.model,
            self.quant_mode.label(),
            self.batch,
            self.ops(&self.unprepared),
            self.ops(&self.prepared),
            self.speedup(),
        )
    }
}

fn random_input(rng: &mut Rng, batch: usize, res: usize) -> Tensor<f32> {
    let mut d = vec![0f32; batch * res * res * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    Tensor::from_vec(&[batch, res, res, 3], d)
}

fn run_case(
    model: &'static str,
    quant_mode: QuantMode,
    q: &QGraph,
    res: usize,
    batch: usize,
) -> Case {
    let mut rng = Rng::seeded(9 + batch as u64);
    let x = random_input(&mut rng, batch, res);
    let qin = QTensor::quantize(&x, q.input_params);

    let tag = quant_mode.label();
    let unprepared = bench(&format!("{model} [{tag}] batch={batch} unprepared"), 5, || {
        std::hint::black_box(q.run_q(&qin));
    });

    let plan = q.prepare();
    let mut state = ExecState::new();
    // Warm-up so the steady state (reused buffers) is what gets measured.
    plan.run_q(&qin, &mut state);
    let prepared = bench(&format!("{model} [{tag}] batch={batch} prepared"), 5, || {
        std::hint::black_box(plan.run_q(&qin, &mut state).data.len());
    });

    // The two paths must agree bit-for-bit or the numbers mean nothing.
    let want = q.run_q(&qin);
    let got = plan.run_q(&qin, &mut state);
    assert_eq!(want.data.data(), got.data.data(), "{model} [{tag}] prepared path diverged");

    Case { model, quant_mode, batch, unprepared, prepared }
}

fn main() {
    println!("== end-to-end graph inference: prepared vs unprepared, both quant modes ==\n");

    let mut cases = Vec::new();
    for mode in [QuantMode::PerTensor, QuantMode::PerChannel] {
        // The conv-dominated demo graph (papernet: conv/dw/pw stack + GAP + FC).
        let demo = demo_artifact_with_mode("demo", 1, 16, 3, mode).graph;
        // MobileNet dm=0.25 at 32px: the deeper serving-shaped workload.
        let mn = {
            let g = mobilenet(0.25, 16, false, 7);
            let mut rng = Rng::seeded(7);
            let calib = vec![random_input(&mut rng, 2, 32)];
            let (_, q) = quantize_graph(&g, &calib, QuantizeOptions { mode, ..Default::default() });
            q
        };
        for &batch in &[1usize, 8] {
            cases.push(run_case("papernet_demo", mode, &demo, 16, batch));
        }
        for &batch in &[1usize, 4] {
            cases.push(run_case("mobilenet_dm025", mode, &mn, 32, batch));
        }
    }

    println!();
    for c in &cases {
        println!(
            "{:<18} {:<12} batch={}  unprepared {:>9.1} ops/s  prepared {:>9.1} ops/s  speedup {:.2}x",
            c.model,
            c.quant_mode.label(),
            c.batch,
            c.ops(&c.unprepared),
            c.ops(&c.prepared),
            c.speedup(),
        );
    }

    let find = |model: &str, batch: usize| {
        cases
            .iter()
            .find(|c| c.model == model && c.batch == batch && c.quant_mode == QuantMode::PerTensor)
            .unwrap()
    };
    let demo_single = find("papernet_demo", 1);
    let demo_batched = find("papernet_demo", 8);
    let json = format!(
        "{{\n  \"bench\": \"graph_inference\",\n  \"smoke\": {},\n  \"cases\": [\n{}\n  ],\n  \"demo_speedup_single\": {:.3},\n  \"demo_speedup_batched\": {:.3}\n}}\n",
        smoke_mode(),
        cases.iter().map(Case::json).collect::<Vec<_>>().join(",\n"),
        demo_single.speedup(),
        demo_batched.speedup(),
    );
    std::fs::write("BENCH_graph.json", &json).expect("write BENCH_graph.json");
    println!("\nwrote BENCH_graph.json");
}
