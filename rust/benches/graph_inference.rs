//! End-to-end graph inference: prepared plans vs. the unprepared engine,
//! for both weight-quantization modes.
//!
//! Measures the payoff of the pack-once / zero-alloc-steady-state execution
//! layer ([`iaoi::graph::PreparedGraph`]) on whole models, single-image and
//! batched, and emits `BENCH_graph.json` with ops/sec so future PRs have a
//! perf trajectory to regress against. Every case is run under both
//! [`QuantMode::PerTensor`] and [`QuantMode::PerChannel`] (tagged with a
//! `quant_mode` field in the JSON): the per-channel requantization stage
//! indexes one multiplier per output row, and this bench is the regression
//! guard that the indexing costs nothing measurable on whole-model
//! inference. The unprepared numbers run the original
//! [`iaoi::graph::QGraph::run_q`] path, which re-derives all weight-side
//! state (packing, row sums, output stages) and reallocates every
//! intermediate per request.
//!
//! Run: `cargo bench --bench graph_inference`
//! (CI runs it under `IAOI_BENCH_SMOKE=1`, whose numbers are not meaningful.)

use iaoi::bench_util::{bench, smoke_mode, Sample};
use iaoi::data::Rng;
use iaoi::gemm::{IntraOp, WorkerPool};
use iaoi::graph::builders::{mini_resnet, mobilenet};
use iaoi::graph::{ExecState, QGraph};
use iaoi::harness::demo_artifact_with_mode;
use iaoi::nn::QTensor;
use iaoi::quantize::{quantize_graph, QuantMode, QuantizeOptions};
use iaoi::tensor::Tensor;
use std::sync::Arc;

struct Case {
    model: &'static str,
    quant_mode: QuantMode,
    batch: usize,
    unprepared: Sample,
    prepared: Sample,
}

impl Case {
    /// Inferences per second at this batch size.
    fn ops(&self, s: &Sample) -> f64 {
        self.batch as f64 * 1e6 / s.median_us.max(1e-9)
    }

    fn speedup(&self) -> f64 {
        self.unprepared.median_us / self.prepared.median_us.max(1e-9)
    }

    fn json(&self) -> String {
        // Every case is tagged with the runtime-dispatched GEMM micro-kernel
        // so perf trajectories across hosts compare like with like.
        format!(
            "    {{\"model\": \"{}\", \"quant_mode\": \"{}\", \"kernel\": \"{}\", \"batch\": {}, \"unprepared_ops_per_sec\": {:.2}, \"prepared_ops_per_sec\": {:.2}, \"speedup\": {:.3}}}",
            self.model,
            self.quant_mode.label(),
            iaoi::gemm::dispatch::active().name,
            self.batch,
            self.ops(&self.unprepared),
            self.ops(&self.prepared),
            self.speedup(),
        )
    }
}

/// Epilogue fusion: the same prepared plan with the conv→Add rewrite
/// enabled vs disabled (`PreparedGraph::set_fusion`), single-threaded, on
/// the residual mini-resnet — the only builder whose graphs contain Add
/// nodes. Fused and unfused are bit-identical (asserted before timing);
/// the speedup is what eliminating the standalone `qadd_into` pass over
/// each residual tensor buys.
struct FusionCase {
    model: &'static str,
    quant_mode: QuantMode,
    batch: usize,
    fused_nodes: usize,
    unfused: Sample,
    fused: Sample,
}

impl FusionCase {
    fn speedup(&self) -> f64 {
        self.unfused.median_us / self.fused.median_us.max(1e-9)
    }

    fn json(&self) -> String {
        format!(
            "    {{\"tag\": \"fusion\", \"model\": \"{}\", \"quant_mode\": \"{}\", \"kernel\": \"{}\", \"batch\": {}, \"fused_nodes\": {}, \"unfused_us\": {:.1}, \"fused_us\": {:.1}, \"fusion_speedup\": {:.3}}}",
            self.model,
            self.quant_mode.label(),
            iaoi::gemm::dispatch::active().name,
            self.batch,
            self.fused_nodes,
            self.unfused.median_us,
            self.fused.median_us,
            self.speedup(),
        )
    }
}

fn run_fusion_case(
    model: &'static str,
    quant_mode: QuantMode,
    q: &QGraph,
    res: usize,
    batch: usize,
) -> FusionCase {
    let mut rng = Rng::seeded(57 + batch as u64);
    let x = random_input(&mut rng, batch, res);
    let qin = QTensor::quantize(&x, q.input_params);
    let tag = quant_mode.label();

    let fused_plan = q.prepare().with_fusion(true);
    let unfused_plan = q.prepare().with_fusion(false);
    let fused_nodes = fused_plan.fused_nodes();
    assert!(fused_nodes >= 1, "{model}: no conv→Add fusion discovered");

    let mut sf = ExecState::new();
    let mut su = ExecState::new();
    // Warm both states and hold fusion to its contract before timing.
    let want = unfused_plan.run_q(&qin, &mut su).data.data().to_vec();
    assert_eq!(
        fused_plan.run_q(&qin, &mut sf).data.data(),
        &want[..],
        "{model} [{tag}] fused path diverged from unfused"
    );

    let unfused = bench(&format!("{model} [{tag}] batch={batch} fusion=off"), 5, || {
        std::hint::black_box(unfused_plan.run_q(&qin, &mut su).data.len());
    });
    let fused = bench(&format!("{model} [{tag}] batch={batch} fusion=on"), 5, || {
        std::hint::black_box(fused_plan.run_q(&qin, &mut sf).data.len());
    });

    FusionCase { model, quant_mode, batch, fused_nodes, unfused, fused }
}

/// Whole-model intra-op parallelism: the same prepared plan run serial,
/// with per-call scoped spawns, and through a persistent [`WorkerPool`].
/// Scoped and pool use the identical strip partition and threshold, so
/// `pool_vs_scoped` isolates exactly what the pool amortizes: per-GEMM
/// thread provisioning.
struct IntraCase {
    model: &'static str,
    batch: usize,
    threads: usize,
    serial: Sample,
    scoped: Sample,
    pool: Sample,
}

impl IntraCase {
    fn pool_vs_scoped(&self) -> f64 {
        self.scoped.median_us / self.pool.median_us.max(1e-9)
    }

    fn pool_vs_serial(&self) -> f64 {
        self.serial.median_us / self.pool.median_us.max(1e-9)
    }

    fn json(&self) -> String {
        format!(
            "    {{\"model\": \"{}\", \"batch\": {}, \"intra_threads\": {}, \"serial_us\": {:.1}, \"scoped_us\": {:.1}, \"pool_us\": {:.1}, \"pool_vs_scoped\": {:.3}, \"pool_vs_serial\": {:.3}}}",
            self.model,
            self.batch,
            self.threads,
            self.serial.median_us,
            self.scoped.median_us,
            self.pool.median_us,
            self.pool_vs_scoped(),
            self.pool_vs_serial(),
        )
    }
}

fn run_intra_case(
    model: &'static str,
    q: &QGraph,
    res: usize,
    batch: usize,
    threads: usize,
) -> IntraCase {
    let min_n = iaoi::gemm::pool::DEFAULT_MIN_N;
    let mut rng = Rng::seeded(31 + batch as u64);
    let x = random_input(&mut rng, batch, res);
    let qin = QTensor::quantize(&x, q.input_params);
    let plan = q.prepare();

    let mut state = ExecState::new();
    plan.run_q(&qin, &mut state);
    let want = plan.run_q(&qin, &mut state).data.data().to_vec();
    let serial = bench(&format!("{model} batch={batch} intra=serial"), 5, || {
        std::hint::black_box(plan.run_q(&qin, &mut state).data.len());
    });

    state.set_intra(IntraOp::scoped(threads, min_n));
    assert_eq!(plan.run_q(&qin, &mut state).data.data(), &want[..], "scoped diverged");
    let scoped = bench(&format!("{model} batch={batch} intra=scoped({threads})"), 5, || {
        std::hint::black_box(plan.run_q(&qin, &mut state).data.len());
    });

    let pool_handle = Arc::new(WorkerPool::new(threads));
    state.set_intra(IntraOp::pool(pool_handle, min_n));
    assert_eq!(plan.run_q(&qin, &mut state).data.data(), &want[..], "pool diverged");
    let pool = bench(&format!("{model} batch={batch} intra=pool({threads})"), 5, || {
        std::hint::black_box(plan.run_q(&qin, &mut state).data.len());
    });

    IntraCase { model, batch, threads, serial, scoped, pool }
}

fn random_input(rng: &mut Rng, batch: usize, res: usize) -> Tensor<f32> {
    let mut d = vec![0f32; batch * res * res * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    Tensor::from_vec(&[batch, res, res, 3], d)
}

fn run_case(
    model: &'static str,
    quant_mode: QuantMode,
    q: &QGraph,
    res: usize,
    batch: usize,
) -> Case {
    let mut rng = Rng::seeded(9 + batch as u64);
    let x = random_input(&mut rng, batch, res);
    let qin = QTensor::quantize(&x, q.input_params);

    let tag = quant_mode.label();
    let unprepared = bench(&format!("{model} [{tag}] batch={batch} unprepared"), 5, || {
        std::hint::black_box(q.run_q(&qin));
    });

    let plan = q.prepare();
    let mut state = ExecState::new();
    // Warm-up so the steady state (reused buffers) is what gets measured.
    plan.run_q(&qin, &mut state);
    let prepared = bench(&format!("{model} [{tag}] batch={batch} prepared"), 5, || {
        std::hint::black_box(plan.run_q(&qin, &mut state).data.len());
    });

    // The two paths must agree bit-for-bit or the numbers mean nothing.
    let want = q.run_q(&qin);
    let got = plan.run_q(&qin, &mut state);
    assert_eq!(want.data.data(), got.data.data(), "{model} [{tag}] prepared path diverged");

    Case { model, quant_mode, batch, unprepared, prepared }
}

fn main() {
    println!("== end-to-end graph inference: prepared vs unprepared, both quant modes ==\n");

    // One (demo, mobilenet) pair per quant mode, built once and reused by
    // both the prepared-vs-unprepared cases and the intra-op section.
    let graphs: Vec<(QuantMode, QGraph, QGraph)> = [QuantMode::PerTensor, QuantMode::PerChannel]
        .into_iter()
        .map(|mode| {
            // The conv-dominated demo graph (papernet: conv/dw/pw + GAP + FC).
            let demo = demo_artifact_with_mode("demo", 1, 16, 3, mode).graph;
            // MobileNet dm=0.25 at 32px: the deeper serving-shaped workload.
            let mn = {
                let g = mobilenet(0.25, 16, false, 7);
                let mut rng = Rng::seeded(7);
                let calib = vec![random_input(&mut rng, 2, 32)];
                let (_, q) =
                    quantize_graph(&g, &calib, QuantizeOptions { mode, ..Default::default() });
                q
            };
            (mode, demo, mn)
        })
        .collect();

    let mut cases = Vec::new();
    for (mode, demo, mn) in &graphs {
        for &batch in &[1usize, 8] {
            cases.push(run_case("papernet_demo", *mode, demo, 16, batch));
        }
        for &batch in &[1usize, 4] {
            cases.push(run_case("mobilenet_dm025", *mode, mn, 32, batch));
        }
    }

    println!();
    for c in &cases {
        println!(
            "{:<18} {:<12} batch={}  unprepared {:>9.1} ops/s  prepared {:>9.1} ops/s  speedup {:.2}x",
            c.model,
            c.quant_mode.label(),
            c.batch,
            c.ops(&c.unprepared),
            c.ops(&c.prepared),
            c.speedup(),
        );
    }

    // Epilogue fusion on the residual network, fused vs unfused plans from
    // the same quantized graph (tagged "fusion" in the JSON).
    println!("\n== epilogue fusion: conv→Add folded into the output stage ==\n");
    let mut fusion_cases = Vec::new();
    for mode in [QuantMode::PerTensor, QuantMode::PerChannel] {
        let g = mini_resnet(1, 8, 57);
        let mut rng = Rng::seeded(57);
        let calib = vec![random_input(&mut rng, 2, 16)];
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions { mode, ..Default::default() });
        for &batch in &[1usize, 8] {
            fusion_cases.push(run_fusion_case("mini_resnet8", mode, &q, 16, batch));
        }
    }
    println!();
    for c in &fusion_cases {
        println!(
            "{:<18} {:<12} batch={}  fused_nodes={}  unfused {:>9.1}us  fused {:>9.1}us  speedup {:.2}x",
            c.model,
            c.quant_mode.label(),
            c.batch,
            c.fused_nodes,
            c.unfused.median_us,
            c.fused.median_us,
            c.speedup(),
        );
    }

    // Intra-op parallelism on whole batched models: pool vs scoped-spawn vs
    // serial at the default per-layer threshold. On single-core CI the
    // absolute speedups sit at or below 1; pool_vs_scoped is the number the
    // persistent pool exists for (it strips per-GEMM thread provisioning).
    println!("\n== intra-op: serial vs scoped-spawn vs persistent pool ==\n");
    let mut intra_cases = Vec::new();
    {
        let (_, demo_pt, mn_pt) = &graphs[0];
        for &threads in &[2usize, 4] {
            intra_cases.push(run_intra_case("papernet_demo", demo_pt, 16, 8, threads));
            intra_cases.push(run_intra_case("mobilenet_dm025", mn_pt, 32, 4, threads));
        }
    }
    println!();
    for c in &intra_cases {
        println!(
            "{:<18} batch={} threads={}  pool vs scoped {:.2}x  pool vs serial {:.2}x",
            c.model,
            c.batch,
            c.threads,
            c.pool_vs_scoped(),
            c.pool_vs_serial(),
        );
    }

    let find = |model: &str, batch: usize| {
        cases
            .iter()
            .find(|c| c.model == model && c.batch == batch && c.quant_mode == QuantMode::PerTensor)
            .unwrap()
    };
    let demo_single = find("papernet_demo", 1);
    let demo_batched = find("papernet_demo", 8);
    let pool_vs_scoped_batched = intra_cases
        .iter()
        .find(|c| c.model == "papernet_demo" && c.threads == 4)
        .map(IntraCase::pool_vs_scoped)
        .unwrap_or(1.0);
    // Headline fusion numbers: the batched per-tensor case carries the
    // acceptance bar; fused_nodes lets CI assert the pass actually fired.
    let fusion_headline = fusion_cases
        .iter()
        .find(|c| c.batch == 8 && c.quant_mode == QuantMode::PerTensor)
        .expect("fusion case batch=8 per-tensor");
    let json = format!(
        "{{\n  \"bench\": \"graph_inference\",\n  \"smoke\": {},\n  \"selected_kernel\": \"{}\",\n  \"cases\": [\n{}\n  ],\n  \"fusion_cases\": [\n{}\n  ],\n  \"intra_cases\": [\n{}\n  ],\n  \"demo_speedup_single\": {:.3},\n  \"demo_speedup_batched\": {:.3},\n  \"fused_nodes\": {},\n  \"fusion_speedup_batched\": {:.3},\n  \"pool_vs_scoped_batched\": {:.3}\n}}\n",
        smoke_mode(),
        iaoi::gemm::dispatch::active().name,
        cases.iter().map(Case::json).collect::<Vec<_>>().join(",\n"),
        fusion_cases.iter().map(FusionCase::json).collect::<Vec<_>>().join(",\n"),
        intra_cases.iter().map(IntraCase::json).collect::<Vec<_>>().join(",\n"),
        demo_single.speedup(),
        demo_batched.speedup(),
        fusion_headline.fused_nodes,
        fusion_headline.speedup(),
        pool_vs_scoped_batched,
    );
    std::fs::write("BENCH_graph.json", &json).expect("write BENCH_graph.json");
    println!("\nwrote BENCH_graph.json");
}
