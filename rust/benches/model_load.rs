//! Artifact cold-start benchmark: time to decode a `.iaoiq` artifact and
//! time to first inference from raw bytes — the latency a hot-swap
//! ([`iaoi::coordinator::registry::ModelRegistry::swap`]) or a fresh
//! serving process pays before the new model can take traffic.
//!
//! Run: `cargo bench --bench model_load`

use iaoi::bench_util::bench;
use iaoi::data::Rng;
use iaoi::graph::builders::mobilenet;
use iaoi::harness::demo_artifact;
use iaoi::model_format::{self, ModelArtifact};
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;

fn mobilenet_artifact() -> ModelArtifact {
    let g = mobilenet(0.25, 16, false, 1);
    let mut rng = Rng::seeded(4);
    let mut d = vec![0f32; 2 * 32 * 32 * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    let calib = vec![Tensor::from_vec(&[2, 32, 32, 3], d)];
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
    ModelArtifact::new("mobilenet_dm025", 1, [32, 32, 3], q)
}

fn cold_start_case(label: &str, artifact: &ModelArtifact) {
    let bytes = model_format::save(artifact);
    let [h, w, c] = artifact.input_shape;
    let img = Tensor::<f32>::zeros(&[1, h, w, c]);
    println!(
        "== {label}: {} nodes, {} weight bytes, {} artifact bytes ==",
        artifact.graph.nodes.len(),
        artifact.graph.model_bytes(),
        bytes.len()
    );
    let decode = bench(&format!("{label}: decode artifact"), 20, || {
        let loaded = model_format::load(&bytes).expect("load");
        std::hint::black_box(loaded.graph.nodes.len());
    });
    let cold = bench(&format!("{label}: decode + first inference"), 10, || {
        let loaded = model_format::load(&bytes).expect("load");
        std::hint::black_box(loaded.graph.run(&img));
    });
    // Steady-state inference, for reference against the cold number.
    let resident = model_format::load(&bytes).expect("load");
    let warm = bench(&format!("{label}: resident inference"), 10, || {
        std::hint::black_box(resident.graph.run(&img));
    });
    println!(
        "    -> decode {:.2} ms | cold first-inference {:.2} ms | warm {:.2} ms | decode overhead {:.1}%\n",
        decode.median_ms(),
        cold.median_ms(),
        warm.median_ms(),
        100.0 * decode.median_ms() / cold.median_ms().max(1e-9),
    );
}

fn main() {
    println!("== .iaoiq cold-start: deserialize + first-inference latency ==\n");
    cold_start_case("papernet (demo)", &demo_artifact("demo", 1, 16, 3));
    cold_start_case("mobilenet dm=0.25", &mobilenet_artifact());
}
