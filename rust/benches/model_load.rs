//! Artifact cold-start benchmark: time to decode a `.iaoiq` artifact and
//! time to first inference — the latency a hot-swap
//! ([`iaoi::coordinator::registry::ModelRegistry::swap`]) or a fresh
//! serving process pays before the new model can take traffic — measured
//! for every load mode (copy / zerocopy / mmap), plus the **peak transient
//! allocation bytes** of one decode under a counting global allocator.
//! The copy path transiently holds a second copy of the weight bytes; the
//! zero-copy paths must stay `o(weight bytes)`. An 8-model registry
//! install case covers the multi-model resident-memory story.
//!
//! Emits `BENCH_model_load.json` next to `BENCH_graph.json`.
//!
//! Run: `cargo bench --bench model_load`
//! (CI runs it under `IAOI_BENCH_SMOKE=1`, whose numbers are not
//! meaningful.)

use iaoi::bench_util::counting_alloc::{self, CountingAlloc};
use iaoi::bench_util::{bench, Sample};
use iaoi::coordinator::registry::ModelRegistry;
use iaoi::data::Rng;
use iaoi::graph::builders::mobilenet;
use iaoi::harness::demo_artifact;
use iaoi::model_format::{self, LoadMode, ModelArtifact};
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::{ArtifactBytes, Tensor};
use std::path::PathBuf;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` once with the counter armed; returns (peak net bytes, total
/// allocated bytes) during the call.
fn measure_transient(f: impl FnOnce()) -> (u64, u64) {
    let m = counting_alloc::measure(f);
    (m.peak_bytes, m.total_bytes)
}

fn mobilenet_artifact() -> ModelArtifact {
    let g = mobilenet(0.25, 16, false, 1);
    let mut rng = Rng::seeded(4);
    let mut d = vec![0f32; 2 * 32 * 32 * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    let calib = vec![Tensor::from_vec(&[2, 32, 32, 3], d)];
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
    ModelArtifact::new("mobilenet_dm025", 1, [32, 32, 3], q)
}

struct Case {
    model: String,
    mode: LoadMode,
    mapped: bool,
    artifact_bytes: usize,
    weight_bytes: usize,
    decode: Sample,
    cold: Sample,
    peak_transient_bytes: u64,
    total_alloc_bytes: u64,
}

impl Case {
    fn json(&self) -> String {
        format!(
            "    {{\"model\": \"{}\", \"mode\": \"{}\", \"mapped\": {}, \
             \"artifact_bytes\": {}, \"weight_bytes\": {}, \"decode_ms\": {:.4}, \
             \"cold_first_inference_ms\": {:.4}, \"peak_transient_bytes\": {}, \
             \"total_alloc_bytes\": {}}}",
            self.model,
            self.mode.label(),
            self.mapped,
            self.artifact_bytes,
            self.weight_bytes,
            self.decode.median_ms(),
            self.cold.median_ms(),
            self.peak_transient_bytes,
            self.total_alloc_bytes,
        )
    }
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("iaoi-bench-load-{tag}-{}", std::process::id()))
}

fn cold_start_cases(label: &str, artifact: &ModelArtifact, out: &mut Vec<Case>) {
    let bytes = model_format::save(artifact).expect("encode");
    let path = tmp_path(&format!("{}.iaoiq", artifact.name));
    std::fs::write(&path, &bytes).expect("write artifact");
    let [h, w, c] = artifact.input_shape;
    let img = Tensor::<f32>::zeros(&[1, h, w, c]);
    let weight_bytes = artifact.graph.model_bytes();
    println!(
        "== {label}: {} nodes, {weight_bytes} weight bytes, {} artifact bytes ==",
        artifact.graph.nodes.len(),
        bytes.len()
    );
    for mode in [LoadMode::Copy, LoadMode::ZeroCopy, LoadMode::Mmap] {
        // Buffer residency is paid once per serving process; the per-model
        // work being measured is the decode from resident bytes.
        let buf = match mode {
            LoadMode::Copy => None,
            LoadMode::ZeroCopy => Some(ArtifactBytes::from_vec(bytes.clone())),
            LoadMode::Mmap => Some(ArtifactBytes::map_file(&path).expect("map")),
        };
        let mapped = buf.as_ref().is_some_and(ArtifactBytes::is_mapped);
        let decode_once = || match &buf {
            None => model_format::load(&bytes).expect("load"),
            Some(b) => model_format::load_shared(b).expect("load_shared"),
        };
        let decode = bench(&format!("{label}: decode [{}]", mode.label()), 20, || {
            std::hint::black_box(decode_once().graph.nodes.len());
        });
        let cold = bench(&format!("{label}: decode+infer [{}]", mode.label()), 10, || {
            std::hint::black_box(decode_once().graph.run(&img));
        });
        let (peak, total) = measure_transient(|| {
            std::hint::black_box(decode_once().graph.nodes.len());
        });
        println!(
            "    -> [{}] decode {:.2} ms | cold {:.2} ms | peak transient {} B | \
             total alloc {} B ({:.1}% of weight bytes){}",
            mode.label(),
            decode.median_ms(),
            cold.median_ms(),
            peak,
            total,
            100.0 * peak as f64 / weight_bytes.max(1) as f64,
            if mapped { " | mmap-backed" } else { "" },
        );
        out.push(Case {
            model: artifact.name.clone(),
            mode,
            mapped,
            artifact_bytes: bytes.len(),
            weight_bytes,
            decode,
            cold,
            peak_transient_bytes: peak,
            total_alloc_bytes: total,
        });
    }
    let _ = std::fs::remove_file(&path);
    println!();
}

struct RegistryCase {
    mode: LoadMode,
    models: usize,
    install: Sample,
    peak_bytes: u64,
}

impl RegistryCase {
    fn json(&self) -> String {
        format!(
            "    {{\"mode\": \"{}\", \"models\": {}, \"install_ms\": {:.4}, \"peak_bytes\": {}}}",
            self.mode.label(),
            self.models,
            self.install.median_ms(),
            self.peak_bytes,
        )
    }
}

/// Install an 8-model registry (decode + prepare per model) under each load
/// mode — the multi-model swap/install cost the registry pays per artifact.
fn registry_cases(out: &mut Vec<RegistryCase>) {
    const MODELS: usize = 8;
    let dir = tmp_path("registry");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create registry dir");
    for i in 0..MODELS {
        let art = demo_artifact(&format!("m{i}"), 1, 16, i as u64);
        model_format::write_file(&dir.join(format!("m{i}.iaoiq")), &art).expect("write");
    }
    println!("== {MODELS}-model registry install (decode + prepare per artifact) ==");
    for mode in [LoadMode::Copy, LoadMode::ZeroCopy, LoadMode::Mmap] {
        let install = bench(&format!("registry: install x{MODELS} [{}]", mode.label()), 5, || {
            std::hint::black_box(ModelRegistry::load_dir_with(&dir, mode).expect("load_dir").len());
        });
        let (peak, _) = measure_transient(|| {
            std::hint::black_box(ModelRegistry::load_dir_with(&dir, mode).expect("load_dir").len());
        });
        println!(
            "    -> [{}] install {:.2} ms | peak bytes {}",
            mode.label(),
            install.median_ms(),
            peak
        );
        out.push(RegistryCase { mode, models: MODELS, install, peak_bytes: peak });
    }
    let _ = std::fs::remove_dir_all(&dir);
    println!();
}

fn main() {
    println!("== .iaoiq cold-start: decode + first-inference latency per load mode ==\n");
    let mut cases = Vec::new();
    cold_start_cases("papernet (demo)", &demo_artifact("demo", 1, 16, 3), &mut cases);
    cold_start_cases("mobilenet dm=0.25", &mobilenet_artifact(), &mut cases);
    let mut registry = Vec::new();
    registry_cases(&mut registry);

    let json = format!(
        "{{\n  \"cases\": [\n{}\n  ],\n  \"registry\": [\n{}\n  ]\n}}\n",
        cases.iter().map(Case::json).collect::<Vec<_>>().join(",\n"),
        registry.iter().map(RegistryCase::json).collect::<Vec<_>>().join(",\n"),
    );
    std::fs::write("BENCH_model_load.json", &json).expect("write BENCH_model_load.json");
    println!("wrote BENCH_model_load.json");
}
