//! Chaos tests for the fault-containment layer: deterministic fault
//! injection ([`iaoi::graph::fault::FaultPlan`]) driving the serving
//! stack's robustness rails end to end — panic-isolated workers that
//! answer every rider with a structured failure instead of hanging the
//! client, the per-model panic circuit breaker tripping at exactly its
//! threshold and recovering on hot-swap, pre-execution deadline shedding,
//! poisoned-lock recovery, and the acceptor's idle-timeout/connection-cap
//! rails. Every fault here is injected, not waited for: the tests are
//! fully deterministic and run in the ordinary `cargo test` suite.

use iaoi::coordinator::registry::{ModelRegistry, QuarantineConfig};
use iaoi::coordinator::{BatchPolicy, MultiCoordinator, Outcome};
use iaoi::data::Rng;
use iaoi::graph::fault::FaultPlan;
use iaoi::graph::ExecState;
use iaoi::harness::demo_artifact;
use iaoi::model_format;
use iaoi::serve::client::HttpClient;
use iaoi::serve::{ServeConfig, Server};
use iaoi::tensor::Tensor;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A deterministic [16,16,3] input image as a flat f32 vec (both demo
/// models take this shape).
fn image(rng: &mut Rng) -> Vec<f32> {
    (0..16 * 16 * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

fn fresh_policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(1),
        ..Default::default()
    }
}

/// A registry whose `alpha` carries an injected fault; `beta` is healthy.
fn faulted_registry(fault: FaultPlan) -> ModelRegistry {
    let registry = ModelRegistry::new();
    registry.install_with(
        demo_artifact("alpha", 1, 16, 3),
        PathBuf::from("<chaos:alpha>"),
        Some(fault),
    );
    registry.install(demo_artifact("beta", 1, 8, 11), PathBuf::from("<chaos:beta>"));
    registry
}

#[test]
fn injected_panic_answers_every_request_and_server_keeps_serving() {
    // The first alpha batch panics mid-execution. Containment invariant:
    // every concurrent client still gets exactly one response (500 for the
    // panicked batch's riders, 200 for the rest — zero hangs), the worker
    // survives, and post-fault responses are bit-identical to a clean
    // prepared-graph twin.
    let registry = faulted_registry(FaultPlan { panic_on_run: 1, ..Default::default() });
    // Breaker off: this test is about containment, not quarantine.
    registry.set_quarantine(QuarantineConfig { threshold: 0, ..Default::default() });
    let server = Server::start(registry, fresh_policy(), 2, ServeConfig::default()).expect("start");
    let addr = server.local_addr();

    let ok = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let (ok, failed) = (Arc::clone(&ok), Arc::clone(&failed));
            std::thread::spawn(move || {
                let mut client = HttpClient::connect(addr).expect("connect");
                let mut rng = Rng::seeded(500 + t as u64);
                for _ in 0..8 {
                    let img = image(&mut rng);
                    let resp = client.infer("alpha", &img).expect("every request must answer");
                    match resp.status {
                        200 => ok.fetch_add(1, Ordering::SeqCst),
                        500 => {
                            assert!(
                                resp.body_text().contains("\"error\":\"internal\""),
                                "body: {}",
                                resp.body_text()
                            );
                            failed.fetch_add(1, Ordering::SeqCst)
                        }
                        other => panic!("unexpected status {other}: {}", resp.body_text()),
                    };
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let (ok, failed) = (ok.load(Ordering::SeqCst), failed.load(Ordering::SeqCst));
    assert_eq!(ok + failed, 64, "exactly one response per request — no hangs, no dupes");
    assert!(failed >= 1, "the injected panic must surface as at least one 500");
    assert!(failed <= 4, "only the panicked batch's riders may fail (max_batch=4)");

    // Post-fault bit-identity: the rebuilt worker state must produce
    // exactly what a clean prepared graph produces.
    let clean = ModelRegistry::new();
    clean.install(demo_artifact("alpha", 1, 16, 3), PathBuf::from("<chaos:ref>"));
    let entry = clean.resolve("alpha").expect("ref entry");
    let mut state = ExecState::new();
    let mut client = HttpClient::connect(addr).expect("reconnect");
    let mut rng = Rng::seeded(4242);
    for _ in 0..4 {
        let values = image(&mut rng);
        let resp = client.infer("alpha", &values).expect("post-fault infer");
        assert_eq!(resp.status, 200, "post-fault requests must succeed");
        let got = resp.body_f32().expect("f32 body");
        let x = Tensor::from_vec(&entry.batched_shape(1), values);
        let want = entry.plan.run(&x, &mut state);
        assert_eq!(got.len(), want.data().len());
        for (g, w) in got.iter().zip(want.data()) {
            assert_eq!(g.to_bits(), w.to_bits(), "post-fault output diverged from clean twin");
        }
    }

    // The panic is visible in the metrics export, counted exactly once.
    let text = client.get("/metrics").expect("metrics").body_text();
    assert!(
        text.contains("iaoi_worker_panics_total{model=\"alpha\"} 1"),
        "metrics: {text}"
    );
    let report = server.shutdown();
    assert!(report.drained_clean);
}

#[test]
fn quarantine_trips_at_exactly_k_and_recovers_on_swap() {
    // alpha panics on every batch; threshold 2. The breaker must trip at
    // exactly the second panic — request 1 and 2 answer contained 500s,
    // request 3 is refused 503 "quarantined" without touching the engine —
    // and a hot-swap to a healthy version must reset it.
    let registry = faulted_registry(FaultPlan { panic_every: 1, ..Default::default() });
    registry.set_quarantine(QuarantineConfig { threshold: 2, ..Default::default() });
    let server = Server::start(registry, fresh_policy(), 2, ServeConfig::default()).expect("start");
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut rng = Rng::seeded(9);
    let img = image(&mut rng);

    for i in 0..2 {
        let resp = client.infer("alpha", &img).expect("contained failure");
        assert_eq!(resp.status, 500, "panic {i} must answer a contained 500");
    }
    let resp = client.infer("alpha", &img).expect("quarantined rejection");
    assert_eq!(resp.status, 503, "the breaker must be open after exactly 2 panics");
    assert!(resp.body_text().contains("\"error\":\"quarantined\""), "body: {}", resp.body_text());

    // Health and metrics agree with the breaker state; the healthy sibling
    // is untouched.
    let text = client.get("/healthz").expect("healthz").body_text();
    assert!(text.contains("\"status\":\"quarantined\""), "health: {text}");
    assert!(text.contains("\"panics\":2"), "health: {text}");
    let text = client.get("/metrics").expect("metrics").body_text();
    assert!(text.contains("iaoi_quarantined{model=\"alpha\"} 1"), "metrics: {text}");
    assert!(text.contains("iaoi_quarantined{model=\"beta\"} 0"), "metrics: {text}");
    let resp = client.infer("beta", &img).expect("beta");
    assert_eq!(resp.status, 200, "a quarantined model must not take its siblings down");

    // Hot-swap alpha to a healthy v2: the breaker resets and the model
    // serves again under the new version.
    let dir = std::env::temp_dir().join(format!("iaoi-chaos-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let v2 = dir.join("alpha_v2.iaoiq");
    model_format::write_file(&v2, &demo_artifact("alpha", 2, 16, 3)).expect("write v2");
    let (old, new) = server.swap_model("alpha", &v2).expect("swap");
    assert_eq!((old, new), (Some(1), 2));
    let resp = client.infer("alpha", &img).expect("infer after swap");
    assert_eq!(resp.status, 200, "swap must lift the quarantine");
    assert_eq!(resp.header("X-Model-Version"), Some("2"));
    let text = client.get("/metrics").expect("metrics").body_text();
    assert!(text.contains("iaoi_quarantined{model=\"alpha\"} 0"), "metrics: {text}");

    let report = server.shutdown();
    assert!(report.drained_clean);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn expired_deadline_sheds_pre_execution_with_504() {
    // Socket path: an already-expired X-Deadline-Ms budget is shed by the
    // worker before execution — 504, batch_size 0, no engine time burned —
    // while a generous budget executes normally.
    let registry = ModelRegistry::new();
    registry.install(demo_artifact("alpha", 1, 16, 3), PathBuf::from("<chaos:alpha>"));
    let server = Server::start(registry, fresh_policy(), 2, ServeConfig::default()).expect("start");
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut rng = Rng::seeded(21);
    let img = image(&mut rng);
    let resp = client.infer_with_deadline_ms("alpha", &img, 0).expect("expired");
    assert_eq!(resp.status, 504, "body: {}", resp.body_text());
    assert!(resp.body_text().contains("\"error\":\"deadline_exceeded\""), "{}", resp.body_text());
    let resp = client.infer_with_deadline_ms("alpha", &img, 60_000).expect("generous");
    assert_eq!(resp.status, 200, "a generous deadline must not shed");
    let text = client.get("/metrics").expect("metrics").body_text();
    assert!(
        text.contains("iaoi_deadline_shed_total{model=\"alpha\"} 1"),
        "metrics: {text}"
    );
    let report = server.shutdown();
    assert!(report.drained_clean);

    // In-process path: the same rail through the routed client directly.
    let registry = ModelRegistry::new();
    registry.install(demo_artifact("alpha", 1, 16, 3), PathBuf::from("<chaos:alpha>"));
    let coord = MultiCoordinator::start(registry, fresh_policy(), 1);
    let client = coord.client();
    let entry = coord.registry().resolve("alpha").expect("entry");
    let x = Tensor::from_vec(&entry.batched_shape(1), image(&mut rng));
    let resp = client
        .infer_with_deadline("alpha", x, Some(Instant::now()))
        .expect("expired submit");
    assert_eq!(resp.outcome, Outcome::Expired);
    assert_eq!(resp.batch_size, 0, "an expired request must never join a batch execution");
    let metrics = coord.shutdown();
    assert_eq!(metrics.iter().map(|m| m.deadline_shed).sum::<u64>(), 1);
    assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 0);
}

#[test]
fn poisoned_locks_recover() {
    // A thread panicking while holding the shared metrics lock must not
    // wedge the coordinator: every lock in the serving path recovers from
    // poisoning instead of propagating it.
    let registry = ModelRegistry::new();
    registry.install(demo_artifact("alpha", 1, 16, 3), PathBuf::from("<chaos:alpha>"));
    let coord = MultiCoordinator::start(registry, fresh_policy(), 2);
    let handle = coord.metrics_handle();
    let poisoner = std::thread::spawn(move || {
        let _guard = handle.lock().expect("first holder sees a clean lock");
        panic!("poison the metrics lock");
    });
    assert!(poisoner.join().is_err(), "the poisoner must have panicked");

    // Inference and metrics collection both cross the poisoned lock.
    let client = coord.client();
    let entry = coord.registry().resolve("alpha").expect("entry");
    let mut rng = Rng::seeded(33);
    let x = Tensor::from_vec(&entry.batched_shape(1), image(&mut rng));
    let resp = client.infer("alpha", x).expect("infer across a poisoned lock");
    assert_eq!(resp.output().len(), 16);
    let metrics = coord.metrics();
    assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 1);
    coord.shutdown();
}

#[test]
fn idle_connections_time_out_and_acceptor_caps_connections() {
    // Two live keep-alive connections fill a cap of 2: the third arrival
    // is refused at the door with 503 over_capacity. Once the first two go
    // idle past keep_alive_timeout, the server reaps them and new
    // connections are admitted again — idle clients cannot pin threads.
    let cfg = ServeConfig {
        poll_interval: Duration::from_millis(10),
        keep_alive_timeout: Duration::from_millis(250),
        max_connections: 2,
        ..ServeConfig::default()
    };
    let registry = ModelRegistry::new();
    registry.install(demo_artifact("alpha", 1, 16, 3), PathBuf::from("<chaos:alpha>"));
    let server = Server::start(registry, fresh_policy(), 2, cfg).expect("start");
    let addr = server.local_addr();
    let mut rng = Rng::seeded(27);
    let img = image(&mut rng);

    let mut first = HttpClient::connect(addr).expect("connect 1");
    assert_eq!(first.infer("alpha", &img).expect("infer").status, 200);
    let mut second = HttpClient::connect(addr).expect("connect 2");
    assert_eq!(second.get("/healthz").expect("healthz").status, 200);
    let text = first.get("/metrics").expect("metrics").body_text();
    assert!(text.contains("iaoi_open_connections 2"), "metrics: {text}");

    // Past the cap: the acceptor answers 503 without reading a request.
    let mut third = HttpClient::connect(addr).expect("connect 3");
    let resp = third.read_response().expect("over-capacity rejection");
    assert_eq!(resp.status, 503);
    assert!(resp.body_text().contains("\"error\":\"over_capacity\""), "{}", resp.body_text());
    assert!(resp.header("Retry-After").is_some(), "rejection must hint a retry");

    // Let the two admitted connections idle out, then verify a fresh
    // client is admitted and served.
    std::thread::sleep(Duration::from_millis(700));
    let mut fresh = HttpClient::connect(addr).expect("connect after reap");
    let resp = fresh.infer("alpha", &img).expect("infer after reap");
    assert_eq!(resp.status, 200, "reaped idle connections must free cap slots");
    let text = fresh.get("/metrics").expect("metrics").body_text();
    assert!(text.contains("iaoi_open_connections 1"), "metrics: {text}");

    let report = server.shutdown();
    assert!(report.drained_clean);
}
