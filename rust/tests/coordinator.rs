//! Property tests over the serving coordinator's invariants (DESIGN.md §7),
//! using the seeded property harness from `iaoi::data` (no proptest in this
//! offline build — failures print a replay seed).

use iaoi::coordinator::{BatchPolicy, Coordinator, EngineKind};
use iaoi::data::{check, Rng};
use iaoi::graph::builders::papernet_random;
use iaoi::nn::FusedActivation;
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

fn engine(seed: u64) -> EngineKind {
    let g = papernet_random(4, FusedActivation::Relu6, seed);
    let mut rng = Rng::seeded(seed);
    let calib: Vec<Tensor<f32>> = (0..2)
        .map(|_| {
            let mut d = vec![0f32; 16 * 16 * 3];
            for v in d.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            Tensor::from_vec(&[1, 16, 16, 3], d)
        })
        .collect();
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
    EngineKind::Quant(Arc::new(q))
}

fn image(rng: &mut Rng) -> Tensor<f32> {
    let mut d = vec![0f32; 16 * 16 * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    Tensor::from_vec(&[1, 16, 16, 3], d)
}

#[derive(Debug)]
struct Scenario {
    requests: usize,
    max_batch: usize,
    max_delay_us: u64,
    workers: usize,
}

fn gen_scenario(rng: &mut Rng) -> Scenario {
    Scenario {
        requests: 1 + rng.below(40),
        max_batch: 1 + rng.below(12),
        max_delay_us: 100 + rng.below(3000) as u64,
        workers: 1 + rng.below(3),
    }
}

#[test]
fn prop_every_request_completes_exactly_once() {
    check("exactly-once completion", 12, gen_scenario, |s| {
        let coord = Coordinator::start(
            engine(1),
            BatchPolicy {
                max_batch: s.max_batch,
                max_delay: Duration::from_micros(s.max_delay_us),
            },
            s.workers,
        );
        let client = coord.client();
        let mut rng = Rng::seeded(s.requests as u64);
        let pending: Vec<_> =
            (0..s.requests).map(|_| client.submit(image(&mut rng)).unwrap()).collect();
        let mut seen = HashSet::new();
        for (id, rx) in pending {
            let resp = rx.recv().expect("response");
            if resp.id != id || !seen.insert(resp.id) {
                return false;
            }
        }
        let m = coord.shutdown();
        m.completed as usize == s.requests && seen.len() == s.requests
    });
}

#[test]
fn prop_batch_sizes_respect_policy() {
    check("batch size bounds", 10, gen_scenario, |s| {
        let coord = Coordinator::start(
            engine(2),
            BatchPolicy {
                max_batch: s.max_batch,
                max_delay: Duration::from_micros(s.max_delay_us),
            },
            s.workers,
        );
        let client = coord.client();
        let mut rng = Rng::seeded(99 + s.requests as u64);
        let pending: Vec<_> =
            (0..s.requests).map(|_| client.submit(image(&mut rng)).unwrap()).collect();
        let ok = pending.into_iter().all(|(_, rx)| {
            let r = rx.recv().expect("response");
            r.batch_size >= 1 && r.batch_size <= s.max_batch
        });
        let m = coord.shutdown();
        // The histogram must also respect the bound.
        let hist_ok = m
            .batch_sizes
            .iter()
            .enumerate()
            .all(|(size, &count)| count == 0 || (1..=s.max_batch).contains(&size));
        ok && hist_ok
    });
}

#[test]
fn prop_responses_are_deterministic_per_input() {
    // The same image must produce identical outputs no matter how it gets
    // batched: quantized inference is bitwise deterministic.
    check("batching-invariant outputs", 6, gen_scenario, |s| {
        let eng = engine(3);
        let mut rng = Rng::seeded(7);
        let img = image(&mut rng);
        // Reference: direct single-request run.
        let coord1 = Coordinator::start(eng.clone(), BatchPolicy { max_batch: 1, max_delay: Duration::ZERO }, 1);
        let want = coord1.client().infer(img.clone()).unwrap().output;
        coord1.shutdown();
        // Same image inside a noisy burst under the scenario's policy.
        let coord = Coordinator::start(
            eng.clone(),
            BatchPolicy {
                max_batch: s.max_batch,
                max_delay: Duration::from_micros(s.max_delay_us),
            },
            s.workers,
        );
        let client = coord.client();
        let mut others = Vec::new();
        for _ in 0..s.requests.min(10) {
            others.push(client.submit(image(&mut rng)).unwrap());
        }
        let (_, rx) = client.submit(img.clone()).unwrap();
        let got = rx.recv().unwrap().output;
        for (_, orx) in others {
            let _ = orx.recv();
        }
        coord.shutdown();
        got == want
    });
}

#[test]
fn submit_after_shutdown_errors_cleanly() {
    let coord = Coordinator::start(engine(4), BatchPolicy::default(), 1);
    let client = coord.client();
    coord.shutdown();
    let mut rng = Rng::seeded(1);
    assert!(client.submit(image(&mut rng)).is_err());
}
