//! Property tests over the serving coordinator's invariants (DESIGN.md §7),
//! using the seeded property harness from `iaoi::data` (no proptest in this
//! offline build — failures print a replay seed), plus the multi-model
//! registry pipeline: per-model batching (batches never mix models) and
//! atomic hot-swap that drops no in-flight request.

use iaoi::coordinator::registry::ModelRegistry;
use iaoi::coordinator::{BatchPolicy, Coordinator, EngineKind, MultiCoordinator};
use iaoi::data::{check, Rng};
use iaoi::graph::builders::papernet_random;
use iaoi::harness::demo_artifact;
use iaoi::model_format;
use iaoi::nn::FusedActivation;
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn engine(seed: u64) -> EngineKind {
    let g = papernet_random(4, FusedActivation::Relu6, seed);
    let mut rng = Rng::seeded(seed);
    let calib: Vec<Tensor<f32>> = (0..2)
        .map(|_| {
            let mut d = vec![0f32; 16 * 16 * 3];
            for v in d.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            Tensor::from_vec(&[1, 16, 16, 3], d)
        })
        .collect();
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
    EngineKind::Quant(Arc::new(q))
}

fn image(rng: &mut Rng) -> Tensor<f32> {
    let mut d = vec![0f32; 16 * 16 * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    Tensor::from_vec(&[1, 16, 16, 3], d)
}

#[derive(Debug)]
struct Scenario {
    requests: usize,
    max_batch: usize,
    max_delay_us: u64,
    workers: usize,
}

fn gen_scenario(rng: &mut Rng) -> Scenario {
    Scenario {
        requests: 1 + rng.below(40),
        max_batch: 1 + rng.below(12),
        max_delay_us: 100 + rng.below(3000) as u64,
        workers: 1 + rng.below(3),
    }
}

#[test]
fn prop_every_request_completes_exactly_once() {
    check("exactly-once completion", 12, gen_scenario, |s| {
        let coord = Coordinator::start(
            engine(1),
            BatchPolicy {
                max_batch: s.max_batch,
                max_delay: Duration::from_micros(s.max_delay_us),
                ..Default::default()
            },
            s.workers,
        );
        let client = coord.client();
        let mut rng = Rng::seeded(s.requests as u64);
        let pending: Vec<_> =
            (0..s.requests).map(|_| client.submit(image(&mut rng)).unwrap()).collect();
        let mut seen = HashSet::new();
        for (id, rx) in pending {
            let resp = rx.recv().expect("response");
            if resp.id != id || !seen.insert(resp.id) {
                return false;
            }
        }
        let m = coord.shutdown();
        m.completed as usize == s.requests && seen.len() == s.requests
    });
}

#[test]
fn prop_batch_sizes_respect_policy() {
    check("batch size bounds", 10, gen_scenario, |s| {
        let coord = Coordinator::start(
            engine(2),
            BatchPolicy {
                max_batch: s.max_batch,
                max_delay: Duration::from_micros(s.max_delay_us),
                ..Default::default()
            },
            s.workers,
        );
        let client = coord.client();
        let mut rng = Rng::seeded(99 + s.requests as u64);
        let pending: Vec<_> =
            (0..s.requests).map(|_| client.submit(image(&mut rng)).unwrap()).collect();
        let ok = pending.into_iter().all(|(_, rx)| {
            let r = rx.recv().expect("response");
            r.batch_size >= 1 && r.batch_size <= s.max_batch
        });
        let m = coord.shutdown();
        // The histogram must also respect the bound.
        let hist_ok = m
            .batch_sizes
            .iter()
            .enumerate()
            .all(|(size, &count)| count == 0 || (1..=s.max_batch).contains(&size));
        ok && hist_ok
    });
}

#[test]
fn prop_responses_are_deterministic_per_input() {
    // The same image must produce identical outputs no matter how it gets
    // batched: quantized inference is bitwise deterministic.
    check("batching-invariant outputs", 6, gen_scenario, |s| {
        let eng = engine(3);
        let mut rng = Rng::seeded(7);
        let img = image(&mut rng);
        // Reference: direct single-request run.
        let coord1 = Coordinator::start(eng.clone(), BatchPolicy { max_batch: 1, max_delay: Duration::ZERO, ..Default::default() }, 1);
        let want = coord1.client().infer(img.clone()).unwrap().output().to_vec();
        coord1.shutdown();
        // Same image inside a noisy burst under the scenario's policy.
        let coord = Coordinator::start(
            eng.clone(),
            BatchPolicy {
                max_batch: s.max_batch,
                max_delay: Duration::from_micros(s.max_delay_us),
                ..Default::default()
            },
            s.workers,
        );
        let client = coord.client();
        let mut others = Vec::new();
        for _ in 0..s.requests.min(10) {
            others.push(client.submit(image(&mut rng)).unwrap());
        }
        let (_, rx) = client.submit(img.clone()).unwrap();
        let got = rx.recv().unwrap().output().to_vec();
        for (_, orx) in others {
            let _ = orx.recv();
        }
        coord.shutdown();
        got == want
    });
}

#[test]
fn prop_intra_pool_serving_preserves_all_invariants() {
    // With a shared intra-op worker pool (--intra-threads > 1) every
    // request still completes exactly once, responses keep their ids, and
    // outputs are bit-identical to intra_threads = 1 — the pool only
    // changes who computes each GEMM strip.
    check("intra-pool exactly-once + determinism", 6, gen_scenario, |s| {
        let eng = engine(5);
        let mut rng = Rng::seeded(31 + s.requests as u64);
        let images: Vec<Tensor<f32>> = (0..s.requests).map(|_| image(&mut rng)).collect();
        // Reference outputs from a serial coordinator.
        let serial = Coordinator::start(eng.clone(), BatchPolicy::default(), 1);
        let want: Vec<Vec<f32>> = images
            .iter()
            .map(|x| serial.client().infer(x.clone()).unwrap().output().to_vec())
            .collect();
        serial.shutdown();

        let coord = Coordinator::start(
            eng,
            BatchPolicy {
                max_batch: s.max_batch,
                max_delay: Duration::from_micros(s.max_delay_us),
                intra_threads: 2 + s.workers, // always > 1
                ..Default::default()
            },
            s.workers,
        );
        let client = coord.client();
        let pending: Vec<_> = images.iter().map(|x| client.submit(x.clone()).unwrap()).collect();
        let mut seen = HashSet::new();
        for ((id, rx), want) in pending.into_iter().zip(&want) {
            let resp = rx.recv().expect("response");
            if resp.id != id || !seen.insert(resp.id) || resp.output() != want.as_slice() {
                return false;
            }
        }
        let m = coord.shutdown();
        m.completed as usize == s.requests && seen.len() == s.requests
    });
}

#[test]
fn intra_pool_multi_model_serving_is_deterministic() {
    // The multi-model pipeline shares one pool across workers and models.
    let registry = two_model_registry();
    let serial = MultiCoordinator::start(registry, BatchPolicy::default(), 1);
    let mut rng = Rng::seeded(47);
    let images: Vec<(String, Tensor<f32>)> = (0..12)
        .map(|i| {
            let name = if i % 2 == 0 { "wide" } else { "narrow" };
            (name.to_string(), image(&mut rng))
        })
        .collect();
    let want: Vec<Vec<f32>> = images
        .iter()
        .map(|(name, x)| serial.client().infer(name, x.clone()).unwrap().output().to_vec())
        .collect();
    serial.shutdown();

    let coord = MultiCoordinator::start(
        two_model_registry(),
        BatchPolicy { intra_threads: 3, ..Default::default() },
        2,
    );
    let client = coord.client();
    let pending: Vec<_> =
        images.iter().map(|(name, x)| client.submit(name, x.clone()).unwrap()).collect();
    let mut seen = HashSet::new();
    for ((id, rx), want) in pending.into_iter().zip(&want) {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.id, id);
        assert_eq!(resp.output(), want.as_slice(), "pooled multi-model output diverged");
        assert!(seen.insert(id), "duplicate completion");
    }
    assert_eq!(seen.len(), 12);
    coord.shutdown();
}

#[test]
fn submit_after_shutdown_errors_cleanly() {
    let coord = Coordinator::start(engine(4), BatchPolicy::default(), 1);
    let client = coord.client();
    coord.shutdown();
    let mut rng = Rng::seeded(1);
    assert!(client.submit(image(&mut rng)).is_err());
}

// ---- multi-model registry pipeline ----

fn two_model_registry() -> ModelRegistry {
    let registry = ModelRegistry::new();
    // Different class counts make any cross-model batch mix-up visible in
    // the output arity.
    registry.install(demo_artifact("wide", 1, 16, 100), PathBuf::new());
    registry.install(demo_artifact("narrow", 1, 4, 200), PathBuf::new());
    registry
}

#[test]
fn routed_requests_complete_on_their_own_model() {
    let coord = MultiCoordinator::start(
        two_model_registry(),
        BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(5), ..Default::default() },
        2,
    );
    let client = coord.client();
    let mut rng = Rng::seeded(11);
    // Interleave the two models aggressively inside the batching window.
    let pending: Vec<_> = (0..40)
        .map(|i| {
            let name = if i % 2 == 0 { "wide" } else { "narrow" };
            (name, client.submit(name, image(&mut rng)).unwrap())
        })
        .collect();
    let mut seen = HashSet::new();
    for (name, (id, rx)) in pending {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.id, id);
        assert_eq!(resp.model, name);
        let want_classes = if name == "wide" { 16 } else { 4 };
        assert_eq!(resp.output().len(), want_classes, "batch mixed models!");
        assert!(seen.insert(id), "duplicate completion");
    }
    let metrics = coord.shutdown();
    assert_eq!(metrics.len(), 2);
    assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 40);
    for m in &metrics {
        assert_eq!(m.completed, 20, "{}", m.engine);
    }
}

#[test]
fn unknown_model_and_bad_shape_error_at_submit() {
    let coord = MultiCoordinator::start(two_model_registry(), BatchPolicy::default(), 1);
    let client = coord.client();
    let mut rng = Rng::seeded(3);
    let err = client.submit("missing", image(&mut rng)).unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");
    let bad = Tensor::<f32>::zeros(&[1, 8, 8, 3]);
    let err = client.submit("wide", bad).unwrap_err();
    assert!(err.to_string().contains("input shape"), "{err}");
    coord.shutdown();
}

#[test]
fn hot_swap_mid_stream_drops_nothing_and_routes_new_traffic_to_v2() {
    let dir = std::env::temp_dir()
        .join(format!("iaoi-coord-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let v2_path = dir.join("wide_v2.iaoiq");
    model_format::write_file(&v2_path, &demo_artifact("wide", 2, 16, 300)).unwrap();

    let registry = two_model_registry();
    let coord = MultiCoordinator::start(
        registry.clone(),
        BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(20), ..Default::default() },
        2,
    );
    let client = coord.client();
    let mut rng = Rng::seeded(17);
    // Phase 1: keep a burst in flight across the swap.
    let inflight: Vec<_> = (0..12).map(|_| client.submit("wide", image(&mut rng)).unwrap()).collect();
    let (old, new) = registry.swap("wide", &v2_path).expect("swap");
    assert_eq!((old, new), (Some(1), 2));
    for (id, rx) in inflight {
        let resp = rx.recv().expect("in-flight request must survive the swap");
        assert_eq!(resp.id, id);
        assert_eq!(resp.output().len(), 16);
        assert!(resp.version == 1 || resp.version == 2, "version {}", resp.version);
    }
    // Phase 2: everything submitted after the swap drained must be v2.
    for _ in 0..8 {
        let resp = client.infer("wide", image(&mut rng)).unwrap();
        assert_eq!(resp.version, 2, "post-swap traffic must hit the new model");
    }
    // The sibling model is untouched.
    let resp = client.infer("narrow", image(&mut rng)).unwrap();
    assert_eq!((resp.version, resp.output().len()), (1, 4));
    coord.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn multi_shutdown_drains_inflight() {
    let coord = MultiCoordinator::start(two_model_registry(), BatchPolicy::default(), 1);
    let client = coord.client();
    let mut rng = Rng::seeded(23);
    let pending: Vec<_> = (0..10)
        .map(|i| {
            let name = if i % 2 == 0 { "wide" } else { "narrow" };
            client.submit(name, image(&mut rng)).unwrap()
        })
        .collect();
    let metrics = coord.shutdown();
    assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 10);
    for (_, rx) in pending {
        assert!(rx.recv().is_ok(), "request must complete before shutdown");
    }
}
