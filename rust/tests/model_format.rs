//! `.iaoiq` artifact format tests: lossless round-trip (serialize →
//! deserialize → **bit-identical** uint8 inference on random inputs, the
//! acceptance bar for the deployment artifact) plus malformed-input
//! behaviour — truncated files, bad magic, future versions, flipped bytes,
//! single-bit corruption sweeps in copy *and* zero-copy load modes —
//! which must yield structured [`DecodeError`]s, never panics. Load-mode
//! equivalence (copy / zerocopy / mmap produce bit-identical graphs) is
//! pinned here too.

use iaoi::data::{check, Rng};
use iaoi::graph::builders::{mini_resnet, papernet_heterogeneous_dw, papernet_random};
use iaoi::graph::{FloatGraph, FloatOp, NodeRef, QOp};
use iaoi::model_format::{self, DecodeError, LoadMode, ModelArtifact};
use iaoi::nn::conv::Conv2d;
use iaoi::nn::fc::FullyConnected;
use iaoi::nn::{FusedActivation, Padding, QTensor};
use iaoi::quantize::{quantize_graph, QuantMode, QuantizeOptions};
use iaoi::tensor::{ArtifactBytes, Tensor};

/// Serialize, panicking on the (structured) encode errors no valid
/// converter output can produce — the tests' encode helper.
fn save(art: &ModelArtifact) -> Vec<u8> {
    model_format::save(art).expect("valid artifact must encode")
}

/// Downgrade a freshly-encoded v3 buffer to a valid v2 one: drop the
/// header checksum and patch the version. The payload layout is identical
/// from the name field onward, so this is exactly what a v2 writer would
/// have produced.
fn to_v2(v3: &[u8]) -> Vec<u8> {
    assert_eq!(&v3[..4], model_format::MAGIC);
    let mut out = Vec::with_capacity(v3.len() - 8);
    out.extend_from_slice(&v3[..4]);
    out.extend_from_slice(&2u32.to_le_bytes());
    out.extend_from_slice(&v3[model_format::PAYLOAD_OFFSET..]);
    out
}

fn random_batches(rng: &mut Rng, shape: &[usize], count: usize) -> Vec<Tensor<f32>> {
    (0..count)
        .map(|_| {
            let mut d = vec![0f32; shape.iter().product()];
            for v in d.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            Tensor::from_vec(shape, d)
        })
        .collect()
}

fn ptq_artifact(g: &FloatGraph, input_hw: usize, seed: u64) -> ModelArtifact {
    let mut rng = Rng::seeded(seed);
    let calib = random_batches(&mut rng, &[2, input_hw, input_hw, 3], 3);
    let (_, q) = quantize_graph(g, &calib, QuantizeOptions::default());
    ModelArtifact::new("test-model", 1, [input_hw, input_hw, 3], q)
}

/// The acceptance property: a reloaded graph produces bit-identical
/// quantized outputs at *every* node, for every input.
fn assert_bit_identical(art: &ModelArtifact, inputs: &[Tensor<f32>]) {
    let bytes = save(art);
    let loaded = model_format::load(&bytes).expect("load");
    assert_eq!(loaded.graph.nodes.len(), art.graph.nodes.len());
    for x in inputs {
        let want = art.graph.run_all(x);
        let got = loaded.graph.run_all(x);
        assert_eq!(want.len(), got.len());
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.params, g.params, "node {i} params");
            assert_eq!(w.data, g.data, "node {i} uint8 output differs after reload");
        }
    }
    // Determinism oracle: re-serializing the loaded graph reproduces the
    // bytes exactly, so nothing was lost or renormalized in flight.
    assert_eq!(save(&loaded), bytes);
}

#[test]
fn papernet_roundtrip_bit_identical() {
    let g = papernet_random(16, FusedActivation::Relu6, 7);
    let art = ptq_artifact(&g, 16, 7);
    let mut rng = Rng::seeded(99);
    let inputs = random_batches(&mut rng, &[2, 16, 16, 3], 3);
    assert_bit_identical(&art, &inputs);
}

#[test]
fn resnet_with_bypass_roundtrip_bit_identical() {
    // mini_resnet exercises Add nodes, 1x1 projections and ReLU fusion.
    let g = mini_resnet(1, 8, 21);
    let art = ptq_artifact(&g, 12, 21);
    let mut rng = Rng::seeded(22);
    let inputs = random_batches(&mut rng, &[1, 12, 12, 3], 2);
    assert_bit_identical(&art, &inputs);
}

#[test]
fn concat_pool_softmax_roundtrip_bit_identical() {
    // Hand-built graph covering the ops the model builders don't: Concat
    // (App. A.3 shared params), both pool kinds, and Softmax.
    let mut rng = Rng::seeded(31);
    let mut g = FloatGraph::default();
    let mut w = vec![0f32; 4 * 3 * 3 * 3];
    rng.fill_normal(&mut w, 0.3);
    let conv = Conv2d {
        weights: Tensor::from_vec(&[4, 3, 3, 3], w),
        bias: vec![0.1, -0.1, 0.2, 0.0],
        stride: 1,
        padding: Padding::Same,
        activation: FusedActivation::None,
    };
    let c = g.push("conv", NodeRef::Input, FloatOp::Conv(conv));
    let r = g.push("relu", c, FloatOp::Relu6);
    let p1 = g.push("maxpool", r, FloatOp::MaxPool { kernel: 2, stride: 2, padding: Padding::Valid });
    let p2 = g.push("avgpool", r, FloatOp::AvgPool { kernel: 2, stride: 2, padding: Padding::Valid });
    let cat = g.push("cat", p1, FloatOp::Concat(vec![p2]));
    let gap = g.push("gap", cat, FloatOp::GlobalAvgPool);
    let mut fw = vec![0f32; 5 * 8];
    rng.fill_normal(&mut fw, 0.3);
    let fc = g.push(
        "logits",
        gap,
        FloatOp::Fc(FullyConnected {
            weights: Tensor::from_vec(&[5, 8], fw),
            bias: vec![0.0; 5],
            activation: FusedActivation::None,
        }),
    );
    g.push("softmax", fc, FloatOp::Softmax);

    let art = ptq_artifact(&g, 8, 31);
    let mut rng = Rng::seeded(32);
    let inputs = random_batches(&mut rng, &[2, 8, 8, 3], 2);
    assert_bit_identical(&art, &inputs);
}

#[test]
fn prop_random_models_roundtrip_bit_identical() {
    // Seeded property sweep: random architecture knobs, random inputs.
    check(
        "artifact round-trip is lossless",
        6,
        |rng| {
            (
                4 + rng.below(16),                   // classes
                rng.below(3) as u64 + rng.next_u64() % 1000, // model seed
            )
        },
        |&(classes, seed)| {
            let act = if seed % 2 == 0 { FusedActivation::Relu6 } else { FusedActivation::Relu };
            let g = papernet_random(classes, act, seed);
            let art = ptq_artifact(&g, 16, seed ^ 0xabc);
            let mut rng = Rng::seeded(seed ^ 0xdef);
            let inputs = random_batches(&mut rng, &[1, 16, 16, 3], 1);
            let bytes = save(&art);
            let loaded = match model_format::load(&bytes) {
                Ok(l) => l,
                Err(_) => return false,
            };
            let want = art.graph.run_q(&iaoi::nn::QTensor::quantize(&inputs[0], art.graph.input_params));
            let got = loaded.graph.run_q(&iaoi::nn::QTensor::quantize(&inputs[0], loaded.graph.input_params));
            want.data == got.data && want.params == got.params
        },
    );
}

#[test]
fn load_then_prepare_matches_in_memory_conversion_bit_for_bit() {
    // The deployment path — serialize → load → prepare → infer — must be
    // bit-identical to preparing the in-memory graph the converter
    // produced, and both must match the unprepared executor.
    let g = mini_resnet(1, 6, 41);
    let art = ptq_artifact(&g, 12, 41);
    let bytes = save(&art);
    let loaded = model_format::load(&bytes).expect("load");

    let plan_mem = art.graph.prepare();
    let plan_loaded = loaded.prepare();
    let mut state_mem = iaoi::graph::ExecState::new();
    let mut state_loaded = iaoi::graph::ExecState::new();

    let mut rng = Rng::seeded(42);
    for x in random_batches(&mut rng, &[2, 12, 12, 3], 3) {
        let qin = iaoi::nn::QTensor::quantize(&x, art.graph.input_params);
        let want = art.graph.run_q(&qin);
        let got_mem = plan_mem.run_q(&qin, &mut state_mem);
        assert_eq!(want.data, got_mem.data, "prepared(in-memory) diverged");
        let got_loaded = plan_loaded.run_q(&qin, &mut state_loaded);
        assert_eq!(want.data, got_loaded.data, "prepared(loaded) diverged");
    }
}

/// A version-1 artifact produced before the v2 (per-channel) format landed:
/// one FC node with hand-picked exactly-representable parameters
/// (`S_w = S_in = 0.5`, `S_out = 128`, so `M = 2^-9` → `m0 = 2^30`,
/// `shift = −8`). Golden backward-compat anchor: v1 files must keep
/// decoding and producing bit-identical outputs forever.
const GOLDEN_V1: &[u8] = include_bytes!("golden_v1.iaoiq");

#[test]
fn golden_v1_artifact_decodes_and_infers_bit_identically() {
    let art = model_format::load(GOLDEN_V1).expect("v1 artifact must keep loading");
    assert_eq!(art.name, "golden");
    assert_eq!(art.version, 7);
    assert_eq!(art.input_shape, [1, 1, 4]);
    assert_eq!(art.graph.nodes.len(), 1);
    let iaoi::graph::QOp::Fc(fc) = &art.graph.nodes[0].op else {
        panic!("golden node must be the FC classifier");
    };
    assert!(!fc.weight_quant.is_per_channel(), "v1 is always per-tensor");
    assert_eq!(fc.weight_quant.zero_point(), 128);
    assert_eq!(fc.bias, vec![10, -10]);

    // Fixed uint8 input through the decoded graph: the integer pipeline's
    // output bytes are pinned (acc → ×2^-9 via srdhm + rounding shift).
    let qin = QTensor {
        data: Tensor::from_vec(&[1, 4], vec![0u8, 50, 100, 200]),
        params: art.graph.input_params,
    };
    let out = art.graph.run_q(&qin);
    assert_eq!(out.data.data(), &[29u8, 53], "v1 arithmetic drifted");

    // And through the prepared deployment path.
    let plan = art.prepare();
    let mut state = iaoi::graph::ExecState::new();
    let got = plan.run_q(&qin, &mut state);
    assert_eq!(got.data.data(), &[29u8, 53], "v1 prepared arithmetic drifted");
}

#[test]
fn per_channel_model_roundtrips_through_v2_bit_identically() {
    // The acceptance path for the v2 format: a per-channel-quantized synth
    // depthwise model must save → load → prepare → infer bit-identically.
    let g = papernet_heterogeneous_dw(8, 61);
    let mut rng = Rng::seeded(61);
    let calib = random_batches(&mut rng, &[2, 16, 16, 3], 3);
    let (_, q) = quantize_graph(
        &g,
        &calib,
        QuantizeOptions { mode: QuantMode::PerChannel, ..Default::default() },
    );
    let art = ModelArtifact::new("pc-model", 2, [16, 16, 3], q);
    let inputs = random_batches(&mut rng, &[2, 16, 16, 3], 3);
    assert_bit_identical(&art, &inputs);

    // Deployment path: loaded + prepared executor agrees too.
    let bytes = save(&art);
    let loaded = model_format::load(&bytes).expect("load v2");
    let plan = loaded.prepare();
    let mut state = iaoi::graph::ExecState::new();
    for x in &inputs {
        let qin = QTensor::quantize(x, art.graph.input_params);
        let want = art.graph.run_q(&qin);
        let got = plan.run_q(&qin, &mut state);
        assert_eq!(want.data, got.data, "prepared(loaded v2) diverged");
    }

    // Corrupt sweep: flipped bytes in a per-channel artifact must never
    // panic (structured errors or clean payload-only damage).
    for pos in (0..bytes.len()).step_by(7) {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0xa5;
        let _ = model_format::load(&corrupt);
    }
}

#[test]
fn truncated_files_error_never_panic() {
    let g = papernet_random(8, FusedActivation::Relu6, 3);
    let art = ptq_artifact(&g, 16, 3);
    let bytes = save(&art);
    // Every strict prefix must decode to a structured error.
    for len in 0..bytes.len() {
        let result = model_format::load(&bytes[..len]);
        assert!(result.is_err(), "prefix of {len} bytes decoded successfully?!");
    }
}

#[test]
fn corrupt_bytes_error_or_stay_wellformed_never_panic() {
    let g = papernet_random(4, FusedActivation::Relu6, 5);
    let art = ptq_artifact(&g, 16, 5);
    let bytes = save(&art);
    // Flipping any single byte must never panic: either a structured error
    // (structure damaged) or a clean decode (payload-only damage, e.g. a
    // weight byte).
    for pos in (0..bytes.len()).step_by(7) {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0xa5;
        let _ = model_format::load(&corrupt);
    }
}

#[test]
fn malformed_headers_are_structured_errors() {
    let g = papernet_random(4, FusedActivation::Relu6, 9);
    let art = ptq_artifact(&g, 16, 9);
    let bytes = save(&art);

    // Bad magic.
    let mut bad_magic = bytes.clone();
    bad_magic[..4].copy_from_slice(b"NOPE");
    assert_eq!(
        model_format::load(&bad_magic).unwrap_err(),
        DecodeError::BadMagic { found: *b"NOPE" }
    );

    // Version from the future.
    let mut future = bytes.clone();
    future[4..8].copy_from_slice(&7u32.to_le_bytes());
    assert_eq!(
        model_format::load(&future).unwrap_err(),
        DecodeError::UnsupportedVersion {
            found: 7,
            supported: model_format::FORMAT_VERSION
        }
    );

    // Trailing garbage after a complete artifact extends the checksummed
    // span, so the checksum catches it first; once the checksum is made
    // consistent again the structural diagnostic takes over.
    let mut trailing = bytes.clone();
    trailing.extend_from_slice(&[0; 5]);
    assert!(matches!(
        model_format::load(&trailing).unwrap_err(),
        DecodeError::ChecksumMismatch { .. }
    ));
    model_format::restamp_checksum(&mut trailing);
    assert_eq!(
        model_format::load(&trailing).unwrap_err(),
        DecodeError::TrailingBytes { extra: 5 }
    );

    // Empty and tiny buffers.
    assert!(matches!(model_format::load(&[]), Err(DecodeError::Truncated { .. })));
    assert!(matches!(model_format::load(b"IA"), Err(DecodeError::Truncated { .. })));
}

#[test]
fn unknown_op_code_is_rejected() {
    // A single-node Softmax graph ends with its op code as the final byte.
    let graph = {
        let g = papernet_random(4, FusedActivation::Relu6, 13);
        let art = ptq_artifact(&g, 16, 13);
        art.graph
    };
    let mut one_node = graph.clone();
    one_node.nodes.truncate(0);
    one_node.nodes.push(iaoi::graph::QNode {
        name: "sm".to_string(),
        input: NodeRef::Input,
        op: iaoi::graph::QOp::Softmax,
    });
    let art = ModelArtifact::new("tiny", 1, [4, 4, 3], one_node);
    let mut bytes = save(&art);
    let n = bytes.len();
    bytes[n - 1] = 0xee;
    // Restamp the header checksum so the structural validation is
    // reachable (otherwise the checksum reports the damage first).
    model_format::restamp_checksum(&mut bytes);
    assert_eq!(
        model_format::load(&bytes).unwrap_err(),
        DecodeError::BadOpCode { node: 0, code: 0xee }
    );
}

/// Decode under every in-memory load mode: plain copy and zero-copy
/// (shared heap buffer). Returns the results that decoded.
fn load_both_modes(bytes: &[u8]) -> [Result<ModelArtifact, DecodeError>; 2] {
    let copied = model_format::load(bytes);
    let buf = ArtifactBytes::from_vec(bytes.to_vec());
    let shared = model_format::load_shared(&buf);
    [copied, shared]
}

#[test]
fn all_load_modes_are_bit_identical_through_prepare_and_infer() {
    // The acceptance bar for the zero-copy storage refactor: copy,
    // zerocopy and mmap loads of the same file must produce graphs whose
    // unprepared *and* prepared executors emit identical output bytes, and
    // which re-encode to the identical artifact.
    let g = mini_resnet(1, 6, 77);
    let art = ptq_artifact(&g, 12, 77);
    let bytes = save(&art);
    let dir = std::env::temp_dir().join(format!("iaoi-mf-modes-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.iaoiq");
    std::fs::write(&path, &bytes).unwrap();

    let mut rng = Rng::seeded(78);
    let inputs = random_batches(&mut rng, &[2, 12, 12, 3], 2);

    let reference = model_format::read_file_with(&path, LoadMode::Copy).unwrap();
    assert!(reference.backing.is_none());
    for mode in [LoadMode::Copy, LoadMode::ZeroCopy, LoadMode::Mmap] {
        let loaded = model_format::read_file_with(&path, mode).unwrap();
        if mode != LoadMode::Copy {
            assert!(loaded.backing.is_some(), "{mode:?} must carry its buffer");
            let views = loaded
                .graph
                .nodes
                .iter()
                .filter(|n| match &n.op {
                    QOp::Conv(c) => c.weights.is_view(),
                    QOp::Depthwise(d) => d.weights.is_view(),
                    QOp::Fc(fc) => fc.weights.is_view(),
                    _ => false,
                })
                .count();
            assert!(views > 0, "{mode:?} must borrow large weight tensors");
        }
        assert_eq!(save(&loaded), bytes, "{mode:?} re-encode drifted");
        let plan = loaded.prepare();
        let mut state = iaoi::graph::ExecState::new();
        for x in &inputs {
            let qin = QTensor::quantize(x, reference.graph.input_params);
            let want = reference.graph.run_q(&qin);
            let got = loaded.graph.run_q(&qin);
            assert_eq!(want.data, got.data, "{mode:?} unprepared diverged");
            let got_prepared = plan.run_q(&qin, &mut state);
            assert_eq!(want.data, got_prepared.data, "{mode:?} prepared diverged");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn golden_v1_decodes_identically_in_zero_copy_mode() {
    let copy = model_format::load(GOLDEN_V1).expect("v1 copy load");
    let buf = ArtifactBytes::from_vec(GOLDEN_V1.to_vec());
    let shared = model_format::load_shared(&buf).expect("v1 zero-copy load");
    let qin = QTensor {
        data: Tensor::from_vec(&[1, 4], vec![0u8, 50, 100, 200]),
        params: shared.graph.input_params,
    };
    assert_eq!(copy.graph.run_q(&qin).data, shared.graph.run_q(&qin).data);
    assert_eq!(shared.graph.run_q(&qin).data.data(), &[29u8, 53]);
}

/// Fuzz-lite: every single-bit flip and every truncation boundary of an
/// artifact buffer must produce a structured error or a clean decode —
/// never a panic — in both copy and zero-copy load modes.
fn corruption_sweep(label: &str, bytes: &[u8]) {
    for pos in 0..bytes.len() {
        for bit in 0..8 {
            let mut corrupt = bytes.to_vec();
            corrupt[pos] ^= 1 << bit;
            for result in load_both_modes(&corrupt) {
                // Either outcome is fine; panicking or over-allocating is
                // not. A clean decode can only happen where the flip landed
                // in an unchecksummed span (v1/v2 payload bytes) — such an
                // artifact must still re-encode without panicking.
                if let Ok(art) = result {
                    model_format::save(&art).expect("decoded artifact re-encodes");
                }
            }
        }
    }
    for len in 0..bytes.len() {
        for result in load_both_modes(&bytes[..len]) {
            assert!(result.is_err(), "{label}: prefix of {len} bytes decoded successfully?!");
        }
    }
}

#[test]
fn corruption_sweep_golden_v1_never_panics() {
    corruption_sweep("golden v1", GOLDEN_V1);
}

#[test]
fn corruption_sweep_v2_and_v3_never_panic() {
    // A small fresh artifact keeps the exhaustive bit-flip sweep cheap.
    let g = papernet_random(4, FusedActivation::Relu6, 83);
    let art = ptq_artifact(&g, 8, 83);
    let v3 = save(&art);
    let v2 = to_v2(&v3);
    // The downgrade itself must be a valid v2 artifact with identical
    // semantics (same payload, no checksum).
    let from_v2 = model_format::load(&v2).expect("downgraded v2 decodes");
    assert_eq!(from_v2.graph.nodes.len(), art.graph.nodes.len());
    corruption_sweep("fresh v3", &v3);
    corruption_sweep("fresh v2", &v2);
}

#[test]
fn file_roundtrip_and_extension() {
    let g = papernet_random(4, FusedActivation::Relu6, 17);
    let art = ptq_artifact(&g, 16, 17);
    let dir = std::env::temp_dir().join(format!("iaoi-mf-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("m.{}", model_format::EXTENSION));
    model_format::write_file(&path, &art).unwrap();
    let loaded = model_format::read_file(&path).unwrap();
    assert_eq!(loaded.name, art.name);
    assert_eq!(save(&loaded), save(&art));
    let _ = std::fs::remove_dir_all(&dir);
}
