//! Cross-kernel bit-identity property suite for the runtime-dispatched
//! SIMD micro-kernels ([`iaoi::gemm::dispatch`]).
//!
//! The dispatch layer's hard invariant is that every SIMD tile (SSE2,
//! AVX2, AVX-512) produces **byte-identical** int32 accumulators — and
//! therefore byte-identical uint8 outputs — to the scalar tile on every
//! shape, every tail, and every operand value. These tests enforce it at
//! four levels:
//!
//! 1. exhaustively over the `m % MR` × `n % NR` × `k % KC` tail lattice on
//!    the raw unprepared accumulation;
//! 2. at the u8 value extremes (all-zeros, all-255, alternating) crossed
//!    with zero-point extremes, against the [`Kernel::Reference`] oracle;
//! 3. through the prepared / strip / scoped-spawn / worker-pool execution
//!    paths with a per-channel output stage;
//! 4. on whole quantized graphs (conv + depthwise + pointwise + FC) under
//!    both per-tensor and per-channel weight quantization.
//!
//! Plus the dispatch-resolution contract itself: name resolution, error
//! text, and the `IAOI_KERNEL` environment override (CI runs this whole
//! target under `IAOI_KERNEL=scalar` to pin the fallback everywhere).

use iaoi::data::Rng;
use iaoi::gemm::dispatch;
use iaoi::gemm::kernel::accumulate_blocked_with;
use iaoi::gemm::output::{OutputStage, Requant};
use iaoi::gemm::parallel::{run_parallel_prepared, run_strips_scoped};
use iaoi::gemm::{Kernel, PreparedGemm, QGemm, Scratch, WorkerPool, KC, MR, NR};
use iaoi::graph::builders::papernet_random;
use iaoi::graph::ExecState;
use iaoi::nn::{FusedActivation, QTensor};
use iaoi::quant::QuantizedMultiplier;
use iaoi::quantize::{quantize_graph, QuantMode, QuantizeOptions};
use iaoi::tensor::Tensor;

fn fill(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(256) as u8).collect()
}

/// Every tail remainder the blocking can produce: one past each tile
/// boundary in every dimension, plus a multi-tile case per axis.
#[test]
fn exhaustive_tails_bit_identical_to_scalar() {
    let scalar = dispatch::scalar();
    let simd: Vec<_> =
        dispatch::available().into_iter().filter(|d| d.name != scalar.name).collect();
    let mut ms: Vec<usize> = (1..=MR + 1).collect();
    ms.push(2 * MR + 3);
    let mut ns: Vec<usize> = (1..=NR + 1).collect();
    ns.push(2 * NR + 5);
    let ks = [1, 2, 3, 7, KC - 1, KC, KC + 1, 2 * KC + 5];
    let mut rng = Rng::seeded(4242);
    for &m in &ms {
        for &k in &ks {
            let lhs = fill(&mut rng, m * k);
            for &n in &ns {
                let rhs = fill(&mut rng, k * n);
                let g = QGemm::new(m, k, n, 128, 3);
                let mut golden = vec![0i32; m * n];
                accumulate_blocked_with(scalar, &g, &lhs, &rhs, &mut golden);
                for d in &simd {
                    let mut got = vec![0i32; m * n];
                    accumulate_blocked_with(d, &g, &lhs, &rhs, &mut got);
                    assert_eq!(golden, got, "{} != scalar at ({m},{k},{n})", d.name);
                }
            }
        }
    }
}

/// Operand and zero-point extremes against the eq. 4 reference oracle: the
/// pmaddwd schedule must stay exact at the very top of the u8 range (the
/// saturation-impossibility argument in dispatch.rs), and the eq. 7
/// corrections must hold for every legal zero-point corner.
#[test]
fn edge_values_and_zero_points_match_reference() {
    let (m, k, n) = (MR + 3, 67, NR + 7);
    let mut rng = Rng::seeded(99);
    let patterns: [Vec<u8>; 4] = [
        vec![0u8; m.max(n) * k],
        vec![255u8; m.max(n) * k],
        (0..m.max(n) * k).map(|i| if i % 2 == 0 { 0 } else { 255 }).collect(),
        fill(&mut rng, m.max(n) * k),
    ];
    for lhs_pat in &patterns {
        for rhs_pat in &patterns {
            let lhs = &lhs_pat[..m * k];
            let rhs = &rhs_pat[..k * n];
            for (z1, z2) in [(0, 0), (255, 255), (0, 255), (128, 77)] {
                let g = QGemm::new(m, k, n, z1, z2);
                let mut want = vec![0i32; m * n];
                g.accumulate(Kernel::Reference, lhs, rhs, &mut want);
                for d in dispatch::available() {
                    let mut got = vec![0i32; m * n];
                    accumulate_blocked_with(d, &g, lhs, rhs, &mut got);
                    assert_eq!(want, got, "{} != reference at Z1={z1} Z2={z2}", d.name);
                }
            }
        }
    }
}

fn per_channel_stage(m: usize) -> OutputStage {
    OutputStage {
        bias: (0..m as i32).map(|i| i * 19 - 70).collect(),
        multiplier: Requant::PerChannel(
            (0..m)
                .map(|i| QuantizedMultiplier::from_f64(0.0009 * 1.6f64.powi(i as i32 % 6)))
                .collect(),
        ),
        out_zero: 7,
        clamp_min: 0,
        clamp_max: 255,
    }
}

/// Forced micro-kernels through every prepared execution path — full run,
/// column strips, scoped-spawn threads, and the persistent worker pool —
/// with a per-channel output stage so requantization indexes per-row
/// multipliers on top of the SIMD accumulators.
#[test]
fn forced_ukernels_identical_through_prepared_and_parallel_paths() {
    let mut rng = Rng::seeded(7);
    for (m, k, n) in [(9, 300, 35), (MR + 1, KC + 1, NR + 1)] {
        let lhs = fill(&mut rng, m * k);
        let rhs = fill(&mut rng, k * n);
        let g = QGemm::new(m, k, n, 77, 201);
        let base = PreparedGemm::from_qgemm(&g, Kernel::Blocked, &lhs, per_channel_stage(m))
            .with_ukernel(dispatch::scalar());
        let mut want = vec![0u8; m * n];
        base.run(n, &rhs, &mut want, &mut Scratch::new());
        for d in dispatch::available() {
            let plan = base.clone().with_ukernel(d);
            let mut got = vec![0u8; m * n];
            let mut scratch = Scratch::new();
            plan.run(n, &rhs, &mut got, &mut scratch);
            assert_eq!(want, got, "{} run ({m},{k},{n})", d.name);
            // Warm-scratch rerun: buffer reuse must not corrupt.
            plan.run(n, &rhs, &mut got, &mut scratch);
            assert_eq!(want, got, "{} warm run ({m},{k},{n})", d.name);
            let mut scoped = vec![0u8; m * n];
            run_strips_scoped(&plan, &rhs, n, &mut scoped, 3);
            assert_eq!(want, scoped, "{} scoped ({m},{k},{n})", d.name);
            let pool = WorkerPool::new(2);
            let mut pooled = vec![0u8; m * n];
            run_parallel_prepared(&plan, &rhs, n, &mut pooled, &pool);
            assert_eq!(want, pooled, "{} pool ({m},{k},{n})", d.name);
        }
    }
}

/// Whole-graph bit-identity: the conv-dominated demo net, quantized under
/// both weight modes, must produce identical bytes through every forced
/// micro-kernel — and through the unprepared path, whichever kernel
/// [`dispatch::active`] selected for this process.
#[test]
fn whole_graph_identical_across_kernels_and_quant_modes() {
    let g = papernet_random(8, FusedActivation::Relu6, 91);
    let mut rng = Rng::seeded(91);
    let mk = |rng: &mut Rng, batch: usize| {
        let mut d = vec![0f32; batch * 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        Tensor::from_vec(&[batch, 16, 16, 3], d)
    };
    let calib = vec![mk(&mut rng, 2), mk(&mut rng, 2)];
    for mode in [QuantMode::PerTensor, QuantMode::PerChannel] {
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions { mode, ..Default::default() });
        let qin = QTensor::quantize(&mk(&mut rng, 3), q.input_params);

        let golden_plan = q.prepare().with_ukernel(dispatch::scalar());
        let mut state = ExecState::new();
        let want = golden_plan.run_q(&qin, &mut state).data.data().to_vec();

        // The unprepared engine dispatches through the process-wide
        // selection; bit-identity makes it agree with forced-scalar.
        let unprep = q.run_q(&qin);
        assert_eq!(want, unprep.data.data(), "unprepared diverged ({mode:?})");

        for d in dispatch::available() {
            let plan = q.prepare().with_ukernel(d);
            let mut st = ExecState::new();
            let got = plan.run_q(&qin, &mut st).data.data().to_vec();
            assert_eq!(want, got, "{} whole graph ({mode:?})", d.name);
            // Second run through the warmed state (reused scratch).
            let again = plan.run_q(&qin, &mut st).data.data().to_vec();
            assert_eq!(want, again, "{} whole graph warm ({mode:?})", d.name);
        }
    }
}

/// The dispatch-resolution contract: names resolve, errors name the
/// compiled-in kernels, and `IAOI_KERNEL` pins the process-wide selection
/// (CI runs the suite under `IAOI_KERNEL=scalar` to exercise the pin).
#[test]
fn dispatch_resolution_and_env_override() {
    assert_eq!(dispatch::resolve("scalar").expect("scalar always resolves").name, "scalar");
    let err = dispatch::resolve("neon").expect_err("unknown kernel must not resolve");
    assert!(err.contains("scalar"), "error should list compiled-in kernels: {err}");

    let available = dispatch::available();
    assert_eq!(available[0].name, "scalar", "scalar is the always-on baseline");
    for d in &available {
        assert_eq!(dispatch::resolve(d.name).expect("available kernels resolve").name, d.name);
    }
    let active = dispatch::active();
    assert!(
        available.iter().any(|d| d.name == active.name),
        "active kernel {} must be detected on this CPU",
        active.name
    );
    if let Ok(want) = std::env::var("IAOI_KERNEL") {
        assert_eq!(active.name, want.trim(), "IAOI_KERNEL override must win");
    }
    #[cfg(target_arch = "x86_64")]
    assert!(
        available.iter().any(|d| d.name == "sse2"),
        "SSE2 is baseline x86-64 and must always be detected"
    );
}
