//! Allocation-count regression test for the prepared execution path.
//!
//! A counting [`GlobalAlloc`] wraps the system allocator; the single test
//! below (kept alone in this target so no concurrent test can allocate
//! while the counter is armed) asserts that a prepared
//! [`iaoi::graph::PreparedGraph::run_q`] performs **zero** heap
//! allocations in steady state — i.e. after a warm-up pass has grown every
//! scratch buffer and output slot to its high-water mark — and, as a guard
//! that the counter itself works, that the unprepared [`QGraph::run_q`]
//! path does allocate.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use iaoi::data::Rng;
use iaoi::graph::builders::papernet_random;
use iaoi::graph::ExecState;
use iaoi::nn::{FusedActivation, QTensor};
use iaoi::quantize::{quantize_graph, QuantizeOptions};
use iaoi::tensor::Tensor;

/// Counts allocation events (alloc / alloc_zeroed / realloc) while armed.
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` with the counter armed, returning the number of allocation
/// events it performed.
fn count_allocs(f: impl FnOnce()) -> u64 {
    EVENTS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    f();
    ARMED.store(false, Ordering::SeqCst);
    EVENTS.load(Ordering::SeqCst)
}

#[test]
fn prepared_run_q_is_allocation_free_in_steady_state() {
    // Build the conv-dominated demo net (conv, depthwise, pointwise, GAP,
    // FC — every op on the zero-alloc path).
    let g = papernet_random(8, FusedActivation::Relu6, 91);
    let mut rng = Rng::seeded(91);
    let mk = |rng: &mut Rng, batch: usize| {
        let mut d = vec![0f32; batch * 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        Tensor::from_vec(&[batch, 16, 16, 3], d)
    };
    let calib = vec![mk(&mut rng, 2), mk(&mut rng, 2)];
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());

    let plan = q.prepare();
    let mut state = ExecState::new();
    let qin = QTensor::quantize(&mk(&mut rng, 4), q.input_params);

    // Warm-up: first runs may grow scratch buffers and output slots.
    plan.run_q(&qin, &mut state);
    plan.run_q(&qin, &mut state);

    // Steady state: same shape again — not one allocation event allowed.
    let steady = count_allocs(|| {
        plan.run_q(&qin, &mut state);
    });
    assert_eq!(steady, 0, "prepared run_q made {steady} allocations in steady state");

    // Guard: the counter must actually count — the unprepared path
    // reallocates intermediates every call.
    let unprepared = count_allocs(|| {
        let _ = q.run_q(&qin);
    });
    assert!(unprepared > 0, "allocation counter appears broken (unprepared counted 0)");

    // A smaller batch through the warmed state stays within the high-water
    // mark, so it is also allocation-free.
    let small = QTensor::quantize(&mk(&mut rng, 1), q.input_params);
    plan.run_q(&small, &mut state);
    let steady_small = count_allocs(|| {
        plan.run_q(&small, &mut state);
    });
    assert_eq!(steady_small, 0, "batch-1 steady state made {steady_small} allocations");
}
