//! Allocation-count regression test for the prepared execution path.
//!
//! The shared counting allocator (`iaoi::bench_util::counting_alloc`)
//! wraps the system allocator; the single test below (kept alone in this
//! target so no concurrent test can allocate while the counter is armed)
//! asserts that a prepared
//! [`iaoi::graph::PreparedGraph::run_q`] performs **zero** heap
//! allocations in steady state — i.e. after a warm-up pass has grown every
//! scratch buffer and output slot to its high-water mark — and, as a guard
//! that the counter itself works, that the unprepared [`QGraph::run_q`]
//! path does allocate.

use iaoi::bench_util::counting_alloc::{self, CountingAlloc};
use iaoi::data::Rng;
use iaoi::gemm::{Kernel, QGemm};
use iaoi::graph::builders::{mini_resnet, papernet_random};
use iaoi::graph::{ExecState, FloatGraph, FloatOp, NodeRef};
use iaoi::model_format::{self, ModelArtifact};
use iaoi::nn::conv::Conv2d;
use iaoi::nn::fc::FullyConnected;
use iaoi::nn::{FusedActivation, Padding, QTensor};
use iaoi::quantize::{quantize_graph, QuantMode, QuantizeOptions};
use iaoi::tensor::{ArtifactBytes, Tensor};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` with the counter armed, returning the number of allocation
/// events (alloc / alloc_zeroed / realloc) it performed.
fn count_allocs(f: impl FnOnce()) -> u64 {
    counting_alloc::measure(f).events
}

#[test]
fn prepared_run_q_is_allocation_free_in_steady_state() {
    // Build the conv-dominated demo net (conv, depthwise, pointwise, GAP,
    // FC — every op on the zero-alloc path).
    let g = papernet_random(8, FusedActivation::Relu6, 91);
    let mut rng = Rng::seeded(91);
    let mk = |rng: &mut Rng, batch: usize| {
        let mut d = vec![0f32; batch * 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        Tensor::from_vec(&[batch, 16, 16, 3], d)
    };
    let calib = vec![mk(&mut rng, 2), mk(&mut rng, 2)];
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());

    let plan = q.prepare();
    let mut state = ExecState::new();
    let qin = QTensor::quantize(&mk(&mut rng, 4), q.input_params);

    // Warm-up: first runs may grow scratch buffers and output slots.
    plan.run_q(&qin, &mut state);
    plan.run_q(&qin, &mut state);

    // Steady state: same shape again — not one allocation event allowed.
    let steady = count_allocs(|| {
        plan.run_q(&qin, &mut state);
    });
    assert_eq!(steady, 0, "prepared run_q made {steady} allocations in steady state");

    // Guard: the counter must actually count — the unprepared path
    // reallocates intermediates every call.
    let unprepared = count_allocs(|| {
        let _ = q.run_q(&qin);
    });
    assert!(unprepared > 0, "allocation counter appears broken (unprepared counted 0)");

    // A smaller batch through the warmed state stays within the high-water
    // mark, so it is also allocation-free.
    let small = QTensor::quantize(&mk(&mut rng, 1), q.input_params);
    plan.run_q(&small, &mut state);
    let steady_small = count_allocs(|| {
        plan.run_q(&small, &mut state);
    });
    assert_eq!(steady_small, 0, "batch-1 steady state made {steady_small} allocations");

    // Per-channel requantization must not cost any steady-state allocation
    // either: the multiplier vectors live inside the prepared output stages.
    let (_, qpc) =
        quantize_graph(&g, &calib, QuantizeOptions { mode: QuantMode::PerChannel, ..Default::default() });
    let plan_pc = qpc.prepare();
    let mut state_pc = ExecState::new();
    let qin_pc = QTensor::quantize(&mk(&mut rng, 2), qpc.input_params);
    plan_pc.run_q(&qin_pc, &mut state_pc);
    plan_pc.run_q(&qin_pc, &mut state_pc);
    let steady_pc = count_allocs(|| {
        plan_pc.run_q(&qin_pc, &mut state_pc);
    });
    assert_eq!(steady_pc, 0, "per-channel steady state made {steady_pc} allocations");

    // Epilogue fusion (conv→Add folded into the conv's output stage) must
    // keep the steady-state guarantee — the fused residual read borrows an
    // earlier output slot in place — and must *shrink* the ExecState
    // arena: the fused Add nodes are skipped, so their output slots are
    // never grown past the empty default.
    let gr = mini_resnet(1, 4, 212);
    let mut rng_r = Rng::seeded(212);
    let mkr = |rng: &mut Rng, batch: usize| {
        let mut d = vec![0f32; batch * 12 * 12 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        Tensor::from_vec(&[batch, 12, 12, 3], d)
    };
    let calib_r = vec![mkr(&mut rng_r, 1), mkr(&mut rng_r, 1)];
    let (_, qr) = quantize_graph(&gr, &calib_r, QuantizeOptions::default());
    let plan_fused = qr.prepare().with_fusion(true);
    let plan_unfused = qr.prepare().with_fusion(false);
    assert!(plan_fused.fused_nodes() >= 1, "mini-resnet must discover a conv→Add fusion");
    let qin_r = QTensor::quantize(&mkr(&mut rng_r, 1), qr.input_params);
    let mut state_f = ExecState::new();
    let mut state_u = ExecState::new();
    plan_fused.run_q(&qin_r, &mut state_f);
    plan_fused.run_q(&qin_r, &mut state_f);
    plan_unfused.run_q(&qin_r, &mut state_u);
    plan_unfused.run_q(&qin_r, &mut state_u);
    let steady_fused = count_allocs(|| {
        plan_fused.run_q(&qin_r, &mut state_f);
    });
    assert_eq!(steady_fused, 0, "fused mini-resnet made {steady_fused} steady allocations");
    assert!(
        state_f.arena_bytes() < state_u.arena_bytes(),
        "fused arena ({} bytes) must be strictly smaller than unfused ({} bytes): \
         fused Add output slots stay empty",
        state_f.arena_bytes(),
        state_u.arena_bytes()
    );

    // Ops that allocated per call until PR 3 — Concat's operand gather and
    // the fixed-point Softmax/Logistic — must now be zero-alloc too.
    let gc = concat_softmax_logistic_graph();
    let mut rng2 = Rng::seeded(17);
    let mkc = |rng: &mut Rng, batch: usize| {
        let mut d = vec![0f32; batch * 8 * 8 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        Tensor::from_vec(&[batch, 8, 8, 3], d)
    };
    let calib_c = vec![mkc(&mut rng2, 2), mkc(&mut rng2, 2)];
    let (_, qc) = quantize_graph(&gc, &calib_c, QuantizeOptions::default());
    let plan_c = qc.prepare();
    let mut state_c = ExecState::new();
    let qin_c = QTensor::quantize(&mkc(&mut rng2, 2), qc.input_params);
    plan_c.run_q(&qin_c, &mut state_c);
    plan_c.run_q(&qin_c, &mut state_c);
    let steady_c = count_allocs(|| {
        plan_c.run_q(&qin_c, &mut state_c);
    });
    assert_eq!(
        steady_c, 0,
        "concat/softmax/logistic steady state made {steady_c} allocations"
    );

    // Zero-copy artifact loading: decoding from a shared buffer must
    // allocate strictly less than the copy path (it skips the per-weight-
    // tensor copies) …
    let art = ModelArtifact::new("alloc-test", 1, [16, 16, 3], q.clone());
    let bytes = model_format::save(&art).expect("encode");
    let copy_load = count_allocs(|| {
        let _ = std::hint::black_box(model_format::load(&bytes).expect("copy load"));
    });
    let buf = ArtifactBytes::from_vec(bytes.clone());
    let shared_load = count_allocs(|| {
        let _ = std::hint::black_box(model_format::load_shared(&buf).expect("zero-copy load"));
    });
    assert!(
        shared_load < copy_load,
        "zero-copy load allocated {shared_load} events, copy load {copy_load}: \
         borrowing weight views should allocate strictly less"
    );

    // … and a plan prepared from a zero-copy-loaded graph keeps the
    // steady-state zero-alloc guarantee (packing owns its buffers; the
    // borrowed weight views are read-only inputs).
    let loaded = model_format::load_shared(&buf).expect("zero-copy load");
    let plan_zc = loaded.graph.prepare();
    let mut state_zc = ExecState::new();
    let qin_zc = QTensor::quantize(&mk(&mut rng, 2), loaded.graph.input_params);
    plan_zc.run_q(&qin_zc, &mut state_zc);
    plan_zc.run_q(&qin_zc, &mut state_zc);
    let steady_zc = count_allocs(|| {
        plan_zc.run_q(&qin_zc, &mut state_zc);
    });
    assert_eq!(steady_zc, 0, "zero-copy-loaded steady state made {steady_zc} allocations");

    // The *unprepared* blocked GEMM packs its RHS into a thread-local
    // high-water-mark scratch, so after one warm call a same-shape
    // accumulate may allocate only the two eq. 8 sum vectors — never a
    // fresh packed panel.
    let (m, k, n) = (24, 96, 40);
    let lhs_g: Vec<u8> = (0..m * k).map(|i| (i * 31 % 251) as u8).collect();
    let rhs_g: Vec<u8> = (0..k * n).map(|i| (i * 17 % 253) as u8).collect();
    let gq = QGemm::new(m, k, n, 7, 9);
    let mut acc = vec![0i32; m * n];
    gq.accumulate(Kernel::Blocked, &lhs_g, &rhs_g, &mut acc);
    let warm = counting_alloc::measure(|| {
        gq.accumulate(Kernel::Blocked, &lhs_g, &rhs_g, &mut acc);
    });
    assert!(
        warm.events <= 2,
        "warm unprepared accumulate made {} allocations (row/col sums only allowed)",
        warm.events
    );
    assert!(
        warm.total_bytes <= ((m + n) * 4) as u64,
        "warm unprepared accumulate allocated {} bytes, more than the {} the sum vectors need",
        warm.total_bytes,
        (m + n) * 4
    );
}

/// A graph exercising the three formerly-allocating prepared ops: a
/// channel-duplicating Concat (its operands are one node twice, so the
/// App. A.3 unified parameters hold by construction for any seed), pools,
/// then FC → Logistic and a final Softmax.
fn concat_softmax_logistic_graph() -> FloatGraph {
    let mut rng = Rng::seeded(23);
    let mut g = FloatGraph::default();
    let mut w = vec![0f32; 4 * 3 * 3 * 3];
    rng.fill_normal(&mut w, 0.3);
    let conv = Conv2d {
        weights: Tensor::from_vec(&[4, 3, 3, 3], w),
        bias: vec![0.1, -0.1, 0.2, 0.0],
        stride: 1,
        padding: Padding::Same,
        activation: FusedActivation::None,
    };
    let c = g.push("conv", NodeRef::Input, FloatOp::Conv(conv));
    let r = g.push("relu", c, FloatOp::Relu6);
    let cat = g.push("cat", r, FloatOp::Concat(vec![r]));
    let p = g.push("maxpool", cat, FloatOp::MaxPool { kernel: 2, stride: 2, padding: Padding::Valid });
    let gap = g.push("gap", p, FloatOp::GlobalAvgPool);
    let mut fw = vec![0f32; 5 * 8];
    rng.fill_normal(&mut fw, 0.3);
    let fc = g.push(
        "logits",
        gap,
        FloatOp::Fc(FullyConnected {
            weights: Tensor::from_vec(&[5, 8], fw),
            bias: vec![0.0; 5],
            activation: FusedActivation::None,
        }),
    );
    g.push("sigmoid", fc, FloatOp::Logistic);
    g.push("softmax", fc, FloatOp::Softmax);
    g
}
