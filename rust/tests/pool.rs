//! Property tests for the persistent intra-op worker pool
//! (`iaoi::gemm::pool`): pool-parallel prepared graph execution must be
//! **bit-identical** to serial execution for every thread count, in both
//! weight-quantization modes, on a graph exercising conv + depthwise +
//! FC + concat — the pool only changes *who* computes each GEMM column
//! strip, never a single integer. Also covers the serving shape: one pool
//! shared by several concurrent executor threads.
//!
//! The concat's operands are one node twice, so the App. A.3 unified
//! quantization parameters hold by construction for any seed.

use iaoi::data::Rng;
use iaoi::gemm::{IntraOp, WorkerPool};
use iaoi::graph::{ExecState, FloatGraph, FloatOp, NodeRef};
use iaoi::nn::conv::Conv2d;
use iaoi::nn::depthwise::DepthwiseConv2d;
use iaoi::nn::fc::FullyConnected;
use iaoi::nn::{FusedActivation, Padding, QTensor};
use iaoi::quantize::{quantize_graph, QuantMode, QuantizeOptions};
use iaoi::tensor::Tensor;
use std::sync::Arc;

/// conv → relu6 → depthwise(relu6) → concat(dw, dw) → gap → fc: every
/// matmul-shaped prepared op plus the indexed-concat path in one graph.
fn mixed_graph(seed: u64) -> FloatGraph {
    let mut rng = Rng::seeded(seed);
    let mut g = FloatGraph::default();
    let mut cw = vec![0f32; 8 * 3 * 3 * 3];
    rng.fill_normal(&mut cw, 0.3);
    let conv = Conv2d {
        weights: Tensor::from_vec(&[8, 3, 3, 3], cw),
        bias: (0..8).map(|i| 0.05 * i as f32 - 0.2).collect(),
        stride: 1,
        padding: Padding::Same,
        activation: FusedActivation::None,
    };
    let c = g.push("conv0", NodeRef::Input, FloatOp::Conv(conv));
    let r = g.push("relu", c, FloatOp::Relu6);
    let mut dww = vec![0f32; 3 * 3 * 8];
    rng.fill_normal(&mut dww, 0.35);
    let dw = DepthwiseConv2d {
        weights: Tensor::from_vec(&[1, 3, 3, 8], dww),
        bias: vec![],
        stride: 1,
        padding: Padding::Same,
        activation: FusedActivation::Relu6,
    };
    let d = g.push("dw", r, FloatOp::Depthwise(dw));
    let cat = g.push("cat", d, FloatOp::Concat(vec![d]));
    let gap = g.push("gap", cat, FloatOp::GlobalAvgPool);
    let mut fw = vec![0f32; 5 * 16];
    rng.fill_normal(&mut fw, 0.3);
    g.push(
        "logits",
        gap,
        FloatOp::Fc(FullyConnected {
            weights: Tensor::from_vec(&[5, 16], fw),
            bias: vec![0.1, -0.1, 0.0, 0.2, -0.2],
            activation: FusedActivation::None,
        }),
    );
    g
}

fn input(rng: &mut Rng, batch: usize) -> Tensor<f32> {
    let mut d = vec![0f32; batch * 8 * 8 * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    Tensor::from_vec(&[batch, 8, 8, 3], d)
}

#[test]
fn pool_graph_execution_is_bit_identical_across_thread_counts_and_modes() {
    let g = mixed_graph(71);
    let mut rng = Rng::seeded(71);
    let calib = vec![input(&mut rng, 2), input(&mut rng, 2)];
    for mode in [QuantMode::PerTensor, QuantMode::PerChannel] {
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions { mode, ..Default::default() });
        let plan = q.prepare();
        for batch in [1usize, 4] {
            let qin = QTensor::quantize(&input(&mut rng, batch), q.input_params);
            let want = q.run_q(&qin);
            for threads in [1usize, 2, 3, 8] {
                let pool = Arc::new(WorkerPool::new(threads));
                let mut state = ExecState::new();
                // min_n = 1 forces every conv/FC GEMM through the pool.
                state.set_intra(IntraOp::pool(pool, 1));
                let got = plan.run_q(&qin, &mut state);
                assert_eq!(
                    want.data.data(),
                    got.data.data(),
                    "{mode:?} batch={batch} threads={threads}"
                );
                // Warm re-run through the same state and pool.
                let again = plan.run_q(&qin, &mut state);
                assert_eq!(
                    want.data.data(),
                    again.data.data(),
                    "{mode:?} batch={batch} threads={threads} warm"
                );
            }
        }
    }
}

#[test]
fn pool_and_scoped_strategies_agree_with_serial_at_default_threshold() {
    // At the production threshold (DEFAULT_MIN_N) only the large layers
    // split; serial, scoped-spawn, and pool execution must still match.
    let g = mixed_graph(72);
    let mut rng = Rng::seeded(72);
    let calib = vec![input(&mut rng, 2)];
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
    let plan = q.prepare();
    let qin = QTensor::quantize(&input(&mut rng, 4), q.input_params);
    let want = q.run_q(&qin);
    let min_n = iaoi::gemm::pool::DEFAULT_MIN_N;
    let pool = Arc::new(WorkerPool::new(3));
    for intra in [IntraOp::serial(), IntraOp::scoped(3, min_n), IntraOp::pool(pool, min_n)] {
        let mut state = ExecState::new();
        state.set_intra(intra.clone());
        let got = plan.run_q(&qin, &mut state);
        assert_eq!(want.data.data(), got.data.data(), "{:?}", intra.strategy);
    }
}

#[test]
fn one_pool_is_shared_by_concurrent_executors() {
    // The serving topology: several batch workers, each with its own
    // ExecState, drive one shared pool concurrently. Every run must stay
    // bit-identical to serial no matter how jobs interleave on the queue.
    let g = mixed_graph(73);
    let mut rng = Rng::seeded(73);
    let calib = vec![input(&mut rng, 2)];
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
    let plan = q.prepare();
    let inputs: Vec<QTensor> =
        (0..3).map(|_| QTensor::quantize(&input(&mut rng, 2), q.input_params)).collect();
    let wants: Vec<Vec<u8>> = inputs.iter().map(|x| q.run_q(x).data.data().to_vec()).collect();
    let pool = Arc::new(WorkerPool::new(4));
    std::thread::scope(|scope| {
        for worker in 0..4 {
            let (plan, inputs, wants, pool) = (&plan, &inputs, &wants, &pool);
            scope.spawn(move || {
                let mut state = ExecState::new();
                state.set_intra(IntraOp::pool(Arc::clone(pool), 1));
                for round in 0..6 {
                    let i = (worker + round) % inputs.len();
                    let got = plan.run_q(&inputs[i], &mut state);
                    assert_eq!(wants[i], got.data.data(), "worker {worker} round {round}");
                }
            });
        }
    });
}
