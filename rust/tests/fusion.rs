//! Differential tests for the conv→Add epilogue-fusion pass.
//!
//! The prepare-time rewrite (see `QGraph::prepare`) folds a residual Add
//! into the producing conv's output stage. Its one non-negotiable contract
//! is **bit-identity**: because the fused epilogue and the standalone
//! `qadd_into` share `ResidualAdd::apply`, a fused plan must produce the
//! same uint8 stream as the unfused oracle on every kernel, quant mode,
//! and thread count. These tests sweep exactly that grid, over both the
//! mini-resnet builder (real identity/projection blocks) and small
//! synthetic conv→Add(→ReLU) lattices, and pin down the no-false-fusion
//! rule: a conv with more than one consumer must not be rewritten.

use iaoi::data::Rng;
use iaoi::gemm::{dispatch, IntraOp, WorkerPool};
use iaoi::graph::{builders, ExecState, FloatGraph, FloatOp, NodeRef};
use iaoi::nn::conv::Conv2d;
use iaoi::nn::{FusedActivation, Padding, QTensor};
use iaoi::quantize::{quantize_graph, QuantMode, QuantizeOptions};
use iaoi::tensor::Tensor;

fn random_input(rng: &mut Rng, shape: &[usize]) -> Tensor<f32> {
    let mut d = vec![0f32; shape.iter().product()];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    Tensor::from_vec(shape, d)
}

/// Quantize `g`, then check the fused plan against both the unfused plan
/// and the unprepared oracle, bit for bit, across every detected GEMM
/// kernel and thread counts {1, 2, 8}. Returns the fused-node count so
/// callers can assert the pass actually fired (or was refused).
fn assert_fused_matches_unfused(
    g: &FloatGraph,
    input_shape: &[usize],
    mode: QuantMode,
    seed: u64,
) -> usize {
    let mut rng = Rng::seeded(seed);
    let calib = vec![random_input(&mut rng, input_shape), random_input(&mut rng, input_shape)];
    let opts = QuantizeOptions { mode, ..Default::default() };
    let (_, q) = quantize_graph(g, &calib, opts);
    let qin = QTensor::quantize(&random_input(&mut rng, input_shape), q.input_params);
    let oracle = q.run_q(&qin);

    let mut fused_nodes = None;
    for kernel in dispatch::available() {
        for threads in [1usize, 2, 8] {
            let intra = if threads == 1 {
                IntraOp::serial()
            } else {
                // min_n = 1 forces every conv/FC GEMM through the pool so
                // the strip epilogue path is exercised, not just the
                // serial one.
                IntraOp::pool(std::sync::Arc::new(WorkerPool::new(threads)), 1)
            };
            let mut fused = q.prepare().with_fusion(true).with_ukernel(kernel);
            fused.set_intra(intra.clone());
            let mut unfused = q.prepare().with_fusion(false).with_ukernel(kernel);
            unfused.set_intra(intra);
            assert_eq!(unfused.fused_nodes(), 0, "disabled plan must report 0 fused nodes");
            let n = fused.fused_nodes();
            if let Some(prev) = fused_nodes {
                assert_eq!(prev, n, "fused-node count must not depend on kernel/threads");
            }
            fused_nodes = Some(n);

            let mut sf = ExecState::new();
            let mut su = ExecState::new();
            for pass in 0..2 {
                let got_f = fused.run_q(&qin, &mut sf).data.data().to_vec();
                let got_u = unfused.run_q(&qin, &mut su);
                assert_eq!(
                    got_f,
                    got_u.data.data(),
                    "fused vs unfused diverged: kernel={} threads={threads} mode={mode:?} pass={pass}",
                    kernel.name
                );
                assert_eq!(
                    got_f,
                    oracle.data.data(),
                    "prepared vs unprepared oracle diverged: kernel={} threads={threads} mode={mode:?} pass={pass}",
                    kernel.name
                );
            }
        }
    }
    fused_nodes.unwrap()
}

/// A shape-preserving 3×3 conv (SAME, stride 1, cout == cin) so its output
/// can be Added to any same-shaped earlier value.
fn shape_preserving_conv(rng: &mut Rng, cin: usize, act: FusedActivation) -> Conv2d {
    let mut w = vec![0f32; cin * 3 * 3 * cin];
    rng.fill_normal(&mut w, 0.3);
    let mut bias = vec![0f32; cin];
    rng.fill_normal(&mut bias, 0.1);
    Conv2d {
        weights: Tensor::from_vec(&[cin, 3, 3, cin], w),
        bias,
        stride: 1,
        padding: Padding::Same,
        activation: act,
    }
}

/// `Input → conv → Add(conv, Input)`, optionally followed by a ReLU — the
/// smallest fusable lattice (counterpart is the graph input).
fn conv_add_input_graph(seed: u64, relu_tail: bool) -> FloatGraph {
    let mut rng = Rng::seeded(seed);
    let mut g = FloatGraph::default();
    let c = g.push(
        "conv",
        NodeRef::Input,
        FloatOp::Conv(shape_preserving_conv(&mut rng, 3, FusedActivation::None)),
    );
    let a = g.push("add", c, FloatOp::Add(NodeRef::Input));
    if relu_tail {
        g.push("relu", a, FloatOp::Relu);
    }
    g
}

/// `Input → conv0 → conv1 → Add(conv1, conv0)`: conv0 feeds both conv1 and
/// the Add (two consumers, must not fuse); conv1 has one consumer and an
/// earlier-node counterpart, so exactly one fusion fires.
fn conv_conv_add_graph(seed: u64) -> FloatGraph {
    let mut rng = Rng::seeded(seed);
    let mut g = FloatGraph::default();
    let c0 = g.push(
        "conv0",
        NodeRef::Input,
        FloatOp::Conv(shape_preserving_conv(&mut rng, 3, FusedActivation::Relu)),
    );
    let c1 = g.push(
        "conv1",
        c0,
        FloatOp::Conv(shape_preserving_conv(&mut rng, 3, FusedActivation::None)),
    );
    g.push("add", c1, FloatOp::Add(c0));
    g
}

/// `conv` consumed by two different Adds: every operand position sees a
/// multi-consumer conv, so the pass must refuse to rewrite anything.
fn multi_consumer_graph(seed: u64) -> FloatGraph {
    let mut rng = Rng::seeded(seed);
    let mut g = FloatGraph::default();
    let c = g.push(
        "conv",
        NodeRef::Input,
        FloatOp::Conv(shape_preserving_conv(&mut rng, 3, FusedActivation::None)),
    );
    let a1 = g.push("add1", c, FloatOp::Add(NodeRef::Input));
    g.push("add2", c, FloatOp::Add(a1));
    g
}

#[test]
fn mini_resnet_fuses_all_residual_adds_bit_identically() {
    // n = 1 → three residual blocks (one identity, two projection), three
    // Add nodes; every one has a single-consumer conv operand, so all
    // three must fuse — and the fused plan must match the unfused oracle
    // bit for bit on every kernel/thread/mode combination.
    let g = builders::mini_resnet(1, 4, 212);
    for mode in [QuantMode::PerTensor, QuantMode::PerChannel] {
        let fused = assert_fused_matches_unfused(&g, &[1, 12, 12, 3], mode, 212);
        assert_eq!(fused, 3, "mini_resnet(1) has 3 residual Adds; all must fuse ({mode:?})");
    }
}

#[test]
fn synthetic_conv_add_lattices_fuse_bit_identically() {
    for mode in [QuantMode::PerTensor, QuantMode::PerChannel] {
        for relu_tail in [false, true] {
            let g = conv_add_input_graph(41, relu_tail);
            let fused = assert_fused_matches_unfused(&g, &[2, 8, 8, 3], mode, 41);
            assert_eq!(fused, 1, "conv→Add(Input) must fuse (relu_tail={relu_tail}, {mode:?})");
        }
        let g = conv_conv_add_graph(43);
        let fused = assert_fused_matches_unfused(&g, &[1, 8, 8, 3], mode, 43);
        assert_eq!(fused, 1, "only the single-consumer conv1 may fuse ({mode:?})");
    }
}

#[test]
fn multi_consumer_conv_is_never_fused() {
    let g = multi_consumer_graph(47);
    let fused = assert_fused_matches_unfused(&g, &[1, 8, 8, 3], QuantMode::PerTensor, 47);
    assert_eq!(fused, 0, "a conv with two consumers must not be rewritten");
}
