//! Integration tests for the socket serving front end (`iaoi serve --addr`):
//! real TCP round trips against [`iaoi::serve::Server`] on an ephemeral
//! port, covering the production rails the subsystem exists for —
//! bit-identical responses vs direct prepared-graph execution, health
//! transitions around a hot-swap drain, deterministic load-shedding at the
//! admission cap, graceful shutdown that drops no admitted request, and
//! malformed input that must never wedge the acceptor.

use iaoi::coordinator::registry::ModelRegistry;
use iaoi::coordinator::BatchPolicy;
use iaoi::data::Rng;
use iaoi::graph::ExecState;
use iaoi::harness::demo_artifact;
use iaoi::model_format;
use iaoi::serve::client::HttpClient;
use iaoi::serve::{ServeConfig, Server};
use iaoi::tensor::Tensor;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Two in-memory demo models, same pair `iaoi serve --addr` installs when
/// run without `--models`.
fn demo_registry() -> ModelRegistry {
    let registry = ModelRegistry::new();
    registry.install(demo_artifact("alpha", 1, 16, 3), PathBuf::from("<test:alpha>"));
    registry.install(demo_artifact("beta", 1, 8, 11), PathBuf::from("<test:beta>"));
    registry
}

fn start_server(policy: BatchPolicy, cfg: ServeConfig) -> Server {
    Server::start(demo_registry(), policy, 2, cfg).expect("server start")
}

/// A deterministic [16,16,3] input image as a flat f32 vec.
fn image(rng: &mut Rng) -> Vec<f32> {
    (0..16 * 16 * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

fn fresh_policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_millis(1),
        ..Default::default()
    }
}

#[test]
fn socket_roundtrip_is_bit_identical_to_prepared_graph() {
    // Concurrent clients over real sockets: every response must match a
    // direct PreparedGraph execution of the same input bit-for-bit, no
    // matter how the coordinator batched it with co-riders.
    let server = start_server(fresh_policy(), ServeConfig::default());
    let addr = server.local_addr();
    let registry = server.registry();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let registry = registry.clone();
            std::thread::spawn(move || {
                let model = if t % 2 == 0 { "alpha" } else { "beta" };
                let entry = registry.resolve(model).expect("entry");
                let mut state = ExecState::new();
                let mut client = HttpClient::connect(addr).expect("connect");
                let mut rng = Rng::seeded(1000 + t as u64);
                for _ in 0..8 {
                    let values = image(&mut rng);
                    let resp = client.infer(model, &values).expect("infer");
                    assert_eq!(resp.status, 200, "body: {}", resp.body_text());
                    assert_eq!(resp.header("X-Model-Version"), Some("1"));
                    let got = resp.body_f32().expect("f32 body");
                    let x = Tensor::from_vec(&entry.batched_shape(1), values);
                    let want = entry.plan.run(&x, &mut state);
                    assert_eq!(got.len(), want.data().len());
                    for (g, w) in got.iter().zip(want.data()) {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "socket response diverged from direct prepared execution"
                        );
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let report = server.shutdown();
    assert!(report.drained_clean);
    assert_eq!(report.shed, 0, "no caps set, nothing may shed");
}

#[test]
fn health_transitions_and_versions_across_hot_swap() {
    let server = start_server(fresh_policy(), ServeConfig::default());
    let addr = server.local_addr();
    let mut client = HttpClient::connect(addr).expect("connect");
    let mut rng = Rng::seeded(7);

    // Steady state: everything reports "serving".
    let health = client.get("/healthz").expect("healthz");
    assert_eq!(health.status, 200);
    let text = health.body_text();
    assert!(text.contains("\"status\":\"serving\""), "health: {text}");
    assert!(!text.contains("draining"), "health: {text}");

    // Draining one model flips only that model's status; its requests get
    // a clean 503 while the other model keeps serving.
    server.begin_model_drain("alpha");
    let text = client.get("/healthz").expect("healthz").body_text();
    assert!(
        text.contains("\"name\":\"alpha\",\"version\":1,\"input_shape\":[16,16,3],\"status\":\"draining\""),
        "health during drain: {text}"
    );
    let img = image(&mut rng);
    let resp = client.infer("alpha", &img).expect("infer during drain");
    assert_eq!(resp.status, 503);
    assert!(resp.body_text().contains("\"error\":\"draining\""), "body: {}", resp.body_text());
    // The draining rejection closes the connection by design; reconnect.
    let mut client = HttpClient::connect(addr).expect("reconnect");
    let resp = client.infer("beta", &img).expect("beta unaffected");
    assert_eq!(resp.status, 200);
    server.end_model_drain("alpha");
    let resp = client.infer("alpha", &img).expect("infer after reopen");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("X-Model-Version"), Some("1"));

    // Hot-swap alpha to v2 through the drain-then-swap path: subsequent
    // responses must carry the new registry version.
    let dir = std::env::temp_dir().join(format!("iaoi-serve-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let v2 = dir.join("alpha_v2.iaoiq");
    model_format::write_file(&v2, &demo_artifact("alpha", 2, 16, 3)).expect("write v2");
    let (old, new) = server.swap_model("alpha", &v2).expect("swap");
    assert_eq!((old, new), (Some(1), 2));
    let resp = client.infer("alpha", &img).expect("infer after swap");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("X-Model-Version"), Some("2"));
    // The drain set must be empty again: health is all-serving.
    let text = client.get("/healthz").expect("healthz").body_text();
    assert!(!text.contains("draining"), "health after swap: {text}");

    let report = server.shutdown();
    assert!(report.drained_clean);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn saturated_caps_shed_with_retry_after_then_recover() {
    // Global cap 1: a held permit forces a deterministic queue-full
    // rejection — 503 with both the Retry-After header and the JSON
    // retry_after_ms hint — and releasing the permit restores service.
    let server = start_server(
        BatchPolicy { global_inflight_cap: 1, ..fresh_policy() },
        ServeConfig::default(),
    );
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut rng = Rng::seeded(13);
    let img = image(&mut rng);
    let admission = server.admission();
    let permit = admission.try_acquire("alpha").expect("hold the only slot");
    let resp = client.infer("alpha", &img).expect("shed response");
    assert_eq!(resp.status, 503);
    assert!(resp.header("Retry-After").is_some(), "shed reply must carry Retry-After");
    let body = resp.body_text();
    assert!(body.contains("\"error\":\"overloaded\""), "body: {body}");
    assert!(body.contains("\"scope\":\"global\""), "body: {body}");
    assert!(body.contains("\"retry_after_ms\":"), "body: {body}");
    drop(permit);
    let resp = client.infer("alpha", &img).expect("after release");
    assert_eq!(resp.status, 200, "capacity must recover once the permit drops");
    let report = server.shutdown();
    assert_eq!(report.shed, 1);
    assert!(report.drained_clean);

    // Per-model cap 1: saturating alpha sheds alpha with model scope but
    // must not starve beta.
    let server = start_server(
        BatchPolicy { model_inflight_cap: 1, ..fresh_policy() },
        ServeConfig::default(),
    );
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let admission = server.admission();
    let permit = admission.try_acquire("alpha").expect("hold alpha's slot");
    let resp = client.infer("alpha", &img).expect("alpha shed");
    assert_eq!(resp.status, 503);
    assert!(resp.body_text().contains("\"scope\":\"model\""), "body: {}", resp.body_text());
    let resp = client.infer("beta", &img).expect("beta");
    assert_eq!(resp.status, 200, "a saturated model must not shed other models");
    drop(permit);
    let report = server.shutdown();
    assert_eq!(report.shed, 1);
    assert!(report.drained_clean);
}

#[test]
fn shutdown_drains_every_admitted_request() {
    // Closed-loop load from 8 threads while the server shuts down
    // mid-flight: every request either completes with 200 or is answered
    // with a clean 503, and the server-side completion count equals the
    // client-side success count — zero admitted requests dropped.
    let server = start_server(
        BatchPolicy { global_inflight_cap: 4, ..fresh_policy() },
        ServeConfig::default(),
    );
    let addr = server.local_addr();
    let ok = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let ok = Arc::clone(&ok);
            std::thread::spawn(move || {
                let model = if t % 2 == 0 { "alpha" } else { "beta" };
                let Ok(mut client) = HttpClient::connect(addr) else { return };
                let mut rng = Rng::seeded(31 + t as u64);
                for _ in 0..10_000 {
                    let img = image(&mut rng);
                    match client.infer(model, &img) {
                        Ok(resp) if resp.status == 200 => {
                            ok.fetch_add(1, Ordering::SeqCst);
                        }
                        // Shed under the tiny cap: back off and retry.
                        Ok(resp) if resp.body_text().contains("overloaded") => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        // Draining (server stopping) or connection torn
                        // down by shutdown: this request was never
                        // admitted, stop offering load.
                        Ok(_) | Err(_) => return,
                    }
                }
            })
        })
        .collect();
    // Let the load ramp, then pull the plug mid-flight.
    std::thread::sleep(Duration::from_millis(100));
    let report = server.shutdown();
    for h in handles {
        h.join().expect("client thread");
    }
    assert!(report.drained_clean, "in-flight requests must finish inside the drain window");
    let completed: u64 = report.metrics.iter().map(|m| m.completed).sum();
    let ok = ok.load(Ordering::SeqCst);
    assert!(ok > 0, "load must have completed some requests before shutdown");
    assert_eq!(
        completed, ok,
        "server completed {completed} requests but clients saw {ok} — an admitted request was dropped"
    );
    // A permit acquired in the instant the flag flips is released with a
    // clean "draining" rejection instead of executing, so admitted may
    // exceed completed by at most that race window — never the reverse.
    assert!(report.admitted >= completed, "completed requests must all have been admitted");
}

#[test]
fn malformed_input_never_wedges_the_acceptor() {
    // Tight request timeout so the truncated-body case resolves quickly.
    let cfg = ServeConfig {
        poll_interval: Duration::from_millis(20),
        request_timeout: Duration::from_millis(300),
        ..ServeConfig::default()
    };
    let server = start_server(fresh_policy(), cfg);
    let addr = server.local_addr();
    let mut rng = Rng::seeded(3);
    let img = image(&mut rng);

    // Garbage bytes: answered with 400 on that connection only.
    let mut bad = HttpClient::connect(addr).expect("connect");
    bad.send_raw(b"garbage that is not HTTP\r\n\r\n").expect("send");
    let resp = bad.read_response().expect("error response");
    assert_eq!(resp.status, 400);

    // Oversized declared body: rejected up front with 413, before any
    // body byte is read or buffered.
    let mut bad = HttpClient::connect(addr).expect("connect");
    bad.send_raw(b"POST /infer/alpha HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n")
        .expect("send");
    let resp = bad.read_response().expect("error response");
    assert_eq!(resp.status, 413);

    // Truncated body: the declared length never arrives; the read budget
    // expires and the connection gets a 400 instead of pinning a thread.
    let mut bad = HttpClient::connect(addr).expect("connect");
    bad.send_raw(b"POST /infer/alpha HTTP/1.1\r\nContent-Length: 3072\r\n\r\nonly a few bytes")
        .expect("send");
    let resp = bad.read_response().expect("error response");
    assert_eq!(resp.status, 400);
    assert!(resp.body_text().contains("timed out"), "body: {}", resp.body_text());

    // Wrong value count (valid HTTP, wrong tensor size) and wrong
    // method/path: each answered in protocol, connection semantics intact.
    let mut client = HttpClient::connect(addr).expect("connect");
    let resp = client.infer("alpha", &img[..10]).expect("short tensor");
    assert_eq!(resp.status, 400);
    let mut client = HttpClient::connect(addr).expect("connect");
    let resp = client.get("/infer/alpha").expect("GET on infer");
    assert_eq!(resp.status, 405);
    let resp = client.get("/no/such/path").expect("unknown path");
    assert_eq!(resp.status, 404);
    let resp = client.infer("nonexistent", &img).expect("unknown model");
    assert_eq!(resp.status, 404);

    // After all of the above, the acceptor still accepts and serves.
    let mut client = HttpClient::connect(addr).expect("connect after abuse");
    assert_eq!(client.get("/healthz").expect("healthz").status, 200);
    let resp = client.infer("alpha", &img).expect("real inference still works");
    assert_eq!(resp.status, 200);
    let report = server.shutdown();
    assert!(report.drained_clean);
}

#[test]
fn metrics_endpoint_exports_quantiles_and_admission_counters() {
    let server = start_server(fresh_policy(), ServeConfig::default());
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut rng = Rng::seeded(17);
    for _ in 0..6 {
        let img = image(&mut rng);
        assert_eq!(client.infer("alpha", &img).expect("infer").status, 200);
    }
    let text = client.get("/metrics").expect("metrics").body_text();
    for needle in [
        "iaoi_requests_completed_total{model=\"alpha\"}",
        "iaoi_latency_us{model=\"alpha\",quantile=\"0.5\"}",
        "iaoi_latency_us{model=\"alpha\",quantile=\"0.999\"}",
        "iaoi_latency_us{model=\"_all\",quantile=\"0.99\"}",
        "iaoi_inflight{scope=\"global\"} 0",
        "iaoi_admitted_total{scope=\"global\"} 6",
        "iaoi_shed_total{scope=\"global\"} 0",
        "iaoi_admitted_total{model=\"alpha\"} 6",
        // Robustness counters: present (and zero) even on a fault-free run,
        // so dashboards can alert on them without existence checks.
        "iaoi_requests_failed_total{model=\"alpha\"} 0",
        "iaoi_worker_panics_total{model=\"alpha\"} 0",
        "iaoi_worker_panics_total{model=\"_all\"} 0",
        "iaoi_deadline_shed_total{model=\"_all\"} 0",
        "iaoi_quarantined{model=\"alpha\"} 0",
        "iaoi_quarantined{model=\"beta\"} 0",
        "iaoi_open_connections 1",
        "iaoi_uptime_seconds",
    ] {
        assert!(text.contains(needle), "metrics missing {needle:?}:\n{text}");
    }
    let report = server.shutdown();
    assert_eq!(report.admitted, 6);
}
