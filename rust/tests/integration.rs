//! Cross-layer integration tests over the AOT artifacts: these are the
//! anchors tying L1 (Pallas), L2 (JAX graphs) and L3 (Rust engine) to one
//! arithmetic definition. They require `make artifacts` to have run; each
//! test skips (with a loud message) when the artifacts are absent so
//! `cargo test` stays green on a fresh checkout.

use iaoi::data::ClassificationSet;
use iaoi::harness::{self, papernet_from_params, papernet_int8};
use iaoi::nn::FusedActivation;
use iaoi::quantize::QuantizeOptions;
use iaoi::train::{Knobs, Trainer};
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("base").join("train_step.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn pallas_kernel_matches_rust_engine_bit_exact() {
    // The quickstart harness asserts bit-exact equality between the AOT
    // Pallas qmatmul (via PJRT) and the Rust integer GEMM.
    let Some(arts) = artifacts() else { return };
    harness::quickstart(&arts).expect("pallas/rust parity");
}

#[test]
fn train_step_reduces_loss_and_exports() {
    let Some(arts) = artifacts() else { return };
    let mut tr = Trainer::new(&arts.join("base"), 13).expect("trainer");
    let mut first = 0f32;
    for s in 0..60 {
        let loss = tr.train_step().expect("step");
        assert!(loss.is_finite(), "loss must stay finite");
        if s == 0 {
            first = loss;
        }
    }
    let last = *tr.losses.last().unwrap();
    assert!(
        last < first,
        "QAT loss should decrease over 60 steps: first {first}, last {last}"
    );
    // Exported folded params feed both Rust engines.
    let params = tr.export_folded().expect("export");
    let ranges = tr.learned_ranges().expect("ranges");
    assert!(!params.is_empty() && !ranges.is_empty());
    let spec = tr.spec.clone();
    let fgraph = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6).unwrap();
    let qgraph = papernet_int8(
        &params,
        &ranges,
        &spec.export_keys,
        FusedActivation::Relu6,
        QuantizeOptions::default(),
    )
    .unwrap();
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 13);
    let (x, _) = ds.batch(1, 0, 2);
    assert_eq!(fgraph.run(&x).shape(), &[2, spec.num_classes]);
    assert_eq!(qgraph.run(&x).shape(), &[2, spec.num_classes]);
}

#[test]
fn rust_float_engine_matches_aot_eval_float() {
    // The Rust float engine on exported folded weights must reproduce the
    // L2 eval_float graph's logits: same eq. 14 folding, same topology.
    let Some(arts) = artifacts() else { return };
    let mut tr = Trainer::new(&arts.join("base"), 21).expect("trainer");
    for _ in 0..20 {
        tr.train_step().expect("step");
    }
    let spec = tr.spec.clone();
    // AOT float accuracy vs Rust float-engine accuracy on the same split:
    // identical arithmetic => identical predictions => identical accuracy.
    let aot_acc = tr.eval_float(4).expect("aot eval");
    let params = tr.export_folded().expect("export");
    let fgraph = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6).unwrap();
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 21);
    let rust_acc = harness::accuracy(&mut |x| fgraph.run(x), &ds, 4, spec.batch);
    assert!(
        (aot_acc - rust_acc).abs() < 0.02,
        "float engines diverged: AOT {aot_acc}, Rust {rust_acc}"
    );
}

#[test]
fn quant_sim_matches_integer_engine_accuracy() {
    // The paper's co-design requirement: the training-side simulation
    // (fig. 1.1b, AOT eval_qsim with the Pallas fake-quant kernel) and the
    // integer-only inference engine (fig. 1.1a, pure Rust) must agree.
    let Some(arts) = artifacts() else { return };
    let mut tr = Trainer::new(&arts.join("base"), 31)
        .expect("trainer")
        .with_knobs(Knobs::default());
    for _ in 0..80 {
        tr.train_step().expect("step");
    }
    let spec = tr.spec.clone();
    let qsim_acc = tr.eval_qsim(4).expect("qsim");
    let params = tr.export_folded().expect("export");
    let ranges = tr.learned_ranges().expect("ranges");
    let qgraph = papernet_int8(
        &params,
        &ranges,
        &spec.export_keys,
        FusedActivation::Relu6,
        QuantizeOptions::default(),
    )
    .unwrap();
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 31);
    let engine_acc = harness::accuracy(&mut |x| qgraph.run(x), &ds, 4, spec.batch);
    assert!(
        (qsim_acc - engine_acc).abs() <= 0.05,
        "training arithmetic (qsim {qsim_acc}) and inference arithmetic (engine {engine_acc}) diverged"
    );
}

#[test]
fn trained_model_roundtrips_through_disk() {
    let Some(arts) = artifacts() else { return };
    let mut tr = Trainer::new(&arts.join("base"), 41).expect("trainer");
    for _ in 0..5 {
        tr.train_step().expect("step");
    }
    let out = std::env::temp_dir().join("iaoi-test-model.bin");
    tr.save(&out).expect("save");
    let loaded = harness::load_trained(&out).expect("load");
    assert!(!loaded.params.is_empty());
    assert!(!loaded.ranges.is_empty());
    let spec = tr.spec.clone();
    let g = papernet_from_params(&loaded.params, &spec.export_keys, FusedActivation::Relu6).unwrap();
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 41);
    let (x, _) = ds.batch(1, 0, 1);
    assert_eq!(g.run(&x).dim(1), spec.num_classes);
}

#[test]
fn variant_artifacts_are_loadable() {
    // Every architecture variant emitted by aot.py must train.
    let Some(arts) = artifacts() else { return };
    for variant in ["d2", "dm050_r16"] {
        let dir = arts.join(variant);
        if !dir.exists() {
            eprintln!("SKIP variant {variant}");
            continue;
        }
        let mut tr = Trainer::new(&dir, 3).expect("trainer");
        let loss = tr.train_step().expect("step");
        assert!(loss.is_finite(), "{variant} first loss finite");
    }
}

#[test]
fn bit_depth_knobs_affect_training() {
    let Some(arts) = artifacts() else { return };
    // 4-bit QAT must still run; its folded export differs from 8-bit.
    let dir = arts.join("base");
    let mut t8 = Trainer::new(&dir, 51).expect("t8").with_knobs(Knobs::default());
    let mut t4 = Trainer::new(&dir, 51)
        .expect("t4")
        .with_knobs(Knobs { weight_bits: 4, act_bits: 4, ..Knobs::default() });
    for _ in 0..15 {
        t8.train_step().expect("step8");
        t4.train_step().expect("step4");
    }
    let p8 = t8.export_folded().expect("e8");
    let p4 = t4.export_folded().expect("e4");
    let w8 = &p8["conv0/w"];
    let w4 = &p4["conv0/w"];
    assert!(w8.max_abs_diff(w4) > 1e-6, "bit-depth knob had no effect on training");
}

#[test]
fn per_channel_improves_or_ties_per_tensor_on_synth_depthwise_model() {
    // Acceptance check for the per-channel requantization path: on the
    // synth depthwise model (heterogeneous channel ranges), per-channel
    // weight quantization must improve — or at worst tie — the harness
    // accuracy table vs per-tensor, and must strictly reduce the logit
    // error vs the float engine. Needs no AOT artifacts (pure PTQ), so it
    // runs on a fresh checkout.
    let r = harness::tables::quant_mode_report(true);
    // Fidelity is a discrete metric (argmax agreement over the eval split);
    // allow a one-example slack so the continuous logit-error assertion
    // below carries the strict-improvement requirement.
    let one_example = 1.0 / 64.0;
    assert!(
        r.per_channel_fidelity >= r.per_tensor_fidelity - one_example,
        "per-channel fidelity {} must not trail per-tensor {}",
        r.per_channel_fidelity,
        r.per_tensor_fidelity
    );
    assert!(
        r.per_channel_logit_err < r.per_tensor_logit_err,
        "per-channel logit error {} must beat per-tensor {}",
        r.per_channel_logit_err,
        r.per_tensor_logit_err
    );
    // Per-channel FC (the converter now quantizes FC per output unit in
    // PerChannel mode): on the wide-classifier-head model, whose FC rows
    // span a 256x magnitude spread, the same ordering must hold.
    assert!(
        r.wide_head_per_channel_fidelity >= r.wide_head_per_tensor_fidelity - one_example,
        "wide-head per-channel fidelity {} must not trail per-tensor {}",
        r.wide_head_per_channel_fidelity,
        r.wide_head_per_tensor_fidelity
    );
    assert!(
        r.wide_head_per_channel_logit_err < r.wide_head_per_tensor_logit_err,
        "wide-head per-channel logit error {} must beat per-tensor {}",
        r.wide_head_per_channel_logit_err,
        r.wide_head_per_tensor_logit_err
    );
}

/// Guard that artifacts dir referenced by the default CLI path matches the
/// layout the binary expects.
#[test]
fn artifact_layout_contract() {
    let Some(arts) = artifacts() else { return };
    for f in [
        "base/train_step.hlo.txt",
        "base/eval_float.hlo.txt",
        "base/eval_qsim.hlo.txt",
        "base/export_fold.hlo.txt",
        "base/params_init.bin",
        "base/model_spec.txt",
        "quickstart.hlo.txt",
        "quickstart_spec.txt",
    ] {
        assert!(arts.join(f).exists(), "missing artifact {f}");
    }
    // Python must never be needed at run time: no .py files in artifacts.
    fn no_py(dir: &Path) {
        for e in std::fs::read_dir(dir).unwrap().flatten() {
            let p = e.path();
            if p.is_dir() {
                no_py(&p);
            } else {
                assert_ne!(p.extension().and_then(|s| s.to_str()), Some("py"), "{p:?}");
            }
        }
    }
    no_py(&arts);
}
