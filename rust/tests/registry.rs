//! Fleet-lifecycle integration tests, socket level: eviction with request
//! draining (zero dropped requests under concurrent load), evict→reinstall
//! bit-identity, LRU residency enforcement against live traffic, and
//! quarantined models as preferred eviction victims. The registry-level
//! unit tests live with [`iaoi::coordinator::registry`]; these drive the
//! same machinery through [`iaoi::serve::Server`] and the wire protocol.

use iaoi::coordinator::registry::{ModelRegistry, QuarantineConfig, ResidencyPolicy};
use iaoi::coordinator::BatchPolicy;
use iaoi::data::Rng;
use iaoi::gemm::PrepareMode;
use iaoi::graph::fault::FaultPlan;
use iaoi::harness::demo_artifact;
use iaoi::model_format::{self, LoadMode};
use iaoi::serve::client::HttpClient;
use iaoi::serve::{ServeConfig, Server};
use std::path::PathBuf;
use std::time::Duration;

/// A deterministic [16,16,3] input image as a flat f32 vec.
fn image(rng: &mut Rng) -> Vec<f32> {
    (0..16 * 16 * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1), ..Default::default() }
}

/// Write `models` as `.iaoiq` artifacts into a fresh temp dir; returns
/// (dir, path-per-model in input order).
fn artifact_dir(tag: &str, models: &[(&str, u64)]) -> (PathBuf, Vec<PathBuf>) {
    let dir = std::env::temp_dir().join(format!("iaoi-registry-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create artifact dir");
    let paths = models
        .iter()
        .map(|(name, seed)| {
            let path = dir.join(format!("{name}.iaoiq"));
            model_format::write_file(&path, &demo_artifact(name, 1, 8, *seed)).expect("write");
            path
        })
        .collect();
    (dir, paths)
}

#[test]
fn evict_under_concurrent_load_answers_every_request() {
    // Clients hammer `alpha` while it is evicted mid-load. Invariant:
    // every request gets exactly one response — 200 before the drain, 503
    // "draining" during it, 404 after, 500 for requests already queued
    // when the entry vanished — and none hang or drop.
    let (dir, paths) = artifact_dir("drain", &[("alpha", 3)]);
    let registry = ModelRegistry::new();
    registry.register_file_with(&paths[0], LoadMode::Mmap).expect("install alpha");
    let server = Server::start(registry, policy(), 2, ServeConfig::default()).expect("start");
    let addr = server.local_addr();

    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..6)
            .map(|t| {
                s.spawn(move || {
                    let mut rng = Rng::seeded(700 + t as u64);
                    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
                    for _ in 0..20 {
                        // Fresh connection per request: the draining
                        // rejection closes its connection by design.
                        let mut client = HttpClient::connect(addr).expect("connect");
                        let img = image(&mut rng);
                        let resp =
                            client.infer("alpha", &img).expect("every request must answer");
                        match resp.status {
                            200 => ok += 1,
                            503 | 404 => shed += 1,
                            500 => failed += 1,
                            other => panic!("unexpected status {other}: {}", resp.body_text()),
                        }
                    }
                    (ok, shed, failed)
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        let v = server.evict_model("alpha").expect("evict");
        assert_eq!(v, 1, "evict must report the retired version");
        for h in handles {
            let (o, s_, f) = h.join().expect("client thread");
            ok += o;
            shed += s_;
            failed += f;
        }
    });
    assert_eq!(ok + shed + failed, 120, "exactly one response per request — zero drops");
    assert!(ok >= 1, "requests before the evict must succeed");
    assert!(shed >= 1, "requests after the evict must be cleanly refused");

    let mut client = HttpClient::connect(addr).expect("connect post-evict");
    let resp = client.infer("alpha", &image(&mut Rng::seeded(1))).expect("post-evict infer");
    assert_eq!(resp.status, 404, "an evicted model routes like an unknown one");
    let text = client.get("/healthz").expect("healthz").body_text();
    assert!(text.contains("\"resident\":\"cold\""), "health: {text}");
    assert!(text.contains("\"status\":\"cold\""), "health: {text}");
    let text = client.get("/metrics").expect("metrics").body_text();
    assert!(text.contains("iaoi_evictions_total 1"), "metrics: {text}");
    assert!(text.contains("iaoi_resident_models 0"), "metrics: {text}");

    let report = server.shutdown();
    assert!(report.drained_clean);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn evict_then_reinstall_serves_bit_identical_outputs() {
    // A lazily-prepared, mmap-backed model must survive a full
    // evict→reinstall cycle with bit-identical outputs over the wire.
    let (dir, paths) = artifact_dir("reinstall", &[("delta", 5)]);
    let registry = ModelRegistry::new();
    registry.set_prepare_mode(PrepareMode::Lazy);
    registry.register_file_with(&paths[0], LoadMode::Mmap).expect("install delta");
    let server = Server::start(registry, policy(), 1, ServeConfig::default()).expect("start");
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut rng = Rng::seeded(91);
    let imgs: Vec<Vec<f32>> = (0..3).map(|_| image(&mut rng)).collect();

    let before: Vec<Vec<f32>> = imgs
        .iter()
        .map(|img| {
            let resp = client.infer("delta", img).expect("pre-evict infer");
            assert_eq!(resp.status, 200);
            assert_eq!(resp.header("X-Model-Version"), Some("1"));
            resp.body_f32().expect("f32 body")
        })
        .collect();

    assert_eq!(server.evict_model("delta").expect("evict"), 1);
    let mut gone = HttpClient::connect(server.local_addr()).expect("reconnect");
    assert_eq!(gone.infer("delta", &imgs[0]).expect("evicted infer").status, 404);

    let (name, version) = server.install_model(&paths[0]).expect("reinstall");
    assert_eq!((name.as_str(), version), ("delta", 1));
    let mut client = HttpClient::connect(server.local_addr()).expect("reconnect");
    for (img, want) in imgs.iter().zip(&before) {
        let resp = client.infer("delta", img).expect("post-reinstall infer");
        assert_eq!(resp.status, 200);
        let got = resp.body_f32().expect("f32 body");
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            assert_eq!(g.to_bits(), w.to_bits(), "reinstalled output diverged");
        }
    }

    let report = server.shutdown();
    assert!(report.drained_clean);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn residency_cap_evicts_exactly_the_least_recently_served() {
    // a, b, c resident under cap 3; live traffic touches a and c, so the
    // install of d must evict exactly b — "recently used" is defined by
    // served requests, not install order.
    let (dir, paths) = artifact_dir("lru", &[("a", 21), ("b", 22), ("c", 23), ("d", 24)]);
    let registry = ModelRegistry::new();
    registry.set_residency(ResidencyPolicy { max_resident_models: 3 });
    for p in &paths[..3] {
        registry.register_file_with(p, LoadMode::Mmap).expect("install");
    }
    let server = Server::start(registry, policy(), 1, ServeConfig::default()).expect("start");
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut rng = Rng::seeded(55);
    for model in ["a", "c"] {
        let resp = client.infer(model, &image(&mut rng)).expect("touch traffic");
        assert_eq!(resp.status, 200);
    }

    let (name, _) = server.install_model(&paths[3]).expect("install d");
    assert_eq!(name, "d");
    let registry = server.registry();
    assert_eq!(registry.names(), vec!["a", "c", "d"], "b was least-recently served");
    assert_eq!(registry.cold_names(), vec!["b"]);
    assert_eq!(registry.evictions_total(), 1);
    let text = client.get("/metrics").expect("metrics").body_text();
    assert!(text.contains("iaoi_resident_models 3"), "metrics: {text}");

    let report = server.shutdown();
    assert!(report.drained_clean);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quarantined_models_are_preferred_eviction_victims() {
    // `sick` panics every batch and trips the breaker; even though it is
    // the most recently used model, the residency policy must pick it as
    // the eviction victim over the healthy, less-recent `good`.
    let (dir, paths) = artifact_dir("sickbay", &[("good", 31), ("spare", 33)]);
    let registry = ModelRegistry::new();
    registry.register_file_with(&paths[0], LoadMode::Mmap).expect("install good");
    registry.install_with(
        demo_artifact("sick", 1, 8, 32),
        PathBuf::from("<registry:sick>"),
        Some(FaultPlan { panic_every: 1, ..Default::default() }),
    );
    registry.set_quarantine(QuarantineConfig { threshold: 1, ..Default::default() });
    let server = Server::start(registry, policy(), 1, ServeConfig::default()).expect("start");
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut rng = Rng::seeded(66);
    let img = image(&mut rng);
    assert_eq!(client.infer("sick", &img).expect("contained panic").status, 500);
    assert_eq!(client.infer("sick", &img).expect("quarantined").status, 503);

    // `sick` is now the most recently *resolved* model, but quarantined:
    // capping residency at 1 must evict it, not `good`.
    let registry = server.registry();
    let evicted = registry.set_residency(ResidencyPolicy { max_resident_models: 1 });
    assert_eq!(evicted, vec!["sick"], "quarantined models go first");
    assert_eq!(registry.names(), vec!["good"]);

    // The freed slot admits a healthy install; `good` stays resident
    // because the tombstoned `sick` no longer counts against the cap.
    registry.set_residency(ResidencyPolicy { max_resident_models: 2 });
    let (name, _) = server.install_model(&paths[1]).expect("install spare");
    assert_eq!(name, "spare");
    assert_eq!(registry.names(), vec!["good", "spare"]);
    let text = client.get("/healthz").expect("healthz").body_text();
    assert!(text.contains("\"name\":\"sick\",\"version\":1,\"status\":\"cold\""), "health: {text}");

    let report = server.shutdown();
    assert!(report.drained_clean);
    std::fs::remove_dir_all(&dir).ok();
}
