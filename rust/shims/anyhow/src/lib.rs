//! A minimal, offline stand-in for the `anyhow` crate covering exactly the
//! API surface this repository uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Errors are stored as flattened message chains (`context: cause`)
//! rather than as a source chain — enough for CLI diagnostics and tests.
//!
//! This is an independent implementation, not vendored code; replace the
//! `anyhow` entry in the workspace manifest with the crates.io release if
//! network access is available and richer backtraces are wanted.

use std::fmt;

/// A flattened error message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a printable message (what `anyhow!` expands to).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string() }
    }

    /// Prepend a context layer, mirroring `anyhow::Error::context`.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that is what makes this blanket conversion (and
// therefore `?` on std error types) coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Self { msg }
    }
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("not a number")?;
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse("17").unwrap(), 17);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not a number"), "{e}");
        let e = parse("-2").unwrap_err();
        assert_eq!(e.to_string(), "negative: -2");
        let e: Error = anyhow!("a {}", 1);
        assert_eq!(format!("{e:?}"), "a 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u8).with_context(|| "x").unwrap(), 3);
    }
}
