//! Inert stand-in for the `xla` PJRT bindings used by the `iaoi` crate's
//! AOT training path (`runtime`/`train`). The real bindings need the
//! `xla_extension` C library, which offline build hosts do not have, so
//! this shim keeps the crate compiling and fails gracefully at run time:
//! [`PjRtClient::cpu`] returns an error, which every trainer/quickstart
//! entry point surfaces as "PJRT runtime unavailable". The pure-Rust
//! integer inference engine never touches this crate.
//!
//! To run the QAT training path, point the workspace's `xla` dependency at
//! the real bindings instead; the API subset here matches their signatures.

use std::fmt;

/// Error type mirroring `xla::Error` for the methods the repo calls.
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Self(
            "PJRT runtime unavailable: this build uses the inert xla shim \
             (rust/shims/xla); link the real xla_extension bindings to run \
             AOT artifacts"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types of XLA literals (only the ones the repo names).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    U8,
    S32,
    F32,
}

/// Rust scalar types storable in a literal.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for u8 {}
impl NativeType for i8 {}
impl NativeType for u16 {}
impl NativeType for i16 {}
impl NativeType for u32 {}
impl NativeType for i32 {}
impl NativeType for u64 {}
impl NativeType for i64 {}

/// Host-side literal value. The shim stores nothing: literals can be
/// constructed (so data-prep code runs), but every readback errors.
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

/// Shape of an array literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> Vec<i64> {
        self.dims.clone()
    }
}

/// Parsed HLO module.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable())
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Device-side buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single entry point, and
/// in this shim it always errors — nothing downstream can be reached.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "shim".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}
