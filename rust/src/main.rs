//! `iaoi` — the leader binary: QAT training driver, integer-only engine
//! evaluation, serving coordinator, and the paper's benchmark harnesses.
//!
//! Subcommands (hand-rolled parser; this offline build has no clap):
//!
//! ```text
//! iaoi train      --steps N [--artifacts DIR] [--out FILE] [--seed S]
//! iaoi eval       --model FILE [--artifacts DIR] [--batches N]
//! iaoi export     --out FILE [--name N] [--model-version V] [--classes C]
//!                 [--seed S] [--model FILE --artifacts DIR]
//!                 [--quant-mode per-tensor|per-channel]
//!                 [--load copy|zerocopy|mmap]
//! iaoi serve      --model FILE | --models DIR [--requests N] [--max-batch B]
//!                 [--workers W] [--intra-threads T]
//!                 [--load copy|zerocopy|mmap]
//! iaoi serve      --addr HOST:PORT [--models DIR] [--queue-depth N]
//!                 [--model-inflight-cap N] [--port-file FILE]
//!                 [--max-batch B] [--workers W] [--intra-threads T]
//!                 [--request-deadline-ms MS] [--max-connections N]
//!                 [--quarantine-threshold K] [--max-resident-models N]
//!                 [--prepare eager|lazy] [--load copy|zerocopy|mmap]
//! iaoi quickstart [--artifacts DIR]
//! iaoi bench      --table 4.1|...|4.8|quant-modes|pool|kernels|fusion | --fig 1.1c|4.1|4.2|4.3 [--fast]
//! ```
//!
//! `export` writes a `.iaoiq` quantized-model artifact; `serve --models`
//! loads every artifact in a directory into the hot-swappable multi-model
//! registry and routes requests per model.

use anyhow::{anyhow, bail, Result};
use iaoi::gemm::PrepareMode;
use iaoi::harness;
use iaoi::model_format::LoadMode;
use std::collections::HashMap;
use std::path::PathBuf;

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got {}", args[i]))?;
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            out.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            out.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(out)
}

fn get<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

/// The `--load` knob: explicit flag wins, else the `IAOI_LOAD` environment
/// default (which is `copy` when unset).
fn load_mode(flags: &HashMap<String, String>) -> Result<LoadMode> {
    match flags.get("load") {
        None => Ok(LoadMode::from_env()),
        Some(label) => LoadMode::from_label(label)
            .ok_or_else(|| anyhow!("unknown --load {label} (copy | zerocopy | mmap)")),
    }
}

/// The `--prepare` knob: explicit flag wins, else the `IAOI_PREPARE`
/// environment default (which is `eager` when unset).
fn prepare_mode(flags: &HashMap<String, String>) -> Result<PrepareMode> {
    match flags.get("prepare") {
        None => Ok(PrepareMode::from_env()),
        Some(label) => PrepareMode::from_label(label)
            .ok_or_else(|| anyhow!("unknown --prepare {label} (eager | lazy)")),
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "eval" => cmd_eval(&flags),
        "export" => cmd_export(&flags),
        "serve" => cmd_serve(&flags),
        "quickstart" => harness::quickstart(&PathBuf::from(get(&flags, "artifacts", "artifacts"))),
        "bench" => cmd_bench(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `iaoi help`)"),
    }
}

fn print_usage() {
    eprintln!(
        "iaoi — integer-arithmetic-only inference (Jacob et al. 2017 reproduction)\n\
         \n\
         usage:\n  iaoi train      --steps N [--artifacts DIR] [--out FILE] [--seed S]\n  \
         iaoi eval       --model FILE [--artifacts DIR] [--batches N]\n  \
         iaoi export     --out FILE [--name N] [--model-version V] [--classes C] [--seed S] [--model FILE --artifacts DIR] [--quant-mode per-tensor|per-channel] [--load copy|zerocopy|mmap]\n  \
         iaoi serve      --model FILE | --models DIR [--requests N] [--max-batch B] [--workers W] [--intra-threads T] [--load copy|zerocopy|mmap]\n  \
         iaoi serve      --addr HOST:PORT [--models DIR] [--queue-depth N] [--model-inflight-cap N] [--port-file FILE] [--max-batch B] [--workers W] [--intra-threads T] [--request-deadline-ms MS] [--max-connections N] [--quarantine-threshold K] [--max-resident-models N] [--prepare eager|lazy] [--load copy|zerocopy|mmap]\n  \
         iaoi quickstart [--artifacts DIR]\n  \
         iaoi bench      --table <id> | --fig <id> [--fast]  (tables 4.1-4.8, quant-modes, pool, kernels, fusion)\n"
    );
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = PathBuf::from(get(flags, "artifacts", "artifacts"));
    let steps: u64 = get(flags, "steps", "300").parse()?;
    let seed: u64 = get(flags, "seed", "0").parse()?;
    let out = PathBuf::from(get(flags, "out", "artifacts/model_trained.bin"));
    let eval_every: u64 = get(flags, "eval-every", "100").parse()?;
    harness::train(&artifacts, steps, seed, eval_every, &out)
}

fn cmd_eval(flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = PathBuf::from(get(flags, "artifacts", "artifacts"));
    let model = PathBuf::from(get(flags, "model", "artifacts/model_trained.bin"));
    let batches: usize = get(flags, "batches", "16").parse()?;
    harness::eval(&artifacts, &model, batches)
}

/// `iaoi export`: write a `.iaoiq` quantized-model artifact (format v3;
/// older readers cannot decode the output, this build still reads v1/v2
/// files). By default a self-contained PTQ demo model is exported;
/// `--model` (with `--artifacts`) converts a QAT-trained checkpoint
/// instead. `--quant-mode per-channel` exports per-channel conv/depthwise
/// weights. `--load` picks the storage mode for the post-write readback
/// verification.
fn cmd_export(flags: &HashMap<String, String>) -> Result<()> {
    let out = PathBuf::from(get(flags, "out", "models/demo.iaoiq"));
    let name = get(flags, "name", "demo");
    let version: u32 = get(flags, "model-version", "1").parse()?;
    let classes: usize = get(flags, "classes", "16").parse()?;
    let seed: u64 = get(flags, "seed", "0").parse()?;
    let artifacts = PathBuf::from(get(flags, "artifacts", "artifacts"));
    let trained = flags.get("model").map(PathBuf::from);
    let mode_label = get(flags, "quant-mode", "per-tensor");
    let mode = iaoi::quantize::QuantMode::from_label(mode_label)
        .ok_or_else(|| anyhow!("unknown --quant-mode {mode_label} (per-tensor | per-channel)"))?;
    let verify_load = load_mode(flags)?;
    harness::export_model(
        &out,
        name,
        version,
        classes,
        seed,
        trained.as_deref().map(|m| (artifacts.as_path(), m)),
        mode,
        verify_load,
    )
}

/// `iaoi serve`: `--intra-threads N` (default 1) sizes the persistent
/// intra-op GEMM worker pool every batch worker shares; 1 keeps the serial
/// zero-alloc path. `--load` picks the registry's artifact weight-storage
/// mode (`--models` path only — the single-model path reads a trained
/// checkpoint, not an `.iaoiq` artifact).
///
/// `--addr HOST:PORT` switches to the socket front end: serve over HTTP
/// until SIGINT/SIGTERM, with bounded admission (`--queue-depth` = global
/// in-flight cap, `--model-inflight-cap` = per-model; 0 = unbounded) and
/// graceful drain. `--port-file FILE` records the bound address (for
/// `--addr host:0` ephemeral ports). Without `--models`, two in-memory
/// demo models are served.
///
/// Robustness knobs (socket mode): `--request-deadline-ms MS` is the
/// default completion deadline for requests without an `X-Deadline-Ms`
/// header (expired requests shed pre-execution with 504; 0 disables);
/// `--max-connections N` caps concurrently open connections (503 at the
/// door past it; 0 = unbounded); `--quarantine-threshold K` circuit-breaks
/// a model after K worker panics in a sliding window (503 `"quarantined"`
/// until hot-swapped; 0 disables).
///
/// Fleet lifecycle knobs (socket mode): `--max-resident-models N` is the
/// LRU residency cap — past it each install evicts the least-recently
/// served model to a reinstallable cold tombstone (0 = unbounded);
/// `--prepare eager|lazy` picks when GEMM panels are packed (lazy defers
/// per layer to first touch, making evict/reinstall cycles cheap).
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let requests: usize = get(flags, "requests", "256").parse()?;
    let max_batch: usize = get(flags, "max-batch", "8").parse()?;
    let workers: usize = get(flags, "workers", "1").parse()?;
    let intra_threads: usize = get(flags, "intra-threads", "1").parse()?;
    anyhow::ensure!(intra_threads >= 1, "--intra-threads must be >= 1");
    if let Some(addr) = flags.get("addr") {
        let models = flags.get("models").map(PathBuf::from);
        let port_file = flags.get("port-file").map(PathBuf::from);
        let opts = harness::SocketServeOpts {
            max_batch,
            workers,
            intra_threads,
            queue_depth: get(flags, "queue-depth", "64").parse()?,
            model_inflight_cap: get(flags, "model-inflight-cap", "0").parse()?,
            request_deadline_ms: get(flags, "request-deadline-ms", "5000").parse()?,
            max_connections: get(flags, "max-connections", "0").parse()?,
            quarantine_threshold: get(flags, "quarantine-threshold", "3").parse()?,
            max_resident_models: get(flags, "max-resident-models", "0").parse()?,
            prepare: prepare_mode(flags)?,
            load: load_mode(flags)?,
        };
        return harness::serve_socket(addr, models.as_deref(), port_file.as_deref(), opts);
    }
    if let Some(models_dir) = flags.get("models") {
        return harness::serve_registry(
            &PathBuf::from(models_dir),
            requests,
            max_batch,
            workers,
            intra_threads,
            load_mode(flags)?,
        );
    }
    let artifacts = PathBuf::from(get(flags, "artifacts", "artifacts"));
    let model = PathBuf::from(get(flags, "model", "artifacts/model_trained.bin"));
    harness::serve(&artifacts, &model, requests, max_batch, workers, intra_threads)
}

fn cmd_bench(flags: &HashMap<String, String>) -> Result<()> {
    let fast = flags.contains_key("fast");
    if let Some(table) = flags.get("table") {
        return harness::run_table(table, fast);
    }
    if let Some(fig) = flags.get("fig") {
        return harness::run_figure(fig, fast);
    }
    bail!("bench requires --table <id> or --fig <id>")
}
