//! Binary interchange between the Python build path (L2) and the Rust
//! runtime (L3): named tensors (trained parameters, QAT-learned ranges) in
//! a small self-describing format both sides implement.
//!
//! Layout (all little-endian):
//! ```text
//! magic  b"IAOI"          4 bytes
//! version u32             currently 1
//! count  u32
//! repeat count times:
//!   name_len u16, name utf-8
//!   dtype u8              0 = f32, 1 = u8, 2 = i32
//!   rank u8, dims u32 × rank
//!   data                  elem_size × Π dims
//! ```

use crate::graph::builders::ParamMap;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"IAOI";
const VERSION: u32 = 1;

/// A named tensor of any dtype the header format declares.
#[derive(Clone, Debug, PartialEq)]
pub enum NamedTensor {
    F32(Tensor<f32>),
    U8(Tensor<u8>),
    I32(Tensor<i32>),
}

impl NamedTensor {
    /// The wire dtype code (0 = f32, 1 = u8, 2 = i32).
    pub fn dtype_code(&self) -> u8 {
        match self {
            NamedTensor::F32(_) => 0,
            NamedTensor::U8(_) => 1,
            NamedTensor::I32(_) => 2,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            NamedTensor::F32(t) => t.shape(),
            NamedTensor::U8(t) => t.shape(),
            NamedTensor::I32(t) => t.shape(),
        }
    }

    fn element_bytes(&self) -> Vec<u8> {
        match self {
            NamedTensor::F32(t) => t.data().iter().flat_map(|v| v.to_le_bytes()).collect(),
            NamedTensor::U8(t) => t.data().to_vec(),
            NamedTensor::I32(t) => t.data().iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }
}

/// Write named tensors of any supported dtype.
pub fn write_tensors(path: &Path, tensors: &[(String, NamedTensor)]) -> Result<()> {
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.dtype_code()])?;
        f.write_all(&[t.shape().len() as u8])?;
        for &d in t.shape() {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&t.element_bytes())?;
    }
    Ok(())
}

/// Write named f32 tensors (the trained-parameter interchange).
pub fn write_params(path: &Path, params: &[(String, Tensor<f32>)]) -> Result<()> {
    let tensors: Vec<(String, NamedTensor)> = params
        .iter()
        .map(|(name, t)| (name.clone(), NamedTensor::F32(t.clone())))
        .collect();
    write_tensors(path, &tensors)
}

fn read_exact<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Read named tensors of every dtype the format declares (0 = f32,
/// 1 = u8, 2 = i32), in file order.
pub fn read_tensors(path: &Path) -> Result<Vec<(String, NamedTensor)>> {
    let file_len = std::fs::metadata(path).with_context(|| format!("stat {path:?}"))?.len();
    let mut f =
        std::io::BufReader::new(std::fs::File::open(path).with_context(|| format!("open {path:?}"))?);
    let magic = read_exact::<4>(&mut f)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let version = u32::from_le_bytes(read_exact::<4>(&mut f)?);
    if version != VERSION {
        bail!("{path:?}: unsupported version {version}");
    }
    let count = u32::from_le_bytes(read_exact::<4>(&mut f)?);
    // No pre-allocation from the untrusted count: grow as tensors decode.
    let mut out = Vec::new();
    for _ in 0..count {
        let name_len = u16::from_le_bytes(read_exact::<2>(&mut f)?) as usize;
        let mut name_bytes = vec![0u8; name_len];
        f.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("tensor name is not utf-8")?;
        let dtype = read_exact::<1>(&mut f)?[0];
        let rank = read_exact::<1>(&mut f)?[0] as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(u32::from_le_bytes(read_exact::<4>(&mut f)?) as usize);
        }
        let elem_size: usize = match dtype {
            0 | 2 => 4,
            1 => 1,
            other => bail!("{path:?}: tensor {name}: unknown dtype {other}"),
        };
        // Bound the allocation by the bytes the file can actually hold: a
        // corrupt shape must fail cleanly, not overflow or exhaust memory.
        let n = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .filter(|&n| {
                n.checked_mul(elem_size).is_some_and(|b| b as u64 <= file_len)
            })
            .ok_or_else(|| {
                anyhow!("{path:?}: tensor {name}: declared shape {shape:?} exceeds file size")
            })?;
        let mut raw = vec![0u8; n * elem_size];
        f.read_exact(&mut raw)?;
        let tensor = match dtype {
            0 => {
                let data: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                NamedTensor::F32(Tensor::from_vec(&shape, data))
            }
            1 => NamedTensor::U8(Tensor::from_vec(&shape, raw)),
            _ => {
                let data: Vec<i32> = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                NamedTensor::I32(Tensor::from_vec(&shape, data))
            }
        };
        out.push((name, tensor));
    }
    Ok(out)
}

/// Read named f32 tensors into a [`ParamMap`]; rejects files carrying
/// other dtypes (trained-parameter files are f32-only by contract).
pub fn read_params(path: &Path) -> Result<ParamMap> {
    let mut out = ParamMap::new();
    for (name, tensor) in read_tensors(path)? {
        match tensor {
            NamedTensor::F32(t) => {
                out.insert(name, t);
            }
            other => bail!(
                "{path:?}: tensor {name}: only f32 (dtype 0) supported here, got {}",
                other.dtype_code()
            ),
        }
    }
    Ok(out)
}

/// Read QAT-learned activation ranges exported by the L2 side: every tensor
/// named `range:<key>` of shape `[2]` becomes `(key, (min, max))`.
pub fn read_ranges(params: &ParamMap) -> Vec<(String, (f64, f64))> {
    let mut out: Vec<(String, (f64, f64))> = params
        .iter()
        .filter_map(|(name, t)| {
            let key = name.strip_prefix("range:")?;
            assert_eq!(t.len(), 2, "range tensor {name} must have 2 entries");
            Some((key.to_string(), (f64::from(t.data()[0]), f64::from(t.data()[1]))))
        })
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// A tiny key=value text config (one per line, `#` comments) used for the
/// model-spec interchange where JSON would normally go (offline build: no
/// serde). Values stay strings; callers parse.
pub fn read_kv(path: &Path) -> Result<Vec<(String, String)>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("bad config line: {line}");
        };
        out.push((k.trim().to_string(), v.trim().to_string()));
    }
    Ok(out)
}

/// Write a key=value text config.
pub fn write_kv(path: &Path, pairs: &[(String, String)]) -> Result<()> {
    let mut s = String::new();
    for (k, v) in pairs {
        s.push_str(&format!("{k} = {v}\n"));
    }
    std::fs::write(path, s).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("iaoi-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn params_roundtrip() {
        let path = tmpfile("roundtrip.bin");
        let params = vec![
            ("conv0/w".to_string(), Tensor::from_vec(&[2, 3], vec![1.0f32, -2.5, 3.25, 0.0, 1e-8, -1e8])),
            ("fc/b".to_string(), Tensor::from_vec(&[4], vec![0.1f32, 0.2, 0.3, 0.4])),
            ("scalarish".to_string(), Tensor::from_vec(&[1], vec![42.0f32])),
        ];
        write_params(&path, &params).unwrap();
        let back = read_params(&path).unwrap();
        assert_eq!(back.len(), 3);
        for (name, t) in &params {
            let rt = &back[name];
            assert_eq!(rt.shape(), t.shape(), "{name}");
            assert_eq!(rt.data(), t.data(), "{name}");
        }
    }

    #[test]
    fn mixed_dtypes_roundtrip() {
        // The header format has always declared u8 and i32 dtypes; they
        // must round-trip exactly alongside f32.
        let path = tmpfile("mixed.bin");
        let tensors = vec![
            ("weights/q".to_string(), NamedTensor::U8(Tensor::from_vec(&[2, 2], vec![0u8, 1, 128, 255]))),
            ("bias/q".to_string(), NamedTensor::I32(Tensor::from_vec(&[3], vec![i32::MIN, 0, i32::MAX]))),
            ("scale".to_string(), NamedTensor::F32(Tensor::from_vec(&[1], vec![0.125f32]))),
        ];
        write_tensors(&path, &tensors).unwrap();
        let back = read_tensors(&path).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn read_params_rejects_non_f32() {
        let path = tmpfile("non_f32.bin");
        let tensors =
            vec![("q".to_string(), NamedTensor::U8(Tensor::from_vec(&[1], vec![7u8])))];
        write_tensors(&path, &tensors).unwrap();
        let err = read_params(&path).unwrap_err();
        assert!(err.to_string().contains("only f32"), "{err}");
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("bad.bin");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(read_params(&path).is_err());
    }

    #[test]
    fn ranges_extracted_from_params() {
        let mut pm = ParamMap::new();
        pm.insert("range:conv0".into(), Tensor::from_vec(&[2], vec![-1.5f32, 2.5]));
        pm.insert("conv0/w".into(), Tensor::from_vec(&[1], vec![0.0f32]));
        let ranges = read_ranges(&pm);
        assert_eq!(ranges.len(), 1);
        assert_eq!(ranges[0].0, "conv0");
        assert_eq!(ranges[0].1, (-1.5, 2.5));
    }

    #[test]
    fn kv_roundtrip() {
        let path = tmpfile("cfg.txt");
        let pairs = vec![
            ("model".to_string(), "papernet".to_string()),
            ("num_classes".to_string(), "16".to_string()),
        ];
        write_kv(&path, &pairs).unwrap();
        let back = read_kv(&path).unwrap();
        assert_eq!(back, pairs);
    }
}
