//! Baseline weight-quantization schemes the paper compares against in
//! Table 4.2: binary weight networks (BWN), ternary weight networks (TWN),
//! incremental network quantization (INQ, power-of-two weights) and
//! fine-grained quantization (FGQ, group-wise ternary).
//!
//! These are *weight-only* schemes (activations stay float32 in the paper's
//! table, except FGQ), so each quantizer maps a float weight array to a
//! quantized-then-dequantized float array that the float engine then runs —
//! exactly how such schemes deploy on commodity hardware without an integer
//! kernel. Our scheme ("Ours" in the table) is the full integer path in
//! [`crate::gemm`] + [`crate::nn`].



/// Which baseline to apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightScheme {
    /// BWN: `w ≈ α · sign(w)` with `α = mean |w|` (1-bit weights).
    Binary,
    /// TWN: `w ≈ α · t, t ∈ {−1, 0, +1}` with threshold `Δ = 0.7 · mean |w|`
    /// and `α = mean { |w| : |w| > Δ }` (2-bit weights).
    Ternary,
    /// INQ-style: each weight snapped to `± 2^k` or 0, `k` chosen from a
    /// window of `bits − 1` exponents below the max-magnitude weight
    /// (5 bits in Table 4.2).
    PowerOfTwo { bits: u32 },
    /// FGQ-style: ternary per group of `group_size` consecutive output
    /// channels (finer-grained α than TWN; 2 bits in Table 4.2).
    FineGrainedTernary { group_size: usize },
    /// Ours: affine 8-bit (handled by [`crate::quant::QuantParams`]); present
    /// here so the Table 4.2 harness can sweep one enum.
    AffineUint8,
}

impl WeightScheme {
    /// Effective weight bit-depth, for the table's "Weight bits" row.
    pub fn weight_bits(&self) -> u32 {
        match self {
            WeightScheme::Binary => 1,
            WeightScheme::Ternary => 2,
            WeightScheme::PowerOfTwo { bits } => *bits,
            WeightScheme::FineGrainedTernary { .. } => 2,
            WeightScheme::AffineUint8 => 8,
        }
    }

    /// Quantize-dequantize a weight array laid out with `ch_stride` values
    /// per output channel (used only by the fine-grained scheme).
    pub fn apply(&self, w: &[f32], ch_stride: usize) -> Vec<f32> {
        match self {
            WeightScheme::Binary => binary(w),
            WeightScheme::Ternary => ternary(w),
            WeightScheme::PowerOfTwo { bits } => power_of_two(w, *bits),
            WeightScheme::FineGrainedTernary { group_size } => {
                fine_grained_ternary(w, ch_stride, *group_size)
            }
            WeightScheme::AffineUint8 => {
                let p = crate::quant::QuantParams::for_weights(w, 8);
                w.iter().map(|&v| crate::quant::fake_quantize(&p, v)).collect()
            }
        }
    }
}

fn mean_abs(w: &[f32]) -> f32 {
    if w.is_empty() {
        return 0.0;
    }
    w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32
}

/// BWN quantizer.
pub fn binary(w: &[f32]) -> Vec<f32> {
    let alpha = mean_abs(w);
    w.iter().map(|&v| if v >= 0.0 { alpha } else { -alpha }).collect()
}

/// TWN quantizer with the standard 0.7·E|w| threshold.
pub fn ternary(w: &[f32]) -> Vec<f32> {
    let delta = 0.7 * mean_abs(w);
    let kept: Vec<f32> = w.iter().filter(|v| v.abs() > delta).map(|v| v.abs()).collect();
    let alpha = if kept.is_empty() { 0.0 } else { kept.iter().sum::<f32>() / kept.len() as f32 };
    w.iter()
        .map(|&v| {
            if v > delta {
                alpha
            } else if v < -delta {
                -alpha
            } else {
                0.0
            }
        })
        .collect()
}

/// INQ-style power-of-two quantizer: magnitudes snap to the nearest of
/// `{0} ∪ {2^k : k ∈ [k_max − 2^(bits−1) + 2, k_max]}` where
/// `k_max = floor(log2(max |w|))` — with `bits − 1` magnitude bits plus sign,
/// matching INQ's 5-bit configuration in spirit.
pub fn power_of_two(w: &[f32], bits: u32) -> Vec<f32> {
    assert!(bits >= 2);
    let max_abs = w.iter().fold(0f32, |m, v| m.max(v.abs()));
    if max_abs == 0.0 {
        return vec![0.0; w.len()];
    }
    let k_max = max_abs.log2().floor() as i32;
    let n_levels = (1i32 << (bits - 1)) - 1; // distinct power-of-two magnitudes
    let k_min = k_max - n_levels + 1;
    w.iter()
        .map(|&v| {
            if v == 0.0 {
                return 0.0;
            }
            let sign = v.signum();
            let a = v.abs();
            // Nearest power of two in [2^k_min, 2^k_max], or 0 if below the
            // midpoint to the smallest level.
            let k = a.log2().round().clamp(k_min as f32, k_max as f32) as i32;
            let q = 2f32.powi(k);
            if a < 2f32.powi(k_min) * 0.5 {
                0.0
            } else {
                sign * q
            }
        })
        .collect()
}

/// FGQ-style group-wise ternary: weights are grouped by blocks of
/// `group_size` output channels (each channel spanning `ch_stride` values)
/// and each group gets its own `(Δ, α)` — much finer granularity than TWN.
pub fn fine_grained_ternary(w: &[f32], ch_stride: usize, group_size: usize) -> Vec<f32> {
    assert!(ch_stride > 0 && group_size > 0);
    let block = ch_stride * group_size;
    let mut out = Vec::with_capacity(w.len());
    for chunk in w.chunks(block) {
        out.extend(ternary(chunk));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_weights(n: usize) -> Vec<f32> {
        // Deterministic pseudo-Gaussian-ish spread including outliers.
        (0..n)
            .map(|i| {
                let t = (i as f32 * 0.734_21).sin() * 0.4 + (i as f32 * 0.113).cos() * 0.1;
                if i % 97 == 0 {
                    t * 8.0 // outlier channel, the paper's failure mode 2
                } else {
                    t
                }
            })
            .collect()
    }

    #[test]
    fn binary_has_two_levels() {
        let w = sample_weights(512);
        let q = binary(&w);
        let mut levels: Vec<i32> = q.iter().map(|v| (v * 1e6) as i32).collect();
        levels.sort_unstable();
        levels.dedup();
        assert_eq!(levels.len(), 2);
        // Signs preserved.
        for (a, b) in w.iter().zip(&q) {
            assert!(a * b >= 0.0);
        }
    }

    #[test]
    fn ternary_has_three_levels_and_zeroes_small_weights() {
        let w = sample_weights(512);
        let q = ternary(&w);
        let mut levels: Vec<i32> = q.iter().map(|v| (v * 1e6) as i32).collect();
        levels.sort_unstable();
        levels.dedup();
        assert!(levels.len() <= 3);
        assert!(q.iter().any(|&v| v == 0.0), "threshold must zero some weights");
    }

    #[test]
    fn power_of_two_values_are_powers_or_zero() {
        let w = sample_weights(512);
        let q = power_of_two(&w, 5);
        for &v in &q {
            if v != 0.0 {
                let l = v.abs().log2();
                assert!((l - l.round()).abs() < 1e-6, "{v} is not a power of two");
            }
        }
    }

    #[test]
    fn power_of_two_level_count_respects_bits() {
        let w = sample_weights(4096);
        let q = power_of_two(&w, 5);
        let mut mags: Vec<i32> = q.iter().filter(|v| **v != 0.0).map(|v| v.abs().log2().round() as i32).collect();
        mags.sort_unstable();
        mags.dedup();
        assert!(mags.len() <= 15, "5-bit pow2 has <= 2^4 - 1 magnitudes, got {}", mags.len());
    }

    #[test]
    fn fine_grained_beats_global_ternary_on_mse() {
        // The whole point of FGQ: per-group scales track range variation
        // across channels (the paper's failure mode 1).
        let w = sample_weights(64 * 9 * 4);
        let global = ternary(&w);
        let fine = fine_grained_ternary(&w, 9, 4);
        let mse = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / a.len() as f32
        };
        assert!(mse(&w, &fine) <= mse(&w, &global) + 1e-9);
    }

    #[test]
    fn affine_uint8_is_most_accurate() {
        let w = sample_weights(1024);
        let mse = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / a.len() as f32
        };
        let ours = WeightScheme::AffineUint8.apply(&w, 1);
        for scheme in [
            WeightScheme::Binary,
            WeightScheme::Ternary,
            WeightScheme::PowerOfTwo { bits: 5 },
            WeightScheme::FineGrainedTernary { group_size: 4 },
        ] {
            let q = scheme.apply(&w, 9);
            assert!(
                mse(&w, &ours) <= mse(&w, &q),
                "8-bit affine should dominate {scheme:?} on reconstruction error"
            );
        }
    }

    #[test]
    fn scheme_bit_depths_match_table_4_2() {
        assert_eq!(WeightScheme::Binary.weight_bits(), 1);
        assert_eq!(WeightScheme::Ternary.weight_bits(), 2);
        assert_eq!(WeightScheme::PowerOfTwo { bits: 5 }.weight_bits(), 5);
        assert_eq!(WeightScheme::FineGrainedTernary { group_size: 4 }.weight_bits(), 2);
        assert_eq!(WeightScheme::AffineUint8.weight_bits(), 8);
    }

    #[test]
    fn empty_input_ok() {
        assert!(binary(&[]).is_empty());
        assert!(ternary(&[]).is_empty());
        assert!(power_of_two(&[], 5).is_empty());
    }
}
