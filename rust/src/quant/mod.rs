//! The paper's quantization scheme (§2.1): the affine map `r = S(q − Z)`.
//!
//! One [`QuantParams`] instance exists per weights array and per activations
//! array (a single set of parameters for all values within an array; separate
//! arrays use separate parameters). `S` is a positive real scale, `Z` a
//! zero-point of the same integer type as `q`, constructed so the real value
//! 0.0 is *exactly* representable — required so zero-padding introduces no
//! error (§2.1).
//!
//! Submodules:
//! * [`multiplier`] — offline normalization of `M = S1·S2/S3` into
//!   `2^-n · M0` (eq. 5–6).
//! * [`channel`] — symmetric per-channel weight scales
//!   (Krishnamoorthi 1806.08342) and the [`WeightQuant`] carrier the
//!   matmul-shaped layers store.
//! * [`schemes`] — baseline weight quantizers (binary / ternary /
//!   power-of-two / fine-grained) used for the Table 4.2 comparison.

pub mod channel;
pub mod multiplier;
pub mod schemes;

pub use channel::{ChannelAxis, ChannelQuantParams, WeightQuant};
pub use multiplier::{quantize_multiplier, QuantizedMultiplier};



/// Affine quantization parameters for one array: `r = scale · (q − zero_point)`.
///
/// `qmin`/`qmax` carry the quantized range so the same struct covers 8-bit
/// activations, B-bit ablations (Tables 4.7/4.8) and the narrow weight range
/// `[1, 255]` (i.e. int8 `[-127, 127]`) used for the App. B optimization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    /// The scale `S`: an arbitrary positive real (eq. 1). Stored as `f64` at
    /// build/calibration time; it never appears on the integer hot path —
    /// only the normalized multiplier derived from it does (§2.2).
    pub scale: f64,
    /// The zero-point `Z`: the quantized value corresponding to real 0.0.
    pub zero_point: i32,
    /// Smallest representable quantized value (0 for uint8, 1 for
    /// narrow-range weights).
    pub qmin: i32,
    /// Largest representable quantized value (255 for uint8; `2^B − 1`).
    pub qmax: i32,
}

impl QuantParams {
    /// Unit scale, zero zero-point — identity-ish params for testing.
    pub fn unit(qmin: i32, qmax: i32) -> Self {
        Self { scale: 1.0, zero_point: 0, qmin, qmax }
    }

    /// Standard uint8 range `[0, 255]`.
    pub fn uint8_range() -> (i32, i32) {
        (0, 255)
    }

    /// Quantized range for `bits`-bit quantization stored in uint8
    /// (Tables 4.7/4.8 sweep `bits ∈ {4..8}`); `narrow` drops the lowest
    /// value so symmetric int8 weights avoid −128 (App. B, §3.1).
    pub fn range_for_bits(bits: u32, narrow: bool) -> (i32, i32) {
        assert!((2..=8).contains(&bits), "bit depth must be in [2, 8]");
        (i32::from(narrow), (1i32 << bits) - 1)
    }

    /// Choose quantization parameters from an observed real range
    /// `[rmin, rmax]` (§3.1, eq. 13).
    ///
    /// The range is first widened to include 0.0 (so that `Z` exists), the
    /// scale is `s(a,b,n) = (b − a)/(n − 1)` and the zero-point is *nudged*
    /// to an integer so that real 0.0 maps exactly onto it — the paper's
    /// "boundaries [a; b] are nudged so that value 0.0 is exactly
    /// representable".
    pub fn from_min_max(rmin: f64, rmax: f64, qmin: i32, qmax: i32) -> Self {
        assert!(qmax > qmin);
        // Widen to contain zero; a degenerate range still yields valid params.
        let rmin = rmin.min(0.0);
        let rmax = rmax.max(0.0);
        if rmin == rmax {
            return Self { scale: 1.0, zero_point: qmin, qmin, qmax };
        }
        let scale = (rmax - rmin) / f64::from(qmax - qmin);
        // Ideal (real-valued) zero point, then nudge to the nearest integer
        // in range. Following the TFLite converter we pick the candidate
        // that minimizes the error on whichever boundary is closer to 0.
        let zp_from_min = f64::from(qmin) - rmin / scale;
        let zero_point = if zp_from_min < f64::from(qmin) {
            qmin
        } else if zp_from_min > f64::from(qmax) {
            qmax
        } else {
            zp_from_min.round() as i32
        };
        Self { scale, zero_point, qmin, qmax }
    }

    /// Weight-array parameters: `a := min w, b := max w` with the narrow
    /// range tweak so int8 weights never take −128 (§3.1, App. B).
    pub fn for_weights(w: &[f32], bits: u32) -> Self {
        let (mut mn, mut mx) = (0f32, 0f32);
        for &v in w {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        let (qmin, qmax) = Self::range_for_bits(bits, true);
        Self::from_min_max(f64::from(mn), f64::from(mx), qmin, qmax)
    }

    /// Bias-vector parameters (§2.4, eq. 11): int32 storage,
    /// `S_bias = S_weights · S_input`, `Z_bias = 0`.
    pub fn for_bias(weights: &QuantParams, input: &QuantParams) -> Self {
        Self {
            scale: weights.scale * input.scale,
            zero_point: 0,
            qmin: i32::MIN,
            qmax: i32::MAX,
        }
    }

    /// Quantize one real value: `q = clamp(round(r/S) + Z)`.
    #[inline]
    pub fn quantize(&self, r: f32) -> i32 {
        let q = (f64::from(r) / self.scale).round() as i64 + i64::from(self.zero_point);
        q.clamp(i64::from(self.qmin), i64::from(self.qmax)) as i32
    }

    /// Dequantize: `r = S (q − Z)` (eq. 1).
    #[inline]
    pub fn dequantize(&self, q: i32) -> f32 {
        (self.scale * f64::from(q - self.zero_point)) as f32
    }

    /// Quantize a slice into u8 storage (valid when `qmax ≤ 255`).
    pub fn quantize_slice(&self, r: &[f32]) -> Vec<u8> {
        debug_assert!(self.qmax <= 255 && self.qmin >= 0);
        r.iter().map(|&v| self.quantize(v) as u8).collect()
    }

    /// Quantize a bias slice into i32 storage.
    pub fn quantize_bias_slice(&self, r: &[f32]) -> Vec<i32> {
        r.iter().map(|&v| self.quantize(v)).collect()
    }

    /// Dequantize a u8 slice.
    pub fn dequantize_slice(&self, q: &[u8]) -> Vec<f32> {
        q.iter().map(|&v| self.dequantize(i32::from(v))).collect()
    }

    /// The real range `[a, b]` representable by these parameters.
    pub fn real_range(&self) -> (f64, f64) {
        (
            self.scale * f64::from(self.qmin - self.zero_point),
            self.scale * f64::from(self.qmax - self.zero_point),
        )
    }

    /// Number of quantization levels `n` (eq. 12).
    pub fn levels(&self) -> i64 {
        i64::from(self.qmax) - i64::from(self.qmin) + 1
    }

    /// Size of the little-endian wire encoding used by
    /// [`crate::model_format`] and any other binary interchange.
    pub const WIRE_BYTES: usize = 20;

    /// Encode as little-endian bytes: `scale` f64, then `zero_point`,
    /// `qmin`, `qmax` as i32. Lossless: `f64::to_le_bytes` preserves the
    /// exact scale, so a decoded graph requantizes bit-identically.
    pub fn to_wire(&self) -> [u8; Self::WIRE_BYTES] {
        let mut out = [0u8; Self::WIRE_BYTES];
        out[0..8].copy_from_slice(&self.scale.to_le_bytes());
        out[8..12].copy_from_slice(&self.zero_point.to_le_bytes());
        out[12..16].copy_from_slice(&self.qmin.to_le_bytes());
        out[16..20].copy_from_slice(&self.qmax.to_le_bytes());
        out
    }

    /// Decode the [`Self::to_wire`] encoding. Performs no range validation;
    /// callers that read untrusted bytes should check [`Self::wire_valid`].
    pub fn from_wire(b: &[u8; Self::WIRE_BYTES]) -> Self {
        Self {
            scale: f64::from_le_bytes(b[0..8].try_into().unwrap()),
            zero_point: i32::from_le_bytes(b[8..12].try_into().unwrap()),
            qmin: i32::from_le_bytes(b[12..16].try_into().unwrap()),
            qmax: i32::from_le_bytes(b[16..20].try_into().unwrap()),
        }
    }

    /// Whether decoded parameters are sane: positive finite scale and a
    /// non-empty quantized range (§2.1 requires `S > 0`).
    pub fn wire_valid(&self) -> bool {
        self.scale.is_finite() && self.scale > 0.0 && self.qmax > self.qmin
    }
}

/// Simulated ("fake") quantization of a real value (eq. 12): quantize then
/// dequantize in floating point — the forward arithmetic of the QAT graph,
/// which the L1 Pallas kernel mirrors bit-for-bit.
#[inline]
pub fn fake_quantize(params: &QuantParams, r: f32) -> f32 {
    params.dequantize(params.quantize(r))
}

/// Fake-quantize a slice in place.
pub fn fake_quantize_slice(params: &QuantParams, r: &mut [f32]) {
    for v in r.iter_mut() {
        *v = fake_quantize(params, *v);
    }
}

/// Track the min/max range of activations with an exponential moving average
/// (§3.1): "we collect [a; b] ranges seen on activations during training and
/// then aggregate them via EMA with the smoothing parameter close to 1".
#[derive(Clone, Copy, Debug)]
pub struct EmaRange {
    pub min: f64,
    pub max: f64,
    pub decay: f64,
    initialized: bool,
}

impl EmaRange {
    pub fn new(decay: f64) -> Self {
        assert!((0.0..1.0).contains(&decay));
        Self { min: 0.0, max: 0.0, decay, initialized: false }
    }

    /// Fold one observed batch range into the EMA.
    pub fn update(&mut self, batch_min: f64, batch_max: f64) {
        if !self.initialized {
            self.min = batch_min;
            self.max = batch_max;
            self.initialized = true;
        } else {
            self.min = self.decay * self.min + (1.0 - self.decay) * batch_min;
            self.max = self.decay * self.max + (1.0 - self.decay) * batch_max;
        }
    }

    /// Observe a slice of activations.
    pub fn observe(&mut self, xs: &[f32]) {
        if xs.is_empty() {
            return;
        }
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            mn = mn.min(f64::from(x));
            mx = mx.max(f64::from(x));
        }
        self.update(mn, mx);
    }

    pub fn is_initialized(&self) -> bool {
        self.initialized
    }

    /// Materialize quantization parameters from the smoothed range.
    pub fn params(&self, qmin: i32, qmax: i32) -> QuantParams {
        QuantParams::from_min_max(self.min, self.max, qmin, qmax)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_is_exactly_representable() {
        // §2.1: real 0.0 must map to an integer zero-point with no error.
        for (mn, mx) in [(-1.0, 1.0), (-0.3, 2.7), (0.1, 5.0), (-6.0, -0.01), (-128.3, 0.0)] {
            let p = QuantParams::from_min_max(mn, mx, 0, 255);
            let z = p.quantize(0.0);
            assert_eq!(z, p.zero_point);
            assert_eq!(p.dequantize(z), 0.0, "range ({mn},{mx})");
        }
    }

    #[test]
    fn range_widened_to_include_zero() {
        let p = QuantParams::from_min_max(0.5, 2.0, 0, 255);
        let (a, b) = p.real_range();
        assert!(a <= 0.0 && b >= 2.0 - p.scale, "range ({a},{b})");
    }

    #[test]
    fn quantize_dequantize_error_bounded_by_scale() {
        // Interior points are within scale/2 of a grid point; because the
        // grid is nudged (by up to scale/2) so that 0.0 is exact, boundary
        // values can be up to one full scale away (§3.1).
        let p = QuantParams::from_min_max(-3.0, 5.0, 0, 255);
        for i in 0..1000 {
            let r = -3.0 + 8.0 * (i as f32) / 1000.0;
            let rq = p.dequantize(p.quantize(r));
            assert!(
                (f64::from(r) - f64::from(rq)).abs() <= p.scale + 1e-9,
                "r={r} rq={rq} scale={}",
                p.scale
            );
        }
        // Away from the boundaries the half-scale bound holds.
        for i in 0..1000 {
            let r = -2.9 + 7.8 * (i as f32) / 1000.0;
            let rq = p.dequantize(p.quantize(r));
            assert!(
                (f64::from(r) - f64::from(rq)).abs() <= p.scale / 2.0 + 1e-9,
                "interior r={r} rq={rq}"
            );
        }
    }

    #[test]
    fn quantize_saturates_out_of_range() {
        let p = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        assert_eq!(p.quantize(100.0), 255);
        assert_eq!(p.quantize(-100.0), 0);
    }

    #[test]
    fn narrow_range_weights_avoid_neg128() {
        // App. B: int8 weights must stay in [-127, 127]; with uint8 storage
        // and Z ∈ [1,255] that means q ∈ [1, 255].
        let w: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) / 10.0).collect();
        let p = QuantParams::for_weights(&w, 8);
        assert!(p.qmin == 1 && p.qmax == 255);
        for &v in &w {
            let q = p.quantize(v);
            assert!((1..=255).contains(&q));
            // int8 view: q - 128 ∈ [-127, 127]
            assert!((q - 128).abs() <= 127);
        }
    }

    #[test]
    fn bias_params_follow_eq_11() {
        let wp = QuantParams::from_min_max(-0.5, 0.5, 1, 255);
        let ip = QuantParams::from_min_max(0.0, 6.0, 0, 255);
        let bp = QuantParams::for_bias(&wp, &ip);
        assert_eq!(bp.zero_point, 0);
        assert!((bp.scale - wp.scale * ip.scale).abs() < 1e-15);
    }

    #[test]
    fn bit_depth_ranges() {
        assert_eq!(QuantParams::range_for_bits(8, false), (0, 255));
        assert_eq!(QuantParams::range_for_bits(8, true), (1, 255));
        assert_eq!(QuantParams::range_for_bits(7, false), (0, 127));
        assert_eq!(QuantParams::range_for_bits(4, false), (0, 15));
    }

    #[test]
    fn fake_quant_is_idempotent() {
        let p = QuantParams::from_min_max(-2.0, 2.0, 0, 255);
        for i in 0..100 {
            let r = -2.0 + 4.0 * (i as f32) / 100.0;
            let once = fake_quantize(&p, r);
            let twice = fake_quantize(&p, once);
            assert_eq!(once, twice);
        }
    }

    #[test]
    fn ema_range_smooths() {
        let mut ema = EmaRange::new(0.9);
        ema.update(-1.0, 1.0);
        assert_eq!((ema.min, ema.max), (-1.0, 1.0)); // first obs initializes
        ema.update(-3.0, 3.0);
        assert!((ema.min - (-1.2)).abs() < 1e-12);
        assert!((ema.max - 1.2).abs() < 1e-12);
    }

    #[test]
    fn degenerate_range_is_valid() {
        let p = QuantParams::from_min_max(0.0, 0.0, 0, 255);
        assert_eq!(p.quantize(0.0), p.zero_point);
        assert_eq!(p.dequantize(p.zero_point), 0.0);
    }

    #[test]
    fn wire_roundtrip_is_exact() {
        for (mn, mx) in [(-1.0, 1.0), (-0.37, 12.9), (0.0, 1e-6), (-1e9, 3.5)] {
            let p = QuantParams::from_min_max(mn, mx, 0, 255);
            let back = QuantParams::from_wire(&p.to_wire());
            assert_eq!(p, back, "({mn},{mx})");
            assert!(back.wire_valid());
        }
        let bad = QuantParams { scale: f64::NAN, zero_point: 0, qmin: 0, qmax: 255 };
        assert!(!QuantParams::from_wire(&bad.to_wire()).wire_valid());
    }

    #[test]
    fn levels_match_bit_depth() {
        let (qmin, qmax) = QuantParams::range_for_bits(7, false);
        let p = QuantParams::from_min_max(-1.0, 1.0, qmin, qmax);
        assert_eq!(p.levels(), 128);
    }
}
