//! Per-channel weight quantization (Krishnamoorthi 1806.08342 §3.1).
//!
//! The paper quantizes each weight array with a single `(S, Z)` pair
//! (§2.1), which loses accuracy when output channels carry very different
//! ranges — exactly the situation batch-norm folding (eq. 14) creates on
//! depthwise layers, where the per-channel `γ/σ` factors spread weight
//! magnitudes across orders of magnitude. Per-channel quantization gives
//! each *output channel* its own scale while keeping one **shared,
//! symmetric zero-point** (the uint8 midpoint), so:
//!
//! * activations stay per-tensor — nothing changes on the RHS of the GEMM;
//! * the eq. 7 zero-point corrections still use one `Z1` — the int8 GEMM
//!   accumulation core is untouched;
//! * only the §2.4 requantization multiplier becomes per-row
//!   ([`crate::gemm::output::Requant::PerChannel`]), applied once per
//!   output row.
//!
//! [`WeightQuant`] is the weight-side parameter carrier every matmul-shaped
//! layer ([`crate::nn::conv`], [`crate::nn::depthwise`], [`crate::nn::fc`])
//! stores: the per-tensor case wraps the classic [`QuantParams`] and stays
//! the cheap default.

use super::QuantParams;

/// Which axis of a weight tensor indexes the output channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelAxis {
    /// Channel is the outermost dimension: conv OHWI `[Cout, KH, KW, Cin]`
    /// and FC `[units, features]` — each channel's weights are contiguous.
    Outer,
    /// Channel is the innermost dimension: depthwise `[1, KH, KW, C]` —
    /// channel `i % C` for flat index `i`.
    Inner,
}

impl ChannelAxis {
    /// Channel of flat element `i` in a `len`-element array with `channels`
    /// channels.
    #[inline]
    fn channel_of(self, i: usize, len: usize, channels: usize) -> usize {
        match self {
            ChannelAxis::Outer => i / (len / channels),
            ChannelAxis::Inner => i % channels,
        }
    }
}

/// Symmetric per-channel quantization parameters for one weight array:
/// `r = scales[ch] · (q − zero_point)` with a single shared zero-point.
#[derive(Clone, Debug, PartialEq)]
pub struct ChannelQuantParams {
    /// One positive scale per output channel.
    pub scales: Vec<f64>,
    /// Shared zero-point — the storage midpoint `2^(bits−1)`, so symmetric
    /// int8 weights stay in `[−(2^(bits−1)−1), 2^(bits−1)−1]` (App. B's
    /// narrow-range precondition holds per construction).
    pub zero_point: i32,
    /// Smallest representable quantized value (narrow range: `qmin = 1`).
    pub qmin: i32,
    /// Largest representable quantized value (`2^bits − 1`).
    pub qmax: i32,
}

impl ChannelQuantParams {
    /// Choose symmetric per-channel parameters from a float weight array
    /// with `channels` output channels along `axis`. Channels whose weights
    /// are all zero get scale 1.0 (any positive scale represents them
    /// exactly).
    pub fn for_weights(w: &[f32], channels: usize, axis: ChannelAxis, bits: u32) -> Self {
        assert!((2..=8).contains(&bits), "bit depth must be in [2, 8]");
        assert!(channels > 0 && w.len() % channels == 0, "weight volume must split into channels");
        let mut max_abs = vec![0f64; channels];
        for (i, &v) in w.iter().enumerate() {
            let ch = axis.channel_of(i, w.len(), channels);
            max_abs[ch] = max_abs[ch].max(f64::from(v.abs()));
        }
        let half_levels = f64::from((1i32 << (bits - 1)) - 1);
        let scales = max_abs
            .into_iter()
            .map(|m| if m == 0.0 { 1.0 } else { m / half_levels })
            .collect();
        Self {
            scales,
            zero_point: 1 << (bits - 1),
            qmin: 1,
            qmax: (1 << bits) - 1,
        }
    }

    pub fn channels(&self) -> usize {
        self.scales.len()
    }

    /// Quantize one real value belonging to channel `ch`.
    #[inline]
    pub fn quantize(&self, ch: usize, r: f32) -> i32 {
        let q = (f64::from(r) / self.scales[ch]).round() as i64 + i64::from(self.zero_point);
        q.clamp(i64::from(self.qmin), i64::from(self.qmax)) as i32
    }

    /// Dequantize one value of channel `ch`.
    #[inline]
    pub fn dequantize(&self, ch: usize, q: i32) -> f32 {
        (self.scales[ch] * f64::from(q - self.zero_point)) as f32
    }

    /// Quantize a whole weight array laid out along `axis` into u8 storage.
    pub fn quantize_slice(&self, w: &[f32], axis: ChannelAxis) -> Vec<u8> {
        debug_assert!(self.qmax <= 255 && self.qmin >= 0);
        let channels = self.channels();
        w.iter()
            .enumerate()
            .map(|(i, &v)| self.quantize(axis.channel_of(i, w.len(), channels), v) as u8)
            .collect()
    }

    /// Quantize a per-channel bias vector per eq. 11: element `ch` is stored
    /// as int32 at scale `scales[ch] · input_scale` with zero-point 0.
    pub fn quantize_bias(&self, bias: &[f32], input_scale: f64) -> Vec<i32> {
        assert!(bias.is_empty() || bias.len() == self.channels(), "bias is per output channel");
        bias.iter()
            .enumerate()
            .map(|(ch, &b)| {
                let q = (f64::from(b) / (self.scales[ch] * input_scale)).round();
                q.clamp(f64::from(i32::MIN), f64::from(i32::MAX)) as i32
            })
            .collect()
    }

    /// Whether decoded parameters are sane: positive finite scales, a
    /// non-empty quantized range, and a zero-point valid as a u8 storage
    /// value — the checks the `.iaoiq` loader applies to untrusted bytes.
    pub fn wire_valid(&self) -> bool {
        !self.scales.is_empty()
            && self.scales.iter().all(|s| s.is_finite() && *s > 0.0)
            && self.qmax > self.qmin
            && (0..=255).contains(&self.zero_point)
    }
}

/// Weight-side quantization of one matmul-shaped layer: the per-tensor
/// affine scheme of §2.1, or symmetric per-channel scales.
#[derive(Clone, Debug, PartialEq)]
pub enum WeightQuant {
    /// One `(S, Z)` pair for the whole array (the paper's scheme).
    PerTensor(QuantParams),
    /// One scale per output channel, shared symmetric zero-point.
    PerChannel(ChannelQuantParams),
}

impl WeightQuant {
    /// The shared zero-point `Z1` the GEMM core subtracts — single-valued in
    /// both modes by construction.
    #[inline]
    pub fn zero_point(&self) -> i32 {
        match self {
            WeightQuant::PerTensor(p) => p.zero_point,
            WeightQuant::PerChannel(c) => c.zero_point,
        }
    }

    /// The scale of output channel `ch` (per-tensor: the one scale).
    #[inline]
    pub fn scale(&self, ch: usize) -> f64 {
        match self {
            WeightQuant::PerTensor(p) => p.scale,
            WeightQuant::PerChannel(c) => c.scales[ch],
        }
    }

    /// Number of per-channel scales, `None` in per-tensor mode.
    pub fn channels(&self) -> Option<usize> {
        match self {
            WeightQuant::PerTensor(_) => None,
            WeightQuant::PerChannel(c) => Some(c.channels()),
        }
    }

    pub fn is_per_channel(&self) -> bool {
        matches!(self, WeightQuant::PerChannel(_))
    }

    /// Loader-side sanity check (see the per-variant `wire_valid`s).
    pub fn wire_valid(&self) -> bool {
        match self {
            WeightQuant::PerTensor(p) => p.wire_valid(),
            WeightQuant::PerChannel(c) => c.wire_valid(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Weights with channel ranges spanning two orders of magnitude — the
    /// BN-folded depthwise failure mode per-channel quantization exists for.
    fn heterogeneous(channels: usize, per: usize) -> Vec<f32> {
        (0..channels * per)
            .map(|i| {
                let ch = i / per;
                let gain = 0.05f32 * 4f32.powi((ch % 4) as i32);
                gain * ((i as f32 * 0.73).sin())
            })
            .collect()
    }

    #[test]
    fn symmetric_zero_point_is_midpoint_and_zero_exact() {
        let w = heterogeneous(4, 9);
        let p = ChannelQuantParams::for_weights(&w, 4, ChannelAxis::Outer, 8);
        assert_eq!(p.zero_point, 128);
        assert_eq!((p.qmin, p.qmax), (1, 255));
        for ch in 0..4 {
            assert_eq!(p.quantize(ch, 0.0), 128);
            assert_eq!(p.dequantize(ch, 128), 0.0);
        }
    }

    #[test]
    fn per_channel_stays_in_narrow_range() {
        let w = heterogeneous(6, 16);
        let p = ChannelQuantParams::for_weights(&w, 6, ChannelAxis::Outer, 8);
        let q = p.quantize_slice(&w, ChannelAxis::Outer);
        for &v in &q {
            assert!((1..=255).contains(&i32::from(v)));
            // int8 view: never −128 (App. B precondition).
            assert!((i32::from(v) - 128).abs() <= 127);
        }
    }

    #[test]
    fn per_channel_reconstruction_beats_per_tensor_on_heterogeneous_channels() {
        let w = heterogeneous(8, 27);
        let pc = ChannelQuantParams::for_weights(&w, 8, ChannelAxis::Outer, 8);
        let pt = QuantParams::for_weights(&w, 8);
        let mse = |deq: &dyn Fn(usize, f32) -> f32| -> f64 {
            w.iter()
                .enumerate()
                .map(|(i, &v)| {
                    let d = f64::from(v) - f64::from(deq(i, v));
                    d * d
                })
                .sum::<f64>()
                / w.len() as f64
        };
        let per = w.len() / 8;
        let pc_mse = mse(&|i, v| pc.dequantize(i / per, pc.quantize(i / per, v)));
        let pt_mse = mse(&|_, v| pt.dequantize(pt.quantize(v)));
        assert!(
            pc_mse < pt_mse * 0.25,
            "per-channel should sharply cut weight error: {pc_mse} vs {pt_mse}"
        );
    }

    #[test]
    fn inner_axis_matches_depthwise_layout() {
        // Depthwise [1, KH, KW, C]: channel is innermost. Quantizing with
        // Inner must give every element of channel ch the scale of ch.
        let c = 3;
        let taps = 9;
        let w: Vec<f32> = (0..taps * c)
            .map(|i| if i % c == 2 { 10.0 } else { 0.1 } * ((i as f32).cos()))
            .collect();
        let p = ChannelQuantParams::for_weights(&w, c, ChannelAxis::Inner, 8);
        assert!(p.scales[2] > p.scales[0] * 10.0);
        let q = p.quantize_slice(&w, ChannelAxis::Inner);
        for (i, &qv) in q.iter().enumerate() {
            let back = p.dequantize(i % c, i32::from(qv));
            assert!(
                (back - w[i]).abs() <= p.scales[i % c] as f32 * 0.51 + 1e-6,
                "element {i}: {back} vs {}",
                w[i]
            );
        }
    }

    #[test]
    fn all_zero_channel_gets_valid_scale() {
        let mut w = heterogeneous(4, 8);
        for v in w[8..16].iter_mut() {
            *v = 0.0;
        }
        let p = ChannelQuantParams::for_weights(&w, 4, ChannelAxis::Outer, 8);
        assert_eq!(p.scales[1], 1.0);
        assert!(p.wire_valid());
        assert_eq!(p.quantize(1, 0.0), 128);
    }

    #[test]
    fn bias_uses_per_channel_scale() {
        let w = heterogeneous(4, 9);
        let p = ChannelQuantParams::for_weights(&w, 4, ChannelAxis::Outer, 8);
        let bias = [0.5f32, -0.25, 1.0, 0.0];
        let q = p.quantize_bias(&bias, 0.02);
        for ch in 0..4 {
            let back = f64::from(q[ch]) * p.scales[ch] * 0.02;
            assert!(
                (back - f64::from(bias[ch])).abs() <= p.scales[ch] * 0.02 * 0.51,
                "ch {ch}: {back} vs {}",
                bias[ch]
            );
        }
        assert!(p.quantize_bias(&[], 0.02).is_empty());
    }

    #[test]
    fn weight_quant_accessors() {
        let pt = WeightQuant::PerTensor(QuantParams::from_min_max(-1.0, 1.0, 1, 255));
        assert!(!pt.is_per_channel());
        assert_eq!(pt.channels(), None);
        assert!(pt.wire_valid());

        let w = heterogeneous(4, 9);
        let pc = WeightQuant::PerChannel(ChannelQuantParams::for_weights(
            &w,
            4,
            ChannelAxis::Outer,
            8,
        ));
        assert!(pc.is_per_channel());
        assert_eq!(pc.channels(), Some(4));
        assert_eq!(pc.zero_point(), 128);
        assert!(pc.scale(0) > 0.0);
        assert!(pc.wire_valid());

        let bad = WeightQuant::PerChannel(ChannelQuantParams {
            scales: vec![1.0, f64::NAN],
            zero_point: 128,
            qmin: 1,
            qmax: 255,
        });
        assert!(!bad.wire_valid());
    }

    #[test]
    fn lower_bit_depths_scale_the_range() {
        let w = heterogeneous(2, 8);
        let p = ChannelQuantParams::for_weights(&w, 2, ChannelAxis::Outer, 4);
        assert_eq!(p.zero_point, 8);
        assert_eq!((p.qmin, p.qmax), (1, 15));
        let q = p.quantize_slice(&w, ChannelAxis::Outer);
        for &v in &q {
            assert!((1..=15).contains(&i32::from(v)));
        }
    }
}
