//! Offline normalization of the real multiplier `M = S1·S2/S3` (eq. 5) into
//! the integer-friendly form `M = 2^-n · M0` with `M0 ∈ [0.5, 1)` (eq. 6).
//!
//! `M0` is stored as the int32 nearest to `2^31·M0`; because `M0 ≥ 0.5` the
//! stored value is at least `2^30`, guaranteeing ≥30 bits of relative
//! accuracy (§2.2). At run time the pair is applied with
//! [`crate::fixedpoint::multiply_by_quantized_multiplier`]:
//! a `SQRDMULH`-style fixed-point multiply followed by a correctly-rounding
//! right shift.

use crate::fixedpoint::{multiply_by_quantized_multiplier_signed_shift, srdhm, rounding_div_by_pot};


/// A real multiplier normalized for integer-only application.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantizedMultiplier {
    /// Q0.31 mantissa, `round(2^31 · M0)` with `M0 ∈ [0.5, 1)`; 0 encodes M = 0.
    pub m0: i32,
    /// Total binary exponent: `M = M0 · 2^shift`. Negative for `M < 1`
    /// (the common matmul case, where `shift = -n`), positive allowed for
    /// the Add-layer rescale (App. A.2).
    pub shift: i32,
}

impl QuantizedMultiplier {
    /// Normalize a real multiplier. Requires `m ≥ 0` and
    /// `m < 2^30` (far beyond any multiplier arising from eq. 5).
    pub fn from_f64(m: f64) -> Self {
        assert!(m >= 0.0 && m.is_finite(), "multiplier must be finite and non-negative, got {m}");
        if m == 0.0 {
            return Self { m0: 0, shift: 0 };
        }
        // m = m0 * 2^shift, m0 in [0.5, 1).
        let mut shift = 0i32;
        let mut m0 = m;
        while m0 < 0.5 {
            m0 *= 2.0;
            shift -= 1;
        }
        while m0 >= 1.0 {
            m0 /= 2.0;
            shift += 1;
        }
        if shift < -31 {
            // M < 2^-31 underflows the representable range: |M·acc| < 0.5
            // for every int32 accumulator, so the correctly-rounded result
            // is always 0 — and a right shift this deep would leave the
            // `(0..=31)` domain of `rounding_div_by_pot`, whose
            // release-build `>>` would wrap the shift amount mod 32 and
            // emit garbage (per-channel quantization hits this on
            // near-dead channels, where a channel's tiny `max_abs` makes
            // its eq. 5 multiplier vanish). Flush to the exact encoding of
            // zero.
            return Self { m0: 0, shift: 0 };
        }
        let mut q = (m0 * 2f64.powi(31)).round() as i64;
        // Rounding can push the mantissa to exactly 2^31 (m0 == 1.0 - eps).
        if q == 1i64 << 31 {
            q /= 2;
            shift += 1;
        }
        debug_assert!((1i64 << 30..1i64 << 31).contains(&q));
        Self { m0: q as i32, shift }
    }

    /// The real value this normalized multiplier represents.
    pub fn to_f64(self) -> f64 {
        f64::from(self.m0) / 2f64.powi(31) * 2f64.powi(self.shift)
    }

    /// Apply to an int32 accumulator using only integer arithmetic.
    #[inline]
    pub fn apply(self, acc: i32) -> i32 {
        multiply_by_quantized_multiplier_signed_shift(acc, self.m0, self.shift)
    }

    /// Apply assuming `M < 1` (hot path: avoids the left-shift branch).
    #[inline]
    pub fn apply_lt_one(self, acc: i32) -> i32 {
        debug_assert!(self.shift <= 0, "apply_lt_one requires M < 1");
        rounding_div_by_pot(srdhm(acc, self.m0), -self.shift)
    }
}

/// Normalize the matmul requantization multiplier `M = S1·S2/S3` (eq. 5).
/// The paper observes `M ∈ (0, 1)` empirically; we assert it so a violation
/// (a mis-calibrated output scale) fails loudly at conversion time rather
/// than silently saturating at run time.
pub fn quantize_multiplier(s1: f64, s2: f64, s3: f64) -> QuantizedMultiplier {
    assert!(s1 > 0.0 && s2 > 0.0 && s3 > 0.0, "scales must be positive");
    let m = s1 * s2 / s3;
    assert!(m < 1.0, "requantization multiplier M = {m} >= 1; output scale too small (eq. 5-6)");
    QuantizedMultiplier::from_f64(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_invariants() {
        for &m in &[0.9999, 0.5, 0.25, 0.1, 1e-3, 1e-6, 0.7531, 2.0 / 3.0] {
            let qm = QuantizedMultiplier::from_f64(m);
            assert!(qm.m0 >= 1 << 30, "m0 has >= 30 bits of relative accuracy for m={m}");
            assert!(qm.shift <= 0, "m={m} < 1 must have non-positive shift");
            let rel_err = (qm.to_f64() - m).abs() / m;
            assert!(rel_err < 1e-9, "m={m} rel_err={rel_err}");
        }
    }

    #[test]
    fn multipliers_ge_one_supported_for_add_rescale() {
        for &m in &[1.0, 1.5, 3.75, 100.0] {
            let qm = QuantizedMultiplier::from_f64(m);
            let rel_err = (qm.to_f64() - m).abs() / m;
            assert!(rel_err < 1e-9);
            // application: 1000 * m
            let got = qm.apply(1000);
            assert!((f64::from(got) - 1000.0 * m).abs() <= 1.0, "m={m} got={got}");
        }
    }

    #[test]
    fn zero_multiplier() {
        let qm = QuantizedMultiplier::from_f64(0.0);
        assert_eq!(qm.apply(123456), 0);
    }

    #[test]
    fn underflowing_multipliers_flush_to_exact_zero() {
        // Multipliers below 2^-31 cannot shift within rounding_div_by_pot's
        // (0..=31) domain; they must normalize to the exact zero encoding
        // in debug AND release (release `>>` would otherwise wrap the shift
        // amount mod 32). The correct rounded result is 0 for every
        // accumulator: |M·acc| < 2^-31 · 2^31 / 2 < 0.5.
        for &m in &[2e-10, 1e-10, 1e-20, 1e-300, f64::MIN_POSITIVE] {
            let qm = QuantizedMultiplier::from_f64(m);
            assert_eq!((qm.m0, qm.shift), (0, 0), "m={m}");
            for acc in [i32::MAX, i32::MIN, 1, -1, 0] {
                assert_eq!(qm.apply(acc), 0, "m={m} acc={acc}");
                assert_eq!(qm.apply_lt_one(acc), 0, "m={m} acc={acc}");
            }
            assert_eq!(qm.to_f64(), 0.0, "m={m}");
        }
        // The boundary stays exact: shift == -31 is still representable and
        // must NOT flush (1.5·2^-32 = 0.75·2^-31).
        let qm = QuantizedMultiplier::from_f64(1.5 * 2f64.powi(-32));
        assert_eq!(qm.shift, -31);
        assert!(qm.m0 >= 1 << 30);
        let rel = (qm.to_f64() - 1.5 * 2f64.powi(-32)).abs() / (1.5 * 2f64.powi(-32));
        assert!(rel < 1e-9);
    }

    #[test]
    fn apply_matches_real_arithmetic() {
        // Integer application must be within 1 of round(acc * M) — the
        // paper's ≥30-bit relative accuracy claim.
        let cases = [
            (0.000_316_2, 1_234_567),
            (0.007_812_5, -987_654),
            (0.5, 2_000_000_000),
            (0.999_999, -2_000_000_000),
            (0.123_456_789, 1),
            (0.75, -3),
        ];
        for (m, acc) in cases {
            let qm = QuantizedMultiplier::from_f64(m);
            let got = i64::from(qm.apply(acc));
            let want = (f64::from(acc) * m).round() as i64;
            assert!((got - want).abs() <= 1, "m={m} acc={acc} got={got} want={want}");
        }
    }

    #[test]
    fn apply_lt_one_matches_apply() {
        for &m in &[0.9, 0.5, 0.001, 0.33] {
            let qm = QuantizedMultiplier::from_f64(m);
            for &acc in &[0, 1, -1, 1000, -1000, i32::MAX / 2, i32::MIN / 2] {
                assert_eq!(qm.apply(acc), qm.apply_lt_one(acc), "m={m} acc={acc}");
            }
        }
    }

    #[test]
    fn matmul_multiplier_in_unit_interval() {
        let qm = quantize_multiplier(0.02, 0.05, 0.1);
        assert!((qm.to_f64() - 0.01).abs() < 1e-10);
    }

    #[test]
    #[should_panic(expected = "multiplier M")]
    fn matmul_multiplier_ge_one_panics() {
        let _ = quantize_multiplier(1.0, 1.0, 0.5);
    }

    #[test]
    fn exactly_representable_powers_of_two() {
        let qm = QuantizedMultiplier::from_f64(0.25);
        assert_eq!(qm.apply(1 << 20), 1 << 18);
    }
}
