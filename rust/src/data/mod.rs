//! Deterministic synthetic datasets substituting for the paper's ImageNet /
//! COCO / Flickr-face workloads (DESIGN.md §Substitutions), plus the seeded
//! PRNG everything in the repo uses (the offline build has no `rand`).
//!
//! Three tasks mirror the paper's evaluation settings:
//! * [`ClassificationSet`] — "SynthShapes": multi-class images of rendered
//!   geometric shapes with texture and noise (ImageNet stand-in, §4.1/4.2.1).
//! * [`DetectionSet`] — small bright objects on clutter with SSD-style grid
//!   targets (COCO / face-detection stand-in, §4.2.2/4.2.3).
//! * [`AttributeSet`] — images with binary attributes plus a scalar "age"
//!   target (face-attributes stand-in, §4.2.4, Tables 4.7/4.8).
//!
//! Everything is procedurally generated from a seed: the same (seed, index)
//! always yields the same example, so train/eval splits are exact and the
//! Python (L2) and Rust (L3) sides can generate identical batches.

pub mod synth;

pub use synth::{AttributeSet, ClassificationSet, DetectionSet};

/// PCG32 (PCG-XSH-RR 64/32): small, fast, and good enough for data
/// synthesis and weight init. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Seed with an arbitrary (seed, stream) pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-seed constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform integer in `[0, n)`. `n` must be positive.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((u64::from(self.next_u32()) * n as u64) >> 32) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fill a slice with N(0, stddev²) values (weight init).
    pub fn fill_normal(&mut self, out: &mut [f32], stddev: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * stddev;
        }
    }
}

/// A minimal seeded property-test driver (the offline build has no
/// proptest). Runs `f` against `cases` generated inputs; on failure the
/// panic message carries the case seed so the exact input can be replayed
/// with [`replay`].
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    f: impl Fn(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Rng::seeded(seed);
        let input = gen(&mut rng);
        assert!(
            f(&input),
            "property `{name}` failed on case {case} (replay seed {seed:#x}): {input:?}"
        );
    }
}

/// Re-generate the failing input of a [`check`] run from its seed.
pub fn replay<T>(seed: u64, gen: impl Fn(&mut Rng) -> T) -> T {
    let mut rng = Rng::seeded(seed);
    gen(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn rng_streams_differ() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all 10 buckets should be hit in 1000 draws");
    }

    #[test]
    fn f32_in_unit_interval_with_sane_mean() {
        let mut rng = Rng::seeded(3);
        let mut sum = 0f64;
        for _ in 0..10_000 {
            let v = rng.f32();
            assert!((0.0..1.0).contains(&v));
            sum += f64::from(v);
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Rng::seeded(9);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().map(|&x| f64::from(x)).sum::<f64>() / n as f64;
        let var = xs.iter().map(|&x| (f64::from(x) - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn property_harness_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always_false", 3, |r| r.below(100), |_| false);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
        let v = replay(0x5EED_0000, |r| r.below(100));
        let v2 = replay(0x5EED_0000, |r| r.below(100));
        assert_eq!(v, v2);
    }
}
