//! Procedural image tasks. See the module docs in [`super`] and
//! DESIGN.md §Substitutions for why each stands in for the paper's dataset.

use super::Rng;
use crate::tensor::Tensor;

/// Shape families composing the classification classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ShapeKind {
    Disk,
    Square,
    Cross,
    Stripes,
}

const KINDS: [ShapeKind; 4] = [ShapeKind::Disk, ShapeKind::Square, ShapeKind::Cross, ShapeKind::Stripes];

/// Render one shape into `img` (NHWC with n=0, c channels) centred at
/// (cy, cx) with half-extent `r`, intensity `amp`, rotation `theta`.
#[allow(clippy::too_many_arguments)]
fn render_shape(
    img: &mut Tensor<f32>,
    kind: ShapeKind,
    cy: f32,
    cx: f32,
    r: f32,
    amp: f32,
    theta: f32,
    channel_gains: &[f32],
) {
    let (h, w, c) = (img.dim(1), img.dim(2), img.dim(3));
    let (sin_t, cos_t) = theta.sin_cos();
    for y in 0..h {
        for x in 0..w {
            let dy = y as f32 - cy;
            let dx = x as f32 - cx;
            // Rotate into the shape frame.
            let u = cos_t * dx + sin_t * dy;
            let v = -sin_t * dx + cos_t * dy;
            let inside = match kind {
                ShapeKind::Disk => (u * u + v * v).sqrt() <= r,
                ShapeKind::Square => u.abs() <= r && v.abs() <= r,
                ShapeKind::Cross => {
                    (u.abs() <= r * 0.35 && v.abs() <= r) || (v.abs() <= r * 0.35 && u.abs() <= r)
                }
                ShapeKind::Stripes => {
                    (u * u + v * v).sqrt() <= r && ((v / r * 3.0).floor() as i32).rem_euclid(2) == 0
                }
            };
            if inside {
                for ch in 0..c.min(channel_gains.len()) {
                    let cur = img.at4(0, y, x, ch);
                    img.set4(0, y, x, ch, cur + amp * channel_gains[ch]);
                }
            }
        }
    }
}

fn add_noise(img: &mut Tensor<f32>, rng: &mut Rng, sigma: f32) {
    for v in img.data_mut() {
        *v += rng.normal() * sigma;
    }
}

fn clamp_unit(img: &mut Tensor<f32>) {
    for v in img.data_mut() {
        *v = v.clamp(-1.0, 1.0);
    }
}

/// "SynthShapes" classification (ImageNet stand-in).
///
/// A class is a (shape kind, size bucket, orientation bucket) triple —
/// `4 × 2 × 2 = 16` classes by default. Position, exact size/angle within
/// the bucket, per-channel colour, background gradient and pixel noise are
/// all randomized, so the task needs real feature learning but is solvable
/// by a small CNN.
#[derive(Clone, Debug)]
pub struct ClassificationSet {
    pub resolution: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub seed: u64,
}

impl ClassificationSet {
    pub fn new(resolution: usize, num_classes: usize, seed: u64) -> Self {
        assert!(num_classes <= 16, "at most 16 composable classes");
        assert!(resolution >= 8);
        Self { resolution, channels: 3, num_classes, seed }
    }

    /// Deterministically generate example `index` of split `split`
    /// (0 = train, 1 = eval). Image values are in `[-1, 1]` (the paper's
    /// preprocessing for detection/attributes normalizes to `[-1, 1]`).
    pub fn example(&self, split: u64, index: u64) -> (Tensor<f32>, usize) {
        let mut rng = Rng::new(self.seed ^ (split.wrapping_mul(0x9E37_79B9)), index);
        let label = rng.below(self.num_classes);
        let img = self.render_class(label, &mut rng);
        (img, label)
    }

    fn render_class(&self, label: usize, rng: &mut Rng) -> Tensor<f32> {
        let res = self.resolution;
        let mut img = Tensor::zeros(&[1, res, res, self.channels]);
        // Background: soft gradient + DC offset.
        let gx = rng.range_f32(-0.3, 0.3) / res as f32;
        let gy = rng.range_f32(-0.3, 0.3) / res as f32;
        let dc = rng.range_f32(-0.2, 0.2);
        for y in 0..res {
            for x in 0..res {
                for ch in 0..self.channels {
                    img.set4(0, y, x, ch, dc + gx * x as f32 + gy * y as f32);
                }
            }
        }
        // Class decomposition: kind (low 2 bits), size bucket, angle bucket.
        let kind = KINDS[label % 4];
        let big = (label / 4) % 2 == 1;
        let tilted = (label / 8) % 2 == 1;
        let r_frac = if big { rng.range_f32(0.28, 0.38) } else { rng.range_f32(0.12, 0.2) };
        let r = r_frac * res as f32;
        let theta = if tilted {
            std::f32::consts::FRAC_PI_4 + rng.range_f32(-0.15, 0.15)
        } else {
            rng.range_f32(-0.15, 0.15)
        };
        let cy = rng.range_f32(r + 1.0, res as f32 - r - 1.0);
        let cx = rng.range_f32(r + 1.0, res as f32 - r - 1.0);
        let gains: Vec<f32> = (0..self.channels).map(|_| rng.range_f32(0.5, 1.0)).collect();
        let amp = rng.range_f32(0.6, 0.9) * if rng.bool(0.5) { 1.0 } else { -1.0 };
        render_shape(&mut img, kind, cy, cx, r, amp, theta, &gains);
        add_noise(&mut img, rng, 0.08);
        clamp_unit(&mut img);
        img
    }

    /// A batch as one NHWC tensor plus labels.
    pub fn batch(&self, split: u64, start: u64, batch: usize) -> (Tensor<f32>, Vec<usize>) {
        let res = self.resolution;
        let mut out = Tensor::zeros(&[batch, res, res, self.channels]);
        let mut labels = Vec::with_capacity(batch);
        for b in 0..batch {
            let (img, label) = self.example(split, start + b as u64);
            let stride = res * res * self.channels;
            out.data_mut()[b * stride..(b + 1) * stride].copy_from_slice(img.data());
            labels.push(label);
        }
        (out, labels)
    }
}

/// A ground-truth box in pixel coordinates (y0, x0, y1, x1) with a class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GtBox {
    pub y0: f32,
    pub x0: f32,
    pub y1: f32,
    pub x1: f32,
    pub class: usize,
}

impl GtBox {
    /// Intersection-over-union with another box.
    pub fn iou(&self, o: &GtBox) -> f32 {
        let iy0 = self.y0.max(o.y0);
        let ix0 = self.x0.max(o.x0);
        let iy1 = self.y1.min(o.y1);
        let ix1 = self.x1.min(o.x1);
        let inter = (iy1 - iy0).max(0.0) * (ix1 - ix0).max(0.0);
        let a = (self.y1 - self.y0) * (self.x1 - self.x0);
        let b = (o.y1 - o.y0) * (o.x1 - o.x0);
        if inter <= 0.0 {
            0.0
        } else {
            inter / (a + b - inter)
        }
    }
}

/// Single-shot detection set (COCO / face-detection stand-in): 1–3 shapes
/// ("objects") of distinct classes on a cluttered background. Targets are an
/// SSD-style `G×G` grid: per cell (objectness, class, dy, dx, log dh, log dw)
/// with the object assigned to the cell containing its centre.
#[derive(Clone, Debug)]
pub struct DetectionSet {
    pub resolution: usize,
    pub grid: usize,
    pub num_classes: usize,
    pub seed: u64,
}

impl DetectionSet {
    pub fn new(resolution: usize, grid: usize, num_classes: usize, seed: u64) -> Self {
        assert!(resolution % grid == 0, "grid must divide resolution");
        assert!(num_classes <= 4);
        Self { resolution, grid, num_classes, seed }
    }

    /// Generate example `index`: image in `[-1,1]` plus ground-truth boxes.
    pub fn example(&self, split: u64, index: u64) -> (Tensor<f32>, Vec<GtBox>) {
        let mut rng = Rng::new(self.seed ^ (0xDE7E_C7 + split * 0x9E37_79B9), index);
        let res = self.resolution;
        let mut img = Tensor::zeros(&[1, res, res, 3]);
        // Clutter: low-amplitude random blobs.
        for _ in 0..4 {
            let r = rng.range_f32(2.0, res as f32 * 0.15);
            let cy = rng.range_f32(0.0, res as f32);
            let cx = rng.range_f32(0.0, res as f32);
            let gains = [rng.range_f32(0.2, 0.5); 3];
            render_shape(&mut img, ShapeKind::Disk, cy, cx, r, rng.range_f32(-0.25, 0.25), 0.0, &gains);
        }
        let count = 1 + rng.below(3);
        let mut boxes: Vec<GtBox> = Vec::new();
        for _ in 0..count {
            let class = rng.below(self.num_classes);
            let r = rng.range_f32(res as f32 * 0.08, res as f32 * 0.18);
            let cy = rng.range_f32(r + 1.0, res as f32 - r - 1.0);
            let cx = rng.range_f32(r + 1.0, res as f32 - r - 1.0);
            let candidate = GtBox { y0: cy - r, x0: cx - r, y1: cy + r, x1: cx + r, class };
            // Avoid heavy overlap so the grid assignment stays unambiguous.
            if boxes.iter().any(|b| b.iou(&candidate) > 0.2) {
                continue;
            }
            let gains = [1.0, 0.9, 0.8];
            render_shape(&mut img, KINDS[class % 4], cy, cx, r, 0.9, 0.0, &gains);
            boxes.push(candidate);
        }
        add_noise(&mut img, &mut rng, 0.06);
        clamp_unit(&mut img);
        (img, boxes)
    }

    /// Encode ground truth boxes into the SSD grid target tensor
    /// `[1, G, G, 5 + num_classes]`: (objectness, dy, dx, log h, log w,
    /// one-hot class).
    pub fn encode_targets(&self, boxes: &[GtBox]) -> Tensor<f32> {
        let g = self.grid;
        let cell = (self.resolution / self.grid) as f32;
        let mut t = Tensor::zeros(&[1, g, g, 5 + self.num_classes]);
        for b in boxes {
            let cy = (b.y0 + b.y1) / 2.0;
            let cx = (b.x0 + b.x1) / 2.0;
            let gy = ((cy / cell) as usize).min(g - 1);
            let gx = ((cx / cell) as usize).min(g - 1);
            t.set4(0, gy, gx, 0, 1.0);
            t.set4(0, gy, gx, 1, cy / cell - gy as f32 - 0.5);
            t.set4(0, gy, gx, 2, cx / cell - gx as f32 - 0.5);
            t.set4(0, gy, gx, 3, ((b.y1 - b.y0) / cell).ln());
            t.set4(0, gy, gx, 4, ((b.x1 - b.x0) / cell).ln());
            t.set4(0, gy, gx, 5 + b.class, 1.0);
        }
        t
    }

    /// Decode a prediction tensor `[1, G, G, 5 + C]` back into boxes with
    /// scores above `threshold` (sigmoid applied to objectness logit).
    pub fn decode_predictions(&self, pred: &Tensor<f32>, threshold: f32) -> Vec<(GtBox, f32)> {
        let g = self.grid;
        let cell = (self.resolution / self.grid) as f32;
        let mut out = Vec::new();
        for gy in 0..g {
            for gx in 0..g {
                let obj = 1.0 / (1.0 + (-pred.at4(0, gy, gx, 0)).exp());
                if obj < threshold {
                    continue;
                }
                let cy = (gy as f32 + 0.5 + pred.at4(0, gy, gx, 1)) * cell;
                let cx = (gx as f32 + 0.5 + pred.at4(0, gy, gx, 2)) * cell;
                let hh = pred.at4(0, gy, gx, 3).exp() * cell;
                let ww = pred.at4(0, gy, gx, 4).exp() * cell;
                let mut best = 0;
                let mut best_v = f32::NEG_INFINITY;
                for c in 0..self.num_classes {
                    let v = pred.at4(0, gy, gx, 5 + c);
                    if v > best_v {
                        best_v = v;
                        best = c;
                    }
                }
                out.push((
                    GtBox { y0: cy - hh / 2.0, x0: cx - ww / 2.0, y1: cy + hh / 2.0, x1: cx + ww / 2.0, class: best },
                    obj,
                ));
            }
        }
        out
    }
}

/// Attribute task (face-attributes stand-in): each image has one object;
/// binary attributes are properties of it, "age" is its radius in pixels.
///
/// Attributes: 0 = is-bright, 1 = is-round (disk vs square), 2 = is-tilted,
/// 3 = is-large. Age target = radius (a real value the paper's Table 4.8
/// "age precision at 5 years" metric maps onto as radius-within-Δ).
#[derive(Clone, Debug)]
pub struct AttributeSet {
    pub resolution: usize,
    pub seed: u64,
}

pub const NUM_ATTRIBUTES: usize = 4;

impl AttributeSet {
    pub fn new(resolution: usize, seed: u64) -> Self {
        Self { resolution, seed }
    }

    /// (image in [-1,1], binary attributes, age scalar).
    pub fn example(&self, split: u64, index: u64) -> (Tensor<f32>, [bool; NUM_ATTRIBUTES], f32) {
        let mut rng = Rng::new(self.seed ^ (0xA77E + split * 0x9E37_79B9), index);
        let res = self.resolution;
        let bright = rng.bool(0.5);
        let round = rng.bool(0.5);
        let tilted = rng.bool(0.5);
        let large = rng.bool(0.5);
        let r = if large {
            rng.range_f32(res as f32 * 0.25, res as f32 * 0.4)
        } else {
            rng.range_f32(res as f32 * 0.1, res as f32 * 0.2)
        };
        let mut img = Tensor::zeros(&[1, res, res, 3]);
        let cy = rng.range_f32(r + 1.0, res as f32 - r - 1.0);
        let cx = rng.range_f32(r + 1.0, res as f32 - r - 1.0);
        let amp = if bright { rng.range_f32(0.7, 0.95) } else { rng.range_f32(0.25, 0.45) };
        let theta = if tilted { std::f32::consts::FRAC_PI_4 } else { 0.0 };
        let kind = if round { ShapeKind::Disk } else { ShapeKind::Square };
        render_shape(&mut img, kind, cy, cx, r, amp, theta, &[1.0, 1.0, 1.0]);
        add_noise(&mut img, &mut rng, 0.05);
        clamp_unit(&mut img);
        (img, [bright, round, tilted, large], r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_is_deterministic() {
        let ds = ClassificationSet::new(16, 16, 99);
        let (a, la) = ds.example(0, 7);
        let (b, lb) = ds.example(0, 7);
        assert_eq!(la, lb);
        assert_eq!(a.data(), b.data());
        let (c, _) = ds.example(1, 7); // different split differs
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn classification_values_in_range_and_informative() {
        let ds = ClassificationSet::new(16, 16, 1);
        for i in 0..8 {
            let (img, label) = ds.example(0, i);
            assert!(label < 16);
            let (mn, mx) = img.min_max();
            assert!(mn >= -1.0 && mx <= 1.0);
            assert!(mx - mn > 0.3, "image {i} should have contrast, got range {mn}..{mx}");
        }
    }

    #[test]
    fn classification_labels_cover_all_classes() {
        let ds = ClassificationSet::new(8, 16, 5);
        let mut seen = [false; 16];
        for i in 0..400 {
            let (_, l) = ds.example(0, i);
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn batch_concatenates_examples() {
        let ds = ClassificationSet::new(8, 4, 2);
        let (batch, labels) = ds.batch(0, 10, 3);
        assert_eq!(batch.shape(), &[3, 8, 8, 3]);
        assert_eq!(labels.len(), 3);
        let (single, l0) = ds.example(0, 10);
        assert_eq!(&batch.data()[..single.len()], single.data());
        assert_eq!(labels[0], l0);
    }

    #[test]
    fn iou_properties() {
        let a = GtBox { y0: 0.0, x0: 0.0, y1: 10.0, x1: 10.0, class: 0 };
        assert!((a.iou(&a) - 1.0).abs() < 1e-6);
        let b = GtBox { y0: 20.0, x0: 20.0, y1: 30.0, x1: 30.0, class: 0 };
        assert_eq!(a.iou(&b), 0.0);
        let c = GtBox { y0: 0.0, x0: 5.0, y1: 10.0, x1: 15.0, class: 0 };
        assert!((a.iou(&c) - 1.0 / 3.0).abs() < 1e-6);
        // Symmetry.
        assert_eq!(a.iou(&c), c.iou(&a));
    }

    #[test]
    fn detection_targets_roundtrip() {
        let ds = DetectionSet::new(32, 4, 3, 11);
        for i in 0..10 {
            let (_, boxes) = ds.example(0, i);
            assert!(!boxes.is_empty() && boxes.len() <= 3);
            let t = ds.encode_targets(&boxes);
            // Perfect predictions (logit +inf ~ 10) must decode back to the
            // encoded boxes with IoU ~1.
            let mut pred = t.clone();
            for gy in 0..4 {
                for gx in 0..4 {
                    let obj = pred.at4(0, gy, gx, 0);
                    pred.set4(0, gy, gx, 0, if obj > 0.5 { 10.0 } else { -10.0 });
                }
            }
            let decoded = ds.decode_predictions(&pred, 0.5);
            assert_eq!(decoded.len(), boxes.len(), "example {i}");
            for b in &boxes {
                let best = decoded.iter().map(|(d, _)| d.iou(b)).fold(0.0f32, f32::max);
                assert!(best > 0.95, "example {i}: box not recovered, best IoU {best}");
            }
        }
    }

    #[test]
    fn detection_grid_cells_unique_per_box() {
        let ds = DetectionSet::new(32, 4, 3, 13);
        for i in 0..20 {
            let (_, boxes) = ds.example(0, i);
            let t = ds.encode_targets(&boxes);
            let cells: usize = (0..4)
                .flat_map(|gy| (0..4).map(move |gx| (gy, gx)))
                .filter(|&(gy, gx)| t.at4(0, gy, gx, 0) > 0.5)
                .count();
            assert!(cells >= 1);
        }
    }

    #[test]
    fn attributes_deterministic_and_consistent() {
        let ds = AttributeSet::new(16, 3);
        let (img1, attrs1, age1) = ds.example(0, 5);
        let (img2, attrs2, age2) = ds.example(0, 5);
        assert_eq!(img1.data(), img2.data());
        assert_eq!(attrs1, attrs2);
        assert_eq!(age1, age2);
        // Age correlates with the "large" attribute by construction.
        let mut large_ages = vec![];
        let mut small_ages = vec![];
        for i in 0..100 {
            let (_, attrs, age) = ds.example(0, i);
            if attrs[3] {
                large_ages.push(age);
            } else {
                small_ages.push(age);
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean(&large_ages) > mean(&small_ages));
    }
}
