//! Elementwise layers: Addition (App. A.2) and Concatenation (App. A.3).
//!
//! **Addition** needs *rescaling*: the two inputs generally carry different
//! scales, so each `(q − Z)` is brought onto a common high-precision scale
//! with a fixed-point multiply before the integer add, and the sum is
//! rescaled once more onto the output's scale — "more expensive in
//! quantized inference compared to floating-point" exactly as App. A.2
//! notes.
//!
//! **Concatenation** is required to be lossless: instead of rescaling uint8
//! values (lossy), the converter forces all inputs and the output of a
//! concat to share one set of quantization parameters, making the op free
//! of arithmetic (App. A.3). [`qconcat`] asserts that contract.

use crate::gemm::ResidualAdd;
use crate::nn::QTensor;
use crate::quant::QuantParams;
use crate::tensor::Tensor;

/// Structured report of an Add whose operands disagree on shape. Raised by
/// [`try_qadd_into`] *before* any output is touched — previously a
/// mismatched pair could only fail as a deep slice-index panic partway
/// through the elementwise loop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AddShapeError {
    /// Shape of the left (primary) operand.
    pub lhs: Vec<usize>,
    /// Shape of the right (residual) operand.
    pub rhs: Vec<usize>,
}

impl std::fmt::Display for AddShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "add operands must have equal shapes: lhs {:?} vs rhs {:?}", self.lhs, self.rhs)
    }
}

impl std::error::Error for AddShapeError {}

/// Quantized elementwise addition with rescaling (App. A.2).
pub fn qadd(a: &QTensor, b: &QTensor, out_params: QuantParams) -> QTensor {
    let mut out = QTensor::default();
    qadd_into(a, b, out_params, &mut out);
    out
}

/// [`qadd`] into a reusable output (the prepared path's zero-alloc steady
/// state). Panics on shape mismatch; use [`try_qadd_into`] to get the
/// structured [`AddShapeError`] instead.
pub fn qadd_into(a: &QTensor, b: &QTensor, out_params: QuantParams, dst: &mut QTensor) {
    if let Err(e) = try_qadd_into(a, b, out_params, dst) {
        panic!("{e}");
    }
}

/// [`qadd_into`] with up-front operand validation: a shape mismatch is
/// reported as a structured error with both shapes, and `dst` is left
/// untouched.
///
/// The arithmetic delegates to [`ResidualAdd`] — the exact epilogue the
/// prepare-time fusion pass folds into the GEMM output stage — so the
/// standalone pass and the fused path are bit-identical by construction.
pub fn try_qadd_into(
    a: &QTensor,
    b: &QTensor,
    out_params: QuantParams,
    dst: &mut QTensor,
) -> Result<(), AddShapeError> {
    if a.shape() != b.shape() {
        return Err(AddShapeError { lhs: a.shape().to_vec(), rhs: b.shape().to_vec() });
    }
    let r = ResidualAdd::for_params(a.params, b.params, out_params);
    dst.params = out_params;
    // Safe: the loop below writes every output element.
    dst.data.reset_for_overwrite(a.shape());
    for ((o, &qa), &qb) in dst.data.data_mut().iter_mut().zip(a.data.data()).zip(b.data.data()) {
        *o = r.apply(qa, qb);
    }
    Ok(())
}

/// Quantized concatenation along the channel (last) axis. All inputs and the
/// output must share quantization parameters (App. A.3) — enforced here.
pub fn qconcat(inputs: &[&QTensor], out_params: QuantParams) -> QTensor {
    let mut out = QTensor::default();
    qconcat_into(inputs, out_params, &mut out);
    out
}

/// [`qconcat`] into a reusable output.
pub fn qconcat_into(inputs: &[&QTensor], out_params: QuantParams, dst: &mut QTensor) {
    qconcat_into_indexed(inputs.len(), |i| inputs[i], out_params, dst);
}

/// [`qconcat_into`] with operands fetched by index instead of gathered into
/// a slice: the prepared graph executor resolves each operand straight out
/// of its node-output slots, so the concat path performs **zero heap
/// allocations** in steady state (no short-lived operand-ref `Vec`; the
/// output shape reuses the destination's shape buffer).
pub fn qconcat_into_indexed<'a>(
    count: usize,
    get: impl Fn(usize) -> &'a QTensor,
    out_params: QuantParams,
    dst: &mut QTensor,
) {
    assert!(count > 0);
    let first = get(0);
    let rank = first.data.rank();
    let mut c_total = 0usize;
    for i in 0..count {
        let t = get(i);
        assert_eq!(
            (t.params.scale, t.params.zero_point),
            (out_params.scale, out_params.zero_point),
            "concat requires identical quantization parameters on every operand (App. A.3)"
        );
        assert_eq!(t.data.rank(), rank);
        assert_eq!(t.shape()[..rank - 1], first.shape()[..rank - 1], "leading dims must match");
        c_total += t.shape()[rank - 1];
    }
    let lead: usize = first.shape()[..rank - 1].iter().product();
    dst.params = out_params;
    // Safe: every row copies its full span of c_total channels.
    dst.data.reset_for_overwrite_last_dim(first.shape(), c_total);
    let data = dst.data.data_mut();
    for row in 0..lead {
        let mut off = 0;
        for i in 0..count {
            let t = get(i);
            let c = t.shape()[rank - 1];
            data[row * c_total + off..row * c_total + off + c]
                .copy_from_slice(&t.data.data()[row * c..(row + 1) * c]);
            off += c;
        }
    }
}

/// Float reference add.
pub fn add_f32(a: &Tensor<f32>, b: &Tensor<f32>) -> Tensor<f32> {
    assert_eq!(a.shape(), b.shape());
    let data = a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect();
    Tensor::from_vec(a.shape(), data)
}

/// Float reference channel concat.
pub fn concat_f32(inputs: &[&Tensor<f32>]) -> Tensor<f32> {
    let rank = inputs[0].rank();
    let lead: usize = inputs[0].shape()[..rank - 1].iter().product();
    let c_total: usize = inputs.iter().map(|t| t.shape()[rank - 1]).sum();
    let mut shape = inputs[0].shape().to_vec();
    shape[rank - 1] = c_total;
    let mut data = vec![0f32; lead * c_total];
    for row in 0..lead {
        let mut off = 0;
        for t in inputs {
            let c = t.shape()[rank - 1];
            data[row * c_total + off..row * c_total + off + c]
                .copy_from_slice(&t.data()[row * c..(row + 1) * c]);
            off += c;
        }
    }
    Tensor::from_vec(&shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn qadd_tracks_float_add_across_mismatched_scales() {
        let mut rng = Rng::seeded(77);
        let pa = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let pb = QuantParams::from_min_max(-4.0, 2.0, 0, 255); // different scale
        let po = QuantParams::from_min_max(-5.0, 3.0, 0, 255);
        let mut av = vec![0f32; 64];
        let mut bv = vec![0f32; 64];
        for v in av.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        for v in bv.iter_mut() {
            *v = rng.range_f32(-4.0, 2.0);
        }
        let at = Tensor::from_vec(&[1, 4, 4, 4], av);
        let bt = Tensor::from_vec(&[1, 4, 4, 4], bv);
        let qa = QTensor::quantize(&at, pa);
        let qb = QTensor::quantize(&bt, pb);
        let got = qadd(&qa, &qb, po).dequantize();
        let want = add_f32(&qa.dequantize(), &qb.dequantize());
        // One output LSB plus the two rescale roundings.
        let tol = (po.scale * 1.5) as f32;
        assert!(want.max_abs_diff(&got) <= tol, "diff {}", want.max_abs_diff(&got));
    }

    #[test]
    fn qadd_saturates_gracefully() {
        let p = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let po = QuantParams::from_min_max(-0.5, 0.5, 0, 255); // output too narrow
        let ones = Tensor::from_vec(&[4], vec![1.0f32; 4]);
        let qa = QTensor::quantize(&ones, p);
        let out = qadd(&qa, &qa, po); // real sum 2.0 ≫ 0.5
        for &q in out.data.data() {
            assert_eq!(q, 255, "must clamp at qmax");
        }
    }

    #[test]
    fn try_qadd_reports_shape_mismatch_without_touching_dst() {
        let p = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let a = QTensor::real_zeros(&[1, 2, 2, 3], p);
        let b = QTensor::real_zeros(&[1, 2, 3, 2], p);
        let mut dst = QTensor::real_zeros(&[7], p);
        let err = try_qadd_into(&a, &b, p, &mut dst).unwrap_err();
        assert_eq!(err.lhs, vec![1, 2, 2, 3]);
        assert_eq!(err.rhs, vec![1, 2, 3, 2]);
        assert!(err.to_string().contains("equal shapes"), "{err}");
        // The destination must be exactly as it was: validation runs
        // before any write (previously this failed as a slice-index panic
        // mid-loop, after clobbering a prefix of dst).
        assert_eq!(dst.shape(), &[7]);
    }

    #[test]
    fn try_qadd_matches_qadd_on_valid_operands() {
        let p = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let po = QuantParams::from_min_max(-2.0, 2.0, 0, 255);
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![0.25f32, -0.5, 0.75, 0.0]);
        let qa = QTensor::quantize(&x, p);
        let want = qadd(&qa, &qa, po);
        let mut got = QTensor::default();
        try_qadd_into(&qa, &qa, po, &mut got).unwrap();
        assert_eq!(want.data.data(), got.data.data());
    }

    #[test]
    #[should_panic(expected = "add operands must have equal shapes")]
    fn qadd_into_panics_with_both_shapes_in_message() {
        let p = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let a = QTensor::real_zeros(&[2, 3], p);
        let b = QTensor::real_zeros(&[3, 2], p);
        let mut dst = QTensor::default();
        qadd_into(&a, &b, p, &mut dst);
    }

    #[test]
    fn qconcat_is_lossless() {
        let p = QuantParams::from_min_max(-2.0, 2.0, 0, 255);
        let a = QTensor::quantize(&Tensor::from_vec(&[1, 2, 2, 2], vec![0.1f32; 8]), p);
        let b = QTensor::quantize(&Tensor::from_vec(&[1, 2, 2, 3], vec![-0.7f32; 12]), p);
        let out = qconcat(&[&a, &b], p);
        assert_eq!(out.shape(), &[1, 2, 2, 5]);
        // Bit-exact copies: concat performs no arithmetic.
        for row in 0..4 {
            assert_eq!(&out.data.data()[row * 5..row * 5 + 2], &a.data.data()[row * 2..row * 2 + 2]);
            assert_eq!(&out.data.data()[row * 5 + 2..row * 5 + 5], &b.data.data()[row * 3..row * 3 + 3]);
        }
    }

    #[test]
    #[should_panic(expected = "identical quantization parameters")]
    fn qconcat_rejects_mismatched_params() {
        let p1 = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let p2 = QuantParams::from_min_max(-2.0, 2.0, 0, 255);
        let a = QTensor::real_zeros(&[1, 1, 1, 2], p1);
        let b = QTensor::real_zeros(&[1, 1, 1, 2], p2);
        let _ = qconcat(&[&a, &b], p1);
    }

    #[test]
    fn concat_f32_matches_layout() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0f32, 2.0]);
        let b = Tensor::from_vec(&[1, 1, 2, 2], vec![3.0f32, 4.0, 5.0, 6.0]);
        let out = concat_f32(&[&a, &b]);
        assert_eq!(out.shape(), &[1, 1, 2, 3]);
        assert_eq!(out.data(), &[1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
    }
}
