//! Depthwise 2-D convolution — the workhorse of MobileNets (§4.2) and of
//! the separable SSD prediction layers the paper swaps in for COCO (§4.2.2).
//!
//! Each input channel is convolved with its own `KH×KW` filter; there is no
//! cross-channel reduction, so the op is computed directly (im2col would
//! build a block-diagonal matrix of zeros). The integer arithmetic per
//! output value is exactly the fused-layer recipe of §2.4: int32 accumulate
//! of `(q_w − Z_w)(q_x − Z_x)`, int32 bias, fixed-point requantize,
//! saturate, clamp.

use crate::gemm::output::{OutputStage, Requant};
use crate::gemm::prepared::grow;
use crate::nn::{conv::apply_activation_f32, FusedActivation, LayerScratch, Padding, QTensor};
use crate::quant::{QuantParams, WeightQuant};
use crate::tensor::Tensor;

/// Fused quantized depthwise convolution (channel multiplier 1).
#[derive(Clone, Debug)]
pub struct QDepthwiseConv2d {
    /// Weights `[1, KH, KW, C]` (TFLite depthwise layout, multiplier 1).
    pub weights: Tensor<u8>,
    /// Weight quantization; depthwise is where per-channel scales
    /// ([`WeightQuant::PerChannel`], channel = innermost axis) recover the
    /// most accuracy, since BN folding spreads channel ranges widely.
    pub weight_quant: WeightQuant,
    /// Per-channel int32 bias (eq. 11), empty = none.
    pub bias: Vec<i32>,
    pub stride: usize,
    pub padding: Padding,
    pub input_params: QuantParams,
    pub output_params: QuantParams,
    pub activation: FusedActivation,
}

impl QDepthwiseConv2d {
    fn stage(&self) -> OutputStage {
        // Depthwise "rows" are the channels themselves: requantize_one is
        // called with the channel index, so the per-channel multiplier
        // vector is indexed exactly like the conv GEMM's output rows.
        let multiplier = Requant::for_weights(
            &self.weight_quant,
            self.input_params.scale,
            self.output_params.scale,
            self.weights.dim(3),
        );
        let (clamp_min, clamp_max) = self
            .activation
            .clamp_bounds(self.output_params.scale, self.output_params.zero_point);
        OutputStage {
            bias: vec![], // applied per-channel inline below
            multiplier,
            out_zero: self.output_params.zero_point,
            clamp_min,
            clamp_max,
        }
    }

    pub fn run(&self, input: &QTensor) -> QTensor {
        let x = &input.data;
        let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let (kh, kw) = (self.weights.dim(1), self.weights.dim(2));
        assert_eq!(self.weights.dim(3), c, "depthwise channel mismatch");
        let (oh, pad_h) = self.padding.resolve(ih, kh, self.stride);
        let (ow, pad_w) = self.padding.resolve(iw, kw, self.stride);
        let zw = self.weight_quant.zero_point();
        let zx = self.input_params.zero_point;
        let stage = self.stage();
        let xd = x.data();
        // Channel-innermost schedule: pre-centre the weights once, then for
        // each output pixel accumulate tap-by-tap over the contiguous
        // channel vector — LLVM vectorizes the per-channel loops (the
        // original per-channel tap loop was the engine's top bottleneck
        // after the GEMM pass; EXPERIMENTS.md §Perf).
        let w_centered: Vec<i32> =
            self.weights.data().iter().map(|&w| i32::from(w) - zw).collect();

        let mut out = Tensor::zeros(&[batch, oh, ow, c]);
        let od = out.data_mut();
        let mut acc = vec![0i32; c];
        for b in 0..batch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let obase = ((b * oh + oy) * ow + ox) * c;
                    if self.bias.is_empty() {
                        acc.fill(0);
                    } else {
                        acc.copy_from_slice(&self.bias);
                    }
                    for ky in 0..kh {
                        let y = (oy * self.stride + ky) as isize - pad_h as isize;
                        if y < 0 || y >= ih as isize {
                            continue; // padded taps contribute (Z_x − Z_x)·w = 0
                        }
                        for kx in 0..kw {
                            let xx = (ox * self.stride + kx) as isize - pad_w as isize;
                            if xx < 0 || xx >= iw as isize {
                                continue;
                            }
                            let wrow = &w_centered[(ky * kw + kx) * c..(ky * kw + kx) * c + c];
                            let xbase = ((b * ih + y as usize) * iw + xx as usize) * c;
                            let xrow = &xd[xbase..xbase + c];
                            for ch in 0..c {
                                acc[ch] += wrow[ch] * (i32::from(xrow[ch]) - zx);
                            }
                        }
                    }
                    for ch in 0..c {
                        od[obase + ch] = stage.requantize_one(ch, acc[ch]);
                    }
                }
            }
        }
        QTensor { data: out, params: self.output_params }
    }

    /// Build the prepared plan: weights pre-centred once, the output stage
    /// built once. Depthwise has no GEMM, so "packing" is the `(q_w − Z_w)`
    /// recentre the unprepared path redoes every call.
    pub fn prepare(&self) -> PreparedDepthwiseConv2d {
        let zw = self.weight_quant.zero_point();
        PreparedDepthwiseConv2d {
            w_centered: self.weights.data().iter().map(|&w| i32::from(w) - zw).collect(),
            bias: self.bias.clone(),
            stage: self.stage(),
            kh: self.weights.dim(1),
            kw: self.weights.dim(2),
            c: self.weights.dim(3),
            stride: self.stride,
            padding: self.padding,
            input_zero: self.input_params.zero_point,
            output_params: self.output_params,
        }
    }
}

/// A [`QDepthwiseConv2d`] with the weight recentre and output stage hoisted
/// out of the request path; `run_into` is allocation-free once warmed up and
/// bit-identical to [`QDepthwiseConv2d::run`].
#[derive(Clone, Debug)]
pub struct PreparedDepthwiseConv2d {
    /// `(q_w − Z_w)` per tap, the per-call recentre of the unprepared path.
    w_centered: Vec<i32>,
    bias: Vec<i32>,
    /// Bias-free stage; the per-channel bias is seeded into the
    /// accumulators directly (same as the unprepared path).
    stage: OutputStage,
    kh: usize,
    kw: usize,
    c: usize,
    stride: usize,
    padding: Padding,
    input_zero: i32,
    output_params: QuantParams,
}

impl PreparedDepthwiseConv2d {
    /// Run the layer, writing the NHWC result into `out` (reshaped in
    /// place, allocation reused).
    pub fn run_into(&self, input: &QTensor, out: &mut QTensor, scratch: &mut LayerScratch) {
        assert_eq!(
            input.params.zero_point, self.input_zero,
            "input must be quantized with the layer's input params"
        );
        let x = &input.data;
        let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        assert_eq!(c, self.c, "depthwise channel mismatch");
        let (oh, pad_h) = self.padding.resolve(ih, self.kh, self.stride);
        let (ow, pad_w) = self.padding.resolve(iw, self.kw, self.stride);
        let zx = self.input_zero;
        let xd = x.data();

        out.params = self.output_params;
        // Safe: the loop below requantizes into every output element.
        out.data.reset_for_overwrite(&[batch, oh, ow, c]);
        let od = out.data.data_mut();
        let acc = grow(&mut scratch.acc32, c);
        for b in 0..batch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let obase = ((b * oh + oy) * ow + ox) * c;
                    if self.bias.is_empty() {
                        acc.fill(0);
                    } else {
                        acc.copy_from_slice(&self.bias);
                    }
                    for ky in 0..self.kh {
                        let y = (oy * self.stride + ky) as isize - pad_h as isize;
                        if y < 0 || y >= ih as isize {
                            continue; // padded taps contribute (Z_x − Z_x)·w = 0
                        }
                        for kx in 0..self.kw {
                            let xx = (ox * self.stride + kx) as isize - pad_w as isize;
                            if xx < 0 || xx >= iw as isize {
                                continue;
                            }
                            let wrow = &self.w_centered
                                [(ky * self.kw + kx) * c..(ky * self.kw + kx) * c + c];
                            let xbase = ((b * ih + y as usize) * iw + xx as usize) * c;
                            let xrow = &xd[xbase..xbase + c];
                            for ch in 0..c {
                                acc[ch] += wrow[ch] * (i32::from(xrow[ch]) - zx);
                            }
                        }
                    }
                    for ch in 0..c {
                        od[obase + ch] = self.stage.requantize_one(ch, acc[ch]);
                    }
                }
            }
        }
    }
}

/// Float reference depthwise convolution.
#[derive(Clone, Debug)]
pub struct DepthwiseConv2d {
    pub weights: Tensor<f32>,
    pub bias: Vec<f32>,
    pub stride: usize,
    pub padding: Padding,
    pub activation: FusedActivation,
}

impl DepthwiseConv2d {
    pub fn run(&self, x: &Tensor<f32>) -> Tensor<f32> {
        let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let (kh, kw) = (self.weights.dim(1), self.weights.dim(2));
        assert_eq!(self.weights.dim(3), c);
        let (oh, pad_h) = self.padding.resolve(ih, kh, self.stride);
        let (ow, pad_w) = self.padding.resolve(iw, kw, self.stride);
        let wd = self.weights.data();
        let mut out = Tensor::zeros(&[batch, oh, ow, c]);
        for b in 0..batch {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ch in 0..c {
                        let mut acc = if self.bias.is_empty() { 0.0 } else { self.bias[ch] };
                        for ky in 0..kh {
                            let y = (oy * self.stride + ky) as isize - pad_h as isize;
                            if y < 0 || y >= ih as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let xx = (ox * self.stride + kx) as isize - pad_w as isize;
                                if xx < 0 || xx >= iw as isize {
                                    continue;
                                }
                                acc += x.at4(b, y as usize, xx as usize, ch)
                                    * wd[(ky * kw + kx) * c + ch];
                            }
                        }
                        out.set4(b, oy, ox, ch, apply_activation_f32(acc, self.activation));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn make_pair(rng: &mut Rng, c: usize, stride: usize, act: FusedActivation) -> (DepthwiseConv2d, QDepthwiseConv2d) {
        let mut w = vec![0f32; 9 * c];
        rng.fill_normal(&mut w, 0.4);
        let bias: Vec<f32> = (0..c).map(|_| rng.range_f32(-0.3, 0.3)).collect();
        let fl = DepthwiseConv2d {
            weights: Tensor::from_vec(&[1, 3, 3, c], w),
            bias,
            stride,
            padding: Padding::Same,
            activation: act,
        };
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let wp = QuantParams::for_weights(fl.weights.data(), 8);
        let bp = QuantParams::for_bias(&wp, &ip);
        let ql = QDepthwiseConv2d {
            weights: fl.weights.map(|v| wp.quantize(v) as u8),
            weight_quant: WeightQuant::PerTensor(wp),
            bias: bp.quantize_bias_slice(&fl.bias),
            stride,
            padding: Padding::Same,
            input_params: ip,
            output_params: QuantParams::from_min_max(-4.0, 4.0, 0, 255),
            activation: act,
        };
        (fl, ql)
    }

    /// Per-channel twin of the layer built from the same float weights,
    /// using the depthwise (innermost-channel) axis.
    fn per_channel_twin(fl: &DepthwiseConv2d, ql: &QDepthwiseConv2d) -> QDepthwiseConv2d {
        use crate::quant::{ChannelAxis, ChannelQuantParams};
        let c = fl.weights.dim(3);
        let cq = ChannelQuantParams::for_weights(fl.weights.data(), c, ChannelAxis::Inner, 8);
        QDepthwiseConv2d {
            weights: Tensor::from_vec(
                fl.weights.shape(),
                cq.quantize_slice(fl.weights.data(), ChannelAxis::Inner),
            ),
            bias: cq.quantize_bias(&fl.bias, ql.input_params.scale),
            weight_quant: WeightQuant::PerChannel(cq),
            ..ql.clone()
        }
    }

    #[test]
    fn quantized_depthwise_tracks_float() {
        let mut rng = Rng::seeded(31);
        for (stride, act) in [(1, FusedActivation::None), (2, FusedActivation::Relu6)] {
            let (fl, ql) = make_pair(&mut rng, 6, stride, act);
            let mut xd = vec![0f32; 2 * 8 * 8 * 6];
            for v in xd.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            let x = Tensor::from_vec(&[2, 8, 8, 6], xd);
            let want = fl.run(&x);
            let qx = QTensor::quantize(&x, ql.input_params);
            let got = ql.run(&qx).dequantize();
            let tol = (ql.output_params.scale * 3.0) as f32 + 0.02;
            let diff = want.max_abs_diff(&got);
            assert!(diff < tol, "stride={stride} {act:?}: diff {diff} tol {tol}");
        }
    }

    #[test]
    fn prepared_depthwise_is_bit_identical() {
        let mut rng = Rng::seeded(77);
        let (_, ql) = make_pair(&mut rng, 5, 2, FusedActivation::Relu6);
        let mut xd = vec![0f32; 2 * 9 * 9 * 5];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let qx = QTensor::quantize(&Tensor::from_vec(&[2, 9, 9, 5], xd), ql.input_params);
        let want = ql.run(&qx);
        let plan = ql.prepare();
        let mut got = QTensor::default();
        let mut scratch = crate::nn::LayerScratch::new();
        plan.run_into(&qx, &mut got, &mut scratch);
        assert_eq!(want.shape(), got.shape());
        assert_eq!(want.data.data(), got.data.data());
        plan.run_into(&qx, &mut got, &mut scratch);
        assert_eq!(want.data.data(), got.data.data(), "warm rerun");
    }

    #[test]
    fn per_channel_with_uniform_scale_is_bit_identical_to_per_tensor() {
        use crate::quant::ChannelQuantParams;
        let mut rng = Rng::seeded(55);
        let (_, pt) = make_pair(&mut rng, 5, 1, FusedActivation::None);
        let WeightQuant::PerTensor(wp) = pt.weight_quant.clone() else { unreachable!() };
        let pc = QDepthwiseConv2d {
            weight_quant: WeightQuant::PerChannel(ChannelQuantParams {
                scales: vec![wp.scale; 5],
                zero_point: wp.zero_point,
                qmin: wp.qmin,
                qmax: wp.qmax,
            }),
            ..pt.clone()
        };
        let mut xd = vec![0f32; 7 * 7 * 5];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let qx = QTensor::quantize(&Tensor::from_vec(&[1, 7, 7, 5], xd), pt.input_params);
        let want = pt.run(&qx);
        assert_eq!(want.data.data(), pc.run(&qx).data.data(), "unprepared");
        let mut got = QTensor::default();
        pc.prepare().run_into(&qx, &mut got, &mut crate::nn::LayerScratch::new());
        assert_eq!(want.data.data(), got.data.data(), "prepared");
    }

    #[test]
    fn per_channel_recovers_heterogeneous_depthwise_channels() {
        // Scale each channel's weights by a different power of 3 (the
        // BN-fold γ/σ spread): one shared scale drowns the small channels;
        // per-channel scales keep every channel accurate.
        let mut rng = Rng::seeded(56);
        let (mut fl, proto) = make_pair(&mut rng, 6, 1, FusedActivation::None);
        {
            let c = 6;
            let wd = fl.weights.data_mut();
            for (i, w) in wd.iter_mut().enumerate() {
                *w *= 0.05 * 3f32.powi((i % c) as i32);
            }
            for (ch, b) in fl.bias.iter_mut().enumerate() {
                *b *= 0.05 * 3f32.powi(ch as i32);
            }
        }
        // Re-quantize per-tensor from the rescaled float weights; output
        // range wide enough that neither mode saturates.
        let ip = proto.input_params;
        let wp = QuantParams::for_weights(fl.weights.data(), 8);
        let bp = QuantParams::for_bias(&wp, &ip);
        let pt = QDepthwiseConv2d {
            weights: fl.weights.map(|v| wp.quantize(v) as u8),
            weight_quant: WeightQuant::PerTensor(wp),
            bias: bp.quantize_bias_slice(&fl.bias),
            output_params: QuantParams::from_min_max(-40.0, 40.0, 0, 255),
            ..proto.clone()
        };
        let pc = per_channel_twin(&fl, &pt);
        let mut xd = vec![0f32; 8 * 8 * 6];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[1, 8, 8, 6], xd);
        let want = fl.run(&x);
        let qx = QTensor::quantize(&x, ip);
        let mean_err = |got: &Tensor<f32>| -> f64 {
            want.data()
                .iter()
                .zip(got.data())
                .map(|(a, b)| f64::from((a - b).abs()))
                .sum::<f64>()
                / want.len() as f64
        };
        let pt_err = mean_err(&pt.run(&qx).dequantize());
        let pc_err = mean_err(&pc.run(&qx).dequantize());
        assert!(
            pc_err < pt_err,
            "per-channel ({pc_err}) must beat per-tensor ({pt_err}) on spread channels"
        );
    }

    #[test]
    fn depthwise_channels_are_independent() {
        // Zeroing one channel's weights must zero only that channel's output
        // (up to the bias) — no cross-channel leakage.
        let mut rng = Rng::seeded(17);
        let (_, mut ql) = make_pair(&mut rng, 3, 1, FusedActivation::None);
        ql.bias = vec![0; 3];
        // Set channel-1 weights to the zero-point (= real 0).
        let c = 3;
        {
            let zw = ql.weight_quant.zero_point() as u8;
            let wd = ql.weights.data_mut();
            for t in 0..9 {
                wd[t * c + 1] = zw;
            }
        }
        let ip = ql.input_params;
        let mut xd = vec![0f32; 6 * 6 * 3];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let qx = QTensor::quantize(&Tensor::from_vec(&[1, 6, 6, 3], xd), ip);
        let got = ql.run(&qx).dequantize();
        for y in 0..6 {
            for x in 0..6 {
                assert!(got.at4(0, y, x, 1).abs() <= (ql.output_params.scale * 1.01) as f32);
            }
        }
    }

    #[test]
    fn shape_matches_regular_conv_rules() {
        let mut rng = Rng::seeded(8);
        let (fl, _) = make_pair(&mut rng, 4, 2, FusedActivation::None);
        let x = Tensor::zeros(&[1, 9, 9, 4]);
        assert_eq!(fl.run(&x).shape(), &[1, 5, 5, 4]);
    }
}
