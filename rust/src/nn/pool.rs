//! Pooling on quantized activations.
//!
//! Max pooling is monotone in `q` so it runs directly on the uint8 values
//! with unchanged quantization parameters. Average pooling keeps the input
//! parameters too (the mean of values in `[a,b]` stays in `[a,b]`) and
//! computes the integer mean with round-to-nearest — no requantization
//! needed, as in TFLite.

use crate::nn::{Padding, QTensor};
use crate::tensor::Tensor;

/// Quantized max pooling, NHWC.
pub fn qmax_pool(input: &QTensor, kernel: usize, stride: usize, padding: Padding) -> QTensor {
    let mut out = QTensor::default();
    qmax_pool_into(input, kernel, stride, padding, &mut out);
    out
}

/// [`qmax_pool`] into a reusable output (the prepared path's zero-alloc
/// steady state).
pub fn qmax_pool_into(
    input: &QTensor,
    kernel: usize,
    stride: usize,
    padding: Padding,
    dst: &mut QTensor,
) {
    let x = &input.data;
    let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (oh, pad_h) = padding.resolve(ih, kernel, stride);
    let (ow, pad_w) = padding.resolve(iw, kernel, stride);
    dst.params = input.params;
    // Safe: the loops below write every output position.
    dst.data.reset_for_overwrite(&[batch, oh, ow, c]);
    let out = &mut dst.data;
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = u8::MIN;
                    let mut any = false;
                    for ky in 0..kernel {
                        let y = (oy * stride + ky) as isize - pad_h as isize;
                        if y < 0 || y >= ih as isize {
                            continue;
                        }
                        for kx in 0..kernel {
                            let xx = (ox * stride + kx) as isize - pad_w as isize;
                            if xx < 0 || xx >= iw as isize {
                                continue;
                            }
                            best = best.max(x.at4(b, y as usize, xx as usize, ch));
                            any = true;
                        }
                    }
                    // Padding taps are excluded (TFLite semantics); a window
                    // fully in padding can't occur with SAME/VALID resolve.
                    debug_assert!(any);
                    out.set4(b, oy, ox, ch, best);
                }
            }
        }
    }
}

/// Quantized average pooling with round-to-nearest integer mean, NHWC.
pub fn qavg_pool(input: &QTensor, kernel: usize, stride: usize, padding: Padding) -> QTensor {
    let mut out = QTensor::default();
    qavg_pool_into(input, kernel, stride, padding, &mut out);
    out
}

/// [`qavg_pool`] into a reusable output.
pub fn qavg_pool_into(
    input: &QTensor,
    kernel: usize,
    stride: usize,
    padding: Padding,
    dst: &mut QTensor,
) {
    let x = &input.data;
    let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (oh, pad_h) = padding.resolve(ih, kernel, stride);
    let (ow, pad_w) = padding.resolve(iw, kernel, stride);
    dst.params = input.params;
    // Safe: the loops below write every output position.
    dst.data.reset_for_overwrite(&[batch, oh, ow, c]);
    let out = &mut dst.data;
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut sum = 0i32;
                    let mut count = 0i32;
                    for ky in 0..kernel {
                        let y = (oy * stride + ky) as isize - pad_h as isize;
                        if y < 0 || y >= ih as isize {
                            continue;
                        }
                        for kx in 0..kernel {
                            let xx = (ox * stride + kx) as isize - pad_w as isize;
                            if xx < 0 || xx >= iw as isize {
                                continue;
                            }
                            sum += i32::from(x.at4(b, y as usize, xx as usize, ch));
                            count += 1;
                        }
                    }
                    let avg = (sum + count / 2) / count; // round-to-nearest
                    out.set4(b, oy, ox, ch, avg as u8);
                }
            }
        }
    }
}

/// Global average pooling: NHWC → [batch, 1, 1, C].
pub fn qglobal_avg_pool(input: &QTensor) -> QTensor {
    let mut out = QTensor::default();
    qglobal_avg_pool_into(input, &mut out);
    out
}

/// [`qglobal_avg_pool`] into a reusable output.
pub fn qglobal_avg_pool_into(input: &QTensor, dst: &mut QTensor) {
    let x = &input.data;
    let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let area = (ih * iw) as i32;
    dst.params = input.params;
    // Safe: the loops below write every output position.
    dst.data.reset_for_overwrite(&[batch, 1, 1, c]);
    let out = &mut dst.data;
    for b in 0..batch {
        for ch in 0..c {
            let mut sum = 0i32;
            for y in 0..ih {
                for xx in 0..iw {
                    sum += i32::from(x.at4(b, y, xx, ch));
                }
            }
            out.set4(b, 0, 0, ch, ((sum + area / 2) / area) as u8);
        }
    }
}

/// Float reference average pool.
pub fn avg_pool_f32(x: &Tensor<f32>, kernel: usize, stride: usize, padding: Padding) -> Tensor<f32> {
    let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (oh, pad_h) = padding.resolve(ih, kernel, stride);
    let (ow, pad_w) = padding.resolve(iw, kernel, stride);
    let mut out = Tensor::zeros(&[batch, oh, ow, c]);
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut sum = 0f32;
                    let mut count = 0f32;
                    for ky in 0..kernel {
                        let y = (oy * stride + ky) as isize - pad_h as isize;
                        if y < 0 || y >= ih as isize {
                            continue;
                        }
                        for kx in 0..kernel {
                            let xx = (ox * stride + kx) as isize - pad_w as isize;
                            if xx < 0 || xx >= iw as isize {
                                continue;
                            }
                            sum += x.at4(b, y as usize, xx as usize, ch);
                            count += 1.0;
                        }
                    }
                    out.set4(b, oy, ox, ch, sum / count);
                }
            }
        }
    }
    out
}

/// Float reference global average pool.
pub fn global_avg_pool_f32(x: &Tensor<f32>) -> Tensor<f32> {
    let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let mut out = Tensor::zeros(&[batch, 1, 1, c]);
    for b in 0..batch {
        for ch in 0..c {
            let mut sum = 0f32;
            for y in 0..ih {
                for xx in 0..iw {
                    sum += x.at4(b, y, xx, ch);
                }
            }
            out.set4(b, 0, 0, ch, sum / (ih * iw) as f32);
        }
    }
    out
}

/// Float reference max pool.
pub fn max_pool_f32(x: &Tensor<f32>, kernel: usize, stride: usize, padding: Padding) -> Tensor<f32> {
    let (batch, ih, iw, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (oh, pad_h) = padding.resolve(ih, kernel, stride);
    let (ow, pad_w) = padding.resolve(iw, kernel, stride);
    let mut out = Tensor::zeros(&[batch, oh, ow, c]);
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    for ky in 0..kernel {
                        let y = (oy * stride + ky) as isize - pad_h as isize;
                        if y < 0 || y >= ih as isize {
                            continue;
                        }
                        for kx in 0..kernel {
                            let xx = (ox * stride + kx) as isize - pad_w as isize;
                            if xx < 0 || xx >= iw as isize {
                                continue;
                            }
                            best = best.max(x.at4(b, y as usize, xx as usize, ch));
                        }
                    }
                    out.set4(b, oy, ox, ch, best);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::quant::QuantParams;

    #[test]
    fn qavg_tracks_float_avg() {
        let mut rng = Rng::seeded(55);
        let p = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let mut xd = vec![0f32; 8 * 8 * 3];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[1, 8, 8, 3], xd);
        let q = QTensor::quantize(&x, p);
        let got = qavg_pool(&q, 2, 2, Padding::Valid).dequantize();
        let want = avg_pool_f32(&q.dequantize(), 2, 2, Padding::Valid);
        assert!(want.max_abs_diff(&got) <= p.scale as f32);
    }

    #[test]
    fn qmax_is_exact_in_quantized_domain() {
        // Max over q equals quantize(max over r): monotone map.
        let mut rng = Rng::seeded(56);
        let p = QuantParams::from_min_max(-2.0, 2.0, 0, 255);
        let mut xd = vec![0f32; 6 * 6 * 2];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-2.0, 2.0);
        }
        let x = Tensor::from_vec(&[1, 6, 6, 2], xd);
        let q = QTensor::quantize(&x, p);
        let got = qmax_pool(&q, 3, 3, Padding::Valid);
        let want_f = max_pool_f32(&q.dequantize(), 3, 3, Padding::Valid);
        let want = QTensor::quantize(&want_f, p);
        assert_eq!(got.data.data(), want.data.data());
    }

    #[test]
    fn global_avg_shapes_and_value() {
        let p = QuantParams::from_min_max(0.0, 1.0, 0, 255);
        let x = Tensor::from_vec(&[2, 2, 2, 1], vec![0.0f32, 0.0, 1.0, 1.0, 0.25, 0.25, 0.25, 0.25]);
        let q = QTensor::quantize(&x, p);
        let out = qglobal_avg_pool(&q);
        assert_eq!(out.shape(), &[2, 1, 1, 1]);
        let d = out.dequantize();
        assert!((d.data()[0] - 0.5).abs() <= p.scale as f32);
        assert!((d.data()[1] - 0.25).abs() <= p.scale as f32);
    }

    #[test]
    fn pooling_preserves_params() {
        let p = QuantParams::from_min_max(-1.0, 3.0, 0, 255);
        let q = QTensor::real_zeros(&[1, 4, 4, 2], p);
        assert_eq!(qmax_pool(&q, 2, 2, Padding::Valid).params, p);
        assert_eq!(qavg_pool(&q, 2, 2, Padding::Valid).params, p);
        assert_eq!(qglobal_avg_pool(&q).params, p);
    }
}
