//! Quantized neural-network operators (§2.4, App. A) and their float32
//! reference twins.
//!
//! Every quantized op consumes/produces [`QTensor`]s — uint8 data plus the
//! affine [`QuantParams`] of eq. 1 — and computes with integers only;
//! the matching `*_f32` twin is the float path the paper benchmarks against
//! (its "Eigen" baseline). The op set covers what MobileNet-style
//! classifiers and SSD-lite detectors need:
//!
//! * [`conv`] — conv2d as im2col + the quantized GEMM (fused bias/requant/clamp)
//! * [`depthwise`] — depthwise conv2d (direct, §4.2.2's separable convs)
//! * [`fc`] — fully connected
//! * [`elementwise`] — Add with rescaling (App. A.2), Concat with shared
//!   params (App. A.3)
//! * [`pool`] — average / max pooling on quantized values
//! * [`activations`] — fixed-point softmax / logistic / tanh (App. A.1)

pub mod activations;
pub mod conv;
pub mod depthwise;
pub mod elementwise;
pub mod fc;
pub mod pool;

pub use crate::gemm::output::FusedActivation;
use crate::quant::QuantParams;
use crate::tensor::Tensor;

/// A quantized activation array: uint8 storage plus its quantization
/// parameters — the paper's "quantized buffer" data structure (§2.1).
#[derive(Clone, Debug)]
pub struct QTensor {
    pub data: Tensor<u8>,
    pub params: QuantParams,
}

impl Default for QTensor {
    /// An empty placeholder (shape `[0]`, unit params) — the initial state
    /// of reusable output slots in [`crate::graph::ExecState`].
    fn default() -> Self {
        Self { data: Tensor::zeros(&[0]), params: QuantParams::unit(0, 255) }
    }
}

impl QTensor {
    /// Quantize a real-valued tensor under `params`.
    pub fn quantize(real: &Tensor<f32>, params: QuantParams) -> Self {
        let data = real.map(|v| params.quantize(v) as u8);
        Self { data, params }
    }

    /// Quantize `real` into this tensor in place, reusing its allocation —
    /// the zero-alloc counterpart of [`Self::quantize`] for the prepared
    /// serving path.
    pub fn quantize_from(&mut self, real: &Tensor<f32>, params: QuantParams) {
        self.params = params;
        // Safe: the loop below writes every element.
        self.data.reset_for_overwrite(real.shape());
        for (d, &v) in self.data.data_mut().iter_mut().zip(real.data()) {
            *d = params.quantize(v) as u8;
        }
    }

    /// Dequantize back to real values (eq. 1).
    pub fn dequantize(&self) -> Tensor<f32> {
        let p = self.params;
        self.data.map(|q| p.dequantize(i32::from(q)))
    }

    pub fn shape(&self) -> &[usize] {
        self.data.shape()
    }

    /// A tensor of zeros *in real space*: filled with the zero-point, which
    /// is exactly why the zero-point must exist (§2.1 zero-padding).
    pub fn real_zeros(shape: &[usize], params: QuantParams) -> Self {
        Self { data: Tensor::full(shape, params.zero_point as u8), params }
    }
}

/// Reusable per-worker buffers for the prepared layer paths
/// ([`conv::PreparedConv2d`], [`depthwise::PreparedDepthwiseConv2d`],
/// [`fc::PreparedFullyConnected`]): the GEMM scratch plus the layer-level
/// staging buffers (im2col patches, channel-major GEMM output, depthwise
/// accumulators). One instance per worker thread; every buffer grows to its
/// high-water mark during warm-up and is then reused allocation-free.
#[derive(Clone, Debug, Default)]
pub struct LayerScratch {
    /// GEMM-side buffers (packed RHS panels, i32 accumulators, column sums).
    pub gemm: crate::gemm::Scratch,
    /// im2col patch matrix (conv) / feature-major transposed input (fc).
    pub cols: Vec<u8>,
    /// Channel-major uint8 GEMM output staged before the NHWC scatter.
    pub staging: Vec<u8>,
    /// Per-channel int32 accumulators (depthwise).
    pub acc32: Vec<i32>,
    /// Per-row Q0.31 exponentials (fixed-point softmax).
    pub acc64: Vec<i64>,
    /// Intra-op GEMM parallelism for this worker: serial by default; a
    /// serving coordinator attaches a shared [`crate::gemm::WorkerPool`]
    /// (with a per-layer `N` threshold) so large conv/FC GEMMs split
    /// across persistent workers. Riding in the scratch keeps the prepared
    /// layer APIs unchanged — every `run_into` already receives it.
    pub intra: crate::gemm::IntraOp,
}

impl LayerScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes currently held across every arena (high-water marks after
    /// warm-up) — feeds [`crate::graph::ExecState::arena_bytes`].
    pub fn bytes(&self) -> usize {
        self.gemm.bytes()
            + self.cols.len()
            + self.staging.len()
            + self.acc32.len() * std::mem::size_of::<i32>()
            + self.acc64.len() * std::mem::size_of::<i64>()
    }
}

/// Spatial padding mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    /// Output spatial size = ceil(input / stride); zero-pads evenly.
    Same,
    /// No padding; output = floor((input - kernel) / stride) + 1.
    Valid,
}

impl Padding {
    /// Stable numeric code for binary model artifacts
    /// ([`crate::model_format`]). Codes are append-only across versions.
    pub fn code(self) -> u8 {
        match self {
            Padding::Same => 0,
            Padding::Valid => 1,
        }
    }

    /// Inverse of [`Self::code`]; `None` for unknown codes.
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(Padding::Same),
            1 => Some(Padding::Valid),
            _ => None,
        }
    }

    /// (output size, pad before) along one spatial dim.
    pub fn resolve(self, input: usize, kernel: usize, stride: usize) -> (usize, usize) {
        match self {
            Padding::Valid => {
                assert!(input >= kernel, "VALID padding needs input >= kernel");
                ((input - kernel) / stride + 1, 0)
            }
            Padding::Same => {
                let out = input.div_ceil(stride);
                let needed = ((out - 1) * stride + kernel).saturating_sub(input);
                (out, needed / 2)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qtensor_roundtrip() {
        let p = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let real = Tensor::from_vec(&[1, 2, 2, 1], vec![-1.0f32, -0.5, 0.5, 1.0]);
        let q = QTensor::quantize(&real, p);
        let back = q.dequantize();
        assert!(real.max_abs_diff(&back) <= p.scale as f32);
    }

    #[test]
    fn real_zeros_dequantize_to_exactly_zero() {
        let p = QuantParams::from_min_max(-3.7, 9.1, 0, 255);
        let z = QTensor::real_zeros(&[1, 2, 2, 3], p);
        for &v in z.dequantize().data() {
            assert_eq!(v, 0.0);
        }
    }

    #[test]
    fn padding_same_resolves() {
        // 8 input, 3 kernel, stride 1 → out 8, pad 1.
        assert_eq!(Padding::Same.resolve(8, 3, 1), (8, 1));
        // stride 2 → out 4, total pad 1 (before 0).
        assert_eq!(Padding::Same.resolve(8, 3, 2), (4, 0));
        assert_eq!(Padding::Same.resolve(9, 3, 2), (5, 1));
    }

    #[test]
    fn padding_valid_resolves() {
        assert_eq!(Padding::Valid.resolve(8, 3, 1), (6, 0));
        assert_eq!(Padding::Valid.resolve(8, 3, 2), (3, 0));
        assert_eq!(Padding::Valid.resolve(8, 8, 1), (1, 0));
    }
}
