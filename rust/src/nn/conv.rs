//! 2-D convolution as im2col + the quantized GEMM of §2.2–2.4, plus its
//! float32 twin.
//!
//! Patches are gathered into a `K×N` matrix (`K = KH·KW·Cin`, `N = batch ×
//! output positions`) whose **padding entries are filled with the input's
//! zero-point** — this is exactly why §2.1 requires real 0.0 to be exactly
//! representable. The weights form the `M×K` LHS (`M = Cout`), so the bias /
//! requantize / clamp output pipeline applies per output channel, matching
//! the fused-layer layout of figure 1.1a.

use crate::gemm::output::{Requant, ResidualAdd};
use crate::gemm::prepared::grow;
use crate::gemm::{output::OutputStage, Kernel, LhsBytes, PrepareMode, PreparedGemm, QGemm};
use crate::nn::{FusedActivation, LayerScratch, Padding, QTensor};
use crate::quant::{QuantParams, WeightQuant};
use crate::tensor::Tensor;

/// A fused quantized convolution layer: uint8 in → uint8 out (fig. 1.1a).
#[derive(Clone, Debug)]
pub struct QConv2d {
    /// Weights, OHWI layout `[Cout, KH, KW, Cin]`, uint8 narrow range.
    pub weights: Tensor<u8>,
    /// Weight quantization: per-tensor (§2.1) or per-output-channel scales
    /// ([`WeightQuant::PerChannel`]) — either way one shared zero-point, so
    /// the GEMM core below is identical in both modes.
    pub weight_quant: WeightQuant,
    /// int32 bias quantized per eq. 11 (empty = no bias).
    pub bias: Vec<i32>,
    pub stride: usize,
    pub padding: Padding,
    /// Input activation quantization (fixed at conversion time).
    pub input_params: QuantParams,
    /// Output activation quantization.
    pub output_params: QuantParams,
    pub activation: FusedActivation,
}

impl QConv2d {
    /// Derived output stage (multiplier per eq. 5 — per output channel when
    /// the weights carry per-channel scales; clamp per activation).
    pub fn output_stage(&self) -> OutputStage {
        let multiplier = Requant::for_weights(
            &self.weight_quant,
            self.input_params.scale,
            self.output_params.scale,
            self.weights.dim(0),
        );
        let (clamp_min, clamp_max) = self
            .activation
            .clamp_bounds(self.output_params.scale, self.output_params.zero_point);
        OutputStage {
            bias: self.bias.clone(),
            multiplier,
            out_zero: self.output_params.zero_point,
            clamp_min,
            clamp_max,
        }
    }

    /// Run the layer on a quantized input (NHWC).
    pub fn run(&self, input: &QTensor, kern: Kernel) -> QTensor {
        assert_eq!(
            input.params.zero_point, self.input_params.zero_point,
            "input must be quantized with the layer's input params"
        );
        let x = &input.data;
        let (batch, ih, iw, cin) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let (cout, kh, kw, wcin) = (
            self.weights.dim(0),
            self.weights.dim(1),
            self.weights.dim(2),
            self.weights.dim(3),
        );
        assert_eq!(cin, wcin, "channel mismatch");
        let (oh, pad_h) = self.padding.resolve(ih, kh, self.stride);
        let (ow, pad_w) = self.padding.resolve(iw, kw, self.stride);

        let k = kh * kw * cin;
        let n = batch * oh * ow;
        // im2col with zero-point padding (§2.1).
        let cols = im2col(x, kh, kw, self.stride, pad_h, pad_w, oh, ow, input.params.zero_point as u8);
        debug_assert_eq!(cols.len(), k * n);

        let g = QGemm::new(cout, k, n, self.weight_quant.zero_point(), input.params.zero_point);
        let stage = self.output_stage();
        let mut out_cm = vec![0u8; cout * n]; // [Cout][N] channel-major
        g.run(kern, self.weights.data(), &cols, &stage, &mut out_cm);

        // Scatter back to NHWC.
        let mut out = Tensor::zeros(&[batch, oh, ow, cout]);
        scatter_cm_to_nhwc(&out_cm, cout, n, out.data_mut());
        QTensor { data: out, params: self.output_params }
    }

    /// Build the prepared plan for this layer: weights packed for `kern`,
    /// row sums and output stage computed once. All per-request cost after
    /// this is activation-side only.
    pub fn prepare(&self, kern: Kernel) -> PreparedConv2d {
        self.prepare_with(kern, PrepareMode::Eager)
    }

    /// [`Self::prepare`] with an explicit [`PrepareMode`]. Under
    /// [`PrepareMode::Lazy`] panel packing is deferred to the layer's first
    /// execution — packing straight from the artifact [`ByteView`] when the
    /// weights are a zero-copy view (no intermediate owned copy), from an
    /// owned copy otherwise. Bit-identical to eager either way.
    ///
    /// [`ByteView`]: crate::tensor::ByteView
    pub fn prepare_with(&self, kern: Kernel, mode: PrepareMode) -> PreparedConv2d {
        let (cout, kh, kw, cin) = (
            self.weights.dim(0),
            self.weights.dim(1),
            self.weights.dim(2),
            self.weights.dim(3),
        );
        let k = kh * kw * cin;
        let plan = match mode {
            PrepareMode::Eager => PreparedGemm::new(
                kern,
                cout,
                k,
                self.weight_quant.zero_point(),
                self.input_params.zero_point,
                self.weights.data(),
                self.output_stage(),
            ),
            PrepareMode::Lazy => PreparedGemm::new_lazy(
                kern,
                cout,
                k,
                self.weight_quant.zero_point(),
                self.input_params.zero_point,
                match self.weights.view() {
                    Some(view) => LhsBytes::View(view.clone()),
                    None => LhsBytes::Owned(self.weights.data().to_vec()),
                },
                self.output_stage(),
            ),
        };
        PreparedConv2d {
            plan,
            kh,
            kw,
            cin,
            cout,
            stride: self.stride,
            padding: self.padding,
            input_zero: self.input_params.zero_point,
            output_params: self.output_params,
        }
    }
}

/// A [`QConv2d`] with all weight-side work hoisted out of the request path:
/// packed weights, precomputed row sums, built-once output stage. `run_into`
/// is allocation-free once the scratch and output have warmed up, and
/// bit-identical to [`QConv2d::run`].
#[derive(Clone, Debug)]
pub struct PreparedConv2d {
    plan: PreparedGemm,
    kh: usize,
    kw: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    padding: Padding,
    input_zero: i32,
    output_params: QuantParams,
}

impl PreparedConv2d {
    /// Pin the GEMM micro-kernel implementation for this layer's plan
    /// (see [`crate::gemm::dispatch`]); defaults to the process-wide
    /// selection.
    pub fn set_ukernel(&mut self, u: &'static crate::gemm::dispatch::KernelDispatch) {
        self.plan.set_ukernel(u);
    }

    /// Heap bytes currently held by this layer's GEMM plan (see
    /// [`PreparedGemm::plan_bytes`]).
    pub fn plan_bytes(&self) -> usize {
        self.plan.plan_bytes()
    }

    /// Run the layer, writing the NHWC result into `out` (reshaped in
    /// place, allocation reused).
    pub fn run_into(&self, input: &QTensor, out: &mut QTensor, scratch: &mut LayerScratch) {
        self.run_into_res(input, None, out, scratch);
    }

    /// [`Self::run_into`] with the composable residual-add epilogue: when
    /// `res` is given, the fused conv→add path combines every
    /// just-requantized output element with the matching element of the
    /// residual source (same NHWC shape as this conv's output) inside the
    /// GEMM's cache-resident output stage, and the output carries the Add's
    /// quantization parameters. `res = None` is exactly [`Self::run_into`].
    pub fn run_into_res(
        &self,
        input: &QTensor,
        res: Option<ResidualArgs<'_>>,
        out: &mut QTensor,
        scratch: &mut LayerScratch,
    ) {
        assert_eq!(
            input.params.zero_point, self.input_zero,
            "input must be quantized with the layer's input params"
        );
        let x = &input.data;
        let (batch, ih, iw, cin) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        assert_eq!(cin, self.cin, "channel mismatch");
        let (oh, pad_h) = self.padding.resolve(ih, self.kh, self.stride);
        let (ow, pad_w) = self.padding.resolve(iw, self.kw, self.stride);
        let k = self.kh * self.kw * cin;
        let n = batch * oh * ow;
        if let Some(args) = &res {
            assert_eq!(
                args.src.shape(),
                [batch, oh, ow, self.cout],
                "residual operand shape must equal the conv output shape"
            );
        }

        let LayerScratch { gemm, cols, staging, intra, .. } = scratch;
        let cols = grow(cols, k * n);
        im2col_into(x, self.kh, self.kw, self.stride, pad_h, pad_w, oh, ow, self.input_zero as u8, cols);
        let staging = grow(staging, self.cout * n);
        // Large-N GEMMs split across the worker's intra-op pool (serial by
        // default; bit-identical either way — the pool only changes who
        // computes each column strip).
        let epi = res.as_ref().map(|a| (&a.cfg, a.src.data.data()));
        intra.run_res(&self.plan, cols, n, staging, epi, gemm);

        out.params = match &res {
            Some(args) => args.out_params,
            None => self.output_params,
        };
        // Safe: the scatter below writes every output element exactly once.
        out.data.reset_for_overwrite(&[batch, oh, ow, self.cout]);
        scatter_cm_to_nhwc(staging, self.cout, n, out.data.data_mut());
    }
}

/// The residual operand of a fused conv→add execution: the epilogue config
/// (built at prepare time from the three quantization parameter sets), the
/// already-computed residual tensor, and the Add's output parameters which
/// the fused output adopts.
#[derive(Clone, Copy, Debug)]
pub struct ResidualArgs<'a> {
    /// App. A.2 rescale multipliers/zero-points for `conv_out + src → out`.
    pub cfg: ResidualAdd,
    /// The residual source (NHWC, same shape as the conv output).
    pub src: &'a QTensor,
    /// Quantization parameters of the fused (Add) output.
    pub out_params: QuantParams,
}

/// Transpose a channel-major `[C][N]` GEMM result into NHWC order (channel
/// innermost): `dst[pos*C + c] = src[c*N + pos]`.
fn scatter_cm_to_nhwc(src: &[u8], c_total: usize, n: usize, dst: &mut [u8]) {
    debug_assert_eq!(src.len(), c_total * n);
    debug_assert_eq!(dst.len(), c_total * n);
    for c in 0..c_total {
        let row = &src[c * n..(c + 1) * n];
        for (pos, &v) in row.iter().enumerate() {
            dst[pos * c_total + c] = v;
        }
    }
}

/// Gather convolution patches into a row-major `K×N` matrix
/// (`K = KH·KW·Cin` rows, `N = batch·OH·OW` columns); out-of-bounds taps
/// read the zero-point.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &Tensor<u8>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    oh: usize,
    ow: usize,
    zero: u8,
) -> Vec<u8> {
    let (batch, cin) = (x.dim(0), x.dim(3));
    let mut cols = vec![0u8; kh * kw * cin * batch * oh * ow];
    im2col_into(x, kh, kw, stride, pad_h, pad_w, oh, ow, zero, &mut cols);
    cols
}

/// [`im2col`] into a caller-provided buffer (the prepared path's reusable
/// scratch); `cols` must hold exactly `K×N` bytes and is fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    x: &Tensor<u8>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad_h: usize,
    pad_w: usize,
    oh: usize,
    ow: usize,
    zero: u8,
    cols: &mut [u8],
) {
    let (batch, ih, iw, cin) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let k = kh * kw * cin;
    let n = batch * oh * ow;
    assert_eq!(cols.len(), k * n, "cols must be K*N");
    cols.fill(zero);
    let xd = x.data();
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let col = (b * oh + oy) * ow + ox;
                for ky in 0..kh {
                    let y = (oy * stride + ky) as isize - pad_h as isize;
                    if y < 0 || y >= ih as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let xx = (ox * stride + kx) as isize - pad_w as isize;
                        if xx < 0 || xx >= iw as isize {
                            continue;
                        }
                        let src = ((b * ih + y as usize) * iw + xx as usize) * cin;
                        let row0 = (ky * kw + kx) * cin;
                        for c in 0..cin {
                            cols[(row0 + c) * n + col] = xd[src + c];
                        }
                    }
                }
            }
        }
    }
}

/// Float reference convolution (the paper's float baseline path).
#[derive(Clone, Debug)]
pub struct Conv2d {
    /// Weights OHWI `[Cout, KH, KW, Cin]`.
    pub weights: Tensor<f32>,
    pub bias: Vec<f32>,
    pub stride: usize,
    pub padding: Padding,
    pub activation: FusedActivation,
}

impl Conv2d {
    pub fn run(&self, x: &Tensor<f32>) -> Tensor<f32> {
        let (batch, ih, iw, cin) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let (cout, kh, kw, wcin) = (
            self.weights.dim(0),
            self.weights.dim(1),
            self.weights.dim(2),
            self.weights.dim(3),
        );
        assert_eq!(cin, wcin);
        let (oh, pad_h) = self.padding.resolve(ih, kh, self.stride);
        let (ow, pad_w) = self.padding.resolve(iw, kw, self.stride);
        let mut out = Tensor::zeros(&[batch, oh, ow, cout]);
        let wd = self.weights.data();
        for b in 0..batch {
            for oy in 0..oh {
                for ox in 0..ow {
                    for co in 0..cout {
                        let mut acc = if self.bias.is_empty() { 0.0 } else { self.bias[co] };
                        for ky in 0..kh {
                            let y = (oy * self.stride + ky) as isize - pad_h as isize;
                            if y < 0 || y >= ih as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let xx = (ox * self.stride + kx) as isize - pad_w as isize;
                                if xx < 0 || xx >= iw as isize {
                                    continue;
                                }
                                for c in 0..cin {
                                    acc += x.at4(b, y as usize, xx as usize, c)
                                        * wd[((co * kh + ky) * kw + kx) * cin + c];
                                }
                            }
                        }
                        out.set4(b, oy, ox, co, apply_activation_f32(acc, self.activation));
                    }
                }
            }
        }
        out
    }
}

/// Float-side fused activation.
#[inline]
pub fn apply_activation_f32(x: f32, act: FusedActivation) -> f32 {
    match act {
        FusedActivation::None => x,
        FusedActivation::Relu => x.max(0.0),
        FusedActivation::Relu6 => x.clamp(0.0, 6.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    /// Build a quantized layer mirroring a float layer, with output params
    /// calibrated from the float output's true range.
    fn quantize_layer(fl: &Conv2d, input_params: QuantParams, out_min: f32, out_max: f32) -> QConv2d {
        let wp = QuantParams::for_weights(fl.weights.data(), 8);
        let weights = fl.weights.map(|v| wp.quantize(v) as u8);
        let bp = QuantParams::for_bias(&wp, &input_params);
        let bias = bp.quantize_bias_slice(&fl.bias);
        QConv2d {
            weights,
            weight_quant: WeightQuant::PerTensor(wp),
            bias,
            stride: fl.stride,
            padding: fl.padding,
            input_params,
            output_params: QuantParams::from_min_max(f64::from(out_min), f64::from(out_max), 0, 255),
            activation: fl.activation,
        }
    }

    fn random_float_conv(rng: &mut Rng, cout: usize, kh: usize, kw: usize, cin: usize) -> Conv2d {
        let mut w = vec![0f32; cout * kh * kw * cin];
        rng.fill_normal(&mut w, 0.3);
        let bias: Vec<f32> = (0..cout).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        Conv2d {
            weights: Tensor::from_vec(&[cout, kh, kw, cin], w),
            bias,
            stride: 1,
            padding: Padding::Same,
            activation: FusedActivation::None,
        }
    }

    #[test]
    fn quantized_conv_tracks_float_conv() {
        let mut rng = Rng::seeded(21);
        for (stride, padding, act) in [
            (1, Padding::Same, FusedActivation::None),
            (2, Padding::Same, FusedActivation::Relu),
            (1, Padding::Valid, FusedActivation::Relu6),
        ] {
            let mut fl = random_float_conv(&mut rng, 6, 3, 3, 4);
            fl.stride = stride;
            fl.padding = padding;
            fl.activation = act;

            let mut xd = vec![0f32; 2 * 8 * 8 * 4];
            for v in xd.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            let x = Tensor::from_vec(&[2, 8, 8, 4], xd);
            let want = fl.run(&x);
            let (omin, omax) = want.min_max();

            let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
            let ql = quantize_layer(&fl, ip, omin, omax);
            let qx = QTensor::quantize(&x, ip);
            let got = ql.run(&qx, Kernel::Int8Pairwise).dequantize();

            // Error budget: input quant (S_in/2 per tap, amplified by L1 of
            // weights) + weight quant + output rounding. Empirically well
            // under 4 output LSBs for these magnitudes.
            let tol = (ql.output_params.scale * 4.0) as f32 + 0.02;
            let diff = want.max_abs_diff(&got);
            assert!(diff < tol, "stride={stride} {padding:?} {act:?}: diff {diff} tol {tol}");
        }
    }

    #[test]
    fn conv_kernels_agree() {
        let mut rng = Rng::seeded(5);
        let fl = random_float_conv(&mut rng, 5, 3, 3, 3);
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let ql = quantize_layer(&fl, ip, -4.0, 4.0);
        let mut xd = vec![0f32; 1 * 7 * 7 * 3];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let qx = QTensor::quantize(&Tensor::from_vec(&[1, 7, 7, 3], xd), ip);
        let a = ql.run(&qx, Kernel::Reference);
        let b = ql.run(&qx, Kernel::Blocked);
        let c = ql.run(&qx, Kernel::Int8Pairwise);
        assert_eq!(a.data.data(), b.data.data());
        assert_eq!(a.data.data(), c.data.data());
    }

    #[test]
    fn prepared_conv_is_bit_identical() {
        let mut rng = Rng::seeded(9);
        let mut fl = random_float_conv(&mut rng, 6, 3, 3, 4);
        fl.stride = 2;
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let ql = quantize_layer(&fl, ip, -4.0, 4.0);
        let mut xd = vec![0f32; 2 * 9 * 9 * 4];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let qx = QTensor::quantize(&Tensor::from_vec(&[2, 9, 9, 4], xd), ip);
        let mut scratch = crate::nn::LayerScratch::new();
        let mut got = QTensor::default();
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let want = ql.run(&qx, kern);
            let plan = ql.prepare(kern);
            plan.run_into(&qx, &mut got, &mut scratch);
            assert_eq!(want.shape(), got.shape(), "{kern:?}");
            assert_eq!(want.data.data(), got.data.data(), "{kern:?}");
            // Warm buffers (shared across kernels) must not corrupt results.
            plan.run_into(&qx, &mut got, &mut scratch);
            assert_eq!(want.data.data(), got.data.data(), "{kern:?} warm");
        }
    }

    #[test]
    fn per_channel_with_uniform_scale_is_bit_identical_to_per_tensor() {
        // Satellite property: a per-channel layer whose channels all share
        // the per-tensor scale and zero-point must reproduce the per-tensor
        // path bit for bit (same weights bytes, same multipliers).
        use crate::quant::ChannelQuantParams;
        let mut rng = Rng::seeded(133);
        let fl = random_float_conv(&mut rng, 6, 3, 3, 4);
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let pt = quantize_layer(&fl, ip, -4.0, 4.0);
        let WeightQuant::PerTensor(wp) = pt.weight_quant.clone() else { unreachable!() };
        let pc = QConv2d {
            weight_quant: WeightQuant::PerChannel(ChannelQuantParams {
                scales: vec![wp.scale; 6],
                zero_point: wp.zero_point,
                qmin: wp.qmin,
                qmax: wp.qmax,
            }),
            ..pt.clone()
        };
        let mut xd = vec![0f32; 2 * 8 * 8 * 4];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let qx = QTensor::quantize(&Tensor::from_vec(&[2, 8, 8, 4], xd), ip);
        let mut scratch = crate::nn::LayerScratch::new();
        let mut got = QTensor::default();
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let want = pt.run(&qx, kern);
            let got_pc = pc.run(&qx, kern);
            assert_eq!(want.data.data(), got_pc.data.data(), "{kern:?} unprepared");
            pc.prepare(kern).run_into(&qx, &mut got, &mut scratch);
            assert_eq!(want.data.data(), got.data.data(), "{kern:?} prepared");
        }
    }

    #[test]
    fn per_channel_conv_tracks_float_on_heterogeneous_channels() {
        // Channels with 100x different magnitudes: per-channel scales keep
        // every channel accurate where one shared scale cannot.
        use crate::quant::{ChannelAxis, ChannelQuantParams};
        let mut rng = Rng::seeded(134);
        let mut fl = random_float_conv(&mut rng, 6, 3, 3, 4);
        {
            let cout = 6;
            let per = fl.weights.len() / cout;
            let wd = fl.weights.data_mut();
            for o in 0..cout {
                let gain = 0.05f32 * 3f32.powi(o as i32);
                for t in 0..per {
                    wd[o * per + t] *= gain;
                }
            }
            for (o, b) in fl.bias.iter_mut().enumerate() {
                *b *= 0.05 * 3f32.powi(o as i32);
            }
        }
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let mut xd = vec![0f32; 2 * 8 * 8 * 4];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[2, 8, 8, 4], xd);
        let want = fl.run(&x);
        let (omin, omax) = want.min_max();
        let op = QuantParams::from_min_max(f64::from(omin), f64::from(omax), 0, 255);

        let cq = ChannelQuantParams::for_weights(fl.weights.data(), 6, ChannelAxis::Outer, 8);
        let pc = QConv2d {
            weights: Tensor::from_vec(
                fl.weights.shape(),
                cq.quantize_slice(fl.weights.data(), ChannelAxis::Outer),
            ),
            bias: cq.quantize_bias(&fl.bias, ip.scale),
            weight_quant: WeightQuant::PerChannel(cq),
            stride: fl.stride,
            padding: fl.padding,
            input_params: ip,
            output_params: op,
            activation: fl.activation,
        };
        let pt = quantize_layer(&fl, ip, omin, omax);
        let qx = QTensor::quantize(&x, ip);
        let pc_diff = want.max_abs_diff(&pc.run(&qx, Kernel::Int8Pairwise).dequantize());
        let pt_diff = want.max_abs_diff(&pt.run(&qx, Kernel::Int8Pairwise).dequantize());
        assert!(
            pc_diff <= pt_diff + (op.scale * 0.5) as f32,
            "per-channel ({pc_diff}) should not trail per-tensor ({pt_diff})"
        );
        // And it must still track the float layer within a few output LSBs.
        assert!(pc_diff < (op.scale * 5.0) as f32 + 0.05, "pc diff {pc_diff}");
    }

    #[test]
    fn near_dead_per_channel_weights_requantize_to_exact_zero() {
        // Headline regression for the release-mode shift overflow: a
        // per-channel conv whose one channel has max_abs ≈ 1e-8 weights
        // gets an eq. 5 multiplier below 2^-32, i.e. `shift < -31`.
        // `QuantizedMultiplier::from_f64` must flush that to the exact zero
        // encoding so the channel outputs the quantized zero — identical in
        // debug and release (pre-fix, debug panicked on the overflowing
        // shift while release wrapped the shift amount mod 32 and emitted
        // garbage activations). CI runs this test in both profiles.
        use crate::quant::{ChannelAxis, ChannelQuantParams};
        let mut rng = Rng::seeded(135);
        let mut fl = random_float_conv(&mut rng, 4, 3, 3, 2);
        fl.bias = vec![0.0; 4];
        {
            // Channel 0 (outermost axis): magnitudes collapse to ~1e-8.
            let per = fl.weights.len() / 4;
            let wd = fl.weights.data_mut();
            for t in 0..per {
                wd[t] = if wd[t] >= 0.0 { 1e-8 } else { -1e-8 };
            }
        }
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let op = QuantParams::from_min_max(-4.0, 4.0, 0, 255);
        let cq = ChannelQuantParams::for_weights(fl.weights.data(), 4, ChannelAxis::Outer, 8);
        let pc = QConv2d {
            weights: Tensor::from_vec(
                fl.weights.shape(),
                cq.quantize_slice(fl.weights.data(), ChannelAxis::Outer),
            ),
            bias: cq.quantize_bias(&fl.bias, ip.scale),
            weight_quant: WeightQuant::PerChannel(cq),
            stride: 1,
            padding: Padding::Same,
            input_params: ip,
            output_params: op,
            activation: FusedActivation::None,
        };
        // The derived stage must carry the exact zero encoding for row 0.
        let stage = pc.output_stage();
        let m0 = stage.multiplier.for_row(0);
        assert_eq!((m0.m0, m0.shift), (0, 0), "underflowing channel multiplier must flush");

        let mut xd = vec![0f32; 6 * 6 * 2];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let qx = QTensor::quantize(&Tensor::from_vec(&[1, 6, 6, 2], xd), ip);
        let zero_q = op.zero_point as u8;
        let mut scratch = crate::nn::LayerScratch::new();
        let mut prepared_out = QTensor::default();
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let y = pc.run(&qx, kern);
            let yd = y.data.data();
            // NHWC: channel 0 at every 4th byte must be the quantized zero.
            assert!(
                yd.iter().step_by(4).all(|&v| v == zero_q),
                "{kern:?}: near-dead channel must be exact quantized zero, got {:?}",
                yd.iter().step_by(4).take(8).collect::<Vec<_>>()
            );
            // Sanity: a healthy channel still carries signal.
            assert!(
                yd.iter().skip(1).step_by(4).any(|&v| v != zero_q),
                "{kern:?}: healthy channels should not be all-zero"
            );
            // Prepared path agrees byte for byte.
            pc.prepare(kern).run_into(&qx, &mut prepared_out, &mut scratch);
            assert_eq!(yd, prepared_out.data.data(), "{kern:?} prepared");
        }
    }

    #[test]
    fn padding_uses_zero_point() {
        // A conv over an all-real-zero input with SAME padding must behave
        // as if the padded border is also real zero — i.e. output = bias.
        let w = Tensor::from_vec(&[1, 3, 3, 1], vec![0.5f32; 9]);
        let fl = Conv2d {
            weights: w,
            bias: vec![0.25],
            stride: 1,
            padding: Padding::Same,
            activation: FusedActivation::None,
        };
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let ql = quantize_layer(&fl, ip, -1.0, 1.0);
        let x = Tensor::from_vec(&[1, 4, 4, 1], vec![0.0f32; 16]);
        let got = ql.run(&QTensor::quantize(&x, ip), Kernel::Reference).dequantize();
        for &v in got.data() {
            assert!((v - 0.25).abs() < (ql.output_params.scale * 1.5) as f32, "{v}");
        }
    }

    #[test]
    fn output_shapes() {
        let mut rng = Rng::seeded(2);
        let fl = random_float_conv(&mut rng, 4, 3, 3, 2);
        let x = Tensor::zeros(&[2, 9, 9, 2]);
        assert_eq!(fl.run(&x).shape(), &[2, 9, 9, 4]);
        let mut fl2 = random_float_conv(&mut rng, 4, 3, 3, 2);
        fl2.stride = 2;
        assert_eq!(fl2.run(&x).shape(), &[2, 5, 5, 4]);
        fl2.padding = Padding::Valid;
        assert_eq!(fl2.run(&x).shape(), &[2, 4, 4, 4]);
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: im2col is a pure transpose.
        let x = Tensor::from_vec(&[1, 2, 2, 3], (0..12).map(|v| v as u8).collect());
        let cols = im2col(&x, 1, 1, 1, 0, 0, 2, 2, 99);
        // K=3 rows, N=4 cols; cols[c*4 + pos] = x[pos*3 + c]
        for pos in 0..4 {
            for c in 0..3 {
                assert_eq!(cols[c * 4 + pos], x.data()[pos * 3 + c]);
            }
        }
    }
}
