//! Fully-connected (inner product) layer — the §2.4 reference fusion is
//! specified for exactly this op in TFLite, and it is the matmul of §2.2
//! with `M = units`, `K = input features`, `N = batch`.

use crate::gemm::output::Requant;
use crate::gemm::prepared::grow;
use crate::gemm::{output::OutputStage, Kernel, LhsBytes, PrepareMode, PreparedGemm, QGemm};
use crate::nn::{conv::apply_activation_f32, FusedActivation, LayerScratch, QTensor};
use crate::quant::{QuantParams, WeightQuant};
use crate::tensor::Tensor;

/// Fused quantized fully-connected layer.
#[derive(Clone, Debug)]
pub struct QFullyConnected {
    /// Weights `[units, in_features]`, uint8 narrow range.
    pub weights: Tensor<u8>,
    /// Weight quantization: per-tensor, or one scale per output unit (the
    /// GEMM rows), same shared zero-point either way.
    pub weight_quant: WeightQuant,
    pub bias: Vec<i32>,
    pub input_params: QuantParams,
    pub output_params: QuantParams,
    pub activation: FusedActivation,
}

impl QFullyConnected {
    /// Derived output stage (multiplier per eq. 5 — per output unit when
    /// the weights carry per-channel scales; clamp per activation).
    pub fn output_stage(&self) -> OutputStage {
        let multiplier = Requant::for_weights(
            &self.weight_quant,
            self.input_params.scale,
            self.output_params.scale,
            self.weights.dim(0),
        );
        let (clamp_min, clamp_max) = self
            .activation
            .clamp_bounds(self.output_params.scale, self.output_params.zero_point);
        OutputStage {
            bias: self.bias.clone(),
            multiplier,
            out_zero: self.output_params.zero_point,
            clamp_min,
            clamp_max,
        }
    }

    /// Build the prepared plan for this layer (weights packed once for
    /// `kern`, output stage built once).
    pub fn prepare(&self, kern: Kernel) -> PreparedFullyConnected {
        self.prepare_with(kern, PrepareMode::Eager)
    }

    /// Like [`prepare`](Self::prepare), but `mode` selects when panel
    /// packing runs: `Eager` packs here, `Lazy` defers to first touch
    /// (packing straight from a mapped [`crate::tensor::ByteView`] when
    /// the weights are view-backed).
    pub fn prepare_with(&self, kern: Kernel, mode: PrepareMode) -> PreparedFullyConnected {
        let units = self.weights.dim(0);
        let feat = self.weights.dim(1);
        let plan = match mode {
            PrepareMode::Eager => PreparedGemm::new(
                kern,
                units,
                feat,
                self.weight_quant.zero_point(),
                self.input_params.zero_point,
                self.weights.data(),
                self.output_stage(),
            ),
            PrepareMode::Lazy => PreparedGemm::new_lazy(
                kern,
                units,
                feat,
                self.weight_quant.zero_point(),
                self.input_params.zero_point,
                match self.weights.view() {
                    Some(view) => LhsBytes::View(view.clone()),
                    None => LhsBytes::Owned(self.weights.data().to_vec()),
                },
                self.output_stage(),
            ),
        };
        PreparedFullyConnected {
            plan,
            units,
            feat,
            input_zero: self.input_params.zero_point,
            output_params: self.output_params,
        }
    }

    pub fn run(&self, input: &QTensor, kern: Kernel) -> QTensor {
        let x = &input.data;
        let batch = x.dim(0);
        let feat: usize = x.shape()[1..].iter().product();
        let units = self.weights.dim(0);
        assert_eq!(self.weights.dim(1), feat, "feature mismatch");

        // RHS must be K×N = features × batch: transpose the input.
        let xd = x.data();
        let mut rhs = vec![0u8; feat * batch];
        for b in 0..batch {
            for f in 0..feat {
                rhs[f * batch + b] = xd[b * feat + f];
            }
        }
        let stage = self.output_stage();
        let g =
            QGemm::new(units, feat, batch, self.weight_quant.zero_point(), self.input_params.zero_point);
        let mut out_cm = vec![0u8; units * batch];
        g.run(kern, self.weights.data(), &rhs, &stage, &mut out_cm);

        // Back to [batch, units].
        let mut out = Tensor::zeros(&[batch, units]);
        let od = out.data_mut();
        for u in 0..units {
            for b in 0..batch {
                od[b * units + u] = out_cm[u * batch + b];
            }
        }
        QTensor { data: out, params: self.output_params }
    }
}

/// A [`QFullyConnected`] with packed weights and built-once output stage;
/// `run_into` is allocation-free once warmed up and bit-identical to
/// [`QFullyConnected::run`].
#[derive(Clone, Debug)]
pub struct PreparedFullyConnected {
    plan: PreparedGemm,
    units: usize,
    feat: usize,
    input_zero: i32,
    output_params: QuantParams,
}

impl PreparedFullyConnected {
    /// Pin the GEMM micro-kernel implementation for this layer's plan
    /// (see [`crate::gemm::dispatch`]); defaults to the process-wide
    /// selection.
    pub fn set_ukernel(&mut self, u: &'static crate::gemm::dispatch::KernelDispatch) {
        self.plan.set_ukernel(u);
    }

    /// Heap bytes currently held by this layer's GEMM plan (see
    /// [`PreparedGemm::plan_bytes`]).
    pub fn plan_bytes(&self) -> usize {
        self.plan.plan_bytes()
    }

    /// Run the layer, writing `[batch, units]` into `out` (reshaped in
    /// place, allocation reused).
    pub fn run_into(&self, input: &QTensor, out: &mut QTensor, scratch: &mut LayerScratch) {
        assert_eq!(
            input.params.zero_point, self.input_zero,
            "input must be quantized with the layer's input params"
        );
        let x = &input.data;
        let batch = x.dim(0);
        let feat: usize = x.shape()[1..].iter().product();
        assert_eq!(feat, self.feat, "feature mismatch");

        // RHS must be K×N = features × batch: transpose into scratch.
        let LayerScratch { gemm, cols, staging, intra, .. } = scratch;
        let rhs = grow(cols, feat * batch);
        let xd = x.data();
        for b in 0..batch {
            for f in 0..feat {
                rhs[f * batch + b] = xd[b * feat + f];
            }
        }
        let out_cm = grow(staging, self.units * batch);
        // N = batch here, so FC only splits across the intra-op pool for
        // genuinely large batches (bit-identical either way).
        intra.run(&self.plan, rhs, batch, out_cm, gemm);

        // Back to [batch, units]. Safe: the transpose writes every element.
        out.params = self.output_params;
        out.data.reset_for_overwrite(&[batch, self.units]);
        let od = out.data.data_mut();
        for u in 0..self.units {
            for b in 0..batch {
                od[b * self.units + u] = out_cm[u * batch + b];
            }
        }
    }
}

/// Float reference fully-connected layer.
#[derive(Clone, Debug)]
pub struct FullyConnected {
    pub weights: Tensor<f32>,
    pub bias: Vec<f32>,
    pub activation: FusedActivation,
}

impl FullyConnected {
    pub fn run(&self, x: &Tensor<f32>) -> Tensor<f32> {
        let batch = x.dim(0);
        let feat: usize = x.shape()[1..].iter().product();
        let units = self.weights.dim(0);
        assert_eq!(self.weights.dim(1), feat);
        let xd = x.data();
        let wd = self.weights.data();
        let mut out = Tensor::zeros(&[batch, units]);
        let od = out.data_mut();
        for b in 0..batch {
            for u in 0..units {
                let mut acc = if self.bias.is_empty() { 0.0 } else { self.bias[u] };
                for f in 0..feat {
                    acc += xd[b * feat + f] * wd[u * feat + f];
                }
                od[b * units + u] = apply_activation_f32(acc, self.activation);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn quantized_fc_tracks_float() {
        let mut rng = Rng::seeded(41);
        let (units, feat, batch) = (10, 32, 4);
        let mut w = vec![0f32; units * feat];
        rng.fill_normal(&mut w, 0.25);
        let bias: Vec<f32> = (0..units).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let fl = FullyConnected {
            weights: Tensor::from_vec(&[units, feat], w),
            bias,
            activation: FusedActivation::None,
        };
        let mut xd = vec![0f32; batch * feat];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[batch, feat], xd);
        let want = fl.run(&x);
        let (omin, omax) = want.min_max();

        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let wp = QuantParams::for_weights(fl.weights.data(), 8);
        let bp = QuantParams::for_bias(&wp, &ip);
        let ql = QFullyConnected {
            weights: fl.weights.map(|v| wp.quantize(v) as u8),
            weight_quant: WeightQuant::PerTensor(wp),
            bias: bp.quantize_bias_slice(&fl.bias),
            input_params: ip,
            output_params: QuantParams::from_min_max(f64::from(omin), f64::from(omax), 0, 255),
            activation: FusedActivation::None,
        };
        let got = ql.run(&QTensor::quantize(&x, ip), Kernel::Int8Pairwise).dequantize();
        let tol = (ql.output_params.scale * 4.0) as f32 + 0.02;
        let diff = want.max_abs_diff(&got);
        assert!(diff < tol, "diff {diff} tol {tol}");
    }

    #[test]
    fn fc_flattens_rank4_inputs() {
        let mut rng = Rng::seeded(6);
        let mut w = vec![0f32; 3 * 18];
        rng.fill_normal(&mut w, 0.3);
        let fl = FullyConnected {
            weights: Tensor::from_vec(&[3, 18], w),
            bias: vec![],
            activation: FusedActivation::None,
        };
        let x = Tensor::zeros(&[2, 3, 3, 2]); // 18 features
        assert_eq!(fl.run(&x).shape(), &[2, 3]);
    }

    #[test]
    fn prepared_fc_is_bit_identical() {
        let mut rng = Rng::seeded(71);
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let (units, feat) = (5, 19);
        let mut w = vec![0f32; units * feat];
        rng.fill_normal(&mut w, 0.3);
        let wp = QuantParams::for_weights(&w, 8);
        let bp = QuantParams::for_bias(&wp, &ip);
        let bias: Vec<f32> = (0..units).map(|_| rng.range_f32(-0.4, 0.4)).collect();
        let ql = QFullyConnected {
            weights: Tensor::from_vec(&[units, feat], wp.quantize_slice(&w)),
            weight_quant: WeightQuant::PerTensor(wp),
            bias: bp.quantize_bias_slice(&bias),
            input_params: ip,
            output_params: QuantParams::from_min_max(-3.0, 3.0, 0, 255),
            activation: FusedActivation::Relu,
        };
        let mut scratch = crate::nn::LayerScratch::new();
        let mut got = QTensor::default();
        for batch in [1usize, 3, 7] {
            let mut xd = vec![0f32; batch * feat];
            rng.fill_normal(&mut xd, 0.5);
            let qx = QTensor::quantize(&Tensor::from_vec(&[batch, feat], xd), ip);
            for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
                let want = ql.run(&qx, kern);
                let plan = ql.prepare(kern);
                plan.run_into(&qx, &mut got, &mut scratch);
                assert_eq!(want.shape(), got.shape(), "{kern:?} batch={batch}");
                assert_eq!(want.data.data(), got.data.data(), "{kern:?} batch={batch}");
            }
        }
    }

    #[test]
    fn per_channel_with_uniform_scale_is_bit_identical_to_per_tensor() {
        use crate::quant::ChannelQuantParams;
        let mut rng = Rng::seeded(73);
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let (units, feat) = (6, 23);
        let mut w = vec![0f32; units * feat];
        rng.fill_normal(&mut w, 0.3);
        let wp = QuantParams::for_weights(&w, 8);
        let pt = QFullyConnected {
            weights: Tensor::from_vec(&[units, feat], wp.quantize_slice(&w)),
            weight_quant: WeightQuant::PerTensor(wp),
            bias: QuantParams::for_bias(&wp, &ip)
                .quantize_bias_slice(&(0..units).map(|_| rng.range_f32(-0.4, 0.4)).collect::<Vec<_>>()),
            input_params: ip,
            output_params: QuantParams::from_min_max(-3.0, 3.0, 0, 255),
            activation: FusedActivation::Relu,
        };
        let pc = QFullyConnected {
            weight_quant: WeightQuant::PerChannel(ChannelQuantParams {
                scales: vec![wp.scale; units],
                zero_point: wp.zero_point,
                qmin: wp.qmin,
                qmax: wp.qmax,
            }),
            ..pt.clone()
        };
        let mut scratch = crate::nn::LayerScratch::new();
        let mut got = QTensor::default();
        for batch in [1usize, 5] {
            let mut xd = vec![0f32; batch * feat];
            rng.fill_normal(&mut xd, 0.5);
            let qx = QTensor::quantize(&Tensor::from_vec(&[batch, feat], xd), ip);
            for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
                let want = pt.run(&qx, kern);
                assert_eq!(
                    want.data.data(),
                    pc.run(&qx, kern).data.data(),
                    "{kern:?} batch={batch} unprepared"
                );
                pc.prepare(kern).run_into(&qx, &mut got, &mut scratch);
                assert_eq!(want.data.data(), got.data.data(), "{kern:?} batch={batch} prepared");
            }
        }
    }

    #[test]
    fn batch_rows_are_independent() {
        let mut rng = Rng::seeded(61);
        let ip = QuantParams::from_min_max(-1.0, 1.0, 0, 255);
        let mut w = vec![0f32; 4 * 8];
        rng.fill_normal(&mut w, 0.3);
        let wp = QuantParams::for_weights(&w, 8);
        let wq = Tensor::from_vec(&[4, 8], wp.quantize_slice(&w));
        let ql = QFullyConnected {
            weights: wq,
            weight_quant: WeightQuant::PerTensor(wp),
            bias: vec![],
            input_params: ip,
            output_params: QuantParams::from_min_max(-3.0, 3.0, 0, 255),
            activation: FusedActivation::None,
        };
        let mut x1 = vec![0f32; 8];
        let mut x2 = vec![0f32; 8];
        rng.fill_normal(&mut x1, 0.5);
        rng.fill_normal(&mut x2, 0.5);
        let both: Vec<f32> = x1.iter().chain(&x2).copied().collect();
        let qb = ql.run(&QTensor::quantize(&Tensor::from_vec(&[2, 8], both), ip), Kernel::Blocked);
        let q1 = ql.run(&QTensor::quantize(&Tensor::from_vec(&[1, 8], x1), ip), Kernel::Blocked);
        let q2 = ql.run(&QTensor::quantize(&Tensor::from_vec(&[1, 8], x2), ip), Kernel::Blocked);
        assert_eq!(&qb.data.data()[..4], q1.data.data());
        assert_eq!(&qb.data.data()[4..], q2.data.data());
    }
}
