//! Quantized math-function activations (App. A.1): softmax, logistic, tanh
//! computed in pure fixed-point arithmetic — no lookup tables — on top of
//! [`crate::fixedpoint::transcendental`], plus float references.
//!
//! Following TFLite's quantized kernels, softmax and logistic produce
//! outputs with the *fixed* quantization `S = 1/256, Z = 0` (probabilities
//! in `[0, 255/256]`) and tanh with `S = 1/128, Z = 128` — the natural
//! ranges of these functions, independent of learned statistics.

use crate::fixedpoint::transcendental::{exp_on_negative_values, rounding_div};
use crate::fixedpoint::{logistic as fp_logistic, rounding_div_by_pot, tanh as fp_tanh, Fp};
use crate::nn::QTensor;
use crate::quant::{QuantParams, QuantizedMultiplier};
use crate::tensor::Tensor;

/// Integer bits used for the fixed-point input domain of exp/logistic/tanh.
/// `Fp<5>` covers (−32, 32), far beyond where the functions saturate.
const INPUT_IB: i32 = 5;

/// Output params of quantized softmax / logistic: scale 1/256, zero 0.
pub fn prob_output_params() -> QuantParams {
    QuantParams { scale: 1.0 / 256.0, zero_point: 0, qmin: 0, qmax: 255 }
}

/// Output params of quantized tanh: scale 1/128, zero 128.
pub fn tanh_output_params() -> QuantParams {
    QuantParams { scale: 1.0 / 128.0, zero_point: 128, qmin: 0, qmax: 255 }
}

/// Multiplier mapping integer input deltas `(q − ref)` onto `Fp<INPUT_IB>`
/// raw units: `raw = (q − ref) · S_in · 2^(31 − IB)`.
fn input_multiplier(scale: f64) -> QuantizedMultiplier {
    QuantizedMultiplier::from_f64(scale * 2f64.powi(31 - INPUT_IB))
}

/// Quantized softmax over the last axis (App. A.1).
///
/// For each row: subtract the row max (all diffs ≤ 0), convert to
/// fixed-point, `exp` each diff with the gemmlowp kernel, then renormalize
/// with an integer division — every step integer-only.
pub fn qsoftmax(input: &QTensor) -> QTensor {
    let mut out = QTensor::default();
    qsoftmax_into(input, &mut out, &mut crate::nn::LayerScratch::new());
    out
}

/// [`qsoftmax`] into a reusable output, with the per-row exponential
/// buffer drawn from `scratch.acc64` — the prepared path's zero-alloc
/// steady state.
pub fn qsoftmax_into(input: &QTensor, dst: &mut QTensor, scratch: &mut crate::nn::LayerScratch) {
    let rank = input.data.rank();
    let c = input.shape()[rank - 1];
    let rows: usize = input.shape()[..rank - 1].iter().product();
    let mult = input_multiplier(input.params.scale);
    let xd = input.data.data();
    dst.params = prob_output_params();
    // Safe: the loop below writes every output element.
    dst.data.reset_for_overwrite(input.shape());
    let out = dst.data.data_mut();
    let exps = crate::gemm::prepared::grow(&mut scratch.acc64, c);
    for r in 0..rows {
        let row = &xd[r * c..(r + 1) * c];
        let max_q = i32::from(*row.iter().max().expect("non-empty row"));
        // exp(S(q - max)) in Q0.31.
        let mut sum: i64 = 0;
        for (i, &q) in row.iter().enumerate() {
            let diff = i32::from(q) - max_q; // <= 0
            let raw = mult.apply(diff).max(i32::MIN + 1);
            let e = exp_on_negative_values(Fp::<INPUT_IB>::from_raw(raw.min(0)));
            exps[i] = i64::from(e.raw());
            sum += exps[i];
        }
        // out = e / sum scaled to [0, 256): integer rounding division.
        for (i, &e) in exps.iter().enumerate() {
            let q = rounding_div(e * 256, sum);
            out[r * c + i] = q.clamp(0, 255) as u8;
        }
    }
}

/// Quantized logistic (sigmoid) elementwise (App. A.1).
pub fn qlogistic(input: &QTensor) -> QTensor {
    let mut out = QTensor::default();
    qlogistic_into(input, &mut out);
    out
}

/// [`qlogistic`] into a reusable output (elementwise, no scratch needed).
pub fn qlogistic_into(input: &QTensor, dst: &mut QTensor) {
    let mult = input_multiplier(input.params.scale);
    let z = input.params.zero_point;
    dst.params = prob_output_params();
    // Safe: the loop below writes every output element.
    dst.data.reset_for_overwrite(input.shape());
    for (o, &q) in dst.data.data_mut().iter_mut().zip(input.data.data()) {
        let raw = mult.apply(i32::from(q) - z);
        let p = fp_logistic(Fp::<INPUT_IB>::from_raw(raw));
        // Q0.31 → [0, 256): divide by 2^23 with rounding.
        *o = rounding_div_by_pot(p.raw(), 23).clamp(0, 255) as u8;
    }
}

/// Quantized tanh elementwise (App. A.1).
pub fn qtanh(input: &QTensor) -> QTensor {
    let mult = input_multiplier(input.params.scale);
    let z = input.params.zero_point;
    let data: Vec<u8> = input
        .data
        .data()
        .iter()
        .map(|&q| {
            let raw = mult.apply(i32::from(q) - z);
            let t = fp_tanh(Fp::<INPUT_IB>::from_raw(raw));
            // Q0.31 in (−1,1) → [0,256) centred at 128.
            (rounding_div_by_pot(t.raw(), 24) + 128).clamp(0, 255) as u8
        })
        .collect();
    QTensor { data: Tensor::from_vec(input.shape(), data), params: tanh_output_params() }
}

/// Float reference softmax over the last axis.
pub fn softmax_f32(x: &Tensor<f32>) -> Tensor<f32> {
    let rank = x.rank();
    let c = x.shape()[rank - 1];
    let rows: usize = x.shape()[..rank - 1].iter().product();
    let xd = x.data();
    let mut out = vec![0f32; xd.len()];
    for r in 0..rows {
        let row = &xd[r * c..(r + 1) * c];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let exps: Vec<f32> = row.iter().map(|&v| (v - m).exp()).collect();
        let s: f32 = exps.iter().sum();
        for (i, e) in exps.iter().enumerate() {
            out[r * c + i] = e / s;
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// Float reference logistic.
pub fn logistic_f32(x: &Tensor<f32>) -> Tensor<f32> {
    x.map(|v| 1.0 / (1.0 + (-v).exp()))
}

/// Float reference tanh.
pub fn tanh_f32(x: &Tensor<f32>) -> Tensor<f32> {
    x.map(f32::tanh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn qsoftmax_tracks_float_softmax() {
        let mut rng = Rng::seeded(91);
        let p = QuantParams::from_min_max(-8.0, 8.0, 0, 255);
        let mut xd = vec![0f32; 6 * 10];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-8.0, 8.0);
        }
        let x = Tensor::from_vec(&[6, 10], xd);
        let q = QTensor::quantize(&x, p);
        let got = qsoftmax(&q).dequantize();
        let want = softmax_f32(&q.dequantize());
        let diff = want.max_abs_diff(&got);
        // Probabilities to within ~1.5/256 plus input-grid effects.
        assert!(diff < 0.015, "softmax diff {diff}");
    }

    #[test]
    fn qsoftmax_rows_sum_to_one() {
        let mut rng = Rng::seeded(92);
        let p = QuantParams::from_min_max(-4.0, 4.0, 0, 255);
        let mut xd = vec![0f32; 4 * 7];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-4.0, 4.0);
        }
        let q = QTensor::quantize(&Tensor::from_vec(&[4, 7], xd), p);
        let out = qsoftmax(&q);
        for r in 0..4 {
            let s: i32 = out.data.data()[r * 7..(r + 1) * 7].iter().map(|&v| i32::from(v)).sum();
            // Σ q/256 ≈ 1 → Σ q ≈ 256, within per-element rounding.
            assert!((s - 256).abs() <= 7, "row {r} sums to {s}");
        }
    }

    #[test]
    fn qsoftmax_argmax_preserved() {
        let p = QuantParams::from_min_max(-6.0, 6.0, 0, 255);
        let x = Tensor::from_vec(&[1, 5], vec![-1.0f32, 3.0, 0.0, -5.0, 2.0]);
        let q = QTensor::quantize(&x, p);
        let out = qsoftmax(&q);
        let arg = out.data.data().iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        assert_eq!(arg, 1);
    }

    #[test]
    fn into_variants_match_allocating_ops_with_warm_buffers() {
        let mut rng = Rng::seeded(93);
        let p = QuantParams::from_min_max(-6.0, 6.0, 0, 255);
        let mut scratch = crate::nn::LayerScratch::new();
        let mut dst = QTensor::default();
        for rows in [1usize, 4, 7] {
            let mut xd = vec![0f32; rows * 9];
            for v in xd.iter_mut() {
                *v = rng.range_f32(-6.0, 6.0);
            }
            let q = QTensor::quantize(&Tensor::from_vec(&[rows, 9], xd), p);
            let want = qsoftmax(&q);
            qsoftmax_into(&q, &mut dst, &mut scratch);
            assert_eq!(want.data, dst.data, "softmax rows={rows}");
            assert_eq!(want.params, dst.params);
            let want = qlogistic(&q);
            qlogistic_into(&q, &mut dst);
            assert_eq!(want.data, dst.data, "logistic rows={rows}");
            assert_eq!(want.params, dst.params);
        }
    }

    #[test]
    fn qlogistic_tracks_float() {
        let p = QuantParams::from_min_max(-8.0, 8.0, 0, 255);
        let xs: Vec<f32> = (-16..=16).map(|i| i as f32 / 2.0).collect();
        let n = xs.len();
        let q = QTensor::quantize(&Tensor::from_vec(&[n], xs), p);
        let got = qlogistic(&q).dequantize();
        let want = logistic_f32(&q.dequantize());
        assert!(want.max_abs_diff(&got) < 0.01);
    }

    #[test]
    fn qtanh_tracks_float_and_is_centred() {
        let p = QuantParams::from_min_max(-4.0, 4.0, 0, 255);
        let xs: Vec<f32> = (-16..=16).map(|i| i as f32 / 4.0).collect();
        let n = xs.len();
        let q = QTensor::quantize(&Tensor::from_vec(&[n], xs.clone()), p);
        let got = qtanh(&q).dequantize();
        let want = tanh_f32(&q.dequantize());
        assert!(want.max_abs_diff(&got) < 0.02);
        // tanh(0) must map to exactly the zero point.
        let zero_q = QTensor::quantize(&Tensor::from_vec(&[1], vec![0.0f32]), p);
        assert_eq!(qtanh(&zero_q).data.data()[0], 128);
    }
}
