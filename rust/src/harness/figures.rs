//! Latency-vs-accuracy trade-off figures (1.1c, 4.1, 4.2, 4.3).
//!
//! Each point is one PaperNet variant (width multiplier × resolution —
//! the paper's MobileNet DM × resolution sweep) trained twice (float
//! baseline and QAT), with:
//! * accuracy measured on the float engine / integer engine respectively,
//! * latency reported two ways: *measured* single-image latency of the
//!   Rust engines on this host, and the *fitted ARM core model* estimate
//!   for the figure's core (DESIGN.md §Hardware-Adaptation).
//!
//! The paper's qualitative claims to reproduce: int8 dominates float at
//! equal latency on the S835 (figs. 1.1c, 4.1) and the gap narrows on the
//! float-optimized S821 (fig. 4.2).

use super::{accuracy, papernet_from_params, papernet_int8, time_median_ms};
use crate::data::ClassificationSet;
use crate::nn::FusedActivation;
use crate::quantize::QuantizeOptions;
use crate::sim::{ArmCoreModel, Dtype};
use crate::train::{Knobs, Trainer};
use anyhow::{anyhow, Result};
use std::path::PathBuf;

/// (variant, dm, resolution) sweep points.
const SWEEP: &[(&str, f64, usize)] = &[
    ("dm050_r16", 0.5, 16),
    ("base", 1.0, 16),
    ("dm200_r16", 2.0, 16),
    ("dm100_r24", 1.0, 24),
    ("dm200_r24", 2.0, 24),
    ("dm100_r32", 1.0, 32),
];

fn core_by_name(name: &str) -> Result<ArmCoreModel> {
    ArmCoreModel::all()
        .into_iter()
        .find(|c| c.name == name)
        .ok_or_else(|| anyhow!("unknown core {name}"))
}

/// Shared figure driver: one series per numeric type.
pub fn latency_accuracy(core_name: &str, fast: bool) -> Result<()> {
    let core = core_by_name(core_name)?;
    println!("# Figure — latency-vs-accuracy trade-off on {core_name}");
    println!("| dm | res | type | acc | host ms/img | {core_name} est. ms |");
    println!("|---|---|---|---|---|---|");
    let arts = PathBuf::from("artifacts");
    let steps: u64 = if fast { 120 } else { 400 };
    let eval_batches = if fast { 4 } else { 8 };
    for &(variant, dm, res) in SWEEP {
        let dir = arts.join(variant);
        // --- float baseline run ---
        let mut ft = Trainer::new(&dir, 4)?.with_knobs(Knobs::float_baseline());
        for _ in 0..steps {
            ft.train_step()?;
        }
        let fspec = ft.spec.clone();
        let fparams = ft.export_folded()?;
        let fgraph = papernet_from_params(&fparams, &fspec.export_keys, FusedActivation::Relu6)?;
        let ds = ClassificationSet::new(fspec.resolution, fspec.num_classes, 4);
        let facc = accuracy(&mut |x| fgraph.run(x), &ds, eval_batches, fspec.batch);
        let (x1, _) = ds.batch(1, 0, 1);
        let fms = time_median_ms(10, || {
            let _ = fgraph.run(&x1);
        });
        let fest = core.latency_ms(&fgraph, &[1, res, res, 3], Dtype::F32);
        println!(
            "| {dm} | {res} | float | {:.1}% | {fms:.3} | {fest:.2} |",
            facc * 100.0
        );

        // --- QAT run + integer engine ---
        let mut qt = Trainer::new(&dir, 4)?.with_knobs(Knobs::default());
        for _ in 0..steps {
            qt.train_step()?;
        }
        let qparams = qt.export_folded()?;
        let qranges = qt.learned_ranges()?;
        let qgraph = papernet_int8(
            &qparams,
            &qranges,
            &fspec.export_keys,
            FusedActivation::Relu6,
            QuantizeOptions::default(),
        )?;
        let qacc = accuracy(&mut |x| qgraph.run(x), &ds, eval_batches, fspec.batch);
        let qms = time_median_ms(10, || {
            let _ = qgraph.run(&x1);
        });
        // The cost model consumes the float graph's op profile; dtype picks
        // the throughput table.
        let qest = core.latency_ms(&fgraph, &[1, res, res, 3], Dtype::Int8);
        println!(
            "| {dm} | {res} | int8 | {:.1}% | {qms:.3} | {qest:.2} |",
            qacc * 100.0
        );
    }
    println!();
    println!(
        "(paper shape to check: int8 series dominates float at equal latency on S835;\n\
         the advantage narrows on the float-optimized S821 — compare --fig 4.1 vs 4.2)"
    );
    Ok(())
}

/// Figure 4.3 — face-attribute classifier trade-off on the S821.
/// Substitute task: the same sweep evaluated with the attribute-style
/// metric (mean per-class binary accuracy over the 16 SynthShapes classes,
/// a multi-attribute readout of the same backbone), on the S821 core model.
pub fn latency_accuracy_attributes(fast: bool) -> Result<()> {
    println!("(attribute-task stand-in: per-class mean binary accuracy, S821 core model)");
    latency_accuracy("S821-big", fast)
}
