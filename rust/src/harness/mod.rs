//! Experiment harnesses: the CLI-visible commands (`train`, `eval`,
//! `serve`, `quickstart`) plus one regeneration routine per table and
//! figure of the paper's evaluation (DESIGN.md §6 maps each to its
//! modules). Output is printed in the paper's row/series layout so results
//! can be pasted into EXPERIMENTS.md.

pub mod detection;
pub mod figures;
pub mod tables;

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::{BatchPolicy, Coordinator, EngineKind, MultiCoordinator};
use crate::data::ClassificationSet;
use crate::gemm::{Kernel, PrepareMode};
use crate::graph::builders::{papernet_random, ParamMap};
use crate::graph::{FloatGraph, FloatOp, NodeRef, QGraph};
use crate::io;
use crate::model_format::{self, LoadMode, ModelArtifact};
use crate::nn::conv::Conv2d;
use crate::nn::depthwise::DepthwiseConv2d;
use crate::nn::fc::FullyConnected;
use crate::nn::{FusedActivation, Padding};
use crate::quant::EmaRange;
use crate::quantize::{convert, quantize_graph, Calibration, QuantMode, QuantizeOptions};
use crate::tensor::Tensor;
use crate::train::{Knobs, Trainer};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Run the standalone Pallas quickstart artifact and verify the Rust gemm
/// computes the *bit-identical* integer result — the cross-layer anchor
/// proving L1 (Pallas), the AOT path, and the L3 engine share one
/// arithmetic definition.
pub fn quickstart(artifacts: &Path) -> Result<()> {
    use crate::gemm::{output::OutputStage, QGemm};
    use crate::quant::QuantizedMultiplier;
    use crate::runtime::{literal_i32, literal_u8, u8_tensor_from_literal, Engine};

    let spec = io::read_kv(&artifacts.join("quickstart_spec.txt"))?;
    let get = |k: &str| -> Result<Vec<i64>> {
        spec.iter()
            .find(|(key, _)| key == k)
            .map(|(_, v)| v.split(',').map(|s| s.trim().parse().unwrap()).collect())
            .ok_or_else(|| anyhow!("quickstart_spec missing {k}"))
    };
    let mkn = get("mkn")?;
    let zps = get("zps")?;
    let mult = get("multiplier")?;
    let (m, k, n) = (mkn[0] as usize, mkn[1] as usize, mkn[2] as usize);
    let (z1, z2, z3) = (zps[0] as i32, zps[1] as i32, zps[2] as i32);

    // Deterministic demo inputs.
    let mut rng = crate::data::Rng::seeded(42);
    let q1: Vec<u8> = (0..m * k).map(|_| 1 + (rng.below(255) as u8)).collect();
    let q2: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
    let bias: Vec<i32> = (0..m).map(|_| rng.below(10_000) as i32 - 5_000).collect();

    let mut engine = Engine::new(artifacts)?;
    println!("PJRT platform: {}", engine.platform());
    let outs = engine.run(
        "quickstart.hlo.txt",
        &[
            literal_u8(&q1, &[m as i64, k as i64])?,
            literal_u8(&q2, &[k as i64, n as i64])?,
            literal_i32(&bias, &[m as i64])?,
        ],
    )?;
    let pallas_out = u8_tensor_from_literal(&outs[0])?;

    // Same computation on the pure-Rust integer engine.
    let g = QGemm::new(m, k, n, z1, z2);
    let stage = OutputStage {
        bias,
        multiplier: QuantizedMultiplier { m0: mult[0] as i32, shift: -(mult[1] as i32) }.into(),
        out_zero: z3,
        clamp_min: 0,
        clamp_max: 255,
    };
    let mut rust_out = vec![0u8; m * n];
    g.run(Kernel::Int8Pairwise, &q1, &q2, &stage, &mut rust_out);

    println!("pallas (via PJRT): {:?}", pallas_out.data());
    println!("rust integer gemm: {rust_out:?}");
    anyhow::ensure!(
        pallas_out.data() == &rust_out[..],
        "Pallas kernel and Rust engine disagree — integer arithmetic definitions diverged"
    );
    println!("OK: L1 Pallas kernel == L3 Rust engine, bit-exact ({m}x{k}x{n}).");
    Ok(())
}

/// `iaoi train`: QAT-train the base PaperNet via the AOT train_step and
/// save folded weights + learned ranges.
pub fn train(artifacts: &Path, steps: u64, seed: u64, eval_every: u64, out: &Path) -> Result<()> {
    let base = artifacts.join("base");
    let mut trainer = Trainer::new(&base, seed)?;
    println!(
        "training PaperNet ({} conv layers, res {}, batch {}) for {steps} QAT steps",
        trainer.spec.param_keys.len() / 3,
        trainer.spec.resolution,
        trainer.spec.batch
    );
    let start = Instant::now();
    for s in 0..steps {
        let loss = trainer.train_step()?;
        if s % 20 == 0 || s + 1 == steps {
            println!("step {s:>5}  loss {loss:.4}");
        }
        if eval_every > 0 && s > 0 && s % eval_every == 0 {
            let acc_f = trainer.eval_float(4)?;
            let acc_q = trainer.eval_qsim(4)?;
            println!("step {s:>5}  eval: float {:.1}%  quant-sim {:.1}%", acc_f * 100.0, acc_q * 100.0);
        }
    }
    let secs = start.elapsed().as_secs_f64();
    println!("trained {steps} steps in {secs:.1}s ({:.1} steps/s)", steps as f64 / secs);
    let acc_f = trainer.eval_float(8)?;
    let acc_q = trainer.eval_qsim(8)?;
    println!("final eval: float {:.2}%  quant-sim {:.2}%", acc_f * 100.0, acc_q * 100.0);
    trainer.save(out)?;
    println!("saved folded weights + learned ranges to {out:?}");
    Ok(())
}

/// Kind and stride of a PaperNet layer, reconstructed from its name.
fn layer_desc(name: &str) -> (bool, usize) {
    // (is_depthwise, stride)
    if name.starts_with("dw") {
        (true, 2)
    } else if name.starts_with("mdw") {
        (true, 1)
    } else {
        (false, 1)
    }
}

/// Build the float PaperNet graph from exported folded params, driven by
/// the spec's export-key order (so it works for every variant).
pub fn papernet_from_params(
    params: &ParamMap,
    export_keys: &[String],
    act: FusedActivation,
) -> Result<FloatGraph> {
    let mut g = FloatGraph::default();
    let mut cur = NodeRef::Input;
    let layer_names: Vec<&str> = export_keys
        .iter()
        .filter_map(|k| k.strip_suffix("/w"))
        .filter(|n| *n != "fc")
        .collect();
    for name in &layer_names {
        let w = params
            .get(&format!("{name}/w"))
            .ok_or_else(|| anyhow!("missing {name}/w"))?
            .clone();
        let b = params
            .get(&format!("{name}/b"))
            .ok_or_else(|| anyhow!("missing {name}/b"))?
            .clone()
            .into_data();
        let (depthwise, stride) = layer_desc(name);
        if depthwise {
            g.push(
                *name,
                cur,
                FloatOp::Depthwise(DepthwiseConv2d {
                    weights: w,
                    bias: b,
                    stride,
                    padding: Padding::Same,
                    activation: act,
                }),
            );
        } else {
            g.push(
                *name,
                cur,
                FloatOp::Conv(Conv2d {
                    weights: w,
                    bias: b,
                    stride,
                    padding: Padding::Same,
                    activation: act,
                }),
            );
        }
        cur = NodeRef::Node(g.nodes.len() - 1);
    }
    cur = g.push("gap", cur, FloatOp::GlobalAvgPool);
    g.push(
        "logits",
        cur,
        FloatOp::Fc(FullyConnected {
            weights: params.get("fc/w").ok_or_else(|| anyhow!("missing fc/w"))?.clone(),
            bias: params.get("fc/b").ok_or_else(|| anyhow!("missing fc/b"))?.clone().into_data(),
            activation: FusedActivation::None,
        }),
    );
    Ok(g)
}

/// Build the integer-only graph from folded params + the QAT-learned
/// ranges (Algorithm 1 step 4: the converter consumes training statistics,
/// no post-hoc calibration needed).
pub fn papernet_int8(
    params: &ParamMap,
    ranges: &[(String, (f64, f64))],
    export_keys: &[String],
    act: FusedActivation,
    opts: QuantizeOptions,
) -> Result<QGraph> {
    let float_graph = papernet_from_params(params, export_keys, act)?;
    let find = |key: &str| -> Result<(f64, f64)> {
        ranges
            .iter()
            .find(|(name, _)| name == key)
            .map(|(_, r)| *r)
            .ok_or_else(|| anyhow!("missing learned range {key}"))
    };
    let mk = |r: (f64, f64)| {
        let mut e = EmaRange::new(0.9);
        e.update(r.0, r.1);
        e
    };
    // One range per graph node, in node order: layers, gap (inherits the
    // previous activation range), logits.
    let mut node_ranges = Vec::new();
    let mut last = (0.0, 6.0);
    for node in &float_graph.nodes {
        match node.name.as_str() {
            "gap" => node_ranges.push(mk(last)),
            "logits" => node_ranges.push(mk(find("logits/act")?)),
            name => {
                let r = find(&format!("{name}/act"))?;
                last = r;
                node_ranges.push(mk(r));
            }
        }
    }
    let calib = Calibration { input: mk((-1.0, 1.0)), ranges: node_ranges };
    Ok(convert(&float_graph, &calib, opts))
}

/// A trained model bundle loaded from disk.
pub struct TrainedModel {
    pub params: ParamMap,
    pub ranges: Vec<(String, (f64, f64))>,
}

pub fn load_trained(path: &Path) -> Result<TrainedModel> {
    let all = io::read_params(path).with_context(|| format!("load model {path:?}"))?;
    let ranges = io::read_ranges(&all);
    let params: ParamMap =
        all.into_iter().filter(|(k, _)| !k.starts_with("range:")).collect();
    Ok(TrainedModel { params, ranges })
}

/// Top-1 accuracy of a logits-producing engine on the synthetic eval split.
pub fn accuracy(
    run: &mut dyn FnMut(&Tensor<f32>) -> Tensor<f32>,
    ds: &ClassificationSet,
    batches: usize,
    batch_size: usize,
) -> f32 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..batches {
        let (x, labels) = ds.batch(1, (b * batch_size) as u64, batch_size);
        let logits = run(&x);
        let classes = logits.dim(logits.rank() - 1);
        for (row, &label) in labels.iter().enumerate() {
            let data = &logits.data()[row * classes..(row + 1) * classes];
            let argmax = data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(argmax == label);
            total += 1;
        }
    }
    correct as f32 / total as f32
}

/// Top-k accuracy (recall@k) — Table 4.3's "recall 5" and Table 4.8's
/// second-metric substitute use k = 2 on 16 classes.
pub fn topk_accuracy(
    run: &mut dyn FnMut(&Tensor<f32>) -> Tensor<f32>,
    ds: &ClassificationSet,
    batches: usize,
    batch_size: usize,
    k: usize,
) -> f32 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..batches {
        let (x, labels) = ds.batch(1, (b * batch_size) as u64, batch_size);
        let logits = run(&x);
        let classes = logits.dim(logits.rank() - 1);
        for (row, &label) in labels.iter().enumerate() {
            let data = &logits.data()[row * classes..(row + 1) * classes];
            let mut idx: Vec<usize> = (0..classes).collect();
            idx.sort_by(|&a, &b| data[b].partial_cmp(&data[a]).unwrap());
            correct += usize::from(idx[..k].contains(&label));
            total += 1;
        }
    }
    correct as f32 / total as f32
}

/// Median wall-clock of `f` over `iters` runs after one warmup.
pub fn time_median_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// `iaoi eval`: compare float vs integer-only engines on a trained model.
pub fn eval(artifacts: &Path, model_path: &Path, batches: usize) -> Result<()> {
    let base = artifacts.join("base");
    let spec = crate::train::ModelSpec::load(&base)?;
    let model = load_trained(model_path)?;
    let float_graph =
        papernet_from_params(&model.params, &spec.export_keys, FusedActivation::Relu6)?;
    let int8_graph = papernet_int8(
        &model.params,
        &model.ranges,
        &spec.export_keys,
        FusedActivation::Relu6,
        QuantizeOptions::default(),
    )?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 0);

    let acc_f = accuracy(&mut |x| float_graph.run(x), &ds, batches, spec.batch);
    let acc_q = accuracy(&mut |x| int8_graph.run(x), &ds, batches, spec.batch);
    let (x1, _) = ds.batch(1, 0, 1);
    let ms_f = time_median_ms(20, || {
        let _ = float_graph.run(&x1);
    });
    let ms_q = time_median_ms(20, || {
        let _ = int8_graph.run(&x1);
    });
    println!("model: {model_path:?}");
    println!(
        "  float32 engine : top-1 {:.2}%  latency {ms_f:.3} ms/img  {} weight bytes",
        acc_f * 100.0,
        float_graph.model_bytes()
    );
    println!(
        "  int8 engine    : top-1 {:.2}%  latency {ms_q:.3} ms/img  {} weight bytes",
        acc_q * 100.0,
        int8_graph.model_bytes()
    );
    println!(
        "  accuracy gap {:+.2}%  speedup {:.2}x  size ratio {:.2}x",
        (acc_q - acc_f) * 100.0,
        ms_f / ms_q,
        float_graph.model_bytes() as f64 / int8_graph.model_bytes() as f64
    );
    Ok(())
}

/// `iaoi serve`: closed-loop serving demo through the coordinator.
pub fn serve(
    artifacts: &Path,
    model_path: &Path,
    requests: usize,
    max_batch: usize,
    workers: usize,
    intra_threads: usize,
) -> Result<()> {
    let base = artifacts.join("base");
    let spec = crate::train::ModelSpec::load(&base)?;
    let model = load_trained(model_path)?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 7);
    let int8_graph = papernet_int8(
        &model.params,
        &model.ranges,
        &spec.export_keys,
        FusedActivation::Relu6,
        QuantizeOptions::default(),
    )?;
    // Geometry-derived batching hint: OH·OW of the dominant conv layer, so
    // NR-aligned batch capping engages on the real model instead of the
    // neutral default.
    let positions_hint =
        int8_graph.dominant_positions([spec.resolution, spec.resolution, spec.channels]);
    println!(
        "int8 batching: positions_hint {positions_hint} (dominant conv OH·OW), \
         intra-threads {intra_threads}"
    );
    for (label, engine, hint) in [
        ("int8", EngineKind::Quant(Arc::new(int8_graph)), positions_hint),
        (
            "float32",
            EngineKind::Float(Arc::new(papernet_from_params(
                &model.params,
                &spec.export_keys,
                FusedActivation::Relu6,
            )?)),
            // The float baseline runs no quantized GEMM; leave the
            // alignment preference off.
            1,
        ),
    ] {
        let policy = BatchPolicy {
            max_batch,
            max_delay: Duration::from_millis(2),
            positions_hint: hint,
            intra_threads,
            ..Default::default()
        };
        let coord = Coordinator::start(engine, policy, workers);
        let client = coord.client();
        let start = Instant::now();
        let pending: Vec<_> = (0..requests)
            .map(|i| {
                let (img, _) = ds.example(2, i as u64);
                client.submit(img).expect("submit")
            })
            .collect();
        for (_, rx) in pending {
            rx.recv().expect("response");
        }
        let wall = start.elapsed().as_secs_f64();
        let metrics = coord.shutdown();
        println!("{}", metrics.summary());
        println!("  [{label}] throughput {:.1} req/s over {requests} requests", requests as f64 / wall);
    }
    Ok(())
}

/// PTQ-quantize the self-contained demo PaperNet (random weights, synthetic
/// calibration) into a `.iaoiq`-ready artifact. Needs no AOT artifacts, so
/// `iaoi export` and the serving demos work on a fresh checkout; different
/// seeds give genuinely different weights (useful for hot-swap demos).
pub fn demo_artifact(name: &str, version: u32, classes: usize, seed: u64) -> ModelArtifact {
    demo_artifact_with_mode(name, version, classes, seed, QuantMode::PerTensor)
}

/// [`demo_artifact`] with an explicit weight-quantization granularity
/// (`iaoi export --quant-mode per-channel` and the quant-mode benches).
pub fn demo_artifact_with_mode(
    name: &str,
    version: u32,
    classes: usize,
    seed: u64,
    mode: QuantMode,
) -> ModelArtifact {
    let float_model = papernet_random(classes, FusedActivation::Relu6, seed);
    let mut rng = crate::data::Rng::seeded(seed ^ 0xca11b);
    let calib: Vec<Tensor<f32>> = (0..3)
        .map(|_| {
            let mut d = vec![0f32; 2 * 16 * 16 * 3];
            for v in d.iter_mut() {
                *v = rng.range_f32(-1.0, 1.0);
            }
            Tensor::from_vec(&[2, 16, 16, 3], d)
        })
        .collect();
    let (_, q) = quantize_graph(&float_model, &calib, QuantizeOptions { mode, ..Default::default() });
    ModelArtifact::new(name, version, [16, 16, 3], q)
}

/// `iaoi export`: serialize a quantized model to a `.iaoiq` artifact.
/// With `trained = Some((artifacts, model))` the QAT-trained checkpoint is
/// converted (Algorithm 1 step 4, using the learned ranges); otherwise the
/// self-contained PTQ demo model is exported. `mode` picks per-tensor or
/// per-channel weight quantization for conv/depthwise layers.
/// `verify_load` is the `--load` knob: the written file is read back under
/// that storage mode and must re-encode byte-identically — catching a torn
/// write (and exercising the checksum) before the artifact is shipped.
#[allow(clippy::too_many_arguments)]
pub fn export_model(
    out: &Path,
    name: &str,
    version: u32,
    classes: usize,
    seed: u64,
    trained: Option<(&Path, &Path)>,
    mode: QuantMode,
    verify_load: LoadMode,
) -> Result<()> {
    let artifact = match trained {
        Some((artifacts, model_path)) => {
            let spec = crate::train::ModelSpec::load(&artifacts.join("base"))?;
            let model = load_trained(model_path)?;
            let graph = papernet_int8(
                &model.params,
                &model.ranges,
                &spec.export_keys,
                FusedActivation::Relu6,
                QuantizeOptions { mode, ..Default::default() },
            )?;
            ModelArtifact::new(
                name,
                version,
                [spec.resolution, spec.resolution, spec.channels],
                graph,
            )
        }
        None => demo_artifact_with_mode(name, version, classes, seed, mode),
    };
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).with_context(|| format!("create {parent:?}"))?;
        }
    }
    let written = model_format::write_file(out, &artifact)?;
    // Read-back verification under the requested load mode: checksum plus
    // full decode, and the decoded graph must re-encode to the bytes just
    // written (deterministic encoding makes this an equality, not a fuzzy
    // check).
    let readback = model_format::read_file_with(out, verify_load)?;
    let reencoded = model_format::save(&readback).context("re-encode readback")?;
    anyhow::ensure!(
        written == reencoded,
        "readback of {out:?} under load mode {} is not byte-identical",
        verify_load.label()
    );
    println!(
        "exported model {:?} v{} -> {out:?} ({} nodes, {} weight bytes, input {:?}; \
         readback-verified, load={})",
        artifact.name,
        artifact.version,
        artifact.graph.nodes.len(),
        artifact.graph.model_bytes(),
        artifact.input_shape,
        verify_load.label(),
    );
    Ok(())
}

/// `iaoi serve --models DIR`: load every artifact in the directory into a
/// [`ModelRegistry`] and drive the multi-model coordinator with a
/// closed-loop workload round-robined across the registered models.
pub fn serve_registry(
    models_dir: &Path,
    requests: usize,
    max_batch: usize,
    workers: usize,
    intra_threads: usize,
    load: LoadMode,
) -> Result<()> {
    let registry = ModelRegistry::load_dir_with(models_dir, load)?;
    let names = registry.names();
    println!("registry: {} model(s) from {models_dir:?} (load={})", names.len(), load.label());
    for name in &names {
        let entry = registry.resolve(name)?;
        println!(
            "  {name} v{} ({} nodes, {} fused, input {:?}, positions_hint {}, weights {}, from {:?})",
            entry.version,
            entry.graph.nodes.len(),
            entry.plan.fused_nodes(),
            entry.input_shape,
            entry.positions_hint,
            if entry.is_mapped() {
                "mmap-backed"
            } else if entry.backing.is_some() {
                "shared-heap views"
            } else {
                "owned copies"
            },
            entry.source
        );
    }
    // positions_hint stays at the neutral default here: the multi-model
    // batcher uses each entry's own geometry-derived hint per group.
    let policy = BatchPolicy {
        max_batch,
        max_delay: Duration::from_millis(2),
        intra_threads,
        ..Default::default()
    };
    let coord = MultiCoordinator::start(registry.clone(), policy, workers);
    let client = coord.client();
    // Deterministic random inputs matched to each model's exact [H, W, C] —
    // artifacts are free to declare any geometry.
    let shapes: Vec<[usize; 3]> = names
        .iter()
        .map(|n| registry.resolve(n).expect("listed above").input_shape)
        .collect();
    let mut rng = crate::data::Rng::seeded(7);
    let start = Instant::now();
    let mut done = 0usize;
    while done < requests {
        let burst: Vec<_> = (0..32.min(requests - done))
            .map(|i| {
                let which = (done + i) % names.len();
                let [h, w, c] = shapes[which];
                let mut d = vec![0f32; h * w * c];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                let img = Tensor::from_vec(&[1, h, w, c], d);
                client.submit(&names[which], img).expect("submit")
            })
            .collect();
        done += burst.len();
        for (_, rx) in burst {
            rx.recv().expect("response");
        }
    }
    let wall = start.elapsed().as_secs_f64();
    for m in coord.shutdown() {
        println!("{}", m.summary());
    }
    println!("  {requests} requests across {} models in {wall:.2}s ({:.1} req/s)", names.len(), requests as f64 / wall);
    Ok(())
}

/// Set by the SIGINT/SIGTERM handler; [`serve_socket`]'s main loop polls it.
static STOP_REQUESTED: AtomicBool = AtomicBool::new(false);

/// Route SIGINT and SIGTERM to a flag instead of process death, so
/// [`serve_socket`] can drain in-flight requests before exiting. Only the
/// flag store happens in signal context (async-signal-safe); everything
/// else runs on the main thread.
#[cfg(unix)]
fn install_stop_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_stop(_sig: i32) {
        STOP_REQUESTED.store(true, Ordering::SeqCst);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_stop as usize);
        signal(SIGTERM, on_stop as usize);
    }
}

#[cfg(not(unix))]
fn install_stop_handlers() {
    // No signal routing off unix: the process serves until killed.
}

/// Knobs for [`serve_socket`] beyond the address/models/port-file trio —
/// one struct so the CLI can grow flags without another parameter sweep
/// through every caller.
#[derive(Clone, Copy, Debug)]
pub struct SocketServeOpts {
    pub max_batch: usize,
    pub workers: usize,
    pub intra_threads: usize,
    /// Global in-flight cap (0 = unbounded); past it requests are shed
    /// with 503 + `Retry-After`. CLI: `--queue-depth`.
    pub queue_depth: usize,
    /// Per-model in-flight cap (0 = unbounded). CLI: `--model-inflight-cap`.
    pub model_inflight_cap: usize,
    /// Default completion deadline for requests without `X-Deadline-Ms`,
    /// in milliseconds; expired requests are shed pre-execution with 504.
    /// 0 disables. CLI: `--request-deadline-ms`.
    pub request_deadline_ms: u64,
    /// Cap on concurrently open connections (0 = unbounded); past it the
    /// acceptor answers 503 and closes. CLI: `--max-connections`.
    pub max_connections: usize,
    /// Worker panics within the quarantine window before a model is
    /// circuit-broken (503 until hot-swapped). 0 disables the breaker.
    /// CLI: `--quarantine-threshold`.
    pub quarantine_threshold: u32,
    /// LRU residency cap: past this many resident models, each install
    /// evicts the least-recently-served model (quarantined victims first)
    /// to a reinstallable cold tombstone. 0 = unbounded.
    /// CLI: `--max-resident-models`.
    pub max_resident_models: usize,
    /// When each model's GEMM panels are packed: at install (`Eager`) or
    /// per layer on first touch (`Lazy` — cheap evict/reinstall cycles).
    /// CLI: `--prepare`; default honours `IAOI_PREPARE`.
    pub prepare: PrepareMode,
    pub load: LoadMode,
}

impl Default for SocketServeOpts {
    fn default() -> Self {
        let q = crate::coordinator::registry::QuarantineConfig::default();
        Self {
            max_batch: 8,
            workers: 2,
            intra_threads: 1,
            queue_depth: 0,
            model_inflight_cap: 0,
            request_deadline_ms: 5_000,
            max_connections: 0,
            quarantine_threshold: q.threshold,
            max_resident_models: 0,
            prepare: PrepareMode::from_env(),
            load: LoadMode::default(),
        }
    }
}

/// `iaoi serve --addr HOST:PORT`: run the socket front end
/// ([`crate::serve::Server`]) until SIGINT/SIGTERM, then drain gracefully.
/// Without `--models`, two in-memory demo models (`alpha`, 16 classes, and
/// `beta`, 8 classes) are installed so the endpoint is probe-able on a
/// fresh checkout. `port_file`, when set, receives the actually-bound
/// `HOST:PORT` once the listener is up — how scripts and CI discover an
/// ephemeral `--addr host:0` port. Everything else rides in
/// [`SocketServeOpts`].
pub fn serve_socket(
    addr: &str,
    models_dir: Option<&Path>,
    port_file: Option<&Path>,
    opts: SocketServeOpts,
) -> Result<()> {
    let SocketServeOpts {
        max_batch,
        workers,
        intra_threads,
        queue_depth,
        model_inflight_cap,
        request_deadline_ms,
        max_connections,
        quarantine_threshold,
        max_resident_models,
        prepare,
        load,
    } = opts;
    // Lifecycle knobs go on before the first install so the initial loads
    // already honour the prepare mode and the residency cap (with more
    // artifacts than cap, later loads LRU-evict earlier ones to tombstones).
    let registry = ModelRegistry::new();
    registry.set_prepare_mode(prepare);
    if max_resident_models > 0 {
        registry.set_residency(crate::coordinator::registry::ResidencyPolicy {
            max_resident_models,
        });
    }
    match models_dir {
        Some(dir) => registry.register_dir_with(dir, load)?,
        None => {
            for (name, classes, seed) in [("alpha", 16usize, 3u64), ("beta", 8, 11)] {
                registry.install(
                    demo_artifact(name, 1, classes, seed),
                    PathBuf::from(format!("<demo:{name}>")),
                );
            }
        }
    };
    registry.set_quarantine(crate::coordinator::registry::QuarantineConfig {
        threshold: quarantine_threshold,
        ..Default::default()
    });
    let policy = BatchPolicy {
        max_batch,
        max_delay: Duration::from_millis(2),
        intra_threads,
        global_inflight_cap: queue_depth,
        model_inflight_cap,
        ..Default::default()
    };
    let cfg = crate::serve::ServeConfig {
        addr: addr.to_string(),
        request_deadline: Duration::from_millis(request_deadline_ms),
        max_connections,
        ..Default::default()
    };
    let server = crate::serve::Server::start(registry, policy, workers, cfg)?;
    let bound = server.local_addr();
    if let Some(pf) = port_file {
        // Write-then-rename so a polling reader never sees a half-written
        // address.
        let tmp = pf.with_extension("tmp");
        std::fs::write(&tmp, format!("{bound}\n")).with_context(|| format!("write {tmp:?}"))?;
        std::fs::rename(&tmp, pf).with_context(|| format!("rename to {pf:?}"))?;
    }
    let cap = |n: usize| if n == 0 { "unbounded".to_string() } else { n.to_string() };
    // Logged once at startup (and exported via /metrics and /healthz) so a
    // deployed fleet can confirm it is on the SIMD fast path.
    println!("gemm kernel: {}", crate::gemm::dispatch::active().name);
    let registry = server.registry();
    for name in registry.names() {
        let entry = registry.resolve(&name)?;
        println!(
            "  {name} v{} (input {:?}, {} nodes, {} fused)",
            entry.version,
            entry.input_shape,
            entry.graph.nodes.len(),
            entry.plan.fused_nodes()
        );
    }
    println!(
        "serving on http://{bound} — {} model(s), {workers} worker(s), caps: global {}, \
         per-model {}, connections {}, resident models {}; prepare {}, deadline {}, \
         quarantine after {} panic(s)\n\
         endpoints: POST /infer/<model> (raw LE f32 body), GET /healthz, GET /metrics\n\
         Ctrl-C (or SIGTERM) drains in-flight requests and exits",
        registry.len(),
        cap(queue_depth),
        cap(model_inflight_cap),
        cap(max_connections),
        cap(max_resident_models),
        prepare.label(),
        if request_deadline_ms == 0 {
            "off".to_string()
        } else {
            format!("{request_deadline_ms} ms")
        },
        if quarantine_threshold == 0 { "∞".to_string() } else { quarantine_threshold.to_string() },
    );
    install_stop_handlers();
    while !STOP_REQUESTED.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }
    println!("signal received: draining in-flight requests");
    let report = server.shutdown();
    for m in &report.metrics {
        println!("{}", m.summary());
    }
    println!(
        "drained {} — admitted {}, shed {}",
        if report.drained_clean { "clean" } else { "TIMED OUT" },
        report.admitted,
        report.shed
    );
    Ok(())
}

/// Dispatch `iaoi bench --table <id>`.
pub fn run_table(id: &str, fast: bool) -> Result<()> {
    match id {
        "4.1" => tables::table_4_1(fast),
        "4.2" => tables::table_4_2(fast),
        "4.3" => tables::table_4_3(fast),
        "4.4" => detection::table_4_4(fast),
        "4.5" => detection::table_4_5(fast),
        "4.6" => detection::table_4_6(fast),
        "4.7" => tables::table_4_7(fast),
        "4.8" => tables::table_4_8(fast),
        "quant-modes" => tables::table_quant_modes(fast),
        "pool" => tables::table_pool(fast),
        "kernels" => tables::table_kernels(fast),
        "fusion" => tables::table_fusion(fast),
        other => Err(anyhow!("unknown table {other} (4.1-4.8, quant-modes, pool, kernels, fusion)")),
    }
}

/// Dispatch `iaoi bench --fig <id>`.
pub fn run_figure(id: &str, fast: bool) -> Result<()> {
    match id {
        "1.1c" => figures::latency_accuracy("S835-LITTLE", fast),
        "4.1" => figures::latency_accuracy("S835-big", fast),
        "4.2" => figures::latency_accuracy("S821-big", fast),
        "4.3" => figures::latency_accuracy_attributes(fast),
        other => Err(anyhow!("unknown figure {other} (1.1c, 4.1, 4.2, 4.3)")),
    }
}

/// Train one variant with the given knobs; returns (trainer, float_acc,
/// int8_engine_acc). Shared by the table/figure harnesses.
pub fn train_and_eval(
    artifacts: &Path,
    variant: &str,
    knobs: Knobs,
    steps: u64,
    seed: u64,
    eval_batches: usize,
) -> Result<(f32, f32)> {
    let dir = artifacts.join(variant);
    let mut trainer = Trainer::new(&dir, seed)?.with_knobs(knobs);
    for _ in 0..steps {
        trainer.train_step()?;
    }
    let acc_float = trainer.eval_float(eval_batches)?;
    // For the quantized number, run the *real* integer engine on exported
    // folded weights + learned ranges (not just quant-sim).
    let act = if knobs.act_ceiling > 100.0 { FusedActivation::Relu } else { FusedActivation::Relu6 };
    let params = trainer.export_folded()?;
    let ranges = trainer.learned_ranges()?;
    let spec = &trainer.spec;
    let int8 = papernet_int8(
        &params,
        &ranges,
        &spec.export_keys,
        act,
        QuantizeOptions {
            weight_bits: knobs.weight_bits,
            activation_bits: knobs.act_bits,
            ..Default::default()
        },
    )?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, seed);
    let acc_int8 = accuracy(&mut |x| int8.run(x), &ds, eval_batches, spec.batch);
    Ok((acc_float, acc_int8))
}
