//! Accuracy tables (4.1, 4.2, 4.3, 4.7, 4.8): every number comes from a
//! real QAT or float training run driven through the AOT train_step,
//! evaluated on the synthetic stand-in task (DESIGN.md §Substitutions),
//! with the quantized numbers measured on the *integer-only Rust engine*.

use super::{accuracy, load_trained, papernet_from_params, train_and_eval, topk_accuracy};
use crate::data::ClassificationSet;
use crate::nn::FusedActivation;
use crate::quant::schemes::WeightScheme;
use crate::quantize::apply_weight_scheme;
use crate::train::{Knobs, Trainer, RELU_CEIL};
use anyhow::Result;
use std::path::{Path, PathBuf};

fn artifacts() -> PathBuf {
    PathBuf::from("artifacts")
}

fn steps(fast: bool) -> u64 {
    if fast {
        120
    } else {
        400
    }
}

fn eval_batches(fast: bool) -> usize {
    if fast {
        4
    } else {
        10
    }
}

/// Table 4.1 — float vs integer-quantized accuracy across network depths.
/// Paper: ResNet-{50,100,150} on ImageNet, gap within ~2%. Ours:
/// PaperNet-{6,8,10 conv layers} on SynthShapes; same protocol (separate
/// float and QAT runs, integer engine for the quantized number).
pub fn table_4_1(fast: bool) -> Result<()> {
    println!("# Table 4.1 — float vs integer-quantized accuracy across depths");
    println!("| depth (conv layers) | float acc | int8 acc | gap |");
    println!("|---|---|---|---|");
    for (variant, depth) in [("base", 6), ("d2", 8), ("d3", 10)] {
        let (float_acc, _) =
            train_and_eval(&artifacts(), variant, Knobs::float_baseline(), steps(fast), 1, eval_batches(fast))?;
        let (_, int8_acc) =
            train_and_eval(&artifacts(), variant, Knobs::default(), steps(fast), 1, eval_batches(fast))?;
        println!(
            "| {depth} | {:.1}% | {:.1}% | {:+.1}% |",
            float_acc * 100.0,
            int8_acc * 100.0,
            (int8_acc - float_acc) * 100.0
        );
    }
    Ok(())
}

/// Table 4.2 — accuracy under different quantization schemes. Paper:
/// BWN/TWN/INQ/FGQ vs ours on ResNet50. Ours: the same weight-only
/// baselines applied to the float-trained PaperNet (running on the float
/// engine, as those schemes deploy), vs our full integer path.
pub fn table_4_2(fast: bool) -> Result<()> {
    println!("# Table 4.2 — accuracy under various quantization schemes");
    let arts = artifacts();
    let dir = arts.join("base");
    // One float training run; schemes post-process its weights.
    let mut trainer = Trainer::new(&dir, 2)?.with_knobs(Knobs::float_baseline());
    for _ in 0..steps(fast) {
        trainer.train_step()?;
    }
    let params = trainer.export_folded()?;
    let spec = trainer.spec.clone();
    let float_graph = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6)?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 2);
    let base_acc = accuracy(&mut |x| float_graph.run(x), &ds, eval_batches(fast), spec.batch);

    // Our scheme: QAT + integer engine.
    let (_, ours_acc) =
        train_and_eval(&arts, "base", Knobs::default(), steps(fast), 2, eval_batches(fast))?;

    println!("| scheme | weight bits | act bits | accuracy |");
    println!("|---|---|---|---|");
    println!("| float baseline | 32 | float32 | {:.1}% |", base_acc * 100.0);
    for (name, scheme) in [
        ("BWN (binary)", WeightScheme::Binary),
        ("TWN (ternary)", WeightScheme::Ternary),
        ("INQ (pow2, 5-bit)", WeightScheme::PowerOfTwo { bits: 5 }),
        ("FGQ (group ternary)", WeightScheme::FineGrainedTernary { group_size: 4 }),
    ] {
        let g = apply_weight_scheme(&float_graph, scheme);
        let acc = accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch);
        println!(
            "| {name} | {} | float32 | {:.1}% |",
            scheme.weight_bits(),
            acc * 100.0
        );
    }
    println!("| **Ours (integer-only)** | 8 | 8 | {:.1}% |", ours_acc * 100.0);
    Ok(())
}

/// Table 4.3 — ReLU vs ReLU6 at float/8/7 bits, mean ± std over seeds.
/// Paper: Inception v3 on ImageNet. Ours: PaperNet on SynthShapes with the
/// activation ceiling and bit depth as traced knobs of one artifact.
pub fn table_4_3(fast: bool) -> Result<()> {
    println!("# Table 4.3 — accuracy and recall@2 by activation fn and bit depth");
    println!("| act | type | top-1 mean | top-1 std | recall@2 mean |");
    println!("|---|---|---|---|---|");
    let seeds: &[u64] = if fast { &[1, 2] } else { &[1, 2, 3] };
    for (act_name, ceiling) in [("ReLU6", 6.0f32), ("ReLU", RELU_CEIL)] {
        for (ty, bits) in [("floats", 0u32), ("8 bits", 8), ("7 bits", 7)] {
            let mut top1 = Vec::new();
            let mut top2 = Vec::new();
            for &seed in seeds {
                let knobs = if bits == 0 {
                    Knobs { w_quant_on: 0.0, act_ceiling: ceiling, ..Knobs::default() }
                } else {
                    Knobs { act_ceiling: ceiling, weight_bits: bits, act_bits: bits, ..Knobs::default() }
                };
                let (acc1, acc2) = run_with_recall(&artifacts(), knobs, steps(fast), seed, eval_batches(fast))?;
                top1.push(acc1);
                top2.push(acc2);
            }
            let (m1, s1) = mean_std(&top1);
            let (m2, _) = mean_std(&top2);
            println!(
                "| {act_name} | {ty} | {:.1}% | {:.1}% | {:.1}% |",
                m1 * 100.0,
                s1 * 100.0,
                m2 * 100.0
            );
        }
    }
    Ok(())
}

/// One training run returning (top-1, top-2) on the appropriate engine.
fn run_with_recall(
    arts: &Path,
    knobs: Knobs,
    steps: u64,
    seed: u64,
    batches: usize,
) -> Result<(f32, f32)> {
    let dir = arts.join("base");
    let mut trainer = Trainer::new(&dir, seed)?.with_knobs(knobs);
    for _ in 0..steps {
        trainer.train_step()?;
    }
    let spec = trainer.spec.clone();
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, seed);
    let act = if knobs.act_ceiling > 100.0 { FusedActivation::Relu } else { FusedActivation::Relu6 };
    if knobs.w_quant_on == 0.0 {
        // Float model: evaluate the float engine on folded weights.
        let params = trainer.export_folded()?;
        let g = papernet_from_params(&params, &spec.export_keys, act)?;
        let a1 = accuracy(&mut |x| g.run(x), &ds, batches, spec.batch);
        let a2 = topk_accuracy(&mut |x| g.run(x), &ds, batches, spec.batch, 2);
        Ok((a1, a2))
    } else {
        let params = trainer.export_folded()?;
        let ranges = trainer.learned_ranges()?;
        let g = super::papernet_int8(
            &params,
            &ranges,
            &spec.export_keys,
            act,
            crate::quantize::QuantizeOptions {
                weight_bits: knobs.weight_bits,
                activation_bits: knobs.act_bits,
                ..Default::default()
            },
        )?;
        let a1 = accuracy(&mut |x| g.run(x), &ds, batches, spec.batch);
        let a2 = topk_accuracy(&mut |x| g.run(x), &ds, batches, spec.batch, 2);
        Ok((a1, a2))
    }
}

fn mean_std(xs: &[f32]) -> (f32, f32) {
    let n = xs.len() as f32;
    let mean = xs.iter().sum::<f32>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    (mean, var.sqrt())
}

/// Tables 4.7/4.8 — bit-depth ablation grid. Paper: face-attribute mAP and
/// age precision vs (weight bits × activation bits), relative to float.
/// Ours: top-1 (4.7) and recall@2 (4.8) on SynthShapes, relative to the
/// float baseline, integer engine throughout.
fn bit_grid(fast: bool, metric_topk: usize, title: &str) -> Result<()> {
    println!("# {title}");
    let arts = artifacts();
    let bit_list: &[u32] = if fast { &[8, 6, 4] } else { &[8, 7, 6, 5, 4] };
    // Float baseline once.
    let (baseline, _) = {
        let knobs = Knobs::float_baseline();
        let dir = arts.join("base");
        let mut trainer = Trainer::new(&dir, 3)?.with_knobs(knobs);
        for _ in 0..steps(fast) {
            trainer.train_step()?;
        }
        let spec = trainer.spec.clone();
        let params = trainer.export_folded()?;
        let g = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6)?;
        let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 3);
        let a = if metric_topk == 1 {
            accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch)
        } else {
            topk_accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch, metric_topk)
        };
        (a, 0.0)
    };
    println!("float baseline: {:.1}%", baseline * 100.0);
    print!("| wt \\\\ act |");
    for ab in bit_list {
        print!(" {ab} |");
    }
    println!();
    print!("|---|");
    for _ in bit_list {
        print!("---|");
    }
    println!();
    for &wb in bit_list {
        print!("| {wb} |");
        for &ab in bit_list {
            let knobs = Knobs { weight_bits: wb, act_bits: ab, ..Knobs::default() };
            let dir = arts.join("base");
            let mut trainer = Trainer::new(&dir, 3)?.with_knobs(knobs);
            for _ in 0..steps(fast) {
                trainer.train_step()?;
            }
            let spec = trainer.spec.clone();
            let params = trainer.export_folded()?;
            let ranges = trainer.learned_ranges()?;
            let g = super::papernet_int8(
                &params,
                &ranges,
                &spec.export_keys,
                FusedActivation::Relu6,
                crate::quantize::QuantizeOptions {
                    weight_bits: wb,
                    activation_bits: ab,
                    ..Default::default()
                },
            )?;
            let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 3);
            let a = if metric_topk == 1 {
                accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch)
            } else {
                topk_accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch, metric_topk)
            };
            print!(" {:+.1}% |", (a - baseline) * 100.0);
        }
        println!();
    }
    Ok(())
}

/// Table 4.7 — top-1 accuracy relative to float, by (weight, act) bits.
pub fn table_4_7(fast: bool) -> Result<()> {
    bit_grid(
        fast,
        1,
        "Table 4.7 — relative top-1 accuracy vs float, by weight x activation bit depth",
    )
}

/// Table 4.8 — second metric (recall@2) relative to float, same grid.
pub fn table_4_8(fast: bool) -> Result<()> {
    bit_grid(
        fast,
        2,
        "Table 4.8 — relative recall@2 vs float, by weight x activation bit depth (age-precision substitute)",
    )
}

/// Per-tensor vs per-channel PTQ quality on the synth depthwise model
/// (PaperNet with heterogeneous depthwise channel ranges — the BN-fold
/// failure mode of Krishnamoorthi 1806.08342). The model is PTQ'd from
/// builder weights, so label accuracy is chance for every engine; the
/// table therefore reports *fidelity to the float engine* (argmax
/// agreement on the synth eval split) plus the mean logit error — the
/// quantization-quality measures that do not require a training run.
pub struct QuantModeReport {
    /// Fraction of eval examples whose argmax matches the float engine.
    pub per_tensor_fidelity: f32,
    pub per_channel_fidelity: f32,
    /// Mean |logit difference| vs the float engine.
    pub per_tensor_logit_err: f64,
    pub per_channel_logit_err: f64,
    /// Same measures on the wide-classifier-head model
    /// ([`crate::graph::builders::papernet_wide_head`]) — the workload
    /// per-channel **FC** quantization targets: FC output units with a
    /// 256x magnitude spread, where one per-tensor scale wipes the quiet
    /// units' resolution.
    pub wide_head_per_tensor_fidelity: f32,
    pub wide_head_per_channel_fidelity: f32,
    pub wide_head_per_tensor_logit_err: f64,
    pub wide_head_per_channel_logit_err: f64,
}

/// Per-tensor vs per-channel PTQ of one float graph: returns
/// `(pt_fidelity, pc_fidelity, pt_logit_err, pc_logit_err)` against the
/// folded float engine.
fn compare_quant_modes(
    g: &crate::graph::FloatGraph,
    seed: u64,
    fast: bool,
) -> (f32, f32, f64, f64) {
    use crate::quantize::{quantize_graph, QuantMode, QuantizeOptions};
    use crate::tensor::Tensor;

    let ds = ClassificationSet::new(16, 16, seed);
    let batch = 16usize;
    let calib: Vec<Tensor<f32>> =
        (0..3).map(|b| ds.batch(0, (b * batch) as u64, batch).0).collect();
    let (folded, q_pt) = quantize_graph(g, &calib, QuantizeOptions::default());
    let (_, q_pc) = quantize_graph(
        g,
        &calib,
        QuantizeOptions { mode: QuantMode::PerChannel, ..Default::default() },
    );

    let batches = eval_batches(fast);
    let (mut agree_pt, mut agree_pc, mut total) = (0usize, 0usize, 0usize);
    let (mut err_pt, mut err_pc, mut elems) = (0f64, 0f64, 0usize);
    for b in 0..batches {
        let (x, _) = ds.batch(1, (b * batch) as u64, batch);
        let want = folded.run(&x);
        let got_pt = q_pt.run(&x);
        let got_pc = q_pc.run(&x);
        let classes = want.dim(1);
        let argmax = |t: &Tensor<f32>, row: usize| {
            (0..classes)
                .max_by(|&i, &j| {
                    t.data()[row * classes + i].partial_cmp(&t.data()[row * classes + j]).unwrap()
                })
                .unwrap()
        };
        for row in 0..batch {
            agree_pt += usize::from(argmax(&want, row) == argmax(&got_pt, row));
            agree_pc += usize::from(argmax(&want, row) == argmax(&got_pc, row));
            total += 1;
        }
        for ((w, p), c) in want.data().iter().zip(got_pt.data()).zip(got_pc.data()) {
            err_pt += f64::from((w - p).abs());
            err_pc += f64::from((w - c).abs());
            elems += 1;
        }
    }
    (
        agree_pt as f32 / total as f32,
        agree_pc as f32 / total as f32,
        err_pt / elems as f64,
        err_pc / elems as f64,
    )
}

/// Compute the quant-mode comparison (shared by the table printer and the
/// acceptance test in `rust/tests/integration.rs`): the heterogeneous
/// depthwise model (per-channel conv/dw story) and the wide-classifier-head
/// model (per-channel FC story).
pub fn quant_mode_report(fast: bool) -> QuantModeReport {
    use crate::graph::builders;

    let (pt_f, pc_f, pt_e, pc_e) =
        compare_quant_modes(&builders::papernet_heterogeneous_dw(16, 5), 5, fast);
    let (wh_pt_f, wh_pc_f, wh_pt_e, wh_pc_e) =
        compare_quant_modes(&builders::papernet_wide_head(16, 7), 7, fast);
    QuantModeReport {
        per_tensor_fidelity: pt_f,
        per_channel_fidelity: pc_f,
        per_tensor_logit_err: pt_e,
        per_channel_logit_err: pc_e,
        wide_head_per_tensor_fidelity: wh_pt_f,
        wide_head_per_channel_fidelity: wh_pc_f,
        wide_head_per_tensor_logit_err: wh_pt_e,
        wide_head_per_channel_logit_err: wh_pc_e,
    }
}

/// `iaoi bench --table quant-modes` — per-tensor vs per-channel weight
/// quantization on the synth depthwise model. Unlike the 4.x tables this
/// needs no training run, so it works without the AOT artifacts.
pub fn table_quant_modes(fast: bool) -> Result<()> {
    let r = quant_mode_report(fast);
    println!("# Quant modes — per-tensor vs per-channel PTQ on synthetic stress models");
    println!("| model | weight quantization | float-argmax fidelity | mean logit err |");
    println!("|---|---|---|---|");
    println!(
        "| heterogeneous depthwise | per-tensor (paper §2.1) | {:.1}% | {:.4} |",
        r.per_tensor_fidelity * 100.0,
        r.per_tensor_logit_err
    );
    println!(
        "| heterogeneous depthwise | per-channel (1806.08342) | {:.1}% | {:.4} |",
        r.per_channel_fidelity * 100.0,
        r.per_channel_logit_err
    );
    println!(
        "| wide classifier head | per-tensor (paper §2.1) | {:.1}% | {:.4} |",
        r.wide_head_per_tensor_fidelity * 100.0,
        r.wide_head_per_tensor_logit_err
    );
    println!(
        "| wide classifier head | per-channel FC (1806.08342) | {:.1}% | {:.4} |",
        r.wide_head_per_channel_fidelity * 100.0,
        r.wide_head_per_channel_logit_err
    );
    println!(
        "\nper-channel improves mean logit error by {:.1}% on heterogeneous depthwise channels \
         and {:.1}% on the wide classifier head",
        (1.0 - r.per_channel_logit_err / r.per_tensor_logit_err.max(1e-12)) * 100.0,
        (1.0 - r.wide_head_per_channel_logit_err / r.wide_head_per_tensor_logit_err.max(1e-12))
            * 100.0
    );
    Ok(())
}

/// `iaoi bench --table pool` — persistent worker pool vs per-call scoped
/// spawns vs serial on a detector-shaped prepared GEMM (72×648, the §4.2.3
/// face-detector geometry) across activation widths N. The pool and scoped
/// paths split identically; the delta is pure thread provisioning, i.e.
/// exactly what the persistent pool amortizes. On a single core the
/// absolute speedups are ≤ 1; the pool-vs-scoped ratio is meaningful
/// everywhere.
pub fn table_pool(fast: bool) -> Result<()> {
    use crate::gemm::output::OutputStage;
    use crate::gemm::parallel::run_strips_scoped;
    use crate::gemm::{Kernel, PreparedGemm, QGemm, Scratch, WorkerPool};
    use crate::quant::QuantizedMultiplier;
    use super::time_median_ms;

    let (m, k) = (72usize, 648usize);
    let threads = 4usize;
    let iters = if fast { 5 } else { 15 };
    let mut rng = crate::data::Rng::seeded(46);
    let lhs: Vec<u8> = (0..m * k).map(|_| 1 + rng.below(255) as u8).collect();
    let g = QGemm::new(m, k, 1, 128, 111);
    let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.003), 10);
    let plan = PreparedGemm::from_qgemm(&g, Kernel::Int8Pairwise, &lhs, stage);
    let pool = WorkerPool::new(threads);
    let mut pool_scratch = Scratch::new();

    println!(
        "# Pool — persistent worker pool vs scoped spawns vs serial ({m}x{k}, {threads} threads)"
    );
    println!("| N | serial ms | scoped ms | pool ms | pool vs scoped | pool vs serial |");
    println!("|---|---|---|---|---|---|");
    for n in [64usize, 256, 1024, 4096] {
        let rhs: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let mut serial_out = vec![0u8; m * n];
        let mut scoped_out = vec![0u8; m * n];
        let mut pool_out = vec![0u8; m * n];
        let mut serial_scratch = Scratch::new();
        let serial_ms =
            time_median_ms(iters, || plan.run(n, &rhs, &mut serial_out, &mut serial_scratch));
        let scoped_ms =
            time_median_ms(iters, || run_strips_scoped(&plan, &rhs, n, &mut scoped_out, threads));
        let pool_ms = time_median_ms(iters, || {
            pool.run_strips(&plan, &rhs, n, &mut pool_out, &mut pool_scratch)
        });
        // The three paths must agree bit-for-bit or the timings are noise.
        anyhow::ensure!(serial_out == scoped_out, "scoped diverged at N={n}");
        anyhow::ensure!(serial_out == pool_out, "pool diverged at N={n}");
        println!(
            "| {n} | {serial_ms:.3} | {scoped_ms:.3} | {pool_ms:.3} | {:.2}x | {:.2}x |",
            scoped_ms / pool_ms.max(1e-9),
            serial_ms / pool_ms.max(1e-9),
        );
    }
    println!("\n(host cores: {}; single-core testbeds measure provisioning overhead only)",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    Ok(())
}

/// `iaoi bench --table kernels` — scalar vs every detected SIMD micro-kernel
/// ([`crate::gemm::dispatch`]), first on raw GEMM accumulation across
/// conv/FC-shaped geometries, then on whole-model prepared inference with
/// the kernel pinned per plan. Every timed case is guarded by byte-equality
/// against the scalar golden output: a diverging kernel aborts the table
/// instead of reporting a bogus speedup.
pub fn table_kernels(fast: bool) -> Result<()> {
    use super::time_median_ms;
    use crate::gemm::dispatch;
    use crate::gemm::kernel::accumulate_blocked_with;
    use crate::gemm::QGemm;
    use crate::graph::ExecState;
    use crate::nn::QTensor;
    use crate::tensor::Tensor;

    let impls = dispatch::available();
    let iters = if fast { 3 } else { 9 };
    println!(
        "# Kernels — runtime-dispatched GEMM micro-kernels (active: {}, compiled: {})",
        dispatch::active().name,
        dispatch::all().iter().map(|d| d.name).collect::<Vec<_>>().join("/"),
    );

    println!("\n## Raw GEMM accumulation (i32 out)");
    println!("| m | k | n | kernel | median ms | GMAC/s | vs scalar |");
    println!("|---|---|---|---|---|---|---|");
    let mut rng = crate::data::Rng::seeded(91);
    for (m, k, n) in
        [(64usize, 288usize, 256usize), (256, 256, 196), (128, 1152, 64), (1024, 1024, 16)]
    {
        let lhs: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let rhs: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let g = QGemm::new(m, k, n, 77, 201);
        let mut golden = vec![0i32; m * n];
        accumulate_blocked_with(dispatch::scalar(), &g, &lhs, &rhs, &mut golden);
        let mut scalar_ms = f64::NAN;
        for d in impls.iter().copied() {
            let mut acc = vec![0i32; m * n];
            let ms =
                time_median_ms(iters, || accumulate_blocked_with(d, &g, &lhs, &rhs, &mut acc));
            anyhow::ensure!(
                acc == golden,
                "{} diverged from scalar at ({m},{k},{n}) — timing withheld",
                d.name
            );
            if d.name == "scalar" {
                scalar_ms = ms;
            }
            let gmacs = (m * k * n) as f64 / ms / 1e6;
            println!(
                "| {m} | {k} | {n} | {} | {ms:.3} | {gmacs:.2} | {:.2}x |",
                d.name,
                scalar_ms / ms.max(1e-9)
            );
        }
    }

    // Whole-model: the demo PaperNet through prepared plans with the
    // micro-kernel pinned per plan (conv + FC dispatch through it; the
    // depthwise layer has no GEMM and rides along unchanged).
    println!("\n## Whole-model prepared inference (papernet demo, batch 8)");
    println!("| kernel | median ms | vs scalar |");
    println!("|---|---|---|");
    let q = super::demo_artifact("kernel-sweep", 1, 16, 5).graph;
    let batch = 8usize;
    let mut d = vec![0f32; batch * 16 * 16 * 3];
    for v in d.iter_mut() {
        *v = rng.range_f32(-1.0, 1.0);
    }
    let x = Tensor::from_vec(&[batch, 16, 16, 3], d);
    let qin = QTensor::quantize(&x, q.input_params);
    let mut golden: Vec<u8> = Vec::new();
    let mut scalar_ms = f64::NAN;
    for d in impls.iter().copied() {
        let mut plan = q.prepare();
        plan.set_ukernel(d);
        let mut state = ExecState::new();
        let out = plan.run_q(&qin, &mut state).data.data().to_vec();
        if d.name == "scalar" {
            golden = out.clone();
        }
        anyhow::ensure!(
            out == golden,
            "{} whole-model output diverged from scalar — timing withheld",
            d.name
        );
        let ms = time_median_ms(iters, || {
            std::hint::black_box(plan.run_q(&qin, &mut state).data.len());
        });
        if d.name == "scalar" {
            scalar_ms = ms;
        }
        println!("| {} | {ms:.3} | {:.2}x |", d.name, scalar_ms / ms.max(1e-9));
    }
    println!(
        "\n(impls are listed scalar-first, so \"vs scalar\" is measured against this run's \
         own scalar timing; IAOI_KERNEL forces the serving default)"
    );
    Ok(())
}

/// `iaoi bench --table fusion` — conv→Add epilogue fusion on the residual
/// mini-resnet: the same quantized graph prepared with the rewrite on vs
/// off ([`crate::graph::PreparedGraph::set_fusion`]), swept over every
/// detected GEMM micro-kernel. The two plans must agree byte-for-byte
/// before any timing is reported — fusion's contract is bit-identity, so a
/// divergence aborts the table instead of printing a bogus speedup.
pub fn table_fusion(fast: bool) -> Result<()> {
    use super::time_median_ms;
    use crate::gemm::dispatch;
    use crate::graph::{builders, ExecState};
    use crate::nn::QTensor;
    use crate::quantize::{quantize_graph, QuantizeOptions};
    use crate::tensor::Tensor;

    let iters = if fast { 3 } else { 9 };
    let mut rng = crate::data::Rng::seeded(75);
    let mk = |rng: &mut crate::data::Rng, batch: usize| {
        let mut d = vec![0f32; batch * 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        Tensor::from_vec(&[batch, 16, 16, 3], d)
    };
    let g = builders::mini_resnet(1, 8, 75);
    let calib = vec![mk(&mut rng, 2)];
    let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
    let fused_nodes = q.prepare().fused_nodes();
    anyhow::ensure!(fused_nodes >= 1, "mini-resnet discovered no conv→Add fusion");

    println!(
        "# Fusion — conv→Add folded into the GEMM output stage \
         (mini_resnet, {fused_nodes} fused nodes, active kernel: {})",
        dispatch::active().name
    );
    println!("| batch | kernel | unfused ms | fused ms | speedup |");
    println!("|---|---|---|---|---|");
    for batch in [1usize, 8] {
        let qin = QTensor::quantize(&mk(&mut rng, batch), q.input_params);
        for d in dispatch::available() {
            let fused_plan = q.prepare().with_fusion(true).with_ukernel(d);
            let unfused_plan = q.prepare().with_fusion(false).with_ukernel(d);
            let mut sf = ExecState::new();
            let mut su = ExecState::new();
            let want = unfused_plan.run_q(&qin, &mut su).data.data().to_vec();
            let got = fused_plan.run_q(&qin, &mut sf).data.data().to_vec();
            anyhow::ensure!(
                got == want,
                "{}: fused output diverged from unfused at batch {batch} — timing withheld",
                d.name
            );
            let unfused_ms = time_median_ms(iters, || {
                std::hint::black_box(unfused_plan.run_q(&qin, &mut su).data.len());
            });
            let fused_ms = time_median_ms(iters, || {
                std::hint::black_box(fused_plan.run_q(&qin, &mut sf).data.len());
            });
            println!(
                "| {batch} | {} | {unfused_ms:.3} | {fused_ms:.3} | {:.2}x |",
                d.name,
                unfused_ms / fused_ms.max(1e-9)
            );
        }
    }
    println!(
        "\n(both plans come from the same quantized graph; `IAOI_FUSION=off` forces the \
         unfused path process-wide for differential runs)"
    );
    Ok(())
}

/// Used by `eval` when a saved model exists; re-exported for tests.
pub fn quick_eval(model_path: &Path) -> Result<f32> {
    let arts = artifacts();
    let spec = crate::train::ModelSpec::load(&arts.join("base"))?;
    let model = load_trained(model_path)?;
    let g = papernet_from_params(&model.params, &spec.export_keys, FusedActivation::Relu6)?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 0);
    Ok(accuracy(&mut |x| g.run(x), &ds, 4, spec.batch))
}
