//! Accuracy tables (4.1, 4.2, 4.3, 4.7, 4.8): every number comes from a
//! real QAT or float training run driven through the AOT train_step,
//! evaluated on the synthetic stand-in task (DESIGN.md §Substitutions),
//! with the quantized numbers measured on the *integer-only Rust engine*.

use super::{accuracy, load_trained, papernet_from_params, train_and_eval, topk_accuracy};
use crate::data::ClassificationSet;
use crate::nn::FusedActivation;
use crate::quant::schemes::WeightScheme;
use crate::quantize::apply_weight_scheme;
use crate::train::{Knobs, Trainer, RELU_CEIL};
use anyhow::Result;
use std::path::{Path, PathBuf};

fn artifacts() -> PathBuf {
    PathBuf::from("artifacts")
}

fn steps(fast: bool) -> u64 {
    if fast {
        120
    } else {
        400
    }
}

fn eval_batches(fast: bool) -> usize {
    if fast {
        4
    } else {
        10
    }
}

/// Table 4.1 — float vs integer-quantized accuracy across network depths.
/// Paper: ResNet-{50,100,150} on ImageNet, gap within ~2%. Ours:
/// PaperNet-{6,8,10 conv layers} on SynthShapes; same protocol (separate
/// float and QAT runs, integer engine for the quantized number).
pub fn table_4_1(fast: bool) -> Result<()> {
    println!("# Table 4.1 — float vs integer-quantized accuracy across depths");
    println!("| depth (conv layers) | float acc | int8 acc | gap |");
    println!("|---|---|---|---|");
    for (variant, depth) in [("base", 6), ("d2", 8), ("d3", 10)] {
        let (float_acc, _) =
            train_and_eval(&artifacts(), variant, Knobs::float_baseline(), steps(fast), 1, eval_batches(fast))?;
        let (_, int8_acc) =
            train_and_eval(&artifacts(), variant, Knobs::default(), steps(fast), 1, eval_batches(fast))?;
        println!(
            "| {depth} | {:.1}% | {:.1}% | {:+.1}% |",
            float_acc * 100.0,
            int8_acc * 100.0,
            (int8_acc - float_acc) * 100.0
        );
    }
    Ok(())
}

/// Table 4.2 — accuracy under different quantization schemes. Paper:
/// BWN/TWN/INQ/FGQ vs ours on ResNet50. Ours: the same weight-only
/// baselines applied to the float-trained PaperNet (running on the float
/// engine, as those schemes deploy), vs our full integer path.
pub fn table_4_2(fast: bool) -> Result<()> {
    println!("# Table 4.2 — accuracy under various quantization schemes");
    let arts = artifacts();
    let dir = arts.join("base");
    // One float training run; schemes post-process its weights.
    let mut trainer = Trainer::new(&dir, 2)?.with_knobs(Knobs::float_baseline());
    for _ in 0..steps(fast) {
        trainer.train_step()?;
    }
    let params = trainer.export_folded()?;
    let spec = trainer.spec.clone();
    let float_graph = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6)?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 2);
    let base_acc = accuracy(&mut |x| float_graph.run(x), &ds, eval_batches(fast), spec.batch);

    // Our scheme: QAT + integer engine.
    let (_, ours_acc) =
        train_and_eval(&arts, "base", Knobs::default(), steps(fast), 2, eval_batches(fast))?;

    println!("| scheme | weight bits | act bits | accuracy |");
    println!("|---|---|---|---|");
    println!("| float baseline | 32 | float32 | {:.1}% |", base_acc * 100.0);
    for (name, scheme) in [
        ("BWN (binary)", WeightScheme::Binary),
        ("TWN (ternary)", WeightScheme::Ternary),
        ("INQ (pow2, 5-bit)", WeightScheme::PowerOfTwo { bits: 5 }),
        ("FGQ (group ternary)", WeightScheme::FineGrainedTernary { group_size: 4 }),
    ] {
        let g = apply_weight_scheme(&float_graph, scheme);
        let acc = accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch);
        println!(
            "| {name} | {} | float32 | {:.1}% |",
            scheme.weight_bits(),
            acc * 100.0
        );
    }
    println!("| **Ours (integer-only)** | 8 | 8 | {:.1}% |", ours_acc * 100.0);
    Ok(())
}

/// Table 4.3 — ReLU vs ReLU6 at float/8/7 bits, mean ± std over seeds.
/// Paper: Inception v3 on ImageNet. Ours: PaperNet on SynthShapes with the
/// activation ceiling and bit depth as traced knobs of one artifact.
pub fn table_4_3(fast: bool) -> Result<()> {
    println!("# Table 4.3 — accuracy and recall@2 by activation fn and bit depth");
    println!("| act | type | top-1 mean | top-1 std | recall@2 mean |");
    println!("|---|---|---|---|---|");
    let seeds: &[u64] = if fast { &[1, 2] } else { &[1, 2, 3] };
    for (act_name, ceiling) in [("ReLU6", 6.0f32), ("ReLU", RELU_CEIL)] {
        for (ty, bits) in [("floats", 0u32), ("8 bits", 8), ("7 bits", 7)] {
            let mut top1 = Vec::new();
            let mut top2 = Vec::new();
            for &seed in seeds {
                let knobs = if bits == 0 {
                    Knobs { w_quant_on: 0.0, act_ceiling: ceiling, ..Knobs::default() }
                } else {
                    Knobs { act_ceiling: ceiling, weight_bits: bits, act_bits: bits, ..Knobs::default() }
                };
                let (acc1, acc2) = run_with_recall(&artifacts(), knobs, steps(fast), seed, eval_batches(fast))?;
                top1.push(acc1);
                top2.push(acc2);
            }
            let (m1, s1) = mean_std(&top1);
            let (m2, _) = mean_std(&top2);
            println!(
                "| {act_name} | {ty} | {:.1}% | {:.1}% | {:.1}% |",
                m1 * 100.0,
                s1 * 100.0,
                m2 * 100.0
            );
        }
    }
    Ok(())
}

/// One training run returning (top-1, top-2) on the appropriate engine.
fn run_with_recall(
    arts: &Path,
    knobs: Knobs,
    steps: u64,
    seed: u64,
    batches: usize,
) -> Result<(f32, f32)> {
    let dir = arts.join("base");
    let mut trainer = Trainer::new(&dir, seed)?.with_knobs(knobs);
    for _ in 0..steps {
        trainer.train_step()?;
    }
    let spec = trainer.spec.clone();
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, seed);
    let act = if knobs.act_ceiling > 100.0 { FusedActivation::Relu } else { FusedActivation::Relu6 };
    if knobs.w_quant_on == 0.0 {
        // Float model: evaluate the float engine on folded weights.
        let params = trainer.export_folded()?;
        let g = papernet_from_params(&params, &spec.export_keys, act)?;
        let a1 = accuracy(&mut |x| g.run(x), &ds, batches, spec.batch);
        let a2 = topk_accuracy(&mut |x| g.run(x), &ds, batches, spec.batch, 2);
        Ok((a1, a2))
    } else {
        let params = trainer.export_folded()?;
        let ranges = trainer.learned_ranges()?;
        let g = super::papernet_int8(
            &params,
            &ranges,
            &spec.export_keys,
            act,
            crate::quantize::QuantizeOptions {
                weight_bits: knobs.weight_bits,
                activation_bits: knobs.act_bits,
                kernel: crate::gemm::Kernel::default(),
            },
        )?;
        let a1 = accuracy(&mut |x| g.run(x), &ds, batches, spec.batch);
        let a2 = topk_accuracy(&mut |x| g.run(x), &ds, batches, spec.batch, 2);
        Ok((a1, a2))
    }
}

fn mean_std(xs: &[f32]) -> (f32, f32) {
    let n = xs.len() as f32;
    let mean = xs.iter().sum::<f32>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    (mean, var.sqrt())
}

/// Tables 4.7/4.8 — bit-depth ablation grid. Paper: face-attribute mAP and
/// age precision vs (weight bits × activation bits), relative to float.
/// Ours: top-1 (4.7) and recall@2 (4.8) on SynthShapes, relative to the
/// float baseline, integer engine throughout.
fn bit_grid(fast: bool, metric_topk: usize, title: &str) -> Result<()> {
    println!("# {title}");
    let arts = artifacts();
    let bit_list: &[u32] = if fast { &[8, 6, 4] } else { &[8, 7, 6, 5, 4] };
    // Float baseline once.
    let (baseline, _) = {
        let knobs = Knobs::float_baseline();
        let dir = arts.join("base");
        let mut trainer = Trainer::new(&dir, 3)?.with_knobs(knobs);
        for _ in 0..steps(fast) {
            trainer.train_step()?;
        }
        let spec = trainer.spec.clone();
        let params = trainer.export_folded()?;
        let g = papernet_from_params(&params, &spec.export_keys, FusedActivation::Relu6)?;
        let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 3);
        let a = if metric_topk == 1 {
            accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch)
        } else {
            topk_accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch, metric_topk)
        };
        (a, 0.0)
    };
    println!("float baseline: {:.1}%", baseline * 100.0);
    print!("| wt \\\\ act |");
    for ab in bit_list {
        print!(" {ab} |");
    }
    println!();
    print!("|---|");
    for _ in bit_list {
        print!("---|");
    }
    println!();
    for &wb in bit_list {
        print!("| {wb} |");
        for &ab in bit_list {
            let knobs = Knobs { weight_bits: wb, act_bits: ab, ..Knobs::default() };
            let dir = arts.join("base");
            let mut trainer = Trainer::new(&dir, 3)?.with_knobs(knobs);
            for _ in 0..steps(fast) {
                trainer.train_step()?;
            }
            let spec = trainer.spec.clone();
            let params = trainer.export_folded()?;
            let ranges = trainer.learned_ranges()?;
            let g = super::papernet_int8(
                &params,
                &ranges,
                &spec.export_keys,
                FusedActivation::Relu6,
                crate::quantize::QuantizeOptions {
                    weight_bits: wb,
                    activation_bits: ab,
                    kernel: crate::gemm::Kernel::default(),
                },
            )?;
            let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 3);
            let a = if metric_topk == 1 {
                accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch)
            } else {
                topk_accuracy(&mut |x| g.run(x), &ds, eval_batches(fast), spec.batch, metric_topk)
            };
            print!(" {:+.1}% |", (a - baseline) * 100.0);
        }
        println!();
    }
    Ok(())
}

/// Table 4.7 — top-1 accuracy relative to float, by (weight, act) bits.
pub fn table_4_7(fast: bool) -> Result<()> {
    bit_grid(
        fast,
        1,
        "Table 4.7 — relative top-1 accuracy vs float, by weight x activation bit depth",
    )
}

/// Table 4.8 — second metric (recall@2) relative to float, same grid.
pub fn table_4_8(fast: bool) -> Result<()> {
    bit_grid(
        fast,
        2,
        "Table 4.8 — relative recall@2 vs float, by weight x activation bit depth (age-precision substitute)",
    )
}

/// Used by `eval` when a saved model exists; re-exported for tests.
pub fn quick_eval(model_path: &Path) -> Result<f32> {
    let arts = artifacts();
    let spec = crate::train::ModelSpec::load(&arts.join("base"))?;
    let model = load_trained(model_path)?;
    let g = papernet_from_params(&model.params, &spec.export_keys, FusedActivation::Relu6)?;
    let ds = ClassificationSet::new(spec.resolution, spec.num_classes, 0);
    Ok(accuracy(&mut |x| g.run(x), &ds, 4, spec.batch))
}
