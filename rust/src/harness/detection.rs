//! Detection tables (4.4, 4.5, 4.6): SSD-lite on the synthetic detection
//! task, comparing the float engine against the integer-only engine.
//!
//! Substitution note (DESIGN.md §Substitutions): the paper fine-tunes a
//! MobileNet-SSD on COCO / a face corpus; without those corpora (and
//! without a detection train graph in the AOT budget) the quantization
//! question the tables answer — *does the int8 engine preserve the float
//! detector's behaviour, and at what latency?* — is measured directly:
//! the float detector's decoded boxes serve as reference, and the int8
//! engine's boxes are scored against them with the paper's own metrics
//! (mAP@[.5:.95] for 4.4, IoU-sweep-averaged precision/recall for 4.5).
//! Latencies are host-measured on both engines plus the fitted ARM core
//! model for the Snapdragon columns.

use super::time_median_ms;
use crate::data::synth::{DetectionSet, GtBox};
use crate::data::Rng;
use crate::graph::builders::ssd_lite;
use crate::quantize::{quantize_graph, QuantizeOptions};
use crate::sim::{ArmCoreModel, Dtype};
use crate::tensor::Tensor;
use anyhow::Result;

const RES: usize = 32;
const GRID: usize = 4;
const CLASSES: usize = 3;

/// Decode predictions of both engines on `count` images; returns
/// (reference boxes per image, candidate boxes+scores per image).
#[allow(clippy::type_complexity)]
fn run_detectors(
    dm: f64,
    count: usize,
    threshold: f32,
) -> Result<(Vec<Vec<GtBox>>, Vec<Vec<(GtBox, f32)>>, f64, f64)> {
    let ds = DetectionSet::new(RES, GRID, CLASSES, 77);
    let float_graph = ssd_lite(dm, CLASSES, 9).fold_batch_norms();
    // PTQ calibration batches from the same distribution.
    let calib: Vec<Tensor<f32>> = (0..4).map(|i| ds.example(0, i).0).collect();
    let (_, int8_graph) = quantize_graph(&float_graph, &calib, QuantizeOptions::default());

    let mut reference = Vec::with_capacity(count);
    let mut candidate = Vec::with_capacity(count);
    for i in 0..count {
        let (img, _) = ds.example(1, i as u64);
        let fpred = float_graph.run(&img);
        let qpred = int8_graph.run(&img);
        reference.push(ds.decode_predictions(&fpred, threshold).into_iter().map(|(b, _)| b).collect());
        candidate.push(ds.decode_predictions(&qpred, threshold));
    }
    let (x1, _) = ds.example(1, 0);
    let fms = time_median_ms(8, || {
        let _ = float_graph.run(&x1);
    });
    let qms = time_median_ms(8, || {
        let _ = int8_graph.run(&x1);
    });
    Ok((reference, candidate, fms, qms))
}

/// Average precision of candidates against reference boxes at one IoU.
fn average_precision(
    reference: &[Vec<GtBox>],
    candidate: &[Vec<(GtBox, f32)>],
    iou_thresh: f32,
) -> f32 {
    // Flatten detections with image ids, sort by score descending.
    let mut dets: Vec<(usize, GtBox, f32)> = candidate
        .iter()
        .enumerate()
        .flat_map(|(img, dets)| dets.iter().map(move |(b, s)| (img, *b, *s)))
        .collect();
    dets.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    let total_ref: usize = reference.iter().map(Vec::len).sum();
    if total_ref == 0 {
        return if dets.is_empty() { 1.0 } else { 0.0 };
    }
    let mut matched: Vec<Vec<bool>> = reference.iter().map(|r| vec![false; r.len()]).collect();
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut precisions_at_recall = Vec::new();
    for (img, b, _) in dets {
        let refs = &reference[img];
        let mut best = -1f32;
        let mut best_j = usize::MAX;
        for (j, r) in refs.iter().enumerate() {
            if matched[img][j] || r.class != b.class {
                continue;
            }
            let iou = r.iou(&b);
            if iou > best {
                best = iou;
                best_j = j;
            }
        }
        if best >= iou_thresh && best_j != usize::MAX {
            matched[img][best_j] = true;
            tp += 1;
        } else {
            fp += 1;
        }
        precisions_at_recall.push((
            tp as f32 / (tp + fp) as f32,
            tp as f32 / total_ref as f32,
        ));
    }
    // 101-point interpolated AP (COCO style).
    let mut ap = 0f32;
    for i in 0..=100 {
        let r = i as f32 / 100.0;
        let p = precisions_at_recall
            .iter()
            .filter(|(_, rec)| *rec >= r)
            .map(|(p, _)| *p)
            .fold(0f32, f32::max);
        ap += p / 101.0;
    }
    ap
}

/// Precision and recall at one IoU threshold (greedy matching).
fn precision_recall(
    reference: &[Vec<GtBox>],
    candidate: &[Vec<(GtBox, f32)>],
    iou_thresh: f32,
) -> (f32, f32) {
    let mut tp = 0usize;
    let mut n_det = 0usize;
    let mut n_ref = 0usize;
    for (refs, dets) in reference.iter().zip(candidate) {
        n_ref += refs.len();
        n_det += dets.len();
        let mut used = vec![false; refs.len()];
        for (b, _) in dets {
            for (j, r) in refs.iter().enumerate() {
                if !used[j] && r.class == b.class && r.iou(b) >= iou_thresh {
                    used[j] = true;
                    tp += 1;
                    break;
                }
            }
        }
    }
    let precision = if n_det == 0 { 1.0 } else { tp as f32 / n_det as f32 };
    let recall = if n_ref == 0 { 1.0 } else { tp as f32 / n_ref as f32 };
    (precision, recall)
}

/// Table 4.4 — detection mAP + latency, DM in {1.0, 0.5}.
pub fn table_4_4(fast: bool) -> Result<()> {
    let count = if fast { 24 } else { 80 };
    println!("# Table 4.4 — SSD-lite detection: int8 fidelity to the float detector + latency");
    println!("| DM | type | mAP@[.5:.95] vs float ref | host ms | S835-big est. ms | S835-LITTLE est. ms |");
    println!("|---|---|---|---|---|---|");
    for dm in [1.0, 0.5] {
        let (reference, candidate, fms, qms) = run_detectors(dm, count, 0.5)?;
        // Float vs itself is 1.0 by construction; report it as the anchor.
        let float_graph = ssd_lite(dm, CLASSES, 9).fold_batch_norms();
        let shape = [1usize, RES, RES, 3];
        let big = ArmCoreModel::s835_big();
        let little = ArmCoreModel::s835_little();
        println!(
            "| {dm} | floats | (reference) | {fms:.3} | {:.1} | {:.1} |",
            big.latency_ms(&float_graph, &shape, Dtype::F32),
            little.latency_ms(&float_graph, &shape, Dtype::F32),
        );
        let mut map = 0f32;
        let mut n = 0;
        let mut iou = 0.5f32;
        while iou < 0.96 {
            map += average_precision(&reference, &candidate, iou);
            n += 1;
            iou += 0.05;
        }
        println!(
            "| {dm} | 8 bits | {:.3} | {qms:.3} | {:.1} | {:.1} |",
            map / n as f32,
            big.latency_ms(&float_graph, &shape, Dtype::Int8),
            little.latency_ms(&float_graph, &shape, Dtype::Int8),
        );
    }
    Ok(())
}

/// Table 4.5 — precision/recall averaged over IoU in {.5, .55, ..., .95},
/// DM in {1.0, 0.5, 0.25}.
pub fn table_4_5(fast: bool) -> Result<()> {
    let count = if fast { 24 } else { 80 };
    println!("# Table 4.5 — detection precision/recall of int8 vs the float reference");
    println!("| DM | type | precision | recall |");
    println!("|---|---|---|---|");
    for dm in [1.0, 0.5, 0.25] {
        let (reference, candidate, _, _) = run_detectors(dm, count, 0.5)?;
        println!("| {dm} | floats | (reference) | (reference) |");
        let mut ps = Vec::new();
        let mut rs = Vec::new();
        let mut iou = 0.5f32;
        while iou < 0.96 {
            let (p, r) = precision_recall(&reference, &candidate, iou);
            ps.push(p);
            rs.push(r);
            iou += 0.05;
        }
        let mp = ps.iter().sum::<f32>() / ps.len() as f32;
        let mr = rs.iter().sum::<f32>() / rs.len() as f32;
        println!("| {dm} | 8 bits | {:.0}% | {:.0}% |", mp * 100.0, mr * 100.0);
    }
    Ok(())
}

/// Table 4.6 — multi-threading: detector latency on 1/2/4 cores.
/// Host measurement exercises `gemm::parallel` on the detector's dominant
/// GEMM (this testbed has one core, so host numbers show overhead, not
/// speedup); the Snapdragon columns come from the fitted core model's
/// Amdahl scaling (DESIGN.md §Hardware-Adaptation).
pub fn table_4_6(fast: bool) -> Result<()> {
    use crate::gemm::{output::OutputStage, parallel::run_parallel, Kernel, QGemm};
    use crate::quant::QuantizedMultiplier;
    println!("# Table 4.6 — detector latency by core count");
    println!("| DM | type | cores | S835-LITTLE est. ms | S835-big est. ms | host GEMM ms |");
    println!("|---|---|---|---|---|---|");
    let little = ArmCoreModel::s835_little();
    let big = ArmCoreModel::s835_big();
    for dm in [1.0, 0.5, 0.25] {
        let g = ssd_lite(dm, CLASSES, 9).fold_batch_norms();
        let shape = [1usize, RES, RES, 3];
        // Host-measured thread scaling on a detector-representative GEMM
        // (dominant layer shape scaled by dm).
        let m = (64.0 * dm) as usize + 8;
        let (k, n) = (9 * m, if fast { 24 * 24 } else { 32 * 32 });
        let mut rng = Rng::seeded(3);
        let lhs: Vec<u8> = (0..m * k).map(|_| 1 + rng.below(255) as u8).collect();
        let rhs: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let qg = QGemm::new(m, k, n, 128, 120);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.004), 12);
        println!(
            "| {dm} | floats | 1 | {:.1} | {:.1} | - |",
            little.latency_ms(&g, &shape, Dtype::F32),
            big.latency_ms(&g, &shape, Dtype::F32)
        );
        for cores in [1usize, 2, 4] {
            let mut out = vec![0u8; m * n];
            let host_ms = time_median_ms(5, || {
                run_parallel(&qg, Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut out, cores);
            });
            println!(
                "| {dm} | 8 bits | {cores} | {:.1} | {:.1} | {host_ms:.3} |",
                little.latency_ms_multicore(&g, &shape, Dtype::Int8, cores),
                big.latency_ms_multicore(&g, &shape, Dtype::Int8, cores),
            );
        }
    }
    println!("(host has a single core: host GEMM column shows threading overhead, not speedup)");
    Ok(())
}
