//! Model builders for every architecture the experiments need.
//!
//! * [`mobilenet`] — MobileNet v1 with depth multiplier and resolution knobs
//!   (the paper's §4.2.1 sweep axes).
//! * [`mini_resnet`] — `6n+2`-layer CIFAR-style ResNet (Table 4.1's depth
//!   sweep, scaled to this testbed).
//! * [`papernet`] / [`papernet_random`] — the small QAT ConvNet whose JAX
//!   twin lives in `python/compile/model.py`; [`papernet`] instantiates it
//!   from trained parameters exported by the L2 side.
//! * [`ssd_lite`] — detection backbone + separable prediction head
//!   (§4.2.2's "replace SSD convs with separable convolutions").
//! * [`attribute_net`] — the face-attributes stand-in (§4.2.4).
//!
//! All builders emit conv→BN→activation triples so the PTQ pipeline
//! exercises batch-norm folding (eq. 14) exactly as the paper describes.

use std::collections::HashMap;

use crate::data::Rng;
use crate::graph::{BatchNorm, FloatGraph, FloatOp, NodeRef};
use crate::nn::conv::Conv2d;
use crate::nn::depthwise::DepthwiseConv2d;
use crate::nn::fc::FullyConnected;
use crate::nn::{FusedActivation, Padding};
use crate::tensor::Tensor;

/// Named parameter collection (the interchange with the Python L2 side).
pub type ParamMap = HashMap<String, Tensor<f32>>;

fn he_conv(rng: &mut Rng, cout: usize, kh: usize, kw: usize, cin: usize) -> Tensor<f32> {
    let fan_in = (kh * kw * cin) as f32;
    let std = (2.0 / fan_in).sqrt();
    let mut w = vec![0f32; cout * kh * kw * cin];
    rng.fill_normal(&mut w, std);
    Tensor::from_vec(&[cout, kh, kw, cin], w)
}

fn he_dw(rng: &mut Rng, kh: usize, kw: usize, c: usize) -> Tensor<f32> {
    let std = (2.0 / (kh * kw) as f32).sqrt();
    let mut w = vec![0f32; kh * kw * c];
    rng.fill_normal(&mut w, std);
    Tensor::from_vec(&[1, kh, kw, c], w)
}

fn fresh_bn(rng: &mut Rng, c: usize) -> BatchNorm {
    // Mildly randomized BN statistics so folding is non-trivial in tests.
    BatchNorm {
        gamma: (0..c).map(|_| rng.range_f32(0.8, 1.2)).collect(),
        beta: (0..c).map(|_| rng.range_f32(-0.1, 0.1)).collect(),
        mean: (0..c).map(|_| rng.range_f32(-0.05, 0.05)).collect(),
        var: (0..c).map(|_| rng.range_f32(0.8, 1.2)).collect(),
        eps: 1e-3,
    }
}

/// conv → BN → activation triple.
fn conv_bn(
    g: &mut FloatGraph,
    rng: &mut Rng,
    name: &str,
    input: NodeRef,
    cout: usize,
    k: usize,
    cin: usize,
    stride: usize,
    act: FusedActivation,
) -> NodeRef {
    let conv = Conv2d {
        weights: he_conv(rng, cout, k, k, cin),
        bias: vec![],
        stride,
        padding: Padding::Same,
        activation: FusedActivation::None,
    };
    let c = g.push(format!("{name}/conv"), input, FloatOp::Conv(conv));
    let b = g.push(format!("{name}/bn"), c, FloatOp::BatchNorm(fresh_bn(rng, cout)));
    match act {
        FusedActivation::None => b,
        FusedActivation::Relu => g.push(format!("{name}/relu"), b, FloatOp::Relu),
        FusedActivation::Relu6 => g.push(format!("{name}/relu6"), b, FloatOp::Relu6),
    }
}

/// depthwise → BN → activation triple.
fn dw_bn(
    g: &mut FloatGraph,
    rng: &mut Rng,
    name: &str,
    input: NodeRef,
    c: usize,
    stride: usize,
    act: FusedActivation,
) -> NodeRef {
    let dw = DepthwiseConv2d {
        weights: he_dw(rng, 3, 3, c),
        bias: vec![],
        stride,
        padding: Padding::Same,
        activation: FusedActivation::None,
    };
    let d = g.push(format!("{name}/dw"), input, FloatOp::Depthwise(dw));
    let b = g.push(format!("{name}/bn"), d, FloatOp::BatchNorm(fresh_bn(rng, c)));
    match act {
        FusedActivation::None => b,
        FusedActivation::Relu => g.push(format!("{name}/relu"), b, FloatOp::Relu),
        FusedActivation::Relu6 => g.push(format!("{name}/relu6"), b, FloatOp::Relu6),
    }
}

fn scale_channels(c: usize, dm: f64) -> usize {
    (((c as f64 * dm / 8.0).round() as usize) * 8).max(8)
}

/// MobileNet v1 (§4.2.1): depth multiplier `dm` scales every channel count;
/// spatial resolution is a property of the input fed to it. `with_softmax`
/// appends the classifier softmax (off for latency benches so logits are
/// the output, matching the paper's timing of the network body).
pub fn mobilenet(dm: f64, num_classes: usize, with_softmax: bool, seed: u64) -> FloatGraph {
    let mut rng = Rng::seeded(seed ^ 0x0b11e7);
    let mut g = FloatGraph::default();
    let act = FusedActivation::Relu6;
    // (pointwise output channels, depthwise stride) per v1 block.
    let blocks: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let c0 = scale_channels(32, dm);
    let mut cur = conv_bn(&mut g, &mut rng, "stem", NodeRef::Input, c0, 3, 3, 2, act);
    let mut cin = c0;
    for (i, (cout_base, stride)) in blocks.iter().enumerate() {
        let cout = scale_channels(*cout_base, dm);
        cur = dw_bn(&mut g, &mut rng, &format!("block{i}"), cur, cin, *stride, act);
        cur = conv_bn(&mut g, &mut rng, &format!("block{i}/pw"), cur, cout, 1, cin, 1, act);
        cin = cout;
    }
    cur = g.push("gap", cur, FloatOp::GlobalAvgPool);
    let fc = FullyConnected {
        weights: {
            let std = (2.0 / cin as f32).sqrt();
            let mut w = vec![0f32; num_classes * cin];
            rng.fill_normal(&mut w, std);
            Tensor::from_vec(&[num_classes, cin], w)
        },
        bias: vec![0.0; num_classes],
        activation: FusedActivation::None,
    };
    cur = g.push("logits", cur, FloatOp::Fc(fc));
    if with_softmax {
        g.push("softmax", cur, FloatOp::Softmax);
    }
    g
}

/// CIFAR-style ResNet of depth `6n + 2` (Table 4.1's sweep, laptop scale):
/// stem conv, then 3 stages of `n` residual blocks with channels
/// (16, 32, 64), stride-2 downsampling (with 1×1 projection) entering
/// stages 2 and 3, global pool and an FC classifier.
pub fn mini_resnet(n: usize, num_classes: usize, seed: u64) -> FloatGraph {
    assert!(n >= 1);
    let mut rng = Rng::seeded(seed ^ 0x2e5);
    let mut g = FloatGraph::default();
    let act = FusedActivation::Relu;
    let mut cur = conv_bn(&mut g, &mut rng, "stem", NodeRef::Input, 16, 3, 3, 1, act);
    let mut cin = 16;
    for (stage, &c) in [16usize, 32, 64].iter().enumerate() {
        for block in 0..n {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let name = format!("s{stage}b{block}");
            // Main branch: conv-bn-relu, conv-bn.
            let h = conv_bn(&mut g, &mut rng, &format!("{name}/c1"), cur, c, 3, cin, stride, act);
            let h2 = conv_bn(&mut g, &mut rng, &format!("{name}/c2"), h, c, 3, c, 1, FusedActivation::None);
            // Skip branch: identity, or 1x1 stride-2 projection when the
            // shape changes.
            let skip = if stride != 1 || cin != c {
                conv_bn(&mut g, &mut rng, &format!("{name}/proj"), cur, c, 1, cin, stride, FusedActivation::None)
            } else {
                cur
            };
            let sum = g.push(format!("{name}/add"), h2, FloatOp::Add(skip));
            cur = g.push(format!("{name}/relu"), sum, FloatOp::Relu);
            cin = c;
        }
    }
    cur = g.push("gap", cur, FloatOp::GlobalAvgPool);
    let fc = FullyConnected {
        weights: {
            let mut w = vec![0f32; num_classes * cin];
            rng.fill_normal(&mut w, (2.0 / cin as f32).sqrt());
            Tensor::from_vec(&[num_classes, cin], w)
        },
        bias: vec![0.0; num_classes],
        activation: FusedActivation::None,
    };
    g.push("logits", cur, FloatOp::Fc(fc));
    g
}

/// The depth of a [`mini_resnet`] in the paper's counting (6n + 2).
pub fn mini_resnet_depth(n: usize) -> usize {
    6 * n + 2
}

/// PaperNet: the exact architecture of the JAX QAT model
/// (`python/compile/model.py::PAPERNET`). Layer names and shapes must stay
/// in lock-step with the Python side; `tests/parity.rs` enforces it through
/// the AOT artifacts.
///
/// conv0 3×3 s1 c8 → dw1 s2 → pw1 c16 → dw2 s2 → pw2 c32 → GAP → FC.
/// `act` is ReLU6 in the default configuration (Table 4.3 sweeps ReLU too).
pub fn papernet_random(num_classes: usize, act: FusedActivation, seed: u64) -> FloatGraph {
    let mut rng = Rng::seeded(seed ^ 0x9a9e2);
    let mut g = FloatGraph::default();
    let mut cur = conv_bn(&mut g, &mut rng, "conv0", NodeRef::Input, 8, 3, 3, 1, act);
    cur = dw_bn(&mut g, &mut rng, "dw1", cur, 8, 2, act);
    cur = conv_bn(&mut g, &mut rng, "pw1", cur, 16, 1, 8, 1, act);
    cur = dw_bn(&mut g, &mut rng, "dw2", cur, 16, 2, act);
    cur = conv_bn(&mut g, &mut rng, "pw2", cur, 32, 1, 16, 1, act);
    cur = g.push("gap", cur, FloatOp::GlobalAvgPool);
    let fc = FullyConnected {
        weights: {
            let mut w = vec![0f32; num_classes * 32];
            rng.fill_normal(&mut w, 0.25);
            Tensor::from_vec(&[num_classes, 32], w)
        },
        bias: vec![0.0; num_classes],
        activation: FusedActivation::None,
    };
    g.push("logits", cur, FloatOp::Fc(fc));
    g
}

/// PaperNet with per-channel-heterogeneous depthwise ranges: each depthwise
/// channel's weights scaled by a different power of 3, mimicking an extreme
/// BN fold (eq. 14) — the synth workload per-channel quantization
/// (Krishnamoorthi 1806.08342) exists for. Used by the quant-mode accuracy
/// harness and the converter tests.
pub fn papernet_heterogeneous_dw(num_classes: usize, seed: u64) -> FloatGraph {
    let mut g = papernet_random(num_classes, FusedActivation::Relu6, seed);
    for node in &mut g.nodes {
        if let FloatOp::Depthwise(d) = &mut node.op {
            let c = d.weights.dim(3);
            let wd = d.weights.data_mut();
            for (i, w) in wd.iter_mut().enumerate() {
                // 256x spread: the smallest channels fall below one
                // per-tensor quantization step and get wiped, while
                // per-channel scales keep them intact.
                *w *= 0.03 * 4f32.powi(((i % c) % 5) as i32);
            }
        }
    }
    g
}

/// PaperNet whose classifier head has per-unit-heterogeneous weight
/// magnitudes: each FC output row is scaled by a different power of 4
/// (256x spread) — the wide-classifier-head shape per-channel FC
/// quantization targets, where one per-tensor scale must cover every
/// unit and the quiet rows lose their resolution. Used by the
/// `quant-modes` accuracy harness.
pub fn papernet_wide_head(num_classes: usize, seed: u64) -> FloatGraph {
    let mut g = papernet_random(num_classes, FusedActivation::Relu6, seed);
    for node in &mut g.nodes {
        if let FloatOp::Fc(f) = &mut node.op {
            let rows = f.weights.dim(0);
            let cols = f.weights.dim(1);
            let wd = f.weights.data_mut();
            for r in 0..rows {
                let factor = 0.02 * 4f32.powi((r % 5) as i32);
                for w in &mut wd[r * cols..(r + 1) * cols] {
                    *w *= factor;
                }
            }
        }
    }
    g
}

/// PaperNet from *folded* trained parameters exported by the L2 side
/// (`aot.py` exports `<layer>/w` and `<layer>/b` with BN already folded per
/// eq. 14, which is exactly what inference needs — fig. C.6).
pub fn papernet(params: &ParamMap, num_classes: usize, act: FusedActivation) -> FloatGraph {
    let mut g = FloatGraph::default();
    let get = |name: &str| -> Tensor<f32> {
        params.get(name).unwrap_or_else(|| panic!("missing param {name}")).clone()
    };
    let bias_of = |name: &str| -> Vec<f32> { get(name).into_data() };

    let conv = |g: &mut FloatGraph, name: &str, input, stride| -> NodeRef {
        let c = Conv2d {
            weights: get(&format!("{name}/w")),
            bias: bias_of(&format!("{name}/b")),
            stride,
            padding: Padding::Same,
            activation: act,
        };
        g.push(name, input, FloatOp::Conv(c))
    };
    let dw = |g: &mut FloatGraph, name: &str, input, stride| -> NodeRef {
        let d = DepthwiseConv2d {
            weights: get(&format!("{name}/w")),
            bias: bias_of(&format!("{name}/b")),
            stride,
            padding: Padding::Same,
            activation: act,
        };
        g.push(name, input, FloatOp::Depthwise(d))
    };

    let mut cur = conv(&mut g, "conv0", NodeRef::Input, 1);
    cur = dw(&mut g, "dw1", cur, 2);
    cur = conv(&mut g, "pw1", cur, 1);
    cur = dw(&mut g, "dw2", cur, 2);
    cur = conv(&mut g, "pw2", cur, 1);
    cur = g.push("gap", cur, FloatOp::GlobalAvgPool);
    let fc = FullyConnected {
        weights: {
            let w = get("fc/w");
            assert_eq!(w.dim(0), num_classes);
            w
        },
        bias: bias_of("fc/b"),
        activation: FusedActivation::None,
    };
    g.push("logits", cur, FloatOp::Fc(fc));
    g
}

/// SSD-lite detector (§4.2.2): small separable backbone, three stride-2
/// reductions (res/8 grid), then a *separable* prediction head emitting
/// `5 + num_classes` channels per cell — the paper's replacement of the
/// regular SSD convs with depthwise + 1×1 projections.
pub fn ssd_lite(dm: f64, num_classes: usize, seed: u64) -> FloatGraph {
    let mut rng = Rng::seeded(seed ^ 0x55d);
    let act = FusedActivation::Relu6;
    let mut g = FloatGraph::default();
    let c1 = scale_channels(16, dm);
    let c2 = scale_channels(32, dm);
    let c3 = scale_channels(64, dm);
    let mut cur = conv_bn(&mut g, &mut rng, "stem", NodeRef::Input, c1, 3, 3, 2, act);
    cur = dw_bn(&mut g, &mut rng, "b1", cur, c1, 2, act);
    cur = conv_bn(&mut g, &mut rng, "b1/pw", cur, c2, 1, c1, 1, act);
    cur = dw_bn(&mut g, &mut rng, "b2", cur, c2, 2, act);
    cur = conv_bn(&mut g, &mut rng, "b2/pw", cur, c3, 1, c2, 1, act);
    // Separable prediction head: dw3x3 + 1x1 projection, no activation.
    cur = dw_bn(&mut g, &mut rng, "head", cur, c3, 1, act);
    let out_ch = 5 + num_classes;
    let proj = Conv2d {
        weights: he_conv(&mut rng, out_ch, 1, 1, c3),
        bias: vec![0.0; out_ch],
        stride: 1,
        padding: Padding::Same,
        activation: FusedActivation::None,
    };
    g.push("head/proj", cur, FloatOp::Conv(proj));
    g
}

/// Face-attributes stand-in network (§4.2.4): tiny separable ConvNet with a
/// `NUM_ATTRIBUTES + 1` logit head (binary attributes + the "age" scalar).
pub fn attribute_net(dm: f64, num_outputs: usize, seed: u64) -> FloatGraph {
    let mut rng = Rng::seeded(seed ^ 0xa77);
    let act = FusedActivation::Relu6;
    let mut g = FloatGraph::default();
    let c1 = scale_channels(8, dm);
    let c2 = scale_channels(16, dm);
    let mut cur = conv_bn(&mut g, &mut rng, "stem", NodeRef::Input, c1, 3, 3, 2, act);
    cur = dw_bn(&mut g, &mut rng, "b1", cur, c1, 2, act);
    cur = conv_bn(&mut g, &mut rng, "b1/pw", cur, c2, 1, c1, 1, act);
    cur = g.push("gap", cur, FloatOp::GlobalAvgPool);
    let fc = FullyConnected {
        weights: {
            let mut w = vec![0f32; num_outputs * c2];
            rng.fill_normal(&mut w, (2.0 / c2 as f32).sqrt());
            Tensor::from_vec(&[num_outputs, c2], w)
        },
        bias: vec![0.0; num_outputs],
        activation: FusedActivation::None,
    };
    g.push("logits", cur, FloatOp::Fc(fc));
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mobilenet_shapes_and_scaling() {
        for (dm, res) in [(0.25, 32), (0.5, 32), (1.0, 64)] {
            let g = mobilenet(dm, 16, true, 1);
            let x = Tensor::zeros(&[1, res, res, 3]);
            let y = g.run(&x);
            assert_eq!(y.shape(), &[1, 16], "dm={dm} res={res}");
        }
        // Depth multiplier shrinks the model roughly quadratically.
        let big = mobilenet(1.0, 16, false, 1).model_bytes();
        let small = mobilenet(0.25, 16, false, 1).model_bytes();
        assert!(big > small * 8, "dm=1.0 ({big}B) vs dm=0.25 ({small}B)");
    }

    #[test]
    fn mobilenet_macs_scale_with_resolution() {
        let g = mobilenet(0.25, 16, false, 1);
        let m32 = g.mac_count(&[1, 32, 32, 3]);
        let m64 = g.mac_count(&[1, 64, 64, 3]);
        assert!(m64 > 3 * m32, "macs m32={m32} m64={m64}");
    }

    #[test]
    fn mini_resnet_depths() {
        assert_eq!(mini_resnet_depth(1), 8);
        assert_eq!(mini_resnet_depth(2), 14);
        assert_eq!(mini_resnet_depth(3), 20);
        for n in [1, 2] {
            let g = mini_resnet(n, 16, 7);
            let y = g.run(&Tensor::zeros(&[1, 16, 16, 3]));
            assert_eq!(y.shape(), &[1, 16], "n={n}");
        }
    }

    #[test]
    fn mini_resnet_fold_preserves_function() {
        let g = mini_resnet(1, 8, 3);
        let folded = g.fold_batch_norms();
        let mut rng = crate::data::Rng::seeded(1);
        let mut xd = vec![0f32; 16 * 16 * 3];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[1, 16, 16, 3], xd);
        let d = g.run(&x).max_abs_diff(&folded.run(&x));
        assert!(d < 1e-4, "fold diff {d}");
    }

    #[test]
    fn papernet_variants_agree_on_shape() {
        let g = papernet_random(16, FusedActivation::Relu6, 5);
        let y = g.run(&Tensor::zeros(&[2, 16, 16, 3]));
        assert_eq!(y.shape(), &[2, 16]);
    }

    #[test]
    fn papernet_from_params_runs() {
        // Build a parameter map with the expected names/shapes and check the
        // graph assembles and runs.
        let mut params = ParamMap::new();
        let mut rng = Rng::seeded(11);
        let mut add = |name: &str, shape: &[usize]| {
            let mut w = vec![0f32; shape.iter().product()];
            rng.fill_normal(&mut w, 0.2);
            params.insert(name.to_string(), Tensor::from_vec(shape, w));
        };
        add("conv0/w", &[8, 3, 3, 3]);
        add("conv0/b", &[8]);
        add("dw1/w", &[1, 3, 3, 8]);
        add("dw1/b", &[8]);
        add("pw1/w", &[16, 1, 1, 8]);
        add("pw1/b", &[16]);
        add("dw2/w", &[1, 3, 3, 16]);
        add("dw2/b", &[16]);
        add("pw2/w", &[32, 1, 1, 16]);
        add("pw2/b", &[32]);
        add("fc/w", &[16, 32]);
        add("fc/b", &[16]);
        let g = papernet(&params, 16, FusedActivation::Relu6);
        let y = g.run(&Tensor::zeros(&[1, 16, 16, 3]));
        assert_eq!(y.shape(), &[1, 16]);
    }

    #[test]
    fn ssd_lite_grid_output() {
        let g = ssd_lite(0.5, 3, 9);
        let y = g.run(&Tensor::zeros(&[1, 32, 32, 3]));
        assert_eq!(y.shape(), &[1, 4, 4, 8]); // 32/8 grid, 5+3 channels
    }

    #[test]
    fn attribute_net_output() {
        let g = attribute_net(1.0, 5, 2);
        let y = g.run(&Tensor::zeros(&[2, 16, 16, 3]));
        assert_eq!(y.shape(), &[2, 5]);
    }
}
