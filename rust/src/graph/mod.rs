//! Model graphs: a float training/eval graph, its integer-only quantized
//! counterpart, batch-norm folding (eq. 14), and the model builders used by
//! the experiments (MobileNet, mini-ResNet, the QAT ConvNet mirror, and the
//! SSD-lite detection head).
//!
//! Graphs are DAGs in topological order: node `i` may read the graph input
//! or any node `j < i` (general enough for ResNet bypasses and SSD
//! multi-head outputs, which is all the paper needs — see figs. C.3/C.4).

pub mod builders;
pub mod fault;

use crate::nn::activations::{
    logistic_f32, qlogistic, qlogistic_into, qsoftmax, qsoftmax_into, softmax_f32,
};
use crate::gemm::ResidualAdd;
use crate::nn::conv::{Conv2d, PreparedConv2d, QConv2d, ResidualArgs};
use crate::nn::depthwise::{DepthwiseConv2d, PreparedDepthwiseConv2d, QDepthwiseConv2d};
use crate::nn::elementwise::{
    add_f32, concat_f32, qadd, qadd_into, qconcat, qconcat_into_indexed,
};
use crate::nn::fc::{FullyConnected, PreparedFullyConnected, QFullyConnected};
use crate::nn::pool::{
    avg_pool_f32, global_avg_pool_f32, max_pool_f32, qavg_pool, qavg_pool_into,
    qglobal_avg_pool, qglobal_avg_pool_into, qmax_pool, qmax_pool_into,
};
use crate::nn::{LayerScratch, Padding, QTensor};
use crate::quant::QuantParams;
use crate::tensor::Tensor;

/// Reference to a node's data source.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRef {
    /// The graph input tensor.
    Input,
    /// The output of an earlier node.
    Node(usize),
}

/// Batch normalization (training-graph form; folded away for inference per
/// §3.2 eq. 14).
#[derive(Clone, Debug)]
pub struct BatchNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    /// `EMA(μ_B)` — moving-average mean.
    pub mean: Vec<f32>,
    /// `EMA(σ²_B)` — moving-average variance.
    pub var: Vec<f32>,
    pub eps: f32,
}

impl BatchNorm {
    pub fn run(&self, x: &Tensor<f32>) -> Tensor<f32> {
        let c = *x.shape().last().unwrap();
        assert_eq!(self.gamma.len(), c);
        let mut out = x.clone();
        let lead = x.len() / c;
        let od = out.data_mut();
        for i in 0..lead {
            for ch in 0..c {
                let v = od[i * c + ch];
                od[i * c + ch] = self.gamma[ch] * (v - self.mean[ch])
                    / (self.var[ch] + self.eps).sqrt()
                    + self.beta[ch];
            }
        }
        out
    }

    /// Per-channel folding factors `γ / sqrt(EMA(σ²) + ε)` (eq. 14).
    pub fn fold_scales(&self) -> Vec<f32> {
        self.gamma
            .iter()
            .zip(&self.var)
            .map(|(g, v)| g / (v + self.eps).sqrt())
            .collect()
    }

    /// Folded bias `β − scale · EMA(μ)` to add to the conv bias.
    pub fn fold_biases(&self) -> Vec<f32> {
        self.fold_scales()
            .iter()
            .zip(self.beta.iter().zip(&self.mean))
            .map(|(s, (b, m))| b - s * m)
            .collect()
    }
}

/// Float-graph operations.
#[derive(Clone, Debug)]
pub enum FloatOp {
    Conv(Conv2d),
    Depthwise(DepthwiseConv2d),
    Fc(FullyConnected),
    BatchNorm(BatchNorm),
    AvgPool { kernel: usize, stride: usize, padding: Padding },
    MaxPool { kernel: usize, stride: usize, padding: Padding },
    GlobalAvgPool,
    Add(NodeRef),
    Concat(Vec<NodeRef>),
    Softmax,
    Logistic,
    Relu,
    Relu6,
}

/// One node of the float graph.
#[derive(Clone, Debug)]
pub struct FloatNode {
    pub name: String,
    pub input: NodeRef,
    pub op: FloatOp,
}

/// A float model: the paper's baseline inference path and the source for
/// post-training quantization.
#[derive(Clone, Debug, Default)]
pub struct FloatGraph {
    pub nodes: Vec<FloatNode>,
}

impl FloatGraph {
    pub fn push(&mut self, name: impl Into<String>, input: NodeRef, op: FloatOp) -> NodeRef {
        self.nodes.push(FloatNode { name: name.into(), input, op });
        NodeRef::Node(self.nodes.len() - 1)
    }

    /// Execute, returning every node's output (used by calibration).
    pub fn run_all(&self, input: &Tensor<f32>) -> Vec<Tensor<f32>> {
        let mut outs: Vec<Tensor<f32>> = Vec::with_capacity(self.nodes.len());
        let fetch = |outs: &Vec<Tensor<f32>>, r: NodeRef| -> Tensor<f32> {
            match r {
                NodeRef::Input => input.clone(),
                NodeRef::Node(i) => outs[i].clone(),
            }
        };
        for node in &self.nodes {
            let x = fetch(&outs, node.input);
            let y = match &node.op {
                FloatOp::Conv(op) => op.run(&x),
                FloatOp::Depthwise(op) => op.run(&x),
                FloatOp::Fc(op) => op.run(&x),
                FloatOp::BatchNorm(op) => op.run(&x),
                FloatOp::AvgPool { kernel, stride, padding } => avg_pool_f32(&x, *kernel, *stride, *padding),
                FloatOp::MaxPool { kernel, stride, padding } => max_pool_f32(&x, *kernel, *stride, *padding),
                FloatOp::GlobalAvgPool => global_avg_pool_f32(&x),
                FloatOp::Add(other) => add_f32(&x, &fetch(&outs, *other)),
                FloatOp::Concat(others) => {
                    let rest: Vec<Tensor<f32>> = others.iter().map(|r| fetch(&outs, *r)).collect();
                    let mut all: Vec<&Tensor<f32>> = vec![&x];
                    all.extend(rest.iter());
                    concat_f32(&all)
                }
                FloatOp::Softmax => softmax_f32(&x),
                FloatOp::Logistic => logistic_f32(&x),
                FloatOp::Relu => x.map(|v| v.max(0.0)),
                FloatOp::Relu6 => x.map(|v| v.clamp(0.0, 6.0)),
            };
            outs.push(y);
        }
        outs
    }

    /// Execute and return the final node's output.
    pub fn run(&self, input: &Tensor<f32>) -> Tensor<f32> {
        self.run_all(input).pop().expect("empty graph")
    }

    /// Fold every BatchNorm node into the preceding Conv/Depthwise (eq. 14),
    /// returning an equivalent graph without BN nodes — §3.2's inference
    /// transformation (figs. C.5 → C.6).
    ///
    /// Requires each BN to directly follow its conv (the builders guarantee
    /// this). Node indices shift; all `NodeRef`s are remapped.
    pub fn fold_batch_norms(&self) -> FloatGraph {
        // old index -> new index (after removals), where a BN maps to its
        // producer's new index.
        let mut remap: Vec<usize> = Vec::with_capacity(self.nodes.len());
        let mut out = FloatGraph::default();
        for (idx, node) in self.nodes.iter().enumerate() {
            match &node.op {
                FloatOp::BatchNorm(bn) => {
                    let NodeRef::Node(prev_old) = node.input else {
                        panic!("BatchNorm cannot be the first node");
                    };
                    let prev_new = remap[prev_old];
                    let scales = bn.fold_scales();
                    let extra = bn.fold_biases();
                    match &mut out.nodes[prev_new].op {
                        FloatOp::Conv(conv) => fold_into_conv(conv, &scales, &extra),
                        FloatOp::Depthwise(dw) => fold_into_depthwise(dw, &scales, &extra),
                        other => panic!("BatchNorm must follow Conv/Depthwise, found {other:?}"),
                    }
                    remap.push(prev_new);
                    debug_assert_eq!(remap.len(), idx + 1);
                }
                _ => {
                    let mut node = node.clone();
                    let fix = |r: NodeRef| match r {
                        NodeRef::Input => NodeRef::Input,
                        NodeRef::Node(i) => NodeRef::Node(remap[i]),
                    };
                    node.input = fix(node.input);
                    match &mut node.op {
                        FloatOp::Add(o) => *o = fix(*o),
                        FloatOp::Concat(os) => {
                            for o in os.iter_mut() {
                                *o = fix(*o);
                            }
                        }
                        _ => {}
                    }
                    out.nodes.push(node);
                    remap.push(out.nodes.len() - 1);
                }
            }
        }
        out
    }

    /// Total weight bytes of the float model (f32 weights + biases).
    pub fn model_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                FloatOp::Conv(c) => 4 * (c.weights.len() + c.bias.len()),
                FloatOp::Depthwise(d) => 4 * (d.weights.len() + d.bias.len()),
                FloatOp::Fc(f) => 4 * (f.weights.len() + f.bias.len()),
                FloatOp::BatchNorm(b) => 4 * 4 * b.gamma.len(),
                _ => 0,
            })
            .sum()
    }

    /// Multiply-accumulate count for one inference at the given input shape
    /// (drives the ARM core cost model in [`crate::sim`]).
    pub fn mac_count(&self, input_shape: &[usize]) -> u64 {
        let probe = Tensor::<f32>::zeros(input_shape);
        let outs = self.run_all(&probe);
        let mut macs = 0u64;
        for (i, node) in self.nodes.iter().enumerate() {
            let out_shape = outs[i].shape();
            let out_elems: u64 = out_shape.iter().product::<usize>() as u64;
            macs += match &node.op {
                FloatOp::Conv(c) => {
                    let k = (c.weights.len() / c.weights.dim(0)) as u64;
                    out_elems * k
                }
                FloatOp::Depthwise(d) => {
                    let taps = (d.weights.dim(1) * d.weights.dim(2)) as u64;
                    out_elems * taps
                }
                FloatOp::Fc(f) => out_elems * f.weights.dim(1) as u64,
                FloatOp::AvgPool { kernel, .. } | FloatOp::MaxPool { kernel, .. } => {
                    out_elems * (*kernel * *kernel) as u64
                }
                FloatOp::GlobalAvgPool => {
                    let in_shape = match node.input {
                        NodeRef::Input => input_shape.to_vec(),
                        NodeRef::Node(j) => outs[j].shape().to_vec(),
                    };
                    in_shape.iter().product::<usize>() as u64
                }
                _ => out_elems,
            };
        }
        macs
    }
}

/// `w_fold = γ·w / sqrt(EMA(σ²)+ε)` per output channel (eq. 14) plus the
/// corresponding bias fold.
fn fold_into_conv(conv: &mut Conv2d, scales: &[f32], extra_bias: &[f32]) {
    let cout = conv.weights.dim(0);
    assert_eq!(scales.len(), cout, "BN width must equal conv output channels");
    let per_out = conv.weights.len() / cout;
    {
        let wd = conv.weights.data_mut();
        for o in 0..cout {
            for t in 0..per_out {
                wd[o * per_out + t] *= scales[o];
            }
        }
    }
    if conv.bias.is_empty() {
        conv.bias = extra_bias.to_vec();
    } else {
        for (b, (s, e)) in conv.bias.iter_mut().zip(scales.iter().zip(extra_bias)) {
            *b = *b * s + e;
        }
    }
}

/// Depthwise weights are `[1, KH, KW, C]`: the channel axis is innermost.
fn fold_into_depthwise(dw: &mut DepthwiseConv2d, scales: &[f32], extra_bias: &[f32]) {
    let c = dw.weights.dim(3);
    assert_eq!(scales.len(), c);
    let taps = dw.weights.len() / c;
    {
        let wd = dw.weights.data_mut();
        for t in 0..taps {
            for ch in 0..c {
                wd[t * c + ch] *= scales[ch];
            }
        }
    }
    if dw.bias.is_empty() {
        dw.bias = extra_bias.to_vec();
    } else {
        for (b, (s, e)) in dw.bias.iter_mut().zip(scales.iter().zip(extra_bias)) {
            *b = *b * s + e;
        }
    }
}

/// Quantized-graph operations (integer-only at run time).
#[derive(Clone, Debug)]
pub enum QOp {
    Conv(QConv2d),
    Depthwise(QDepthwiseConv2d),
    Fc(QFullyConnected),
    AvgPool { kernel: usize, stride: usize, padding: Padding },
    MaxPool { kernel: usize, stride: usize, padding: Padding },
    GlobalAvgPool,
    Add { other: NodeRef, out_params: QuantParams },
    Concat { others: Vec<NodeRef>, out_params: QuantParams },
    Softmax,
    Logistic,
}

impl QOp {
    /// Human-readable op kind (error messages, artifact dumps).
    pub fn kind_name(&self) -> &'static str {
        match self {
            QOp::Conv(_) => "conv2d",
            QOp::Depthwise(_) => "depthwise_conv2d",
            QOp::Fc(_) => "fully_connected",
            QOp::AvgPool { .. } => "avg_pool",
            QOp::MaxPool { .. } => "max_pool",
            QOp::GlobalAvgPool => "global_avg_pool",
            QOp::Add { .. } => "add",
            QOp::Concat { .. } => "concat",
            QOp::Softmax => "softmax",
            QOp::Logistic => "logistic",
        }
    }

    /// Extra data inputs beyond the node's primary input (Add's other
    /// operand, Concat's tail operands).
    pub fn extra_inputs(&self) -> Vec<NodeRef> {
        match self {
            QOp::Add { other, .. } => vec![*other],
            QOp::Concat { others, .. } => others.clone(),
            _ => Vec::new(),
        }
    }
}

/// One node of the quantized graph.
#[derive(Clone, Debug)]
pub struct QNode {
    pub name: String,
    pub input: NodeRef,
    pub op: QOp,
}

impl QNode {
    /// Every data input of this node (primary first).
    pub fn inputs(&self) -> Vec<NodeRef> {
        let mut refs = vec![self.input];
        refs.extend(self.op.extra_inputs());
        refs
    }
}

/// The integer-only model: uint8 activations everywhere, fig. 1.1a per layer.
#[derive(Clone, Debug)]
pub struct QGraph {
    pub input_params: QuantParams,
    pub nodes: Vec<QNode>,
    /// GEMM kernel selection for all conv/fc nodes.
    pub kernel: crate::gemm::Kernel,
}

impl QGraph {
    /// Quantize a float input and run the integer graph end-to-end,
    /// returning every node's quantized output.
    pub fn run_all(&self, input: &Tensor<f32>) -> Vec<QTensor> {
        let qin = QTensor::quantize(input, self.input_params);
        self.run_all_q(&qin)
    }

    /// Run from an already-quantized input (the hot path: no float anywhere).
    pub fn run_all_q(&self, qin: &QTensor) -> Vec<QTensor> {
        let mut outs: Vec<QTensor> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let y = {
                let fetch = |r: &NodeRef| -> &QTensor {
                    match r {
                        NodeRef::Input => qin,
                        NodeRef::Node(i) => &outs[*i],
                    }
                };
                let x: &QTensor = fetch(&node.input);
                match &node.op {
                    QOp::Conv(op) => op.run(x, self.kernel),
                    QOp::Depthwise(op) => op.run(x),
                    QOp::Fc(op) => op.run(x, self.kernel),
                    QOp::AvgPool { kernel, stride, padding } => qavg_pool(x, *kernel, *stride, *padding),
                    QOp::MaxPool { kernel, stride, padding } => qmax_pool(x, *kernel, *stride, *padding),
                    QOp::GlobalAvgPool => qglobal_avg_pool(x),
                    QOp::Add { other, out_params } => qadd(x, fetch(other), *out_params),
                    QOp::Concat { others, out_params } => {
                        let rest: Vec<&QTensor> = others.iter().map(&fetch).collect();
                        let mut all = vec![x];
                        all.extend(rest);
                        qconcat(&all, *out_params)
                    }
                    QOp::Softmax => qsoftmax(x),
                    QOp::Logistic => qlogistic(x),
                }
            };
            outs.push(y);
        }
        outs
    }

    /// Convenience: final output, dequantized to float for the caller.
    pub fn run(&self, input: &Tensor<f32>) -> Tensor<f32> {
        self.run_all(input).pop().expect("empty graph").dequantize()
    }

    /// Final output without leaving the quantized domain.
    pub fn run_q(&self, qin: &QTensor) -> QTensor {
        self.run_all_q(qin).pop().expect("empty graph")
    }

    /// Check the topological-order invariant every executor relies on:
    /// node `i` may only read the graph input or a node `j < i`. Returns a
    /// description of the first violation. Used by the artifact loader
    /// ([`crate::model_format`]) so corrupt files fail before execution.
    pub fn validate_topology(&self) -> Result<(), String> {
        for (i, node) in self.nodes.iter().enumerate() {
            for r in node.inputs() {
                if let NodeRef::Node(j) = r {
                    if j >= i {
                        return Err(format!(
                            "node {i} ({}, {}) reads node {j}, which is not earlier in the DAG",
                            node.name,
                            node.op.kind_name()
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Total weight bytes (uint8 weights + int32 biases) — the paper's 4×
    /// model-size reduction claim is checked against this.
    pub fn model_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                QOp::Conv(c) => c.weights.len() + 4 * c.bias.len(),
                QOp::Depthwise(d) => d.weights.len() + 4 * d.bias.len(),
                QOp::Fc(f) => f.weights.len() + 4 * f.bias.len(),
                _ => 0,
            })
            .sum()
    }

    /// Statically resolve the output quantization parameters of `r` for
    /// the prepare-time fusion pass: conv-like layers store them, Add and
    /// Concat carry them, pools propagate their producer's unchanged
    /// ([`crate::nn::pool`]). `None` where resolution would need runtime
    /// information (the fixed-point Softmax/Logistic output domains).
    fn node_out_params(&self, r: NodeRef) -> Option<QuantParams> {
        match r {
            NodeRef::Input => Some(self.input_params),
            NodeRef::Node(j) => match &self.nodes[j].op {
                QOp::Conv(c) => Some(c.output_params),
                QOp::Depthwise(d) => Some(d.output_params),
                QOp::Fc(f) => Some(f.output_params),
                QOp::Add { out_params, .. } | QOp::Concat { out_params, .. } => Some(*out_params),
                QOp::AvgPool { .. } | QOp::MaxPool { .. } | QOp::GlobalAvgPool => {
                    self.node_out_params(self.nodes[j].input)
                }
                QOp::Softmax | QOp::Logistic => None,
            },
        }
    }

    /// Build the prepared execution plan: per-node weight packing, row sums
    /// and output stages, all computed once. Call at conversion time or at
    /// `.iaoiq` load time ([`crate::model_format`]); the plan is immutable
    /// and `Sync`, so serving threads share it read-only (each with its own
    /// [`ExecState`]). Prepared execution is bit-identical to
    /// [`QGraph::run_q`].
    ///
    /// This is also where the epilogue-fusion pass runs: every
    /// `conv → Add` chain whose conv output has exactly one consumer is
    /// rewritten so the conv applies the residual add inside its GEMM
    /// output stage ([`ResidualAdd`]) and the Add node becomes a no-op
    /// alias of the conv. Fusion is bit-identical to the unfused path
    /// (both route through [`ResidualAdd::apply`]) and defaults on;
    /// `IAOI_FUSION=off` (or `0`) disables it at prepare time, and
    /// [`PreparedGraph::set_fusion`] overrides it per plan.
    ///
    /// Packing runs eagerly here unless `IAOI_PREPARE=lazy` is set — see
    /// [`Self::prepare_with`] for the explicit-mode variant.
    pub fn prepare(&self) -> PreparedGraph {
        self.prepare_with(crate::gemm::PrepareMode::from_env())
    }

    /// [`Self::prepare`] with an explicit [`crate::gemm::PrepareMode`]:
    /// `Eager` packs every conv/FC weight panel here; `Lazy` defers each
    /// layer's packing to its first execution (packing straight from the
    /// mapped [`crate::tensor::ByteView`] when the weights are view-backed,
    /// so evict→reinstall cycles touch no weight bytes until traffic does).
    /// Both modes are bit-identical — they share the same pack routines.
    /// Depthwise has no GEMM and always prepares eagerly (its plan is the
    /// weights it already holds).
    pub fn prepare_with(&self, mode: crate::gemm::PrepareMode) -> PreparedGraph {
        let nodes = self
            .nodes
            .iter()
            .map(|n| PreparedNode {
                name: n.name.clone(),
                input: n.input,
                op: match &n.op {
                    QOp::Conv(c) => PreparedOp::Conv(c.prepare_with(self.kernel, mode)),
                    QOp::Depthwise(d) => PreparedOp::Depthwise(d.prepare()),
                    QOp::Fc(f) => PreparedOp::Fc(f.prepare_with(self.kernel, mode)),
                    QOp::AvgPool { kernel, stride, padding } => {
                        PreparedOp::AvgPool { kernel: *kernel, stride: *stride, padding: *padding }
                    }
                    QOp::MaxPool { kernel, stride, padding } => {
                        PreparedOp::MaxPool { kernel: *kernel, stride: *stride, padding: *padding }
                    }
                    QOp::GlobalAvgPool => PreparedOp::GlobalAvgPool,
                    QOp::Add { other, out_params } => {
                        PreparedOp::Add { other: *other, out_params: *out_params }
                    }
                    QOp::Concat { others, out_params } => {
                        PreparedOp::Concat { others: others.clone(), out_params: *out_params }
                    }
                    QOp::Softmax => PreparedOp::Softmax,
                    QOp::Logistic => PreparedOp::Logistic,
                },
            })
            .collect();

        // Fusion pass: rewrite conv → Add chains so the residual add runs
        // inside the conv's output stage. A conv qualifies only when the
        // Add is its sole consumer (otherwise another node still needs the
        // raw conv output) and the counterpart operand is already
        // materialized when the conv executes (the graph input or a
        // strictly earlier node). When both operands are qualifying convs
        // only the later one can see the earlier one as its residual, so
        // the larger index wins.
        let mut fused_cfg: Vec<Option<FusedAddCfg>> = vec![None; self.nodes.len()];
        let mut alias: Vec<usize> = (0..self.nodes.len()).collect();
        let mut consumers = vec![0usize; self.nodes.len()];
        for node in &self.nodes {
            for r in node.inputs() {
                if let NodeRef::Node(j) = r {
                    consumers[j] += 1;
                }
            }
        }
        for (a, node) in self.nodes.iter().enumerate() {
            let QOp::Add { other, out_params } = &node.op else { continue };
            let mut pick: Option<(usize, NodeRef)> = None;
            for (op_ref, counterpart) in [(node.input, *other), (*other, node.input)] {
                let NodeRef::Node(c) = op_ref else { continue };
                if !matches!(self.nodes[c].op, QOp::Conv(_)) || consumers[c] != 1 {
                    continue;
                }
                let available = match counterpart {
                    NodeRef::Input => true,
                    NodeRef::Node(j) => j < c,
                };
                if available && pick.is_none_or(|(pc, _)| c > pc) {
                    pick = Some((c, counterpart));
                }
            }
            let Some((c, counterpart)) = pick else { continue };
            let QOp::Conv(conv) = &self.nodes[c].op else { unreachable!() };
            let Some(res_params) = self.node_out_params(counterpart) else { continue };
            fused_cfg[c] = Some(FusedAddCfg {
                src: counterpart,
                cfg: ResidualAdd::for_params(conv.output_params, res_params, *out_params),
                out_params: *out_params,
            });
            alias[a] = c;
        }

        PreparedGraph {
            input_params: self.input_params,
            nodes,
            intra: None,
            fault: None,
            fused_cfg,
            alias,
            fused: fusion_enabled_from_env(),
        }
    }

    /// `OH·OW` of the dominant (highest-MAC) conv layer at batch 1 — the
    /// geometry-derived value for
    /// [`crate::coordinator::BatchPolicy::positions_hint`], so NR-aligned
    /// batch capping engages on real models instead of relying on an
    /// operator-supplied hint. Runs one zero-input probe inference to
    /// resolve layer shapes (install/load-time cost, never on the request
    /// path). Returns 1 (the neutral hint) for graphs without conv layers.
    pub fn dominant_positions(&self, input_shape: [usize; 3]) -> usize {
        let [h, w, c] = input_shape;
        let probe = QTensor::real_zeros(&[1, h, w, c], self.input_params);
        let outs = self.run_all_q(&probe);
        let mut best_macs = 0u64;
        let mut positions = 1usize;
        for (node, out) in self.nodes.iter().zip(&outs) {
            if let QOp::Conv(conv) = &node.op {
                let cout = conv.weights.dim(0);
                let k = (conv.weights.len() / cout) as u64;
                let out_elems = out.data.len() as u64;
                let macs = out_elems * k;
                if macs > best_macs {
                    best_macs = macs;
                    // Batch is 1, so N = OH·OW exactly.
                    positions = out.data.len() / cout;
                }
            }
        }
        positions
    }
}

/// Prepared per-node operation: conv-like nodes carry their packed plans;
/// the rest execute through the `_into` zero-alloc op variants.
#[derive(Clone, Debug)]
enum PreparedOp {
    Conv(PreparedConv2d),
    Depthwise(PreparedDepthwiseConv2d),
    Fc(PreparedFullyConnected),
    AvgPool { kernel: usize, stride: usize, padding: Padding },
    MaxPool { kernel: usize, stride: usize, padding: Padding },
    GlobalAvgPool,
    Add { other: NodeRef, out_params: QuantParams },
    Concat { others: Vec<NodeRef>, out_params: QuantParams },
    Softmax,
    Logistic,
}

/// One node of the prepared graph.
#[derive(Clone, Debug)]
struct PreparedNode {
    #[allow(dead_code)] // surfaced in panics/debug dumps
    name: String,
    input: NodeRef,
    op: PreparedOp,
}

/// A fused `conv → Add` rewrite: the Add became a no-op alias of the conv,
/// which now applies this epilogue in its output stage.
#[derive(Clone, Copy, Debug)]
struct FusedAddCfg {
    /// The residual operand (the Add's non-conv operand).
    src: NodeRef,
    /// App. A.2 rescale configuration for `conv_out + src → out`.
    cfg: ResidualAdd,
    /// The Add's output quantization, adopted by the fused conv output.
    out_params: QuantParams,
}

/// `IAOI_FUSION` env override, read at prepare time: fusion defaults on;
/// `off` or `0` disables it (keeping the unfused oracle reachable in CI).
fn fusion_enabled_from_env() -> bool {
    match std::env::var("IAOI_FUSION") {
        Ok(v) => !matches!(v.as_str(), "off" | "0"),
        Err(_) => true,
    }
}

/// The prepared form of a [`QGraph`]: every weight-side and
/// allocation-shaped cost hoisted out of the per-request path. Immutable
/// and shareable across threads; pair with one [`ExecState`] per worker.
#[derive(Clone, Debug)]
pub struct PreparedGraph {
    pub input_params: QuantParams,
    nodes: Vec<PreparedNode>,
    /// Graph-level intra-op parallelism: when set, [`Self::run_q`] applies
    /// it to the executing state for the duration of the run (restoring
    /// the state's own setting afterwards), so every worker driving this
    /// plan splits large conv/FC GEMMs across the shared
    /// [`crate::gemm::WorkerPool`]. `None` (the default) leaves each
    /// [`ExecState`]'s own setting in force (serial unless the state was
    /// configured via [`ExecState::set_intra`]).
    intra: Option<crate::gemm::IntraOp>,
    /// Deterministic fault injection ([`fault::FaultPlan`]) for chaos tests
    /// and degraded-mode benchmarks; `None` in production. The state is
    /// `Arc`-shared across clones so "panic on the N-th run" counts runs
    /// across every worker driving this plan. Zero-cost when unset: the
    /// run hook is a single `Option` check, no allocation.
    fault: Option<std::sync::Arc<fault::FaultState>>,
    /// Per-node epilogue-fusion configs, indexed by the *conv* node that
    /// absorbs the Add. `None` for unfused nodes. Built by the fusion pass
    /// in [`QGraph::prepare`]; consulted only when [`Self::fused`] is set,
    /// so toggling fusion never requires re-preparing.
    fused_cfg: Vec<Option<FusedAddCfg>>,
    /// Node aliasing for fused Adds: identity everywhere except
    /// `alias[add] = conv`, letting consumers of the Add read the conv's
    /// output slot (which holds the post-add values when fused).
    alias: Vec<usize>,
    /// Whether the fusion rewrites are active. Seeded from `IAOI_FUSION`
    /// at prepare time; [`Self::set_fusion`] overrides per plan.
    fused: bool,
}

/// Per-worker mutable execution state: the layer scratch arena plus
/// reusable per-node output tensors (and a reusable quantized-input slot).
/// After a warm-up run at a given input shape, [`PreparedGraph::run_q`]
/// performs **zero heap allocations** across every op — including Concat
/// (operands resolved by index, no operand-ref `Vec`) and the fixed-point
/// Softmax/Logistic `_into` variants — enforced by `rust/tests/alloc.rs`.
#[derive(Clone, Debug, Default)]
pub struct ExecState {
    scratch: LayerScratch,
    outs: Vec<QTensor>,
    qin: QTensor,
}

impl ExecState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Configure this worker's intra-op GEMM parallelism (e.g. attach the
    /// coordinator's shared [`crate::gemm::WorkerPool`]). Serial by
    /// default; a graph-level setting ([`PreparedGraph::set_intra`]) takes
    /// precedence while running that graph.
    pub fn set_intra(&mut self, intra: crate::gemm::IntraOp) {
        self.scratch.intra = intra;
    }

    /// Total bytes resident in this state's arenas after warm-up: every
    /// node output slot, the reusable quantized-input slot, and the layer
    /// scratch high-water marks. Epilogue fusion shrinks this — a fused
    /// Add's output slot is never written, so it stays at zero capacity
    /// (asserted in `rust/tests/alloc.rs`).
    pub fn arena_bytes(&self) -> usize {
        self.outs.iter().map(|t| t.data.len()).sum::<usize>()
            + self.qin.data.len()
            + self.scratch.bytes()
    }
}

impl PreparedGraph {
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Attach graph-level intra-op parallelism: every subsequent
    /// [`Self::run_q`] applies `intra` to the executing state. Prepared
    /// execution stays bit-identical — the pool only changes who computes
    /// each GEMM column strip.
    pub fn set_intra(&mut self, intra: crate::gemm::IntraOp) {
        self.intra = Some(intra);
    }

    /// Builder-style [`Self::set_intra`].
    pub fn with_intra(mut self, intra: crate::gemm::IntraOp) -> Self {
        self.intra = Some(intra);
        self
    }

    /// Pin the GEMM micro-kernel implementation for every conv/FC plan in
    /// this graph (see [`crate::gemm::dispatch`]) — depthwise, pooling, and
    /// elementwise ops have no GEMM and are unaffected. Plans default to
    /// the process-wide [`crate::gemm::dispatch::active`] selection; this
    /// per-graph override exists so tests and the kernel bench sweep can
    /// force paths without racing on a global.
    pub fn set_ukernel(&mut self, u: &'static crate::gemm::dispatch::KernelDispatch) {
        for node in &mut self.nodes {
            match &mut node.op {
                PreparedOp::Conv(p) => p.set_ukernel(u),
                PreparedOp::Fc(p) => p.set_ukernel(u),
                _ => {}
            }
        }
    }

    /// Builder-style [`Self::set_ukernel`].
    pub fn with_ukernel(mut self, u: &'static crate::gemm::dispatch::KernelDispatch) -> Self {
        self.set_ukernel(u);
        self
    }

    /// Enable or disable the conv→Add epilogue-fusion rewrites discovered
    /// at prepare time. Both settings are bit-identical (the fused epilogue
    /// and [`crate::nn::elementwise::qadd_into`] share
    /// [`ResidualAdd::apply`]); `false` keeps the unfused oracle alive for
    /// differential tests and the `IAOI_FUSION=off` CI lane. Like
    /// [`Self::set_ukernel`], this exists so tests can force both paths
    /// without racing on process environment.
    pub fn set_fusion(&mut self, fused: bool) {
        self.fused = fused;
    }

    /// Builder-style [`Self::set_fusion`].
    pub fn with_fusion(mut self, fused: bool) -> Self {
        self.set_fusion(fused);
        self
    }

    /// Heap bytes currently held by this plan's packed GEMM panels (conv +
    /// FC; other ops carry no plan-side weight copies). Eager plans report
    /// their full packed footprint immediately; lazy plans grow as layers
    /// are first touched — a freshly view-backed lazy plan reports 0.
    /// Surfaced in `/healthz` (`"plan_bytes"`) and `/metrics`
    /// (`iaoi_plan_bytes`).
    pub fn plan_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                PreparedOp::Conv(p) => p.plan_bytes(),
                PreparedOp::Fc(p) => p.plan_bytes(),
                _ => 0,
            })
            .sum()
    }

    /// Number of Add nodes currently executed as fused conv epilogues
    /// (0 when fusion is disabled). Surfaced in the prepare log, bench
    /// artifacts, and the `/healthz` model JSON.
    pub fn fused_nodes(&self) -> usize {
        if self.fused {
            self.fused_cfg.iter().flatten().count()
        } else {
            0
        }
    }

    /// Install a deterministic fault-injection plan: every subsequent run
    /// consults it (counted run, optional delays, panic at the configured
    /// run index). Chaos-test/bench machinery — see [`fault::FaultPlan`].
    pub fn set_fault(&mut self, plan: fault::FaultPlan) {
        self.fault = Some(std::sync::Arc::new(fault::FaultState::new(plan)));
    }

    /// Builder-style [`Self::set_fault`].
    pub fn with_fault(mut self, plan: fault::FaultPlan) -> Self {
        self.set_fault(plan);
        self
    }

    /// The installed fault state, if any (tests read the run counter).
    pub fn fault_state(&self) -> Option<&std::sync::Arc<fault::FaultState>> {
        self.fault.as_ref()
    }

    /// Run from an already-quantized input — the serving hot path. Returns
    /// a borrow of the final node's output slot inside `state` (copy it out
    /// if it must outlive the next run).
    pub fn run_q<'a>(&self, qin: &QTensor, state: &'a mut ExecState) -> &'a QTensor {
        assert!(!self.nodes.is_empty(), "empty graph");
        if let Some(f) = &self.fault {
            f.before_run();
        }
        // Graph-level intra-op config takes precedence for the duration of
        // this run only; the state's own setting is restored afterwards so
        // one ExecState can serve differently-configured plans. Cheap: an
        // Arc clone in, a swap back out, no heap allocation.
        let saved_intra = self
            .intra
            .as_ref()
            .map(|intra| std::mem::replace(&mut state.scratch.intra, intra.clone()));
        while state.outs.len() < self.nodes.len() {
            state.outs.push(QTensor::default());
        }
        let fused = self.fused;
        for (i, node) in self.nodes.iter().enumerate() {
            // A fused Add is a no-op alias of its conv: skip it entirely.
            if fused && self.alias[i] != i {
                continue;
            }
            if let Some(f) = &self.fault {
                f.before_node();
            }
            // Split so earlier outputs stay readable while node i's slot is
            // written — the DAG invariant (validate_topology) guarantees
            // inputs are strictly earlier. When fused, reads resolve
            // through the alias map (always to an index ≤ the original, so
            // still strictly earlier than i).
            let (done, rest) = state.outs.split_at_mut(i);
            let dst = &mut rest[0];
            let fetch = |r: &NodeRef| -> &QTensor {
                match r {
                    NodeRef::Input => qin,
                    NodeRef::Node(j) => &done[if fused { self.alias[*j] } else { *j }],
                }
            };
            let x = fetch(&node.input);
            match &node.op {
                PreparedOp::Conv(p) => {
                    let epi = if fused { self.fused_cfg[i].as_ref() } else { None };
                    match epi {
                        Some(fc) => p.run_into_res(
                            x,
                            Some(ResidualArgs {
                                cfg: fc.cfg,
                                src: fetch(&fc.src),
                                out_params: fc.out_params,
                            }),
                            dst,
                            &mut state.scratch,
                        ),
                        None => p.run_into(x, dst, &mut state.scratch),
                    }
                }
                PreparedOp::Depthwise(p) => p.run_into(x, dst, &mut state.scratch),
                PreparedOp::Fc(p) => p.run_into(x, dst, &mut state.scratch),
                PreparedOp::AvgPool { kernel, stride, padding } => {
                    qavg_pool_into(x, *kernel, *stride, *padding, dst)
                }
                PreparedOp::MaxPool { kernel, stride, padding } => {
                    qmax_pool_into(x, *kernel, *stride, *padding, dst)
                }
                PreparedOp::GlobalAvgPool => qglobal_avg_pool_into(x, dst),
                PreparedOp::Add { other, out_params } => {
                    qadd_into(x, fetch(other), *out_params, dst)
                }
                PreparedOp::Concat { others, out_params } => {
                    // Operands resolved by index straight from the node
                    // slots: no gather Vec, so concat stays zero-alloc.
                    qconcat_into_indexed(
                        others.len() + 1,
                        |i| if i == 0 { x } else { fetch(&others[i - 1]) },
                        *out_params,
                        dst,
                    );
                }
                PreparedOp::Softmax => qsoftmax_into(x, dst, &mut state.scratch),
                PreparedOp::Logistic => qlogistic_into(x, dst),
            }
        }
        if let Some(prev) = saved_intra {
            state.scratch.intra = prev;
        }
        let last = self.nodes.len() - 1;
        &state.outs[if fused { self.alias[last] } else { last }]
    }

    /// Quantize a float input (into the state's reusable slot) and run,
    /// returning the dequantized final output — the float-boundary
    /// convenience mirroring [`QGraph::run`].
    pub fn run(&self, input: &Tensor<f32>, state: &mut ExecState) -> Tensor<f32> {
        let mut qin = std::mem::take(&mut state.qin);
        qin.quantize_from(input, self.input_params);
        let out = self.run_q(&qin, state).dequantize();
        state.qin = qin;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::nn::FusedActivation;

    fn conv_bn_relu_graph(rng: &mut Rng) -> FloatGraph {
        let mut g = FloatGraph::default();
        let mut w = vec![0f32; 4 * 3 * 3 * 3];
        rng.fill_normal(&mut w, 0.3);
        let conv = Conv2d {
            weights: Tensor::from_vec(&[4, 3, 3, 3], w),
            bias: vec![0.1, -0.1, 0.2, 0.0],
            stride: 1,
            padding: Padding::Same,
            activation: FusedActivation::None,
        };
        let c = g.push("conv0", NodeRef::Input, FloatOp::Conv(conv));
        let bn = BatchNorm {
            gamma: vec![1.2, 0.8, 1.0, 0.5],
            beta: vec![0.1, 0.0, -0.2, 0.3],
            mean: vec![0.05, -0.02, 0.1, 0.0],
            var: vec![0.8, 1.1, 0.9, 1.3],
            eps: 1e-3,
        };
        let b = g.push("bn0", c, FloatOp::BatchNorm(bn));
        g.push("relu0", b, FloatOp::Relu6);
        g
    }

    #[test]
    fn bn_fold_preserves_function() {
        // Eq. 14: the folded graph must compute the same function.
        let mut rng = Rng::seeded(101);
        let g = conv_bn_relu_graph(&mut rng);
        let folded = g.fold_batch_norms();
        assert_eq!(folded.nodes.len(), g.nodes.len() - 1);
        let mut xd = vec![0f32; 2 * 6 * 6 * 3];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[2, 6, 6, 3], xd);
        let want = g.run(&x);
        let got = folded.run(&x);
        assert!(want.max_abs_diff(&got) < 1e-5, "diff {}", want.max_abs_diff(&got));
    }

    #[test]
    fn bn_fold_handles_depthwise_and_remaps_skips() {
        let mut rng = Rng::seeded(102);
        let mut g = FloatGraph::default();
        let mut w = vec![0f32; 9 * 3];
        rng.fill_normal(&mut w, 0.4);
        let dw = DepthwiseConv2d {
            weights: Tensor::from_vec(&[1, 3, 3, 3], w),
            bias: vec![],
            stride: 1,
            padding: Padding::Same,
            activation: FusedActivation::None,
        };
        let d = g.push("dw", NodeRef::Input, FloatOp::Depthwise(dw));
        let bn = BatchNorm {
            gamma: vec![0.9, 1.1, 1.0],
            beta: vec![0.0, 0.1, -0.1],
            mean: vec![0.0, 0.05, 0.0],
            var: vec![1.0, 0.9, 1.2],
            eps: 1e-3,
        };
        let b = g.push("bn", d, FloatOp::BatchNorm(bn));
        // Bypass connection over the BN (fig. C.3 style).
        g.push("add", b, FloatOp::Add(NodeRef::Input));

        let folded = g.fold_batch_norms();
        let mut xd = vec![0f32; 5 * 5 * 3];
        for v in xd.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[1, 5, 5, 3], xd);
        assert!(g.run(&x).max_abs_diff(&folded.run(&x)) < 1e-5);
    }

    #[test]
    fn graph_executor_handles_concat_and_pool() {
        let mut g = FloatGraph::default();
        let a = g.push("relu", NodeRef::Input, FloatOp::Relu);
        let b = g.push(
            "pool",
            NodeRef::Input,
            FloatOp::MaxPool { kernel: 1, stride: 1, padding: Padding::Valid },
        );
        g.push("cat", a, FloatOp::Concat(vec![b]));
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![-1.0f32, 2.0, -3.0, 4.0]);
        let y = g.run(&x);
        assert_eq!(y.shape(), &[1, 2, 2, 2]);
        assert_eq!(y.data(), &[0.0, -1.0, 2.0, 2.0, 0.0, -3.0, 4.0, 4.0]);
    }

    #[test]
    fn prepared_graph_matches_unprepared_bit_for_bit() {
        use crate::graph::builders;
        use crate::quantize::{quantize_graph, QuantizeOptions};
        let mut rng = Rng::seeded(211);
        let batches: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; 2 * 16 * 16 * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[2, 16, 16, 3], d)
            })
            .collect();
        for kern in [
            crate::gemm::Kernel::Reference,
            crate::gemm::Kernel::Blocked,
            crate::gemm::Kernel::Int8Pairwise,
        ] {
            let g = builders::papernet_random(6, FusedActivation::Relu6, 211);
            let (_, mut q) = quantize_graph(&g, &batches, QuantizeOptions::default());
            q.kernel = kern;
            let plan = q.prepare();
            let mut state = ExecState::new();
            let qin = QTensor::quantize(&batches[0], q.input_params);
            let want = q.run_q(&qin);
            let got = plan.run_q(&qin, &mut state);
            assert_eq!(want.shape(), got.shape(), "{kern:?}");
            assert_eq!(want.data.data(), got.data.data(), "{kern:?}");
            // Warm rerun and a different batch size through the same state.
            let got2 = plan.run_q(&qin, &mut state);
            assert_eq!(want.data.data(), got2.data.data(), "{kern:?} warm");
            let single = QTensor {
                data: Tensor::from_vec(
                    &[1, 16, 16, 3],
                    qin.data.data()[..16 * 16 * 3].to_vec(),
                ),
                params: qin.params,
            };
            let want1 = q.run_q(&single);
            let got1 = plan.run_q(&single, &mut state);
            assert_eq!(want1.data.data(), got1.data.data(), "{kern:?} batch=1");
        }
    }

    #[test]
    fn prepared_graph_handles_resnet_adds() {
        use crate::graph::builders;
        use crate::quantize::{quantize_graph, QuantizeOptions};
        let mut rng = Rng::seeded(212);
        let batches: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; 12 * 12 * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[1, 12, 12, 3], d)
            })
            .collect();
        let g = builders::mini_resnet(1, 4, 212);
        let (_, q) = quantize_graph(&g, &batches, QuantizeOptions::default());
        let plan = q.prepare();
        let mut state = ExecState::new();
        let qin = QTensor::quantize(&batches[1], q.input_params);
        let want = q.run_q(&qin);
        let got = plan.run_q(&qin, &mut state);
        assert_eq!(want.data.data(), got.data.data());
        // The float-boundary convenience must agree with QGraph::run.
        let wantf = q.run(&batches[1]);
        let gotf = plan.run(&batches[1], &mut state);
        assert_eq!(wantf.data(), gotf.data());
    }

    #[test]
    fn dominant_positions_finds_the_heaviest_conv() {
        use crate::graph::builders;
        use crate::quantize::{quantize_graph, QuantizeOptions};
        let g = builders::papernet_random(4, FusedActivation::Relu6, 77);
        let mut rng = Rng::seeded(77);
        let mut d = vec![0f32; 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let calib = vec![Tensor::from_vec(&[1, 16, 16, 3], d)];
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
        // conv0 dominates at 16×16 input: 16·16·8 outputs × K = 27 MACs
        // beats both pointwise layers; its OH·OW is 256.
        assert_eq!(q.dominant_positions([16, 16, 3]), 256);
        // And at a different geometry the hint scales with it.
        assert_eq!(q.dominant_positions([8, 8, 3]), 64);
    }

    #[test]
    fn graph_level_intra_pool_is_bit_identical() {
        use crate::gemm::{IntraOp, WorkerPool};
        use crate::graph::builders;
        use crate::quantize::{quantize_graph, QuantizeOptions};
        use std::sync::Arc;
        let mut rng = Rng::seeded(218);
        let mut d = vec![0f32; 2 * 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[2, 16, 16, 3], d);
        let g = builders::papernet_random(6, FusedActivation::Relu6, 218);
        let (_, q) = quantize_graph(&g, &[x.clone()], QuantizeOptions::default());
        let qin = QTensor::quantize(&x, q.input_params);
        let want = q.run_q(&qin);

        let pool = Arc::new(WorkerPool::new(3));
        // min_n = 1 forces every conv/FC through the pool.
        let plan = q.prepare().with_intra(IntraOp::pool(pool, 1));
        let mut state = ExecState::new();
        let got = plan.run_q(&qin, &mut state);
        assert_eq!(want.data.data(), got.data.data());
        let again = plan.run_q(&qin, &mut state);
        assert_eq!(want.data.data(), again.data.data(), "warm");
    }

    #[test]
    fn mac_count_sane_for_known_conv() {
        let mut rng = Rng::seeded(104);
        let g = conv_bn_relu_graph(&mut rng);
        // conv: out 1*8*8*4 elems × K = 3*3*3 = 27 → 6912; BN + relu ≈ +512.
        let macs = g.mac_count(&[1, 8, 8, 3]);
        assert!(macs >= 6912 && macs < 8000, "macs {macs}");
    }
}
