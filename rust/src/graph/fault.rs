//! Deterministic fault injection for prepared-graph execution.
//!
//! A [`FaultPlan`] installed on a [`super::PreparedGraph`] (via
//! [`super::PreparedGraph::set_fault`] or the `IAOI_FAULT` environment
//! variable, applied at registry install time) makes the plan panic on a
//! chosen run, panic periodically, or sleep before runs/nodes. The serving
//! layer's containment (`catch_unwind` in the coordinator workers, the
//! per-model circuit breaker) is driven by exactly these injected faults
//! in the chaos tests and the degraded-mode loadgen phase, so the failure
//! paths are exercised deterministically rather than waited for.
//!
//! Injected panics *are* the injected errors: the coordinator converts a
//! contained panic into a structured per-request failure (HTTP 500), which
//! is the only error channel a prepared graph has.
//!
//! `IAOI_FAULT` grammar — comma-separated `key=value` pairs:
//!
//! | key              | meaning                                          |
//! |------------------|--------------------------------------------------|
//! | `panic-on-batch` | panic on exactly the N-th run (1-based)          |
//! | `panic-every`    | panic on every N-th run (`error-every` is an alias) |
//! | `error-on-batch` | alias of `panic-on-batch`                        |
//! | `delay-ms`       | sleep this long at the start of every run        |
//! | `node-delay-us`  | sleep this long before every node                |
//! | `model`          | only inject into plans for this model name       |
//!
//! Everything is std-only and zero-cost when no plan is installed (the
//! hook is a single `Option` check).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// What to inject. `Default` is a no-op plan.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic when the plan's run counter reaches exactly this value
    /// (1-based); 0 = never.
    pub panic_on_run: u64,
    /// Panic on every run whose 1-based index is a multiple of this;
    /// 0 = never.
    pub panic_every: u64,
    /// Sleep this long at the start of every run (simulates a degraded
    /// backend; used by the deadline-shed tests to hold a worker busy).
    pub run_delay: Duration,
    /// Sleep this long before every node (per-node slowdown).
    pub node_delay: Duration,
    /// Restrict env-driven injection to this model name (`None` = all
    /// models). Plans installed explicitly via builder ignore this.
    pub model: Option<String>,
}

impl FaultPlan {
    /// True when the plan would never do anything.
    pub fn is_noop(&self) -> bool {
        self.panic_on_run == 0
            && self.panic_every == 0
            && self.run_delay.is_zero()
            && self.node_delay.is_zero()
    }

    /// Whether env-driven injection targets `model`.
    pub fn applies_to(&self, model: &str) -> bool {
        self.model.as_deref().is_none_or(|m| m == model)
    }

    /// Parse the `IAOI_FAULT` grammar (see module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) =
                part.split_once('=').ok_or_else(|| format!("`{part}`: expected key=value"))?;
            let num = || -> Result<u64, String> {
                value.trim().parse().map_err(|_| format!("`{part}`: bad number `{value}`"))
            };
            match key.trim() {
                "panic-on-batch" | "error-on-batch" => plan.panic_on_run = num()?,
                "panic-every" | "error-every" => plan.panic_every = num()?,
                "delay-ms" => plan.run_delay = Duration::from_millis(num()?),
                "node-delay-us" => plan.node_delay = Duration::from_micros(num()?),
                "model" => plan.model = Some(value.trim().to_string()),
                other => return Err(format!("unknown fault key `{other}`")),
            }
        }
        Ok(plan)
    }

    /// The plan from `IAOI_FAULT`, if set, parseable, and not a no-op.
    /// Parse errors are reported once to stderr and treated as "no plan" —
    /// a typo in a chaos knob must not take down a production launch.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("IAOI_FAULT").ok()?;
        match Self::parse(&spec) {
            Ok(plan) if !plan.is_noop() => Some(plan),
            Ok(_) => None,
            Err(e) => {
                eprintln!("ignoring IAOI_FAULT={spec:?}: {e}");
                None
            }
        }
    }
}

/// A [`FaultPlan`] plus the shared run counter that drives it. One per
/// installed plan, shared (`Arc`) by every clone of the prepared graph, so
/// "panic on the N-th run" counts runs across all serving workers.
#[derive(Debug, Default)]
pub struct FaultState {
    plan: FaultPlan,
    runs: AtomicU64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        FaultState { plan, runs: AtomicU64::new(0) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Runs observed so far (each `before_run` call counts one).
    pub fn runs(&self) -> u64 {
        self.runs.load(Ordering::SeqCst)
    }

    /// Hook at the top of every prepared-graph run: counts the run, applies
    /// the run delay, then panics if this run is a configured fault point.
    pub fn before_run(&self) {
        let n = self.runs.fetch_add(1, Ordering::SeqCst) + 1;
        if !self.plan.run_delay.is_zero() {
            std::thread::sleep(self.plan.run_delay);
        }
        let hit = (self.plan.panic_on_run != 0 && n == self.plan.panic_on_run)
            || (self.plan.panic_every != 0 && n % self.plan.panic_every == 0);
        if hit {
            panic!("injected fault: panic on run {n} (FaultPlan)");
        }
    }

    /// Hook before each node of a run: applies the per-node delay.
    pub fn before_node(&self) {
        if !self.plan.node_delay.is_zero() {
            std::thread::sleep(self.plan.node_delay);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let plan =
            FaultPlan::parse("panic-on-batch=3, panic-every=10,delay-ms=5,node-delay-us=7,model=alpha")
                .unwrap();
        assert_eq!(
            plan,
            FaultPlan {
                panic_on_run: 3,
                panic_every: 10,
                run_delay: Duration::from_millis(5),
                node_delay: Duration::from_micros(7),
                model: Some("alpha".to_string()),
            }
        );
        assert!(plan.applies_to("alpha"));
        assert!(!plan.applies_to("beta"));
        // The error-* aliases land on the same counters.
        let alias = FaultPlan::parse("error-on-batch=3,error-every=10").unwrap();
        assert_eq!((alias.panic_on_run, alias.panic_every), (3, 10));
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("panic-on-batch").is_err());
        assert!(FaultPlan::parse("panic-on-batch=x").is_err());
        assert!(FaultPlan::parse("frobnicate=1").is_err());
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("model=alpha").unwrap().is_noop());
    }

    #[test]
    fn panics_on_exactly_the_configured_run() {
        let state = FaultState::new(FaultPlan { panic_on_run: 3, ..Default::default() });
        state.before_run();
        state.before_run();
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| state.before_run()));
        assert!(hit.is_err(), "third run must panic");
        state.before_run(); // run 4: clean again
        assert_eq!(state.runs(), 4);
    }

    #[test]
    fn panic_every_fires_periodically() {
        let state = FaultState::new(FaultPlan { panic_every: 2, ..Default::default() });
        let mut outcomes = Vec::new();
        for _ in 0..6 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| state.before_run()));
            outcomes.push(r.is_err());
        }
        assert_eq!(outcomes, [false, true, false, true, false, true]);
    }
}
