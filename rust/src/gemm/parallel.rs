//! Multi-threaded quantized GEMM (paper §4.2.3, Table 4.6).
//!
//! The paper reports 1.5–2.2× speedups from running the face detector on
//! 2 and 4 cores. gemmlowp parallelizes by splitting the *result* matrix;
//! we split along N (activation columns) — each worker computes a disjoint
//! column strip `LHS · RHS[:, n0..n1]` including its own output-pipeline
//! application. Workers cooperate with prepared plans
//! ([`super::prepared::PreparedGemm`]): they share the single packed-weight
//! panel read-only, pack their RHS strip **directly from the strided
//! source** into their own scratch (no intermediate strip copy), and write
//! through disjoint `&mut` splits of the one output buffer (no per-thread
//! `sub_out` gather).
//!
//! Two thread-provisioning flavours share that strip plan (this offline
//! build has no rayon; see DESIGN.md §Offline-substitutions):
//!
//! * [`run_strips_scoped`] — plain `std::thread::scope` spawns, paying a
//!   thread spawn + join per worker per call. Kept as the baseline the
//!   persistent pool is benchmarked against.
//! * [`super::pool::WorkerPool::run_strips`] — long-lived workers fed over
//!   a job channel; the serving path ([`run_parallel_prepared`] and the
//!   prepared conv/FC layers) routes through it so per-call threading cost
//!   is packing, not thread creation.
//!
//! Both are bit-identical to serial execution for every thread count. On
//! this single-core testbed thread counts > 1 measure scheduling overhead;
//! `sim::ArmCoreModel` provides the multi-core latency estimates for
//! Table 4.6 (DESIGN.md §Hardware-Adaptation).
//!
//! Workers inherit the plan's micro-kernel ([`super::dispatch`]): every
//! strip executes `PreparedGemm::run_strip` → `accumulate_cols`, so the
//! runtime-dispatched SIMD tile (or a per-plan `set_ukernel` override)
//! applies identically on the serial, scoped, and pooled paths.

use super::output::ResidualAdd;
use super::pool::{carve_row_segments, carve_strips, WorkerPool};
use super::prepared::{PreparedGemm, Scratch};
use super::{output::OutputStage, Kernel, QGemm};

/// Run the full quantized GEMM splitting the N dimension into `threads`
/// strips, each computed on its own scoped OS thread. Packs the weights
/// into a one-shot prepared plan; callers that run the same weights
/// repeatedly should build a [`PreparedGemm`] themselves and call
/// [`run_parallel_prepared`] with a persistent [`WorkerPool`] to pay both
/// the packing and the thread-spawn cost once.
///
/// All operand lengths are validated up front — a short RHS fails here
/// with the real geometry, not deep inside strip packing with a misleading
/// slice-bounds panic (or, worse, silently in the serial fallback).
pub fn run_parallel(
    g: &QGemm,
    kern: Kernel,
    lhs: &[u8],
    rhs: &[u8],
    stage: &OutputStage,
    out: &mut [u8],
    threads: usize,
) {
    assert!(threads >= 1);
    assert_eq!(lhs.len(), g.m * g.k, "lhs must be M*K");
    assert_eq!(rhs.len(), g.k * g.n, "rhs must be K*N");
    assert_eq!(out.len(), g.m * g.n, "out must be M*N");
    if threads == 1 || g.n < 2 * threads {
        g.run(kern, lhs, rhs, stage, out);
        return;
    }
    let plan = PreparedGemm::from_qgemm(g, kern, lhs, stage.clone());
    run_strips_scoped(&plan, rhs, g.n, out, threads);
}

/// Multi-threaded execution of a prepared plan over a row-major `K×N` RHS,
/// routed through a persistent [`WorkerPool`] (the pool's degree decides
/// the split; narrow `n` degenerates to serial). The plan (packed weights,
/// row sums, output stage) is shared read-only; pool workers reuse their
/// own long-lived [`Scratch`]es, the calling thread computes the first
/// strip.
pub fn run_parallel_prepared(
    plan: &PreparedGemm,
    rhs: &[u8],
    n: usize,
    out: &mut [u8],
    pool: &WorkerPool,
) {
    pool.run_strips(plan, rhs, n, out, &mut Scratch::new());
}

/// The scoped-spawn baseline: same strip partition as the pool path, but
/// every worker is a fresh `std::thread::scope` thread with a cold
/// [`Scratch`]. This is what `run_parallel_prepared` did before the
/// persistent pool existed; it remains the honest per-call-spawn
/// comparison point for `iaoi bench --table pool` and
/// `cargo bench --bench multithread`.
pub fn run_strips_scoped(
    plan: &PreparedGemm,
    rhs: &[u8],
    n: usize,
    out: &mut [u8],
    threads: usize,
) {
    run_strips_scoped_res(plan, rhs, n, out, None, threads);
}

/// [`run_strips_scoped`] with the composable residual-add epilogue: each
/// scoped worker applies the fused [`ResidualAdd`] to its own column strip
/// of the shared NHWC residual source.
pub fn run_strips_scoped_res(
    plan: &PreparedGemm,
    rhs: &[u8],
    n: usize,
    out: &mut [u8],
    res: Option<(&ResidualAdd, &[u8])>,
    threads: usize,
) {
    assert!(threads >= 1);
    let m = plan.m();
    assert_eq!(rhs.len(), plan.k() * n, "rhs must be K*N");
    assert_eq!(out.len(), m * n, "out must be M*N");
    if threads == 1 || n < 2 * threads {
        plan.run_res(n, rhs, out, res, &mut Scratch::new());
        return;
    }
    let strips = carve_strips(n, threads);
    let per_worker = carve_row_segments(out, m, n, &strips);

    std::thread::scope(|scope| {
        let handles: Vec<_> = strips
            .iter()
            .zip(per_worker)
            .map(|(&(n0, _), mut segs)| {
                scope.spawn(move || {
                    let mut scratch = Scratch::new();
                    plan.run_strip_res(rhs, n, n0, &mut segs, res, &mut scratch);
                })
            })
            .collect();
        for h in handles {
            h.join().expect("gemm worker panicked");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedMultiplier;

    fn pseudo(seed: u64, n: usize) -> Vec<u8> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(17);
                (s >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_for_all_thread_counts() {
        let (m, k, n) = (6, 40, 37);
        let g = QGemm::new(m, k, n, 120, 99);
        let lhs = pseudo(5, m * k);
        let rhs = pseudo(6, k * n);
        let stage = OutputStage {
            bias: (0..m as i32).map(|i| i * 100 - 200).collect(),
            multiplier: QuantizedMultiplier::from_f64(0.003).into(),
            out_zero: 17,
            clamp_min: 3,
            clamp_max: 250,
        };
        let mut want = vec![0u8; m * n];
        g.run(Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut want);
        for threads in [1, 2, 3, 4, 8] {
            let mut got = vec![0u8; m * n];
            run_parallel(&g, Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut got, threads);
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn prepared_parallel_matches_serial_across_kernels() {
        let (m, k, n) = (9, 65, 52);
        let g = QGemm::new(m, k, n, 88, 140);
        let lhs = pseudo(7, m * k).iter().map(|&v| v.max(1)).collect::<Vec<_>>();
        let rhs = pseudo(8, k * n);
        let stage = OutputStage {
            bias: (0..m as i32).map(|i| 50 - i * 13).collect(),
            multiplier: crate::gemm::output::Requant::PerChannel(
                (0..m)
                    .map(|i| QuantizedMultiplier::from_f64(0.0017 * 1.3f64.powi(i as i32 % 5)))
                    .collect(),
            ),
            out_zero: 9,
            clamp_min: 0,
            clamp_max: 255,
        };
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let plan = PreparedGemm::from_qgemm(&g, kern, &lhs, stage.clone());
            let mut want = vec![0u8; m * n];
            plan.run(n, &rhs, &mut want, &mut Scratch::new());
            for threads in [2, 3, 5] {
                // Scoped-spawn baseline and pool-routed execution must both
                // reproduce the serial bytes.
                let mut scoped = vec![0u8; m * n];
                run_strips_scoped(&plan, &rhs, n, &mut scoped, threads);
                assert_eq!(want, scoped, "{kern:?} threads={threads} scoped");
                let pool = WorkerPool::new(threads);
                let mut pooled = vec![0u8; m * n];
                run_parallel_prepared(&plan, &rhs, n, &mut pooled, &pool);
                assert_eq!(want, pooled, "{kern:?} threads={threads} pool");
            }
        }
    }

    #[test]
    fn forced_ukernels_agree_across_parallel_paths() {
        // Each available SIMD micro-kernel, pinned on the plan, must match
        // the scalar-forced serial bytes through both the scoped-spawn and
        // the pooled strip paths.
        let (m, k, n) = (11, 300, 47);
        let g = QGemm::new(m, k, n, 77, 201);
        let lhs = pseudo(31, m * k);
        let rhs = pseudo(32, k * n);
        let stage = OutputStage {
            bias: (0..m as i32).map(|i| i * 21 - 90).collect(),
            multiplier: QuantizedMultiplier::from_f64(0.0029).into(),
            out_zero: 11,
            clamp_min: 0,
            clamp_max: 255,
        };
        let base = PreparedGemm::from_qgemm(&g, Kernel::Blocked, &lhs, stage)
            .with_ukernel(crate::gemm::dispatch::scalar());
        let mut want = vec![0u8; m * n];
        base.run(n, &rhs, &mut want, &mut Scratch::new());
        for d in crate::gemm::dispatch::available() {
            let plan = base.clone().with_ukernel(d);
            let mut scoped = vec![0u8; m * n];
            run_strips_scoped(&plan, &rhs, n, &mut scoped, 3);
            assert_eq!(want, scoped, "{} scoped", d.name);
            let pool = WorkerPool::new(2);
            let mut pooled = vec![0u8; m * n];
            run_parallel_prepared(&plan, &rhs, n, &mut pooled, &pool);
            assert_eq!(want, pooled, "{} pool", d.name);
        }
    }

    #[test]
    fn degenerate_narrow_n_falls_back_to_serial() {
        let (m, k, n) = (4, 16, 3);
        let g = QGemm::new(m, k, n, 0, 0);
        let lhs = pseudo(1, m * k);
        let rhs = pseudo(2, k * n);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.01), 0);
        let mut a = vec![0u8; m * n];
        let mut b = vec![0u8; m * n];
        g.run(Kernel::Blocked, &lhs, &rhs, &stage, &mut a);
        run_parallel(&g, Kernel::Blocked, &lhs, &rhs, &stage, &mut b, 4);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "rhs must be K*N")]
    fn short_rhs_fails_up_front_with_the_real_geometry() {
        // Regression: a short RHS used to survive until strip packing (or
        // the serial fallback) and die on an unrelated slice bound.
        let (m, k, n) = (4, 16, 64);
        let g = QGemm::new(m, k, n, 0, 0);
        let lhs = pseudo(1, m * k);
        let rhs = pseudo(2, k * n - 5);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.01), 0);
        let mut out = vec![0u8; m * n];
        run_parallel(&g, Kernel::Blocked, &lhs, &rhs, &stage, &mut out, 4);
    }

    #[test]
    #[should_panic(expected = "rhs must be K*N")]
    fn short_rhs_fails_up_front_even_on_the_serial_fallback() {
        let (m, k, n) = (4, 16, 3);
        let g = QGemm::new(m, k, n, 0, 0);
        let lhs = pseudo(1, m * k);
        let rhs = pseudo(2, k * n - 1);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.01), 0);
        let mut out = vec![0u8; m * n];
        // threads=4 with n=3 would fall back to the serial path.
        run_parallel(&g, Kernel::Blocked, &lhs, &rhs, &stage, &mut out, 4);
    }
}
