//! Multi-threaded quantized GEMM (paper §4.2.3, Table 4.6).
//!
//! The paper reports 1.5–2.2× speedups from running the face detector on
//! 2 and 4 cores. gemmlowp parallelizes by splitting the *result* matrix;
//! we split along N (activation columns) — each worker computes a disjoint
//! column strip `LHS · RHS[:, n0..n1]` including its own output-pipeline
//! application. Workers cooperate with prepared plans
//! ([`super::prepared::PreparedGemm`]): they share the single packed-weight
//! panel read-only, pack their RHS strip **directly from the strided
//! source** into their own scratch (no intermediate strip copy), and write
//! through disjoint `&mut` splits of the one output buffer (no per-thread
//! `sub_out` gather). Workers are plain `std::thread::scope` threads (this
//! offline build has no rayon; see DESIGN.md §Offline-substitutions). On
//! this single-core testbed thread counts > 1 measure scheduling overhead;
//! `sim::ArmCoreModel` provides the multi-core latency estimates for
//! Table 4.6 (DESIGN.md §Hardware-Adaptation).

use super::prepared::{PreparedGemm, Scratch};
use super::{output::OutputStage, Kernel, QGemm};

/// Run the full quantized GEMM splitting the N dimension into `threads`
/// strips, each computed on its own OS thread. Packs the weights into a
/// one-shot prepared plan; callers that run the same weights repeatedly
/// should build a [`PreparedGemm`] themselves and call
/// [`run_parallel_prepared`] to pay the packing cost once.
pub fn run_parallel(
    g: &QGemm,
    kern: Kernel,
    lhs: &[u8],
    rhs: &[u8],
    stage: &OutputStage,
    out: &mut [u8],
    threads: usize,
) {
    assert!(threads >= 1);
    assert_eq!(out.len(), g.m * g.n);
    if threads == 1 || g.n < 2 * threads {
        g.run(kern, lhs, rhs, stage, out);
        return;
    }
    let plan = PreparedGemm::from_qgemm(g, kern, lhs, stage.clone());
    run_parallel_prepared(&plan, rhs, g.n, out, threads);
}

/// Multi-threaded execution of a prepared plan over a row-major `K×N` RHS.
/// The plan (packed weights, row sums, output stage) is shared read-only;
/// each worker owns a [`Scratch`] and a disjoint set of per-row output
/// segments, so no worker ever copies its strip out of or back into a
/// gather buffer.
pub fn run_parallel_prepared(
    plan: &PreparedGemm,
    rhs: &[u8],
    n: usize,
    out: &mut [u8],
    threads: usize,
) {
    assert!(threads >= 1);
    let m = plan.m();
    assert_eq!(rhs.len(), plan.k() * n, "rhs must be K*N");
    assert_eq!(out.len(), m * n, "out must be M*N");
    if threads == 1 || n < 2 * threads {
        plan.run(n, rhs, out, &mut Scratch::new());
        return;
    }
    let strip = n.div_ceil(threads);
    let strips: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * strip, ((t + 1) * strip).min(n)))
        .filter(|(a, b)| a < b)
        .collect();

    // Carve the output into disjoint &mut row segments, one set per worker:
    // worker w gets rows' sub-slices [n0_w, n1_w) for every row.
    let mut per_worker: Vec<Vec<&mut [u8]>> =
        strips.iter().map(|_| Vec::with_capacity(m)).collect();
    let mut rest: &mut [u8] = out;
    for _ in 0..m {
        let (row, tail) = rest.split_at_mut(n);
        rest = tail;
        let mut row_rest = row;
        for (w, &(n0, n1)) in strips.iter().enumerate() {
            let (seg, t) = row_rest.split_at_mut(n1 - n0);
            row_rest = t;
            per_worker[w].push(seg);
        }
    }

    std::thread::scope(|scope| {
        let handles: Vec<_> = strips
            .iter()
            .zip(per_worker)
            .map(|(&(n0, _), mut segs)| {
                scope.spawn(move || {
                    let mut scratch = Scratch::new();
                    plan.run_strip(rhs, n, n0, &mut segs, &mut scratch);
                })
            })
            .collect();
        for h in handles {
            h.join().expect("gemm worker panicked");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedMultiplier;

    fn pseudo(seed: u64, n: usize) -> Vec<u8> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(17);
                (s >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_for_all_thread_counts() {
        let (m, k, n) = (6, 40, 37);
        let g = QGemm::new(m, k, n, 120, 99);
        let lhs = pseudo(5, m * k);
        let rhs = pseudo(6, k * n);
        let stage = OutputStage {
            bias: (0..m as i32).map(|i| i * 100 - 200).collect(),
            multiplier: QuantizedMultiplier::from_f64(0.003).into(),
            out_zero: 17,
            clamp_min: 3,
            clamp_max: 250,
        };
        let mut want = vec![0u8; m * n];
        g.run(Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut want);
        for threads in [1, 2, 3, 4, 8] {
            let mut got = vec![0u8; m * n];
            run_parallel(&g, Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut got, threads);
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn prepared_parallel_matches_serial_across_kernels() {
        let (m, k, n) = (9, 65, 52);
        let g = QGemm::new(m, k, n, 88, 140);
        let lhs = pseudo(7, m * k).iter().map(|&v| v.max(1)).collect::<Vec<_>>();
        let rhs = pseudo(8, k * n);
        let stage = OutputStage {
            bias: (0..m as i32).map(|i| 50 - i * 13).collect(),
            multiplier: super::output::Requant::PerChannel(
                (0..m)
                    .map(|i| QuantizedMultiplier::from_f64(0.0017 * 1.3f64.powi(i as i32 % 5)))
                    .collect(),
            ),
            out_zero: 9,
            clamp_min: 0,
            clamp_max: 255,
        };
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let plan = PreparedGemm::from_qgemm(&g, kern, &lhs, stage.clone());
            let mut want = vec![0u8; m * n];
            plan.run(n, &rhs, &mut want, &mut Scratch::new());
            for threads in [2, 3, 5] {
                let mut got = vec![0u8; m * n];
                run_parallel_prepared(&plan, &rhs, n, &mut got, threads);
                assert_eq!(want, got, "{kern:?} threads={threads}");
            }
        }
    }

    #[test]
    fn degenerate_narrow_n_falls_back_to_serial() {
        let (m, k, n) = (4, 16, 3);
        let g = QGemm::new(m, k, n, 0, 0);
        let lhs = pseudo(1, m * k);
        let rhs = pseudo(2, k * n);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.01), 0);
        let mut a = vec![0u8; m * n];
        let mut b = vec![0u8; m * n];
        g.run(Kernel::Blocked, &lhs, &rhs, &stage, &mut a);
        run_parallel(&g, Kernel::Blocked, &lhs, &rhs, &stage, &mut b, 4);
        assert_eq!(a, b);
    }
}
