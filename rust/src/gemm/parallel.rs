//! Multi-threaded quantized GEMM (paper §4.2.3, Table 4.6).
//!
//! The paper reports 1.5–2.2× speedups from running the face detector on
//! 2 and 4 cores. gemmlowp parallelizes by splitting the *result* matrix;
//! we split the RHS (activations) along N — each worker computes a disjoint
//! column strip `LHS · RHS[:, n0..n1]` including its own output-pipeline
//! application, so workers share only read-only inputs and never contend on
//! writes. Workers are plain `std::thread::scope` threads (this offline
//! build has no rayon; see DESIGN.md §Offline-substitutions). On this
//! single-core testbed thread counts > 1 measure scheduling overhead;
//! `sim::ArmCoreModel` provides the multi-core latency estimates for
//! Table 4.6 (DESIGN.md §Hardware-Adaptation).

use super::{output::OutputStage, Kernel, QGemm};

/// Run the full quantized GEMM splitting the N dimension into `threads`
/// strips, each computed on its own OS thread.
pub fn run_parallel(
    g: &QGemm,
    kern: Kernel,
    lhs: &[u8],
    rhs: &[u8],
    stage: &OutputStage,
    out: &mut [u8],
    threads: usize,
) {
    assert!(threads >= 1);
    assert_eq!(out.len(), g.m * g.n);
    if threads == 1 || g.n < 2 * threads {
        g.run(kern, lhs, rhs, stage, out);
        return;
    }
    let strip = g.n.div_ceil(threads);
    let strips: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * strip, ((t + 1) * strip).min(g.n)))
        .filter(|(a, b)| a < b)
        .collect();

    let results: Vec<(usize, usize, Vec<u8>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = strips
            .iter()
            .map(|&(n0, n1)| {
                scope.spawn(move || {
                    let nn = n1 - n0;
                    // Gather the RHS strip (rows stay K, columns n0..n1).
                    let mut rhs_strip = vec![0u8; g.k * nn];
                    for j in 0..g.k {
                        rhs_strip[j * nn..(j + 1) * nn]
                            .copy_from_slice(&rhs[j * g.n + n0..j * g.n + n1]);
                    }
                    let sub = QGemm { n: nn, ..g.clone() };
                    let mut sub_out = vec![0u8; g.m * nn];
                    sub.run(kern, lhs, &rhs_strip, stage, &mut sub_out);
                    (n0, n1, sub_out)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("gemm worker panicked")).collect()
    });

    for (n0, n1, sub_out) in results {
        let nn = n1 - n0;
        for i in 0..g.m {
            out[i * g.n + n0..i * g.n + n1].copy_from_slice(&sub_out[i * nn..(i + 1) * nn]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedMultiplier;

    fn pseudo(seed: u64, n: usize) -> Vec<u8> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(17);
                (s >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_for_all_thread_counts() {
        let (m, k, n) = (6, 40, 37);
        let g = QGemm::new(m, k, n, 120, 99);
        let lhs = pseudo(5, m * k);
        let rhs = pseudo(6, k * n);
        let stage = OutputStage {
            bias: (0..m as i32).map(|i| i * 100 - 200).collect(),
            multiplier: QuantizedMultiplier::from_f64(0.003),
            out_zero: 17,
            clamp_min: 3,
            clamp_max: 250,
        };
        let mut want = vec![0u8; m * n];
        g.run(Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut want);
        for threads in [1, 2, 3, 4, 8] {
            let mut got = vec![0u8; m * n];
            run_parallel(&g, Kernel::Int8Pairwise, &lhs, &rhs, &stage, &mut got, threads);
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn degenerate_narrow_n_falls_back_to_serial() {
        let (m, k, n) = (4, 16, 3);
        let g = QGemm::new(m, k, n, 0, 0);
        let lhs = pseudo(1, m * k);
        let rhs = pseudo(2, k * n);
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.01), 0);
        let mut a = vec![0u8; m * n];
        let mut b = vec![0u8; m * n];
        g.run(Kernel::Blocked, &lhs, &rhs, &stage, &mut a);
        run_parallel(&g, Kernel::Blocked, &lhs, &rhs, &stage, &mut b, 4);
        assert_eq!(a, b);
    }
}
