//! Integer-arithmetic-only matrix multiplication (§2.2–2.4) — the Rust
//! counterpart of gemmlowp's `GemmWithOutputPipeline`.
//!
//! The core computation is eq. 7: the product of two quantized matrices
//! reduces to one uint8 integer GEMM accumulation `Σ_j q1·q2` (eq. 9, the
//! only `O(M·N·K)` term) plus `O(M·N)` corrections built from row sums of
//! the LHS and column sums of the RHS — the paper's "efficient handling of
//! zero-points" (§2.3). The int32 accumulators then pass through the fused
//! output pipeline (§2.4): int32 bias addition, fixed-point multiplication
//! by the normalized multiplier `M = 2^-n·M0`, saturating cast to uint8 and
//! the clamp that subsumes ReLU/ReLU6.
//!
//! Three interchangeable inner kernels compute eq. 9:
//! * [`Kernel::Reference`] — the obviously-correct triple loop;
//! * [`Kernel::Blocked`] — cache-blocked and panel-packed ([`kernel`]);
//! * [`Kernel::Int8Pairwise`] — the App. B trick: operands recentred to
//!   int8 (weights guaranteed in [−127,127] by training), two products
//!   accumulated in an int16 before widening (SMULL/SMLAL/SADALP analogue).
//!
//! The Blocked kernel's MR×NR inner tile is additionally **runtime
//! dispatched** ([`dispatch`]): scalar always, SSE2/AVX2/AVX-512 `pmaddwd`
//! variants where the CPU supports them, selected once per process
//! (`IAOI_KERNEL` overrides). Every path — and every dispatch variant — is
//! bit-identical; tests enforce it.

pub mod dispatch;
pub mod int8_trick;
pub mod kernel;
pub mod output;
pub mod parallel;
pub mod pool;
pub mod prepared;

pub use kernel::{KC, MR, NR};
pub use output::{OutputStage, ResidualAdd, ADD_LEFT_SHIFT};
pub use pool::{IntraOp, IntraStrategy, WorkerPool};
pub use prepared::{LhsBytes, PrepareMode, PreparedGemm, Scratch};

use crate::quant::QuantizedMultiplier;

/// Which inner kernel computes the eq. 9 accumulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Kernel {
    /// Naive triple loop (correctness oracle).
    Reference,
    /// Cache-blocked, panel-packed u8 kernel. Default: with AVX-512 on this
    /// host the widened MR×NR i32 tile out-runs the pairwise path (see
    /// EXPERIMENTS.md §Perf for the measured iteration log).
    #[default]
    Blocked,
    /// App. B int8 path with i16 pairwise accumulation — the faithful ARM
    /// NEON (SMULL/SMLAL/SADALP) schedule.
    Int8Pairwise,
}

impl Kernel {
    /// Stable numeric code for binary model artifacts
    /// ([`crate::model_format`]). Codes are append-only across versions.
    pub fn code(self) -> u8 {
        match self {
            Kernel::Reference => 0,
            Kernel::Blocked => 1,
            Kernel::Int8Pairwise => 2,
        }
    }

    /// Inverse of [`Self::code`]; `None` for unknown codes.
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(Kernel::Reference),
            1 => Some(Kernel::Blocked),
            2 => Some(Kernel::Int8Pairwise),
            _ => None,
        }
    }
}

/// Geometry and quantization of one quantized GEMM: `LHS (M×K) · RHS (K×N)`.
///
/// By §2.4 convention the LHS is the weights matrix (`Z1 = lhs_zero`) and
/// the RHS is the activations matrix (`Z2 = rhs_zero`); the output carries
/// `Z3 = out_zero` inside the [`OutputStage`].
#[derive(Clone, Debug)]
pub struct QGemm {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Zero-point of the LHS (weights), `Z1`.
    pub lhs_zero: i32,
    /// Zero-point of the RHS (activations), `Z2`.
    pub rhs_zero: i32,
}

impl QGemm {
    pub fn new(m: usize, k: usize, n: usize, lhs_zero: i32, rhs_zero: i32) -> Self {
        assert!(
            (0..=255).contains(&lhs_zero) && (0..=255).contains(&rhs_zero),
            "zero points are quantized values (§2.1)"
        );
        Self { m, k, n, lhs_zero, rhs_zero }
    }

    /// Full quantized GEMM: eq. 7 + output pipeline, writing uint8 outputs.
    ///
    /// `lhs` is row-major `M×K`, `rhs` row-major `K×N`, `out` row-major
    /// `M×N`.
    pub fn run(&self, kern: Kernel, lhs: &[u8], rhs: &[u8], stage: &OutputStage, out: &mut [u8]) {
        let mut acc = vec![0i32; self.m * self.n];
        self.accumulate(kern, lhs, rhs, &mut acc);
        stage.apply(&acc, self.m, self.n, out);
    }

    /// Compute the corrected int32 accumulators
    /// `Σ_j (q1−Z1)(q2−Z2) = K·Z1·Z2 − Z1·a2 − Z2·ā1 + Σ_j q1·q2` (eq. 7)
    /// without applying the output stage (used by bias-less fusions and by
    /// tests).
    pub fn accumulate(&self, kern: Kernel, lhs: &[u8], rhs: &[u8], acc: &mut [i32]) {
        assert_eq!(lhs.len(), self.m * self.k, "lhs must be M*K");
        assert_eq!(rhs.len(), self.k * self.n, "rhs must be K*N");
        assert_eq!(acc.len(), self.m * self.n, "out must be M*N");
        match kern {
            Kernel::Reference => self.accumulate_reference(lhs, rhs, acc),
            Kernel::Blocked => kernel::accumulate_blocked(self, lhs, rhs, acc),
            Kernel::Int8Pairwise => int8_trick::accumulate_int8_pairwise(self, lhs, rhs, acc),
        }
    }

    /// Reference implementation: direct evaluation of eq. 4, `2·M·N·K`
    /// subtractions and all — the form §2.3 exists to avoid. Kept as the
    /// correctness oracle for the optimized kernels.
    fn accumulate_reference(&self, lhs: &[u8], rhs: &[u8], acc: &mut [i32]) {
        for i in 0..self.m {
            for col in 0..self.n {
                let mut sum = 0i32;
                for j in 0..self.k {
                    let a = i32::from(lhs[i * self.k + j]) - self.lhs_zero;
                    let b = i32::from(rhs[j * self.n + col]) - self.rhs_zero;
                    sum += a * b;
                }
                acc[i * self.n + col] = sum;
            }
        }
    }

    /// Row sums `ā1(i) = Σ_j q1(i,j)` of the LHS (eq. 8). `O(M·K)`.
    pub fn lhs_row_sums(&self, lhs: &[u8]) -> Vec<i32> {
        let mut sums = vec![0i32; self.m];
        for i in 0..self.m {
            let row = &lhs[i * self.k..(i + 1) * self.k];
            sums[i] = row.iter().map(|&v| i32::from(v)).sum();
        }
        sums
    }

    /// Column sums `a2(k) = Σ_j q2(j,k)` of the RHS (eq. 8). `O(K·N)`.
    pub fn rhs_col_sums(&self, rhs: &[u8]) -> Vec<i32> {
        let mut sums = vec![0i32; self.n];
        for j in 0..self.k {
            let row = &rhs[j * self.n..(j + 1) * self.n];
            for (s, &v) in sums.iter_mut().zip(row) {
                *s += i32::from(v);
            }
        }
        sums
    }

    /// Apply the eq. 7 zero-point corrections to raw `Σ q1·q2` accumulators.
    pub fn apply_zero_point_corrections(
        &self,
        raw: &mut [i32],
        lhs_row_sums: &[i32],
        rhs_col_sums: &[i32],
    ) {
        let kzz = self.k as i32 * self.lhs_zero * self.rhs_zero;
        for i in 0..self.m {
            let row_term = kzz - self.rhs_zero * lhs_row_sums[i];
            let out_row = &mut raw[i * self.n..(i + 1) * self.n];
            for (o, &cs) in out_row.iter_mut().zip(rhs_col_sums) {
                *o += row_term - self.lhs_zero * cs;
            }
        }
    }
}

/// Plain f32 GEMM, row-major `M×K · K×N` — the "Eigen" baseline the paper
/// benchmarks float inference with (§4). Blocked the same way as the
/// quantized kernel so the comparison is fair.
pub fn gemm_f32(m: usize, k: usize, n: usize, lhs: &[f32], rhs: &[f32], out: &mut [f32]) {
    assert_eq!(lhs.len(), m * k);
    assert_eq!(rhs.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // Loop order i-j-col keeps rhs row access contiguous and lets LLVM
    // vectorize the inner axpy. No zero-skip: a data-dependent branch would
    // make the float baseline's cost vary with weight sparsity and the
    // quantized-vs-float speedup numbers dishonest (§4 compares dense
    // kernels on both sides).
    for i in 0..m {
        let out_row = &mut out[i * n..(i + 1) * n];
        for j in 0..k {
            let a = lhs[i * k + j];
            let rhs_row = &rhs[j * n..(j + 1) * n];
            for (o, &b) in out_row.iter_mut().zip(rhs_row) {
                *o += a * b;
            }
        }
    }
}

/// Convenience: the requantization multiplier for a GEMM with the given
/// input/weight/output scales (eq. 5 + 6).
pub fn gemm_multiplier(s_weights: f64, s_input: f64, s_output: f64) -> QuantizedMultiplier {
    crate::quant::quantize_multiplier(s_weights, s_input, s_output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantParams;

    fn pseudo(seed: u64, n: usize, lo: u8, hi: u8) -> Vec<u8> {
        // Small deterministic LCG for test data.
        let mut state = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let span = u64::from(hi) - u64::from(lo) + 1;
                lo + ((state >> 33) % span) as u8
            })
            .collect()
    }

    #[test]
    fn all_kernels_bit_identical() {
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (8, 16, 8), (13, 31, 17), (32, 64, 48)] {
            let g = QGemm::new(m, k, n, 131, 119);
            // Narrow-range lhs (weights never hit 0 → int8 never -128).
            let lhs = pseudo(m as u64, m * k, 1, 255);
            let rhs = pseudo(n as u64, k * n, 0, 255);
            let mut a = vec![0i32; m * n];
            let mut b = vec![0i32; m * n];
            let mut c = vec![0i32; m * n];
            g.accumulate(Kernel::Reference, &lhs, &rhs, &mut a);
            g.accumulate(Kernel::Blocked, &lhs, &rhs, &mut b);
            g.accumulate(Kernel::Int8Pairwise, &lhs, &rhs, &mut c);
            assert_eq!(a, b, "blocked != reference at ({m},{k},{n})");
            assert_eq!(a, c, "int8 != reference at ({m},{k},{n})");
        }
    }

    #[test]
    fn zero_point_corrections_match_direct_form() {
        // Eq. 7 == eq. 4: raw Σq1q2 + corrections must equal the direct
        // subtract-then-multiply evaluation.
        let (m, k, n) = (5, 9, 6);
        let g = QGemm::new(m, k, n, 100, 50);
        let lhs = pseudo(7, m * k, 0, 255);
        let rhs = pseudo(9, k * n, 0, 255);
        let mut direct = vec![0i32; m * n];
        g.accumulate(Kernel::Reference, &lhs, &rhs, &mut direct);

        // Raw uint8 products only (the eq. 9 core).
        let mut raw = vec![0i32; m * n];
        for i in 0..m {
            for col in 0..n {
                let mut s = 0i32;
                for j in 0..k {
                    s += i32::from(lhs[i * k + j]) * i32::from(rhs[j * n + col]);
                }
                raw[i * n + col] = s;
            }
        }
        let rs = g.lhs_row_sums(&lhs);
        let cs = g.rhs_col_sums(&rhs);
        g.apply_zero_point_corrections(&mut raw, &rs, &cs);
        assert_eq!(raw, direct);
    }

    #[test]
    fn quantized_gemm_tracks_real_matmul() {
        // End-to-end §2.2 semantics: dequantize(q3) ≈ r1 · r2 within the
        // output scale's rounding error plus input quantization error.
        let (m, k, n) = (4, 32, 4);
        let lhs_p = QuantParams::from_min_max(-1.0, 1.0, 1, 255);
        let rhs_p = QuantParams::from_min_max(-2.0, 2.0, 0, 255);
        // Generous output range so M < 1.
        let out_p = QuantParams::from_min_max(-40.0, 40.0, 0, 255);

        let lhs_r: Vec<f32> = (0..m * k).map(|i| ((i * 37 % 101) as f32 / 50.0) - 1.0).collect();
        let rhs_r: Vec<f32> = (0..k * n).map(|i| ((i * 53 % 89) as f32 / 22.0) - 2.0).collect();
        let lhs_q: Vec<u8> = lhs_r.iter().map(|&v| lhs_p.quantize(v) as u8).collect();
        let rhs_q: Vec<u8> = rhs_r.iter().map(|&v| rhs_p.quantize(v) as u8).collect();

        let g = QGemm::new(m, k, n, lhs_p.zero_point, rhs_p.zero_point);
        let stage = OutputStage {
            bias: vec![],
            multiplier: output::Requant::PerTensor(gemm_multiplier(
                lhs_p.scale,
                rhs_p.scale,
                out_p.scale,
            )),
            out_zero: out_p.zero_point,
            clamp_min: 0,
            clamp_max: 255,
        };
        let mut out = vec![0u8; m * n];
        g.run(Kernel::Int8Pairwise, &lhs_q, &rhs_q, &stage, &mut out);

        // Real matmul of the *dequantized* inputs: the integer pipeline must
        // reproduce it to within half an output LSB (plus fixed-point
        // rounding slack).
        for i in 0..m {
            for col in 0..n {
                let mut r = 0f64;
                for j in 0..k {
                    r += f64::from(lhs_p.dequantize(i32::from(lhs_q[i * k + j])))
                        * f64::from(rhs_p.dequantize(i32::from(rhs_q[j * n + col])));
                }
                let got = f64::from(out_p.dequantize(i32::from(out[i * n + col])));
                assert!(
                    (got - r).abs() <= out_p.scale * 0.51 + 1e-6,
                    "({i},{col}): got {got}, real {r}, scale {}",
                    out_p.scale
                );
            }
        }
    }

    #[test]
    fn f32_gemm_matches_naive() {
        let (m, k, n) = (7, 13, 9);
        let lhs: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let rhs: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.53).cos()).collect();
        let mut out = vec![0f32; m * n];
        gemm_f32(m, k, n, &lhs, &rhs, &mut out);
        for i in 0..m {
            for col in 0..n {
                let want: f32 = (0..k).map(|j| lhs[i * k + j] * rhs[j * n + col]).sum();
                assert!((out[i * n + col] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn row_and_col_sums() {
        let g = QGemm::new(2, 3, 2, 0, 0);
        let lhs = vec![1u8, 2, 3, 4, 5, 6]; // rows [1,2,3],[4,5,6]
        let rhs = vec![1u8, 10, 2, 20, 3, 30]; // rows [1,10],[2,20],[3,30]
        assert_eq!(g.lhs_row_sums(&lhs), vec![6, 15]);
        assert_eq!(g.rhs_col_sums(&rhs), vec![6, 60]);
    }

    #[test]
    fn empty_dims_are_ok() {
        let g = QGemm::new(0, 4, 0, 10, 10);
        let mut acc: Vec<i32> = vec![];
        g.accumulate(Kernel::Blocked, &[], &[], &mut acc);
    }
}
