//! Cache-blocked, panel-packed uint8 GEMM kernel.
//!
//! Computes the eq. 9 core `Σ_j q1(i,j)·q2(j,k)` as raw uint8 products with
//! int32 accumulation, then applies the `O(M·N)` zero-point corrections of
//! eq. 7 — exactly the structure gemmlowp uses so that "anything but the
//! smallest values of N" pay no zero-point overhead (§2.3).
//!
//! Blocking: the K dimension is tiled so a packed LHS panel (`MR×KC`) and a
//! packed RHS panel (`KC×NR` column-major-ish) stay in L1/L2; registers hold
//! an `MR×NR` accumulator tile. Sizes are tuned for the single x86-64 core
//! this testbed provides (see EXPERIMENTS.md §Perf for the measurements that
//! picked them).
//!
//! The MR×NR inner tile itself is provided by a [`super::dispatch`]
//! descriptor — scalar always, SSE2/AVX2/AVX-512 where the CPU supports
//! them, selected once per process (override with `IAOI_KERNEL`). All
//! descriptors are bit-identical by construction and by test.

use std::cell::RefCell;

use super::dispatch::{self, KernelDispatch};
use super::QGemm;

/// Rows of LHS per register tile. Shared with the prepared-plan path
/// ([`super::prepared`]) and the SIMD tiles ([`super::dispatch`]) so packed
/// LHS panels line up with the kernels' register tiling.
pub const MR: usize = 8;
/// Columns of RHS per register tile (16 i32 lanes = one AVX-512 register).
pub const NR: usize = 16;
/// K-dimension cache block.
pub const KC: usize = 256;

thread_local! {
    /// Reusable packed-RHS scratch for the unprepared path: grows to the
    /// high-water mark once per thread, then every `accumulate_blocked`
    /// call packs into it allocation-free (the prepared path has its own
    /// per-worker [`super::Scratch`]).
    static PACKED_RHS: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// Blocked accumulation of eq. 7 into `acc` (row-major `M×N`), using the
/// process-wide [`dispatch::active`] micro-kernel.
pub fn accumulate_blocked(g: &QGemm, lhs: &[u8], rhs: &[u8], acc: &mut [i32]) {
    accumulate_blocked_with(dispatch::active(), g, lhs, rhs, acc)
}

/// [`accumulate_blocked`] with an explicit micro-kernel — the hook the
/// cross-kernel property tests and the `bench --table kernels` sweep use to
/// pit every available implementation against the scalar golden output.
pub fn accumulate_blocked_with(
    d: &KernelDispatch,
    g: &QGemm,
    lhs: &[u8],
    rhs: &[u8],
    acc: &mut [i32],
) {
    let (m, k, n) = (g.m, g.k, g.n);
    if m == 0 || n == 0 {
        return;
    }
    acc.fill(0);

    // Raw Σ q1·q2 with blocking over K. The packed panel is sized for the
    // largest K block; panel_len is monotonic in kc, so later (smaller)
    // blocks always fit.
    let blocks = n.div_ceil(NR);
    PACKED_RHS.with(|cell| {
        let mut buf = cell.borrow_mut();
        let packed = super::prepared::grow(&mut *buf, blocks * (d.panel_len)(KC.min(k)));
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            let blen = (d.panel_len)(kc);
            (d.pack_rhs)(rhs, k0, kc, n, 0, n, &mut packed[..blocks * blen]);
            for i0 in (0..m).step_by(MR) {
                let mr = MR.min(m - i0);
                for (b, panel) in packed[..blocks * blen].chunks_exact(blen).enumerate() {
                    let n0 = b * NR;
                    let nr = NR.min(n - n0);
                    let mut tile = [[0i32; NR]; MR];
                    // Row-major LHS view: element (r, j) of the mr×kc
                    // operand is lhs[(i0 + r)·k + k0 + j].
                    (d.tile)(lhs, i0 * k + k0, k, 1, mr, kc, panel, &mut tile);
                    for r in 0..mr {
                        let out = &mut acc[(i0 + r) * n + n0..(i0 + r) * n + n0 + nr];
                        for (o, &t) in out.iter_mut().zip(&tile[r][..nr]) {
                            *o += t;
                        }
                    }
                }
            }
        }
    });

    // O(M·N) zero-point corrections (eq. 7).
    let rs = g.lhs_row_sums(lhs);
    let cs = g.rhs_col_sums(rhs);
    g.apply_zero_point_corrections(acc, &rs, &cs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Kernel;

    fn pseudo(seed: u64, n: usize) -> Vec<u8> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 56) as u8
            })
            .collect()
    }

    /// Shapes hitting every tail case: m % MR, n % NR, k % KC.
    const SHAPES: [(usize, usize, usize); 6] = [
        (1, 1, 1),
        (MR, KC, NR),
        (MR + 1, KC + 1, NR + 1),
        (MR - 1, 3, NR - 1),
        (9, 300, 19),
        (2, 513, 2),
    ];

    #[test]
    fn blocked_equals_reference_over_awkward_shapes() {
        for (m, k, n) in SHAPES {
            let g = QGemm::new(m, k, n, 77, 201);
            let lhs = pseudo(m as u64 * 31 + k as u64, m * k);
            let rhs = pseudo(n as u64 * 17 + k as u64, k * n);
            let mut want = vec![0i32; m * n];
            let mut got = vec![0i32; m * n];
            g.accumulate(Kernel::Reference, &lhs, &rhs, &mut want);
            accumulate_blocked(&g, &lhs, &rhs, &mut got);
            assert_eq!(want, got, "mismatch at ({m},{k},{n})");
        }
    }

    #[test]
    fn every_dispatch_impl_matches_reference() {
        // The full unprepared path under every compiled-and-detected
        // micro-kernel; the exhaustive tail sweep lives in
        // rust/tests/kernels.rs.
        for d in dispatch::available() {
            for (m, k, n) in SHAPES {
                let g = QGemm::new(m, k, n, 77, 201);
                let lhs = pseudo(m as u64 * 31 + k as u64, m * k);
                let rhs = pseudo(n as u64 * 17 + k as u64, k * n);
                let mut want = vec![0i32; m * n];
                let mut got = vec![0i32; m * n];
                g.accumulate(Kernel::Reference, &lhs, &rhs, &mut want);
                accumulate_blocked_with(d, &g, &lhs, &rhs, &mut got);
                assert_eq!(want, got, "{} mismatch at ({m},{k},{n})", d.name);
            }
        }
    }

    #[test]
    fn accumulators_never_overflow_for_max_k() {
        // 255*255*K fits i32 for K up to ~33000; our largest layer K is
        // far below. Sanity-check the extreme at K = 8192 on every path.
        let (m, k, n) = (1, 8192, 1);
        let g = QGemm::new(m, k, n, 0, 0);
        let lhs = vec![255u8; k];
        let rhs = vec![255u8; k];
        for d in dispatch::available() {
            let mut acc = vec![0i32; 1];
            accumulate_blocked_with(d, &g, &lhs, &rhs, &mut acc);
            assert_eq!(acc[0], 255 * 255 * k as i32, "{}", d.name);
        }
    }
}
