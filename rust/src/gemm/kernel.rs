//! Cache-blocked, panel-packed uint8 GEMM kernel.
//!
//! Computes the eq. 9 core `Σ_j q1(i,j)·q2(j,k)` as raw uint8 products with
//! int32 accumulation, then applies the `O(M·N)` zero-point corrections of
//! eq. 7 — exactly the structure gemmlowp uses so that "anything but the
//! smallest values of N" pay no zero-point overhead (§2.3).
//!
//! Blocking: the K dimension is tiled so a packed LHS panel (`MR×KC`) and a
//! packed RHS panel (`KC×NR` column-major-ish) stay in L1/L2; registers hold
//! an `MR×NR` accumulator tile. Sizes are tuned for the single x86-64 core
//! this testbed provides (see EXPERIMENTS.md §Perf for the measurements that
//! picked them).

use super::QGemm;

/// Rows of LHS per register tile. Shared with the prepared-plan path
/// ([`super::prepared`]) so packed-LHS panels line up with this kernel's
/// register tiling.
pub(crate) const MR: usize = 8;
/// Columns of RHS per register tile (16 i32 lanes = one AVX-512 register).
pub(crate) const NR: usize = 16;
/// K-dimension cache block.
pub(crate) const KC: usize = 256;

/// Blocked accumulation of eq. 7 into `acc` (row-major `M×N`).
pub fn accumulate_blocked(g: &QGemm, lhs: &[u8], rhs: &[u8], acc: &mut [i32]) {
    let (m, k, n) = (g.m, g.k, g.n);
    if m == 0 || n == 0 {
        return;
    }
    acc.fill(0);

    // Raw Σ q1·q2 with blocking over K.
    let mut packed_rhs = vec![0u8; KC * n.div_ceil(NR) * NR];
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        // Pack the RHS panel so the micro-kernel reads it sequentially:
        // layout [n0/NR][j][nr] — NR consecutive columns interleaved by j.
        pack_rhs_panel(rhs, k0, kc, n, &mut packed_rhs);
        for i0 in (0..m).step_by(MR) {
            let mr = MR.min(m - i0);
            for n0 in (0..n).step_by(NR) {
                let nr = NR.min(n - n0);
                micro_kernel(
                    lhs, acc, i0, mr, k0, kc, k, n0, nr, n, &packed_rhs,
                );
            }
        }
    }

    // O(M·N) zero-point corrections (eq. 7).
    let rs = g.lhs_row_sums(lhs);
    let cs = g.rhs_col_sums(rhs);
    g.apply_zero_point_corrections(acc, &rs, &cs);
}

/// Pack `kc` rows of the RHS starting at row `k0` into `[ceil(n/NR)][kc][NR]`
/// order (zero-padded in the tail column block).
fn pack_rhs_panel(rhs: &[u8], k0: usize, kc: usize, n: usize, packed: &mut [u8]) {
    let blocks = n.div_ceil(NR);
    for b in 0..blocks {
        let n0 = b * NR;
        let nr = NR.min(n - n0);
        let dst_base = b * kc * NR;
        for j in 0..kc {
            let src = &rhs[(k0 + j) * n + n0..(k0 + j) * n + n0 + nr];
            let dst = &mut packed[dst_base + j * NR..dst_base + j * NR + NR];
            dst[..nr].copy_from_slice(src);
            dst[nr..].fill(0);
        }
    }
}

/// MR×NR register-tile micro-kernel over one K block, reading the packed RHS.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    lhs: &[u8],
    acc: &mut [i32],
    i0: usize,
    mr: usize,
    k0: usize,
    kc: usize,
    k: usize,
    n0: usize,
    nr: usize,
    n: usize,
    packed_rhs: &[u8],
) {
    let block = n0 / NR;
    let panel = &packed_rhs[block * kc * NR..(block + 1) * kc * NR];
    // Local accumulator tile; NR-wide rows vectorize.
    let mut tile = [[0i32; NR]; MR];
    for (j, rhs_row) in panel.chunks_exact(NR).enumerate() {
        for r in 0..mr {
            let a = i32::from(lhs[(i0 + r) * k + k0 + j]);
            let t = &mut tile[r];
            for c in 0..NR {
                t[c] += a * i32::from(rhs_row[c]);
            }
        }
    }
    for r in 0..mr {
        let out = &mut acc[(i0 + r) * n + n0..(i0 + r) * n + n0 + nr];
        for c in 0..nr {
            out[c] += tile[r][c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Kernel;

    fn pseudo(seed: u64, n: usize) -> Vec<u8> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn blocked_equals_reference_over_awkward_shapes() {
        // Shapes chosen to hit every tail case: m % MR, n % NR, k % KC.
        for (m, k, n) in [
            (1, 1, 1),
            (MR, KC, NR),
            (MR + 1, KC + 1, NR + 1),
            (MR - 1, 3, NR - 1),
            (9, 300, 19),
            (2, 513, 2),
        ] {
            let g = QGemm::new(m, k, n, 77, 201);
            let lhs = pseudo(m as u64 * 31 + k as u64, m * k);
            let rhs = pseudo(n as u64 * 17 + k as u64, k * n);
            let mut want = vec![0i32; m * n];
            let mut got = vec![0i32; m * n];
            g.accumulate(Kernel::Reference, &lhs, &rhs, &mut want);
            accumulate_blocked(&g, &lhs, &rhs, &mut got);
            assert_eq!(want, got, "mismatch at ({m},{k},{n})");
        }
    }

    #[test]
    fn packing_is_lossless() {
        let n = 19; // not a multiple of NR
        let k = 7;
        let rhs = pseudo(3, k * n);
        let mut packed = vec![0u8; k * n.div_ceil(NR) * NR];
        pack_rhs_panel(&rhs, 0, k, n, &mut packed);
        for j in 0..k {
            for c in 0..n {
                let block = c / NR;
                let within = c % NR;
                assert_eq!(packed[block * k * NR + j * NR + within], rhs[j * n + c]);
            }
        }
    }

    #[test]
    fn accumulators_never_overflow_for_max_k() {
        // 255*255*K fits i32 for K up to ~33000; our largest layer K is
        // far below. Sanity-check the extreme at K = 8192.
        let (m, k, n) = (1, 8192, 1);
        let g = QGemm::new(m, k, n, 0, 0);
        let lhs = vec![255u8; k];
        let rhs = vec![255u8; k];
        let mut acc = vec![0i32; 1];
        accumulate_blocked(&g, &lhs, &rhs, &mut acc);
        assert_eq!(acc[0], 255 * 255 * k as i32);
    }
}
