//! Persistent intra-op worker pool for the parallel quantized GEMM.
//!
//! The paper's multi-threaded numbers (§4.2.3, Table 4.6: 1.5–2.2× on 2–4
//! cores) presuppose a runtime whose per-GEMM threading cost is *packing*,
//! not thread creation. The scoped-spawn path
//! ([`super::parallel::run_strips_scoped`]) pays a full OS-thread
//! spawn + join per worker per GEMM call — fine for a one-shot benchmark,
//! hopeless for serving where every conv layer of every batch would re-pay
//! it. A [`WorkerPool`] amortizes that cost: threads are spawned once, jobs
//! arrive over a channel, and a completion latch gives the caller the same
//! blocking semantics as a scoped join. Each worker owns a persistent
//! [`Scratch`], so its packing buffers warm up once and are reused across
//! every GEMM the pool ever runs (the pool-side analogue of the prepared
//! path's zero-alloc steady state; the dispatch itself still makes a few
//! small per-call allocations — job boxes and the per-row segment lists,
//! `O(threads + M)` — which are noise next to an `O(M·N·K)` GEMM).
//!
//! Work is split exactly like the scoped path: disjoint column strips of
//! the output, each worker packing its RHS strip straight from the shared
//! strided source and writing through disjoint `&mut` row segments. Every
//! strip computes bit-identical integers regardless of who computes it, so
//! pool execution is **bit-identical** to serial and to scoped-spawn
//! execution for any thread count (property-tested in `rust/tests/pool.rs`).
//!
//! The pool is `Sync`: serving coordinators construct **one** pool
//! (`BatchPolicy::intra_threads`, CLI `iaoi serve --intra-threads N`) and
//! share it across all batch workers and hot-swapped models; concurrent
//! `run_strips` calls simply interleave their jobs on the queue.
//!
//! [`IntraOp`] is the per-worker knob that rides in
//! [`crate::nn::LayerScratch`]: a strategy (serial / scoped-spawn baseline /
//! pool) plus the per-layer `min_n` threshold under which a layer's GEMM
//! stays serial — small layers lose more to coordination than they gain
//! from splitting, and `N = batch·OH·OW` shrinks fast down a CNN.

use super::output::ResidualAdd;
use super::parallel::run_strips_scoped_res;
use super::prepared::{PreparedGemm, Scratch};
use crate::sync::lock_recover;
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Default per-layer threshold on `N = batch·OH·OW` below which a GEMM is
/// not worth splitting (8 NR-wide column blocks: at least a few blocks per
/// worker once split).
pub const DEFAULT_MIN_N: usize = 8 * super::kernel::NR;

/// Completion latch: the dispatcher blocks until every submitted strip has
/// run, which is what makes the borrow-erasure in [`WorkerPool::submit`]
/// sound. Worker panics are counted (not swallowed) and re-raised on the
/// dispatching thread, mirroring the scoped path's `join().expect(..)`.
struct Latch {
    /// (jobs still running, jobs that panicked)
    state: Mutex<(usize, usize)>,
    cv: Condvar,
}

impl Latch {
    /// Starts at zero jobs; [`Self::add_job`] counts each successful
    /// enqueue, so the latch only ever waits for work that actually
    /// reached the queue (a dispatch that dies mid-loop must not deadlock
    /// on jobs it never sent).
    fn new() -> Self {
        Self { state: Mutex::new((0, 0)), cv: Condvar::new() }
    }

    fn add_job(&self) {
        lock_recover(&self.state).0 += 1;
    }

    fn complete(&self, panicked: bool) {
        let mut s = lock_recover(&self.state);
        s.0 -= 1;
        s.1 += usize::from(panicked);
        if s.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every counted job completed; returns how many panicked.
    /// Only meaningful once the dispatching thread has stopped adding jobs
    /// (which is the only call pattern in [`WorkerPool::run_strips`]).
    fn wait(&self) -> usize {
        // The guarded pair is a pair of counters, valid at every store, so
        // recovering a poisoned guard is sound (see `crate::sync`).
        let mut s = lock_recover(&self.state);
        while s.0 > 0 {
            s = self.cv.wait(s).unwrap_or_else(|p| p.into_inner());
        }
        s.1
    }
}

/// One unit of work: a strip closure plus the latch it must tick. The
/// closure's borrows have been erased to `'static` by [`WorkerPool::submit`];
/// the latch protocol guarantees they are still live when it runs.
struct Job {
    work: Box<dyn FnOnce(&mut Scratch) + Send + 'static>,
    latch: Arc<Latch>,
}

/// A persistent pool of GEMM worker threads (long-lived threads, job
/// channel, completion latch). `new(n)` provisions an intra-op parallelism
/// degree of `n` *counting the calling thread*: `n - 1` workers are
/// spawned, and [`Self::run_strips`] computes one strip on the caller while
/// the workers take the rest — so `new(1)` spawns nothing and runs serially.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.threads).finish()
    }
}

impl WorkerPool {
    /// Spawn a pool with intra-op degree `threads` (≥ 1). Threads live until
    /// the pool is dropped.
    pub fn new(threads: usize) -> Self {
        assert!(threads >= 1, "a pool needs at least the calling thread");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (1..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || {
                    // One Scratch per worker for its whole lifetime: packing
                    // buffers grow to their high-water mark and stay warm
                    // across every GEMM this pool ever executes.
                    let mut scratch = Scratch::new();
                    loop {
                        let job = {
                            let guard = lock_recover(&rx);
                            guard.recv()
                        };
                        let Ok(Job { work, latch }) = job else { return };
                        let result = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| work(&mut scratch)),
                        );
                        // Tick the latch even on panic so the dispatcher
                        // never deadlocks; it re-raises after wait().
                        latch.complete(result.is_err());
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers, threads }
    }

    /// The pool's intra-op parallelism degree (including the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enqueue one borrowed job under `latch`.
    ///
    /// SAFETY contract (enforced by the single caller, `run_strips`): the
    /// dispatcher blocks on `latch.wait()` before any borrow captured by
    /// `work` goes out of scope, so erasing the lifetime cannot let a
    /// worker touch freed data.
    fn submit<'env>(&self, work: Box<dyn FnOnce(&mut Scratch) + Send + 'env>, latch: &Arc<Latch>) {
        let work: Box<dyn FnOnce(&mut Scratch) + Send + 'static> =
            unsafe { std::mem::transmute(work) };
        let tx = self.tx.as_ref().expect("pool is shut down");
        // Count the job before sending: once `send` succeeds a worker may
        // already be running it, and the count must never trail the queue.
        latch.add_job();
        if tx.send(Job { work, latch: Arc::clone(latch) }).is_err() {
            // Never queued — un-count it before propagating, so the
            // wait-guard protecting earlier jobs cannot deadlock.
            latch.complete(false);
            panic!("pool workers exited");
        }
    }

    /// Multi-threaded execution of a prepared plan over a row-major `K×N`
    /// RHS — the persistent-pool counterpart of
    /// [`super::parallel::run_strips_scoped`], bit-identical to it and to
    /// [`PreparedGemm::run`]. The output is carved into disjoint column
    /// strips; workers take strips 1.., the caller computes strip 0 with
    /// its own `scratch` (so a 1-thread pool or a narrow `n` degenerates to
    /// exactly the serial path).
    pub fn run_strips(
        &self,
        plan: &PreparedGemm,
        rhs: &[u8],
        n: usize,
        out: &mut [u8],
        scratch: &mut Scratch,
    ) {
        self.run_strips_res(plan, rhs, n, out, None, scratch);
    }

    /// [`Self::run_strips`] with the composable residual-add epilogue: every
    /// worker applies the fused [`ResidualAdd`] to its own column strip
    /// (global columns index the shared NHWC residual source), so the fused
    /// path stays bit-identical across thread counts by the same
    /// strip-disjointness argument as the plain path.
    pub fn run_strips_res(
        &self,
        plan: &PreparedGemm,
        rhs: &[u8],
        n: usize,
        out: &mut [u8],
        res: Option<(&ResidualAdd, &[u8])>,
        scratch: &mut Scratch,
    ) {
        let m = plan.m();
        assert_eq!(rhs.len(), plan.k() * n, "rhs must be K*N");
        assert_eq!(out.len(), m * n, "out must be M*N");
        if self.threads == 1 || n < 2 * self.threads {
            plan.run_res(n, rhs, out, res, scratch);
            return;
        }
        let strips = carve_strips(n, self.threads);
        let mut per_worker = carve_row_segments(out, m, n, &strips);
        let latch = Arc::new(Latch::new());
        {
            // The guard waits for every *queued* job even if dispatch or
            // the caller's own strip panics below: workers must never
            // outlive the borrows their jobs captured (see `submit`), and
            // the latch counts per successful enqueue so an aborted
            // dispatch cannot deadlock on jobs it never sent.
            let _all_jobs_done = WaitGuard(latch.as_ref());
            // Dispatch strips 1.. to the workers first so they compute
            // while the caller handles strip 0.
            let mut segs0 = None;
            for (&(n0, _), mut segs) in strips.iter().zip(per_worker.drain(..)) {
                if segs0.is_none() {
                    segs0 = Some(segs);
                    continue;
                }
                self.submit(
                    Box::new(move |scratch: &mut Scratch| {
                        plan.run_strip_res(rhs, n, n0, &mut segs, res, scratch);
                    }),
                    &latch,
                );
            }
            let mut segs0 = segs0.expect("at least one strip");
            plan.run_strip_res(rhs, n, strips[0].0, &mut segs0, res, scratch);
        }
        // The latch is already released; this re-read is immediate.
        let panicked = latch.wait();
        assert_eq!(panicked, 0, "gemm pool worker panicked");
    }
}

/// Blocks on the latch when dropped — the unwind-safety net for
/// [`WorkerPool::run_strips`].
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel ends every worker's recv loop.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `[0, n)` into up to `threads` contiguous non-empty strips — the
/// one partition every parallel path (scoped and pooled) uses, so the two
/// are trivially bit-identical per strip.
pub(crate) fn carve_strips(n: usize, threads: usize) -> Vec<(usize, usize)> {
    let strip = n.div_ceil(threads);
    (0..threads)
        .map(|t| (t * strip, ((t + 1) * strip).min(n)))
        .filter(|(a, b)| a < b)
        .collect()
}

/// Carve a row-major `M×N` output into disjoint `&mut` row segments, one
/// `Vec` (of `M` segments) per strip: strip `w` gets each row's sub-slice
/// `[n0_w, n1_w)`. No worker ever copies its result through a gather
/// buffer.
pub(crate) fn carve_row_segments<'o>(
    out: &'o mut [u8],
    m: usize,
    n: usize,
    strips: &[(usize, usize)],
) -> Vec<Vec<&'o mut [u8]>> {
    let mut per_worker: Vec<Vec<&'o mut [u8]>> =
        strips.iter().map(|_| Vec::with_capacity(m)).collect();
    let mut rest = out;
    for _ in 0..m {
        let (row, tail) = rest.split_at_mut(n);
        rest = tail;
        let mut row_rest = row;
        for (w, &(n0, n1)) in strips.iter().enumerate() {
            let (seg, t) = row_rest.split_at_mut(n1 - n0);
            row_rest = t;
            per_worker[w].push(seg);
        }
    }
    per_worker
}

/// How a prepared layer parallelizes its GEMM across the N (column)
/// dimension.
#[derive(Clone, Debug, Default)]
pub enum IntraStrategy {
    /// Single-threaded (the zero-alloc serving default).
    #[default]
    Serial,
    /// Spawn scoped OS threads per GEMM call — the pre-pool baseline, kept
    /// for apples-to-apples benchmarking of what the pool amortizes.
    Scoped(usize),
    /// Submit strips to a shared persistent [`WorkerPool`].
    Pool(Arc<WorkerPool>),
}

/// Per-worker intra-op parallelism configuration, carried by
/// [`crate::nn::LayerScratch`] so every prepared conv/FC layer can consult
/// it without threading an extra parameter through the layer APIs. All
/// strategies are bit-identical; they only change *who* computes each
/// output strip.
#[derive(Clone, Debug)]
pub struct IntraOp {
    pub strategy: IntraStrategy,
    /// Per-layer threshold: a layer whose GEMM has `N < min_n` runs serial
    /// even when a pool is attached.
    pub min_n: usize,
}

impl Default for IntraOp {
    fn default() -> Self {
        Self { strategy: IntraStrategy::Serial, min_n: DEFAULT_MIN_N }
    }
}

impl IntraOp {
    /// Serial execution (the default).
    pub fn serial() -> Self {
        Self::default()
    }

    /// Route qualifying layers through a shared persistent pool.
    pub fn pool(pool: Arc<WorkerPool>, min_n: usize) -> Self {
        Self { strategy: IntraStrategy::Pool(pool), min_n }
    }

    /// Scoped-spawn baseline at the given degree (benchmarking only).
    pub fn scoped(threads: usize, min_n: usize) -> Self {
        Self { strategy: IntraStrategy::Scoped(threads), min_n }
    }

    /// Execute a prepared GEMM under this strategy: split across threads
    /// when `n` clears the per-layer threshold, serial otherwise.
    /// Bit-identical to [`PreparedGemm::run`] in every mode.
    pub fn run(
        &self,
        plan: &PreparedGemm,
        rhs: &[u8],
        n: usize,
        out: &mut [u8],
        scratch: &mut Scratch,
    ) {
        self.run_res(plan, rhs, n, out, None, scratch);
    }

    /// [`Self::run`] with the composable residual-add epilogue threaded
    /// through every strategy (serial, scoped-spawn, pool) — the fused
    /// conv→add path parallelizes exactly like the plain one.
    pub fn run_res(
        &self,
        plan: &PreparedGemm,
        rhs: &[u8],
        n: usize,
        out: &mut [u8],
        res: Option<(&ResidualAdd, &[u8])>,
        scratch: &mut Scratch,
    ) {
        match &self.strategy {
            IntraStrategy::Pool(pool) if n >= self.min_n && pool.threads() > 1 => {
                pool.run_strips_res(plan, rhs, n, out, res, scratch);
            }
            IntraStrategy::Scoped(threads) if n >= self.min_n && *threads > 1 => {
                run_strips_scoped_res(plan, rhs, n, out, res, *threads);
            }
            _ => plan.run_res(n, rhs, out, res, scratch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::output::{OutputStage, Requant};
    use crate::gemm::{Kernel, QGemm};
    use crate::quant::QuantizedMultiplier;

    fn pseudo(seed: u64, n: usize) -> Vec<u8> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(17);
                (s >> 56) as u8
            })
            .collect()
    }

    fn plan_and_reference(
        m: usize,
        k: usize,
        n: usize,
        kern: Kernel,
    ) -> (PreparedGemm, Vec<u8>, Vec<u8>) {
        let g = QGemm::new(m, k, n, 120, 99);
        let lhs: Vec<u8> = pseudo(3, m * k).iter().map(|&v| v.max(1)).collect();
        let rhs = pseudo(4, k * n);
        let stage = OutputStage {
            bias: (0..m as i32).map(|i| i * 31 - 90).collect(),
            multiplier: Requant::PerChannel(
                (0..m)
                    .map(|i| QuantizedMultiplier::from_f64(0.002 * 1.4f64.powi(i as i32 % 4)))
                    .collect(),
            ),
            out_zero: 11,
            clamp_min: 0,
            clamp_max: 255,
        };
        let plan = PreparedGemm::from_qgemm(&g, kern, &lhs, stage);
        let mut want = vec![0u8; m * n];
        plan.run(n, &rhs, &mut want, &mut Scratch::new());
        (plan, rhs, want)
    }

    #[test]
    fn pool_matches_serial_for_all_thread_counts() {
        let (m, k, n) = (7, 80, 53);
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let (plan, rhs, want) = plan_and_reference(m, k, n, kern);
            for threads in [1usize, 2, 3, 8] {
                let pool = WorkerPool::new(threads);
                let mut scratch = Scratch::new();
                let mut got = vec![0u8; m * n];
                pool.run_strips(&plan, &rhs, n, &mut got, &mut scratch);
                assert_eq!(want, got, "{kern:?} threads={threads}");
                // Warm re-run through the same pool and caller scratch.
                let mut again = vec![0u8; m * n];
                pool.run_strips(&plan, &rhs, n, &mut again, &mut scratch);
                assert_eq!(want, again, "{kern:?} threads={threads} warm");
            }
        }
    }

    #[test]
    fn one_pool_serves_many_widths_and_plans() {
        let pool = WorkerPool::new(3);
        let mut scratch = Scratch::new();
        for &(m, k, n) in &[(4usize, 33usize, 40usize), (9, 65, 7), (1, 8, 128), (6, 100, 17)] {
            let (plan, rhs, want) = plan_and_reference(m, k, n, Kernel::Int8Pairwise);
            let mut got = vec![0u8; m * n];
            pool.run_strips(&plan, &rhs, n, &mut got, &mut scratch);
            assert_eq!(want, got, "({m},{k},{n})");
        }
    }

    #[test]
    fn pool_is_shared_across_caller_threads() {
        // The serving shape: several batch workers drive one pool
        // concurrently; every caller must still see exact results.
        let (m, k, n) = (6, 64, 96);
        let (plan, rhs, want) = plan_and_reference(m, k, n, Kernel::Blocked);
        let pool = WorkerPool::new(4);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let (pool, plan, rhs, want) = (&pool, &plan, &rhs, &want);
                scope.spawn(move || {
                    let mut scratch = Scratch::new();
                    for _ in 0..8 {
                        let mut got = vec![0u8; m * n];
                        pool.run_strips(plan, rhs, n, &mut got, &mut scratch);
                        assert_eq!(want, &got);
                    }
                });
            }
        });
    }

    #[test]
    fn intra_op_threshold_and_strategies_agree() {
        let (m, k, n) = (5, 48, 64);
        let (plan, rhs, want) = plan_and_reference(m, k, n, Kernel::Int8Pairwise);
        let pool = Arc::new(WorkerPool::new(2));
        for intra in [
            IntraOp::serial(),
            IntraOp::scoped(2, 1),
            IntraOp::scoped(2, n + 1), // below threshold → serial
            IntraOp::pool(Arc::clone(&pool), 1),
            IntraOp::pool(Arc::clone(&pool), n + 1),
        ] {
            let mut got = vec![0u8; m * n];
            intra.run(&plan, &rhs, n, &mut got, &mut Scratch::new());
            assert_eq!(want, got, "{:?}", intra.strategy);
        }
    }

    #[test]
    fn carve_strips_covers_exactly_once() {
        for (n, threads) in [(10usize, 4usize), (9, 4), (16, 2), (7, 7), (100, 3), (8, 8)] {
            let strips = carve_strips(n, threads);
            assert!(strips.len() <= threads);
            assert_eq!(strips[0].0, 0);
            assert_eq!(strips.last().unwrap().1, n);
            for w in strips.windows(2) {
                assert_eq!(w[0].1, w[1].0, "strips must tile [0, n)");
            }
            assert!(strips.iter().all(|(a, b)| a < b));
        }
    }
}
