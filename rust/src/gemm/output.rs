//! The fused output pipeline of §2.4 — gemmlowp's `GemmWithOutputPipeline`.
//!
//! With the final int32 accumulator in hand, "there remain three things left
//! to do: scale down to the final scale used by the 8-bit output activations,
//! cast down to uint8 and apply the activation function":
//!
//! 1. **int32 bias addition** — the bias vector is quantized with
//!    `S_bias = S1·S2` (the accumulator's scale) and `Z_bias = 0` (eq. 11),
//!    so it adds directly onto the accumulator.
//! 2. **Down-scale** — fixed-point multiplication by the normalized
//!    multiplier `M0` plus a correctly-rounding right shift (eq. 6).
//!    With per-channel weight scales ([`Requant::PerChannel`]) the
//!    multiplier varies per output row; the apply loops hoist the row's
//!    multiplier out of the column loop, so the vectorizable inner loop is
//!    identical in both modes.
//! 3. **Saturating cast + clamp** — saturate to `[0, 255]`, then clamp to
//!    the activation's sub-interval. The paper notes trained models learn to
//!    use the whole interval so the clamp usually degenerates into the
//!    saturating cast itself.

use crate::fixedpoint::rounding_div_by_pot;
use crate::quant::{QuantParams, QuantizedMultiplier, WeightQuant};

/// Internal headroom for the residual-add rescale (App. A.2): operands are
/// promoted to a common `2^-SHIFT`-grained fixed-point scale before
/// summation. 16 bits keeps `(q−Z) · 2^16 · M` within i32 for `M ≤ 64`.
///
/// Shared by the standalone [`crate::nn::elementwise::qadd_into`] pass and
/// the fused [`ResidualAdd`] epilogue — one constant, one arithmetic, so
/// fused and unfused execution are bit-identical by construction.
pub const ADD_LEFT_SHIFT: i32 = 16;

/// The requantization multiplier(s) of one GEMM output: one `M = S1·S2/S3`
/// for the whole layer (eq. 5, the paper's scheme) or one per output row
/// (per-channel weight scales, Krishnamoorthi 1806.08342).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Requant {
    /// One normalized multiplier for every row.
    PerTensor(QuantizedMultiplier),
    /// `multipliers[row]` for row = output channel; length must equal the
    /// GEMM's `M`.
    PerChannel(Vec<QuantizedMultiplier>),
}

impl From<QuantizedMultiplier> for Requant {
    fn from(m: QuantizedMultiplier) -> Self {
        Requant::PerTensor(m)
    }
}

impl Requant {
    /// Build the stage multiplier(s) for a layer whose weights are
    /// quantized as `wq`, with per-tensor input/output activation scales
    /// (eq. 5 per row: `M_i = S_w(i)·S_in/S_out`). `rows` is the layer's
    /// output-channel count; per-channel scale vectors must match it.
    pub fn for_weights(wq: &WeightQuant, in_scale: f64, out_scale: f64, rows: usize) -> Self {
        match wq {
            WeightQuant::PerTensor(p) => Requant::PerTensor(QuantizedMultiplier::from_f64(
                p.scale * in_scale / out_scale,
            )),
            WeightQuant::PerChannel(c) => {
                assert_eq!(
                    c.channels(),
                    rows,
                    "per-channel scale count must equal output channels"
                );
                Requant::PerChannel(
                    c.scales
                        .iter()
                        .map(|&s| QuantizedMultiplier::from_f64(s * in_scale / out_scale))
                        .collect(),
                )
            }
        }
    }

    /// The multiplier applied to output row `row`.
    #[inline]
    pub fn for_row(&self, row: usize) -> QuantizedMultiplier {
        match self {
            Requant::PerTensor(m) => *m,
            Requant::PerChannel(v) => v[row],
        }
    }

    /// Whether the variant is consistent with an `m`-row output.
    pub fn rows_valid(&self, m: usize) -> bool {
        match self {
            Requant::PerTensor(_) => true,
            Requant::PerChannel(v) => v.len() == m,
        }
    }
}

/// The residual-add epilogue component (App. A.2 arithmetic): combines the
/// just-requantized GEMM output `qa` with one element `qb` of a second
/// quantized source, each rescaled by its own eq. 6-style fixed-point
/// multiplier onto the Add output's scale.
///
/// This is byte-for-byte the arithmetic of the standalone
/// [`crate::nn::elementwise::qadd_into`] pass — the fusion pass in
/// [`crate::graph::QGraph::prepare`] merely relocates it from a separate
/// memory-bound sweep over two written-out tensors into the GEMM's
/// cache-resident output stage. Bit-identity between fused and unfused
/// execution is therefore structural: both call [`ResidualAdd::apply`].
#[derive(Clone, Copy, Debug)]
pub struct ResidualAdd {
    /// `S_main/S_out · 2^16` for the GEMM-output operand.
    pub main_mult: QuantizedMultiplier,
    /// Zero point of the GEMM-output operand (the conv's own `Z3`).
    pub main_zero: i32,
    /// `S_res/S_out · 2^16` for the residual operand.
    pub res_mult: QuantizedMultiplier,
    /// Zero point of the residual operand.
    pub res_zero: i32,
    /// Zero point of the Add output.
    pub out_zero: i32,
}

impl ResidualAdd {
    /// Build the epilogue for `main + res → out` with the given activation
    /// quantization parameters (App. A.2: each operand's multiplier is
    /// `S_op/S_out`, promoted by `2^ADD_LEFT_SHIFT` for headroom).
    pub fn for_params(main: QuantParams, res: QuantParams, out: QuantParams) -> Self {
        let twopow = (1i64 << ADD_LEFT_SHIFT) as f64;
        Self {
            main_mult: QuantizedMultiplier::from_f64(main.scale / out.scale * twopow),
            main_zero: main.zero_point,
            res_mult: QuantizedMultiplier::from_f64(res.scale / out.scale * twopow),
            res_zero: res.zero_point,
            out_zero: out.zero_point,
        }
    }

    /// One element of the quantized add: rescale both operands onto the
    /// common `2^-16`-grained scale, saturating-add, round back down, and
    /// saturate to uint8. No further activation clamp: the converter absorbs
    /// a trailing ReLU into the Add's *output range*, so the saturating cast
    /// is the whole activation (§2.4).
    #[inline]
    pub fn apply(&self, qa: u8, qb: u8) -> u8 {
        let ra = self.main_mult.apply(i32::from(qa) - self.main_zero);
        let rb = self.res_mult.apply(i32::from(qb) - self.res_zero);
        let sum = ra.saturating_add(rb);
        let q = rounding_div_by_pot(sum, ADD_LEFT_SHIFT).saturating_add(self.out_zero);
        q.clamp(0, 255) as u8
    }
}

/// Fused bias + requantization + activation stage applied to the int32
/// accumulators of one GEMM (rows = output channels).
#[derive(Clone, Debug)]
pub struct OutputStage {
    /// Per-row (output-channel) int32 bias, already quantized per eq. 11.
    /// Empty means no bias.
    pub bias: Vec<i32>,
    /// The normalized requantization multiplier(s) `M = S1·S2/S3`
    /// (eq. 5–6), per-tensor or per-row.
    pub multiplier: Requant,
    /// Output zero-point `Z3`.
    pub out_zero: i32,
    /// Fused activation clamp lower bound (quantized units).
    pub clamp_min: u8,
    /// Fused activation clamp upper bound (quantized units).
    pub clamp_max: u8,
}

impl OutputStage {
    /// Identity-ish stage used in tests: no bias, multiplier M, full clamp.
    pub fn bare(multiplier: QuantizedMultiplier, out_zero: i32) -> Self {
        Self {
            bias: vec![],
            multiplier: Requant::PerTensor(multiplier),
            out_zero,
            clamp_min: 0,
            clamp_max: 255,
        }
    }

    /// Apply the pipeline to row-major `m×n` accumulators, writing uint8.
    pub fn apply(&self, acc: &[i32], m: usize, n: usize, out: &mut [u8]) {
        assert_eq!(acc.len(), m * n);
        assert_eq!(out.len(), m * n);
        assert!(self.bias.is_empty() || self.bias.len() == m, "bias is per output row");
        assert!(self.multiplier.rows_valid(m), "one multiplier per output row");
        assert!(self.clamp_min <= self.clamp_max);
        for i in 0..m {
            let mult = self.multiplier.for_row(i);
            let b = if self.bias.is_empty() { 0 } else { self.bias[i] };
            let src = &acc[i * n..(i + 1) * n];
            let dst = &mut out[i * n..(i + 1) * n];
            for (o, &a) in dst.iter_mut().zip(src) {
                *o = self.requantize_with(mult, a.wrapping_add(b));
            }
        }
    }

    /// Apply the composable epilogue pipeline — requantize (with this
    /// stage's own clamp) then an optional fused residual add — to
    /// row-major `m×n` accumulators covering columns `col0..col0+n` of the
    /// layer output. The GEMM output is channel-major (row = output
    /// channel, column = spatial position); the residual source is the
    /// written-out NHWC activation tensor, so the element pairing with row
    /// `i`, local column `j` is `res[(col0 + j) * m + i]`.
    pub fn apply_res(
        &self,
        acc: &[i32],
        m: usize,
        n: usize,
        out: &mut [u8],
        res: Option<(&ResidualAdd, &[u8])>,
        col0: usize,
    ) {
        let Some((r, data)) = res else {
            self.apply(acc, m, n, out);
            return;
        };
        assert_eq!(acc.len(), m * n);
        assert_eq!(out.len(), m * n);
        assert!(self.bias.is_empty() || self.bias.len() == m, "bias is per output row");
        assert!(self.multiplier.rows_valid(m), "one multiplier per output row");
        assert!(self.clamp_min <= self.clamp_max);
        assert!((col0 + n) * m <= data.len(), "residual source too small for this tile");
        for i in 0..m {
            let mult = self.multiplier.for_row(i);
            let b = if self.bias.is_empty() { 0 } else { self.bias[i] };
            let src = &acc[i * n..(i + 1) * n];
            let dst = &mut out[i * n..(i + 1) * n];
            for (j, (o, &a)) in dst.iter_mut().zip(src).enumerate() {
                let qa = self.requantize_with(mult, a.wrapping_add(b));
                *o = r.apply(qa, data[(col0 + j) * m + i]);
            }
        }
    }

    /// Requantize one biased accumulator with an already-resolved row
    /// multiplier (the hot inner-loop body, row lookup hoisted).
    #[inline]
    pub(crate) fn requantize_with(&self, mult: QuantizedMultiplier, acc: i32) -> u8 {
        let q = mult.apply(acc).saturating_add(self.out_zero);
        // Saturating cast to uint8, then the fused activation clamp.
        (q.clamp(0, 255) as u8).clamp(self.clamp_min, self.clamp_max)
    }

    /// Requantize a single biased accumulator value of output row `row`.
    #[inline]
    pub fn requantize_one(&self, row: usize, acc: i32) -> u8 {
        self.requantize_with(self.multiplier.for_row(row), acc)
    }

    /// Apply to an i32 slice producing i32 requantized values without the
    /// u8 cast — used by layers whose consumers need wider intermediate
    /// values (e.g. the softmax input recentering).
    pub fn requantize_i32(&self, acc: &[i32], m: usize, out: &mut [i32]) {
        assert_eq!(acc.len(), out.len());
        assert!(self.multiplier.rows_valid(m), "one multiplier per output row");
        let n = if m == 0 { 0 } else { acc.len() / m };
        for i in 0..m {
            let mult = self.multiplier.for_row(i);
            let b = if self.bias.is_empty() { 0 } else { self.bias[i] };
            for idx in i * n..(i + 1) * n {
                out[idx] = mult.apply(acc[idx].wrapping_add(b)).saturating_add(self.out_zero);
            }
        }
    }
}

/// Clamp bounds for the fused activation functions the engine supports
/// (§2.4 focuses on "mere clamps": ReLU, ReLU6, or none).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FusedActivation {
    /// No activation: clamp is exactly the saturating uint8 cast.
    #[default]
    None,
    /// max(0, x) in real space.
    Relu,
    /// min(6, max(0, x)) in real space.
    Relu6,
}

impl FusedActivation {
    /// Stable numeric code for binary model artifacts
    /// ([`crate::model_format`]). Codes are append-only across versions.
    pub fn code(self) -> u8 {
        match self {
            FusedActivation::None => 0,
            FusedActivation::Relu => 1,
            FusedActivation::Relu6 => 2,
        }
    }

    /// Inverse of [`Self::code`]; `None` for unknown codes.
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(FusedActivation::None),
            1 => Some(FusedActivation::Relu),
            2 => Some(FusedActivation::Relu6),
            _ => None,
        }
    }

    /// The quantized clamp interval implementing this activation under the
    /// output quantization `(scale, zero_point)`.
    pub fn clamp_bounds(self, scale: f64, zero_point: i32) -> (u8, u8) {
        match self {
            FusedActivation::None => (0, 255),
            FusedActivation::Relu => (zero_point.clamp(0, 255) as u8, 255),
            FusedActivation::Relu6 => {
                let hi = (f64::from(zero_point) + 6.0 / scale).round();
                (zero_point.clamp(0, 255) as u8, hi.clamp(0.0, 255.0) as u8)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{QuantParams, QuantizedMultiplier};

    #[test]
    fn pipeline_matches_real_arithmetic() {
        // acc of scale Sw*Si requantized to So must equal the real-number
        // computation within 1 LSB.
        let (sw, si, so) = (0.02, 0.05, 0.25);
        let mult = QuantizedMultiplier::from_f64(sw * si / so);
        let stage = OutputStage {
            bias: vec![100, -50],
            multiplier: Requant::PerTensor(mult),
            out_zero: 30,
            clamp_min: 0,
            clamp_max: 255,
        };
        let acc = vec![10_000, -2_000, 1_000_000, 0, 123_456, -123_456];
        let mut out = vec![0u8; 6];
        stage.apply(&acc, 2, 3, &mut out);
        for i in 0..2 {
            for c in 0..3 {
                let a = f64::from(acc[i * 3 + c] + stage.bias[i]);
                let want = (a * (sw * si / so)).round() + 30.0;
                let want = want.clamp(0.0, 255.0) as i64;
                let got = i64::from(out[i * 3 + c]);
                assert!((got - want).abs() <= 1, "i={i} c={c} got={got} want={want}");
            }
        }
    }

    #[test]
    fn per_channel_multipliers_are_row_indexed() {
        // Two rows with multipliers differing by 10x: identical accumulators
        // must requantize to values differing by ~10x.
        let stage = OutputStage {
            bias: vec![],
            multiplier: Requant::PerChannel(vec![
                QuantizedMultiplier::from_f64(0.1),
                QuantizedMultiplier::from_f64(0.01),
            ]),
            out_zero: 0,
            clamp_min: 0,
            clamp_max: 255,
        };
        let acc = vec![1000, 2000, 1000, 2000];
        let mut out = vec![0u8; 4];
        stage.apply(&acc, 2, 2, &mut out);
        assert_eq!(out, vec![100, 200, 10, 20]);
        assert_eq!(stage.requantize_one(0, 1000), 100);
        assert_eq!(stage.requantize_one(1, 1000), 10);
    }

    #[test]
    fn per_channel_with_equal_scales_matches_per_tensor() {
        let m = QuantizedMultiplier::from_f64(0.0371);
        let pt = OutputStage {
            bias: vec![5, -5, 0],
            multiplier: Requant::PerTensor(m),
            out_zero: 17,
            clamp_min: 3,
            clamp_max: 250,
        };
        let pc = OutputStage { multiplier: Requant::PerChannel(vec![m; 3]), ..pt.clone() };
        let acc: Vec<i32> = (0..12).map(|i| i * 977 - 4000).collect();
        let (mut a, mut b) = (vec![0u8; 12], vec![0u8; 12]);
        pt.apply(&acc, 3, 4, &mut a);
        pc.apply(&acc, 3, 4, &mut b);
        assert_eq!(a, b);
        let (mut wa, mut wb) = (vec![0i32; 12], vec![0i32; 12]);
        pt.requantize_i32(&acc, 3, &mut wa);
        pc.requantize_i32(&acc, 3, &mut wb);
        assert_eq!(wa, wb);
    }

    #[test]
    #[should_panic(expected = "one multiplier per output row")]
    fn per_channel_row_count_mismatch_panics() {
        let stage = OutputStage {
            bias: vec![],
            multiplier: Requant::PerChannel(vec![QuantizedMultiplier::from_f64(0.1); 2]),
            out_zero: 0,
            clamp_min: 0,
            clamp_max: 255,
        };
        let acc = vec![0i32; 9];
        let mut out = vec![0u8; 9];
        stage.apply(&acc, 3, 3, &mut out);
    }

    #[test]
    fn saturating_cast_bounds() {
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.9999), 0);
        assert_eq!(stage.requantize_one(0, i32::MAX), 255);
        assert_eq!(stage.requantize_one(0, i32::MIN), 0);
    }

    #[test]
    fn relu6_clamp_bounds() {
        // Output quantized with range [0, 6]: clamp should span the whole
        // uint8 interval — the paper's "activation no longer does anything".
        let p = QuantParams::from_min_max(0.0, 6.0, 0, 255);
        let (lo, hi) = FusedActivation::Relu6.clamp_bounds(p.scale, p.zero_point);
        assert_eq!(lo, 0);
        assert_eq!(hi, 255);
        // Wider output range [−3, 9]: clamp must cut at q(0) and q(6).
        let p2 = QuantParams::from_min_max(-3.0, 9.0, 0, 255);
        let (lo2, hi2) = FusedActivation::Relu6.clamp_bounds(p2.scale, p2.zero_point);
        assert_eq!(i32::from(lo2), p2.zero_point);
        assert_eq!(i32::from(hi2), p2.quantize(6.0));
    }

    #[test]
    fn relu_clamp_is_zero_point() {
        let p = QuantParams::from_min_max(-2.0, 2.0, 0, 255);
        let (lo, hi) = FusedActivation::Relu.clamp_bounds(p.scale, p.zero_point);
        assert_eq!(i32::from(lo), p.zero_point);
        assert_eq!(hi, 255);
    }

    #[test]
    fn bias_is_per_row() {
        let stage = OutputStage {
            bias: vec![1000, 0],
            multiplier: Requant::PerTensor(QuantizedMultiplier::from_f64(0.01)),
            out_zero: 0,
            clamp_min: 0,
            clamp_max: 255,
        };
        let acc = vec![0, 0, 0, 0];
        let mut out = vec![0u8; 4];
        stage.apply(&acc, 2, 2, &mut out);
        assert_eq!(out, vec![10, 10, 0, 0]);
    }

    #[test]
    fn requantize_i32_matches_u8_path_in_range() {
        let stage = OutputStage {
            bias: vec![7],
            multiplier: Requant::PerTensor(QuantizedMultiplier::from_f64(0.125)),
            out_zero: 5,
            clamp_min: 0,
            clamp_max: 255,
        };
        let acc = vec![100, 555, -40];
        let mut wide = vec![0i32; 3];
        stage.requantize_i32(&acc, 1, &mut wide);
        let mut narrow = vec![0u8; 3];
        stage.apply(&acc, 1, 3, &mut narrow);
        for i in 0..3 {
            assert_eq!(i32::from(narrow[i]), wide[i].clamp(0, 255));
        }
    }
}
