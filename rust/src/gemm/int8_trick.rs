//! The App. B ARM NEON accumulation trick, in portable form.
//!
//! On NEON the fastest uint8 GEMM path recentres both operands to int8 by
//! subtracting 128 (adjusting zero-points accordingly: `q − Z =
//! (q−128) − (Z−128)`, so eq. 7 is unchanged with primed values). Quantized
//! training guarantees weights never take −128 (§3.1), so every product
//! `|a·b| ≤ 127·128 < 2^14`, and **two** products fit a local int16
//! accumulator before being widened into the int32 accumulator — the
//! SMULL → SMLAL → SADALP sequence. Here we express the same schedule in
//! scalar Rust: LLVM maps the i16 pair-accumulate loop onto `pmaddwd`-class
//! SIMD on x86, doubling the effective lane width exactly as the trick does
//! on NEON.
//!
//! The overflow-safety invariant (weights ∈ [−127,127] ⇒ pairwise i16 sums
//! cannot wrap) is property-tested below and enforced at conversion time by
//! [`crate::quant::QuantParams::for_weights`]'s narrow range.

use super::QGemm;

/// K-dimension cache block (even so pairs never straddle blocks).
const KC: usize = 256;
/// Columns per packed panel block. 16 i32 lanes = one AVX-512 register /
/// two AVX2 registers; the pair-product loop below compiles to the
/// pmaddwd-class pattern at this width (EXPERIMENTS.md §Perf).
const NR: usize = 16;

/// Accumulate eq. 7 using the int8/i16-pairwise schedule.
pub fn accumulate_int8_pairwise(g: &QGemm, lhs: &[u8], rhs: &[u8], acc: &mut [i32]) {
    let (m, k, n) = (g.m, g.k, g.n);
    if m == 0 || n == 0 {
        return;
    }
    acc.fill(0);

    // Recentre once: u8 → i8 by XOR 0x80 (equivalent to subtracting 128).
    let lhs_s: Vec<i8> = lhs.iter().map(|&v| (v ^ 0x80) as i8).collect();
    let rhs_s: Vec<i8> = rhs.iter().map(|&v| (v ^ 0x80) as i8).collect();

    let mut packed = vec![0i8; KC * n.div_ceil(NR) * NR];
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        pack_rhs_panel_i8(&rhs_s, k0, kc, n, &mut packed);
        for i in 0..m {
            let lrow = &lhs_s[i * k + k0..i * k + k0 + kc];
            for b in 0..n.div_ceil(NR) {
                let n0 = b * NR;
                let nr = NR.min(n - n0);
                let panel = &packed[b * kc * NR..(b + 1) * kc * NR];
                let mut tile = [0i32; NR];
                // Process K in pairs — the paper's SMULL/SMLAL/SADALP
                // schedule. Each pairwise product sum fits 16 bits (lhs ∈
                // [−127,127], see `pairwise_sum_fits_i16`), which is what
                // lets NEON keep a local i16 accumulator and x86 use the
                // pmaddwd i16×i16→i32 pairwise form; writing the pair sum
                // directly in i32 lets LLVM pick that instruction (an
                // explicit i16 intermediate blocks the pattern match).
                let pairs = kc / 2;
                for p in 0..pairs {
                    let a0 = i32::from(lrow[2 * p]);
                    let a1 = i32::from(lrow[2 * p + 1]);
                    let r0 = &panel[2 * p * NR..2 * p * NR + NR];
                    let r1 = &panel[(2 * p + 1) * NR..(2 * p + 1) * NR + NR];
                    for c in 0..NR {
                        tile[c] += a0 * i32::from(r0[c]) + a1 * i32::from(r1[c]);
                    }
                }
                if kc % 2 == 1 {
                    let a = i32::from(lrow[kc - 1]);
                    let r = &panel[(kc - 1) * NR..(kc - 1) * NR + NR];
                    for c in 0..NR {
                        tile[c] += a * i32::from(r[c]);
                    }
                }
                let out = &mut acc[i * n + n0..i * n + n0 + nr];
                for c in 0..nr {
                    out[c] += tile[c];
                }
            }
        }
    }

    // Zero-point corrections with the recentred zero points Z' = Z − 128.
    let g_prime = QGemm { lhs_zero: g.lhs_zero - 128, rhs_zero: g.rhs_zero - 128, ..g.clone() };
    let rs = row_sums_i8(&lhs_s, m, k);
    let cs = col_sums_i8(&rhs_s, k, n);
    apply_corrections_i32(&g_prime, acc, &rs, &cs);
}

fn pack_rhs_panel_i8(rhs: &[i8], k0: usize, kc: usize, n: usize, packed: &mut [i8]) {
    for b in 0..n.div_ceil(NR) {
        let n0 = b * NR;
        let nr = NR.min(n - n0);
        let dst_base = b * kc * NR;
        for j in 0..kc {
            let src = &rhs[(k0 + j) * n + n0..(k0 + j) * n + n0 + nr];
            let dst = &mut packed[dst_base + j * NR..dst_base + j * NR + NR];
            dst[..nr].copy_from_slice(src);
            dst[nr..].fill(0);
        }
    }
}

fn row_sums_i8(lhs: &[i8], m: usize, k: usize) -> Vec<i32> {
    (0..m)
        .map(|i| lhs[i * k..(i + 1) * k].iter().map(|&v| i32::from(v)).sum())
        .collect()
}

fn col_sums_i8(rhs: &[i8], k: usize, n: usize) -> Vec<i32> {
    let mut sums = vec![0i32; n];
    for j in 0..k {
        for (s, &v) in sums.iter_mut().zip(&rhs[j * n..(j + 1) * n]) {
            *s += i32::from(v);
        }
    }
    sums
}

/// Eq. 7 corrections with this path's recentred parameters — delegates to
/// the shared implementation in [`super::prepared`].
fn apply_corrections_i32(g: &QGemm, acc: &mut [i32], row_sums: &[i32], col_sums: &[i32]) {
    super::prepared::apply_corrections(
        g.m, g.n, g.k, g.lhs_zero, g.rhs_zero, acc, row_sums, col_sums,
    );
}

/// The invariant that makes the trick sound: with weights restricted to
/// int8 values in [−127, 127], any pairwise product sum fits in i16.
/// Exposed for the property tests and the converter's debug checks.
pub fn pairwise_sum_fits_i16(w0: i8, w1: i8, a0: i8, a1: i8) -> bool {
    if w0 == -128 || w1 == -128 {
        return false; // the case training excludes
    }
    let s = i32::from(w0) * i32::from(a0) + i32::from(w1) * i32::from(a1);
    (i32::from(i16::MIN)..=i32::from(i16::MAX)).contains(&s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Kernel;

    fn pseudo(seed: u64, n: usize, lo: u8) -> Vec<u8> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(99);
                let v = (state >> 56) as u8;
                v.max(lo)
            })
            .collect()
    }

    #[test]
    fn int8_path_equals_reference() {
        for (m, k, n) in [(1, 2, 1), (3, 7, 5), (4, 255, 9), (6, 257, 12), (5, 513, 3)] {
            // lhs narrow range [1,255] — the training guarantee.
            let lhs = pseudo(1 + m as u64, m * k, 1);
            let rhs = pseudo(2 + n as u64, k * n, 0);
            let g = QGemm::new(m, k, n, 90, 133);
            let mut want = vec![0i32; m * n];
            let mut got = vec![0i32; m * n];
            g.accumulate(Kernel::Reference, &lhs, &rhs, &mut want);
            accumulate_int8_pairwise(&g, &lhs, &rhs, &mut got);
            assert_eq!(want, got, "({m},{k},{n})");
        }
    }

    #[test]
    fn worst_case_pair_fits_i16() {
        // |w| ≤ 127, |a| ≤ 128 ⇒ |w·a| ≤ 16256 < 2^14; two fit i16.
        assert!(pairwise_sum_fits_i16(127, 127, -128, -128));
        assert!(pairwise_sum_fits_i16(-127, -127, -128, -128));
        assert!(pairwise_sum_fits_i16(127, -127, 127, -128));
        // The excluded value would overflow: (-128)·(-128)·2 = 32768 > i16::MAX.
        assert!(!pairwise_sum_fits_i16(-128, -128, -128, -128));
    }

    #[test]
    fn exhaustive_pair_safety_on_boundary_weights() {
        for w0 in [-127i8, -1, 0, 1, 127] {
            for w1 in [-127i8, -1, 0, 1, 127] {
                for a0 in [-128i8, -1, 0, 127] {
                    for a1 in [-128i8, -1, 0, 127] {
                        assert!(pairwise_sum_fits_i16(w0, w1, a0, a1), "{w0},{w1},{a0},{a1}");
                    }
                }
            }
        }
    }
}
