//! Runtime-dispatched SIMD micro-kernels for the blocked uint8 GEMM.
//!
//! The tile constants (`MR=8`, `NR=16`, `KC=256`, see [`super::kernel`])
//! were sized for AVX-512-width lanes, but until this module the inner
//! kernel was scalar Rust. Here the MR×NR micro-kernel gets hand-written
//! `core::arch::x86_64` variants — SSE2, AVX2, and (toolchain permitting)
//! AVX-512 — selected **once** per process behind
//! `is_x86_feature_detected!`, with the scalar kernel as the always-on
//! fallback for non-x86 targets and for `IAOI_KERNEL=scalar` runs.
//!
//! # The pmaddwd schedule
//!
//! All SIMD variants use the same arithmetic: uint8 operands are
//! zero-extended to i16 at pack time, and `pmaddwd`
//! (`_mm_madd_epi16` / `_mm256_madd_epi16` / `_mm512_madd_epi16`)
//! multiplies adjacent i16 pairs and adds each pair into an i32 lane —
//! two depth steps per instruction. This is exact: products are at most
//! `255·255 = 65025` and a pair sum at most `130050`, far inside i16×i16
//! product range (`pmaddwd` can only saturate when *both* products are
//! `(-32768)²`, which zero-extended u8 inputs can never produce). A KC=256
//! depth block accumulates at most `256·65025 ≈ 16.6M` per lane — no i32
//! overflow — and integer addition is associative, so any accumulation
//! order (scalar, 2-wide pairs, multi-register ILP splits) produces
//! **byte-identical** i32 accumulators. Bit-identity across every path is
//! enforced by tests here, in `rust/tests/kernels.rs`, and by the GEMM
//! bench, which refuses to report a speedup on mismatched outputs.
//!
//! # Packed-RHS "pairs" layout (shared by all SIMD levels)
//!
//! For each NR-column block and each depth *pair* `p` (`kc.div_ceil(2)`
//! of them), 64 bytes hold the NR columns as i16 pairs: column `c` lives
//! at byte `p·64 + c·4` as `[v₀, 0, v₁, 0]` (little-endian i16
//! zero-extension of rows `2p` and `2p+1`; the odd-`kc` tail row packs
//! `v₁ = 0`). One `_mm_loadu_si128` reads 4 columns, one
//! `_mm256_loadu_si256` reads 8, one 64-byte load reads all NR=16 — the
//! same bytes serve every ISA width. The LHS side needs no repack: two
//! weights broadcast as `_mm_set1_epi32(a₀ | a₁ « 16)` against the whole
//! row of column pairs.
//!
//! # Epilogues live outside the tiles
//!
//! Micro-kernels produce raw i32 accumulators only. Everything downstream
//! — requantization, activation clamping, and the fused residual-add
//! epilogue ([`super::output::ResidualAdd`]) — is applied per output strip
//! *after* accumulation, in kernel-agnostic code. That keeps every
//! descriptor here oblivious to epilogue composition: a new epilogue stage
//! never touches SIMD code, and epilogue results are bit-identical across
//! kernels by construction (the accumulators they consume already are).
//!
//! # Safety invariant
//!
//! Calling the function pointers of a descriptor whose CPU features are
//! not present is **undefined behavior** (illegal instruction at best).
//! Descriptors must therefore be obtained through [`resolve`],
//! [`available`], [`best`], or [`active`] — each checks
//! `is_x86_feature_detected!` first. [`all`] exists for listing names in
//! diagnostics only.

use std::fmt;
use std::sync::OnceLock;

use super::kernel::{MR, NR};

/// One MR×NR i32 accumulator tile.
pub type Tile = [[i32; NR]; MR];

/// A micro-kernel implementation: a name (stable — used by `IAOI_KERNEL`,
/// `/metrics`, `/healthz`, and bench JSON), a packing routine producing the
/// RHS panel layout this kernel reads, the packed-panel size formula, and
/// the tile routine itself.
///
/// `pack_rhs(rhs, k0, kc, stride, n0, nn, packed)` packs `kc` depth rows
/// starting at row `k0` of a row-major RHS with row stride `stride`,
/// columns `[n0, n0+nn)`, into `nn.div_ceil(NR)` blocks of `panel_len(kc)`
/// bytes each (tail columns zero-padded).
///
/// `tile(lhs, off, row_stride, depth_stride, mr, kc, panel, tile)`
/// **overwrites** rows `0..mr` of the tile with the raw uint8 dot products
/// over one packed NR-column panel; rows `mr..` are unspecified. The LHS
/// is an affine view: element `(r, j)` of the logical `mr×kc` operand is
/// `lhs[off + r·row_stride + j·depth_stride]`, which serves both the
/// unprepared row-major LHS (`row_stride = K`, `depth_stride = 1`) and the
/// prepared `MR`-interleaved panels (`row_stride = 1`, `depth_stride =
/// MR`) without copies.
pub struct KernelDispatch {
    pub name: &'static str,
    pub pack_rhs: fn(&[u8], usize, usize, usize, usize, usize, &mut [u8]),
    pub panel_len: fn(usize) -> usize,
    pub tile: fn(&[u8], usize, usize, usize, usize, usize, &[u8], &mut Tile),
}

impl fmt::Debug for KernelDispatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("KernelDispatch").field("name", &self.name).finish_non_exhaustive()
    }
}

/// The always-available scalar micro-kernel — arithmetic identical to the
/// pre-dispatch blocked kernel, and the golden reference every SIMD path
/// must match bit-for-bit.
pub static SCALAR: KernelDispatch = KernelDispatch {
    name: "scalar",
    pack_rhs: pack_rhs_scalar,
    panel_len: panel_len_scalar,
    tile: tile_scalar,
};

#[cfg(all(target_arch = "x86_64", iaoi_avx512))]
static ALL: [&KernelDispatch; 4] = [&SCALAR, &x86::SSE2, &x86::AVX2, &x86::AVX512];
#[cfg(all(target_arch = "x86_64", not(iaoi_avx512)))]
static ALL: [&KernelDispatch; 3] = [&SCALAR, &x86::SSE2, &x86::AVX2];
#[cfg(not(target_arch = "x86_64"))]
static ALL: [&KernelDispatch; 1] = [&SCALAR];

/// Every compiled-in kernel, in ascending preference order. Includes
/// kernels the current CPU may not support — for diagnostics; run only
/// descriptors from [`available`]/[`resolve`]/[`best`]/[`active`].
pub fn all() -> &'static [&'static KernelDispatch] {
    &ALL
}

/// The scalar fallback (always safe to run).
pub fn scalar() -> &'static KernelDispatch {
    &SCALAR
}

/// Does the current CPU support this kernel's instructions?
fn detected(d: &KernelDispatch) -> bool {
    match d.name {
        "scalar" => true,
        #[cfg(target_arch = "x86_64")]
        "sse2" => std::arch::is_x86_feature_detected!("sse2"),
        #[cfg(target_arch = "x86_64")]
        "avx2" => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(iaoi_avx512)]
        "avx512" => {
            // madd needs BW; F alone (Knights-era) is not enough.
            std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512bw")
        }
        _ => false,
    }
}

/// The kernels this CPU can actually run, ascending preference (scalar
/// first — convenient as the golden baseline in sweeps).
pub fn available() -> Vec<&'static KernelDispatch> {
    ALL.iter().copied().filter(|d| detected(d)).collect()
}

/// The fastest kernel supported by this CPU.
pub fn best() -> &'static KernelDispatch {
    *available().last().expect("scalar is always available")
}

/// Look up a kernel by name, verifying the CPU supports it. Errors name
/// the valid choices so `IAOI_KERNEL` typos are self-explanatory.
pub fn resolve(name: &str) -> Result<&'static KernelDispatch, String> {
    let Some(d) = ALL.iter().copied().find(|d| d.name == name) else {
        let known: Vec<&str> = ALL.iter().map(|d| d.name).collect();
        return Err(format!(
            "unknown kernel {name:?}; compiled-in kernels: {}",
            known.join(", ")
        ));
    };
    if !detected(d) {
        let avail: Vec<&str> = available().iter().map(|d| d.name).collect();
        return Err(format!(
            "kernel {name:?} is not supported by this CPU; available: {}",
            avail.join(", ")
        ));
    }
    Ok(d)
}

static ACTIVE: OnceLock<&'static KernelDispatch> = OnceLock::new();

/// The process-wide kernel: `IAOI_KERNEL=scalar|sse2|avx2|avx512` if set
/// (panicking on unknown/unsupported names — a forced kernel that silently
/// fell back would invalidate benchmarks), otherwise [`best`]. Resolved
/// once and cached; every GEMM path (unprepared, prepared, parallel, pool)
/// starts from this unless a plan overrides it via
/// [`super::PreparedGemm::set_ukernel`].
pub fn active() -> &'static KernelDispatch {
    ACTIVE.get_or_init(|| match std::env::var("IAOI_KERNEL") {
        Ok(name) => match resolve(name.trim()) {
            Ok(d) => d,
            Err(e) => panic!("IAOI_KERNEL: {e}"),
        },
        Err(_) => best(),
    })
}

/// Scalar panel: `kc` rows of `NR` u8 each, `[kc][NR]`.
fn panel_len_scalar(kc: usize) -> usize {
    kc * NR
}

/// Scalar packing: `[block][kc][NR]` u8 order (zero-padded tail columns) —
/// the layout the original blocked kernel used.
fn pack_rhs_scalar(
    rhs: &[u8],
    k0: usize,
    kc: usize,
    stride: usize,
    n0: usize,
    nn: usize,
    packed: &mut [u8],
) {
    for b in 0..nn.div_ceil(NR) {
        let b0 = b * NR;
        let nr = NR.min(nn - b0);
        let dst_base = b * kc * NR;
        for j in 0..kc {
            let src = &rhs[(k0 + j) * stride + n0 + b0..(k0 + j) * stride + n0 + b0 + nr];
            let dst = &mut packed[dst_base + j * NR..dst_base + j * NR + NR];
            dst[..nr].copy_from_slice(src);
            dst[nr..].fill(0);
        }
    }
}

/// Scalar MR×NR tile over one packed NR-column panel. Overwrites rows
/// `0..mr`; this exact loop is what every SIMD variant must reproduce
/// bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn tile_scalar(
    lhs: &[u8],
    off: usize,
    row_stride: usize,
    depth_stride: usize,
    mr: usize,
    kc: usize,
    panel: &[u8],
    tile: &mut Tile,
) {
    for row in tile.iter_mut().take(mr) {
        *row = [0; NR];
    }
    for (j, rrow) in panel.chunks_exact(NR).take(kc).enumerate() {
        for (r, trow) in tile.iter_mut().take(mr).enumerate() {
            let a = i32::from(lhs[off + r * row_stride + j * depth_stride]);
            for (t, &v) in trow.iter_mut().zip(rrow) {
                *t += a * i32::from(v);
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! x86-64 micro-kernels. Every `unsafe fn` here requires its
    //! `#[target_feature]` set to be present; the safe wrappers are only
    //! reachable through descriptors that [`super::detected`] vetted.

    use core::arch::x86_64::*;

    use super::{KernelDispatch, Tile, NR};

    pub static SSE2: KernelDispatch = KernelDispatch {
        name: "sse2",
        pack_rhs: pack_rhs_pairs,
        panel_len: panel_len_pairs,
        tile: tile_sse2,
    };

    pub static AVX2: KernelDispatch = KernelDispatch {
        name: "avx2",
        pack_rhs: pack_rhs_pairs,
        panel_len: panel_len_pairs,
        tile: tile_avx2,
    };

    #[cfg(iaoi_avx512)]
    pub static AVX512: KernelDispatch = KernelDispatch {
        name: "avx512",
        pack_rhs: pack_rhs_pairs,
        panel_len: panel_len_pairs,
        tile: tile_avx512,
    };

    /// Pairs panel: `kc.div_ceil(2)` pair-rows of NR i16-pair columns,
    /// 4 bytes per column per pair-row.
    pub(super) fn panel_len_pairs(kc: usize) -> usize {
        kc.div_ceil(2) * NR * 4
    }

    /// Pack into the shared SIMD pairs layout (module docs): column `c` of
    /// depth pair `p` at byte `p·64 + c·4` as `[v₀, 0, v₁, 0]` — u8 rows
    /// `2p` and `2p+1` zero-extended to little-endian i16. Tail columns and
    /// the odd-`kc` missing row pack as zero.
    pub(super) fn pack_rhs_pairs(
        rhs: &[u8],
        k0: usize,
        kc: usize,
        stride: usize,
        n0: usize,
        nn: usize,
        packed: &mut [u8],
    ) {
        let blen = panel_len_pairs(kc);
        let pairs = kc.div_ceil(2);
        for b in 0..nn.div_ceil(NR) {
            let b0 = b * NR;
            let nr = NR.min(nn - b0);
            let dst = &mut packed[b * blen..(b + 1) * blen];
            dst.fill(0);
            for p in 0..pairs {
                let j0 = 2 * p;
                let prow = &mut dst[p * NR * 4..(p + 1) * NR * 4];
                let src0 = (k0 + j0) * stride + n0 + b0;
                for (c, &v) in rhs[src0..src0 + nr].iter().enumerate() {
                    prow[c * 4] = v;
                }
                if j0 + 1 < kc {
                    let src1 = (k0 + j0 + 1) * stride + n0 + b0;
                    for (c, &v) in rhs[src1..src1 + nr].iter().enumerate() {
                        prow[c * 4 + 2] = v;
                    }
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn tile_sse2(
        lhs: &[u8],
        off: usize,
        row_stride: usize,
        depth_stride: usize,
        mr: usize,
        kc: usize,
        panel: &[u8],
        tile: &mut Tile,
    ) {
        // SAFETY: this descriptor is only handed out by resolve/available/
        // best/active after `is_x86_feature_detected!("sse2")` (module-level
        // safety invariant); slice bounds are asserted inside.
        unsafe { tile_sse2_impl(lhs, off, row_stride, depth_stride, mr, kc, panel, tile) }
    }

    /// SSE2 tile: 4 XMM accumulators cover the NR=16 columns of one row;
    /// each `pmaddwd` advances two depth steps for 4 columns.
    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn tile_sse2_impl(
        lhs: &[u8],
        off: usize,
        row_stride: usize,
        depth_stride: usize,
        mr: usize,
        kc: usize,
        panel: &[u8],
        tile: &mut Tile,
    ) {
        let full = kc / 2;
        assert!(panel.len() >= kc.div_ceil(2) * NR * 4, "panel too short for kc");
        let pp = panel.as_ptr();
        for (r, trow) in tile.iter_mut().take(mr).enumerate() {
            let row = off + r * row_stride;
            let mut acc0 = _mm_setzero_si128();
            let mut acc1 = _mm_setzero_si128();
            let mut acc2 = _mm_setzero_si128();
            let mut acc3 = _mm_setzero_si128();
            for p in 0..full {
                let a0 = i32::from(lhs[row + 2 * p * depth_stride]);
                let a1 = i32::from(lhs[row + (2 * p + 1) * depth_stride]);
                let aa = _mm_set1_epi32(a0 | (a1 << 16));
                let base = pp.add(p * NR * 4);
                acc0 = _mm_add_epi32(acc0, _mm_madd_epi16(_mm_loadu_si128(base.cast()), aa));
                acc1 =
                    _mm_add_epi32(acc1, _mm_madd_epi16(_mm_loadu_si128(base.add(16).cast()), aa));
                acc2 =
                    _mm_add_epi32(acc2, _mm_madd_epi16(_mm_loadu_si128(base.add(32).cast()), aa));
                acc3 =
                    _mm_add_epi32(acc3, _mm_madd_epi16(_mm_loadu_si128(base.add(48).cast()), aa));
            }
            if kc % 2 == 1 {
                // Tail half-pair: the packed v₁ lane is zero, and the
                // broadcast's high i16 is zero too.
                let aa = _mm_set1_epi32(i32::from(lhs[row + (kc - 1) * depth_stride]));
                let base = pp.add(full * NR * 4);
                acc0 = _mm_add_epi32(acc0, _mm_madd_epi16(_mm_loadu_si128(base.cast()), aa));
                acc1 =
                    _mm_add_epi32(acc1, _mm_madd_epi16(_mm_loadu_si128(base.add(16).cast()), aa));
                acc2 =
                    _mm_add_epi32(acc2, _mm_madd_epi16(_mm_loadu_si128(base.add(32).cast()), aa));
                acc3 =
                    _mm_add_epi32(acc3, _mm_madd_epi16(_mm_loadu_si128(base.add(48).cast()), aa));
            }
            let out = trow.as_mut_ptr();
            _mm_storeu_si128(out.cast(), acc0);
            _mm_storeu_si128(out.add(4).cast(), acc1);
            _mm_storeu_si128(out.add(8).cast(), acc2);
            _mm_storeu_si128(out.add(12).cast(), acc3);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn tile_avx2(
        lhs: &[u8],
        off: usize,
        row_stride: usize,
        depth_stride: usize,
        mr: usize,
        kc: usize,
        panel: &[u8],
        tile: &mut Tile,
    ) {
        // SAFETY: descriptor vetted by is_x86_feature_detected!("avx2")
        // before being handed out; bounds asserted inside.
        unsafe { tile_avx2_impl(lhs, off, row_stride, depth_stride, mr, kc, panel, tile) }
    }

    /// AVX2 tile: 2 YMM accumulators per row (8 columns each).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn tile_avx2_impl(
        lhs: &[u8],
        off: usize,
        row_stride: usize,
        depth_stride: usize,
        mr: usize,
        kc: usize,
        panel: &[u8],
        tile: &mut Tile,
    ) {
        let full = kc / 2;
        assert!(panel.len() >= kc.div_ceil(2) * NR * 4, "panel too short for kc");
        let pp = panel.as_ptr();
        for (r, trow) in tile.iter_mut().take(mr).enumerate() {
            let row = off + r * row_stride;
            let mut acc0 = _mm256_setzero_si256();
            let mut acc1 = _mm256_setzero_si256();
            for p in 0..full {
                let a0 = i32::from(lhs[row + 2 * p * depth_stride]);
                let a1 = i32::from(lhs[row + (2 * p + 1) * depth_stride]);
                let aa = _mm256_set1_epi32(a0 | (a1 << 16));
                let base = pp.add(p * NR * 4);
                acc0 = _mm256_add_epi32(
                    acc0,
                    _mm256_madd_epi16(_mm256_loadu_si256(base.cast()), aa),
                );
                acc1 = _mm256_add_epi32(
                    acc1,
                    _mm256_madd_epi16(_mm256_loadu_si256(base.add(32).cast()), aa),
                );
            }
            if kc % 2 == 1 {
                let aa = _mm256_set1_epi32(i32::from(lhs[row + (kc - 1) * depth_stride]));
                let base = pp.add(full * NR * 4);
                acc0 = _mm256_add_epi32(
                    acc0,
                    _mm256_madd_epi16(_mm256_loadu_si256(base.cast()), aa),
                );
                acc1 = _mm256_add_epi32(
                    acc1,
                    _mm256_madd_epi16(_mm256_loadu_si256(base.add(32).cast()), aa),
                );
            }
            let out = trow.as_mut_ptr();
            _mm256_storeu_si256(out.cast(), acc0);
            _mm256_storeu_si256(out.add(8).cast(), acc1);
        }
    }

    #[cfg(iaoi_avx512)]
    #[allow(clippy::too_many_arguments)]
    fn tile_avx512(
        lhs: &[u8],
        off: usize,
        row_stride: usize,
        depth_stride: usize,
        mr: usize,
        kc: usize,
        panel: &[u8],
        tile: &mut Tile,
    ) {
        // SAFETY: descriptor vetted by is_x86_feature_detected! for both
        // avx512f and avx512bw before being handed out; bounds asserted
        // inside.
        unsafe { tile_avx512_impl(lhs, off, row_stride, depth_stride, mr, kc, panel, tile) }
    }

    /// AVX-512 tile: one ZMM covers the whole NR=16-column row; two
    /// accumulators interleave even/odd depth pairs for ILP and are summed
    /// once at the end (exact i32 adds — order cannot change the result).
    #[cfg(iaoi_avx512)]
    #[target_feature(enable = "avx512f,avx512bw")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn tile_avx512_impl(
        lhs: &[u8],
        off: usize,
        row_stride: usize,
        depth_stride: usize,
        mr: usize,
        kc: usize,
        panel: &[u8],
        tile: &mut Tile,
    ) {
        let full = kc / 2;
        assert!(panel.len() >= kc.div_ceil(2) * NR * 4, "panel too short for kc");
        let pp = panel.as_ptr();
        for (r, trow) in tile.iter_mut().take(mr).enumerate() {
            let row = off + r * row_stride;
            let mut acc_a = _mm512_setzero_si512();
            let mut acc_b = _mm512_setzero_si512();
            let mut p = 0;
            while p + 2 <= full {
                let a0 = i32::from(lhs[row + 2 * p * depth_stride]);
                let a1 = i32::from(lhs[row + (2 * p + 1) * depth_stride]);
                let b0 = i32::from(lhs[row + (2 * p + 2) * depth_stride]);
                let b1 = i32::from(lhs[row + (2 * p + 3) * depth_stride]);
                let va = core::ptr::read_unaligned(pp.add(p * NR * 4) as *const __m512i);
                let vb = core::ptr::read_unaligned(pp.add((p + 1) * NR * 4) as *const __m512i);
                let aa = _mm512_set1_epi32(a0 | (a1 << 16));
                let bb = _mm512_set1_epi32(b0 | (b1 << 16));
                acc_a = _mm512_add_epi32(acc_a, _mm512_madd_epi16(va, aa));
                acc_b = _mm512_add_epi32(acc_b, _mm512_madd_epi16(vb, bb));
                p += 2;
            }
            if p < full {
                let a0 = i32::from(lhs[row + 2 * p * depth_stride]);
                let a1 = i32::from(lhs[row + (2 * p + 1) * depth_stride]);
                let va = core::ptr::read_unaligned(pp.add(p * NR * 4) as *const __m512i);
                let aa = _mm512_set1_epi32(a0 | (a1 << 16));
                acc_a = _mm512_add_epi32(acc_a, _mm512_madd_epi16(va, aa));
            }
            if kc % 2 == 1 {
                let aa = _mm512_set1_epi32(i32::from(lhs[row + (kc - 1) * depth_stride]));
                let va = core::ptr::read_unaligned(pp.add(full * NR * 4) as *const __m512i);
                acc_b = _mm512_add_epi32(acc_b, _mm512_madd_epi16(va, aa));
            }
            let acc = _mm512_add_epi32(acc_a, acc_b);
            core::ptr::write_unaligned(trow.as_mut_ptr() as *mut __m512i, acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::kernel::KC;
    use super::*;

    fn pseudo(seed: u64, n: usize) -> Vec<u8> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (state >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn scalar_packing_is_lossless() {
        let n = 19; // not a multiple of NR
        let k = 7;
        let rhs = pseudo(3, k * n);
        let mut packed = vec![0u8; n.div_ceil(NR) * panel_len_scalar(k)];
        pack_rhs_scalar(&rhs, 0, k, n, 0, n, &mut packed);
        for j in 0..k {
            for c in 0..n {
                let block = c / NR;
                let within = c % NR;
                assert_eq!(packed[block * k * NR + j * NR + within], rhs[j * n + c]);
            }
        }
        // Tail columns of the last block are zero-padded.
        let last = (n.div_ceil(NR) - 1) * k * NR;
        for j in 0..k {
            for within in n % NR..NR {
                assert_eq!(packed[last + j * NR + within], 0);
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn pairs_packing_is_lossless_and_zero_extended() {
        for (k, n) in [(7, 19), (8, 16), (1, 1), (KC, NR + 1)] {
            let rhs = pseudo(k as u64 * 31 + n as u64, k * n);
            let blen = x86::panel_len_pairs(k);
            let mut packed = vec![0xAAu8; n.div_ceil(NR) * blen];
            x86::pack_rhs_pairs(&rhs, 0, k, n, 0, n, &mut packed);
            for j in 0..k {
                for c in 0..n {
                    let block = c / NR;
                    let within = c % NR;
                    let p = j / 2;
                    let lane = j % 2; // v0 at byte 0, v1 at byte 2
                    let off = block * blen + p * NR * 4 + within * 4 + lane * 2;
                    assert_eq!(packed[off], rhs[j * n + c], "({k},{n}) element ({j},{c})");
                    assert_eq!(packed[off + 1], 0, "high i16 byte must be zero");
                }
            }
            // Odd-k tail row packs v1 = 0 everywhere.
            if k % 2 == 1 {
                let p = k / 2;
                for block in 0..n.div_ceil(NR) {
                    for within in 0..NR {
                        let off = block * blen + p * NR * 4 + within * 4 + 2;
                        assert_eq!(packed[off], 0);
                        assert_eq!(packed[off + 1], 0);
                    }
                }
            }
        }
    }

    /// Every available tile impl must reproduce the scalar tile exactly,
    /// across mr/kc tails and u8 extremes, on both LHS access patterns
    /// (row-major and MR-interleaved).
    #[test]
    fn every_available_tile_matches_scalar() {
        for d in available() {
            if d.name == "scalar" {
                continue;
            }
            for (mr, kc) in [(1, 1), (MR, 2), (MR, KC), (3, 7), (MR - 1, KC - 1), (5, 100), (2, 33)]
            {
                // Row-major LHS (unprepared path): row_stride = kc, depth 1.
                let lhs = pseudo(mr as u64 * 7 + kc as u64, mr * kc);
                let n = NR; // one full block
                let mut rhs = pseudo(kc as u64 * 13 + 5, kc * n);
                // Salt in extremes.
                if !rhs.is_empty() {
                    rhs[0] = 0;
                    let last = rhs.len() - 1;
                    rhs[last] = 255;
                }
                let mut p_want = vec![0u8; panel_len_scalar(kc)];
                let mut p_got = vec![0u8; (d.panel_len)(kc)];
                pack_rhs_scalar(&rhs, 0, kc, n, 0, n, &mut p_want);
                (d.pack_rhs)(&rhs, 0, kc, n, 0, n, &mut p_got);
                let mut want: Tile = [[0; NR]; MR];
                let mut got: Tile = [[0; NR]; MR];
                tile_scalar(&lhs, 0, kc, 1, mr, kc, &p_want, &mut want);
                (d.tile)(&lhs, 0, kc, 1, mr, kc, &p_got, &mut got);
                assert_eq!(want[..mr], got[..mr], "{} row-major mr={mr} kc={kc}", d.name);

                // MR-interleaved LHS (prepared path): row_stride 1, depth MR.
                let mut inter = vec![0u8; kc * MR];
                for r in 0..mr {
                    for j in 0..kc {
                        inter[j * MR + r] = lhs[r * kc + j];
                    }
                }
                let mut got2: Tile = [[0; NR]; MR];
                (d.tile)(&inter, 0, 1, MR, mr, kc, &p_got, &mut got2);
                assert_eq!(want[..mr], got2[..mr], "{} interleaved mr={mr} kc={kc}", d.name);
            }
        }
    }

    #[test]
    fn resolution_invariants() {
        // Scalar is always compiled in, detected, and resolvable.
        assert_eq!(scalar().name, "scalar");
        assert_eq!(resolve("scalar").unwrap().name, "scalar");
        // available() is a prefix-preserving subset of all(), scalar first.
        let avail = available();
        assert_eq!(avail.first().unwrap().name, "scalar");
        for d in &avail {
            assert!(all().iter().any(|a| a.name == d.name));
            assert_eq!(resolve(d.name).unwrap().name, d.name);
        }
        // best() is the last available kernel.
        assert_eq!(best().name, avail.last().unwrap().name);
        // Unknown names fail with a message listing valid kernels.
        let err = resolve("neon").unwrap_err();
        assert!(err.contains("scalar"), "error should list kernels: {err}");
        // The cached active kernel is one of the available ones.
        assert!(avail.iter().any(|d| d.name == active().name));
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse2_is_baseline_on_x86_64() {
        // SSE2 is part of the x86-64 baseline; every x86-64 CPU has it.
        assert!(available().iter().any(|d| d.name == "sse2"));
    }
}
