//! Prepared GEMM plans: pack-once weights, zero-alloc per-request execution.
//!
//! The paper's latency numbers (§2.3–2.4, Table 4.1) assume gemmlowp's
//! execution model: the weights matrix is constant across requests, so all
//! weight-side work — packing into cache-friendly panels, the row sums `ā1`
//! of eq. 8, the fused [`OutputStage`] of §2.4 — is done **once** at model
//! preparation time, and only the activation side is processed per
//! inference. A [`PreparedGemm`] is that one-time product; running it needs
//! only a [`Scratch`] arena of reusable buffers, so steady-state inference
//! performs zero heap allocations (property-tested in `rust/tests/alloc.rs`).
//!
//! All three kernels are covered:
//! * [`Kernel::Reference`] keeps a raw copy of the weights (oracle path);
//! * [`Kernel::Blocked`] packs the LHS into `MR×KC` panels so the
//!   micro-kernel reads both operands sequentially (the unprepared kernel
//!   reads the LHS strided straight out of the row-major buffer);
//! * [`Kernel::Int8Pairwise`] recentres the weights to int8 at pack time
//!   (the App. B trick's `q − 128` shift) and stores the recentred row sums.
//!
//! Plans are built for a fixed `M×K` weights matrix but serve any `N`
//! (batch × positions varies per request); every integer is exact, so the
//! prepared path is bit-identical to the unprepared kernels — enforced by
//! the tests below and by `conv_kernels_agree`-style tests in `nn`.

use super::dispatch::{self, KernelDispatch};
use super::kernel::{KC, MR, NR};
use super::output::{OutputStage, ResidualAdd};
use super::{Kernel, QGemm};
use crate::tensor::ByteView;
use std::sync::OnceLock;

/// When a plan's weight-side packing work runs.
///
/// Mirrors [`crate::model_format::LoadMode`]: an explicit value wins, the
/// `IAOI_PREPARE` environment variable picks the suite-wide default, and
/// both modes are bit-identical by construction (the same [`pack`] routine
/// runs either way — eagerly in [`PreparedGemm::new`], or on first touch
/// behind a [`OnceLock`] in a [`PreparedGemm::new_lazy`] plan).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PrepareMode {
    /// Pack every layer at prepare time (the historical behaviour): install
    /// pays the full cost once, the first request is as fast as the rest.
    #[default]
    Eager,
    /// Defer packing per layer until its first execution. Prepare becomes
    /// `O(1)` per layer — the mode that makes evict/reinstall cycles cheap
    /// (a reinstalled mmap-backed model re-packs only the layers traffic
    /// actually touches, from page-cache-resident bytes) and the seam where
    /// future on-the-fly weight decoding (format-v4 4-bit nibbles) lives.
    Lazy,
}

impl PrepareMode {
    /// Parse a CLI label (`eager` | `lazy`).
    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "eager" => Some(Self::Eager),
            "lazy" => Some(Self::Lazy),
            _ => None,
        }
    }

    /// The default mode: the `IAOI_PREPARE` environment variable when it
    /// names a mode, else [`Self::Eager`]. CI runs the full suite under
    /// `IAOI_PREPARE=lazy` so both prepare paths stay covered. An
    /// unrecognized value falls back to eager but warns on stderr.
    pub fn from_env() -> Self {
        match std::env::var("IAOI_PREPARE") {
            Ok(v) => Self::from_label(&v).unwrap_or_else(|| {
                eprintln!(
                    "warning: IAOI_PREPARE={v:?} is not a prepare mode (eager | lazy); \
                     defaulting to eager"
                );
                Self::Eager
            }),
            Err(_) => Self::Eager,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::Eager => "eager",
            Self::Lazy => "lazy",
        }
    }
}

/// Reusable per-thread buffers for [`PreparedGemm`] execution. One instance
/// per worker thread; every buffer grows to its high-water mark on the first
/// requests and is then reused allocation-free.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    /// int32 accumulators (`M×N`).
    acc: Vec<i32>,
    /// Packed RHS panel for the blocked u8 kernel.
    packed_rhs: Vec<u8>,
    /// Packed, recentred RHS panel for the int8-pairwise kernel.
    packed_rhs_i8: Vec<i8>,
    /// RHS column sums `a2` (eq. 8), recomputed per request.
    col_sums: Vec<i32>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bytes currently held by the scratch buffers (their high-water
    /// marks) — the GEMM-side contribution to
    /// [`crate::graph::ExecState::arena_bytes`].
    pub fn bytes(&self) -> usize {
        self.acc.len() * std::mem::size_of::<i32>()
            + self.packed_rhs.len()
            + self.packed_rhs_i8.len()
            + self.col_sums.len() * std::mem::size_of::<i32>()
    }
}

/// Grow-only buffer access: resizes to at least `len` (allocating only when
/// the high-water mark rises) and returns the leading `len` elements.
/// Contents beyond what the caller overwrites are unspecified. Shared with
/// the prepared layer paths in [`crate::nn`].
pub(crate) fn grow<T: Copy + Default>(v: &mut Vec<T>, len: usize) -> &mut [T] {
    if v.len() < len {
        v.resize(len, T::default());
    }
    &mut v[..len]
}

/// The eq. 7 zero-point correction applied to raw `Σ q1·q2` accumulators:
/// `acc += K·Z1·Z2 − Z2·ā1(i) − Z1·a2(j)`. Shared by the prepared path and
/// [`super::int8_trick`] (with recentred zero points there).
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_corrections(
    m: usize,
    n: usize,
    k: usize,
    lhs_zero: i32,
    rhs_zero: i32,
    acc: &mut [i32],
    row_sums: &[i32],
    col_sums: &[i32],
) {
    let kzz = k as i32 * lhs_zero * rhs_zero;
    for i in 0..m {
        let row_term = kzz - rhs_zero * row_sums[i];
        for (o, &cs) in acc[i * n..(i + 1) * n].iter_mut().zip(col_sums) {
            *o += row_term - lhs_zero * cs;
        }
    }
}

/// Unpacked weight bytes a lazy plan packs from on first touch: either an
/// owned copy, or a borrowed [`ByteView`] into the artifact buffer (heap or
/// mmap) — the pack-from-view path, which skips the intermediate owned copy
/// entirely and reads panel sources straight out of the page cache.
#[derive(Clone, Debug)]
pub enum LhsBytes {
    Owned(Vec<u8>),
    View(ByteView),
}

impl LhsBytes {
    fn as_slice(&self) -> &[u8] {
        match self {
            LhsBytes::Owned(v) => v,
            LhsBytes::View(v) => v.as_slice(),
        }
    }

    /// Heap bytes this source itself holds (a view pins the shared artifact
    /// buffer, which is accounted once at the registry entry, not per plan).
    fn heap_bytes(&self) -> usize {
        match self {
            LhsBytes::Owned(v) => v.len(),
            LhsBytes::View(_) => 0,
        }
    }
}

/// Weight-side storage of a plan, laid out for its kernel's access pattern.
#[derive(Clone, Debug)]
enum PackedLhs {
    /// Raw row-major `M×K` copy (the reference triple loop reads it as-is).
    Reference(Vec<u8>),
    /// `MR`-row panels: for each `KC` block starting at `k0` and each row
    /// block `ib`, a `kc×MR` panel at offset `ibn·MR·k0 + ib·kc·MR` whose
    /// element `(j, r)` is `lhs[(ib·MR + r)·K + k0 + j]`; tail rows are
    /// zero-padded. The micro-kernel reads `MR` weights contiguously per
    /// depth step instead of striding by `K`.
    Blocked(Vec<u8>),
    /// Row-major `M×K` weights recentred to int8 (`q ^ 0x80`, i.e. `q−128`)
    /// once at pack time — the App. B precondition.
    Int8(Vec<i8>),
}

impl PackedLhs {
    fn heap_bytes(&self) -> usize {
        match self {
            PackedLhs::Reference(v) => v.len(),
            PackedLhs::Blocked(v) => v.len(),
            PackedLhs::Int8(v) => v.len(),
        }
    }
}

/// Whether a plan's panels exist yet. Eager plans are born `Ready`; lazy
/// plans hold their unpacked source and a [`OnceLock`] that the first run
/// fills — thread-safe first-touch, one atomic load on every later run.
#[derive(Clone, Debug)]
enum PackState {
    Ready((PackedLhs, Vec<i32>)),
    Lazy { src: LhsBytes, cell: OnceLock<(PackedLhs, Vec<i32>)> },
}

/// The one packing routine both prepare modes run — lazy-vs-eager
/// bit-identity is structural, not tested-and-hoped: there is no second
/// pack implementation to diverge. Returns the kernel-specific packed LHS
/// plus the eq. 8 row sums `ā1` (empty for Reference, which evaluates
/// eq. 4 directly and needs no corrections).
fn pack(kernel: Kernel, m: usize, k: usize, lhs: &[u8]) -> (PackedLhs, Vec<i32>) {
    assert_eq!(lhs.len(), m * k, "lhs must be M*K");
    match kernel {
        Kernel::Reference => (PackedLhs::Reference(lhs.to_vec()), Vec::new()),
        Kernel::Blocked => {
            (PackedLhs::Blocked(pack_lhs_blocked(lhs, m, k)), row_sums_u8(lhs, m, k))
        }
        Kernel::Int8Pairwise => {
            let recentred: Vec<i8> = lhs.iter().map(|&v| (v ^ 0x80) as i8).collect();
            let sums = (0..m)
                .map(|i| recentred[i * k..(i + 1) * k].iter().map(|&v| i32::from(v)).sum())
                .collect();
            (PackedLhs::Int8(recentred), sums)
        }
    }
}

/// A fully prepared quantized GEMM: geometry + quantization + packed
/// weights + precomputed row sums + the built-once output stage. Immutable
/// and `Sync`; share one plan read-only across worker threads, give each
/// worker its own [`Scratch`].
#[derive(Clone, Debug)]
pub struct PreparedGemm {
    m: usize,
    k: usize,
    /// Zero-point of the weights (`Z1`).
    lhs_zero: i32,
    /// Zero-point of the activations (`Z2`), fixed at conversion time.
    rhs_zero: i32,
    kernel: Kernel,
    /// Micro-kernel implementation driving the [`Kernel::Blocked`] path
    /// (ignored by Reference/Int8Pairwise). Defaults to
    /// [`dispatch::active`]; tests and benches pin it per plan via
    /// [`Self::set_ukernel`] — a per-plan override rather than a mutable
    /// global, so concurrent tests can force different paths without racing.
    /// The packed-LHS layout is implementation-independent, so switching is
    /// always safe on an existing plan.
    ukernel: &'static KernelDispatch,
    stage: OutputStage,
    /// Packed panels + eq. 8 row sums `ā1` — materialized at build time
    /// ([`Self::new`]) or on first touch ([`Self::new_lazy`]).
    pack: PackState,
}

impl PreparedGemm {
    /// Build a plan from row-major `M×K` weights. All weight-side cost
    /// (packing, row sums, the output stage) is paid here, never per run.
    pub fn new(
        kernel: Kernel,
        m: usize,
        k: usize,
        lhs_zero: i32,
        rhs_zero: i32,
        lhs: &[u8],
        stage: OutputStage,
    ) -> Self {
        assert_eq!(lhs.len(), m * k, "lhs must be M*K");
        assert!(
            (0..=255).contains(&lhs_zero) && (0..=255).contains(&rhs_zero),
            "zero points are quantized values (§2.1)"
        );
        let ukernel = dispatch::active();
        let pack = PackState::Ready(pack(kernel, m, k, lhs));
        Self { m, k, lhs_zero, rhs_zero, kernel, ukernel, stage, pack }
    }

    /// Build a plan whose panels are packed on **first touch** instead of
    /// here — [`PrepareMode::Lazy`]. `src` is the row-major `M×K` weight
    /// bytes, either owned or a [`ByteView`] borrowing the artifact buffer
    /// (the pack-from-view path: no intermediate owned copy, panel sources
    /// read straight from the mapped bytes). The first [`Self::run`] (on
    /// whichever thread gets there first; concurrent racers block on the
    /// [`OnceLock`]) runs the exact same [`pack`] routine [`Self::new`]
    /// runs, so lazy execution is bit-identical to eager by construction.
    pub fn new_lazy(
        kernel: Kernel,
        m: usize,
        k: usize,
        lhs_zero: i32,
        rhs_zero: i32,
        src: LhsBytes,
        stage: OutputStage,
    ) -> Self {
        assert_eq!(src.as_slice().len(), m * k, "lhs must be M*K");
        assert!(
            (0..=255).contains(&lhs_zero) && (0..=255).contains(&rhs_zero),
            "zero points are quantized values (§2.1)"
        );
        let ukernel = dispatch::active();
        let pack = PackState::Lazy { src, cell: OnceLock::new() };
        Self { m, k, lhs_zero, rhs_zero, kernel, ukernel, stage, pack }
    }

    /// The packed panels + row sums, materializing them now if this is a
    /// lazy plan's first touch.
    fn packed(&self) -> &(PackedLhs, Vec<i32>) {
        match &self.pack {
            PackState::Ready(ready) => ready,
            PackState::Lazy { src, cell } => {
                cell.get_or_init(|| pack(self.kernel, self.m, self.k, src.as_slice()))
            }
        }
    }

    /// True once the panels exist (always for eager plans; after the first
    /// run for lazy ones).
    pub fn is_packed(&self) -> bool {
        match &self.pack {
            PackState::Ready(_) => true,
            PackState::Lazy { cell, .. } => cell.get().is_some(),
        }
    }

    /// Heap bytes this plan holds right now: packed panels + row sums once
    /// materialized, plus any owned unpacked source a lazy plan carries
    /// (a [`LhsBytes::View`] source pins the shared artifact buffer, which
    /// its owner accounts once, not per layer). An untouched lazy
    /// pack-from-view plan reports 0 — the whole point of the mode.
    pub fn plan_bytes(&self) -> usize {
        let packed = |p: &(PackedLhs, Vec<i32>)| {
            p.0.heap_bytes() + p.1.len() * std::mem::size_of::<i32>()
        };
        match &self.pack {
            PackState::Ready(ready) => packed(ready),
            PackState::Lazy { src, cell } => {
                src.heap_bytes() + cell.get().map_or(0, packed)
            }
        }
    }

    /// Pin the micro-kernel implementation for this plan (Blocked path
    /// only). Pass a descriptor from [`dispatch::available`] /
    /// [`dispatch::resolve`] — those verify CPU support.
    pub fn set_ukernel(&mut self, u: &'static KernelDispatch) {
        self.ukernel = u;
    }

    /// Builder-style [`Self::set_ukernel`].
    pub fn with_ukernel(mut self, u: &'static KernelDispatch) -> Self {
        self.set_ukernel(u);
        self
    }

    /// The micro-kernel implementation this plan dispatches to.
    pub fn ukernel(&self) -> &'static KernelDispatch {
        self.ukernel
    }

    /// Convenience: build from an existing [`QGemm`] description (its `n` is
    /// ignored — plans serve any N).
    pub fn from_qgemm(g: &QGemm, kernel: Kernel, lhs: &[u8], stage: OutputStage) -> Self {
        Self::new(kernel, g.m, g.k, g.lhs_zero, g.rhs_zero, lhs, stage)
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn stage(&self) -> &OutputStage {
        &self.stage
    }

    /// Full quantized GEMM against a row-major `K×N` RHS: eq. 7 accumulation
    /// plus the §2.4 output pipeline, writing uint8 into `out` (`M×N`).
    /// Allocation-free once `scratch` has warmed up.
    pub fn run(&self, n: usize, rhs: &[u8], out: &mut [u8], scratch: &mut Scratch) {
        self.run_res(n, rhs, out, None, scratch);
    }

    /// [`Self::run`] with the composable epilogue: after requantization each
    /// output element is optionally combined with the matching element of a
    /// residual source (NHWC bytes with `M` channels) via [`ResidualAdd`] —
    /// the fused conv→add path. `res = None` is exactly [`Self::run`].
    pub fn run_res(
        &self,
        n: usize,
        rhs: &[u8],
        out: &mut [u8],
        res: Option<(&ResidualAdd, &[u8])>,
        scratch: &mut Scratch,
    ) {
        assert_eq!(rhs.len(), self.k * n, "rhs must be K*N");
        assert_eq!(out.len(), self.m * n, "out must be M*N");
        let Scratch { acc, packed_rhs, packed_rhs_i8, col_sums } = scratch;
        let acc = grow(acc, self.m * n);
        self.accumulate_cols(rhs, n, 0, n, acc, packed_rhs, packed_rhs_i8, col_sums);
        self.stage.apply_res(acc, self.m, n, out, res, 0);
    }

    /// Corrected int32 accumulators only (eq. 7 without the output stage) —
    /// the prepared counterpart of [`QGemm::accumulate`].
    pub fn accumulate(&self, n: usize, rhs: &[u8], acc: &mut [i32], scratch: &mut Scratch) {
        assert_eq!(rhs.len(), self.k * n, "rhs must be K*N");
        assert_eq!(acc.len(), self.m * n, "acc must be M*N");
        let Scratch { packed_rhs, packed_rhs_i8, col_sums, .. } = scratch;
        self.accumulate_cols(rhs, n, 0, n, acc, packed_rhs, packed_rhs_i8, col_sums);
    }

    /// Compute one column strip `[n0, n0 + nn)` of the output directly from
    /// the full strided RHS (row stride `stride`), writing through per-row
    /// `&mut` segments — the multi-threaded path
    /// ([`super::parallel::run_parallel_prepared`]) hands each worker
    /// disjoint splits of the one output buffer, so there is no per-thread
    /// `sub_out` gather and no intermediate RHS strip copy.
    pub fn run_strip(
        &self,
        rhs: &[u8],
        stride: usize,
        n0: usize,
        segs: &mut [&mut [u8]],
        scratch: &mut Scratch,
    ) {
        self.run_strip_res(rhs, stride, n0, segs, None, scratch);
    }

    /// [`Self::run_strip`] with the composable residual-add epilogue: the
    /// strip covers global columns `[n0, n0 + nn)`, so row `i`, local column
    /// `j` pairs with residual byte `res[(n0 + j) * M + i]` (NHWC source,
    /// `M` channels). Each worker applies the epilogue to its own strip
    /// while the `M×nn` accumulator block is still cache-resident.
    pub fn run_strip_res(
        &self,
        rhs: &[u8],
        stride: usize,
        n0: usize,
        segs: &mut [&mut [u8]],
        res: Option<(&ResidualAdd, &[u8])>,
        scratch: &mut Scratch,
    ) {
        assert_eq!(segs.len(), self.m, "one output segment per row");
        let nn = segs.first().map_or(0, |s| s.len());
        assert!(n0 + nn <= stride, "strip exceeds RHS width");
        assert_eq!(rhs.len(), self.k * stride, "rhs must be K*stride");
        if self.m == 0 || nn == 0 {
            return;
        }
        let Scratch { acc, packed_rhs, packed_rhs_i8, col_sums } = scratch;
        let acc = grow(acc, self.m * nn);
        self.accumulate_cols(rhs, stride, n0, nn, acc, packed_rhs, packed_rhs_i8, col_sums);
        if let Some((_, data)) = res {
            assert!((n0 + nn) * self.m <= data.len(), "residual source too small for this strip");
        }
        let bias = &self.stage.bias;
        for (i, seg) in segs.iter_mut().enumerate() {
            assert_eq!(seg.len(), nn, "ragged output segments");
            let mult = self.stage.multiplier.for_row(i);
            let b = if bias.is_empty() { 0 } else { bias[i] };
            match res {
                None => {
                    for (o, &a) in seg.iter_mut().zip(&acc[i * nn..(i + 1) * nn]) {
                        *o = self.stage.requantize_with(mult, a.wrapping_add(b));
                    }
                }
                Some((r, data)) => {
                    for (j, (o, &a)) in
                        seg.iter_mut().zip(&acc[i * nn..(i + 1) * nn]).enumerate()
                    {
                        let qa = self.stage.requantize_with(mult, a.wrapping_add(b));
                        *o = r.apply(qa, data[(n0 + j) * self.m + i]);
                    }
                }
            }
        }
    }

    /// Dispatch eq. 7 over the columns `[n0, n0 + nn)` of a strided RHS into
    /// `acc` (`M×nn`, overwritten).
    #[allow(clippy::too_many_arguments)]
    fn accumulate_cols(
        &self,
        rhs: &[u8],
        stride: usize,
        n0: usize,
        nn: usize,
        acc: &mut [i32],
        packed_rhs: &mut Vec<u8>,
        packed_rhs_i8: &mut Vec<i8>,
        col_sums: &mut Vec<i32>,
    ) {
        if self.m == 0 || nn == 0 {
            return;
        }
        let (packed_lhs, row_sums) = self.packed();
        match packed_lhs {
            PackedLhs::Reference(lhs) => {
                self.accumulate_reference(lhs, rhs, stride, n0, nn, acc);
            }
            PackedLhs::Blocked(packed) => {
                self.accumulate_blocked(packed, rhs, stride, n0, nn, acc, packed_rhs);
                let cs = grow(col_sums, nn);
                col_sums_u8_strided(rhs, self.k, stride, n0, nn, cs);
                apply_corrections(
                    self.m, nn, self.k, self.lhs_zero, self.rhs_zero, acc, row_sums, cs,
                );
            }
            PackedLhs::Int8(lhs_s) => {
                self.accumulate_int8(lhs_s, rhs, stride, n0, nn, acc, packed_rhs_i8);
                let cs = grow(col_sums, nn);
                col_sums_i8_strided(rhs, self.k, stride, n0, nn, cs);
                // Recentred zero points Z' = Z − 128 (App. B).
                apply_corrections(
                    self.m,
                    nn,
                    self.k,
                    self.lhs_zero - 128,
                    self.rhs_zero - 128,
                    acc,
                    row_sums,
                    cs,
                );
            }
        }
    }

    /// Direct eq. 4 evaluation over a strided RHS (correctness oracle).
    fn accumulate_reference(
        &self,
        lhs: &[u8],
        rhs: &[u8],
        stride: usize,
        n0: usize,
        nn: usize,
        acc: &mut [i32],
    ) {
        let k = self.k;
        for i in 0..self.m {
            for col in 0..nn {
                let mut sum = 0i32;
                for j in 0..k {
                    let a = i32::from(lhs[i * k + j]) - self.lhs_zero;
                    let b = i32::from(rhs[j * stride + n0 + col]) - self.rhs_zero;
                    sum += a * b;
                }
                acc[i * nn + col] = sum;
            }
        }
    }

    /// The blocked kernel over a pre-packed LHS: identical arithmetic to
    /// [`super::kernel::accumulate_blocked`], but the LHS panel reads are
    /// contiguous `MR`-wide rows instead of `K`-strided scalar loads. The
    /// inner tile and RHS packing come from `self.ukernel`.
    #[allow(clippy::too_many_arguments)]
    fn accumulate_blocked(
        &self,
        packed_lhs: &[u8],
        rhs: &[u8],
        stride: usize,
        n0: usize,
        nn: usize,
        acc: &mut [i32],
        packed_rhs: &mut Vec<u8>,
    ) {
        let d = self.ukernel;
        let (m, k) = (self.m, self.k);
        acc[..m * nn].fill(0);
        let blocks = nn.div_ceil(NR);
        let pr = grow(packed_rhs, blocks * (d.panel_len)(KC.min(k)));
        let ibn = m.div_ceil(MR);
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            let blen = (d.panel_len)(kc);
            (d.pack_rhs)(rhs, k0, kc, stride, n0, nn, &mut pr[..blocks * blen]);
            // Panels for this K block start after the ibn·MR·k0 elements of
            // all previous (full-KC) blocks.
            let kb_base = ibn * MR * k0;
            for ib in 0..ibn {
                let i0 = ib * MR;
                let mr = MR.min(m - i0);
                // Packed-LHS view: element (r, j) of the mr×kc operand is
                // packed_lhs[poff + j·MR + r].
                let poff = kb_base + ib * kc * MR;
                for (b, panel) in pr[..blocks * blen].chunks_exact(blen).enumerate() {
                    let nb0 = b * NR;
                    let nr = NR.min(nn - nb0);
                    let mut tile = [[0i32; NR]; MR];
                    (d.tile)(packed_lhs, poff, 1, MR, mr, kc, panel, &mut tile);
                    for r in 0..mr {
                        let row = &mut acc[(i0 + r) * nn + nb0..(i0 + r) * nn + nb0 + nr];
                        for (o, &t) in row.iter_mut().zip(&tile[r][..nr]) {
                            *o += t;
                        }
                    }
                }
            }
        }
    }

    /// The App. B int8/i16-pairwise schedule over pre-recentred weights;
    /// the RHS is recentred on the fly while packing (one pass, no extra
    /// buffer). Mirrors [`super::int8_trick::accumulate_int8_pairwise`].
    #[allow(clippy::too_many_arguments)]
    fn accumulate_int8(
        &self,
        lhs_s: &[i8],
        rhs: &[u8],
        stride: usize,
        n0: usize,
        nn: usize,
        acc: &mut [i32],
        packed_rhs_i8: &mut Vec<i8>,
    ) {
        let (m, k) = (self.m, self.k);
        acc[..m * nn].fill(0);
        let pr = grow(packed_rhs_i8, KC * nn.div_ceil(NR) * NR);
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            pack_rhs_panel_i8_strided(rhs, k0, kc, stride, n0, nn, pr);
            for i in 0..m {
                let lrow = &lhs_s[i * k + k0..i * k + k0 + kc];
                for b in 0..nn.div_ceil(NR) {
                    let nb0 = b * NR;
                    let nr = NR.min(nn - nb0);
                    let panel = &pr[b * kc * NR..(b + 1) * kc * NR];
                    let mut tile = [0i32; NR];
                    // K in pairs — the SMULL/SMLAL/SADALP schedule; see
                    // int8_trick.rs for why the pair sum fits 16 bits.
                    let pairs = kc / 2;
                    for p in 0..pairs {
                        let a0 = i32::from(lrow[2 * p]);
                        let a1 = i32::from(lrow[2 * p + 1]);
                        let r0 = &panel[2 * p * NR..2 * p * NR + NR];
                        let r1 = &panel[(2 * p + 1) * NR..(2 * p + 1) * NR + NR];
                        for c in 0..NR {
                            tile[c] += a0 * i32::from(r0[c]) + a1 * i32::from(r1[c]);
                        }
                    }
                    if kc % 2 == 1 {
                        let a = i32::from(lrow[kc - 1]);
                        let r = &panel[(kc - 1) * NR..(kc - 1) * NR + NR];
                        for c in 0..NR {
                            tile[c] += a * i32::from(r[c]);
                        }
                    }
                    let out = &mut acc[i * nn + nb0..i * nn + nb0 + nr];
                    for (o, &t) in out.iter_mut().zip(&tile[..nr]) {
                        *o += t;
                    }
                }
            }
        }
    }
}

/// Row sums `ā1` over uint8 weights (eq. 8).
fn row_sums_u8(lhs: &[u8], m: usize, k: usize) -> Vec<i32> {
    (0..m)
        .map(|i| lhs[i * k..(i + 1) * k].iter().map(|&v| i32::from(v)).sum())
        .collect()
}

/// Pack row-major `M×K` weights into the [`PackedLhs::Blocked`] panel
/// layout; tail rows (when `m % MR != 0`) stay zero.
fn pack_lhs_blocked(lhs: &[u8], m: usize, k: usize) -> Vec<u8> {
    let ibn = m.div_ceil(MR);
    let mut packed = vec![0u8; ibn * MR * k];
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        let kb_base = ibn * MR * k0;
        for ib in 0..ibn {
            let i0 = ib * MR;
            let mr = MR.min(m - i0);
            let base = kb_base + ib * kc * MR;
            for (r, row) in lhs[i0 * k..].chunks_exact(k).take(mr).enumerate() {
                for (j, &v) in row[k0..k0 + kc].iter().enumerate() {
                    packed[base + j * MR + r] = v;
                }
            }
        }
    }
    packed
}

/// Pack `kc` rows of a *strided* RHS (row stride `stride`, columns
/// `[n0, n0 + nn)`) into `[ceil(nn/NR)][kc][NR]` order, recentring u8 → i8
/// (`v ^ 0x80`) in the same pass — the int8 path's activation-side recentre
/// costs no extra sweep over the data. (The u8 Blocked path packs through
/// its dispatch descriptor's `pack_rhs` instead.)
fn pack_rhs_panel_i8_strided(
    rhs: &[u8],
    k0: usize,
    kc: usize,
    stride: usize,
    n0: usize,
    nn: usize,
    packed: &mut [i8],
) {
    for b in 0..nn.div_ceil(NR) {
        let b0 = b * NR;
        let nr = NR.min(nn - b0);
        let dst_base = b * kc * NR;
        for j in 0..kc {
            let src = &rhs[(k0 + j) * stride + n0 + b0..(k0 + j) * stride + n0 + b0 + nr];
            let dst = &mut packed[dst_base + j * NR..dst_base + j * NR + NR];
            for (d, &s) in dst[..nr].iter_mut().zip(src) {
                *d = (s ^ 0x80) as i8;
            }
            dst[nr..].fill(0);
        }
    }
}

/// Column sums `a2` of a strided u8 RHS over columns `[n0, n0 + nn)`.
fn col_sums_u8_strided(rhs: &[u8], k: usize, stride: usize, n0: usize, nn: usize, out: &mut [i32]) {
    out.fill(0);
    for j in 0..k {
        let row = &rhs[j * stride + n0..j * stride + n0 + nn];
        for (s, &v) in out.iter_mut().zip(row) {
            *s += i32::from(v);
        }
    }
}

/// Column sums of a strided RHS recentred to int8 on the fly.
fn col_sums_i8_strided(rhs: &[u8], k: usize, stride: usize, n0: usize, nn: usize, out: &mut [i32]) {
    out.fill(0);
    for j in 0..k {
        let row = &rhs[j * stride + n0..j * stride + n0 + nn];
        for (s, &v) in out.iter_mut().zip(row) {
            *s += i32::from((v ^ 0x80) as i8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedMultiplier;

    fn pseudo(seed: u64, n: usize, lo: u8) -> Vec<u8> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 56) as u8).max(lo)
            })
            .collect()
    }

    fn demo_stage(m: usize) -> OutputStage {
        OutputStage {
            bias: (0..m as i32).map(|i| i * 37 - 100).collect(),
            multiplier: crate::gemm::output::Requant::PerTensor(QuantizedMultiplier::from_f64(0.0041)),
            out_zero: 13,
            clamp_min: 2,
            clamp_max: 251,
        }
    }

    /// Per-row multipliers spanning a wide range — exercises the
    /// per-channel stage through the packed/strip paths.
    fn per_channel_stage(m: usize) -> OutputStage {
        OutputStage {
            bias: (0..m as i32).map(|i| i * 11 - 40).collect(),
            multiplier: crate::gemm::output::Requant::PerChannel(
                (0..m)
                    .map(|i| QuantizedMultiplier::from_f64(0.0008 * 1.7f64.powi(i as i32 % 7)))
                    .collect(),
            ),
            out_zero: 9,
            clamp_min: 0,
            clamp_max: 255,
        }
    }

    /// Shapes covering every tail case: `m % MR`, `n % NR`, `k % KC`, plus
    /// the degenerate 1×1×1.
    const AWKWARD: [(usize, usize, usize); 7] = [
        (1, 1, 1),
        (MR, KC, NR),
        (MR + 1, KC + 1, NR + 1),
        (MR - 1, 3, NR - 1),
        (9, 300, 19),
        (2, 513, 2),
        (17, 64, 33),
    ];

    #[test]
    fn packed_lhs_round_trip_is_lossless() {
        // Every lhs element must appear at its documented panel offset.
        for (m, k) in [(1, 1), (MR, KC), (MR + 3, KC + 5), (9, 300), (MR - 1, 2)] {
            let lhs = pseudo(m as u64 * 7 + k as u64, m * k, 0);
            let packed = pack_lhs_blocked(&lhs, m, k);
            let ibn = m.div_ceil(MR);
            assert_eq!(packed.len(), ibn * MR * k);
            for i in 0..m {
                for j in 0..k {
                    let k0 = (j / KC) * KC;
                    let kc = KC.min(k - k0);
                    let ib = i / MR;
                    let off = ibn * MR * k0 + ib * kc * MR + (j - k0) * MR + (i - ib * MR);
                    assert_eq!(packed[off], lhs[i * k + j], "({m},{k}) element ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn prepared_bit_identical_to_unprepared_all_kernels() {
        for &(m, k, n) in &AWKWARD {
            // Narrow-range lhs — the training guarantee the int8 path needs.
            let lhs = pseudo(m as u64 * 31 + k as u64, m * k, 1);
            let rhs = pseudo(n as u64 * 17 + k as u64, k * n, 0);
            let g = QGemm::new(m, k, n, 77, 201);
            let stage = demo_stage(m);
            for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
                let mut want = vec![0u8; m * n];
                g.run(kern, &lhs, &rhs, &stage, &mut want);
                let plan = PreparedGemm::from_qgemm(&g, kern, &lhs, stage.clone());
                let mut scratch = Scratch::new();
                let mut got = vec![0u8; m * n];
                plan.run(n, &rhs, &mut got, &mut scratch);
                assert_eq!(want, got, "{kern:?} ({m},{k},{n})");
                // And again with the warm scratch (reuse must not corrupt).
                let mut again = vec![0u8; m * n];
                plan.run(n, &rhs, &mut again, &mut scratch);
                assert_eq!(want, again, "{kern:?} warm ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn prepared_accumulate_matches_unprepared() {
        for &(m, k, n) in &AWKWARD {
            let lhs = pseudo(3 + m as u64, m * k, 1);
            let rhs = pseudo(5 + n as u64, k * n, 0);
            let g = QGemm::new(m, k, n, 120, 9);
            for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
                let mut want = vec![0i32; m * n];
                g.accumulate(kern, &lhs, &rhs, &mut want);
                let plan = PreparedGemm::from_qgemm(&g, kern, &lhs, demo_stage(m));
                let mut got = vec![0i32; m * n];
                plan.accumulate(n, &rhs, &mut got, &mut Scratch::new());
                assert_eq!(want, got, "{kern:?} ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn per_channel_stage_bit_identical_prepared_vs_unprepared_and_strips() {
        for &(m, k, n) in &AWKWARD {
            let lhs = pseudo(m as u64 * 13 + k as u64, m * k, 1);
            let rhs = pseudo(n as u64 * 19 + k as u64, k * n, 0);
            let g = QGemm::new(m, k, n, 77, 201);
            let stage = per_channel_stage(m);
            for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
                let mut want = vec![0u8; m * n];
                g.run(kern, &lhs, &rhs, &stage, &mut want);
                let plan = PreparedGemm::from_qgemm(&g, kern, &lhs, stage.clone());
                let mut got = vec![0u8; m * n];
                plan.run(n, &rhs, &mut got, &mut Scratch::new());
                assert_eq!(want, got, "{kern:?} ({m},{k},{n}) per-channel");
                // Strip execution must index multipliers by absolute row.
                let mut strip = vec![0u8; m * n];
                let split = (n / 2).max(1).min(n);
                for (n0, n1) in [(0usize, split), (split, n)] {
                    let mut segs: Vec<&mut [u8]> = Vec::with_capacity(m);
                    let mut rest = &mut strip[..];
                    for _ in 0..m {
                        let (row, tail) = rest.split_at_mut(n);
                        rest = tail;
                        segs.push(&mut row[n0..n1]);
                    }
                    plan.run_strip(&rhs, n, n0, &mut segs, &mut Scratch::new());
                }
                assert_eq!(want, strip, "{kern:?} ({m},{k},{n}) per-channel strips");
            }
        }
    }

    #[test]
    fn one_plan_serves_many_batch_widths() {
        // The same prepared weights must serve varying N (batch sizes) from
        // one scratch, shrinking and growing between requests.
        let (m, k) = (6, 70);
        let lhs = pseudo(11, m * k, 1);
        let g = QGemm::new(m, k, 1, 50, 60);
        let stage = demo_stage(m);
        let plan = PreparedGemm::from_qgemm(&g, Kernel::Blocked, &lhs, stage.clone());
        let mut scratch = Scratch::new();
        for n in [5, 33, 1, 16, 7] {
            let rhs = pseudo(n as u64, k * n, 0);
            let gn = QGemm::new(m, k, n, 50, 60);
            let mut want = vec![0u8; m * n];
            gn.run(Kernel::Blocked, &lhs, &rhs, &stage, &mut want);
            let mut got = vec![0u8; m * n];
            plan.run(n, &rhs, &mut got, &mut scratch);
            assert_eq!(want, got, "n={n}");
        }
    }

    #[test]
    fn run_strip_matches_full_run() {
        let (m, k, n) = (7, 90, 41);
        let lhs = pseudo(21, m * k, 1);
        let rhs = pseudo(22, k * n, 0);
        let g = QGemm::new(m, k, n, 130, 44);
        let stage = demo_stage(m);
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let plan = PreparedGemm::from_qgemm(&g, kern, &lhs, stage.clone());
            let mut want = vec![0u8; m * n];
            plan.run(n, &rhs, &mut want, &mut Scratch::new());
            // Compute in two strips through disjoint row segments.
            let mut got = vec![0u8; m * n];
            let split = 17;
            for (n0, n1) in [(0usize, split), (split, n)] {
                let mut segs: Vec<&mut [u8]> = Vec::with_capacity(m);
                let mut rest = &mut got[..];
                for _ in 0..m {
                    let (row, tail) = rest.split_at_mut(n);
                    rest = tail;
                    segs.push(&mut row[n0..n1]);
                }
                plan.run_strip(&rhs, n, n0, &mut segs, &mut Scratch::new());
            }
            assert_eq!(want, got, "{kern:?}");
        }
    }

    #[test]
    fn forced_ukernels_bit_identical_through_prepared_paths() {
        // Every compiled-and-detected micro-kernel, pinned per plan, must
        // reproduce the scalar plan byte-for-byte through run() and
        // run_strip() — per-channel stage so requantization is exercised
        // with per-row multipliers.
        for &(m, k, n) in &AWKWARD {
            let lhs = pseudo(m as u64 * 3 + k as u64, m * k, 1);
            let rhs = pseudo(n as u64 * 5 + k as u64, k * n, 0);
            let g = QGemm::new(m, k, n, 77, 201);
            let stage = per_channel_stage(m);
            let base = PreparedGemm::from_qgemm(&g, Kernel::Blocked, &lhs, stage)
                .with_ukernel(dispatch::scalar());
            let mut want = vec![0u8; m * n];
            base.run(n, &rhs, &mut want, &mut Scratch::new());
            for d in dispatch::available() {
                let plan = base.clone().with_ukernel(d);
                assert_eq!(plan.ukernel().name, d.name);
                let mut got = vec![0u8; m * n];
                plan.run(n, &rhs, &mut got, &mut Scratch::new());
                assert_eq!(want, got, "{} run ({m},{k},{n})", d.name);
                let mut strip = vec![0u8; m * n];
                let split = (n / 2).max(1).min(n);
                for (n0, n1) in [(0usize, split), (split, n)] {
                    let mut segs: Vec<&mut [u8]> = Vec::with_capacity(m);
                    let mut rest = &mut strip[..];
                    for _ in 0..m {
                        let (row, tail) = rest.split_at_mut(n);
                        rest = tail;
                        segs.push(&mut row[n0..n1]);
                    }
                    plan.run_strip(&rhs, n, n0, &mut segs, &mut Scratch::new());
                }
                assert_eq!(want, strip, "{} strips ({m},{k},{n})", d.name);
            }
        }
    }

    #[test]
    fn empty_dims_are_ok() {
        let stage = OutputStage::bare(QuantizedMultiplier::from_f64(0.01), 0);
        let plan = PreparedGemm::new(Kernel::Blocked, 0, 4, 10, 10, &[], stage);
        let mut out: Vec<u8> = vec![];
        plan.run(0, &[], &mut out, &mut Scratch::new());
    }

    #[test]
    fn lazy_plans_bit_identical_to_eager_all_kernels() {
        for &(m, k, n) in &AWKWARD {
            let lhs = pseudo(m as u64 * 29 + k as u64, m * k, 1);
            let rhs = pseudo(n as u64 * 23 + k as u64, k * n, 0);
            let stage = per_channel_stage(m);
            for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
                let eager = PreparedGemm::new(kern, m, k, 77, 201, &lhs, stage.clone());
                let lazy = PreparedGemm::new_lazy(
                    kern,
                    m,
                    k,
                    77,
                    201,
                    LhsBytes::Owned(lhs.clone()),
                    stage.clone(),
                );
                assert!(eager.is_packed());
                assert!(!lazy.is_packed(), "lazy plan must not pack at build time");
                let mut want = vec![0u8; m * n];
                eager.run(n, &rhs, &mut want, &mut Scratch::new());
                let mut got = vec![0u8; m * n];
                lazy.run(n, &rhs, &mut got, &mut Scratch::new());
                assert!(lazy.is_packed(), "first run must materialize the panels");
                assert_eq!(want, got, "{kern:?} ({m},{k},{n}) lazy vs eager");
                // Warm re-run through the already-filled cell.
                let mut again = vec![0u8; m * n];
                lazy.run(n, &rhs, &mut again, &mut Scratch::new());
                assert_eq!(want, again, "{kern:?} ({m},{k},{n}) lazy warm");
            }
        }
    }

    #[test]
    fn lazy_pack_from_view_matches_owned() {
        use crate::tensor::ArtifactBytes;
        let (m, k, n) = (9, 300, 19);
        let lhs = pseudo(41, m * k, 1);
        let rhs = pseudo(42, k * n, 0);
        let stage = demo_stage(m);
        let buf = ArtifactBytes::from_vec(lhs.clone());
        let view = buf.view(0, m * k);
        for kern in [Kernel::Reference, Kernel::Blocked, Kernel::Int8Pairwise] {
            let eager = PreparedGemm::new(kern, m, k, 50, 60, &lhs, stage.clone());
            let lazy = PreparedGemm::new_lazy(
                kern,
                m,
                k,
                50,
                60,
                LhsBytes::View(view.clone()),
                stage.clone(),
            );
            // Untouched pack-from-view plans hold no heap bytes of their own.
            assert_eq!(lazy.plan_bytes(), 0, "{kern:?}");
            let mut want = vec![0u8; m * n];
            eager.run(n, &rhs, &mut want, &mut Scratch::new());
            let mut got = vec![0u8; m * n];
            lazy.run(n, &rhs, &mut got, &mut Scratch::new());
            assert_eq!(want, got, "{kern:?} view-backed lazy vs eager");
            assert!(lazy.plan_bytes() > 0, "{kern:?} packed panels must be accounted");
        }
    }

    #[test]
    fn lazy_first_touch_races_are_safe() {
        // Many threads hit an unpacked plan at once; OnceLock must hand all
        // of them the same panels and every output must be identical.
        let (m, k, n) = (17, 64, 33);
        let lhs = pseudo(55, m * k, 1);
        let rhs = pseudo(56, k * n, 0);
        let stage = demo_stage(m);
        let eager = PreparedGemm::new(Kernel::Blocked, m, k, 77, 201, &lhs, stage.clone());
        let mut want = vec![0u8; m * n];
        eager.run(n, &rhs, &mut want, &mut Scratch::new());
        let lazy = PreparedGemm::new_lazy(
            Kernel::Blocked,
            m,
            k,
            77,
            201,
            LhsBytes::Owned(lhs),
            stage,
        );
        std::thread::scope(|s| {
            for _ in 0..8 {
                let (lazy, rhs, want) = (&lazy, &rhs, &want);
                s.spawn(move || {
                    let mut got = vec![0u8; m * n];
                    lazy.run(n, rhs, &mut got, &mut Scratch::new());
                    assert_eq!(want, &got);
                });
            }
        });
    }

    #[test]
    fn prepare_mode_labels_round_trip() {
        for mode in [PrepareMode::Eager, PrepareMode::Lazy] {
            assert_eq!(PrepareMode::from_label(mode.label()), Some(mode));
        }
        assert_eq!(PrepareMode::from_label("bogus"), None);
        assert_eq!(PrepareMode::default(), PrepareMode::Eager);
    }
}
