//! Serving metrics: latency distribution, batch-size distribution and
//! throughput, collected by the coordinator workers.

use std::time::Duration;

/// Aggregated serving statistics.
#[derive(Clone, Debug)]
pub struct Metrics {
    /// Engine or model label (e.g. `"int8"`, or a registry model name in
    /// multi-model serving).
    pub engine: String,
    pub completed: u64,
    pub batches: u64,
    /// Sum of batch sizes (== completed; kept for averaging convenience).
    pub batched_requests: u64,
    /// Request latencies in microseconds (bounded reservoir).
    latencies_us: Vec<u64>,
    /// Engine compute time per batch, microseconds.
    compute_us: Vec<u64>,
    /// Batch size histogram indexed by size (0 unused).
    pub batch_sizes: Vec<u64>,
}

const RESERVOIR: usize = 100_000;

impl Metrics {
    pub fn new(engine: impl Into<String>) -> Self {
        Self {
            engine: engine.into(),
            completed: 0,
            batches: 0,
            batched_requests: 0,
            latencies_us: Vec::new(),
            compute_us: Vec::new(),
            batch_sizes: vec![0; 64],
        }
    }

    pub fn record_latency(&mut self, latency: Duration) {
        self.completed += 1;
        if self.latencies_us.len() < RESERVOIR {
            self.latencies_us.push(latency.as_micros() as u64);
        }
    }

    pub fn record_batch(&mut self, size: usize, compute: Duration) {
        self.batches += 1;
        self.batched_requests += size as u64;
        if size < self.batch_sizes.len() {
            self.batch_sizes[size] += 1;
        }
        if self.compute_us.len() < RESERVOIR {
            self.compute_us.push(compute.as_micros() as u64);
        }
    }

    fn percentile(sorted: &[u64], p: f64) -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
        sorted[idx]
    }

    /// (p50, p95, p99, mean) request latency in microseconds.
    pub fn latency_summary_us(&self) -> (u64, u64, u64, u64) {
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let mean = if v.is_empty() { 0 } else { v.iter().sum::<u64>() / v.len() as u64 };
        (
            Self::percentile(&v, 0.50),
            Self::percentile(&v, 0.95),
            Self::percentile(&v, 0.99),
            mean,
        )
    }

    /// Mean engine compute time per batch, microseconds.
    pub fn mean_compute_us(&self) -> u64 {
        if self.compute_us.is_empty() {
            0
        } else {
            self.compute_us.iter().sum::<u64>() / self.compute_us.len() as u64
        }
    }

    /// Mean realized batch size.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let (p50, p95, p99, mean) = self.latency_summary_us();
        format!(
            "[{}] {} reqs in {} batches (mean size {:.2}) | latency us p50={} p95={} p99={} mean={} | compute/batch={}us",
            self.engine,
            self.completed,
            self.batches,
            self.mean_batch_size(),
            p50,
            p95,
            p99,
            mean,
            self.mean_compute_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::new("test");
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        let (p50, p95, p99, mean) = m.latency_summary_us();
        assert!((500..=600).contains(&p50), "{p50}");
        assert!(p95 >= 900, "{p95}");
        assert!(p99 >= 900, "{p99}");
        assert_eq!(mean, 550);
        assert_eq!(m.completed, 10);
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new("test");
        m.record_batch(4, Duration::from_micros(100));
        m.record_batch(2, Duration::from_micros(50));
        assert_eq!(m.batches, 2);
        assert_eq!(m.mean_batch_size(), 3.0);
        assert_eq!(m.batch_sizes[4], 1);
        assert_eq!(m.batch_sizes[2], 1);
        assert_eq!(m.mean_compute_us(), 75);
    }

    #[test]
    fn empty_metrics_do_not_panic() {
        let m = Metrics::new("test");
        assert_eq!(m.latency_summary_us(), (0, 0, 0, 0));
        assert_eq!(m.mean_batch_size(), 0.0);
        assert!(!m.summary().is_empty());
    }
}
