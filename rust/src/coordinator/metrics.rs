//! Serving metrics: latency distribution, batch-size distribution and
//! throughput, collected by the coordinator workers and exported by the
//! socket front end ([`crate::serve`]) on its `/metrics` endpoint.
//!
//! Latencies are recorded into a **fixed log-spaced histogram**
//! ([`LatencyHistogram`]) rather than a sample reservoir: memory is bounded
//! (one `u64` counter per bucket, ~4 KiB total) no matter how long the
//! server runs, every request is counted (the previous 100k-entry reservoir
//! silently stopped sampling once full, so long-running servers reported
//! stale percentiles), and histograms from different workers/models merge
//! by bucket-wise addition. The price is bucket-resolution percentiles:
//! with 8 sub-buckets per power of two the relative error of any reported
//! quantile is bounded by half a bucket width, ≤ ~6.7%.

use std::time::Duration;

/// Sub-bucket resolution: 2^3 = 8 log-spaced buckets per power of two.
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;
/// Bucket count covering the full `u64` microsecond range: values below
/// `SUB` get exact unit buckets, every octave above contributes `SUB`
/// buckets, up to exponent 63.
const BUCKETS: usize = ((64 - SUB_BITS as usize) << SUB_BITS as usize) + SUB as usize;

/// Index of the bucket holding `us`.
fn bucket_index(us: u64) -> usize {
    if us < SUB {
        return us as usize;
    }
    let e = 63 - us.leading_zeros(); // floor(log2 us), >= SUB_BITS
    let group = (e - SUB_BITS + 1) as usize;
    let sub = ((us >> (e - SUB_BITS)) & (SUB - 1)) as usize;
    (group << SUB_BITS) + sub
}

/// `[lo, hi)` microsecond bounds of bucket `i` (hi saturates at the top).
fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUB as usize {
        return (i as u64, i as u64 + 1);
    }
    let group = (i >> SUB_BITS as usize) as u32; // >= 1
    let sub = (i & (SUB as usize - 1)) as u64;
    let shift = group - 1;
    let lo = (SUB + sub) << shift;
    let hi = lo.saturating_add(1u64 << shift);
    (lo, hi)
}

/// Fixed-size log-spaced latency histogram (microsecond domain).
///
/// Bounded memory, no truncation, and mergeable across workers: unlike a
/// reservoir, two histograms recorded independently and then
/// [`merged`](Self::merge) are *exactly* the histogram of the combined
/// stream. Percentiles are reported as the midpoint of the covering
/// bucket, clamped to the observed maximum.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKETS], total: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one observation. Never saturates or drops.
    pub fn record(&mut self, us: u64) {
        self.counts[bucket_index(us)] += 1;
        self.total += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean in microseconds (0 when empty).
    pub fn mean_us(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.sum_us / self.total
        }
    }

    /// Largest recorded value in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// The `p`-quantile (`0.0 < p <= 1.0`) in microseconds: the midpoint of
    /// the bucket containing the ceil(p·n)-th smallest observation, clamped
    /// to the observed maximum. 0 when empty.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let (lo, hi) = bucket_bounds(i);
                let mid = lo + (hi - lo) / 2;
                return mid.min(self.max_us);
            }
        }
        self.max_us
    }

    /// Fold `other` into `self` (bucket-wise; exact, order-independent).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Aggregated serving statistics.
#[derive(Clone, Debug)]
pub struct Metrics {
    /// Engine or model label (e.g. `"int8"`, or a registry model name in
    /// multi-model serving).
    pub engine: String,
    pub completed: u64,
    pub batches: u64,
    /// Sum of batch sizes (== completed; kept for averaging convenience).
    pub batched_requests: u64,
    /// Request latency distribution (bounded log-spaced histogram).
    latency: LatencyHistogram,
    /// Engine compute time per batch: exact running sum (for the mean).
    compute_us_sum: u64,
    /// Batch size histogram indexed by size (0 unused).
    pub batch_sizes: Vec<u64>,
    /// Requests answered with a failure because their batch panicked
    /// (contained by the worker's `catch_unwind` → HTTP 500).
    pub failed: u64,
    /// Contained worker panics (one per panicking batch, however many
    /// requests rode in it).
    pub worker_panics: u64,
    /// Requests shed *before* execution because their deadline had already
    /// expired when a worker picked them up (→ HTTP 504).
    pub deadline_shed: u64,
}

impl Metrics {
    pub fn new(engine: impl Into<String>) -> Self {
        Self {
            engine: engine.into(),
            completed: 0,
            batches: 0,
            batched_requests: 0,
            latency: LatencyHistogram::new(),
            compute_us_sum: 0,
            batch_sizes: vec![0; 64],
            failed: 0,
            worker_panics: 0,
            deadline_shed: 0,
        }
    }

    /// Account one contained batch panic that failed `failed_requests`
    /// riders.
    pub fn record_panic(&mut self, failed_requests: usize) {
        self.worker_panics += 1;
        self.failed += failed_requests as u64;
    }

    /// Account `n` requests shed pre-execution on an expired deadline.
    pub fn record_deadline_shed(&mut self, n: usize) {
        self.deadline_shed += n as u64;
    }

    pub fn record_latency(&mut self, latency: Duration) {
        self.completed += 1;
        self.latency.record(latency.as_micros() as u64);
    }

    pub fn record_batch(&mut self, size: usize, compute: Duration) {
        self.batches += 1;
        self.batched_requests += size as u64;
        if size < self.batch_sizes.len() {
            self.batch_sizes[size] += 1;
        }
        self.compute_us_sum = self.compute_us_sum.saturating_add(compute.as_micros() as u64);
    }

    /// The request-latency `p`-quantile in microseconds.
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.latency.percentile_us(p)
    }

    /// Read-only view of the latency histogram (merging, export).
    pub fn latency_histogram(&self) -> &LatencyHistogram {
        &self.latency
    }

    /// (p50, p95, p99, mean) request latency in microseconds.
    pub fn latency_summary_us(&self) -> (u64, u64, u64, u64) {
        (
            self.latency.percentile_us(0.50),
            self.latency.percentile_us(0.95),
            self.latency.percentile_us(0.99),
            self.latency.mean_us(),
        )
    }

    /// Mean engine compute time per batch, microseconds.
    pub fn mean_compute_us(&self) -> u64 {
        if self.batches == 0 {
            0
        } else {
            self.compute_us_sum / self.batches
        }
    }

    /// Mean realized batch size.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Fold `other`'s counters into `self` (the label is kept): used by the
    /// metrics endpoint to produce fleet-wide aggregates from per-model
    /// metrics. Histograms merge exactly.
    pub fn merge(&mut self, other: &Metrics) {
        self.completed += other.completed;
        self.batches += other.batches;
        self.batched_requests += other.batched_requests;
        self.latency.merge(&other.latency);
        self.compute_us_sum = self.compute_us_sum.saturating_add(other.compute_us_sum);
        for (a, b) in self.batch_sizes.iter_mut().zip(&other.batch_sizes) {
            *a += b;
        }
        self.failed += other.failed;
        self.worker_panics += other.worker_panics;
        self.deadline_shed += other.deadline_shed;
    }

    /// One-line human summary. Robustness counters are appended only when
    /// nonzero, so the healthy-path line is unchanged.
    pub fn summary(&self) -> String {
        let (p50, p95, p99, mean) = self.latency_summary_us();
        let mut line = format!(
            "[{}] {} reqs in {} batches (mean size {:.2}) | latency us p50={} p95={} p99={} p999={} mean={} | compute/batch={}us",
            self.engine,
            self.completed,
            self.batches,
            self.mean_batch_size(),
            p50,
            p95,
            p99,
            self.latency.percentile_us(0.999),
            mean,
            self.mean_compute_us(),
        );
        if self.worker_panics > 0 || self.failed > 0 || self.deadline_shed > 0 {
            line.push_str(&format!(
                " | panics={} failed={} deadline_shed={}",
                self.worker_panics, self.failed, self.deadline_shed
            ));
        }
        line
    }

    /// Append this model's counters in Prometheus text exposition format,
    /// labelled `{model="<label>"}`. The serving front end concatenates one
    /// block per model plus a merged `{model="_all"}` aggregate, so the
    /// numbers visible in-process are byte-for-byte the numbers on the
    /// wire.
    pub fn prometheus_into(&self, label: &str, out: &mut String) {
        use std::fmt::Write;
        let l = label;
        let _ = writeln!(out, "iaoi_requests_completed_total{{model=\"{l}\"}} {}", self.completed);
        let _ = writeln!(out, "iaoi_batches_total{{model=\"{l}\"}} {}", self.batches);
        let _ = writeln!(out, "iaoi_mean_batch_size{{model=\"{l}\"}} {:.3}", self.mean_batch_size());
        let _ = writeln!(out, "iaoi_compute_us_per_batch{{model=\"{l}\"}} {}", self.mean_compute_us());
        for (q, label_q) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99"), (0.999, "0.999")] {
            let _ = writeln!(
                out,
                "iaoi_latency_us{{model=\"{l}\",quantile=\"{label_q}\"}} {}",
                self.latency.percentile_us(q)
            );
        }
        let _ = writeln!(out, "iaoi_latency_us_max{{model=\"{l}\"}} {}", self.latency.max_us());
        let _ = writeln!(out, "iaoi_latency_us_mean{{model=\"{l}\"}} {}", self.latency.mean_us());
        let _ = writeln!(out, "iaoi_latency_us_count{{model=\"{l}\"}} {}", self.latency.count());
        let _ = writeln!(out, "iaoi_requests_failed_total{{model=\"{l}\"}} {}", self.failed);
        let _ = writeln!(out, "iaoi_worker_panics_total{{model=\"{l}\"}} {}", self.worker_panics);
        let _ = writeln!(out, "iaoi_deadline_shed_total{{model=\"{l}\"}} {}", self.deadline_shed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_and_bounds_are_consistent() {
        // Every representative value must land in a bucket whose bounds
        // contain it, and bucket bounds must tile the line with no gaps.
        let mut prev_hi = 0u64;
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, prev_hi, "gap/overlap at bucket {i}");
            assert!(hi > lo || hi == u64::MAX, "empty bucket {i}");
            prev_hi = hi;
        }
        for v in [0u64, 1, 7, 8, 9, 15, 16, 100, 500, 1000, 123_456, u64::MAX / 2, u64::MAX] {
            let i = bucket_index(v);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && (v < hi || hi == u64::MAX), "{v} not in [{lo},{hi}) (bucket {i})");
        }
    }

    #[test]
    fn latency_percentiles() {
        let mut m = Metrics::new("test");
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        let (p50, p95, p99, mean) = m.latency_summary_us();
        // Log-bucket resolution: quantiles are bucket midpoints, within
        // ~7% of the exact order statistic (500 for p50, 1000 for p95/p99).
        assert!((460..=540).contains(&p50), "{p50}");
        assert!(p95 >= 930, "{p95}");
        assert!(p99 >= 930, "{p99}");
        assert_eq!(mean, 550, "mean is tracked exactly, not from buckets");
        assert_eq!(m.completed, 10);
        assert!(m.percentile_us(0.999) <= 1000, "clamped to observed max");
    }

    #[test]
    fn histogram_never_truncates() {
        // The old reservoir stopped sampling at 100k entries; the histogram
        // must keep counting and keep quantiles fresh.
        let mut h = LatencyHistogram::new();
        for _ in 0..150_000 {
            h.record(100);
        }
        // A late latency regime shift must be visible in the quantiles.
        for _ in 0..450_000 {
            h.record(10_000);
        }
        assert_eq!(h.count(), 600_000);
        let p50 = h.percentile_us(0.5);
        assert!(p50 >= 9_000, "late samples must dominate p50, got {p50}");
        let rel = (p50 as f64 - 10_000.0).abs() / 10_000.0;
        assert!(rel <= 0.07, "bucket resolution bound violated: p50={p50}");
    }

    #[test]
    fn quantiles_are_within_bucket_resolution() {
        let mut h = LatencyHistogram::new();
        // 1..=10_000 us uniformly: exact quantile q is ~q*10_000.
        for v in 1..=10_000u64 {
            h.record(v);
        }
        for (p, exact) in [(0.5, 5_000f64), (0.99, 9_900.0), (0.999, 9_990.0)] {
            let got = h.percentile_us(p) as f64;
            let rel = (got - exact).abs() / exact;
            assert!(rel <= 0.07, "p{p}: got {got}, exact {exact}, rel {rel}");
        }
    }

    #[test]
    fn merge_equals_recording_the_combined_stream() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for v in [3u64, 17, 250, 999, 12_345] {
            a.record(v);
            all.record(v);
        }
        for v in [1u64, 80, 80, 4_000, 7] {
            b.record(v);
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.mean_us(), all.mean_us());
        assert_eq!(a.max_us(), all.max_us());
        for p in [0.1, 0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.percentile_us(p), all.percentile_us(p), "p{p}");
        }
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new("test");
        m.record_batch(4, Duration::from_micros(100));
        m.record_batch(2, Duration::from_micros(50));
        assert_eq!(m.batches, 2);
        assert_eq!(m.mean_batch_size(), 3.0);
        assert_eq!(m.batch_sizes[4], 1);
        assert_eq!(m.batch_sizes[2], 1);
        assert_eq!(m.mean_compute_us(), 75);
    }

    #[test]
    fn metrics_merge_aggregates_models() {
        let mut a = Metrics::new("alpha");
        a.record_batch(2, Duration::from_micros(40));
        a.record_latency(Duration::from_micros(100));
        a.record_latency(Duration::from_micros(100));
        let mut b = Metrics::new("beta");
        b.record_batch(1, Duration::from_micros(20));
        b.record_latency(Duration::from_micros(300));
        a.merge(&b);
        assert_eq!(a.completed, 3);
        assert_eq!(a.batches, 3);
        assert_eq!(a.batched_requests, 3);
        assert_eq!(a.mean_compute_us(), 20);
        assert_eq!(a.latency_histogram().count(), 3);
        assert_eq!(a.engine, "alpha", "merge keeps the receiver's label");
    }

    #[test]
    fn robustness_counters_flow_through_merge_and_export() {
        let mut a = Metrics::new("alpha");
        a.record_panic(3);
        a.record_deadline_shed(2);
        let mut b = Metrics::new("beta");
        b.record_panic(1);
        a.merge(&b);
        assert_eq!((a.worker_panics, a.failed, a.deadline_shed), (2, 4, 2));
        let mut out = String::new();
        a.prometheus_into("alpha", &mut out);
        assert!(out.contains("iaoi_worker_panics_total{model=\"alpha\"} 2"));
        assert!(out.contains("iaoi_requests_failed_total{model=\"alpha\"} 4"));
        assert!(out.contains("iaoi_deadline_shed_total{model=\"alpha\"} 2"));
        assert!(a.summary().contains("panics=2 failed=4 deadline_shed=2"));
        // Healthy-path summary line is unchanged.
        assert!(!Metrics::new("x").summary().contains("panics="));
    }

    #[test]
    fn empty_metrics_do_not_panic() {
        let m = Metrics::new("test");
        assert_eq!(m.latency_summary_us(), (0, 0, 0, 0));
        assert_eq!(m.mean_batch_size(), 0.0);
        assert!(!m.summary().is_empty());
        let mut out = String::new();
        m.prometheus_into("test", &mut out);
        assert!(out.contains("iaoi_latency_us{model=\"test\",quantile=\"0.999\"} 0"));
    }

    #[test]
    fn prometheus_export_carries_the_in_process_numbers() {
        let mut m = Metrics::new("m");
        for us in [100u64, 200, 400] {
            m.record_latency(Duration::from_micros(us));
        }
        m.record_batch(3, Duration::from_micros(90));
        let mut out = String::new();
        m.prometheus_into("m", &mut out);
        assert!(out.contains("iaoi_requests_completed_total{model=\"m\"} 3"));
        assert!(out.contains("iaoi_batches_total{model=\"m\"} 1"));
        let p50_line = format!(
            "iaoi_latency_us{{model=\"m\",quantile=\"0.5\"}} {}",
            m.percentile_us(0.5)
        );
        assert!(out.contains(&p50_line), "{out}");
    }
}
