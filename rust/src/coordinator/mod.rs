//! Serving coordinator (the L3 system shape for an inference paper):
//! a request router feeding a dynamic batcher in front of engine workers
//! that run either the integer-only or the float graph.
//!
//! Python never appears here: the quantized graph is pure Rust
//! ([`crate::graph::QGraph`]), so the request hot path is
//! submit → batch → uint8 engine → reply.
//!
//! Architecture (std::thread + mpsc; this offline build has no tokio):
//!
//! ```text
//! clients ──▶ router (mpsc) ──▶ batcher thread ──▶ worker threads ──▶ reply
//!                               max_batch / max_delay        │
//!                               policy (§ vLLM-style)        └─▶ metrics
//! ```
//!
//! Invariants (property-tested in `tests/coordinator.rs`): every submitted
//! request completes exactly once; batch sizes lie in `[1, max_batch]`;
//! requests within a batch preserve submission order; shutdown drains the
//! queue.
//!
//! Two coordinator shapes share the batching policy:
//! * [`Coordinator`] — one engine, the original single-model pipeline;
//! * [`MultiCoordinator`] — a [`registry::ModelRegistry`] of named,
//!   versioned models with per-request routing. The batcher keys pending
//!   groups by model name, so **batches never mix models**, and
//!   [`registry::ModelRegistry::swap`] hot-swaps a model atomically while
//!   in-flight batches finish on the version they were formed against.
//!   [`registry::ModelRegistry::evict`] retires a model the same way:
//!   batches already holding an entry snapshot finish on it, queued
//!   requests whose model vanished are answered [`Outcome::Failed`]
//!   (version 0) rather than dropped, and new submits are refused at
//!   [`RoutedClient::submit`] while the eviction drains.

pub mod metrics;
pub mod registry;

use crate::graph::fault::FaultPlan;
use crate::graph::{ExecState, FloatGraph, PreparedGraph, QGraph};
use crate::sync::lock_recover;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use metrics::Metrics;
use registry::ModelRegistry;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Which engine the workers run.
#[derive(Clone)]
pub enum EngineKind {
    Float(Arc<FloatGraph>),
    Quant(Arc<QGraph>),
}

impl EngineKind {
    /// Human label for logs/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Float(_) => "float32",
            EngineKind::Quant(_) => "int8",
        }
    }
}

/// Split a stacked batch output into per-example rows.
fn split_rows(out: &Tensor<f32>, n: usize) -> Vec<Vec<f32>> {
    let per = out.len() / n;
    (0..n).map(|i| out.data()[i * per..(i + 1) * per].to_vec()).collect()
}

/// The per-worker execution engine. Quantized models run through a shared
/// prepared plan (weights packed once, at [`Coordinator::start`]) with a
/// worker-owned [`ExecState`], so the scratch arena persists across batches
/// and steady-state integer inference allocates nothing.
enum WorkerEngine {
    Float(Arc<FloatGraph>),
    Prepared { plan: Arc<PreparedGraph>, state: ExecState },
}

impl WorkerEngine {
    fn from_engine(
        engine: &EngineKind,
        plan: &Option<Arc<PreparedGraph>>,
        intra_pool: &Option<Arc<crate::gemm::WorkerPool>>,
    ) -> Self {
        match engine {
            EngineKind::Float(g) => WorkerEngine::Float(Arc::clone(g)),
            EngineKind::Quant(_) => {
                let mut state = ExecState::new();
                if let Some(pool) = intra_pool {
                    state.set_intra(crate::gemm::IntraOp::pool(
                        Arc::clone(pool),
                        crate::gemm::pool::DEFAULT_MIN_N,
                    ));
                }
                WorkerEngine::Prepared {
                    plan: Arc::clone(plan.as_ref().expect("quant engine has a plan")),
                    state,
                }
            }
        }
    }

    /// Run a stacked NHWC batch, returning per-example output rows.
    fn run_batch(&mut self, batch: &Tensor<f32>) -> Vec<Vec<f32>> {
        let n = batch.dim(0);
        match self {
            WorkerEngine::Float(g) => split_rows(&g.run(batch), n),
            WorkerEngine::Prepared { plan, state } => split_rows(&plan.run(batch, state), n),
        }
    }
}

/// How a request ended. Failure is a first-class outcome, not a dropped
/// reply: a panicking batch still answers every rider (the serving front
/// end maps `Failed` → HTTP 500, `Expired` → HTTP 504), so clients never
/// hang on a fault.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Output logits.
    Ok(Vec<f32>),
    /// The batch executing this request panicked; the panic was contained
    /// by the worker (`catch_unwind`) and the worker kept serving.
    Failed,
    /// The request's deadline had already expired when a worker picked it
    /// up; it was shed *before* execution, burning no compute.
    Expired,
}

impl Outcome {
    /// The output logits, if the request succeeded.
    pub fn ok(&self) -> Option<&[f32]> {
        match self {
            Outcome::Ok(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, Outcome::Ok(_))
    }
}

/// One inference request.
struct Request {
    id: u64,
    image: Tensor<f32>,
    submitted: Instant,
    /// Absolute completion deadline; a worker that picks this request up
    /// past it sheds it pre-execution ([`Outcome::Expired`]). `None` = no
    /// deadline (in-process callers that wait however long it takes).
    deadline: Option<Instant>,
    reply: mpsc::Sender<Response>,
}

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub outcome: Outcome,
    /// Queueing + batching + compute latency, end to end.
    pub latency: Duration,
    /// Size of the batch this request rode in (observability; 0 for
    /// requests shed before joining a batch execution).
    pub batch_size: usize,
}

impl Response {
    /// The output logits; panics unless the request succeeded (the
    /// closed-loop convenience for tests and examples — network-facing
    /// code matches on [`Self::outcome`] instead).
    pub fn output(&self) -> &[f32] {
        self.outcome.ok().expect("request did not succeed")
    }
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests fused into one engine call.
    pub max_batch: usize,
    /// Maximum time the head-of-line request may wait for co-riders.
    pub max_delay: Duration,
    /// GEMM output positions (`OH·OW` of the dominant conv layer) one
    /// example contributes to the GEMM's `N = batch·OH·OW` dimension.
    /// When set (> 1), full batches are capped at the largest size whose
    /// `N` lands on a multiple of the kernel's `NR` tile width, so no GEMM
    /// in the model pays a ragged tail column block on every full batch
    /// (see `rust/src/gemm/kernel.rs`). 0/1 disables the preference.
    ///
    /// [`Coordinator`] (single model) uses this value directly — the
    /// serving harness derives it from the loaded model's geometry
    /// ([`crate::graph::QGraph::dominant_positions`]). The multi-model
    /// batcher ignores it in favour of each entry's own
    /// [`registry::ModelEntry::positions_hint`], since resident models can
    /// have different geometries.
    pub positions_hint: usize,
    /// Intra-op GEMM parallelism degree (counting the batch worker itself).
    /// When > 1 the coordinator constructs **one** persistent
    /// [`crate::gemm::WorkerPool`] of this size, shared by every batch
    /// worker (and, in the multi-model pipeline, every resident model):
    /// large `N = batch·OH·OW` conv/FC GEMMs split across the pool while
    /// small layers stay serial. 1 (the default) keeps the fully serial,
    /// zero-alloc per-worker path. CLI: `iaoi serve --intra-threads N`.
    pub intra_threads: usize,
    /// Admission control (used by the socket front end, [`crate::serve`]):
    /// maximum requests in flight across **all** models before new arrivals
    /// are shed with a retry-after rejection instead of queueing. 0 (the
    /// default) means unbounded — in-process callers that already bound
    /// their own concurrency keep the old behavior. CLI:
    /// `iaoi serve --addr … --queue-depth N`.
    pub global_inflight_cap: usize,
    /// Per-model in-flight cap: one hot model saturating its cap cannot
    /// starve admission for the others. 0 (the default) = unbounded.
    /// CLI: `iaoi serve --addr … --model-inflight-cap N`.
    pub model_inflight_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            positions_hint: 1,
            intra_threads: 1,
            global_inflight_cap: 0,
            model_inflight_cap: 0,
        }
    }
}

impl BatchPolicy {
    /// The batch size full batches actually flush at: the largest
    /// `b ≤ max_batch` with `b · positions_hint` a multiple of `NR`, or
    /// `max_batch` when no such size exists (then alignment is
    /// unreachable and capping would only shrink batches for nothing).
    /// Deadline flushes still send whatever has accumulated.
    pub fn effective_max_batch(&self) -> usize {
        self.effective_max_batch_for(self.positions_hint)
    }

    /// [`Self::effective_max_batch`] under an explicit positions hint —
    /// the multi-model batcher calls this per group with the model's own
    /// geometry-derived hint.
    pub fn effective_max_batch_for(&self, positions_hint: usize) -> usize {
        if positions_hint <= 1 {
            // No geometry hint: the preference is disabled (capping on a
            // hint of 1 would shrink batches whenever max_batch >= NR for
            // no modeled benefit).
            return self.max_batch;
        }
        let nr = crate::gemm::kernel::NR;
        (1..=self.max_batch)
            .rev()
            .find(|b| (b * positions_hint) % nr == 0)
            .unwrap_or(self.max_batch)
    }

    /// The shared intra-op worker pool this policy asks for, if any.
    fn intra_pool(&self) -> Option<Arc<crate::gemm::WorkerPool>> {
        (self.intra_threads > 1).then(|| Arc::new(crate::gemm::WorkerPool::new(self.intra_threads)))
    }
}

/// Handle for submitting requests; cloneable across client threads. The
/// sender is revocable: [`Coordinator::shutdown`] nulls it out so live
/// clones turn into polite errors instead of keeping the batcher alive.
#[derive(Clone)]
pub struct Client {
    tx: Arc<Mutex<Option<mpsc::Sender<Request>>>>,
    next_id: Arc<AtomicU64>,
}

impl Client {
    /// Submit one image; returns a receiver for the response.
    pub fn submit(&self, image: Tensor<f32>) -> Result<(u64, mpsc::Receiver<Response>)> {
        self.submit_with_deadline(image, None)
    }

    /// [`Self::submit`] with an absolute completion deadline: if a worker
    /// picks the request up past `deadline`, it is shed pre-execution and
    /// answered [`Outcome::Expired`].
    pub fn submit_with_deadline(
        &self,
        image: Tensor<f32>,
        deadline: Option<Instant>,
    ) -> Result<(u64, mpsc::Receiver<Response>)> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let guard = lock_recover(&self.tx);
        let tx = guard.as_ref().ok_or_else(|| anyhow!("coordinator is shut down"))?;
        tx.send(Request { id, image, submitted: Instant::now(), deadline, reply: reply_tx })
            .map_err(|_| anyhow!("coordinator is shut down"))?;
        Ok((id, reply_rx))
    }

    /// Submit and wait (convenience for closed-loop clients).
    pub fn infer(&self, image: Tensor<f32>) -> Result<Response> {
        let (_, rx) = self.submit(image)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }
}

/// The running coordinator: batcher + worker threads.
pub struct Coordinator {
    client: Client,
    metrics: Arc<Mutex<Metrics>>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the coordinator with `workers` engine threads.
    pub fn start(engine: EngineKind, policy: BatchPolicy, workers: usize) -> Self {
        assert!(workers >= 1 && policy.max_batch >= 1);
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let metrics = Arc::new(Mutex::new(Metrics::new(engine.label())));
        // Pack-once: build the prepared plan at startup, shared read-only by
        // every worker; each worker owns its ExecState across batches.
        // IAOI_FAULT without a model filter also applies here, so the
        // single-model pipeline is chaos-testable end to end.
        let plan: Option<Arc<PreparedGraph>> = match &engine {
            EngineKind::Quant(g) => {
                let mut p = g.prepare();
                if let Some(f) = FaultPlan::from_env().filter(|f| f.model.is_none()) {
                    p.set_fault(f);
                }
                Some(Arc::new(p))
            }
            EngineKind::Float(_) => None,
        };
        // One persistent intra-op pool shared by every batch worker; only
        // the quantized engine routes GEMMs through it.
        let intra_pool = match &engine {
            EngineKind::Quant(_) => policy.intra_pool(),
            EngineKind::Float(_) => None,
        };

        // Batcher: pull the head request, then co-batch whatever arrives
        // within max_delay, up to the NR-aligned effective max batch.
        let batcher = std::thread::spawn(move || {
            let flush_at = policy.effective_max_batch();
            while let Ok(head) = req_rx.recv() {
                let deadline = Instant::now() + policy.max_delay;
                let mut batch = vec![head];
                while batch.len() < flush_at {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match req_rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            let _ = batch_tx.send(batch);
                            return;
                        }
                    }
                }
                if batch_tx.send(batch).is_err() {
                    return;
                }
            }
        });

        // Workers: execute batches, reply per request, record metrics.
        // Execution is fault-contained: expired requests are shed before
        // the engine runs, and a panicking batch is caught so every rider
        // still gets a (failed) reply and the worker keeps serving.
        let mut worker_handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let engine = engine.clone();
            let plan = plan.clone();
            let intra_pool = intra_pool.clone();
            let batch_rx = Arc::clone(&batch_rx);
            let metrics = Arc::clone(&metrics);
            worker_handles.push(std::thread::spawn(move || {
                let mut worker_engine = WorkerEngine::from_engine(&engine, &plan, &intra_pool);
                loop {
                    let batch = {
                        let guard = lock_recover(&batch_rx);
                        guard.recv()
                    };
                    let Ok(batch) = batch else { return };
                    // Deadline shed, pre-execution: answers nobody is
                    // waiting for anymore must not burn engine time.
                    let now = Instant::now();
                    let (batch, expired): (Vec<Request>, Vec<Request>) =
                        batch.into_iter().partition(|r| r.deadline.is_none_or(|d| now < d));
                    if !expired.is_empty() {
                        lock_recover(&metrics).record_deadline_shed(expired.len());
                        for r in expired {
                            let _ = r.reply.send(Response {
                                id: r.id,
                                outcome: Outcome::Expired,
                                latency: now - r.submitted,
                                batch_size: 0,
                            });
                        }
                    }
                    if batch.is_empty() {
                        continue;
                    }
                    let size = batch.len();
                    // Stack images into one NHWC tensor.
                    let mut shape = batch[0].image.shape().to_vec();
                    shape[0] = size;
                    let per = batch[0].image.len();
                    let mut stacked = vec![0f32; per * size];
                    for (i, r) in batch.iter().enumerate() {
                        stacked[i * per..(i + 1) * per].copy_from_slice(r.image.data());
                    }
                    let compute_start = Instant::now();
                    // Containment boundary: the worker owns its engine
                    // state, so unwinding cannot leave anyone else holding
                    // a broken invariant (AssertUnwindSafe is sound here —
                    // the state is rebuilt below before reuse).
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        worker_engine.run_batch(&Tensor::from_vec(&shape, stacked))
                    }));
                    let compute = compute_start.elapsed();
                    let now = Instant::now();
                    match result {
                        Ok(rows) => {
                            {
                                let mut m = lock_recover(&metrics);
                                m.record_batch(size, compute);
                                for r in &batch {
                                    m.record_latency(now - r.submitted);
                                }
                            }
                            for (r, output) in batch.into_iter().zip(rows) {
                                let latency = now - r.submitted;
                                // Receiver may have gone away; dropping is fine.
                                let _ = r.reply.send(Response {
                                    id: r.id,
                                    outcome: Outcome::Ok(output),
                                    latency,
                                    batch_size: size,
                                });
                            }
                        }
                        Err(_) => {
                            lock_recover(&metrics).record_panic(size);
                            for r in batch {
                                let _ = r.reply.send(Response {
                                    id: r.id,
                                    outcome: Outcome::Failed,
                                    latency: now - r.submitted,
                                    batch_size: size,
                                });
                            }
                            // The unwound run may have left scratch/output
                            // slots half-written; rebuild the engine state
                            // before the next batch.
                            worker_engine =
                                WorkerEngine::from_engine(&engine, &plan, &intra_pool);
                        }
                    }
                }
            }));
        }

        Self {
            client: Client {
                tx: Arc::new(Mutex::new(Some(req_tx))),
                next_id: Arc::new(AtomicU64::new(0)),
            },
            metrics,
            batcher: Some(batcher),
            workers: worker_handles,
        }
    }

    /// A cloneable submission handle.
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Snapshot of the metrics so far.
    pub fn metrics(&self) -> Metrics {
        lock_recover(&self.metrics).clone()
    }

    /// Drain and stop: all already-submitted requests complete first.
    pub fn shutdown(mut self) -> Metrics {
        // Revoke the sender (this also disarms every Client clone); the
        // batcher sees the disconnect and drains, whose sender-drop ends
        // the workers.
        lock_recover(&self.client.tx).take();
        drop(self.client);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        lock_recover(&self.metrics).clone()
    }
}

/// One routed inference request (multi-model pipeline).
struct RoutedRequest {
    id: u64,
    model: String,
    /// The target model's geometry-derived `positions_hint`, snapshotted at
    /// submit time (the client already resolves the entry to validate the
    /// input shape). The batcher uses it to compute the model's NR-aligned
    /// flush size without ever touching the registry itself.
    positions: usize,
    image: Tensor<f32>,
    submitted: Instant,
    /// Absolute completion deadline (see [`Request::deadline`]).
    deadline: Option<Instant>,
    reply: mpsc::Sender<RoutedResponse>,
}

/// A completed routed inference, echoing which model *version* served it —
/// the observable a hot-swap test (or a canary dashboard) keys on.
#[derive(Clone, Debug)]
pub struct RoutedResponse {
    pub id: u64,
    pub model: String,
    /// Registry version of the entry that executed (or, for failed/expired
    /// requests, would have executed) the batch.
    pub version: u32,
    pub outcome: Outcome,
    pub latency: Duration,
    pub batch_size: usize,
}

impl RoutedResponse {
    /// The output logits; panics unless the request succeeded (closed-loop
    /// convenience — network-facing code matches on [`Self::outcome`]).
    pub fn output(&self) -> &[f32] {
        self.outcome.ok().expect("request did not succeed")
    }
}

/// Cloneable submission handle for the multi-model coordinator.
#[derive(Clone)]
pub struct RoutedClient {
    tx: Arc<Mutex<Option<mpsc::Sender<RoutedRequest>>>>,
    next_id: Arc<AtomicU64>,
    registry: ModelRegistry,
}

impl RoutedClient {
    /// Submit one image to the named model; returns a receiver for the
    /// response. Routing and shape errors surface here, before the request
    /// enters the queue.
    pub fn submit(
        &self,
        model: &str,
        image: Tensor<f32>,
    ) -> Result<(u64, mpsc::Receiver<RoutedResponse>)> {
        self.submit_with_deadline(model, image, None)
    }

    /// [`Self::submit`] with an absolute completion deadline: a worker
    /// that picks the request up past it sheds it pre-execution and
    /// answers [`Outcome::Expired`].
    pub fn submit_with_deadline(
        &self,
        model: &str,
        image: Tensor<f32>,
        deadline: Option<Instant>,
    ) -> Result<(u64, mpsc::Receiver<RoutedResponse>)> {
        let entry = self.registry.resolve(model)?;
        let want = entry.batched_shape(1);
        if image.shape() != &want[..] {
            bail!(
                "model {model:?} expects input shape {want:?}, got {:?}",
                image.shape()
            );
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let guard = lock_recover(&self.tx);
        let tx = guard.as_ref().ok_or_else(|| anyhow!("coordinator is shut down"))?;
        tx.send(RoutedRequest {
            id,
            model: model.to_string(),
            positions: entry.positions_hint,
            image,
            submitted: Instant::now(),
            deadline,
            reply: reply_tx,
        })
        .map_err(|_| anyhow!("coordinator is shut down"))?;
        Ok((id, reply_rx))
    }

    /// Submit and wait (closed-loop convenience).
    pub fn infer(&self, model: &str, image: Tensor<f32>) -> Result<RoutedResponse> {
        let (_, rx) = self.submit(model, image)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }

    /// [`Self::infer`] under a deadline.
    pub fn infer_with_deadline(
        &self,
        model: &str,
        image: Tensor<f32>,
        deadline: Option<Instant>,
    ) -> Result<RoutedResponse> {
        let (_, rx) = self.submit_with_deadline(model, image, deadline)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }
}

/// A pending same-model batch accumulating co-riders.
struct PendingGroup {
    since: Instant,
    /// This model's NR-aligned full-batch size
    /// ([`BatchPolicy::effective_max_batch_for`] under the model's own
    /// geometry hint), fixed when the group forms.
    flush_at: usize,
    reqs: Vec<RoutedRequest>,
}

/// Multi-model serving coordinator: per-request model routing over a shared
/// [`ModelRegistry`], with the same dynamic-batching policy as
/// [`Coordinator`] applied **per model**.
pub struct MultiCoordinator {
    client: RoutedClient,
    registry: ModelRegistry,
    metrics: Arc<Mutex<HashMap<String, Metrics>>>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl MultiCoordinator {
    /// Start serving every model in `registry` with `workers` engine
    /// threads. The registry handle stays live: `swap` on any clone of it
    /// hot-swaps models under this coordinator without a restart.
    pub fn start(registry: ModelRegistry, policy: BatchPolicy, workers: usize) -> Self {
        assert!(workers >= 1 && policy.max_batch >= 1);
        let (req_tx, req_rx) = mpsc::channel::<RoutedRequest>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<RoutedRequest>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let metrics: Arc<Mutex<HashMap<String, Metrics>>> = Arc::new(Mutex::new(HashMap::new()));

        // Batcher: groups are keyed by model name, so a batch can only ever
        // hold one model's requests. Each group flushes when it reaches its
        // model's NR-aligned effective max batch (per-model geometry hint,
        // carried on the requests) or its head has waited max_delay.
        let batcher = std::thread::spawn(move || {
            let mut pending: HashMap<String, PendingGroup> = HashMap::new();
            let mut disconnected = false;
            while !disconnected || !pending.is_empty() {
                let now = Instant::now();
                let due: Vec<String> = pending
                    .iter()
                    .filter(|(_, g)| {
                        disconnected
                            || g.reqs.len() >= g.flush_at
                            || now.duration_since(g.since) >= policy.max_delay
                    })
                    .map(|(k, _)| k.clone())
                    .collect();
                for key in due {
                    if let Some(group) = pending.remove(&key) {
                        if batch_tx.send(group.reqs).is_err() {
                            return;
                        }
                    }
                }
                if disconnected {
                    continue; // drain remaining groups, then exit
                }
                let next_deadline = pending.values().map(|g| g.since + policy.max_delay).min();
                let received = match next_deadline {
                    None => match req_rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => None,
                    },
                    Some(deadline) => {
                        let now = Instant::now();
                        if deadline <= now {
                            continue;
                        }
                        match req_rx.recv_timeout(deadline - now) {
                            Ok(r) => Some(r),
                            Err(mpsc::RecvTimeoutError::Timeout) => continue,
                            Err(mpsc::RecvTimeoutError::Disconnected) => None,
                        }
                    }
                };
                match received {
                    Some(r) => {
                        let flush_at = policy.effective_max_batch_for(r.positions);
                        pending
                            .entry(r.model.clone())
                            .or_insert_with(|| PendingGroup {
                                since: Instant::now(),
                                flush_at,
                                reqs: Vec::new(),
                            })
                            .reqs
                            .push(r);
                    }
                    None => disconnected = true,
                }
            }
        });

        // Workers: snapshot the model entry once per batch — a concurrent
        // swap cannot change the graph under a running batch, and the
        // response echoes the snapshot's version. Each worker owns one
        // ExecState for its lifetime: the scratch buffers are
        // shape-agnostic, so one arena serves every resident model across
        // batches without reallocation once warmed up.
        let intra_pool = policy.intra_pool();
        let mut worker_handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let batch_rx = Arc::clone(&batch_rx);
            let metrics = Arc::clone(&metrics);
            let registry = registry.clone();
            let intra_pool = intra_pool.clone();
            let new_state = move |pool: &Option<Arc<crate::gemm::WorkerPool>>| {
                let mut state = ExecState::new();
                if let Some(pool) = pool {
                    // Every resident (and future hot-swapped) model's large
                    // GEMMs share this one pool through the worker's state.
                    state.set_intra(crate::gemm::IntraOp::pool(
                        Arc::clone(pool),
                        crate::gemm::pool::DEFAULT_MIN_N,
                    ));
                }
                state
            };
            worker_handles.push(std::thread::spawn(move || {
                let mut state = new_state(&intra_pool);
                loop {
                    let batch = {
                        let guard = lock_recover(&batch_rx);
                        guard.recv()
                    };
                    let Ok(batch) = batch else { return };
                    let model_name = batch[0].model.clone();
                    debug_assert!(
                        batch.iter().all(|r| r.model == model_name),
                        "batcher must never mix models in one batch"
                    );
                    // Eviction can remove a model while its requests are
                    // still queued. Every rider must still get a reply —
                    // answer Failed (version 0: no entry executed) instead
                    // of silently dropping the batch.
                    let Some(entry) = registry.get(&model_name) else {
                        let now = Instant::now();
                        let size = batch.len();
                        {
                            let mut m = lock_recover(&metrics);
                            m.entry(model_name.clone())
                                .or_insert_with(|| Metrics::new(model_name.clone()))
                                .failed += size as u64;
                        }
                        for r in batch {
                            let _ = r.reply.send(RoutedResponse {
                                id: r.id,
                                model: r.model,
                                version: 0,
                                outcome: Outcome::Failed,
                                latency: now - r.submitted,
                                batch_size: 0,
                            });
                        }
                        continue;
                    };

                    // Deadline shed, pre-execution.
                    let now = Instant::now();
                    let (batch, expired): (Vec<RoutedRequest>, Vec<RoutedRequest>) =
                        batch.into_iter().partition(|r| r.deadline.is_none_or(|d| now < d));
                    if !expired.is_empty() {
                        lock_recover(&metrics)
                            .entry(model_name.clone())
                            .or_insert_with(|| Metrics::new(model_name.clone()))
                            .record_deadline_shed(expired.len());
                        for r in expired {
                            let _ = r.reply.send(RoutedResponse {
                                id: r.id,
                                model: r.model,
                                version: entry.version,
                                outcome: Outcome::Expired,
                                latency: now - r.submitted,
                                batch_size: 0,
                            });
                        }
                    }
                    if batch.is_empty() {
                        continue;
                    }
                    let size = batch.len();

                    // Quarantined models are fenced at admission (the front
                    // end answers 503 without enqueueing), but requests
                    // already queued when the breaker tripped land here:
                    // fail them without executing, so a quarantined model
                    // burns no further compute and cannot panic again.
                    if registry.is_quarantined(&model_name) {
                        {
                            let mut m = lock_recover(&metrics);
                            m.entry(model_name.clone())
                                .or_insert_with(|| Metrics::new(model_name.clone()))
                                .failed += size as u64;
                        }
                        for r in batch {
                            let _ = r.reply.send(RoutedResponse {
                                id: r.id,
                                model: r.model,
                                version: entry.version,
                                outcome: Outcome::Failed,
                                latency: now - r.submitted,
                                batch_size: 0,
                            });
                        }
                        continue;
                    }

                    let mut shape = batch[0].image.shape().to_vec();
                    shape[0] = size;
                    let per = batch[0].image.len();
                    let mut stacked = vec![0f32; per * size];
                    for (i, r) in batch.iter().enumerate() {
                        stacked[i * per..(i + 1) * per].copy_from_slice(r.image.data());
                    }
                    let compute_start = Instant::now();
                    // Containment boundary: state is worker-owned and
                    // rebuilt below on unwind, so AssertUnwindSafe is sound.
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        let out = entry.plan.run(&Tensor::from_vec(&shape, stacked), &mut state);
                        split_rows(&out, size)
                    }));
                    let compute = compute_start.elapsed();
                    let now = Instant::now();
                    match result {
                        Ok(rows) => {
                            {
                                let mut m = lock_recover(&metrics);
                                let m = m
                                    .entry(model_name.clone())
                                    .or_insert_with(|| Metrics::new(model_name.clone()));
                                m.record_batch(size, compute);
                                for r in &batch {
                                    m.record_latency(now - r.submitted);
                                }
                            }
                            for (r, output) in batch.into_iter().zip(rows) {
                                let latency = now - r.submitted;
                                let _ = r.reply.send(RoutedResponse {
                                    id: r.id,
                                    model: r.model,
                                    version: entry.version,
                                    outcome: Outcome::Ok(output),
                                    latency,
                                    batch_size: size,
                                });
                            }
                        }
                        Err(_) => {
                            // Feed the circuit breaker *before* replying,
                            // so a client that just saw the K-th failure
                            // deterministically finds the model quarantined.
                            registry.record_panic(&model_name);
                            lock_recover(&metrics)
                                .entry(model_name.clone())
                                .or_insert_with(|| Metrics::new(model_name.clone()))
                                .record_panic(size);
                            for r in batch {
                                let _ = r.reply.send(RoutedResponse {
                                    id: r.id,
                                    model: r.model,
                                    version: entry.version,
                                    outcome: Outcome::Failed,
                                    latency: now - r.submitted,
                                    batch_size: size,
                                });
                            }
                            // The unwound run may have left the scratch
                            // arena half-written; rebuild it.
                            state = new_state(&intra_pool);
                        }
                    }
                }
            }));
        }

        Self {
            client: RoutedClient {
                tx: Arc::new(Mutex::new(Some(req_tx))),
                next_id: Arc::new(AtomicU64::new(0)),
                registry: registry.clone(),
            },
            registry,
            metrics,
            batcher: Some(batcher),
            workers: worker_handles,
        }
    }

    /// A cloneable routed submission handle.
    pub fn client(&self) -> RoutedClient {
        self.client.clone()
    }

    /// The shared registry handle (for hot-swapping while serving).
    pub fn registry(&self) -> ModelRegistry {
        self.registry.clone()
    }

    /// Snapshot of per-model metrics, sorted by model name.
    pub fn metrics(&self) -> Vec<Metrics> {
        let guard = lock_recover(&self.metrics);
        let mut out: Vec<Metrics> = guard.values().cloned().collect();
        out.sort_by(|a, b| a.engine.cmp(&b.engine));
        out
    }

    /// The live per-model metrics map, shared with the workers. The socket
    /// front end ([`crate::serve`]) holds this so its `/metrics` endpoint
    /// can export the same counters the workers are updating, without
    /// keeping a reference to the whole coordinator.
    pub fn metrics_handle(&self) -> Arc<Mutex<HashMap<String, Metrics>>> {
        Arc::clone(&self.metrics)
    }

    /// Drain and stop; every already-submitted request completes first.
    pub fn shutdown(mut self) -> Vec<Metrics> {
        // Taking the sender disarms every RoutedClient clone (they share the
        // Option) and disconnects the batcher, which drains and exits.
        lock_recover(&self.client.tx).take();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builders;
    use crate::nn::FusedActivation;
    use crate::quantize::{quantize_graph, QuantizeOptions};

    fn tiny_quant_engine() -> EngineKind {
        let g = builders::papernet_random(4, FusedActivation::Relu6, 5);
        let mut rng = crate::data::Rng::seeded(5);
        let batches: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; 2 * 16 * 16 * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[2, 16, 16, 3], d)
            })
            .collect();
        let (_, q) = quantize_graph(&g, &batches, QuantizeOptions::default());
        EngineKind::Quant(Arc::new(q))
    }

    fn image(seed: u64) -> Tensor<f32> {
        let mut rng = crate::data::Rng::seeded(seed);
        let mut d = vec![0f32; 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        Tensor::from_vec(&[1, 16, 16, 3], d)
    }

    #[test]
    fn all_requests_complete_exactly_once() {
        let coord = Coordinator::start(tiny_quant_engine(), BatchPolicy::default(), 2);
        let client = coord.client();
        let receivers: Vec<_> = (0..20).map(|i| client.submit(image(i)).unwrap()).collect();
        let mut ids: Vec<u64> = receivers
            .into_iter()
            .map(|(id, rx)| {
                let resp = rx.recv().expect("response");
                assert_eq!(resp.id, id);
                assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                assert_eq!(resp.output().len(), 4);
                id
            })
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20, "every id exactly once");
        let m = coord.shutdown();
        assert_eq!(m.completed, 20);
    }

    #[test]
    fn batching_fuses_bursts() {
        let policy = BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(50), ..Default::default() };
        let coord = Coordinator::start(tiny_quant_engine(), policy, 1);
        let client = coord.client();
        let receivers: Vec<_> = (0..8).map(|i| client.submit(image(i)).unwrap()).collect();
        let sizes: Vec<usize> =
            receivers.into_iter().map(|(_, rx)| rx.recv().unwrap().batch_size).collect();
        // A synchronous burst of 8 with a generous window must produce at
        // least one multi-request batch.
        assert!(sizes.iter().any(|&s| s > 1), "sizes {sizes:?}");
        coord.shutdown();
    }

    #[test]
    fn effective_max_batch_prefers_nr_aligned_sizes() {
        let nr = crate::gemm::kernel::NR;
        // No hint (or hint 1 with max_batch < NR): no aligned size exists,
        // fall back to max_batch — the pre-hint behavior.
        let p = BatchPolicy::default();
        assert_eq!(p.effective_max_batch(), p.max_batch);
        // hint 4, NR 16: aligned sizes are multiples of 4; 10 → 8.
        let p = BatchPolicy { max_batch: 10, positions_hint: 4, ..Default::default() };
        assert_eq!(p.effective_max_batch(), 8);
        // Already aligned max_batch is kept.
        let p = BatchPolicy { max_batch: 12, positions_hint: 4, ..Default::default() };
        assert_eq!(p.effective_max_batch(), 12);
        // hint 0/1 disables the preference entirely, even above NR.
        let p = BatchPolicy { max_batch: nr + 4, positions_hint: 1, ..Default::default() };
        assert_eq!(p.effective_max_batch(), nr + 4);
        let p = BatchPolicy { max_batch: nr + 4, positions_hint: 0, ..Default::default() };
        assert_eq!(p.effective_max_batch(), nr + 4);
        // hint larger than NR but sharing a factor: 24·2 = 48 = 3·16.
        let p = BatchPolicy { max_batch: 3, positions_hint: 24, ..Default::default() };
        assert_eq!(p.effective_max_batch(), 2);
    }

    #[test]
    fn batcher_caps_full_batches_at_the_aligned_size() {
        // positions_hint 4 with max_batch 10 → full batches flush at 8.
        let policy = BatchPolicy {
            max_batch: 10,
            max_delay: Duration::from_millis(100),
            positions_hint: 4,
            ..Default::default()
        };
        let coord = Coordinator::start(tiny_quant_engine(), policy, 1);
        let client = coord.client();
        let receivers: Vec<_> = (0..16).map(|i| client.submit(image(i)).unwrap()).collect();
        let sizes: Vec<usize> =
            receivers.into_iter().map(|(_, rx)| rx.recv().unwrap().batch_size).collect();
        assert!(
            sizes.iter().all(|&s| s <= 8),
            "full batches must cap at the NR-aligned size, got {sizes:?}"
        );
        assert!(sizes.iter().any(|&s| s > 1), "burst should co-batch, got {sizes:?}");
        coord.shutdown();
    }

    #[test]
    fn intra_pool_serving_matches_serial_serving_bit_for_bit() {
        // --intra-threads > 1 only changes who computes each GEMM strip:
        // responses must be byte-identical to the serial coordinator's.
        let eng = tiny_quant_engine();
        let imgs: Vec<Tensor<f32>> = (0..6).map(|i| image(40 + i)).collect();
        let serial = Coordinator::start(eng.clone(), BatchPolicy::default(), 1);
        let want: Vec<Vec<f32>> =
            imgs.iter().map(|x| serial.client().infer(x.clone()).unwrap().output().to_vec()).collect();
        serial.shutdown();

        let policy = BatchPolicy { intra_threads: 3, ..Default::default() };
        let coord = Coordinator::start(eng, policy, 2);
        let client = coord.client();
        let pending: Vec<_> = imgs.iter().map(|x| client.submit(x.clone()).unwrap()).collect();
        for ((id, rx), want) in pending.into_iter().zip(&want) {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            assert_eq!(resp.output(), want.as_slice(), "pooled output diverged");
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 6);
    }

    #[test]
    fn shutdown_drains_inflight_requests() {
        let coord = Coordinator::start(tiny_quant_engine(), BatchPolicy::default(), 1);
        let client = coord.client();
        let pending: Vec<_> = (0..5).map(|i| client.submit(image(i)).unwrap()).collect();
        let metrics = coord.shutdown();
        assert_eq!(metrics.completed, 5);
        for (_, rx) in pending {
            assert!(rx.recv().is_ok(), "request must complete before shutdown");
        }
    }

    #[test]
    fn float_engine_works_too() {
        let g = builders::papernet_random(4, FusedActivation::Relu6, 6);
        let coord = Coordinator::start(
            EngineKind::Float(Arc::new(g)),
            BatchPolicy::default(),
            1,
        );
        let resp = coord.client().infer(image(1)).unwrap();
        assert_eq!(resp.output().len(), 4);
        coord.shutdown();
    }
}
