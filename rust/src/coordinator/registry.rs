//! Multi-model registry: named, versioned quantized models loaded from
//! `.iaoiq` artifacts ([`crate::model_format`]), shared between the router,
//! the batcher, and the workers, with **atomic hot-swap**.
//!
//! Swap semantics: [`ModelRegistry::swap`] decodes the new artifact fully
//! *before* touching the table, then replaces the entry under a single
//! write-lock — readers either see the old model or the new one, never a
//! partial state. Workers snapshot an `Arc<ModelEntry>` when they pick up a
//! batch, so requests already in flight finish on the model they were
//! batched against and nothing is dropped mid-swap.
//!
//! The registry also hosts the per-model **circuit breaker**
//! ([`QuarantineConfig`]): coordinator workers report contained batch
//! panics here, and once a model accumulates the configured number of
//! panics inside the sliding window it is *quarantined* — the serving
//! front end refuses its traffic with 503 `{"error":"quarantined"}` and
//! workers stop executing its batches, so one faulty artifact cannot keep
//! burning compute or poisoning latency for its neighbours. A successful
//! [`ModelRegistry::swap`] (the operator shipping a fixed artifact) or an
//! explicit [`ModelRegistry::reset_quarantine`] re-admits the model.

use crate::graph::fault::FaultPlan;
use crate::graph::{PreparedGraph, QGraph};
use crate::model_format::{self, LoadMode, ModelArtifact};
use crate::sync::{lock_recover, read_recover, write_recover};
use crate::tensor::ArtifactBytes;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// One resident model: immutable once registered (swaps replace the whole
/// entry).
#[derive(Debug)]
pub struct ModelEntry {
    pub name: String,
    pub version: u32,
    /// Shape `[H, W, C]` of one input example.
    pub input_shape: [usize; 3],
    pub graph: Arc<QGraph>,
    /// The prepared execution plan (weights packed, output stages built),
    /// constructed once at install/load time so no worker ever pays the
    /// weight-side cost per request. Workers share it read-only, each with
    /// its own [`crate::graph::ExecState`].
    pub plan: Arc<PreparedGraph>,
    /// `OH·OW` of this model's dominant conv layer
    /// ([`QGraph::dominant_positions`]), derived from the artifact geometry
    /// at install time. The multi-model batcher uses it as the per-model
    /// `positions_hint`, so NR-aligned batch capping engages with each
    /// model's real geometry — models in one registry can differ.
    pub positions_hint: usize,
    /// Artifact path the entry was loaded from (empty for in-memory
    /// registrations).
    pub source: PathBuf,
    /// Backing buffer the graph's zero-copy weight views borrow from
    /// (`None` for copy-mode loads and in-memory registrations). The views
    /// inside [`Self::graph`] keep the buffer alive on their own; pinning
    /// it on the entry makes the dependency explicit and observable.
    pub backing: Option<ArtifactBytes>,
}

impl ModelEntry {
    /// The batched NHWC input shape for a batch of `n`.
    pub fn batched_shape(&self, n: usize) -> [usize; 4] {
        [n, self.input_shape[0], self.input_shape[1], self.input_shape[2]]
    }

    /// True when this entry's weights borrow a live file mapping.
    pub fn is_mapped(&self) -> bool {
        self.backing.as_ref().is_some_and(ArtifactBytes::is_mapped)
    }
}

/// Circuit-breaker policy: `threshold` contained panics within `window`
/// quarantine a model. `threshold == 0` disables quarantine entirely
/// (panics are still counted and exported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuarantineConfig {
    pub threshold: u32,
    pub window: Duration,
}

impl Default for QuarantineConfig {
    fn default() -> Self {
        // Three strikes in 30s: tight enough that a deterministically
        // crashing artifact is fenced within its first few batches, loose
        // enough that an isolated cosmic-ray panic doesn't take a healthy
        // model out of rotation.
        Self { threshold: 3, window: Duration::from_secs(30) }
    }
}

/// Per-model breaker bookkeeping.
#[derive(Debug, Default)]
struct BreakerEntry {
    /// Panic timestamps inside the sliding window (cleared on trip/reset).
    recent: VecDeque<Instant>,
    /// Lifetime panic count — survives quarantine resets and swaps, so
    /// `/healthz` keeps the model's full history visible.
    total: u64,
    quarantined: bool,
}

#[derive(Debug, Default)]
struct Breaker {
    cfg: QuarantineConfig,
    models: HashMap<String, BreakerEntry>,
}

/// Cloneable handle to the shared name → model table.
#[derive(Clone, Default)]
pub struct ModelRegistry {
    inner: Arc<RwLock<HashMap<String, Arc<ModelEntry>>>>,
    breaker: Arc<Mutex<Breaker>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Load every `*.iaoiq` artifact in `dir` under the environment-default
    /// [`LoadMode`]. Files are visited in sorted order; when several
    /// artifacts carry the same model name, the highest version wins (ties
    /// broken by file order).
    pub fn load_dir(dir: &Path) -> Result<Self> {
        Self::load_dir_with(dir, LoadMode::from_env())
    }

    /// [`Self::load_dir`] with an explicit weight-storage mode (the
    /// `iaoi serve --load` knob).
    pub fn load_dir_with(dir: &Path, mode: LoadMode) -> Result<Self> {
        let registry = Self::new();
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("read model directory {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().and_then(|s| s.to_str()) == Some(model_format::EXTENSION))
            .collect();
        paths.sort();
        if paths.is_empty() {
            bail!("no .{} artifacts in {dir:?}", model_format::EXTENSION);
        }
        for path in paths {
            let artifact = model_format::read_file_with(&path, mode)?;
            let newer = match registry.get(&artifact.name) {
                None => true,
                Some(existing) => artifact.version >= existing.version,
            };
            if newer {
                registry.install(artifact, path);
            }
        }
        Ok(registry)
    }

    fn make_entry(
        artifact: ModelArtifact,
        source: PathBuf,
        fault: Option<FaultPlan>,
    ) -> Arc<ModelEntry> {
        // Pack-once: decode → prepare (and the geometry probe for the
        // batching hint) happen here, off the request path; a hot-swap
        // pays them before the new entry becomes visible.
        let mut plan = artifact.graph.prepare();
        // Fault injection: an explicit plan (chaos tests/benches) wins;
        // otherwise IAOI_FAULT applies to every matching model installed
        // from here on — including swapped-in replacements, so the CI
        // fault smoke keeps injecting across the model's whole lifecycle.
        let fault = fault
            .or_else(|| FaultPlan::from_env().filter(|f| f.applies_to(&artifact.name)));
        if let Some(f) = fault {
            plan.set_fault(f);
        }
        let plan = Arc::new(plan);
        let positions_hint = artifact.graph.dominant_positions(artifact.input_shape);
        Arc::new(ModelEntry {
            name: artifact.name.clone(),
            version: artifact.version,
            input_shape: artifact.input_shape,
            backing: artifact.backing.clone(),
            graph: Arc::new(artifact.graph),
            plan,
            positions_hint,
            source,
        })
    }

    /// Register (or replace) a model from an in-memory artifact.
    pub fn install(&self, artifact: ModelArtifact, source: PathBuf) -> Arc<ModelEntry> {
        self.install_with(artifact, source, None)
    }

    /// [`Self::install`] with an explicit [`FaultPlan`] on the prepared
    /// plan — the deterministic handle the chaos tests and the
    /// degraded-mode bench phase use (env-driven injection is global and
    /// racy across parallel tests; this is not).
    pub fn install_with(
        &self,
        artifact: ModelArtifact,
        source: PathBuf,
        fault: Option<FaultPlan>,
    ) -> Arc<ModelEntry> {
        let entry = Self::make_entry(artifact, source, fault);
        write_recover(&self.inner).insert(entry.name.clone(), Arc::clone(&entry));
        entry
    }

    /// Register a model from an artifact file under its embedded name.
    pub fn register_file(&self, path: &Path) -> Result<Arc<ModelEntry>> {
        self.register_file_with(path, LoadMode::from_env())
    }

    /// [`Self::register_file`] with an explicit weight-storage mode.
    pub fn register_file_with(&self, path: &Path, mode: LoadMode) -> Result<Arc<ModelEntry>> {
        let artifact = model_format::read_file_with(path, mode)?;
        Ok(self.install(artifact, path.to_path_buf()))
    }

    /// Atomically hot-swap the model served under `name` with the artifact
    /// at `path`. The artifact must carry the same model name (a safety rail
    /// against wiring model B's weights under model A's route) and the same
    /// input shape — requests already validated against the resident model
    /// may still be queued, so a geometry change would panic workers; a new
    /// geometry is a new model name. The version may move in either
    /// direction (rollbacks are legitimate swaps).
    /// Returns `(previous_version, new_version)`.
    ///
    /// In-flight batches keep their snapshot of the previous entry and
    /// complete normally; only batches formed after the swap see the new
    /// graph.
    pub fn swap(&self, name: &str, path: &Path) -> Result<(Option<u32>, u32)> {
        self.swap_with(name, path, LoadMode::from_env())
    }

    /// [`Self::swap`] with an explicit weight-storage mode. The artifact is
    /// fully decoded — including the v3 payload-checksum verification, so a
    /// torn or bit-rotted file is rejected here, at swap time, with a
    /// checksum diagnostic — before the registry table is touched.
    pub fn swap_with(&self, name: &str, path: &Path, mode: LoadMode) -> Result<(Option<u32>, u32)> {
        let artifact = model_format::read_file_with(path, mode)?;
        if artifact.name != name {
            bail!(
                "artifact {path:?} names model {:?}, refusing to swap it in as {name:?}",
                artifact.name
            );
        }
        let new_version = artifact.version;
        let entry = Self::make_entry(artifact, path.to_path_buf(), None);
        let previous = {
            let mut table = write_recover(&self.inner);
            if let Some(existing) = table.get(name) {
                if existing.input_shape != entry.input_shape {
                    bail!(
                        "refusing to hot-swap {name:?}: input shape {:?} -> {:?} would break \
                         requests validated against the resident model; register the new \
                         geometry under a new model name instead",
                        existing.input_shape,
                        entry.input_shape
                    );
                }
            }
            table.insert(name.to_string(), entry).map(|old| old.version)
        };
        // A successful swap is the operator's "fixed artifact shipped"
        // signal: re-admit the model (lifetime panic count is kept).
        self.reset_quarantine(name);
        Ok((previous, new_version))
    }

    /// Snapshot the current entry for `name`.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        read_recover(&self.inner).get(name).cloned()
    }

    /// Like [`Self::get`] but with a routing-flavoured error.
    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        self.get(name).ok_or_else(|| {
            anyhow!("unknown model {name:?} (registered: {:?})", self.names())
        })
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = read_recover(&self.inner).keys().cloned().collect();
        names.sort();
        names
    }

    pub fn len(&self) -> usize {
        read_recover(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // --- Circuit breaker ---------------------------------------------------

    /// Set the quarantine policy (applies to panics recorded from now on).
    pub fn set_quarantine(&self, cfg: QuarantineConfig) {
        lock_recover(&self.breaker).cfg = cfg;
    }

    pub fn quarantine_config(&self) -> QuarantineConfig {
        lock_recover(&self.breaker).cfg
    }

    /// Record one contained batch panic for `name`; returns whether the
    /// model is quarantined after this record. Trips the breaker at
    /// *exactly* `threshold` panics inside the sliding window.
    pub fn record_panic(&self, name: &str) -> bool {
        let mut b = lock_recover(&self.breaker);
        let cfg = b.cfg;
        let e = b.models.entry(name.to_string()).or_default();
        e.total += 1;
        if cfg.threshold == 0 {
            return false;
        }
        if e.quarantined {
            return true;
        }
        let now = Instant::now();
        e.recent.push_back(now);
        while e.recent.front().is_some_and(|&t| now.duration_since(t) > cfg.window) {
            e.recent.pop_front();
        }
        if e.recent.len() >= cfg.threshold as usize {
            e.quarantined = true;
            e.recent.clear();
        }
        e.quarantined
    }

    /// Whether `name` is currently fenced off by the breaker.
    pub fn is_quarantined(&self, name: &str) -> bool {
        lock_recover(&self.breaker).models.get(name).is_some_and(|e| e.quarantined)
    }

    /// Lifetime contained-panic count for `name` (survives resets/swaps).
    pub fn panic_count(&self, name: &str) -> u64 {
        lock_recover(&self.breaker).models.get(name).map_or(0, |e| e.total)
    }

    /// Re-admit `name`: clears the quarantine flag and the sliding window
    /// (the lifetime panic count is kept). Called automatically by a
    /// successful [`Self::swap`].
    pub fn reset_quarantine(&self, name: &str) {
        if let Some(e) = lock_recover(&self.breaker).models.get_mut(name) {
            e.quarantined = false;
            e.recent.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::graph::builders::papernet_random;
    use crate::nn::FusedActivation;
    use crate::quantize::{quantize_graph, QuantizeOptions};
    use crate::tensor::Tensor;

    fn artifact(name: &str, version: u32, seed: u64) -> ModelArtifact {
        let g = papernet_random(4, FusedActivation::Relu6, seed);
        let mut rng = Rng::seeded(seed);
        let calib: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; 16 * 16 * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[1, 16, 16, 3], d)
            })
            .collect();
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
        ModelArtifact::new(name, version, [16, 16, 3], q)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("iaoi-registry-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_dir_keeps_highest_version_per_name() {
        let dir = tmpdir("versions");
        model_format::write_file(&dir.join("m_v1.iaoiq"), &artifact("m", 1, 1)).unwrap();
        model_format::write_file(&dir.join("m_v2.iaoiq"), &artifact("m", 2, 2)).unwrap();
        model_format::write_file(&dir.join("other.iaoiq"), &artifact("other", 7, 3)).unwrap();
        let reg = ModelRegistry::load_dir(&dir).unwrap();
        assert_eq!(reg.names(), vec!["m".to_string(), "other".to_string()]);
        assert_eq!(reg.get("m").unwrap().version, 2);
        assert_eq!(reg.get("other").unwrap().version, 7);
    }

    #[test]
    fn swap_replaces_entry_but_old_snapshot_survives() {
        let dir = tmpdir("swap");
        let v2 = dir.join("m_v2.iaoiq");
        model_format::write_file(&v2, &artifact("m", 2, 5)).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 4), PathBuf::new());
        let snapshot = reg.get("m").unwrap();
        let (old, new) = reg.swap("m", &v2).unwrap();
        assert_eq!((old, new), (Some(1), 2));
        assert_eq!(reg.get("m").unwrap().version, 2);
        // The pre-swap snapshot (a worker mid-batch) still runs v1.
        assert_eq!(snapshot.version, 1);
        let x = Tensor::zeros(&[1, 16, 16, 3]);
        assert_eq!(snapshot.graph.run(&x).shape(), &[1, 4]);
    }

    #[test]
    fn entries_derive_the_geometry_batching_hint_at_install() {
        let reg = ModelRegistry::new();
        let entry = reg.install(artifact("m", 1, 50), PathBuf::new());
        // papernet at 16×16: conv0 dominates with OH·OW = 256.
        assert_eq!(entry.positions_hint, 256);
        assert_eq!(entry.positions_hint, entry.graph.dominant_positions(entry.input_shape));
    }

    #[test]
    fn entries_carry_prepared_plans_matching_their_graphs() {
        let reg = ModelRegistry::new();
        let entry = reg.install(artifact("m", 1, 44), PathBuf::new());
        let mut rng = Rng::seeded(44);
        let mut d = vec![0f32; 2 * 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[2, 16, 16, 3], d);
        let want = entry.graph.run(&x);
        let mut state = crate::graph::ExecState::new();
        let got = entry.plan.run(&x, &mut state);
        assert_eq!(want.data(), got.data(), "plan must be bit-identical to the graph");
    }

    #[test]
    fn swap_rejects_torn_artifact_with_checksum_error() {
        let dir = tmpdir("torn");
        let path = dir.join("m_v2.iaoiq");
        model_format::write_file(&path, &artifact("m", 2, 11)).unwrap();
        // Corrupt one payload byte on disk — simulated bit-rot.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 12), PathBuf::new());
        for mode in [LoadMode::Copy, LoadMode::ZeroCopy, LoadMode::Mmap] {
            let err = reg.swap_with("m", &path, mode).unwrap_err();
            assert!(err.to_string().contains("checksum"), "{mode:?}: {err}");
            assert_eq!(reg.get("m").unwrap().version, 1, "failed swap must not apply");
        }
        // A truncated (torn) write fails cleanly too.
        std::fs::write(&path, &std::fs::read(&path).unwrap()[..mid]).unwrap();
        assert!(reg.swap("m", &path).is_err());
        assert_eq!(reg.get("m").unwrap().version, 1);
    }

    #[test]
    fn zero_copy_entries_serve_bit_identically_and_expose_backing() {
        let dir = tmpdir("zerocopy");
        let path = dir.join("m.iaoiq");
        model_format::write_file(&path, &artifact("m", 1, 21)).unwrap();
        let reg = ModelRegistry::new();
        let copy = reg.register_file_with(&path, LoadMode::Copy).unwrap();
        assert!(copy.backing.is_none());

        let mut rng = Rng::seeded(21);
        let mut d = vec![0f32; 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[1, 16, 16, 3], d);
        let want = copy.graph.run(&x);

        for mode in [LoadMode::ZeroCopy, LoadMode::Mmap] {
            let entry = reg.register_file_with(&path, mode).unwrap();
            assert!(entry.backing.is_some(), "{mode:?} keeps the buffer");
            if mode == LoadMode::Mmap && cfg!(all(unix, target_pointer_width = "64")) {
                assert!(entry.is_mapped(), "mmap mode should map on 64-bit unix");
            }
            assert_eq!(entry.graph.run(&x).data(), want.data(), "{mode:?} diverged");
            let mut state = crate::graph::ExecState::new();
            assert_eq!(entry.plan.run(&x, &mut state).data(), want.data(), "{mode:?} plan diverged");
        }
    }

    #[test]
    fn swap_rejects_mismatched_name() {
        let dir = tmpdir("mismatch");
        let path = dir.join("b.iaoiq");
        model_format::write_file(&path, &artifact("b", 1, 6)).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("a", 1, 7), PathBuf::new());
        let err = reg.swap("a", &path).unwrap_err();
        assert!(err.to_string().contains("refusing"), "{err}");
        assert_eq!(reg.get("a").unwrap().version, 1);
    }

    #[test]
    fn swap_rejects_input_shape_change() {
        let dir = tmpdir("shape");
        let path = dir.join("m_v2.iaoiq");
        // Same graph, same name, but declared for a different input geometry.
        let mut art = artifact("m", 2, 8);
        art.input_shape = [8, 8, 3];
        model_format::write_file(&path, &art).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 9), PathBuf::new());
        let err = reg.swap("m", &path).unwrap_err();
        assert!(err.to_string().contains("input shape"), "{err}");
        assert_eq!(reg.get("m").unwrap().version, 1, "swap must not partially apply");
    }

    #[test]
    fn empty_dir_is_an_error() {
        let dir = tmpdir("empty");
        assert!(ModelRegistry::load_dir(&dir).is_err());
    }

    #[test]
    fn quarantine_trips_at_exactly_threshold_and_swap_readmits() {
        let dir = tmpdir("quarantine");
        let v2 = dir.join("m_v2.iaoiq");
        model_format::write_file(&v2, &artifact("m", 2, 31)).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 30), PathBuf::new());
        reg.set_quarantine(QuarantineConfig { threshold: 3, window: Duration::from_secs(60) });

        assert!(!reg.record_panic("m"), "1 panic: below threshold");
        assert!(!reg.record_panic("m"), "2 panics: below threshold");
        assert!(!reg.is_quarantined("m"));
        assert!(reg.record_panic("m"), "3rd panic must trip the breaker");
        assert!(reg.is_quarantined("m"));
        assert_eq!(reg.panic_count("m"), 3);
        // Panics while quarantined keep counting but stay tripped.
        assert!(reg.record_panic("m"));
        assert_eq!(reg.panic_count("m"), 4);

        // A successful swap re-admits the model and keeps the history.
        reg.swap("m", &v2).unwrap();
        assert!(!reg.is_quarantined("m"));
        assert_eq!(reg.panic_count("m"), 4, "lifetime count survives the swap");
        // Post-swap it takes a full fresh window of panics to re-trip.
        assert!(!reg.record_panic("m"));
        assert!(!reg.record_panic("m"));
        assert!(reg.record_panic("m"));
    }

    #[test]
    fn quarantine_disabled_and_unknown_models() {
        let reg = ModelRegistry::new();
        reg.set_quarantine(QuarantineConfig { threshold: 0, window: Duration::from_secs(1) });
        for _ in 0..10 {
            assert!(!reg.record_panic("m"), "threshold 0 must never quarantine");
        }
        assert!(!reg.is_quarantined("m"));
        assert_eq!(reg.panic_count("m"), 10, "panics are still counted");
        assert!(!reg.is_quarantined("never-seen"));
        assert_eq!(reg.panic_count("never-seen"), 0);
        reg.reset_quarantine("never-seen"); // no-op, must not panic
    }

    #[test]
    fn install_with_fault_plan_makes_the_plan_panic_on_cue() {
        use crate::graph::ExecState;
        let reg = ModelRegistry::new();
        let entry = reg.install_with(
            artifact("m", 1, 33),
            PathBuf::new(),
            Some(FaultPlan { panic_on_run: 2, ..Default::default() }),
        );
        let x = Tensor::zeros(&[1, 16, 16, 3]);
        let mut state = ExecState::new();
        let _ = entry.plan.run(&x, &mut state); // run 1: clean
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut state = ExecState::new();
            entry.plan.run(&x, &mut state)
        }));
        assert!(hit.is_err(), "run 2 must hit the injected panic");
        assert_eq!(entry.plan.fault_state().unwrap().runs(), 2);
        let mut state = ExecState::new();
        let _ = entry.plan.run(&x, &mut state); // run 3: clean again
    }
}
