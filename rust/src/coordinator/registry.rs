//! Multi-model registry: named, versioned quantized models loaded from
//! `.iaoiq` artifacts ([`crate::model_format`]), shared between the router,
//! the batcher, and the workers, with **atomic hot-swap**.
//!
//! Swap semantics: [`ModelRegistry::swap`] decodes the new artifact fully
//! *before* touching the table, then replaces the entry under a single
//! write-lock — readers either see the old model or the new one, never a
//! partial state. Workers snapshot an `Arc<ModelEntry>` when they pick up a
//! batch, so requests already in flight finish on the model they were
//! batched against and nothing is dropped mid-swap.
//!
//! The registry also hosts the per-model **circuit breaker**
//! ([`QuarantineConfig`]): coordinator workers report contained batch
//! panics here, and once a model accumulates the configured number of
//! panics inside the sliding window it is *quarantined* — the serving
//! front end refuses its traffic with 503 `{"error":"quarantined"}` and
//! workers stop executing its batches, so one faulty artifact cannot keep
//! burning compute or poisoning latency for its neighbours. A successful
//! [`ModelRegistry::swap`] (the operator shipping a fixed artifact) or an
//! explicit [`ModelRegistry::reset_quarantine`] re-admits the model.
//!
//! **Fleet lifecycle.** Models can also *leave*: [`ModelRegistry::evict`]
//! (two-phase for callers that drain traffic first:
//! [`ModelRegistry::begin_evict`] marks the model so [`resolve`] refuses
//! new arrivals while in-flight batches finish on their snapshots, then
//! [`ModelRegistry::finish_evict`] drops the table entry) and
//! [`ModelRegistry::remove`] (forget entirely). Eviction leaves a *cold
//! tombstone* ([`ColdEntry`]: source path, version, load mode) so the
//! model stays visible in `/healthz` and can come back via
//! [`ModelRegistry::reinstall`] — for an mmap-backed entry that means the
//! plan is dropped but the page cache keeps the artifact bytes, so
//! reinstall is a remap + (lazy) prepare, not a disk read. A
//! [`ResidencyPolicy`] caps how many models stay resident at once:
//! installs over the cap evict the least-recently-used model
//! ([`resolve`] touches an LRU clock), preferring quarantined victims —
//! the models least worth keeping warm.
//!
//! [`resolve`]: ModelRegistry::resolve

use crate::gemm::PrepareMode;
use crate::graph::fault::FaultPlan;
use crate::graph::{PreparedGraph, QGraph};
use crate::model_format::{self, LoadMode, ModelArtifact};
use crate::sync::{lock_recover, read_recover, write_recover};
use crate::tensor::ArtifactBytes;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// One resident model: immutable once registered (swaps replace the whole
/// entry).
#[derive(Debug)]
pub struct ModelEntry {
    pub name: String,
    pub version: u32,
    /// Shape `[H, W, C]` of one input example.
    pub input_shape: [usize; 3],
    pub graph: Arc<QGraph>,
    /// The prepared execution plan (weights packed, output stages built),
    /// constructed once at install/load time so no worker ever pays the
    /// weight-side cost per request. Workers share it read-only, each with
    /// its own [`crate::graph::ExecState`].
    pub plan: Arc<PreparedGraph>,
    /// `OH·OW` of this model's dominant conv layer
    /// ([`QGraph::dominant_positions`]), derived from the artifact geometry
    /// at install time. The multi-model batcher uses it as the per-model
    /// `positions_hint`, so NR-aligned batch capping engages with each
    /// model's real geometry — models in one registry can differ.
    pub positions_hint: usize,
    /// Artifact path the entry was loaded from (empty for in-memory
    /// registrations).
    pub source: PathBuf,
    /// Backing buffer the graph's zero-copy weight views borrow from
    /// (`None` for copy-mode loads and in-memory registrations). The views
    /// inside [`Self::graph`] keep the buffer alive on their own; pinning
    /// it on the entry makes the dependency explicit and observable.
    pub backing: Option<ArtifactBytes>,
}

impl ModelEntry {
    /// The batched NHWC input shape for a batch of `n`.
    pub fn batched_shape(&self, n: usize) -> [usize; 4] {
        [n, self.input_shape[0], self.input_shape[1], self.input_shape[2]]
    }

    /// True when this entry's weights borrow a live file mapping.
    pub fn is_mapped(&self) -> bool {
        self.backing.as_ref().is_some_and(ArtifactBytes::is_mapped)
    }

    /// How this entry's weights are stored, as the `/healthz` label:
    /// `"copy"` (owned decode), `"zerocopy"` (views into a shared heap
    /// buffer), or `"mmap"` (views into a live file mapping).
    pub fn load_mode_label(&self) -> &'static str {
        match &self.backing {
            None => "copy",
            Some(b) if b.is_mapped() => "mmap",
            Some(_) => "zerocopy",
        }
    }

    /// The [`LoadMode`] that reproduces this entry's weight storage —
    /// recorded on the eviction tombstone so [`ModelRegistry::reinstall`]
    /// comes back the same way it left.
    pub fn load_mode(&self) -> LoadMode {
        match &self.backing {
            None => LoadMode::Copy,
            Some(b) if b.is_mapped() => LoadMode::Mmap,
            Some(_) => LoadMode::ZeroCopy,
        }
    }

    /// Heap bytes held by this entry's prepared plan right now
    /// ([`PreparedGraph::plan_bytes`]): the packed conv/FC panels, which
    /// grow lazily under [`PrepareMode::Lazy`]. Surfaced per model in
    /// `/healthz` and as `iaoi_plan_bytes` in `/metrics`.
    pub fn plan_bytes(&self) -> usize {
        self.plan.plan_bytes()
    }
}

/// Circuit-breaker policy: `threshold` contained panics within `window`
/// quarantine a model. `threshold == 0` disables quarantine entirely
/// (panics are still counted and exported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuarantineConfig {
    pub threshold: u32,
    pub window: Duration,
}

impl Default for QuarantineConfig {
    fn default() -> Self {
        // Three strikes in 30s: tight enough that a deterministically
        // crashing artifact is fenced within its first few batches, loose
        // enough that an isolated cosmic-ray panic doesn't take a healthy
        // model out of rotation.
        Self { threshold: 3, window: Duration::from_secs(30) }
    }
}

/// Per-model breaker bookkeeping.
#[derive(Debug, Default)]
struct BreakerEntry {
    /// Panic timestamps inside the sliding window (cleared on trip/reset).
    recent: VecDeque<Instant>,
    /// Lifetime panic count — survives quarantine resets and swaps, so
    /// `/healthz` keeps the model's full history visible.
    total: u64,
    quarantined: bool,
}

#[derive(Debug, Default)]
struct Breaker {
    cfg: QuarantineConfig,
    models: HashMap<String, BreakerEntry>,
}

/// How many models may be resident (prepared, serving) at once.
/// `max_resident_models == 0` means unlimited — the historical behaviour
/// and the default. When an install pushes the registry over the cap,
/// [`ModelRegistry::enforce_residency`] evicts least-recently-used models
/// (quarantined ones first) until the cap holds again.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyPolicy {
    pub max_resident_models: usize,
}

/// Tombstone for an evicted model: everything needed to bring it back
/// ([`ModelRegistry::reinstall`]) and to keep it visible as `"cold"` in
/// the `/healthz` fleet listing.
#[derive(Clone, Debug)]
pub struct ColdEntry {
    /// Artifact file the model was serving from (empty for in-memory
    /// installs, which cannot be reinstalled).
    pub source: PathBuf,
    /// Version at eviction time.
    pub version: u32,
    /// Weight-storage mode the entry was using, so reinstall reproduces it.
    pub load: LoadMode,
}

/// LRU clock, eviction bookkeeping, and the install-time policy knobs.
#[derive(Debug, Default)]
struct Lifecycle {
    policy: ResidencyPolicy,
    /// Prepare mode applied by installs; `None` defers to
    /// [`PrepareMode::from_env`] at each prepare (the suite-wide default).
    prepare: Option<PrepareMode>,
    /// Monotonic use counter — bumped on every [`ModelRegistry::resolve`]
    /// (and install), recorded per model in `last_used`.
    clock: u64,
    last_used: HashMap<String, u64>,
    /// Models mid-eviction: still in the table (in-flight snapshots keep
    /// serving) but refused by `resolve` so no *new* traffic lands.
    evicting: HashSet<String>,
    cold: HashMap<String, ColdEntry>,
    evictions_total: u64,
}

/// Cloneable handle to the shared name → model table.
#[derive(Clone, Default)]
pub struct ModelRegistry {
    inner: Arc<RwLock<HashMap<String, Arc<ModelEntry>>>>,
    breaker: Arc<Mutex<Breaker>>,
    lifecycle: Arc<Mutex<Lifecycle>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Load every `*.iaoiq` artifact in `dir` under the environment-default
    /// [`LoadMode`]. Files are visited in sorted order; when several
    /// artifacts carry the same model name, the highest version wins (ties
    /// broken by file order).
    pub fn load_dir(dir: &Path) -> Result<Self> {
        Self::load_dir_with(dir, LoadMode::from_env())
    }

    /// [`Self::load_dir`] with an explicit weight-storage mode (the
    /// `iaoi serve --load` knob).
    pub fn load_dir_with(dir: &Path, mode: LoadMode) -> Result<Self> {
        let registry = Self::new();
        registry.register_dir_with(dir, mode)?;
        Ok(registry)
    }

    /// Register every `*.iaoiq` artifact in `dir` into this registry (same
    /// ordering/version rules as [`Self::load_dir`]) — the instance form,
    /// for registries whose prepare mode or residency policy must be set
    /// *before* the first install.
    pub fn register_dir_with(&self, dir: &Path, mode: LoadMode) -> Result<()> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("read model directory {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().and_then(|s| s.to_str()) == Some(model_format::EXTENSION))
            .collect();
        paths.sort();
        if paths.is_empty() {
            bail!("no .{} artifacts in {dir:?}", model_format::EXTENSION);
        }
        for path in paths {
            let artifact = model_format::read_file_with(&path, mode)?;
            let newer = match self.get(&artifact.name) {
                None => true,
                Some(existing) => artifact.version >= existing.version,
            };
            if newer {
                self.install(artifact, path);
            }
        }
        Ok(())
    }

    fn make_entry(
        artifact: ModelArtifact,
        source: PathBuf,
        fault: Option<FaultPlan>,
        mode: Option<PrepareMode>,
    ) -> Arc<ModelEntry> {
        // Pack-once: decode → prepare (and the geometry probe for the
        // batching hint) happen here, off the request path; a hot-swap
        // pays them before the new entry becomes visible. Under
        // PrepareMode::Lazy "prepare" builds per-layer pack thunks only —
        // panels materialize on each layer's first batch.
        let mut plan = match mode {
            Some(m) => artifact.graph.prepare_with(m),
            None => artifact.graph.prepare(),
        };
        // Fault injection: an explicit plan (chaos tests/benches) wins;
        // otherwise IAOI_FAULT applies to every matching model installed
        // from here on — including swapped-in replacements, so the CI
        // fault smoke keeps injecting across the model's whole lifecycle.
        let fault = fault
            .or_else(|| FaultPlan::from_env().filter(|f| f.applies_to(&artifact.name)));
        if let Some(f) = fault {
            plan.set_fault(f);
        }
        let plan = Arc::new(plan);
        let positions_hint = artifact.graph.dominant_positions(artifact.input_shape);
        Arc::new(ModelEntry {
            name: artifact.name.clone(),
            version: artifact.version,
            input_shape: artifact.input_shape,
            backing: artifact.backing.clone(),
            graph: Arc::new(artifact.graph),
            plan,
            positions_hint,
            source,
        })
    }

    /// Register (or replace) a model from an in-memory artifact.
    pub fn install(&self, artifact: ModelArtifact, source: PathBuf) -> Arc<ModelEntry> {
        self.install_with(artifact, source, None)
    }

    /// [`Self::install`] with an explicit [`FaultPlan`] on the prepared
    /// plan — the deterministic handle the chaos tests and the
    /// degraded-mode bench phase use (env-driven injection is global and
    /// racy across parallel tests; this is not).
    pub fn install_with(
        &self,
        artifact: ModelArtifact,
        source: PathBuf,
        fault: Option<FaultPlan>,
    ) -> Arc<ModelEntry> {
        let mode = lock_recover(&self.lifecycle).prepare;
        let entry = Self::make_entry(artifact, source, fault, mode);
        write_recover(&self.inner).insert(entry.name.clone(), Arc::clone(&entry));
        {
            // A fresh install is the most-recent use, clears any tombstone
            // for the name, and cancels a half-done eviction.
            let mut lc = lock_recover(&self.lifecycle);
            lc.clock += 1;
            let clock = lc.clock;
            lc.last_used.insert(entry.name.clone(), clock);
            lc.evicting.remove(&entry.name);
            lc.cold.remove(&entry.name);
        }
        self.enforce_residency();
        entry
    }

    /// Register a model from an artifact file under its embedded name.
    pub fn register_file(&self, path: &Path) -> Result<Arc<ModelEntry>> {
        self.register_file_with(path, LoadMode::from_env())
    }

    /// [`Self::register_file`] with an explicit weight-storage mode.
    pub fn register_file_with(&self, path: &Path, mode: LoadMode) -> Result<Arc<ModelEntry>> {
        let artifact = model_format::read_file_with(path, mode)?;
        Ok(self.install(artifact, path.to_path_buf()))
    }

    /// Atomically hot-swap the model served under `name` with the artifact
    /// at `path`. The artifact must carry the same model name (a safety rail
    /// against wiring model B's weights under model A's route) and the same
    /// input shape — requests already validated against the resident model
    /// may still be queued, so a geometry change would panic workers; a new
    /// geometry is a new model name. The version may move in either
    /// direction (rollbacks are legitimate swaps).
    /// Returns `(previous_version, new_version)`.
    ///
    /// In-flight batches keep their snapshot of the previous entry and
    /// complete normally; only batches formed after the swap see the new
    /// graph.
    pub fn swap(&self, name: &str, path: &Path) -> Result<(Option<u32>, u32)> {
        self.swap_with(name, path, LoadMode::from_env())
    }

    /// [`Self::swap`] with an explicit weight-storage mode. The artifact is
    /// fully decoded — including the v3 payload-checksum verification, so a
    /// torn or bit-rotted file is rejected here, at swap time, with a
    /// checksum diagnostic — before the registry table is touched.
    pub fn swap_with(&self, name: &str, path: &Path, mode: LoadMode) -> Result<(Option<u32>, u32)> {
        let artifact = model_format::read_file_with(path, mode)?;
        if artifact.name != name {
            bail!(
                "artifact {path:?} names model {:?}, refusing to swap it in as {name:?}",
                artifact.name
            );
        }
        let new_version = artifact.version;
        let prepare = lock_recover(&self.lifecycle).prepare;
        let entry = Self::make_entry(artifact, path.to_path_buf(), None, prepare);
        let previous = {
            let mut table = write_recover(&self.inner);
            if let Some(existing) = table.get(name) {
                if existing.input_shape != entry.input_shape {
                    bail!(
                        "refusing to hot-swap {name:?}: input shape {:?} -> {:?} would break \
                         requests validated against the resident model; register the new \
                         geometry under a new model name instead",
                        existing.input_shape,
                        entry.input_shape
                    );
                }
            }
            table.insert(name.to_string(), entry).map(|old| old.version)
        };
        {
            let mut lc = lock_recover(&self.lifecycle);
            lc.clock += 1;
            let clock = lc.clock;
            lc.last_used.insert(name.to_string(), clock);
            lc.evicting.remove(name);
            lc.cold.remove(name);
        }
        // A successful swap is the operator's "fixed artifact shipped"
        // signal: re-admit the model (lifetime panic count is kept).
        self.reset_quarantine(name);
        Ok((previous, new_version))
    }

    /// Snapshot the current entry for `name`.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        read_recover(&self.inner).get(name).cloned()
    }

    /// Like [`Self::get`] but with a routing-flavoured error, refusing
    /// models mid-eviction, and touching the LRU clock — this is the
    /// request-path lookup, so "recently used" means "recently served".
    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        if self.is_evicting(name) {
            bail!("model {name:?} is evicting (draining in-flight requests)");
        }
        match self.get(name) {
            Some(entry) => {
                self.touch(name);
                Ok(entry)
            }
            None => Err(anyhow!("unknown model {name:?} (registered: {:?})", self.names())),
        }
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = read_recover(&self.inner).keys().cloned().collect();
        names.sort();
        names
    }

    pub fn len(&self) -> usize {
        read_recover(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // --- Fleet lifecycle ---------------------------------------------------

    /// Set the residency cap, evicting immediately if the fleet is already
    /// over it. Returns the names evicted to satisfy the new policy.
    pub fn set_residency(&self, policy: ResidencyPolicy) -> Vec<String> {
        lock_recover(&self.lifecycle).policy = policy;
        self.enforce_residency()
    }

    pub fn residency(&self) -> ResidencyPolicy {
        lock_recover(&self.lifecycle).policy
    }

    /// Pin the [`PrepareMode`] future installs/swaps use. Unset, each
    /// install falls back to [`PrepareMode::from_env`] (`IAOI_PREPARE`).
    pub fn set_prepare_mode(&self, mode: PrepareMode) {
        lock_recover(&self.lifecycle).prepare = Some(mode);
    }

    /// The pinned install-time prepare mode (`None` = environment default).
    pub fn prepare_mode(&self) -> Option<PrepareMode> {
        lock_recover(&self.lifecycle).prepare
    }

    /// Bump the LRU clock for `name`. [`Self::resolve`] does this on every
    /// request-path lookup; callers resolving through [`Self::get`] (which
    /// deliberately does not touch — `/healthz` reads must not distort the
    /// LRU order) can record genuine use here.
    pub fn touch(&self, name: &str) {
        let mut lc = lock_recover(&self.lifecycle);
        lc.clock += 1;
        let clock = lc.clock;
        lc.last_used.insert(name.to_string(), clock);
    }

    /// Whether `name` is between [`Self::begin_evict`] and
    /// [`Self::finish_evict`] — resident for in-flight snapshots, refused
    /// for new arrivals.
    pub fn is_evicting(&self, name: &str) -> bool {
        lock_recover(&self.lifecycle).evicting.contains(name)
    }

    /// Phase one of a drained eviction: mark `name` as evicting so
    /// [`Self::resolve`] refuses new traffic, while the entry stays in the
    /// table for batches already holding snapshots. The caller drains
    /// in-flight work (the serving layer polls its admission counters) and
    /// then calls [`Self::finish_evict`]. Returns the entry being evicted.
    pub fn begin_evict(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let entry = self
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?} (registered: {:?})", self.names()))?;
        lock_recover(&self.lifecycle).evicting.insert(name.to_string());
        Ok(entry)
    }

    /// Phase two: drop the table entry, leaving a cold tombstone
    /// ([`ColdEntry`]) for `/healthz` visibility and [`Self::reinstall`].
    /// The `Arc`'d plan dies with its last in-flight snapshot; for an
    /// mmap-backed entry the unmap releases address space while the page
    /// cache keeps the artifact bytes warm for the next install. Returns
    /// the evicted version.
    pub fn finish_evict(&self, name: &str) -> Result<u32> {
        let removed = write_recover(&self.inner).remove(name);
        let mut lc = lock_recover(&self.lifecycle);
        lc.evicting.remove(name);
        let Some(entry) = removed else {
            bail!("unknown model {name:?}: nothing to evict");
        };
        lc.last_used.remove(name);
        lc.evictions_total += 1;
        lc.cold.insert(
            name.to_string(),
            ColdEntry {
                source: entry.source.clone(),
                version: entry.version,
                load: entry.load_mode(),
            },
        );
        Ok(entry.version)
    }

    /// One-shot eviction (both phases, no drain window): for callers with
    /// no in-flight traffic to wait on — registry-level tests, benches, and
    /// [`Self::enforce_residency`]. Serving layers drain between the two
    /// phases instead ([`crate::serve`]'s evict endpoint mirrors its
    /// hot-swap drain machinery).
    pub fn evict(&self, name: &str) -> Result<u32> {
        self.begin_evict(name)?;
        self.finish_evict(name)
    }

    /// Forget `name` entirely: resident entry, cold tombstone, LRU state,
    /// and breaker history. Returns the resident version, if any. Unlike
    /// [`Self::evict`] this is not undoable via [`Self::reinstall`].
    pub fn remove(&self, name: &str) -> Option<u32> {
        let removed = write_recover(&self.inner).remove(name);
        {
            let mut lc = lock_recover(&self.lifecycle);
            lc.evicting.remove(name);
            lc.last_used.remove(name);
            lc.cold.remove(name);
        }
        lock_recover(&self.breaker).models.remove(name);
        removed.map(|e| e.version)
    }

    /// Bring an evicted model back from its tombstone: re-read the source
    /// artifact under the load mode it left with (page-cache-warm for
    /// mmap) and install it. Fails for models never evicted, and for
    /// in-memory installs (no file to re-read).
    pub fn reinstall(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let cold = self.cold_entry(name);
        let Some(cold) = cold else {
            bail!("model {name:?} has no eviction tombstone (cold: {:?})", self.cold_names());
        };
        if cold.source.as_os_str().is_empty() {
            bail!("model {name:?} was installed in-memory; no artifact file to reinstall from");
        }
        let entry = self.register_file_with(&cold.source, cold.load)?;
        if entry.name != name {
            bail!(
                "artifact {:?} now names model {:?}, expected {name:?}",
                cold.source,
                entry.name
            );
        }
        Ok(entry)
    }

    /// The model the LRU policy would evict next: quarantined models first
    /// (least worth keeping warm), then the least-recently-used; ties break
    /// by name so tests and drain logs are deterministic. Models already
    /// mid-eviction are skipped. `None` when nothing is evictable.
    pub fn lru_candidate(&self) -> Option<String> {
        let names: Vec<String> = read_recover(&self.inner).keys().cloned().collect();
        let quarantined: HashSet<String> = {
            let b = lock_recover(&self.breaker);
            names
                .iter()
                .filter(|n| b.models.get(n.as_str()).is_some_and(|e| e.quarantined))
                .cloned()
                .collect()
        };
        let lc = lock_recover(&self.lifecycle);
        let mut candidates: Vec<(u64, String)> = names
            .into_iter()
            .filter(|n| !lc.evicting.contains(n))
            .map(|n| (lc.last_used.get(&n).copied().unwrap_or(0), n))
            .collect();
        candidates.sort();
        candidates
            .iter()
            .find(|(_, n)| quarantined.contains(n))
            .or_else(|| candidates.first())
            .map(|(_, n)| n.clone())
    }

    /// Evict LRU victims until the [`ResidencyPolicy`] holds (no-op when
    /// the cap is 0/unlimited). Called automatically after every install.
    /// Returns the evicted names, oldest first.
    pub fn enforce_residency(&self) -> Vec<String> {
        let mut evicted = Vec::new();
        loop {
            let max = lock_recover(&self.lifecycle).policy.max_resident_models;
            if max == 0 || self.len() <= max {
                break;
            }
            let Some(victim) = self.lru_candidate() else { break };
            if self.evict(&victim).is_err() {
                break;
            }
            evicted.push(victim);
        }
        evicted
    }

    /// Lifetime eviction count (exported as `iaoi_evictions_total`).
    pub fn evictions_total(&self) -> u64 {
        lock_recover(&self.lifecycle).evictions_total
    }

    /// Names of evicted-but-reinstallable models, sorted.
    pub fn cold_names(&self) -> Vec<String> {
        let lc = lock_recover(&self.lifecycle);
        let mut names: Vec<String> = lc.cold.keys().cloned().collect();
        names.sort();
        names
    }

    /// The tombstone for `name`, if it is cold.
    pub fn cold_entry(&self, name: &str) -> Option<ColdEntry> {
        lock_recover(&self.lifecycle).cold.get(name).cloned()
    }

    // --- Circuit breaker ---------------------------------------------------

    /// Set the quarantine policy (applies to panics recorded from now on).
    pub fn set_quarantine(&self, cfg: QuarantineConfig) {
        lock_recover(&self.breaker).cfg = cfg;
    }

    pub fn quarantine_config(&self) -> QuarantineConfig {
        lock_recover(&self.breaker).cfg
    }

    /// Record one contained batch panic for `name`; returns whether the
    /// model is quarantined after this record. Trips the breaker at
    /// *exactly* `threshold` panics inside the sliding window.
    pub fn record_panic(&self, name: &str) -> bool {
        let mut b = lock_recover(&self.breaker);
        let cfg = b.cfg;
        let e = b.models.entry(name.to_string()).or_default();
        e.total += 1;
        if cfg.threshold == 0 {
            return false;
        }
        if e.quarantined {
            return true;
        }
        let now = Instant::now();
        e.recent.push_back(now);
        while e.recent.front().is_some_and(|&t| now.duration_since(t) > cfg.window) {
            e.recent.pop_front();
        }
        if e.recent.len() >= cfg.threshold as usize {
            e.quarantined = true;
            e.recent.clear();
        }
        e.quarantined
    }

    /// Whether `name` is currently fenced off by the breaker.
    pub fn is_quarantined(&self, name: &str) -> bool {
        lock_recover(&self.breaker).models.get(name).is_some_and(|e| e.quarantined)
    }

    /// Lifetime contained-panic count for `name` (survives resets/swaps).
    pub fn panic_count(&self, name: &str) -> u64 {
        lock_recover(&self.breaker).models.get(name).map_or(0, |e| e.total)
    }

    /// Re-admit `name`: clears the quarantine flag and the sliding window
    /// (the lifetime panic count is kept). Called automatically by a
    /// successful [`Self::swap`].
    pub fn reset_quarantine(&self, name: &str) {
        if let Some(e) = lock_recover(&self.breaker).models.get_mut(name) {
            e.quarantined = false;
            e.recent.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::graph::builders::papernet_random;
    use crate::nn::FusedActivation;
    use crate::quantize::{quantize_graph, QuantizeOptions};
    use crate::tensor::Tensor;

    fn artifact(name: &str, version: u32, seed: u64) -> ModelArtifact {
        let g = papernet_random(4, FusedActivation::Relu6, seed);
        let mut rng = Rng::seeded(seed);
        let calib: Vec<Tensor<f32>> = (0..2)
            .map(|_| {
                let mut d = vec![0f32; 16 * 16 * 3];
                for v in d.iter_mut() {
                    *v = rng.range_f32(-1.0, 1.0);
                }
                Tensor::from_vec(&[1, 16, 16, 3], d)
            })
            .collect();
        let (_, q) = quantize_graph(&g, &calib, QuantizeOptions::default());
        ModelArtifact::new(name, version, [16, 16, 3], q)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("iaoi-registry-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_dir_keeps_highest_version_per_name() {
        let dir = tmpdir("versions");
        model_format::write_file(&dir.join("m_v1.iaoiq"), &artifact("m", 1, 1)).unwrap();
        model_format::write_file(&dir.join("m_v2.iaoiq"), &artifact("m", 2, 2)).unwrap();
        model_format::write_file(&dir.join("other.iaoiq"), &artifact("other", 7, 3)).unwrap();
        let reg = ModelRegistry::load_dir(&dir).unwrap();
        assert_eq!(reg.names(), vec!["m".to_string(), "other".to_string()]);
        assert_eq!(reg.get("m").unwrap().version, 2);
        assert_eq!(reg.get("other").unwrap().version, 7);
    }

    #[test]
    fn swap_replaces_entry_but_old_snapshot_survives() {
        let dir = tmpdir("swap");
        let v2 = dir.join("m_v2.iaoiq");
        model_format::write_file(&v2, &artifact("m", 2, 5)).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 4), PathBuf::new());
        let snapshot = reg.get("m").unwrap();
        let (old, new) = reg.swap("m", &v2).unwrap();
        assert_eq!((old, new), (Some(1), 2));
        assert_eq!(reg.get("m").unwrap().version, 2);
        // The pre-swap snapshot (a worker mid-batch) still runs v1.
        assert_eq!(snapshot.version, 1);
        let x = Tensor::zeros(&[1, 16, 16, 3]);
        assert_eq!(snapshot.graph.run(&x).shape(), &[1, 4]);
    }

    #[test]
    fn entries_derive_the_geometry_batching_hint_at_install() {
        let reg = ModelRegistry::new();
        let entry = reg.install(artifact("m", 1, 50), PathBuf::new());
        // papernet at 16×16: conv0 dominates with OH·OW = 256.
        assert_eq!(entry.positions_hint, 256);
        assert_eq!(entry.positions_hint, entry.graph.dominant_positions(entry.input_shape));
    }

    #[test]
    fn entries_carry_prepared_plans_matching_their_graphs() {
        let reg = ModelRegistry::new();
        let entry = reg.install(artifact("m", 1, 44), PathBuf::new());
        let mut rng = Rng::seeded(44);
        let mut d = vec![0f32; 2 * 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[2, 16, 16, 3], d);
        let want = entry.graph.run(&x);
        let mut state = crate::graph::ExecState::new();
        let got = entry.plan.run(&x, &mut state);
        assert_eq!(want.data(), got.data(), "plan must be bit-identical to the graph");
    }

    #[test]
    fn swap_rejects_torn_artifact_with_checksum_error() {
        let dir = tmpdir("torn");
        let path = dir.join("m_v2.iaoiq");
        model_format::write_file(&path, &artifact("m", 2, 11)).unwrap();
        // Corrupt one payload byte on disk — simulated bit-rot.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 12), PathBuf::new());
        for mode in [LoadMode::Copy, LoadMode::ZeroCopy, LoadMode::Mmap] {
            let err = reg.swap_with("m", &path, mode).unwrap_err();
            assert!(err.to_string().contains("checksum"), "{mode:?}: {err}");
            assert_eq!(reg.get("m").unwrap().version, 1, "failed swap must not apply");
        }
        // A truncated (torn) write fails cleanly too.
        std::fs::write(&path, &std::fs::read(&path).unwrap()[..mid]).unwrap();
        assert!(reg.swap("m", &path).is_err());
        assert_eq!(reg.get("m").unwrap().version, 1);
    }

    #[test]
    fn zero_copy_entries_serve_bit_identically_and_expose_backing() {
        let dir = tmpdir("zerocopy");
        let path = dir.join("m.iaoiq");
        model_format::write_file(&path, &artifact("m", 1, 21)).unwrap();
        let reg = ModelRegistry::new();
        let copy = reg.register_file_with(&path, LoadMode::Copy).unwrap();
        assert!(copy.backing.is_none());

        let mut rng = Rng::seeded(21);
        let mut d = vec![0f32; 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[1, 16, 16, 3], d);
        let want = copy.graph.run(&x);

        for mode in [LoadMode::ZeroCopy, LoadMode::Mmap] {
            let entry = reg.register_file_with(&path, mode).unwrap();
            assert!(entry.backing.is_some(), "{mode:?} keeps the buffer");
            if mode == LoadMode::Mmap && cfg!(all(unix, target_pointer_width = "64")) {
                assert!(entry.is_mapped(), "mmap mode should map on 64-bit unix");
            }
            assert_eq!(entry.graph.run(&x).data(), want.data(), "{mode:?} diverged");
            let mut state = crate::graph::ExecState::new();
            assert_eq!(entry.plan.run(&x, &mut state).data(), want.data(), "{mode:?} plan diverged");
        }
    }

    #[test]
    fn swap_rejects_mismatched_name() {
        let dir = tmpdir("mismatch");
        let path = dir.join("b.iaoiq");
        model_format::write_file(&path, &artifact("b", 1, 6)).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("a", 1, 7), PathBuf::new());
        let err = reg.swap("a", &path).unwrap_err();
        assert!(err.to_string().contains("refusing"), "{err}");
        assert_eq!(reg.get("a").unwrap().version, 1);
    }

    #[test]
    fn swap_rejects_input_shape_change() {
        let dir = tmpdir("shape");
        let path = dir.join("m_v2.iaoiq");
        // Same graph, same name, but declared for a different input geometry.
        let mut art = artifact("m", 2, 8);
        art.input_shape = [8, 8, 3];
        model_format::write_file(&path, &art).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 9), PathBuf::new());
        let err = reg.swap("m", &path).unwrap_err();
        assert!(err.to_string().contains("input shape"), "{err}");
        assert_eq!(reg.get("m").unwrap().version, 1, "swap must not partially apply");
    }

    #[test]
    fn empty_dir_is_an_error() {
        let dir = tmpdir("empty");
        assert!(ModelRegistry::load_dir(&dir).is_err());
    }

    #[test]
    fn quarantine_trips_at_exactly_threshold_and_swap_readmits() {
        let dir = tmpdir("quarantine");
        let v2 = dir.join("m_v2.iaoiq");
        model_format::write_file(&v2, &artifact("m", 2, 31)).unwrap();
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 30), PathBuf::new());
        reg.set_quarantine(QuarantineConfig { threshold: 3, window: Duration::from_secs(60) });

        assert!(!reg.record_panic("m"), "1 panic: below threshold");
        assert!(!reg.record_panic("m"), "2 panics: below threshold");
        assert!(!reg.is_quarantined("m"));
        assert!(reg.record_panic("m"), "3rd panic must trip the breaker");
        assert!(reg.is_quarantined("m"));
        assert_eq!(reg.panic_count("m"), 3);
        // Panics while quarantined keep counting but stay tripped.
        assert!(reg.record_panic("m"));
        assert_eq!(reg.panic_count("m"), 4);

        // A successful swap re-admits the model and keeps the history.
        reg.swap("m", &v2).unwrap();
        assert!(!reg.is_quarantined("m"));
        assert_eq!(reg.panic_count("m"), 4, "lifetime count survives the swap");
        // Post-swap it takes a full fresh window of panics to re-trip.
        assert!(!reg.record_panic("m"));
        assert!(!reg.record_panic("m"));
        assert!(reg.record_panic("m"));
    }

    #[test]
    fn quarantine_disabled_and_unknown_models() {
        let reg = ModelRegistry::new();
        reg.set_quarantine(QuarantineConfig { threshold: 0, window: Duration::from_secs(1) });
        for _ in 0..10 {
            assert!(!reg.record_panic("m"), "threshold 0 must never quarantine");
        }
        assert!(!reg.is_quarantined("m"));
        assert_eq!(reg.panic_count("m"), 10, "panics are still counted");
        assert!(!reg.is_quarantined("never-seen"));
        assert_eq!(reg.panic_count("never-seen"), 0);
        reg.reset_quarantine("never-seen"); // no-op, must not panic
    }

    #[test]
    fn names_are_sorted_regardless_of_install_order() {
        let reg = ModelRegistry::new();
        for name in ["zeta", "alpha", "mu", "beta"] {
            reg.install(artifact(name, 1, 60), PathBuf::new());
        }
        assert_eq!(
            reg.names(),
            vec!["alpha".to_string(), "beta".to_string(), "mu".to_string(), "zeta".to_string()]
        );
    }

    #[test]
    fn evict_leaves_tombstone_and_reinstall_is_bit_identical() {
        let dir = tmpdir("evict");
        let path = dir.join("m.iaoiq");
        model_format::write_file(&path, &artifact("m", 3, 71)).unwrap();
        let reg = ModelRegistry::new();
        let entry = reg.register_file(&path).unwrap();
        let x = Tensor::zeros(&[1, 16, 16, 3]);
        let mut state = crate::graph::ExecState::new();
        let want = entry.plan.run(&x, &mut state);

        assert_eq!(reg.evict("m").unwrap(), 3);
        assert!(reg.get("m").is_none());
        assert_eq!(reg.cold_names(), vec!["m".to_string()]);
        assert_eq!(reg.evictions_total(), 1);
        // A pre-eviction snapshot (a worker mid-batch) still serves.
        assert_eq!(entry.plan.run(&x, &mut state).data(), want.data());

        let back = reg.reinstall("m").unwrap();
        assert_eq!(back.version, 3);
        assert!(reg.cold_names().is_empty(), "reinstall clears the tombstone");
        let mut s2 = crate::graph::ExecState::new();
        assert_eq!(
            back.plan.run(&x, &mut s2).data(),
            want.data(),
            "evict → reinstall → infer must be bit-identical"
        );
    }

    #[test]
    fn begin_evict_refuses_new_resolves_until_finished() {
        let reg = ModelRegistry::new();
        reg.install(artifact("m", 1, 72), PathBuf::new());
        assert!(reg.resolve("m").is_ok());
        let snapshot = reg.begin_evict("m").unwrap();
        assert!(reg.is_evicting("m"));
        let err = reg.resolve("m").unwrap_err();
        assert!(err.to_string().contains("evicting"), "{err}");
        assert!(reg.get("m").is_some(), "entry stays visible for in-flight snapshots");
        assert_eq!(reg.finish_evict("m").unwrap(), 1);
        assert!(!reg.is_evicting("m"));
        assert!(reg.get("m").is_none());
        assert_eq!(snapshot.version, 1);
        // In-memory installs have no artifact file to come back from.
        assert!(reg.reinstall("m").is_err());
        // remove() forgets even the tombstone.
        assert_eq!(reg.remove("m"), None);
        assert!(reg.cold_names().is_empty());
    }

    #[test]
    fn residency_cap_evicts_exactly_the_least_recent() {
        let dir = tmpdir("lru");
        let reg = ModelRegistry::new();
        for name in ["a", "b", "c"] {
            let p = dir.join(format!("{name}.iaoiq"));
            model_format::write_file(&p, &artifact(name, 1, 73)).unwrap();
            reg.register_file(&p).unwrap();
        }
        assert!(reg.set_residency(ResidencyPolicy { max_resident_models: 3 }).is_empty());
        // Use order: a, c, b → the least-recent model is a.
        reg.resolve("a").unwrap();
        reg.resolve("c").unwrap();
        reg.resolve("b").unwrap();
        let p = dir.join("d.iaoiq");
        model_format::write_file(&p, &artifact("d", 1, 74)).unwrap();
        reg.register_file(&p).unwrap();
        assert_eq!(reg.names(), vec!["b".to_string(), "c".to_string(), "d".to_string()]);
        assert_eq!(reg.cold_names(), vec!["a".to_string()]);
        // Reinstalling a (now most-recent) over the cap evicts c, the
        // least-recent of the survivors.
        reg.reinstall("a").unwrap();
        assert_eq!(reg.cold_names(), vec!["c".to_string()]);
        assert_eq!(reg.evictions_total(), 2);
    }

    #[test]
    fn quarantined_models_are_preferred_eviction_victims() {
        let reg = ModelRegistry::new();
        reg.install(artifact("healthy", 1, 75), PathBuf::new());
        reg.install(artifact("sick", 1, 76), PathBuf::new());
        // sick is *more* recently used than healthy...
        reg.resolve("healthy").unwrap();
        reg.resolve("sick").unwrap();
        reg.set_quarantine(QuarantineConfig { threshold: 1, window: Duration::from_secs(60) });
        assert!(reg.record_panic("sick"));
        // ...but quarantine outranks recency.
        assert_eq!(reg.lru_candidate(), Some("sick".to_string()));
        let evicted = reg.set_residency(ResidencyPolicy { max_resident_models: 1 });
        assert_eq!(evicted, vec!["sick".to_string()]);
        assert_eq!(reg.names(), vec!["healthy".to_string()]);
    }

    #[test]
    fn lazy_prepare_installs_defer_packing_and_serve_identically() {
        let reg = ModelRegistry::new();
        let eager = reg.install(artifact("m", 1, 77), PathBuf::new());
        let lazy_reg = ModelRegistry::new();
        lazy_reg.set_prepare_mode(PrepareMode::Lazy);
        assert_eq!(lazy_reg.prepare_mode(), Some(PrepareMode::Lazy));
        let lazy = lazy_reg.install(artifact("m", 1, 77), PathBuf::new());
        // A lazy install holds at most the unpacked weight bytes (a
        // view-backed one holds none); packing happens on first traffic.
        let before = lazy.plan_bytes();
        assert!(before <= eager.plan_bytes());
        let mut rng = Rng::seeded(77);
        let mut d = vec![0f32; 16 * 16 * 3];
        for v in d.iter_mut() {
            *v = rng.range_f32(-1.0, 1.0);
        }
        let x = Tensor::from_vec(&[1, 16, 16, 3], d);
        let mut s1 = crate::graph::ExecState::new();
        let mut s2 = crate::graph::ExecState::new();
        assert_eq!(
            eager.plan.run(&x, &mut s1).data(),
            lazy.plan.run(&x, &mut s2).data(),
            "lazy-prepared serving must be bit-identical to eager"
        );
        assert!(lazy.plan_bytes() > before, "first traffic materializes the panels");
    }

    #[test]
    fn install_with_fault_plan_makes_the_plan_panic_on_cue() {
        use crate::graph::ExecState;
        let reg = ModelRegistry::new();
        let entry = reg.install_with(
            artifact("m", 1, 33),
            PathBuf::new(),
            Some(FaultPlan { panic_on_run: 2, ..Default::default() }),
        );
        let x = Tensor::zeros(&[1, 16, 16, 3]);
        let mut state = ExecState::new();
        let _ = entry.plan.run(&x, &mut state); // run 1: clean
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut state = ExecState::new();
            entry.plan.run(&x, &mut state)
        }));
        assert!(hit.is_err(), "run 2 must hit the injected panic");
        assert_eq!(entry.plan.fault_state().unwrap().runs(), 2);
        let mut state = ExecState::new();
        let _ = entry.plan.run(&x, &mut state); // run 3: clean again
    }
}
