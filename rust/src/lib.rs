//! # iaoi — Integer-Arithmetic-Only Inference
//!
//! A reproduction of *"Quantization and Training of Neural Networks for
//! Efficient Integer-Arithmetic-Only Inference"* (Jacob et al., 2017) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — fake-quantization and quantized-matmul
//!   kernels in `python/compile/kernels/`, validated against a pure-`jnp`
//!   oracle.
//! * **Layer 2 (JAX, build time)** — the quantization-aware-training (QAT)
//!   model graph in `python/compile/model.py`, AOT-lowered to HLO text
//!   artifacts consumed by the Rust runtime.
//! * **Layer 3 (Rust, run time)** — everything in this crate: a gemmlowp-style
//!   integer-only inference engine ([`gemm`], [`fixedpoint`], [`nn`],
//!   [`graph`]), post-training quantization tooling ([`quantize`]), the QAT
//!   training driver over the AOT artifacts ([`train`]), the `.iaoiq`
//!   quantized-model artifact format ([`model_format`]), and a serving
//!   coordinator with dynamic batching and a hot-swappable multi-model
//!   registry ([`coordinator`]).
//!
//! Python never runs on the request path: once `make artifacts` has produced
//! the HLO files, the `iaoi` binary is self-contained.
//!
//! ## Deployment artifacts and serving
//!
//! A quantized model is persisted as a self-describing `.iaoiq` binary —
//! the deployment unit, mirroring the paper's TFLite-flatbuffer story.
//! Reloading is lossless, so a served model is bit-identical to the graph
//! the converter produced:
//!
//! * `iaoi export --out model.iaoiq` — quantize and serialize a model
//!   (PTQ of the demo net, or a QAT-trained checkpoint via `--model`);
//! * `iaoi serve --models DIR` — serve every artifact in a directory
//!   through the multi-model coordinator, with per-request routing and
//!   atomic hot-swap ([`coordinator::registry::ModelRegistry::swap`]);
//! * `iaoi serve --addr HOST:PORT` — the network front end ([`serve`]):
//!   a std-only HTTP/1.1 listener with bounded admission (load-shedding
//!   past the in-flight caps), graceful drain, and a Prometheus-style
//!   metrics endpoint;
//! * `iaoi serve --model FILE` — the original single-model path;
//! * `iaoi train` / `eval` / `quickstart` / `bench` — paper harnesses.

pub mod fixedpoint;
pub mod quant;
pub mod tensor;
pub mod gemm;
pub mod nn;
pub mod graph;
pub mod quantize;
pub mod model_format;
pub mod runtime;
pub mod train;
pub mod coordinator;
pub mod serve;
pub mod sim;
pub mod sync;
pub mod data;
pub mod io;
pub mod harness;
pub mod bench_util;
