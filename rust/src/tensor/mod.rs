//! Minimal dense tensor type used throughout the engine.
//!
//! Activations are NHWC (batch, height, width, channel) and weights are
//! OHWI (output channel, kernel h, kernel w, input channel) — the layouts
//! TFLite uses and the ones that make the im2col → GEMM lowering in
//! [`crate::nn`] contiguous along the reduction dimension.
//!
//! Storage is normally an owned `Vec<T>`, but a `Tensor<u8>` can instead
//! *borrow* its elements from a shared [`ArtifactBytes`] buffer
//! ([`Tensor::from_view`]) — the zero-copy artifact-load path: weight
//! tensors of a loaded model alias the artifact bytes (heap or `mmap`)
//! instead of owning copies. Borrowed tensors are read-only in spirit;
//! any mutating accessor ([`Tensor::data_mut`], the `reset` family,
//! [`Tensor::into_data`]) first detaches them into an owned copy, so every
//! existing call site keeps working unchanged.

pub mod bytes;

pub use bytes::{ArtifactBytes, ByteView};

/// Element storage: owned, or a borrowed view into a shared artifact
/// buffer. The `Shared` variant is only ever constructed for `T = u8`
/// ([`Tensor::from_view`] is defined on `Tensor<u8>` alone) — the
/// invariant that makes the byte reinterpretation in [`Tensor::data`]
/// sound.
#[derive(Clone, Debug)]
enum Storage<T> {
    Owned(Vec<T>),
    Shared(ByteView),
}

/// A dense row-major tensor over element type `T`.
#[derive(Clone, Debug)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Storage<T>,
}

impl<T: Copy + Default + PartialEq> PartialEq for Tensor<T> {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-initialized (default-initialized) tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: Storage::Owned(vec![T::default(); n]) }
    }

    /// Wrap existing data; `data.len()` must equal the shape volume.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data: Storage::Owned(data) }
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], value: T) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: Storage::Owned(vec![value; n]) }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        match &self.data {
            Storage::Owned(v) => v.len(),
            Storage::Shared(view) => view.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        match &self.data {
            Storage::Owned(v) => v,
            Storage::Shared(view) => {
                // Compiles to nothing for u8; a hard stop if the invariant
                // on `Storage::Shared` construction is ever violated.
                assert!(
                    std::mem::size_of::<T>() == 1 && std::mem::align_of::<T>() == 1,
                    "shared storage is only valid for byte-sized elements"
                );
                let b = view.as_slice();
                // SAFETY: T is byte-sized and byte-aligned (asserted above;
                // by construction T = u8), so reinterpreting the immutable
                // byte slice is sound and the lifetime is tied to &self,
                // which keeps the backing buffer alive.
                unsafe { std::slice::from_raw_parts(b.as_ptr().cast::<T>(), b.len()) }
            }
        }
    }

    /// Detach shared storage into an owned copy; no-op for owned tensors.
    /// All mutating accessors funnel through this, so a zero-copy weight
    /// view silently becomes a private copy the moment anyone writes to it.
    fn make_owned(&mut self) {
        if matches!(self.data, Storage::Shared(_)) {
            let copied = self.data().to_vec();
            self.data = Storage::Owned(copied);
        }
    }

    /// True when the elements are borrowed from a shared artifact buffer
    /// rather than owned (the zero-copy load path).
    pub fn is_view(&self) -> bool {
        matches!(self.data, Storage::Shared(_))
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        self.make_owned();
        match &mut self.data {
            Storage::Owned(v) => v,
            Storage::Shared(_) => unreachable!("make_owned detached the view"),
        }
    }

    pub fn into_data(mut self) -> Vec<T> {
        self.make_owned();
        match self.data {
            Storage::Owned(v) => v,
            Storage::Shared(_) => unreachable!("make_owned detached the view"),
        }
    }

    /// Reinterpret with a new shape of identical volume.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.len());
        self.shape = shape.to_vec();
        self
    }

    /// Re-shape in place to `shape`, resetting every element to the default
    /// value. Unlike [`Self::zeros`] this reuses the existing allocation when
    /// capacity allows, so a tensor cycled through the same shapes performs
    /// no heap allocation after the first pass — the property the prepared
    /// execution path ([`crate::graph::PreparedGraph`]) relies on for its
    /// zero-alloc steady state.
    pub fn reset(&mut self, shape: &[usize]) {
        self.make_owned();
        let n = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        let Storage::Owned(data) = &mut self.data else { unreachable!() };
        data.clear();
        data.resize(n, T::default());
    }

    /// [`Self::reset`] without the element fill: prior contents (up to the
    /// old length) are left in place, so the caller **must overwrite every
    /// element**. This skips a full memset pass per call — the prepared
    /// layer paths use it because they write each output element exactly
    /// once.
    pub fn reset_for_overwrite(&mut self, shape: &[usize]) {
        self.make_owned();
        let n = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        let Storage::Owned(data) = &mut self.data else { unreachable!() };
        if data.len() != n {
            data.resize(n, T::default());
        }
    }

    /// [`Self::reset_for_overwrite`] with the last dimension overridden:
    /// the geometry becomes `shape[..rank-1] + [last]`. Lets the channel
    /// concat shape its output without building a temporary shape `Vec`
    /// (the zero-alloc steady state of [`crate::graph::PreparedGraph`]).
    pub fn reset_for_overwrite_last_dim(&mut self, shape: &[usize], last: usize) {
        assert!(!shape.is_empty(), "need at least one dimension to override");
        self.make_owned();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        *self.shape.last_mut().expect("non-empty shape") = last;
        let n = self.shape.iter().product();
        let Storage::Owned(data) = &mut self.data else { unreachable!() };
        if data.len() != n {
            data.resize(n, T::default());
        }
    }

    /// Size of dimension `i`.
    #[inline]
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Rank (number of dimensions).
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat index for an NHWC coordinate (rank-4 tensors).
    #[inline]
    pub fn idx4(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((n * self.shape[1] + h) * self.shape[2] + w) * self.shape[3] + c
    }

    /// NHWC element access.
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> T {
        self.data()[self.idx4(n, h, w, c)]
    }

    /// NHWC element write.
    #[inline]
    pub fn set4(&mut self, n: usize, h: usize, w: usize, c: usize, v: T) {
        let i = self.idx4(n, h, w, c);
        self.data_mut()[i] = v;
    }

    /// Map every element through `f` into a new (owned) tensor (possibly
    /// new type).
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: Storage::Owned(self.data().iter().map(|&v| f(v)).collect()),
        }
    }
}

impl Tensor<u8> {
    /// Zero-copy constructor: borrow the elements from `view` instead of
    /// owning a copy. The view's byte length must equal the shape volume.
    /// This is how [`crate::model_format::load_shared`] hands out weight
    /// tensors that alias the artifact buffer; the tensor (and its clones)
    /// keep the whole backing buffer alive.
    pub fn from_view(shape: &[usize], view: ByteView) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            view.len(),
            "shape {shape:?} does not match view length {}",
            view.len()
        );
        Self { shape: shape.to_vec(), data: Storage::Shared(view) }
    }

    /// The shared buffer this tensor borrows from, if it is a zero-copy
    /// view.
    pub fn backing(&self) -> Option<&ArtifactBytes> {
        match &self.data {
            Storage::Owned(_) => None,
            Storage::Shared(view) => Some(view.backing()),
        }
    }

    /// The underlying [`ByteView`] of a zero-copy tensor — lets lazy GEMM
    /// plans ([`crate::gemm::PreparedGemm::new_lazy`]) pack panels straight
    /// from the shared artifact bytes without an intermediate owned copy.
    pub fn view(&self) -> Option<&ByteView> {
        match &self.data {
            Storage::Owned(_) => None,
            Storage::Shared(view) => Some(view),
        }
    }
}

impl Tensor<f32> {
    /// Min and max of the elements (0.0 for empty tensors).
    pub fn min_max(&self) -> (f32, f32) {
        if self.data.is_empty() {
            return (0.0, 0.0);
        }
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &self.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        (mn, mx)
    }

    /// Largest absolute elementwise difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor<f32>) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl Tensor<u8> {
    /// Largest absolute elementwise difference in quantized units (LSBs).
    pub fn max_lsb_diff(&self, other: &Tensor<u8>) -> i32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (i32::from(*a) - i32::from(*b)).abs())
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t: Tensor<f32> = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(t.len(), 120);
        assert_eq!(t.shape(), &[2, 3, 4, 5]);
        assert_eq!(t.rank(), 4);
        assert_eq!(t.dim(3), 5);
    }

    #[test]
    fn nhwc_indexing_is_row_major() {
        let mut t: Tensor<i32> = Tensor::zeros(&[2, 2, 2, 3]);
        t.set4(1, 0, 1, 2, 42);
        assert_eq!(t.at4(1, 0, 1, 2), 42);
        // Channel is innermost.
        assert_eq!(t.idx4(0, 0, 0, 1) - t.idx4(0, 0, 0, 0), 1);
        assert_eq!(t.idx4(0, 0, 1, 0) - t.idx4(0, 0, 0, 0), 3);
        assert_eq!(t.idx4(0, 1, 0, 0) - t.idx4(0, 0, 0, 0), 6);
        assert_eq!(t.idx4(1, 0, 0, 0) - t.idx4(0, 0, 0, 0), 12);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn from_vec_checks_volume() {
        let _ = Tensor::from_vec(&[2, 2], vec![1f32; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 6], (0..12).collect::<Vec<i32>>());
        let r = t.clone().reshape(&[3, 4]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 4]);
    }

    #[test]
    fn reset_reuses_allocation_and_zeroes() {
        let mut t = Tensor::from_vec(&[2, 3], vec![7u8; 6]);
        t.reset(&[1, 4]);
        assert_eq!(t.shape(), &[1, 4]);
        assert_eq!(t.data(), &[0u8; 4]);
        // Growing within a prior high-water mark must not lose elements.
        t.reset(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn reset_for_overwrite_keeps_stale_contents_but_fixes_geometry() {
        let mut t = Tensor::from_vec(&[2, 2], vec![9u8; 4]);
        t.reset_for_overwrite(&[4, 1]);
        assert_eq!(t.shape(), &[4, 1]);
        assert_eq!(t.data(), &[9u8; 4], "same volume: contents untouched");
        t.reset_for_overwrite(&[2, 3]);
        assert_eq!(t.len(), 6, "grown to the new volume");
    }

    #[test]
    fn reset_for_overwrite_last_dim_overrides_channel_count() {
        let mut t = Tensor::from_vec(&[2, 2], vec![9u8; 4]);
        t.reset_for_overwrite_last_dim(&[2, 3], 5);
        assert_eq!(t.shape(), &[2, 5]);
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn map_changes_type() {
        let t = Tensor::from_vec(&[4], vec![1u8, 2, 3, 255]);
        let f = t.map(|v| f32::from(v) / 255.0);
        assert!((f.data()[3] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn view_tensor_reads_shared_bytes_and_compares_equal() {
        let buf = ArtifactBytes::from_vec((0..24u8).collect());
        let v = Tensor::from_view(&[2, 3, 4], buf.view(0, 24));
        assert!(v.is_view());
        assert!(v.backing().is_some());
        assert_eq!(v.len(), 24);
        assert_eq!(v.data()[5], 5);
        let owned = Tensor::from_vec(&[2, 3, 4], (0..24u8).collect());
        assert_eq!(v, owned, "views and owned tensors compare by contents");
        // Offset views see the right window.
        let w = Tensor::from_view(&[4], buf.view(20, 4));
        assert_eq!(w.data(), &[20, 21, 22, 23]);
    }

    #[test]
    fn view_tensor_detaches_on_write() {
        let buf = ArtifactBytes::from_vec(vec![9u8; 8]);
        let mut t = Tensor::from_view(&[8], buf.view(0, 8));
        t.data_mut()[0] = 1;
        assert!(!t.is_view(), "mutation must detach the view");
        assert_eq!(t.data()[0], 1);
        assert_eq!(buf.as_slice()[0], 9, "the shared buffer is untouched");
        // into_data on a live view copies too.
        let t2 = Tensor::from_view(&[8], buf.view(0, 8));
        assert_eq!(t2.into_data(), vec![9u8; 8]);
    }

    #[test]
    #[should_panic(expected = "does not match view length")]
    fn from_view_checks_volume() {
        let buf = ArtifactBytes::from_vec(vec![0u8; 6]);
        let _ = Tensor::from_view(&[2, 2], buf.view(0, 6));
    }

    #[test]
    fn min_max_and_diffs() {
        let a = Tensor::from_vec(&[3], vec![-1.5f32, 0.0, 2.5]);
        assert_eq!(a.min_max(), (-1.5, 2.5));
        let b = Tensor::from_vec(&[3], vec![-1.0f32, 0.5, 2.5]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        let q1 = Tensor::from_vec(&[2], vec![10u8, 250]);
        let q2 = Tensor::from_vec(&[2], vec![12u8, 245]);
        assert_eq!(q1.max_lsb_diff(&q2), 5);
    }
}
