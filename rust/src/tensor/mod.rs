//! Minimal dense tensor type used throughout the engine.
//!
//! Activations are NHWC (batch, height, width, channel) and weights are
//! OHWI (output channel, kernel h, kernel w, input channel) — the layouts
//! TFLite uses and the ones that make the im2col → GEMM lowering in
//! [`crate::nn`] contiguous along the reduction dimension.



/// A dense row-major tensor over element type `T`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-initialized (default-initialized) tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    /// Wrap existing data; `data.len()` must equal the shape volume.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], value: T) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![value; n] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Reinterpret with a new shape of identical volume.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Re-shape in place to `shape`, resetting every element to the default
    /// value. Unlike [`Self::zeros`] this reuses the existing allocation when
    /// capacity allows, so a tensor cycled through the same shapes performs
    /// no heap allocation after the first pass — the property the prepared
    /// execution path ([`crate::graph::PreparedGraph`]) relies on for its
    /// zero-alloc steady state.
    pub fn reset(&mut self, shape: &[usize]) {
        let n = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        self.data.clear();
        self.data.resize(n, T::default());
    }

    /// [`Self::reset`] without the element fill: prior contents (up to the
    /// old length) are left in place, so the caller **must overwrite every
    /// element**. This skips a full memset pass per call — the prepared
    /// layer paths use it because they write each output element exactly
    /// once.
    pub fn reset_for_overwrite(&mut self, shape: &[usize]) {
        let n = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        if self.data.len() != n {
            self.data.resize(n, T::default());
        }
    }

    /// [`Self::reset_for_overwrite`] with the last dimension overridden:
    /// the geometry becomes `shape[..rank-1] + [last]`. Lets the channel
    /// concat shape its output without building a temporary shape `Vec`
    /// (the zero-alloc steady state of [`crate::graph::PreparedGraph`]).
    pub fn reset_for_overwrite_last_dim(&mut self, shape: &[usize], last: usize) {
        assert!(!shape.is_empty(), "need at least one dimension to override");
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        *self.shape.last_mut().expect("non-empty shape") = last;
        let n = self.shape.iter().product();
        if self.data.len() != n {
            self.data.resize(n, T::default());
        }
    }

    /// Size of dimension `i`.
    #[inline]
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Rank (number of dimensions).
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Flat index for an NHWC coordinate (rank-4 tensors).
    #[inline]
    pub fn idx4(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((n * self.shape[1] + h) * self.shape[2] + w) * self.shape[3] + c
    }

    /// NHWC element access.
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> T {
        self.data[self.idx4(n, h, w, c)]
    }

    /// NHWC element write.
    #[inline]
    pub fn set4(&mut self, n: usize, h: usize, w: usize, c: usize, v: T) {
        let i = self.idx4(n, h, w, c);
        self.data[i] = v;
    }

    /// Map every element through `f` into a new tensor (possibly new type).
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&v| f(v)).collect() }
    }
}

impl Tensor<f32> {
    /// Min and max of the elements (0.0 for empty tensors).
    pub fn min_max(&self) -> (f32, f32) {
        if self.data.is_empty() {
            return (0.0, 0.0);
        }
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &self.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        (mn, mx)
    }

    /// Largest absolute elementwise difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor<f32>) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl Tensor<u8> {
    /// Largest absolute elementwise difference in quantized units (LSBs).
    pub fn max_lsb_diff(&self, other: &Tensor<u8>) -> i32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (i32::from(*a) - i32::from(*b)).abs())
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t: Tensor<f32> = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(t.len(), 120);
        assert_eq!(t.shape(), &[2, 3, 4, 5]);
        assert_eq!(t.rank(), 4);
        assert_eq!(t.dim(3), 5);
    }

    #[test]
    fn nhwc_indexing_is_row_major() {
        let mut t: Tensor<i32> = Tensor::zeros(&[2, 2, 2, 3]);
        t.set4(1, 0, 1, 2, 42);
        assert_eq!(t.at4(1, 0, 1, 2), 42);
        // Channel is innermost.
        assert_eq!(t.idx4(0, 0, 0, 1) - t.idx4(0, 0, 0, 0), 1);
        assert_eq!(t.idx4(0, 0, 1, 0) - t.idx4(0, 0, 0, 0), 3);
        assert_eq!(t.idx4(0, 1, 0, 0) - t.idx4(0, 0, 0, 0), 6);
        assert_eq!(t.idx4(1, 0, 0, 0) - t.idx4(0, 0, 0, 0), 12);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn from_vec_checks_volume() {
        let _ = Tensor::from_vec(&[2, 2], vec![1f32; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 6], (0..12).collect::<Vec<i32>>());
        let r = t.clone().reshape(&[3, 4]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 4]);
    }

    #[test]
    fn reset_reuses_allocation_and_zeroes() {
        let mut t = Tensor::from_vec(&[2, 3], vec![7u8; 6]);
        t.reset(&[1, 4]);
        assert_eq!(t.shape(), &[1, 4]);
        assert_eq!(t.data(), &[0u8; 4]);
        // Growing within a prior high-water mark must not lose elements.
        t.reset(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn reset_for_overwrite_keeps_stale_contents_but_fixes_geometry() {
        let mut t = Tensor::from_vec(&[2, 2], vec![9u8; 4]);
        t.reset_for_overwrite(&[4, 1]);
        assert_eq!(t.shape(), &[4, 1]);
        assert_eq!(t.data(), &[9u8; 4], "same volume: contents untouched");
        t.reset_for_overwrite(&[2, 3]);
        assert_eq!(t.len(), 6, "grown to the new volume");
    }

    #[test]
    fn reset_for_overwrite_last_dim_overrides_channel_count() {
        let mut t = Tensor::from_vec(&[2, 2], vec![9u8; 4]);
        t.reset_for_overwrite_last_dim(&[2, 3], 5);
        assert_eq!(t.shape(), &[2, 5]);
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn map_changes_type() {
        let t = Tensor::from_vec(&[4], vec![1u8, 2, 3, 255]);
        let f = t.map(|v| f32::from(v) / 255.0);
        assert!((f.data()[3] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn min_max_and_diffs() {
        let a = Tensor::from_vec(&[3], vec![-1.5f32, 0.0, 2.5]);
        assert_eq!(a.min_max(), (-1.5, 2.5));
        let b = Tensor::from_vec(&[3], vec![-1.0f32, 0.5, 2.5]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        let q1 = Tensor::from_vec(&[2], vec![10u8, 250]);
        let q2 = Tensor::from_vec(&[2], vec![12u8, 245]);
        assert_eq!(q1.max_lsb_diff(&q2), 5);
    }
}
