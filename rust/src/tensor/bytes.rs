//! Shared backing buffers for zero-copy artifact loading.
//!
//! [`ArtifactBytes`] owns the raw bytes of a loaded `.iaoiq` artifact —
//! either an ordinary heap allocation or (on 64-bit unix) a read-only
//! `mmap` of the artifact file — behind a cheap `Arc` handle. A
//! [`ByteView`] is a `(buffer, offset, len)` triple into such a buffer;
//! [`super::Tensor::from_view`] wraps one as borrowed tensor storage, which
//! is how [`crate::model_format::load_shared`] hands out weight tensors
//! that alias the artifact bytes instead of copying them: the loaded graph
//! then holds the buffer alive through its views, and loading a model no
//! longer transiently doubles its weight bytes on the heap.
//!
//! The mmap variant uses direct `extern "C"` declarations of `mmap` /
//! `munmap` (this build is offline and takes no crates.io dependencies)
//! and falls back transparently to a heap read when mapping is unavailable
//! (non-unix target, 32-bit, empty file, or a failed `mmap` call). As with
//! any file mapping, truncating the file while a mapping is live is
//! undefined behaviour at the OS level (SIGBUS on access); artifacts are
//! immutable deployment units, so swaps write new files instead of
//! rewriting mapped ones.

use std::fmt;
use std::io;
use std::path::Path;
use std::sync::Arc;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use core::ffi::c_void;

    pub const PROT_READ: i32 = 0x1;
    pub const MAP_PRIVATE: i32 = 0x02;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, length: usize) -> i32;
    }
}

/// A read-only private file mapping, unmapped on drop.
#[cfg(all(unix, target_pointer_width = "64"))]
struct MmapRegion {
    ptr: *mut core::ffi::c_void,
    len: usize,
}

// SAFETY: the region is mapped PROT_READ and never written through; sharing
// immutable reads across threads is sound, and munmap happens exactly once
// (Drop of the uniquely-owned region inside the Arc'd Backing).
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for MmapRegion {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for MmapRegion {}

#[cfg(all(unix, target_pointer_width = "64"))]
impl MmapRegion {
    fn as_slice(&self) -> &[u8] {
        // SAFETY: ptr/len describe a live PROT_READ mapping owned by self.
        unsafe { std::slice::from_raw_parts(self.ptr.cast::<u8>(), self.len) }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for MmapRegion {
    fn drop(&mut self) {
        // SAFETY: exactly the (addr, len) pair returned by a successful mmap.
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

enum Backing {
    Heap(Box<[u8]>),
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mmap(MmapRegion),
}

impl Backing {
    fn as_slice(&self) -> &[u8] {
        match self {
            Backing::Heap(b) => b,
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mmap(m) => m.as_slice(),
        }
    }
}

/// Immutable shared byte buffer backing a loaded artifact. Clones share the
/// same storage (`Arc`), so handing a buffer to every weight view of a
/// graph costs one reference count per view, not one copy.
#[derive(Clone)]
pub struct ArtifactBytes {
    inner: Arc<Backing>,
}

impl ArtifactBytes {
    /// Wrap an in-memory byte vector (heap backing).
    pub fn from_vec(bytes: Vec<u8>) -> Self {
        Self { inner: Arc::new(Backing::Heap(bytes.into_boxed_slice())) }
    }

    /// Read a whole file into a heap backing.
    pub fn read_file(path: &Path) -> io::Result<Self> {
        Ok(Self::from_vec(std::fs::read(path)?))
    }

    /// Map a file read-only. Falls back transparently to [`Self::read_file`]
    /// when mapping is unavailable (non-unix target, empty file, or a failed
    /// `mmap`); check [`Self::is_mapped`] to see which backing was used.
    /// Errors only on real I/O failures (missing file, permissions).
    pub fn map_file(path: &Path) -> io::Result<Self> {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Some(mapped) = Self::try_mmap(path)? {
            return Ok(mapped);
        }
        Self::read_file(path)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn try_mmap(path: &Path) -> io::Result<Option<Self>> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        // mmap(len = 0) is EINVAL; tiny files gain nothing from a mapping
        // either, but keeping the cutoff at zero makes the mode observable.
        if len == 0 {
            return Ok(None);
        }
        let len = usize::try_from(len).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidData, "file too large to map")
        })?;
        // SAFETY: a fresh PROT_READ/MAP_PRIVATE mapping of a file we hold
        // open; the fd may close after mmap returns (POSIX keeps the
        // mapping alive until munmap).
        let ptr = unsafe {
            sys::mmap(std::ptr::null_mut(), len, sys::PROT_READ, sys::MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr.is_null() || ptr as isize == -1 {
            return Ok(None); // MAP_FAILED — fall back to the heap read.
        }
        Ok(Some(Self { inner: Arc::new(Backing::Mmap(MmapRegion { ptr, len })) }))
    }

    /// True when the bytes come from a live file mapping rather than the
    /// heap.
    pub fn is_mapped(&self) -> bool {
        match &*self.inner {
            Backing::Heap(_) => false,
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mmap(_) => true,
        }
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        self.inner.as_slice()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A view of `len` bytes starting at `offset`. Panics when the range is
    /// out of bounds — view construction is producer-side code
    /// ([`crate::model_format`]) operating on ranges it already
    /// bounds-checked against the buffer.
    pub fn view(&self, offset: usize, len: usize) -> ByteView {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= self.len()),
            "view {offset}+{len} out of bounds for buffer of {}",
            self.len()
        );
        ByteView { buf: self.clone(), offset, len }
    }
}

impl fmt::Debug for ArtifactBytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ArtifactBytes")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

/// A borrowed sub-range of an [`ArtifactBytes`] buffer. Holding a view
/// keeps the whole buffer alive.
#[derive(Clone, Debug)]
pub struct ByteView {
    buf: ArtifactBytes,
    offset: usize,
    len: usize,
}

impl ByteView {
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        &self.buf.as_slice()[self.offset..self.offset + self.len]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The buffer this view borrows from.
    pub fn backing(&self) -> &ArtifactBytes {
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_roundtrip_and_views() {
        let buf = ArtifactBytes::from_vec((0..=255u8).collect());
        assert_eq!(buf.len(), 256);
        assert!(!buf.is_mapped());
        let v = buf.view(10, 5);
        assert_eq!(v.as_slice(), &[10, 11, 12, 13, 14]);
        assert_eq!(v.len(), 5);
        // Clones alias the same storage.
        let c = buf.clone();
        assert_eq!(c.as_slice().as_ptr(), buf.as_slice().as_ptr());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_view_panics() {
        let buf = ArtifactBytes::from_vec(vec![0u8; 4]);
        let _ = buf.view(2, 3);
    }

    #[test]
    fn view_keeps_buffer_alive() {
        let v = {
            let buf = ArtifactBytes::from_vec(vec![7u8; 32]);
            buf.view(0, 32)
        };
        assert!(v.as_slice().iter().all(|&b| b == 7));
    }

    #[test]
    fn map_file_reads_file_contents() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("iaoi-bytes-test-{}.bin", std::process::id()));
        std::fs::write(&path, [1u8, 2, 3, 4, 5]).unwrap();
        let mapped = ArtifactBytes::map_file(&path).unwrap();
        assert_eq!(mapped.as_slice(), &[1, 2, 3, 4, 5]);
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(mapped.is_mapped(), "64-bit unix should take the mmap path");
        let heap = ArtifactBytes::read_file(&path).unwrap();
        assert!(!heap.is_mapped());
        assert_eq!(heap.as_slice(), mapped.as_slice());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_falls_back_to_heap() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("iaoi-bytes-empty-{}.bin", std::process::id()));
        std::fs::write(&path, []).unwrap();
        let buf = ArtifactBytes::map_file(&path).unwrap();
        assert!(buf.is_empty());
        assert!(!buf.is_mapped());
        let _ = std::fs::remove_file(&path);
    }
}
