//! Minimal blocking HTTP/1.1 client for the serving front end.
//!
//! Std-only counterpart to [`super::protocol`]: just enough HTTP to drive
//! `iaoi serve --addr` from the integration tests, the loadgen bench
//! (`benches/serving.rs`) and the CI smoke probe — one code path for all
//! three, so a protocol change cannot silently desynchronize them. Not a
//! general client: no chunked encoding, no redirects, no TLS.

use super::protocol::{encode_f32_body, find_head_end};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A parsed HTTP response.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// Lower-cased header names with trimmed values.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// First value of `name` (case-insensitive), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Body decoded as UTF-8 (lossy; for JSON/text endpoints).
    pub fn body_text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    /// Body decoded as raw little-endian `f32`s (for infer responses).
    pub fn body_f32(&self) -> Result<Vec<f32>> {
        if self.body.len() % 4 != 0 {
            bail!("response body length {} is not a multiple of 4", self.body.len());
        }
        Ok(self
            .body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// One keep-alive connection to the server.
pub struct HttpClient {
    stream: TcpStream,
    /// Bytes read past the previous response (keep-alive pipelining).
    leftover: Vec<u8>,
}

impl HttpClient {
    /// Connect with a sane default timeout for tests/benches.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let addr = addr
            .to_socket_addrs()
            .context("resolving server address")?
            .next()
            .ok_or_else(|| anyhow!("server address resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(5))
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .context("setting read timeout")?;
        Ok(Self { stream, leftover: Vec::new() })
    }

    /// Send raw bytes as-is (malformed-input tests).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes).context("writing raw request")?;
        self.stream.flush().ok();
        Ok(())
    }

    /// Issue one request and read the full response.
    pub fn request(&mut self, method: &str, target: &str, body: &[u8]) -> Result<HttpResponse> {
        self.request_with_headers(method, target, &[], body)
    }

    /// [`Self::request`] with extra `(name, value)` header pairs (e.g.
    /// `X-Deadline-Ms`).
    pub fn request_with_headers(
        &mut self,
        method: &str,
        target: &str,
        headers: &[(&str, String)],
        body: &[u8],
    ) -> Result<HttpResponse> {
        let mut req = format!("{method} {target} HTTP/1.1\r\nHost: iaoi\r\n");
        for (name, value) in headers {
            req.push_str(&format!("{name}: {value}\r\n"));
        }
        if method == "POST" || !body.is_empty() {
            req.push_str(&format!("Content-Length: {}\r\n", body.len()));
        }
        req.push_str("\r\n");
        self.stream.write_all(req.as_bytes()).context("writing request head")?;
        self.stream.write_all(body).context("writing request body")?;
        self.stream.flush().ok();
        self.read_response()
    }

    pub fn get(&mut self, target: &str) -> Result<HttpResponse> {
        self.request("GET", target, &[])
    }

    /// `POST /infer/<model>` with an f32 tensor body.
    pub fn infer(&mut self, model: &str, values: &[f32]) -> Result<HttpResponse> {
        let body = encode_f32_body(values);
        self.request("POST", &format!("/infer/{model}"), &body)
    }

    /// [`Self::infer`] carrying an `X-Deadline-Ms` completion budget.
    pub fn infer_with_deadline_ms(
        &mut self,
        model: &str,
        values: &[f32],
        deadline_ms: u64,
    ) -> Result<HttpResponse> {
        let body = encode_f32_body(values);
        self.request_with_headers(
            "POST",
            &format!("/infer/{model}"),
            &[("X-Deadline-Ms", deadline_ms.to_string())],
            &body,
        )
    }

    /// Read one full response (head + Content-Length body) off the stream.
    pub fn read_response(&mut self) -> Result<HttpResponse> {
        let mut buf = std::mem::take(&mut self.leftover);
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(end) = find_head_end(&buf) {
                break end;
            }
            if buf.len() > 64 * 1024 {
                bail!("response head too large");
            }
            let n = self.stream.read(&mut chunk).context("reading response")?;
            if n == 0 {
                bail!("connection closed mid-response ({} bytes in)", buf.len());
            }
            buf.extend_from_slice(&chunk[..n]);
        };

        let head = std::str::from_utf8(&buf[..head_end]).context("non-UTF-8 response head")?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split_ascii_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("malformed status line {status_line:?}"))?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let Some((name, value)) = line.split_once(':') else { continue };
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().context("parsing Content-Length")?;
            }
            headers.push((name, value));
        }

        let mut body = buf[head_end..].to_vec();
        while body.len() < content_length {
            let n = self.stream.read(&mut chunk).context("reading response body")?;
            if n == 0 {
                bail!("connection closed mid-body ({}/{content_length})", body.len());
            }
            body.extend_from_slice(&chunk[..n]);
        }
        // Anything past the declared body belongs to the next response.
        self.leftover = body.split_off(content_length);
        Ok(HttpResponse { status, headers, body })
    }
}
