//! Minimal HTTP/1.1 wire protocol for the serving front end.
//!
//! The server speaks a deliberately small, std-only subset of HTTP/1.1 —
//! enough that `curl` and any stock HTTP client can drive it, with none of
//! a general server's surface. The contract (documented in
//! `EXPERIMENTS.md § Serving`):
//!
//! * `POST /infer/<model>` — body is the input tensor as raw
//!   little-endian `f32` values in NHWC order, exactly `H·W·C` of them
//!   (the model's input geometry; see `GET /healthz`). The response body
//!   is the output logits, again raw little-endian `f32`. Response
//!   headers `X-Model-Version`, `X-Batch-Size` and `X-Latency-Us` echo
//!   serving observables.
//! * `GET /healthz` — JSON: overall `status` (`serving` | `draining`)
//!   plus one entry per model. Resident models carry name, version, input
//!   shape, per-model status, `resident` (`resident` | `evicting`),
//!   `load_mode` (`copy` | `zerocopy` | `mmap`), `plan_bytes` (packed-plan
//!   heap footprint; 0 for an untouched lazy plan), fused-epilogue node
//!   count and in-flight count. Evicted-but-reinstallable models appear
//!   with `"resident":"cold"` and the version/load mode they left with.
//! * `GET /metrics` — Prometheus text exposition of the coordinator's
//!   per-model latency histograms, batch stats, admission counters, and
//!   fleet lifecycle gauges (`iaoi_resident_models`,
//!   `iaoi_evictions_total`, `iaoi_plan_bytes{model=…}`).
//!
//! Error mapping: 400 malformed request or wrong body size, 404 unknown
//! model/path, 405 wrong method, 413 oversized body, 500 contained worker
//! panic (`{"error":"internal"}`), 503 shed (with `Retry-After` and a JSON
//! `retry_after_ms` payload), draining, quarantined model, or acceptor
//! over capacity, 504 deadline expired before execution.
//!
//! Requests may carry `X-Deadline-Ms: <n>` — a completion budget in
//! milliseconds from arrival; past it the request is shed pre-execution
//! with 504 instead of burning engine time on an answer nobody awaits.
//!
//! Parsing is a pure function over bytes ([`parse_head`]) so malformed
//! input handling is unit-testable without sockets. Limits: request head
//! ≤ [`MAX_HEAD_BYTES`], body ≤ the server's configured cap. A parse
//! error poisons only its own connection — the acceptor and other
//! connections are untouched.

use std::io::Write;

/// Cap on the request line + headers (pre-body) section.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Default cap on request bodies; [`crate::serve::ServeConfig`] can lower
/// it. 16 MiB ≫ any realistic input tensor for these models.
pub const DEFAULT_MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// How a request head failed to parse.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProtoError {
    /// Not even a recognizable HTTP request line.
    BadRequestLine,
    /// Header section malformed (non-UTF-8, missing `:`, …).
    BadHeader,
    /// `Content-Length` missing on a method that requires it, or unparsable.
    BadContentLength,
    /// Declared body length exceeds the server cap.
    BodyTooLarge { declared: usize, cap: usize },
    /// Head grew past [`MAX_HEAD_BYTES`] without a blank line.
    HeadTooLarge,
    /// `X-Deadline-Ms` present but not a non-negative integer.
    BadDeadline,
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::BadRequestLine => write!(f, "malformed request line"),
            ProtoError::BadHeader => write!(f, "malformed header"),
            ProtoError::BadContentLength => write!(f, "missing or malformed Content-Length"),
            ProtoError::BodyTooLarge { declared, cap } => {
                write!(f, "declared body of {declared} bytes exceeds cap of {cap}")
            }
            ProtoError::HeadTooLarge => write!(f, "request head exceeds {MAX_HEAD_BYTES} bytes"),
            ProtoError::BadDeadline => {
                write!(f, "X-Deadline-Ms must be a non-negative integer of milliseconds")
            }
        }
    }
}

/// A parsed request head (everything before the body).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RequestHead {
    pub method: String,
    /// Request target as sent (e.g. `/infer/alpha`).
    pub target: String,
    /// Declared body length (0 when absent on GET).
    pub content_length: usize,
    /// False when the client sent `Connection: close`.
    pub keep_alive: bool,
    /// Per-request deadline budget from `X-Deadline-Ms`, in milliseconds
    /// from arrival. `None` = header absent (the server applies its
    /// configured default). 0 is legal and means "already expired" —
    /// useful for probing the shed path.
    pub deadline_ms: Option<u64>,
}

/// Locate the end of the head (`\r\n\r\n`) in `buf`, returning the offset
/// *past* the terminator. `None` = need more bytes.
pub fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Parse the head section `head` (which must end with `\r\n\r\n`; pass the
/// slice up to [`find_head_end`]). `max_body` bounds the declared
/// `Content-Length`.
pub fn parse_head(head: &[u8], max_body: usize) -> Result<RequestHead, ProtoError> {
    let text = std::str::from_utf8(head).map_err(|_| ProtoError::BadHeader)?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().ok_or(ProtoError::BadRequestLine)?;
    let mut parts = request_line.split_ascii_whitespace();
    let method = parts.next().ok_or(ProtoError::BadRequestLine)?;
    let target = parts.next().ok_or(ProtoError::BadRequestLine)?;
    let version = parts.next().ok_or(ProtoError::BadRequestLine)?;
    if parts.next().is_some() || !version.starts_with("HTTP/1.") || !target.starts_with('/') {
        return Err(ProtoError::BadRequestLine);
    }
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(ProtoError::BadRequestLine);
    }

    let mut content_length: Option<usize> = None;
    let mut keep_alive = true;
    let mut deadline_ms: Option<u64> = None;
    for line in lines {
        if line.is_empty() {
            continue; // the blank terminator line(s)
        }
        let (name, value) = line.split_once(':').ok_or(ProtoError::BadHeader)?;
        let name = name.trim();
        let value = value.trim();
        if name.is_empty() {
            return Err(ProtoError::BadHeader);
        }
        if name.eq_ignore_ascii_case("content-length") {
            let n: usize = value.parse().map_err(|_| ProtoError::BadContentLength)?;
            content_length = Some(n);
        } else if name.eq_ignore_ascii_case("connection") && value.eq_ignore_ascii_case("close") {
            keep_alive = false;
        } else if name.eq_ignore_ascii_case("x-deadline-ms") {
            let ms: u64 = value.parse().map_err(|_| ProtoError::BadDeadline)?;
            deadline_ms = Some(ms);
        }
    }

    let content_length = match (method, content_length) {
        // POST must declare a length (no chunked encoding in this subset).
        ("POST", None) => return Err(ProtoError::BadContentLength),
        ("POST", Some(n)) => n,
        (_, n) => n.unwrap_or(0),
    };
    if content_length > max_body {
        return Err(ProtoError::BodyTooLarge { declared: content_length, cap: max_body });
    }

    Ok(RequestHead {
        method: method.to_string(),
        target: target.to_string(),
        content_length,
        keep_alive,
        deadline_ms,
    })
}

/// An HTTP response under construction.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub reason: &'static str,
    /// `(name, value)` pairs beyond the always-present Content-Length /
    /// Content-Type / Connection.
    pub headers: Vec<(String, String)>,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// When false the server closes the connection after writing.
    pub keep_alive: bool,
}

impl Response {
    pub fn new(status: u16, reason: &'static str) -> Self {
        Self {
            status,
            reason,
            headers: Vec::new(),
            content_type: "application/octet-stream",
            body: Vec::new(),
            keep_alive: true,
        }
    }

    pub fn json(status: u16, reason: &'static str, body: String) -> Self {
        let mut r = Self::new(status, reason);
        r.content_type = "application/json";
        r.body = body.into_bytes();
        r
    }

    pub fn text(status: u16, reason: &'static str, body: String) -> Self {
        let mut r = Self::new(status, reason);
        r.content_type = "text/plain; charset=utf-8";
        r.body = body.into_bytes();
        r
    }

    pub fn octets(status: u16, reason: &'static str, body: Vec<u8>) -> Self {
        let mut r = Self::new(status, reason);
        r.body = body;
        r
    }

    pub fn header(mut self, name: &str, value: impl std::fmt::Display) -> Self {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    pub fn close(mut self) -> Self {
        self.keep_alive = false;
        self
    }

    /// Serialize onto `w` (a `TcpStream` in production, a `Vec<u8>` in
    /// tests).
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, self.reason)?;
        write!(w, "Content-Type: {}\r\n", self.content_type)?;
        write!(w, "Content-Length: {}\r\n", self.body.len())?;
        if !self.keep_alive {
            write!(w, "Connection: close\r\n")?;
        }
        for (name, value) in &self.headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Standard error responses (one place so tests and handlers agree).
pub fn bad_request(msg: &str) -> Response {
    Response::json(400, "Bad Request", format!("{{\"error\":{}}}", json_string(msg))).close()
}

pub fn not_found(msg: &str) -> Response {
    Response::json(404, "Not Found", format!("{{\"error\":{}}}", json_string(msg)))
}

pub fn method_not_allowed() -> Response {
    Response::json(405, "Method Not Allowed", "{\"error\":\"method not allowed\"}".to_string())
}

pub fn payload_too_large(declared: usize, cap: usize) -> Response {
    Response::json(
        413,
        "Payload Too Large",
        format!("{{\"error\":\"body of {declared} bytes exceeds cap of {cap}\"}}"),
    )
    .close()
}

/// 503 for a shed request: machine-readable retry hint in both the
/// `Retry-After` header (whole seconds, HTTP convention, rounded up) and a
/// JSON `retry_after_ms` field (the precise value).
pub fn overloaded(retry_after_ms: u64, scope: &str) -> Response {
    let retry_after_s = retry_after_ms.div_ceil(1000).max(1);
    Response::json(
        503,
        "Service Unavailable",
        format!("{{\"error\":\"overloaded\",\"scope\":\"{scope}\",\"retry_after_ms\":{retry_after_ms}}}"),
    )
    .header("Retry-After", retry_after_s)
}

/// 503 for a draining server/model: not retryable on this connection.
pub fn draining(scope: &str) -> Response {
    Response::json(
        503,
        "Service Unavailable",
        format!("{{\"error\":\"draining\",\"scope\":\"{scope}\"}}"),
    )
    .close()
}

/// 500 for a request whose batch panicked inside the engine. The panic was
/// contained worker-side, so the connection stays usable: keep-alive.
pub fn internal_error() -> Response {
    Response::json(500, "Internal Server Error", "{\"error\":\"internal\"}".to_string())
}

/// 503 for a model the circuit breaker has quarantined. Keep-alive: other
/// models on the same connection are still healthy.
pub fn quarantined(model: &str) -> Response {
    Response::json(
        503,
        "Service Unavailable",
        format!("{{\"error\":\"quarantined\",\"model\":{}}}", json_string(model)),
    )
}

/// 504 for a request shed because its deadline expired before execution.
pub fn deadline_exceeded() -> Response {
    Response::json(504, "Gateway Timeout", "{\"error\":\"deadline_exceeded\"}".to_string())
}

/// 503 written by the acceptor when `--max-connections` is saturated; the
/// connection is closed immediately so the slot frees up.
pub fn over_capacity(retry_after_ms: u64) -> Response {
    let retry_after_s = retry_after_ms.div_ceil(1000).max(1);
    Response::json(
        503,
        "Service Unavailable",
        format!("{{\"error\":\"over_capacity\",\"retry_after_ms\":{retry_after_ms}}}"),
    )
    .header("Retry-After", retry_after_s)
    .close()
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Decode an infer body: raw little-endian `f32`s, expecting exactly
/// `want_values` of them.
pub fn decode_f32_body(body: &[u8], want_values: usize) -> Result<Vec<f32>, String> {
    if body.len() != want_values * 4 {
        return Err(format!(
            "body must be {} bytes ({} little-endian f32 values), got {}",
            want_values * 4,
            want_values,
            body.len()
        ));
    }
    Ok(body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Encode `values` as raw little-endian `f32` bytes.
pub fn encode_f32_body(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 4);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_of(raw: &str) -> Result<RequestHead, ProtoError> {
        parse_head(raw.as_bytes(), DEFAULT_MAX_BODY_BYTES)
    }

    #[test]
    fn parses_a_post() {
        let h = head_of(
            "POST /infer/alpha HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\n\r\n",
        )
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.target, "/infer/alpha");
        assert_eq!(h.content_length, 12);
        assert!(h.keep_alive);
    }

    #[test]
    fn parses_a_get_without_length() {
        let h = head_of("GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(h.method, "GET");
        assert_eq!(h.content_length, 0);
    }

    #[test]
    fn connection_close_is_honored() {
        let h = head_of("GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!h.keep_alive);
    }

    #[test]
    fn garbage_is_rejected_not_panicked() {
        for raw in [
            "\r\n\r\n",
            "garbage\r\n\r\n",
            "GET\r\n\r\n",
            "GET /x\r\n\r\n",
            "GET /x SPDY/99\r\n\r\n",
            "get /x HTTP/1.1\r\n\r\n",
            "GET x HTTP/1.1\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
        ] {
            assert_eq!(head_of(raw), Err(ProtoError::BadRequestLine), "{raw:?}");
        }
        assert_eq!(
            head_of("GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n"),
            Err(ProtoError::BadHeader)
        );
        assert_eq!(
            parse_head(&[0xff, 0xfe, 0x0d, 0x0a, 0x0d, 0x0a], 1024),
            Err(ProtoError::BadHeader),
            "non-UTF-8 head"
        );
    }

    #[test]
    fn post_requires_content_length() {
        assert_eq!(
            head_of("POST /infer/a HTTP/1.1\r\n\r\n"),
            Err(ProtoError::BadContentLength)
        );
        assert_eq!(
            head_of("POST /infer/a HTTP/1.1\r\nContent-Length: many\r\n\r\n"),
            Err(ProtoError::BadContentLength)
        );
    }

    #[test]
    fn oversized_declared_body_is_rejected_up_front() {
        let r = parse_head(
            b"POST /infer/a HTTP/1.1\r\nContent-Length: 99999\r\n\r\n",
            1024,
        );
        assert_eq!(r, Err(ProtoError::BodyTooLarge { declared: 99999, cap: 1024 }));
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n"), None);
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nBODY"), Some(18));
    }

    #[test]
    fn response_serialization_round_trips() {
        let mut buf = Vec::new();
        Response::octets(200, "OK", vec![1, 2, 3])
            .header("X-Model-Version", 7)
            .write_to(&mut buf)
            .unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 3\r\n"));
        assert!(text.contains("X-Model-Version: 7\r\n"));
        assert!(buf.ends_with(&[1, 2, 3]));
    }

    #[test]
    fn overload_response_carries_retry_after() {
        let mut buf = Vec::new();
        overloaded(25, "global").write_to(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.contains("\"retry_after_ms\":25"), "{text}");
        assert!(text.contains("503"), "{text}");
    }

    #[test]
    fn f32_body_round_trips_bit_exactly() {
        let values = [0.0f32, -1.5, f32::MIN_POSITIVE, 3.22e8, f32::NEG_INFINITY];
        let bytes = encode_f32_body(&values);
        let back = decode_f32_body(&bytes, values.len()).unwrap();
        for (a, b) in values.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(decode_f32_body(&bytes[..bytes.len() - 1], values.len()).is_err());
    }

    #[test]
    fn deadline_header_parses_and_rejects_garbage() {
        let h = head_of("GET /healthz HTTP/1.1\r\nX-Deadline-Ms: 250\r\n\r\n").unwrap();
        assert_eq!(h.deadline_ms, Some(250));
        // Case-insensitive, like every other header.
        let h = head_of("GET /healthz HTTP/1.1\r\nx-deadline-ms: 0\r\n\r\n").unwrap();
        assert_eq!(h.deadline_ms, Some(0));
        assert_eq!(head_of("GET / HTTP/1.1\r\n\r\n").unwrap().deadline_ms, None);
        for bad in ["soon", "-5", "1.5"] {
            assert_eq!(
                head_of(&format!("GET / HTTP/1.1\r\nX-Deadline-Ms: {bad}\r\n\r\n")),
                Err(ProtoError::BadDeadline),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn robustness_responses_have_the_documented_shape() {
        let mut buf = Vec::new();
        internal_error().write_to(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 500 "), "{text}");
        assert!(text.contains("{\"error\":\"internal\"}"), "{text}");
        assert!(!text.contains("Connection: close"), "contained panic keeps the connection");

        let mut buf = Vec::new();
        quarantined("alpha").write_to(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 503 "), "{text}");
        assert!(text.contains("\"error\":\"quarantined\""), "{text}");
        assert!(text.contains("\"model\":\"alpha\""), "{text}");

        let mut buf = Vec::new();
        deadline_exceeded().write_to(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 504 "), "{text}");
        assert!(text.contains("\"error\":\"deadline_exceeded\""), "{text}");

        let mut buf = Vec::new();
        over_capacity(50).write_to(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 503 "), "{text}");
        assert!(text.contains("\"error\":\"over_capacity\""), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.contains("Connection: close"), "{text}");
    }

    #[test]
    fn json_string_escapes() {
        assert_eq!(json_string("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
    }
}
