//! Socket serving front end: the network layer over the multi-model
//! coordinator.
//!
//! ```text
//! TCP clients ──▶ acceptor ──▶ per-connection threads
//!                                │  parse (protocol) → admission permit
//!                                ▼
//!                        RoutedClient.infer ──▶ MultiCoordinator batching
//!                                │                (batches never mix models)
//!                                └──▶ response; permit released
//! ```
//!
//! Std-only (`std::net::TcpListener`, `std::thread`) — this offline build
//! has no tokio/hyper, and thread-per-connection is the honest shape for a
//! CPU-bound engine anyway: concurrency is bounded by admission control,
//! not by the connection count.
//!
//! Production rails, all testable deterministically:
//!
//! * **Bounded admission** ([`admission`]) — a request must hold a
//!   [`admission::Permit`] before entering the coordinator queue. Past the
//!   global or per-model in-flight cap ([`BatchPolicy::global_inflight_cap`]
//!   / [`BatchPolicy::model_inflight_cap`]) arrivals are shed immediately
//!   with HTTP 503 + `Retry-After` instead of buffering unboundedly:
//!   overload converts to fast rejections, not to memory growth and tail
//!   latency.
//! * **Graceful drain** — [`Server::shutdown`] stops accepting, lets every
//!   admitted request finish (bounded by
//!   [`ServeConfig::drain_timeout`]), then stops the coordinator; new
//!   arrivals during the drain get a clean `"draining"` rejection.
//!   [`Server::swap_model`] does the same per model around a registry
//!   hot-swap, and [`Server::evict_model`] around a registry eviction
//!   ([`ModelRegistry::begin_evict`] / [`ModelRegistry::finish_evict`]):
//!   in-flight requests finish on their entry snapshot, new arrivals get
//!   503 `"draining"`, and the retired model leaves a cold tombstone that
//!   [`Server::install_model`] (or the registry's LRU residency policy)
//!   can bring back — page-cache-warm for mmap-backed artifacts.
//! * **Observable tails** — `GET /metrics` exports the coordinator's
//!   log-spaced latency histograms (p50/p99/p999 per model and merged) and
//!   the admission counters in Prometheus text format; the numbers on the
//!   wire are the same [`Metrics`] the workers update in-process.
//! * **Fault containment** — a worker panic is caught coordinator-side and
//!   mapped to HTTP 500 per rider (no client ever hangs on a fault);
//!   repeated panics trip a per-model circuit breaker
//!   ([`crate::coordinator::registry::ModelRegistry::set_quarantine`]) that
//!   answers 503 `"quarantined"` without touching the engine until a
//!   hot-swap readmits the model. Requests carry deadlines
//!   (`X-Deadline-Ms`, default [`ServeConfig::request_deadline`]) and are
//!   shed pre-execution with 504 once expired. Idle keep-alive connections
//!   time out ([`ServeConfig::keep_alive_timeout`]) and the acceptor caps
//!   concurrent connections ([`ServeConfig::max_connections`]), so slow or
//!   absent clients cannot pin every connection thread.
//!
//! Protocol details (endpoints, error mapping, wire format) live in
//! [`protocol`]; a std-only client for tests/benches/probes in [`client`].

pub mod admission;
pub mod client;
pub mod protocol;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::{BatchPolicy, MultiCoordinator, Outcome, RoutedClient};
use crate::sync::lock_recover;
use crate::tensor::Tensor;
use admission::{Admission, AdmissionConfig, Shed};
use anyhow::{ensure, Context, Result};
use protocol::{
    bad_request, deadline_exceeded, decode_f32_body, draining, encode_f32_body, find_head_end,
    internal_error, json_string, method_not_allowed, not_found, over_capacity, overloaded,
    parse_head, payload_too_large, quarantined, ProtoError, RequestHead, Response, MAX_HEAD_BYTES,
};
use std::collections::{HashMap, HashSet};
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Front-end configuration (the coordinator side is [`BatchPolicy`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Retry hint attached to shed rejections.
    pub retry_after_ms: u64,
    /// Request-body cap (pre-admission; an oversized `Content-Length` is
    /// rejected before any body byte is read).
    pub max_body_bytes: usize,
    /// Socket read quantum: how often an idle connection thread rechecks
    /// the shutdown flag. Bounds shutdown latency, not request latency.
    pub poll_interval: Duration,
    /// Budget for reading one request (head + body) once its first byte
    /// has arrived; a stalled sender is cut off with 400, freeing the
    /// thread.
    pub request_timeout: Duration,
    /// Upper bound on waiting for in-flight requests during
    /// [`Server::shutdown`] / [`Server::swap_model`].
    pub drain_timeout: Duration,
    /// How long an idle keep-alive connection (no request in progress) may
    /// sit before the server closes it. Without this bound, clients that
    /// open connections and go silent pin a thread each, forever.
    pub keep_alive_timeout: Duration,
    /// Default completion deadline applied to every inference request that
    /// does not carry its own `X-Deadline-Ms` header. Requests still
    /// queued past their deadline are shed pre-execution with HTTP 504.
    /// Zero disables the default (header-less requests then wait however
    /// long batching takes). CLI: `iaoi serve --request-deadline-ms N`.
    pub request_deadline: Duration,
    /// Cap on concurrently open connections; past it the acceptor answers
    /// 503 `"over_capacity"` and closes immediately, so a connection flood
    /// degrades into fast rejections instead of thread exhaustion.
    /// 0 = unbounded. CLI: `iaoi serve --max-connections N`.
    pub max_connections: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            retry_after_ms: 25,
            max_body_bytes: protocol::DEFAULT_MAX_BODY_BYTES,
            poll_interval: Duration::from_millis(50),
            request_timeout: Duration::from_secs(5),
            drain_timeout: Duration::from_secs(30),
            keep_alive_timeout: Duration::from_secs(60),
            request_deadline: Duration::from_secs(5),
            max_connections: 0,
        }
    }
}

/// Shared server state, one `Arc` across acceptor + connection threads.
struct ServerState {
    registry: ModelRegistry,
    client: RoutedClient,
    admission: Arc<Admission>,
    /// The coordinator workers' live per-model metrics map.
    metrics: Arc<Mutex<HashMap<String, Metrics>>>,
    shutting_down: AtomicBool,
    /// Models currently draining for a hot-swap: requests for them are
    /// rejected while the swap waits out their in-flight work.
    draining: Mutex<HashSet<String>>,
    /// Live connection gauge (exported as `iaoi_open_connections`); the
    /// acceptor enforces [`ServeConfig::max_connections`] against it.
    open_conns: AtomicUsize,
    started: Instant,
    cfg: ServeConfig,
}

impl ServerState {
    fn is_draining(&self, model: &str) -> bool {
        lock_recover(&self.draining).contains(model)
    }
}

/// What [`Server::shutdown`] observed.
#[derive(Debug)]
pub struct ShutdownReport {
    /// Final per-model coordinator metrics (sorted by model name).
    pub metrics: Vec<Metrics>,
    /// Requests ever admitted (each either completed or is in `metrics`).
    pub admitted: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// True when every in-flight request finished inside
    /// [`ServeConfig::drain_timeout`].
    pub drained_clean: bool,
}

/// The running socket front end. Dropping it without calling
/// [`Self::shutdown`] leaks the acceptor/connection threads until process
/// exit — always shut down explicitly.
pub struct Server {
    state: Arc<ServerState>,
    local_addr: SocketAddr,
    coord: Option<MultiCoordinator>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Server {
    /// Bind `cfg.addr`, start a [`MultiCoordinator`] over `registry`, and
    /// begin accepting connections. The admission caps come from
    /// `policy.global_inflight_cap` / `policy.model_inflight_cap`.
    pub fn start(
        registry: ModelRegistry,
        policy: BatchPolicy,
        workers: usize,
        cfg: ServeConfig,
    ) -> Result<Server> {
        ensure!(!registry.is_empty(), "refusing to serve an empty model registry");
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding listener on {}", cfg.addr))?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let coord = MultiCoordinator::start(registry.clone(), policy, workers);
        let admission = Arc::new(Admission::new(AdmissionConfig {
            global_cap: policy.global_inflight_cap,
            model_cap: policy.model_inflight_cap,
        }));
        let state = Arc::new(ServerState {
            registry,
            client: coord.client(),
            admission,
            metrics: coord.metrics_handle(),
            shutting_down: AtomicBool::new(false),
            draining: Mutex::new(HashSet::new()),
            open_conns: AtomicUsize::new(0),
            started: Instant::now(),
            cfg,
        });
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));

        let acceptor = {
            let state = Arc::clone(&state);
            let conns = Arc::clone(&conns);
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    // Checked after each accept: shutdown() sets the flag and
                    // then self-connects to pop the acceptor out of accept().
                    if state.shutting_down.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut stream) = stream else { continue };
                    // Prune finished handles so a long-lived server's join
                    // list doesn't grow with every connection ever seen.
                    lock_recover(&conns).retain(|h| !h.is_finished());
                    let cap = state.cfg.max_connections;
                    if cap > 0 && state.open_conns.load(Ordering::SeqCst) >= cap {
                        // Refuse at the door: a bounded write of the 503 and
                        // an immediate close, so the flood cannot pin the
                        // acceptor either.
                        let _ =
                            stream.set_write_timeout(Some(Duration::from_millis(250)));
                        let _ = over_capacity(state.cfg.retry_after_ms).write_to(&mut stream);
                        continue;
                    }
                    state.open_conns.fetch_add(1, Ordering::SeqCst);
                    let state = Arc::clone(&state);
                    let handle = std::thread::spawn(move || {
                        handle_connection(&state, stream);
                        state.open_conns.fetch_sub(1, Ordering::SeqCst);
                    });
                    lock_recover(&conns).push(handle);
                }
            })
        };

        Ok(Server { state, local_addr, coord: Some(coord), acceptor: Some(acceptor), conns })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The shared registry handle.
    pub fn registry(&self) -> ModelRegistry {
        self.state.registry.clone()
    }

    /// The admission state (tests hold permits through this to force
    /// deterministic shed/drain scenarios).
    pub fn admission(&self) -> Arc<Admission> {
        Arc::clone(&self.state.admission)
    }

    /// Snapshot of per-model coordinator metrics, sorted by model name.
    pub fn metrics(&self) -> Vec<Metrics> {
        let guard = lock_recover(&self.state.metrics);
        let mut out: Vec<Metrics> = guard.values().cloned().collect();
        out.sort_by(|a, b| a.engine.cmp(&b.engine));
        out
    }

    /// Mark `model` as draining: its requests get a clean 503 `"draining"`
    /// until [`Self::end_model_drain`]. Idempotent.
    pub fn begin_model_drain(&self, model: &str) {
        lock_recover(&self.state.draining).insert(model.to_string());
    }

    /// Reopen `model` for requests after a drain.
    pub fn end_model_drain(&self, model: &str) {
        lock_recover(&self.state.draining).remove(model);
    }

    /// Drain-then-swap: reject new requests for `model`, wait for its
    /// in-flight requests to finish (bounded by
    /// [`ServeConfig::drain_timeout`]), hot-swap the registry entry from
    /// `path`, and reopen. The registry swap itself is atomic either way —
    /// the drain guarantees no request *admitted before the swap* is still
    /// queued when the new version goes live, so a version rollout has a
    /// clean cutover point. Reopens the model even when the swap fails.
    pub fn swap_model(&self, model: &str, path: &Path) -> Result<(Option<u32>, u32)> {
        self.begin_model_drain(model);
        let deadline = Instant::now() + self.state.cfg.drain_timeout;
        while self.state.admission.model_inflight(model) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let result = self.state.registry.swap(model, path);
        self.end_model_drain(model);
        result
    }

    /// Drain-then-evict: refuse new requests for `model`
    /// (registry-level via [`ModelRegistry::begin_evict`] *and*
    /// front-end-level via the draining set), wait out its in-flight
    /// requests (bounded by [`ServeConfig::drain_timeout`]), then drop the
    /// registry entry, leaving a reinstallable cold tombstone. Batches
    /// already formed keep their entry snapshot; requests still queued when
    /// the entry vanishes are answered HTTP 500, never dropped. Returns the
    /// retired version.
    pub fn evict_model(&self, model: &str) -> Result<u32> {
        self.begin_model_drain(model);
        let result = (|| {
            self.state.registry.begin_evict(model)?;
            let deadline = Instant::now() + self.state.cfg.drain_timeout;
            while self.state.admission.model_inflight(model) > 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            self.state.registry.finish_evict(model)
        })();
        self.end_model_drain(model);
        result
    }

    /// Install (or reinstall) a model from an artifact file while serving.
    /// Registration is atomic — the first request after this call sees the
    /// new entry — and the registry's residency policy may evict an LRU
    /// victim to make room. Returns `(name, version)`.
    pub fn install_model(&self, path: &Path) -> Result<(String, u32)> {
        let entry = self.state.registry.register_file(path)?;
        Ok((entry.name.clone(), entry.version))
    }

    /// Graceful shutdown: stop accepting, finish every admitted request,
    /// stop the coordinator, join all threads.
    ///
    /// Ordering note: the drain wait below cannot miss an admitted request.
    /// Admission increments its in-flight counter *before* re-checking the
    /// shutdown flag (both SeqCst), and this method sets the flag before
    /// reading the counter — so every acquirer either observes the flag
    /// (and releases with a `"draining"` rejection) or its permit is
    /// visible to the wait.
    pub fn shutdown(mut self) -> ShutdownReport {
        self.state.shutting_down.store(true, Ordering::SeqCst);
        // Pop the acceptor out of its blocking accept().
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let deadline = Instant::now() + self.state.cfg.drain_timeout;
        while self.state.admission.global_inflight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let drained_clean = self.state.admission.global_inflight() == 0;
        // The coordinator's own shutdown drains anything already queued, so
        // even a timed-out drain loses no admitted work.
        let metrics = match self.coord.take() {
            Some(c) => c.shutdown(),
            None => Vec::new(),
        };
        // Connection threads see the flag at their next poll tick; their
        // final response writes complete before we return.
        let handles: Vec<_> = {
            let mut guard = lock_recover(&self.conns);
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        ShutdownReport {
            metrics,
            admitted: self.state.admission.global().admitted(),
            shed: self.state.admission.global().shed(),
            drained_clean,
        }
    }
}

/// One connection's request loop (keep-alive until error, `Connection:
/// close`, EOF, or shutdown). Every protocol error is answered and closes
/// only this connection; the acceptor and other connections are untouched.
fn handle_connection(state: &Arc<ServerState>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(state.cfg.poll_interval)).is_err() {
        return;
    }
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        match read_request(state, &mut stream, &mut buf) {
            Ok(Some((head, body))) => {
                let response = handle_request(state, &head, &body);
                let keep = head.keep_alive && response.keep_alive;
                if response.write_to(&mut stream).is_err() || !keep {
                    return;
                }
            }
            // Clean EOF between requests, or idle shutdown.
            Ok(None) => return,
            Err(response) => {
                let _ = response.write_to(&mut stream);
                return;
            }
        }
    }
}

/// Read one request off the stream. `buf` carries pipelined bytes between
/// calls. `Ok(None)` = connection is done (EOF / shutdown while idle);
/// `Err(response)` = protocol violation, answer and close.
fn read_request(
    state: &Arc<ServerState>,
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
) -> Result<Option<(RequestHead, Vec<u8>)>, Box<Response>> {
    let mut chunk = [0u8; 4096];
    let mut waited = Duration::ZERO;
    let mut idle = Duration::ZERO;
    let head_end = loop {
        if let Some(end) = find_head_end(buf) {
            break end;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(Box::new(bad_request("request head too large")));
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None); // clean close between requests
                }
                return Err(Box::new(bad_request("connection closed mid-request")));
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if buf.is_empty() {
                    // Idle keep-alive connection: ends on shutdown or once
                    // it has been silent for keep_alive_timeout (a client
                    // that connects and goes away must not pin this thread
                    // — and, under --max-connections, a whole slot —
                    // indefinitely).
                    if state.shutting_down.load(Ordering::SeqCst) {
                        return Ok(None);
                    }
                    idle += state.cfg.poll_interval;
                    if idle >= state.cfg.keep_alive_timeout {
                        return Ok(None);
                    }
                    continue;
                }
                // A request has started: it must finish within the budget
                // (a stalled sender must not pin this thread forever).
                waited += state.cfg.poll_interval;
                if waited >= state.cfg.request_timeout {
                    return Err(Box::new(bad_request("timed out reading request")));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return Ok(None), // reset/abort: nothing to answer
        }
    };

    let head = parse_head(&buf[..head_end], state.cfg.max_body_bytes).map_err(|e| match e {
        ProtoError::BodyTooLarge { declared, cap } => Box::new(payload_too_large(declared, cap)),
        other => Box::new(bad_request(&other.to_string())),
    })?;

    let total = head_end + head.content_length;
    let mut waited = Duration::ZERO;
    while buf.len() < total {
        match stream.read(&mut chunk) {
            Ok(0) => {
                return Err(Box::new(bad_request(&format!(
                    "connection closed mid-body ({} of {} bytes)",
                    buf.len() - head_end,
                    head.content_length
                ))))
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                waited += state.cfg.poll_interval;
                if waited >= state.cfg.request_timeout {
                    return Err(Box::new(bad_request("timed out reading request body")));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return Ok(None),
        }
    }
    let body = buf[head_end..total].to_vec();
    // Anything past this request stays buffered for the next one.
    buf.drain(..total);
    Ok(Some((head, body)))
}

/// Route one parsed request to its handler.
fn handle_request(state: &Arc<ServerState>, head: &RequestHead, body: &[u8]) -> Response {
    match (head.method.as_str(), head.target.as_str()) {
        ("GET", "/healthz") => healthz(state),
        ("GET", "/metrics") => metrics_page(state),
        (_, "/healthz") | (_, "/metrics") => method_not_allowed(),
        ("POST", target) if target.starts_with("/infer/") => {
            infer(state, &target["/infer/".len()..], head, body)
        }
        (_, target) if target.starts_with("/infer/") => method_not_allowed(),
        (_, target) => not_found(&format!("unknown path {target}")),
    }
}

/// `POST /infer/<model>`: validate → admit → execute → reply.
fn infer(state: &Arc<ServerState>, model: &str, head: &RequestHead, body: &[u8]) -> Response {
    if state.shutting_down.load(Ordering::SeqCst) {
        return draining("server");
    }
    if state.is_draining(model) {
        return draining(model);
    }
    let Ok(entry) = state.registry.resolve(model) else {
        return not_found(&format!(
            "unknown model {model:?} (registered: {:?})",
            state.registry.names()
        ));
    };
    // Circuit breaker, checked before admission: a quarantined model burns
    // neither a permit nor engine time.
    if state.registry.is_quarantined(model) {
        return quarantined(model);
    }
    let want: usize = entry.input_shape.iter().product();
    let values = match decode_f32_body(body, want) {
        Ok(v) => v,
        Err(msg) => return bad_request(&msg),
    };
    let permit = match state.admission.try_acquire(model) {
        Ok(p) => p,
        Err(Shed::Global { .. }) => return overloaded(state.cfg.retry_after_ms, "global"),
        Err(Shed::Model { .. }) => return overloaded(state.cfg.retry_after_ms, "model"),
    };
    // Re-check *after* acquiring: pairs with the drain waits (see
    // [`Server::shutdown`]) so no admitted request can slip past a drain.
    if state.shutting_down.load(Ordering::SeqCst) {
        drop(permit);
        return draining("server");
    }
    if state.is_draining(model) {
        drop(permit);
        return draining(model);
    }
    // Per-request deadline: the client's X-Deadline-Ms budget wins;
    // otherwise the configured default (zero = none). Workers shed
    // requests still queued past it, pre-execution, with 504.
    let deadline = match head.deadline_ms {
        Some(ms) => Some(Instant::now() + Duration::from_millis(ms)),
        None => (!state.cfg.request_deadline.is_zero())
            .then(|| Instant::now() + state.cfg.request_deadline),
    };
    let image = Tensor::from_vec(&entry.batched_shape(1), values);
    let result = state.client.infer_with_deadline(model, image, deadline);
    drop(permit);
    match result {
        Ok(r) => match &r.outcome {
            Outcome::Ok(output) => Response::octets(200, "OK", encode_f32_body(output))
                .header("X-Model-Version", r.version)
                .header("X-Batch-Size", r.batch_size)
                .header("X-Latency-Us", r.latency.as_micros()),
            // The batch panicked; the worker contained it and kept serving,
            // so the connection stays usable.
            Outcome::Failed => internal_error(),
            Outcome::Expired => deadline_exceeded(),
        },
        // Only reachable when the coordinator is stopping underneath us.
        Err(_) => draining("server"),
    }
}

/// `GET /healthz`: overall + per-model status as JSON.
fn healthz(state: &Arc<ServerState>) -> Response {
    let shutting_down = state.shutting_down.load(Ordering::SeqCst);
    let overall = if shutting_down { "draining" } else { "serving" };
    let mut body = format!(
        "{{\"status\":\"{overall}\",\"uptime_ms\":{},\"kernel\":\"{}\",\"models\":[",
        state.started.elapsed().as_millis(),
        crate::gemm::dispatch::active().name
    );
    let mut first = true;
    for name in state.registry.names().iter() {
        let Some(entry) = state.registry.get(name) else { continue };
        // Quarantine outranks draining: it says the model is *broken*, not
        // merely paused for a swap.
        let status = if state.registry.is_quarantined(name) {
            "quarantined"
        } else if shutting_down || state.is_draining(name) {
            "draining"
        } else {
            "serving"
        };
        // Lifecycle facet, orthogonal to status: `evicting` = drain in
        // progress (still answering its in-flight snapshots), `resident` =
        // fully installed. Evicted models appear below as `cold`.
        let resident = if state.registry.is_evicting(name) { "evicting" } else { "resident" };
        if !first {
            body.push(',');
        }
        first = false;
        body.push_str(&format!(
            "{{\"name\":{},\"version\":{},\"input_shape\":[{},{},{}],\"status\":\"{status}\",\"resident\":\"{resident}\",\"load_mode\":\"{}\",\"plan_bytes\":{},\"fused_nodes\":{},\"inflight\":{},\"panics\":{}}}",
            json_string(name),
            entry.version,
            entry.input_shape[0],
            entry.input_shape[1],
            entry.input_shape[2],
            entry.load_mode_label(),
            entry.plan_bytes(),
            entry.plan.fused_nodes(),
            state.admission.model_inflight(name),
            state.registry.panic_count(name),
        ));
    }
    // Cold tombstones: evicted but reinstallable (by name or by the LRU
    // policy), reported so a fleet dashboard can see the full roster.
    for name in state.registry.cold_names() {
        let Some(cold) = state.registry.cold_entry(&name) else { continue };
        if !first {
            body.push(',');
        }
        first = false;
        body.push_str(&format!(
            "{{\"name\":{},\"version\":{},\"status\":\"cold\",\"resident\":\"cold\",\"load_mode\":\"{}\",\"plan_bytes\":0}}",
            json_string(&name),
            cold.version,
            cold.load.label(),
        ));
    }
    body.push_str("]}");
    Response::json(200, "OK", body)
}

/// `GET /metrics`: Prometheus text exposition of coordinator metrics
/// (per model + `_all` merge) and admission counters.
fn metrics_page(state: &Arc<ServerState>) -> Response {
    use std::fmt::Write;
    let mut out = String::new();
    let mut merged = Metrics::new("_all");
    {
        let guard = lock_recover(&state.metrics);
        let mut names: Vec<&String> = guard.keys().collect();
        names.sort();
        for name in names {
            let m = &guard[name];
            m.prometheus_into(name, &mut out);
            merged.merge(m);
        }
    }
    merged.prometheus_into("_all", &mut out);
    let g = state.admission.global();
    let _ = writeln!(out, "iaoi_inflight{{scope=\"global\"}} {}", g.inflight());
    let _ = writeln!(out, "iaoi_admitted_total{{scope=\"global\"}} {}", g.admitted());
    let _ = writeln!(out, "iaoi_shed_total{{scope=\"global\"}} {}", g.shed());
    for (model, inflight, admitted, shed) in state.admission.per_model_stats() {
        let _ = writeln!(out, "iaoi_inflight{{model=\"{model}\"}} {inflight}");
        let _ = writeln!(out, "iaoi_admitted_total{{model=\"{model}\"}} {admitted}");
        let _ = writeln!(out, "iaoi_shed_total{{model=\"{model}\"}} {shed}");
    }
    for name in state.registry.names() {
        let q = u8::from(state.registry.is_quarantined(&name));
        let _ = writeln!(out, "iaoi_quarantined{{model=\"{name}\"}} {q}");
    }
    // Fleet lifecycle: how many models are resident, how many evictions the
    // residency policy (or explicit evicts) have performed, and each
    // resident model's packed-plan heap footprint (0 until a lazy plan's
    // first touch; view-backed lazy plans never count the mapped bytes).
    let _ = writeln!(out, "iaoi_resident_models {}", state.registry.len());
    let _ = writeln!(out, "iaoi_evictions_total {}", state.registry.evictions_total());
    for name in state.registry.names() {
        if let Some(entry) = state.registry.get(&name) {
            let _ = writeln!(out, "iaoi_plan_bytes{{model=\"{name}\"}} {}", entry.plan_bytes());
        }
    }
    let _ = writeln!(out, "iaoi_open_connections {}", state.open_conns.load(Ordering::SeqCst));
    // Which GEMM micro-kernel this process dispatched to (info-style gauge:
    // value is always 1, the label carries the name) — lets a deployed
    // fleet confirm every box is on its fast path.
    let _ = writeln!(out, "iaoi_kernel{{name=\"{}\"}} 1", crate::gemm::dispatch::active().name);
    let _ = writeln!(out, "iaoi_uptime_seconds {}", state.started.elapsed().as_secs());
    Response::text(200, "OK", out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.addr, "127.0.0.1:0");
        assert!(cfg.retry_after_ms > 0);
        assert!(cfg.poll_interval < cfg.request_timeout);
        assert!(cfg.request_timeout < cfg.drain_timeout);
        assert!(cfg.poll_interval < cfg.keep_alive_timeout);
        assert!(!cfg.request_deadline.is_zero(), "deadlines default on");
        assert_eq!(cfg.max_connections, 0, "connection cap defaults off");
    }

    #[test]
    fn empty_registry_is_refused() {
        let err = Server::start(
            ModelRegistry::new(),
            BatchPolicy::default(),
            1,
            ServeConfig::default(),
        );
        assert!(err.is_err());
    }
}
