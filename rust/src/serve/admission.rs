//! Bounded admission for the socket front end.
//!
//! The coordinator's mpsc queue is unbounded: in-process callers are
//! closed-loop, so their concurrency self-limits. An open network is not —
//! under overload an unbounded queue just converts excess offered load into
//! unbounded memory and unbounded tail latency. This module adds the
//! missing backpressure: a request must [`Admission::try_acquire`] a
//! [`Permit`] before it may enter the coordinator queue; when the global or
//! per-model in-flight cap is hit the request is *shed* immediately with a
//! retry-after payload (HTTP 503) instead of being buffered.
//!
//! The accounting is two `fetch_add`/`fetch_sub` pairs per request — no
//! lock on the hot path (the per-model counter map takes a lock only the
//! first time a model is seen). Permits release on `Drop`, so every exit
//! path (reply written, connection reset, handler panic) returns capacity.

use crate::sync::lock_recover;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// In-flight caps. 0 = unbounded (that dimension never sheds).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionConfig {
    /// Max requests in flight across all models.
    pub global_cap: usize,
    /// Max requests in flight per model.
    pub model_cap: usize,
}

/// In-flight + lifetime counters for one scope (global, or one model).
#[derive(Debug, Default)]
pub struct Counters {
    inflight: AtomicUsize,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl Counters {
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::SeqCst)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::SeqCst)
    }
}

/// Why a request was refused admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shed {
    /// The global in-flight cap is saturated.
    Global { cap: usize },
    /// This model's in-flight cap is saturated.
    Model { cap: usize },
}

/// Shared admission state. Cheap to clone via `Arc`.
#[derive(Debug)]
pub struct Admission {
    cfg: AdmissionConfig,
    global: Counters,
    per_model: Mutex<HashMap<String, Arc<Counters>>>,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Self {
        Self { cfg, global: Counters::default(), per_model: Mutex::new(HashMap::new()) }
    }

    pub fn config(&self) -> AdmissionConfig {
        self.cfg
    }

    fn model_counters(&self, model: &str) -> Arc<Counters> {
        let mut map = lock_recover(&self.per_model);
        Arc::clone(map.entry(model.to_string()).or_default())
    }

    /// Try to admit one request for `model`. On success the returned
    /// [`Permit`] holds one slot in both the global and the model counter
    /// until dropped; on refusal both shed counters are bumped and nothing
    /// is held.
    ///
    /// Optimistic acquire: increment first, then check-and-undo. Two racing
    /// arrivals at the last slot can therefore both observe `> cap` and
    /// both shed — admission may momentarily under-fill, but the cap is
    /// never exceeded, which is the invariant overload protection needs.
    pub fn try_acquire(self: &Arc<Self>, model: &str) -> Result<Permit, Shed> {
        let m = self.model_counters(model);
        let g_now = self.global.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        if self.cfg.global_cap > 0 && g_now > self.cfg.global_cap {
            self.global.inflight.fetch_sub(1, Ordering::SeqCst);
            self.global.shed.fetch_add(1, Ordering::SeqCst);
            m.shed.fetch_add(1, Ordering::SeqCst);
            return Err(Shed::Global { cap: self.cfg.global_cap });
        }
        let m_now = m.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        if self.cfg.model_cap > 0 && m_now > self.cfg.model_cap {
            m.inflight.fetch_sub(1, Ordering::SeqCst);
            self.global.inflight.fetch_sub(1, Ordering::SeqCst);
            self.global.shed.fetch_add(1, Ordering::SeqCst);
            m.shed.fetch_add(1, Ordering::SeqCst);
            return Err(Shed::Model { cap: self.cfg.model_cap });
        }
        self.global.admitted.fetch_add(1, Ordering::SeqCst);
        m.admitted.fetch_add(1, Ordering::SeqCst);
        Ok(Permit { admission: Arc::clone(self), model: m })
    }

    /// Fleet-wide counters.
    pub fn global(&self) -> &Counters {
        &self.global
    }

    /// Requests currently holding permits, across all models.
    pub fn global_inflight(&self) -> usize {
        self.global.inflight()
    }

    /// Requests currently holding permits for `model` (0 if never seen).
    pub fn model_inflight(&self, model: &str) -> usize {
        let map = lock_recover(&self.per_model);
        map.get(model).map(|c| c.inflight()).unwrap_or(0)
    }

    /// `(model, inflight, admitted, shed)` rows, sorted by model name.
    pub fn per_model_stats(&self) -> Vec<(String, usize, u64, u64)> {
        let map = lock_recover(&self.per_model);
        let mut rows: Vec<_> = map
            .iter()
            .map(|(k, c)| (k.clone(), c.inflight(), c.admitted(), c.shed()))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }
}

/// One admitted request's capacity slot. Dropping it releases the slot.
#[derive(Debug)]
pub struct Permit {
    admission: Arc<Admission>,
    model: Arc<Counters>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.model.inflight.fetch_sub(1, Ordering::SeqCst);
        self.admission.global.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_by_default() {
        let a = Arc::new(Admission::new(AdmissionConfig::default()));
        let permits: Vec<_> =
            (0..100).map(|_| a.try_acquire("m").expect("unbounded")).collect();
        assert_eq!(a.global_inflight(), 100);
        drop(permits);
        assert_eq!(a.global_inflight(), 0);
        assert_eq!(a.global().shed(), 0);
    }

    #[test]
    fn global_cap_sheds_and_recovers() {
        let a = Arc::new(Admission::new(AdmissionConfig { global_cap: 2, model_cap: 0 }));
        let p1 = a.try_acquire("m").unwrap();
        let p2 = a.try_acquire("m").unwrap();
        assert!(matches!(a.try_acquire("m"), Err(Shed::Global { cap: 2 })));
        assert_eq!(a.global().shed(), 1);
        assert_eq!(a.global_inflight(), 2, "failed acquire must not leak a slot");
        drop(p1);
        let p3 = a.try_acquire("m").expect("slot freed on drop");
        drop(p2);
        drop(p3);
        assert_eq!(a.global_inflight(), 0);
        assert_eq!(a.global().admitted(), 3);
    }

    #[test]
    fn model_cap_isolates_models() {
        let a = Arc::new(Admission::new(AdmissionConfig { global_cap: 0, model_cap: 1 }));
        let _pa = a.try_acquire("alpha").unwrap();
        assert!(matches!(a.try_acquire("alpha"), Err(Shed::Model { cap: 1 })));
        // A saturated model must not starve another model's admission.
        let _pb = a.try_acquire("beta").expect("beta unaffected");
        assert_eq!(a.model_inflight("alpha"), 1);
        assert_eq!(a.model_inflight("beta"), 1);
        assert_eq!(a.global_inflight(), 2);
        let rows = a.per_model_stats();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "alpha");
        assert_eq!(rows[0].3, 1, "alpha's shed count");
    }

    #[test]
    fn model_shed_does_not_leak_global_slot() {
        let a = Arc::new(Admission::new(AdmissionConfig { global_cap: 10, model_cap: 1 }));
        let _p = a.try_acquire("m").unwrap();
        for _ in 0..5 {
            assert!(a.try_acquire("m").is_err());
        }
        assert_eq!(a.global_inflight(), 1);
        assert_eq!(a.global().shed(), 5);
    }

    #[test]
    fn concurrent_acquire_never_exceeds_cap() {
        let cap = 8;
        let a = Arc::new(Admission::new(AdmissionConfig { global_cap: cap, model_cap: 0 }));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let a = Arc::clone(&a);
                let peak = Arc::clone(&peak);
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        if let Ok(_p) = a.try_acquire("m") {
                            let now = a.global_inflight();
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= cap, "cap exceeded: {peak:?}");
        assert_eq!(a.global_inflight(), 0, "all permits released");
    }
}
