//! ARM-core latency cost model (DESIGN.md §Hardware-Adaptation).
//!
//! The paper measures on Qualcomm Snapdragon 835 big/LITTLE and 821 big
//! cores, which this testbed does not have. The substitute has two parts:
//!
//! 1. *Measured* latency of the Rust engine (int8 vs f32) on the host CPU —
//!    real end-to-end numbers, reported by `cargo bench` and the latency
//!    harness.
//! 2. *This module*: a first-order throughput model per core type, fitted
//!    to the paper's own published numbers (Tables 4.4/4.6), that converts
//!    a model's MAC/byte profile into estimated per-core milliseconds. It
//!    regenerates the per-core *shape* of figs. 1.1c/4.1/4.2 — who wins,
//!    by what factor, and how the gap differs between the float-optimized
//!    821 and the 835.
//!
//! The model: `latency = macs / throughput(dtype) + nodes · dispatch +
//! bytes / bandwidth`, with multi-core scaling following Amdahl with a
//! model-size-dependent parallel fraction (Table 4.6 shows larger models
//! parallelize better).

use crate::graph::FloatGraph;

/// Numeric path being modelled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    Int8,
}

/// A fitted core model.
#[derive(Clone, Debug)]
pub struct ArmCoreModel {
    pub name: &'static str,
    /// Effective f32 MAC throughput, GMAC/s (single core, dense conv mix).
    pub f32_gmacs: f64,
    /// Effective int8 MAC throughput, GMAC/s.
    pub int8_gmacs: f64,
    /// Fixed per-node dispatch overhead, microseconds.
    pub dispatch_us: f64,
    /// Effective memory bandwidth for weight traffic, GB/s.
    pub mem_gbps: f64,
}

impl ArmCoreModel {
    /// Snapdragon 835 big core (Pixel 2 performance cluster). Fitted to the
    /// paper's face-detector numbers: DM=1.0 float 337 ms vs int8 154 ms.
    pub fn s835_big() -> Self {
        Self { name: "S835-big", f32_gmacs: 2.2, int8_gmacs: 5.0, dispatch_us: 12.0, mem_gbps: 12.0 }
    }

    /// Snapdragon 835 LITTLE core (efficiency cluster): ~2.2× slower than
    /// big with a similar int8:f32 ratio (711 ms vs 372 ms at DM=1.0).
    pub fn s835_little() -> Self {
        Self { name: "S835-LITTLE", f32_gmacs: 1.0, int8_gmacs: 2.3, dispatch_us: 25.0, mem_gbps: 5.0 }
    }

    /// Snapdragon 821 big core (Pixel 1): floating-point is better
    /// optimized relative to integer (§4.2.1: "less noticeable reduction in
    /// latency for quantized models").
    pub fn s821_big() -> Self {
        Self { name: "S821-big", f32_gmacs: 2.6, int8_gmacs: 4.0, dispatch_us: 12.0, mem_gbps: 11.0 }
    }

    /// All three cores the paper evaluates.
    pub fn all() -> Vec<ArmCoreModel> {
        vec![Self::s835_little(), Self::s835_big(), Self::s821_big()]
    }

    /// Estimated single-core latency in milliseconds.
    pub fn latency_ms(&self, graph: &FloatGraph, input_shape: &[usize], dtype: Dtype) -> f64 {
        let macs = graph.mac_count(input_shape) as f64;
        let weight_bytes = graph.model_bytes() as f64 / if dtype == Dtype::Int8 { 4.0 } else { 1.0 };
        let gmacs = match dtype {
            Dtype::F32 => self.f32_gmacs,
            Dtype::Int8 => self.int8_gmacs,
        };
        let compute_ms = macs / (gmacs * 1e9) * 1e3;
        let dispatch_ms = graph.nodes.len() as f64 * self.dispatch_us / 1e3;
        let mem_ms = weight_bytes / (self.mem_gbps * 1e9) * 1e3;
        compute_ms + dispatch_ms + mem_ms
    }

    /// Multi-core latency (Table 4.6): Amdahl scaling with a parallel
    /// fraction that grows with model size — the paper's observation that
    /// "speedup ratios ... are higher for larger models where the overhead
    /// of multi-threading occupies a smaller fraction".
    pub fn latency_ms_multicore(
        &self,
        graph: &FloatGraph,
        input_shape: &[usize],
        dtype: Dtype,
        cores: usize,
    ) -> f64 {
        assert!(cores >= 1);
        let single = self.latency_ms(graph, input_shape, dtype);
        if cores == 1 {
            return single;
        }
        let macs = graph.mac_count(input_shape) as f64;
        let p = parallel_fraction(macs);
        single * ((1.0 - p) + p / cores as f64)
    }
}

/// Parallel fraction as a function of model MACs, fitted so a ~400-MMAC
/// detector reaches the paper's 2.2× at 4 cores and a ~25-MMAC one its
/// 1.55×: p = clamp(0.30 + 0.115·log10(macs/1e6), 0.30, 0.90).
fn parallel_fraction(macs: f64) -> f64 {
    let m = (macs / 1e6).max(1.0);
    (0.30 + 0.115 * m.log10()).clamp(0.30, 0.90)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builders::mobilenet;

    #[test]
    fn int8_beats_f32_on_835() {
        let g = mobilenet(0.5, 16, false, 1);
        for core in [ArmCoreModel::s835_big(), ArmCoreModel::s835_little()] {
            let f = core.latency_ms(&g, &[1, 64, 64, 3], Dtype::F32);
            let q = core.latency_ms(&g, &[1, 64, 64, 3], Dtype::Int8);
            let ratio = f / q;
            assert!(
                ratio > 1.5 && ratio < 3.0,
                "{}: f32 {f:.2}ms int8 {q:.2}ms ratio {ratio:.2}",
                core.name
            );
        }
    }

    #[test]
    fn s821_gap_is_smaller_than_s835() {
        // The paper's point about fig. 4.2: the float-optimized 821 shows a
        // smaller int8 win than the 835.
        let g = mobilenet(1.0, 16, false, 1);
        let shape = [1usize, 96, 96, 3];
        let r835 = {
            let c = ArmCoreModel::s835_big();
            c.latency_ms(&g, &shape, Dtype::F32) / c.latency_ms(&g, &shape, Dtype::Int8)
        };
        let r821 = {
            let c = ArmCoreModel::s821_big();
            c.latency_ms(&g, &shape, Dtype::F32) / c.latency_ms(&g, &shape, Dtype::Int8)
        };
        assert!(r821 < r835, "821 ratio {r821:.2} must be below 835 ratio {r835:.2}");
    }

    #[test]
    fn little_core_is_slower_than_big() {
        let g = mobilenet(0.5, 16, false, 2);
        let shape = [1usize, 64, 64, 3];
        let big = ArmCoreModel::s835_big().latency_ms(&g, &shape, Dtype::Int8);
        let little = ArmCoreModel::s835_little().latency_ms(&g, &shape, Dtype::Int8);
        assert!(little > 1.5 * big, "LITTLE {little:.2} vs big {big:.2}");
    }

    #[test]
    fn multicore_speedup_matches_table_4_6_shape() {
        let big_model = mobilenet(1.0, 16, false, 3);
        let small_model = mobilenet(0.25, 16, false, 3);
        let core = ArmCoreModel::s835_big();
        let sp = |g: &FloatGraph, res: usize| {
            let s1 = core.latency_ms_multicore(g, &[1, res, res, 3], Dtype::Int8, 1);
            let s4 = core.latency_ms_multicore(g, &[1, res, res, 3], Dtype::Int8, 4);
            s1 / s4
        };
        let big_speedup = sp(&big_model, 160);
        let small_speedup = sp(&small_model, 64);
        assert!(big_speedup > small_speedup, "big {big_speedup:.2} vs small {small_speedup:.2}");
        assert!(big_speedup > 1.5 && big_speedup < 2.6, "{big_speedup:.2}");
        assert!(small_speedup > 1.2, "{small_speedup:.2}");
    }

    #[test]
    fn latency_monotone_in_resolution_and_dm() {
        let core = ArmCoreModel::s835_little();
        let small = mobilenet(0.25, 16, false, 4);
        let big = mobilenet(1.0, 16, false, 4);
        let l_small = core.latency_ms(&small, &[1, 96, 96, 3], Dtype::Int8);
        let l_big = core.latency_ms(&big, &[1, 96, 96, 3], Dtype::Int8);
        assert!(l_big > l_small);
        let l_lowres = core.latency_ms(&big, &[1, 64, 64, 3], Dtype::Int8);
        assert!(l_big > l_lowres);
    }
}
