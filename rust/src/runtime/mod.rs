//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via the
//! `xla` crate. This is the only place the Rust side touches XLA; the
//! integer inference engine ([`crate::nn`]/[`crate::graph`]) never does.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits serialized protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §9).

use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus a cache of compiled executables, keyed by
/// artifact file name.
pub struct Engine {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine rooted at the artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            executables: HashMap::new(),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by name).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path is not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("{e:?}"))
        .with_context(|| format!("parse HLO text {path:?} (run `make artifacts`)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("compile {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on positional literal inputs. The AOT side lowers
    /// with `return_tuple=True`, so the single output is a tuple that we
    /// decompose into one literal per logical output.
    pub fn run(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(name)?;
        let exe = self.executables.get(name).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("execute {name}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .context("fetch result")?;
        out.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}")).context("decompose result tuple")
    }
}

/// Convert an f32 tensor into an XLA literal of the same shape.
pub fn literal_f32(t: &Tensor<f32>) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data()).reshape(&dims).map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Convert an i32 slice into an XLA literal of the given dims.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data).reshape(dims).map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Convert a u8 slice into an XLA literal of the given dims. `u8` is not a
/// `NativeType` in the xla crate, so this goes through the untyped-bytes
/// constructor.
pub fn literal_u8(data: &[u8], dims: &[i64]) -> Result<xla::Literal> {
    let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, &dims_usize, data)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Scalar f32 literal.
pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read an XLA literal back into an f32 tensor.
pub fn tensor_from_literal(lit: &xla::Literal) -> Result<Tensor<f32>> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Read a u8 literal back into a tensor.
pub fn u8_tensor_from_literal(lit: &xla::Literal) -> Result<Tensor<u8>> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<u8>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Read a scalar f32 from a literal.
pub fn scalar_from_literal(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0])
}
